"""Model zoo (paper Table 3) -> data/model_zoo.json.

Holds the paper's measured per-task attributes verbatim (batch size,
#GPUs, epoch time, epochs, peak GPU memory) plus the architecture
features the estimators consume.  ``acts_m`` is *calibrated* so that
``memsim(features)`` reproduces the paper's measured memory (DESIGN.md
§1: memsim is our substitute for nvidia-smi, so calibrating the single
free parameter to the published measurements keeps the estimators honest
— they are trained on synthetic models and evaluated on these unseen
"real" ones).

Run ``python -m compile.zoo`` from ``python/`` to regenerate the file.
"""

from __future__ import annotations

import json
import math
import os

from . import memsim
from .memsim import MIB, GIB, TaskFeatures

# name, dataset, class, bs, gpus, epoch_time_min, epochs, mem_gb, params_m,
# n_linear, n_conv, n_bn, activation, input_dim, output_dim, seq/spatial,
# depth, width_max, smact, membw
_TRANSFORMERS = [
    # Table 3(a): Transformer (WikiText-2) - heavy
    ("xlnet_base", "wikitext2", "heavy", 8, 2, 8.95, [8], 9.72, 117.0, 74, 0, 25, "gelu", 32000, 32000, 512, 12, 768, 0.43, 0.39),
    ("bert_base", "wikitext2", "heavy", 32, 1, 14.87, [1], 20.77, 110.0, 74, 0, 25, "gelu", 30522, 30522, 512, 12, 768, 0.56, 0.48),
    ("xlnet_large", "wikitext2", "heavy", 4, 2, 25.31, [3], 14.55, 360.0, 146, 0, 49, "gelu", 32000, 32000, 512, 24, 1024, 0.51, 0.45),
    ("bert_large", "wikitext2", "heavy", 8, 1, 44.93, [1], 13.57, 340.0, 146, 0, 49, "gelu", 30522, 30522, 512, 24, 1024, 0.61, 0.51),
    ("gpt2_large", "wikitext2", "heavy", 8, 2, 64.96, [1], 27.90, 774.0, 218, 36, 73, "gelu", 50257, 50257, 1024, 36, 1280, 0.64, 0.56),
]

_IMAGENET_CNNS = [
    # Table 3(b): CNN (ImageNet) - medium / heavy
    ("efficientnet_b0", "imagenet", "medium", 32, 1, 36.21, [1], 4.96, 5.3, 1, 81, 81, "silu", 150528, 1000, 224, 82, 1280, 0.39, 0.35),
    ("efficientnet_b0", "imagenet", "medium", 64, 1, 35.41, [1], 7.84, 5.3, 1, 81, 81, "silu", 150528, 1000, 224, 82, 1280, 0.44, 0.39),
    ("efficientnet_b0", "imagenet", "medium", 128, 1, 35.21, [1], 13.83, 5.3, 1, 81, 81, "silu", 150528, 1000, 224, 82, 1280, 0.48, 0.44),
    ("resnet50", "imagenet", "medium", 32, 1, 36.32, [1], 5.26, 25.6, 1, 53, 53, "relu", 150528, 1000, 224, 54, 2048, 0.48, 0.43),
    ("resnet50", "imagenet", "medium", 64, 1, 35.50, [1], 8.54, 25.6, 1, 53, 53, "relu", 150528, 1000, 224, 54, 2048, 0.53, 0.47),
    ("resnet50", "imagenet", "medium", 128, 1, 35.01, [1], 15.12, 25.6, 1, 53, 53, "relu", 150528, 1000, 224, 54, 2048, 0.58, 0.51),
    ("mobilenet_v2", "imagenet", "medium", 32, 1, 36.09, [1], 4.54, 3.5, 1, 52, 52, "relu", 150528, 1000, 224, 53, 1280, 0.3, 0.27),
    ("mobilenet_v2", "imagenet", "medium", 64, 1, 35.43, [1], 7.22, 3.5, 1, 52, 52, "relu", 150528, 1000, 224, 53, 1280, 0.34, 0.31),
    ("mobilenet_v2", "imagenet", "medium", 128, 1, 34.91, [1], 12.58, 3.5, 1, 52, 52, "relu", 150528, 1000, 224, 53, 1280, 0.39, 0.36),
    ("vgg16", "imagenet", "medium", 32, 1, 48.45, [1], 8.22, 138.0, 3, 13, 0, "relu", 150528, 1000, 224, 16, 512, 0.66, 0.58),
    ("vgg16", "imagenet", "medium", 64, 1, 44.38, [1], 13.64, 138.0, 3, 13, 0, "relu", 150528, 1000, 224, 16, 512, 0.69, 0.61),
    ("vgg16", "imagenet", "heavy", 128, 1, 42.42, [1], 24.41, 138.0, 3, 13, 0, "relu", 150528, 1000, 224, 16, 512, 0.72, 0.66),
    ("xception", "imagenet", "medium", 32, 1, 46.86, [1], 7.20, 22.9, 1, 40, 40, "relu", 150528, 1000, 224, 41, 2048, 0.51, 0.45),
    ("xception", "imagenet", "medium", 64, 1, 45.78, [1], 11.52, 22.9, 1, 40, 40, "relu", 150528, 1000, 224, 41, 2048, 0.56, 0.5),
    ("xception", "imagenet", "heavy", 128, 1, 44.44, [1], 22.98, 22.9, 1, 40, 40, "relu", 150528, 1000, 224, 41, 2048, 0.61, 0.55),
    ("inception", "imagenet", "medium", 32, 1, 50.10, [1], 6.35, 27.2, 1, 94, 94, "relu", 150528, 1000, 299, 95, 2048, 0.47, 0.41),
    ("inception", "imagenet", "medium", 64, 1, 46.29, [1], 10.56, 27.2, 1, 94, 94, "relu", 150528, 1000, 299, 95, 2048, 0.51, 0.45),
    ("inception", "imagenet", "heavy", 128, 1, 44.85, [1], 19.02, 27.2, 1, 94, 94, "relu", 150528, 1000, 299, 95, 2048, 0.56, 0.5),
]

_CIFAR_CNNS = [
    # Table 3(c): CNN (CIFAR-100) - light; epochs is {20, 50}
    ("efficientnet_b0", "cifar100", "light", 32, 1, 0.77, [20, 50], 1.86, 4.1, 1, 81, 81, "silu", 3072, 100, 32, 82, 1280, 0.23, 0.22),
    ("efficientnet_b0", "cifar100", "light", 64, 1, 0.48, [20, 50], 1.91, 4.1, 1, 81, 81, "silu", 3072, 100, 32, 82, 1280, 0.27, 0.24),
    ("efficientnet_b0", "cifar100", "light", 128, 1, 0.27, [20, 50], 2.05, 4.1, 1, 81, 81, "silu", 3072, 100, 32, 82, 1280, 0.3, 0.27),
    ("resnet18", "cifar100", "light", 32, 1, 0.33, [20, 50], 1.96, 11.2, 1, 20, 20, "relu", 3072, 100, 32, 21, 512, 0.19, 0.17),
    ("resnet18", "cifar100", "light", 64, 1, 0.22, [20, 50], 1.97, 11.2, 1, 20, 20, "relu", 3072, 100, 32, 21, 512, 0.22, 0.2),
    ("resnet18", "cifar100", "light", 128, 1, 0.16, [20, 50], 2.01, 11.2, 1, 20, 20, "relu", 3072, 100, 32, 21, 512, 0.25, 0.22),
    ("resnet34", "cifar100", "light", 32, 1, 0.49, [20, 50], 2.15, 21.3, 1, 36, 36, "relu", 3072, 100, 32, 37, 512, 0.22, 0.2),
    ("resnet34", "cifar100", "light", 64, 1, 0.30, [20, 50], 2.17, 21.3, 1, 36, 36, "relu", 3072, 100, 32, 37, 512, 0.25, 0.22),
    ("resnet34", "cifar100", "light", 128, 1, 0.20, [20, 50], 2.19, 21.3, 1, 36, 36, "relu", 3072, 100, 32, 37, 512, 0.28, 0.25),
    ("mobilenetv3_small", "cifar100", "light", 32, 1, 0.54, [20, 50], 1.78, 2.5, 1, 52, 52, "silu", 3072, 100, 32, 53, 1024, 0.16, 0.14),
    ("mobilenetv3_small", "cifar100", "light", 64, 1, 0.32, [20, 50], 1.79, 2.5, 1, 52, 52, "silu", 3072, 100, 32, 53, 1024, 0.19, 0.16),
    ("mobilenetv3_small", "cifar100", "light", 128, 1, 0.22, [20, 50], 1.82, 2.5, 1, 52, 52, "silu", 3072, 100, 32, 53, 1024, 0.22, 0.19),
]


def _arch_of(dataset: str) -> str:
    return "transformer" if dataset == "wikitext2" else "cnn"


def _calibrate_acts_m(f: TaskFeatures, target_gb: float) -> float:
    """Solve for acts_m so memsim(features) ~= the paper's measured memory.

    Inverts the memsim formula before pool rounding; the resulting memsim
    value lands within one ACT_POOL_STEP (256 MiB) above the target.
    """
    params = f.params_m * 1e6
    per_gpu_batch = f.batch_size / max(f.n_gpus, 1.0)
    weight_pool = memsim._round_up(params * memsim.BYTES_PER_PARAM, memsim.WEIGHT_POOL_STEP)
    if f.arch == "cnn":
        ws = memsim.CONV_WORKSPACE_PER_LAYER * f.n_conv * math.sqrt(per_gpu_batch / 32.0)
    else:
        ws = memsim.GEMM_WORKSPACE
    ws_pool = memsim._round_up(ws, memsim.WORKSPACE_STEP)
    act_bytes = target_gb * GIB - memsim.CTX_BYTES - weight_pool - ws_pool
    act_bytes = max(act_bytes, 64.0 * MIB)
    acts = act_bytes / (4.0 * per_gpu_batch * memsim.ACT_FACTOR[f.arch])
    return acts / 1e6


def build_zoo() -> list[dict]:
    rows = _TRANSFORMERS + _IMAGENET_CNNS + _CIFAR_CNNS
    out = []
    for (
        name, ds, klass, bs, gpus, et_min, epochs, mem_gb, params_m,
        n_linear, n_conv, n_bn, act, in_dim, out_dim, seq_sp, depth, wmax,
        smact, membw,
    ) in rows:
        arch = _arch_of(ds)
        cos, sin = memsim.activation_encoding(act)
        f = TaskFeatures(
            arch=arch,
            n_linear=float(n_linear),
            n_conv=float(n_conv),
            n_batchnorm=float(n_bn),
            n_dropout=float(depth // 4),
            params_m=float(params_m),
            acts_m=0.0,
            batch_size=float(bs),
            n_gpus=float(gpus),
            act_cos=cos,
            act_sin=sin,
            input_dim=float(in_dim),
            output_dim=float(out_dim),
            seq_or_spatial=float(seq_sp),
            depth_total=float(depth),
            width_max=float(wmax),
        )
        f.acts_m = _calibrate_acts_m(f, mem_gb)
        sim_gb = memsim.measured_gb(f)
        out.append(
            {
                "name": name,
                "dataset": ds,
                "arch": arch,
                "weight_class": klass,
                "batch_size": bs,
                "n_gpus": gpus,
                "epoch_time_min": et_min,
                "epochs": epochs,
                "mem_gb": mem_gb,  # paper Table 3 measurement (ground truth)
                "memsim_gb": round(sim_gb, 4),
                "activation": act,
                "smact": smact,
                "membw": membw,
                "features": {
                    "n_linear": f.n_linear,
                    "n_conv": f.n_conv,
                    "n_batchnorm": f.n_batchnorm,
                    "n_dropout": f.n_dropout,
                    "params_m": f.params_m,
                    "acts_m": round(f.acts_m, 6),
                    "batch_size": f.batch_size,
                    "n_gpus": f.n_gpus,
                    "act_cos": f.act_cos,
                    "act_sin": f.act_sin,
                    "input_dim": f.input_dim,
                    "output_dim": f.output_dim,
                    "seq_or_spatial": f.seq_or_spatial,
                    "depth_total": f.depth_total,
                    "width_max": f.width_max,
                },
            }
        )
    return out


def main() -> None:
    here = os.path.dirname(os.path.abspath(__file__))
    out_path = os.path.join(here, "..", "..", "data", "model_zoo.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    zoo = build_zoo()
    with open(out_path, "w") as fh:
        json.dump({"gpu_mem_gb": 40.0, "models": zoo}, fh, indent=1)
    worst = max(abs(m["memsim_gb"] - m["mem_gb"]) for m in zoo)
    print(f"wrote {len(zoo)} zoo entries to {out_path}; worst memsim-vs-paper gap {worst:.3f} GB")


if __name__ == "__main__":
    main()
