"""Live-mode training model: a small causal-transformer LM (L2).

CARMA manages deep-learning *training* tasks.  The end-to-end example
(``examples/live_training.rs``) proves the whole stack composes by making
the Rust coordinator actually execute training steps through PJRT: this
module defines the LM forward/backward + Adam update in JAX, and
``aot.py`` lowers ``init`` and ``train_step`` to HLO text artifacts that
the Rust runtime loads and drives for a few hundred steps on synthetic
token data, logging the loss curve (EXPERIMENTS.md §E2E).

Default config is ~6 M parameters so a few hundred steps complete in
minutes on the CPU PJRT backend; ``--large`` in aot.py exports a ~110 M
variant for real-hardware runs (DESIGN.md §1).

The parameter pytree is flattened in a *fixed documented order* (see
:func:`param_names`); ``artifacts/lm_manifest.json`` records names,
shapes, and argument layout for the Rust side.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class LmConfig(NamedTuple):
    vocab: int = 4096
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    seq_len: int = 128
    batch: int = 8
    lr: float = 1e-3

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


LARGE = LmConfig(vocab=32768, d_model=768, n_layers=12, n_heads=12, d_ff=3072, seq_len=256, batch=8)


def param_names(cfg: LmConfig) -> list[str]:
    names = ["embed", "pos"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1_g", f"l{i}.ln1_b",
            f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.wo",
            f"l{i}.ln2_g", f"l{i}.ln2_b",
            f"l{i}.w1", f"l{i}.b1", f"l{i}.w2", f"l{i}.b2",
        ]
    names += ["lnf_g", "lnf_b", "head"]
    return names


def init(cfg: LmConfig, seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def lin(k, i, o):
        return (jax.random.normal(k, (i, o)) * math.sqrt(2.0 / (i + o))).astype(jnp.float32)

    ks = iter(jax.random.split(key, 4 + 12 * cfg.n_layers))
    p = {
        "embed": (jax.random.normal(next(ks), (v, d)) * 0.02).astype(jnp.float32),
        "pos": (jax.random.normal(next(ks), (cfg.seq_len, d)) * 0.02).astype(jnp.float32),
    }
    for i in range(cfg.n_layers):
        p[f"l{i}.ln1_g"] = jnp.ones((d,), jnp.float32)
        p[f"l{i}.ln1_b"] = jnp.zeros((d,), jnp.float32)
        p[f"l{i}.wq"] = lin(next(ks), d, d)
        p[f"l{i}.wk"] = lin(next(ks), d, d)
        p[f"l{i}.wv"] = lin(next(ks), d, d)
        p[f"l{i}.wo"] = lin(next(ks), d, d)
        p[f"l{i}.ln2_g"] = jnp.ones((d,), jnp.float32)
        p[f"l{i}.ln2_b"] = jnp.zeros((d,), jnp.float32)
        p[f"l{i}.w1"] = lin(next(ks), d, f)
        p[f"l{i}.b1"] = jnp.zeros((f,), jnp.float32)
        p[f"l{i}.w2"] = lin(next(ks), f, d)
        p[f"l{i}.b2"] = jnp.zeros((d,), jnp.float32)
    p["lnf_g"] = jnp.ones((d,), jnp.float32)
    p["lnf_b"] = jnp.zeros((d,), jnp.float32)
    p["head"] = lin(next(ks), d, v)
    return p


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def forward(p: dict, cfg: LmConfig, tokens):
    """tokens: i32[B, S] -> logits f32[B, S, V] (causal)."""
    B, S = tokens.shape
    h = p["embed"][tokens] + p["pos"][:S]
    mask = jnp.tril(jnp.ones((S, S), jnp.float32))
    neg = jnp.asarray(-1e9, jnp.float32)
    for i in range(cfg.n_layers):
        x = _ln(h, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"])
        q = (x @ p[f"l{i}.wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
        k = (x @ p[f"l{i}.wk"]).reshape(B, S, cfg.n_heads, cfg.d_head)
        v = (x @ p[f"l{i}.wv"]).reshape(B, S, cfg.n_heads, cfg.d_head)
        scores = jnp.einsum("bshd,bthd->bhst", q, k) / math.sqrt(cfg.d_head)
        scores = jnp.where(mask[None, None] > 0, scores, neg)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,bthd->bshd", attn, v).reshape(B, S, cfg.d_model)
        h = h + ctx @ p[f"l{i}.wo"]
        x = _ln(h, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
        h = h + jnp.maximum(x @ p[f"l{i}.w1"] + p[f"l{i}.b1"], 0.0) @ p[f"l{i}.w2"] + p[f"l{i}.b2"]
    h = _ln(h, p["lnf_g"], p["lnf_b"])
    return h @ p["head"]


def loss_fn(p: dict, cfg: LmConfig, tokens):
    """tokens: i32[B, S+1]; next-token cross-entropy."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(p, cfg, inputs)
    logz = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - true)


def train_step(p: dict, m: dict, v: dict, step, cfg: LmConfig, tokens):
    """One Adam step. step: f32 scalar (1-based). Returns (p', m', v', loss)."""
    loss, grads = jax.value_and_grad(lambda q: loss_fn(q, cfg, tokens))(p)
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    bc1 = 1.0 - jnp.power(b1, step)
    bc2 = 1.0 - jnp.power(b2, step)
    p = jax.tree.map(
        lambda w, mm, vv: w - cfg.lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps), p, m, v
    )
    return p, m, v, loss


# -- flat (HLO-friendly) wrappers -------------------------------------------


def flat_init(cfg: LmConfig, seed: int = 0):
    """Returns the flat tuple (params..., m..., v...) in param_names order."""
    p = init(cfg, seed)
    names = param_names(cfg)
    flat_p = [p[n] for n in names]
    zeros = [jnp.zeros_like(a) for a in flat_p]
    return tuple(flat_p + zeros + [jnp.zeros_like(a) for a in flat_p])


def make_flat_step(cfg: LmConfig):
    names = param_names(cfg)
    n = len(names)

    def flat_step(*args):
        flat = args[: 3 * n]
        step = args[3 * n]
        tokens = args[3 * n + 1]
        p = dict(zip(names, flat[:n]))
        m = dict(zip(names, flat[n : 2 * n]))
        v = dict(zip(names, flat[2 * n :]))
        p, m, v, loss = train_step(p, m, v, step, cfg, tokens)
        out = [p[x] for x in names] + [m[x] for x in names] + [v[x] for x in names]
        return tuple(out + [loss])

    return flat_step
