"""memsim — analytical GPU-memory *measurement* model.

The paper measures the "actual" GPU memory of training tasks with
``nvidia-smi`` on an A100. No GPU exists in this environment, so memsim is
the substitute ground truth (see DESIGN.md §1): it models what the PyTorch
CUDA caching allocator would *reserve* for a training task:

    reserved = CUDA context
             + weight/grad/optimizer pool   (rounded to 64 MiB)
             + activation pool              (rounded to 256 MiB  -> staircase)
             + cuDNN / cuBLAS workspace     (rounded to 64 MiB)

The 256 MiB activation-pool rounding is what produces the paper's Fig. 3
staircase growth pattern.

IMPORTANT: this module is mirrored *exactly* (same constants, same op
order) by ``rust/src/workload/memsim.rs``; ``tests/memsim_parity.rs``
pins the two against ``data/memsim_golden.json``.  All arithmetic is on
python floats (f64) — do not introduce numpy here.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Constants (mirrored in rust/src/workload/memsim.rs)
# ---------------------------------------------------------------------------

MIB = 1024.0 * 1024.0
GIB = 1024.0 * MIB

CTX_BYTES = 663.0 * MIB  # CUDA context + cuDNN handles on an A100
BYTES_PER_PARAM = 16.0  # fp32 weight (4) + grad (4) + Adam m,v (8)
WEIGHT_POOL_STEP = 64.0 * MIB
ACT_POOL_STEP = 256.0 * MIB  # -> Fig. 3 staircase
WORKSPACE_STEP = 64.0 * MIB
CONV_WORKSPACE_PER_LAYER = 6.0 * MIB  # cuDNN algo workspace per conv layer
GEMM_WORKSPACE = 96.0 * MIB  # cuBLAS workspace (MLP / Transformer)

# Activation bookkeeping factor per architecture: frameworks keep extra
# copies (autograd graph metadata, fused-op buffers, attention matrices
# not counted in per-layer activation totals).
ACT_FACTOR = {"mlp": 1.0, "cnn": 1.15, "transformer": 1.30}

GPU_CAPACITY_GB = 40.0


def _round_up(x: float, step: float) -> float:
    """Round ``x`` up to a multiple of ``step`` (allocator pool growth)."""
    if x <= 0.0:
        return 0.0
    return math.ceil(x / step) * step


@dataclass
class TaskFeatures:
    """Shared 16-slot feature vector (DESIGN.md §6).

    ``params_m``/``acts_m`` are millions of parameters / of forward
    activations *per sample*.  ``seq_or_spatial`` is sequence length for
    transformers, input spatial edge for CNNs, 0 for MLPs.
    """

    arch: str  # "mlp" | "cnn" | "transformer"
    n_linear: float = 0.0
    n_conv: float = 0.0
    n_batchnorm: float = 0.0
    n_dropout: float = 0.0
    params_m: float = 0.0
    acts_m: float = 0.0
    batch_size: float = 32.0
    n_gpus: float = 1.0
    act_cos: float = 1.0  # cos/sin encoding of the activation function
    act_sin: float = 0.0
    input_dim: float = 0.0
    output_dim: float = 0.0
    seq_or_spatial: float = 0.0
    depth_total: float = 0.0
    width_max: float = 0.0
    reserved: float = 0.0

    def to_vec(self) -> list[float]:
        return [
            self.n_linear,
            self.n_conv,
            self.n_batchnorm,
            self.n_dropout,
            self.params_m,
            self.acts_m,
            self.batch_size,
            self.n_gpus,
            self.act_cos,
            self.act_sin,
            self.input_dim,
            self.output_dim,
            self.seq_or_spatial,
            self.depth_total,
            self.width_max,
            self.reserved,
        ]


# Activation function -> angle for the cos/sin encoding (paper §3.2).
ACTIVATION_ANGLE = {
    "relu": 0.0,
    "gelu": math.pi / 3.0,
    "tanh": 2.0 * math.pi / 3.0,
    "sigmoid": math.pi,
    "silu": 4.0 * math.pi / 3.0,
    "leaky_relu": 5.0 * math.pi / 3.0,
}


def activation_encoding(name: str) -> tuple[float, float]:
    a = ACTIVATION_ANGLE[name]
    return (math.cos(a), math.sin(a))


def measured_bytes(f: TaskFeatures) -> float:
    """The memsim ground truth: bytes the allocator reserves on one GPU."""
    arch = f.arch
    params = f.params_m * 1e6
    acts = f.acts_m * 1e6
    # Data-parallel multi-GPU training splits the batch; the full model
    # replica (weights + optimizer) lives on every GPU.
    per_gpu_batch = f.batch_size / max(f.n_gpus, 1.0)

    weight_pool = _round_up(params * BYTES_PER_PARAM, WEIGHT_POOL_STEP)

    act_bytes = 4.0 * acts * per_gpu_batch * ACT_FACTOR[arch]
    act_pool = _round_up(act_bytes, ACT_POOL_STEP)

    if arch == "cnn":
        ws = CONV_WORKSPACE_PER_LAYER * f.n_conv * math.sqrt(
            per_gpu_batch / 32.0
        )
    else:
        ws = GEMM_WORKSPACE
    ws_pool = _round_up(ws, WORKSPACE_STEP)

    return CTX_BYTES + weight_pool + act_pool + ws_pool


def measured_gb(f: TaskFeatures) -> float:
    return measured_bytes(f) / GIB


def label_for(mem_gb: float, range_gb: float, cap_gb: float = GPU_CAPACITY_GB) -> int:
    """Discretize memory into fixed-size classes (paper §3.2).

    Class c covers (c*range, (c+1)*range]; values above the cap are clamped
    to the last class.
    """
    n_classes = int(math.ceil(cap_gb / range_gb))
    c = int(math.ceil(mem_gb / range_gb)) - 1
    return max(0, min(c, n_classes - 1))


def num_classes(range_gb: float, cap_gb: float = GPU_CAPACITY_GB) -> int:
    return int(math.ceil(cap_gb / range_gb))


def estimate_from_label(label: int, range_gb: float) -> float:
    """Estimate = upper edge of the predicted class (never underestimates
    within the class)."""
    return (label + 1) * range_gb
