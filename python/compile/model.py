"""L2 — GPUMemNet estimator models in JAX (paper §3.2, Fig. 5).

Two classifier families, both formulated as *classification over
fixed-size memory buckets* (the staircase growth of GPU memory makes
regression ill-conditioned — paper Fig. 3):

* :func:`mlp_ensemble` — an ensemble of M small feed-forward classifiers
  with heterogeneous depth/width (1..L hidden layers, exponentially
  decaying widths), ReLU + BatchNorm, predictions averaged (Fig. 5a).
* :func:`transformer_classifier` — per-layer (type, acts, params) tuples
  encoded by single-head transformer blocks, concatenated with the flat
  feature vector, classified by an MLP head (Fig. 5b).

Training runs on the pure-jnp reference path (fast on CPU, identical
math); the exported inference graph calls the Pallas kernels
(``kernels/ensemble_mlp.py``, ``kernels/transformer_encoder.py``) so the
AOT artifact exercises the L1 hot path.  BatchNorm is trained with batch
statistics + running stats and *folded* into per-layer affines for
inference/export (:func:`fold_bn`).

Feature normalization lives INSIDE the model (:func:`normalize_features`)
so the Rust coordinator passes raw feature vectors (DESIGN.md §6).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import ensemble_mlp as k_ensemble
from .kernels import transformer_encoder as k_encoder

D_PAD = 64  # padded feature/hidden width for the ensemble
N_MEMBERS = 8
L_HIDDEN = 4  # max hidden layers per member (padded; members use 1..L)
MEMBER_W_MAX = 32  # widest member (paper uses tiny members; we scale up
MEMBER_W_MIN = 8  # slightly for the 40-class MLP dataset — DESIGN.md §5)

SEQ_LEN = 32  # layer-tuple sequence length (matches dataset.SEQ_LEN)
D_ENC = 32  # encoder embedding size
F_ENC = 64  # encoder FFN size
N_BLOCKS = 2

BN_EPS = 1e-5
BN_MOMENTUM = 0.1


# ---------------------------------------------------------------------------
# Feature normalization (shared contract with the Rust feature extractor —
# Rust sends RAW features, all scaling happens here)
# ---------------------------------------------------------------------------


def normalize_features(x):
    """x: f32[B, 16] raw feature vectors (DESIGN.md §6) -> f32[B, 16]."""
    n_linear, n_conv, n_bn, n_drop = x[:, 0], x[:, 1], x[:, 2], x[:, 3]
    params_m, acts_m, bs, n_gpus = x[:, 4], x[:, 5], x[:, 6], x[:, 7]
    act_cos, act_sin = x[:, 8], x[:, 9]
    in_dim, out_dim, seq_sp = x[:, 10], x[:, 11], x[:, 12]
    depth, wmax, reserved = x[:, 13], x[:, 14], x[:, 15]
    return jnp.stack(
        [
            n_linear / 64.0,
            n_conv / 64.0,
            n_bn / 64.0,
            n_drop / 64.0,
            jnp.log1p(params_m) / 8.0,
            jnp.log1p(acts_m) / 8.0,
            jnp.log2(jnp.maximum(bs, 1.0)) / 10.0,
            n_gpus / 4.0,
            act_cos,
            act_sin,
            jnp.log1p(in_dim) / 12.0,
            jnp.log1p(out_dim) / 12.0,
            jnp.log1p(seq_sp) / 8.0,
            depth / 64.0,
            jnp.log1p(wmax) / 10.0,
            reserved,
        ],
        axis=1,
    )


def pad_features(x):
    """f32[B, 16] -> f32[B, D_PAD] (zero padding)."""
    return jnp.pad(x, ((0, 0), (0, D_PAD - x.shape[1])))


def normalize_layer_seq(s):
    """s: f32[B, S, 3] raw (type, acts_m, params_m) tuples -> normalized."""
    return jnp.stack(
        [
            s[..., 0] / 5.0,
            jnp.log1p(jnp.maximum(s[..., 1], 0.0) * 1e6) / 20.0,
            jnp.log1p(jnp.maximum(s[..., 2], 0.0) * 1e6) / 20.0,
        ],
        axis=-1,
    )


# ---------------------------------------------------------------------------
# MLP ensemble (Fig. 5a)
# ---------------------------------------------------------------------------


class EnsembleParams(NamedTuple):
    w_in: jax.Array  # [M, D, D]
    b_in: jax.Array  # [M, D]
    g_in: jax.Array  # [M, D]   BN gamma
    be_in: jax.Array  # [M, D]  BN beta
    w_h: jax.Array  # [M, L, D, D]
    b_h: jax.Array  # [M, L, D]
    g_h: jax.Array  # [M, L, D]
    be_h: jax.Array  # [M, L, D]
    w_out: jax.Array  # [M, D, D]
    b_out: jax.Array  # [M, D]


class EnsembleState(NamedTuple):
    mu_in: jax.Array  # [M, D]  BN running mean
    var_in: jax.Array  # [M, D]
    mu_h: jax.Array  # [M, L, D]
    var_h: jax.Array  # [M, L, D]


class EnsembleStatic(NamedTuple):
    """Structural (non-trained) description of the heterogeneous ensemble."""

    depth: tuple  # per-member hidden-layer count (1..L)
    width: tuple  # per-member hidden width (<= MEMBER_W_MAX)
    n_classes: int


def member_widths(rng_key) -> tuple:
    """Per-member widths decaying exponentially MEMBER_W_MAX -> MEMBER_W_MIN
    (paper: 'neurons per hidden layer decays exponentially')."""
    ws = []
    for m in range(N_MEMBERS):
        frac = m / max(N_MEMBERS - 1, 1)
        ws.append(
            int(round(MEMBER_W_MAX * (MEMBER_W_MIN / MEMBER_W_MAX) ** frac))
        )
    return tuple(ws)


def init_ensemble(key, n_classes: int):
    """Random heterogeneous ensemble; returns (params, state, static, mask).

    ``mask`` has the same structure as params; multiplying gradients by it
    freezes the identity padding (depth) and zero padding (width) so the
    structural encoding survives training.
    """
    k_depth, k_w = jax.random.split(key)
    depth = tuple(
        int(d) for d in jax.random.randint(k_depth, (N_MEMBERS,), 1, L_HIDDEN + 1)
    )
    width = member_widths(k_w)
    static = EnsembleStatic(depth=depth, width=width, n_classes=n_classes)

    M, L, D = N_MEMBERS, L_HIDDEN, D_PAD
    keys = jax.random.split(key, 4)

    def glorot(k, shape, fan_in, fan_out):
        return jax.random.normal(k, shape) * math.sqrt(2.0 / (fan_in + fan_out))

    w_in = jnp.zeros((M, D, D))
    w_h = jnp.zeros((M, L, D, D))
    w_out = jnp.zeros((M, D, D))
    m_in = jnp.zeros((M, D, D))
    m_h = jnp.zeros((M, L, D, D))
    m_out = jnp.zeros((M, D, D))
    g_h = jnp.ones((M, L, D))
    mg_h = jnp.zeros((M, L, D))

    eye = jnp.eye(D)
    for m in range(N_MEMBERS):
        w = width[m]
        d = depth[m]
        km = jax.random.fold_in(keys[0], m)
        w_in = w_in.at[m, :16, :w].set(glorot(km, (16, w), 16, w))
        m_in = m_in.at[m, :16, :w].set(1.0)
        for l in range(L):
            if l < d:
                kl = jax.random.fold_in(km, l + 1)
                w_h = w_h.at[m, l, :w, :w].set(glorot(kl, (w, w), w, w))
                m_h = m_h.at[m, l, :w, :w].set(1.0)
                mg_h = mg_h.at[m, l, :w].set(1.0)
            else:
                w_h = w_h.at[m, l].set(eye)  # identity padding layer
        ko = jax.random.fold_in(km, 99)
        w_out = w_out.at[m, :w, :n_classes].set(glorot(ko, (w, n_classes), w, n_classes))
        m_out = m_out.at[m, :w, :n_classes].set(1.0)

    params = EnsembleParams(
        w_in=w_in,
        b_in=jnp.zeros((M, D)),
        g_in=jnp.ones((M, D)),
        be_in=jnp.zeros((M, D)),
        w_h=w_h,
        b_h=jnp.zeros((M, L, D)),
        g_h=g_h,
        be_h=jnp.zeros((M, L, D)),
        w_out=w_out,
        b_out=jnp.zeros((M, D)),
    )
    state = EnsembleState(
        mu_in=jnp.zeros((M, D)),
        var_in=jnp.ones((M, D)),
        mu_h=jnp.zeros((M, L, D)),
        var_h=jnp.ones((M, L, D)),
    )
    width_vec = jnp.stack(
        [(jnp.arange(D) < width[m]).astype(jnp.float32) for m in range(M)]
    )  # [M, D]
    depth_vec = jnp.stack(
        [
            jnp.stack(
                [
                    width_vec[m] * (1.0 if l < depth[m] else 0.0)
                    for l in range(L)
                ]
            )
            for m in range(M)
        ]
    )  # [M, L, D]
    mask = EnsembleParams(
        w_in=m_in,
        b_in=width_vec,
        g_in=width_vec,
        be_in=width_vec,
        w_h=m_h,
        b_h=depth_vec,
        g_h=depth_vec,
        be_h=depth_vec,
        w_out=m_out,
        b_out=jnp.stack(
            [(jnp.arange(D) < n_classes).astype(jnp.float32)] * M
        ),
    )
    return params, state, static, mask


def _bn_train(h, gamma, beta, mu_run, var_run):
    """BatchNorm with batch statistics; returns (y, new_mu, new_var)."""
    mu = jnp.mean(h, axis=0)
    var = jnp.var(h, axis=0)
    y = (h - mu) / jnp.sqrt(var + BN_EPS) * gamma + beta
    new_mu = (1.0 - BN_MOMENTUM) * mu_run + BN_MOMENTUM * mu
    new_var = (1.0 - BN_MOMENTUM) * var_run + BN_MOMENTUM * var
    return y, new_mu, new_var


def ensemble_train_forward(params: EnsembleParams, state: EnsembleState, static, xraw):
    """Training-mode forward (batch-stat BN). Returns (logits[B, C], state')."""
    x = pad_features(normalize_features(xraw))
    M, L = N_MEMBERS, L_HIDDEN
    acc = 0.0
    mu_in, var_in = [], []
    mu_h = [[None] * L for _ in range(M)]
    var_h = [[None] * L for _ in range(M)]
    for m in range(M):
        h = x @ params.w_in[m] + params.b_in[m]
        h, nm, nv = _bn_train(h, params.g_in[m], params.be_in[m], state.mu_in[m], state.var_in[m])
        mu_in.append(nm)
        var_in.append(nv)
        h = jnp.maximum(h, 0.0)
        for l in range(L):
            if l < static.depth[m]:
                h2 = h @ params.w_h[m, l] + params.b_h[m, l]
                h2, nm, nv = _bn_train(
                    h2, params.g_h[m, l], params.be_h[m, l], state.mu_h[m, l], state.var_h[m, l]
                )
                h = jnp.maximum(h2, 0.0)
            else:
                nm, nv = state.mu_h[m, l], state.var_h[m, l]
            mu_h[m][l] = nm
            var_h[m][l] = nv
        acc = acc + h @ params.w_out[m] + params.b_out[m]
    logits = acc / M
    new_state = EnsembleState(
        mu_in=jnp.stack(mu_in),
        var_in=jnp.stack(var_in),
        mu_h=jnp.stack([jnp.stack(r) for r in mu_h]),
        var_h=jnp.stack([jnp.stack(r) for r in var_h]),
    )
    return logits[:, : static.n_classes], new_state


def fold_bn(params: EnsembleParams, state: EnsembleState, static) -> dict:
    """Fold running BN stats into per-layer affines for the fused kernel.

    Identity padding layers get (s=1, t=0); width padding keeps (s=0, t=0)
    so dead units stay exactly zero.
    """
    M, L, D = N_MEMBERS, L_HIDDEN, D_PAD
    inv_in = 1.0 / jnp.sqrt(state.var_in + BN_EPS)
    s_in = params.g_in * inv_in
    t_in = params.be_in - state.mu_in * s_in
    width_vec = jnp.stack(
        [(jnp.arange(D) < static.width[m]).astype(jnp.float32) for m in range(M)]
    )
    s_in = s_in * width_vec
    t_in = t_in * width_vec

    inv_h = 1.0 / jnp.sqrt(state.var_h + BN_EPS)
    s_h = params.g_h * inv_h
    t_h = params.be_h - state.mu_h * s_h
    s_list, t_list = [], []
    for m in range(M):
        wv = width_vec[m]
        sm, tm = [], []
        for l in range(L):
            if l < static.depth[m]:
                sm.append(s_h[m, l] * wv)
                tm.append(t_h[m, l] * wv)
            else:
                sm.append(jnp.ones((D,)))  # identity layer: relu(h*1+0)=h
                tm.append(jnp.zeros((D,)))
        s_list.append(jnp.stack(sm))
        t_list.append(jnp.stack(tm))

    return {
        "w_in": params.w_in,
        "b_in": params.b_in * width_vec,
        "s_in": s_in,
        "t_in": t_in,
        "w_h": params.w_h,
        "b_h": params.b_h,
        "s_h": jnp.stack(s_list),
        "t_h": jnp.stack(t_list),
        "w_out": params.w_out,
        "b_out": params.b_out,
    }


def ensemble_infer(folded: dict, xraw, n_classes: int, *, use_pallas: bool = True):
    """Inference forward over folded params. This is the graph AOT-exported
    for the Rust coordinator; ``use_pallas=True`` routes through the fused
    L1 kernel."""
    x = pad_features(normalize_features(xraw))
    fwd = k_ensemble.ensemble_mlp_forward if use_pallas else ref.ensemble_mlp_forward
    logits = fwd(x, folded)
    return logits[:, :n_classes]


# ---------------------------------------------------------------------------
# Transformer classifier (Fig. 5b)
# ---------------------------------------------------------------------------


def init_transformer(key, n_classes: int) -> dict:
    ks = jax.random.split(key, 8 + 4 * N_BLOCKS)
    d, f = D_ENC, F_ENC

    def lin(k, i, o):
        return jax.random.normal(k, (i, o)) * math.sqrt(2.0 / (i + o))

    params = {
        "embed_w": lin(ks[0], 3, d),
        "embed_b": jnp.zeros((d,)),
        "blocks": [],
        "head1_w": lin(ks[1], d + 16, f),
        "head1_b": jnp.zeros((f,)),
        "head2_w": lin(ks[2], f, n_classes),
        "head2_b": jnp.zeros((n_classes,)),
    }
    for b in range(N_BLOCKS):
        ko = ks[8 + 4 * b : 8 + 4 * b + 4]
        params["blocks"].append(
            {
                "wq": lin(ko[0], d, d),
                "wk": lin(jax.random.fold_in(ko[0], 1), d, d),
                "wv": lin(ko[1], d, d),
                "wo": lin(jax.random.fold_in(ko[1], 1), d, d),
                "ln1_g": jnp.ones((d,)),
                "ln1_b": jnp.zeros((d,)),
                "ln2_g": jnp.ones((d,)),
                "ln2_b": jnp.zeros((d,)),
                "w1": lin(ko[2], d, f),
                "b1": jnp.zeros((f,)),
                "w2": lin(ko[3], f, d),
                "b2": jnp.zeros((d,)),
            }
        )
    return params


def positional_encoding(seq_len: int = SEQ_LEN, d: int = D_ENC):
    pos = jnp.arange(seq_len)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2.0 * i / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=1)


def transformer_forward(params, xraw, seq_raw, *, use_pallas: bool = False):
    """Full classifier forward. xraw f32[B,16], seq_raw f32[B,S,3]."""
    s = normalize_layer_seq(seq_raw)
    h = s @ params["embed_w"] + params["embed_b"] + positional_encoding()
    block_fn = k_encoder.encoder_block if use_pallas else ref.encoder_block
    for bp in params["blocks"]:
        h = block_fn(h, bp)
    pooled = jnp.mean(h, axis=1)  # [B, D_ENC]
    aux = normalize_features(xraw)
    z = jnp.concatenate([pooled, aux], axis=1)
    z = jnp.maximum(z @ params["head1_w"] + params["head1_b"], 0.0)
    return z @ params["head2_w"] + params["head2_b"]


# ---------------------------------------------------------------------------
# Loss + Adam (hand-rolled; optax is not in the image)
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=1)
    true = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return jnp.mean(logz - true)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return zeros, jax.tree.map(jnp.zeros_like, params)


def adam_update(params, grads, m, v, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step
    params = jax.tree.map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps),
        params,
        m,
        v,
    )
    return params, m, v
