"""Train the GPUMemNet estimators (paper §3.2–3.3, Table 1).

For each architecture dataset (MLP / CNN / Transformer) and each estimator
family (MLP ensemble / Transformer classifier), runs stratified 3-fold
cross-validation on a 70 % split (30 % held-out test), reports accuracy and
macro-F1 (paper Table 1), then retrains on the full training split and
exports folded weights for AOT lowering.

Outputs (under ``artifacts/``):
  table1.json                         — paper Table 1 reproduction
  gpumemnet_{mlp,cnn,tfm}_weights.npz — folded MLP-ensemble weights (the
                                        family CARMA serves, paper §3.3)
  gpumemnet_{mlp,cnn,tfm}_tf.npz      — transformer-classifier weights
  dataset_{arch}.npz                  — the generated datasets (reused by
                                        analysis.py and tests)

Run as ``python -m compile.train [--quick]`` from ``python/``.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset as ds
from . import memsim
from . import model

ARCHS = ("mlp", "cnn", "transformer")
SHORT = {"mlp": "mlp", "cnn": "cnn", "transformer": "tfm"}
N_SAMPLES = {"mlp": 3000, "cnn": 2400, "transformer": 2400}
RANGES = {"mlp": [1.0, 2.0], "cnn": [8.0], "transformer": [8.0]}
SERVE_RANGE = {"mlp": 1.0, "cnn": 8.0, "transformer": 8.0}

EPOCHS = 160
BATCH = 256
LR = 2e-3
SEED = 7


def artifacts_dir() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", "artifacts"))


# ---------------------------------------------------------------------------
# Data plumbing
# ---------------------------------------------------------------------------


def build_dataset(arch: str, n: int, seed: int):
    samples = ds.generate(arch, n, seed=seed)
    X = np.array([s.features for s in samples], dtype=np.float32)
    S = np.array([s.layer_seq for s in samples], dtype=np.float32)
    mem = np.array([s.mem_gb for s in samples], dtype=np.float32)
    return X, S, mem


def labels_for(mem: np.ndarray, range_gb: float) -> np.ndarray:
    return np.array([memsim.label_for(float(m), range_gb) for m in mem], dtype=np.int32)


def stratified_split(labels: np.ndarray, frac: float, seed: int):
    """Index split keeping per-class proportions (paper: stratified)."""
    rng = np.random.default_rng(seed)
    a_idx, b_idx = [], []
    for c in np.unique(labels):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        k = int(round(len(idx) * frac))
        a_idx.extend(idx[:k])
        b_idx.extend(idx[k:])
    return np.array(sorted(a_idx)), np.array(sorted(b_idx))


def kfold(labels: np.ndarray, k: int, seed: int):
    """Stratified k-fold index generator."""
    rng = np.random.default_rng(seed)
    folds = [[] for _ in range(k)]
    for c in np.unique(labels):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        for i, j in enumerate(idx):
            folds[i % k].append(j)
    for i in range(k):
        val = np.array(sorted(folds[i]))
        train = np.array(sorted([j for f in range(k) if f != i for j in folds[f]]))
        yield train, val


def macro_f1(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    f1s = []
    for c in np.unique(y_true):
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        denom = 2 * tp + fp + fn
        f1s.append(2 * tp / denom if denom > 0 else 0.0)
    return float(np.mean(f1s))


# ---------------------------------------------------------------------------
# MLP-ensemble training
# ---------------------------------------------------------------------------


def train_ensemble(X, y, n_classes: int, seed: int, epochs: int):
    key = jax.random.PRNGKey(seed)
    params, state, static, mask = model.init_ensemble(key, n_classes)
    m, v = model.adam_init(params)

    def loss_fn(p, st, xb, yb):
        logits, st2 = model.ensemble_train_forward(p, st, static, xb)
        return model.cross_entropy(logits, yb), st2

    @jax.jit
    def step(p, st, m, v, i, xb, yb):
        (loss, st2), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, st, xb, yb)
        grads = jax.tree.map(lambda g, msk: g * msk, grads, mask)
        p, m, v = model.adam_update(p, grads, m, v, i, lr=LR)
        return p, st2, m, v, loss

    n = X.shape[0]
    rng = np.random.default_rng(seed)
    i = 0
    for _ in range(epochs):
        order = rng.permutation(n)
        for b in range(0, n, BATCH):
            idx = order[b : b + BATCH]
            if len(idx) < 8:
                continue
            i += 1
            params, state, m, v, _ = step(
                params, state, m, v, i, jnp.asarray(X[idx]), jnp.asarray(y[idx])
            )

    folded = model.fold_bn(params, state, static)
    return folded, static


def ensemble_predict(folded, static, X) -> np.ndarray:
    logits = model.ensemble_infer(folded, jnp.asarray(X), static.n_classes, use_pallas=False)
    return np.asarray(jnp.argmax(logits, axis=1))


# ---------------------------------------------------------------------------
# Transformer-classifier training
# ---------------------------------------------------------------------------


def train_transformer(X, S, y, n_classes: int, seed: int, epochs: int):
    key = jax.random.PRNGKey(seed + 1)
    params = model.init_transformer(key, n_classes)
    m, v = model.adam_init(params)

    def loss_fn(p, xb, sb, yb):
        logits = model.transformer_forward(p, xb, sb, use_pallas=False)
        return model.cross_entropy(logits, yb)

    @jax.jit
    def step(p, m, v, i, xb, sb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, sb, yb)
        p, m, v = model.adam_update(p, grads, m, v, i, lr=LR)
        return p, m, v, loss

    n = X.shape[0]
    rng = np.random.default_rng(seed)
    i = 0
    for _ in range(epochs):
        order = rng.permutation(n)
        for b in range(0, n, BATCH):
            idx = order[b : b + BATCH]
            if len(idx) < 8:
                continue
            i += 1
            params, m, v, _ = step(
                params, m, v, i, jnp.asarray(X[idx]), jnp.asarray(S[idx]), jnp.asarray(y[idx])
            )
    return params


def transformer_predict(params, X, S) -> np.ndarray:
    logits = model.transformer_forward(params, jnp.asarray(X), jnp.asarray(S), use_pallas=False)
    return np.asarray(jnp.argmax(logits, axis=1))


# ---------------------------------------------------------------------------
# Weight export helpers
# ---------------------------------------------------------------------------


def save_folded(path: str, folded: dict, static, range_gb: float):
    np.savez(
        path,
        n_classes=np.int32(static.n_classes),
        range_gb=np.float32(range_gb),
        depth=np.array(static.depth, dtype=np.int32),
        width=np.array(static.width, dtype=np.int32),
        **{k: np.asarray(a, dtype=np.float32) for k, a in folded.items()},
    )


def save_transformer(path: str, params: dict, n_classes: int, range_gb: float):
    flat = {
        "embed_w": params["embed_w"],
        "embed_b": params["embed_b"],
        "head1_w": params["head1_w"],
        "head1_b": params["head1_b"],
        "head2_w": params["head2_w"],
        "head2_b": params["head2_b"],
    }
    for i, bp in enumerate(params["blocks"]):
        for k, a in bp.items():
            flat[f"block{i}_{k}"] = a
    np.savez(
        path,
        n_classes=np.int32(n_classes),
        range_gb=np.float32(range_gb),
        n_blocks=np.int32(len(params["blocks"])),
        **{k: np.asarray(a, dtype=np.float32) for k, a in flat.items()},
    )


def load_transformer(path: str):
    z = np.load(path)
    params = {
        "embed_w": jnp.asarray(z["embed_w"]),
        "embed_b": jnp.asarray(z["embed_b"]),
        "head1_w": jnp.asarray(z["head1_w"]),
        "head1_b": jnp.asarray(z["head1_b"]),
        "head2_w": jnp.asarray(z["head2_w"]),
        "head2_b": jnp.asarray(z["head2_b"]),
        "blocks": [],
    }
    for i in range(int(z["n_blocks"])):
        params["blocks"].append(
            {
                k: jnp.asarray(z[f"block{i}_{k}"])
                for k in (
                    "wq", "wk", "wv", "wo", "ln1_g", "ln1_b", "ln2_g", "ln2_b",
                    "w1", "b1", "w2", "b2",
                )
            }
        )
    return params, int(z["n_classes"]), float(z["range_gb"])


def load_folded(path: str):
    z = np.load(path)
    folded = {
        k: jnp.asarray(z[k])
        for k in ("w_in", "b_in", "s_in", "t_in", "w_h", "b_h", "s_h", "t_h", "w_out", "b_out")
    }
    return folded, int(z["n_classes"]), float(z["range_gb"])


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small datasets / few epochs (CI smoke)")
    args = ap.parse_args(argv)

    epochs = 12 if args.quick else EPOCHS
    cv_epochs = max(6, epochs // 2)
    scale = 0.15 if args.quick else 1.0

    out_dir = artifacts_dir()
    os.makedirs(out_dir, exist_ok=True)
    table1 = []

    for arch in ARCHS:
        n = max(300, int(N_SAMPLES[arch] * scale))
        t0 = time.time()
        X, S, mem = build_dataset(arch, n, SEED)
        np.savez(os.path.join(out_dir, f"dataset_{arch}.npz"), X=X, S=S, mem=mem)
        print(f"[{arch}] dataset n={len(X)} ({time.time()-t0:.1f}s)", flush=True)

        for range_gb in RANGES[arch]:
            y = labels_for(mem, range_gb)
            n_classes = memsim.num_classes(range_gb)
            train_idx, test_idx = stratified_split(y, 0.7, SEED)

            for family in ("MLP", "Transformer"):
                accs, f1s = [], []
                for fold, (tr, _val) in enumerate(kfold(y[train_idx], 3, SEED)):
                    tr_idx = train_idx[tr]
                    if family == "MLP":
                        folded, static = train_ensemble(
                            X[tr_idx], y[tr_idx], n_classes, SEED + fold, cv_epochs
                        )
                        pred = ensemble_predict(folded, static, X[test_idx])
                    else:
                        params = train_transformer(
                            X[tr_idx], S[tr_idx], y[tr_idx], n_classes, SEED + fold, cv_epochs
                        )
                        pred = transformer_predict(params, X[test_idx], S[test_idx])
                    accs.append(float(np.mean(pred == y[test_idx])))
                    f1s.append(macro_f1(y[test_idx], pred))
                row = {
                    "dataset": arch,
                    "estimator": family,
                    "range_gb": range_gb,
                    "accuracy": round(float(np.mean(accs)), 4),
                    "f1": round(float(np.mean(f1s)), 4),
                }
                table1.append(row)
                print(f"  {row}", flush=True)

        # final serve-model training on the full training split
        range_gb = SERVE_RANGE[arch]
        y = labels_for(mem, range_gb)
        n_classes = memsim.num_classes(range_gb)
        train_idx, test_idx = stratified_split(y, 0.7, SEED)
        folded, static = train_ensemble(X[train_idx], y[train_idx], n_classes, SEED, epochs)
        pred = ensemble_predict(folded, static, X[test_idx])
        acc = float(np.mean(pred == y[test_idx]))
        # the serve model must (almost) never under-estimate; log the rate
        under = float(np.mean(pred < y[test_idx]))
        print(f"[{arch}] serve model acc={acc:.3f} underestimate-rate={under:.3f}", flush=True)
        save_folded(
            os.path.join(out_dir, f"gpumemnet_{SHORT[arch]}_weights.npz"),
            folded,
            static,
            range_gb,
        )
        tfm = train_transformer(X[train_idx], S[train_idx], y[train_idx], n_classes, SEED, epochs)
        save_transformer(
            os.path.join(out_dir, f"gpumemnet_{SHORT[arch]}_tf.npz"), tfm, n_classes, range_gb
        )

    with open(os.path.join(out_dir, "table1.json"), "w") as fh:
        json.dump(table1, fh, indent=1)
    print(f"wrote {os.path.join(out_dir, 'table1.json')}")


if __name__ == "__main__":
    main()
