"""AOT export: lower the L2/L1 graphs to HLO *text* for the Rust runtime.

Interchange is HLO text, NOT a serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (``artifacts/``):
  gpumemnet_mlp.hlo.txt / _cnn / _tfm   — MLP-ensemble estimators, weights
                                          baked, Pallas ensemble kernel inside
  gpumemnet_cnn_tf.hlo.txt / _tfm_tf    — Transformer-classifier estimators
                                          (Pallas encoder kernel inside)
  gpumemnet_manifest.json               — class count / bucket size per file
  lm_init.hlo.txt, lm_step.hlo.txt      — live-mode LM trainer (init + one
                                          Adam step) for examples/live_training
  lm_manifest.json                      — flat parameter layout for Rust

Run as ``python -m compile.aot`` from ``python/`` (``make artifacts``).
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import livemodel, model
from .train import artifacts_dir, load_folded, load_transformer

SHORTS = ("mlp", "cnn", "tfm")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides big
    # literals as `constant({...})`, which the xla_extension 0.5.1 text
    # parser silently reads back as ZEROS — the baked GPUMemNet weights
    # would vanish and the classifier would answer class 0 for everything.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "elided constants in HLO export"
    return text


def write_hlo(path: str, lowered) -> None:
    text = to_hlo_text(lowered)
    with open(path, "w") as fh:
        fh.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def export_gpumemnet(out_dir: str) -> None:
    manifest = {}
    for short in SHORTS:
        wpath = os.path.join(out_dir, f"gpumemnet_{short}_weights.npz")
        folded, n_classes, range_gb = load_folded(wpath)

        def infer(x, folded=folded, n_classes=n_classes):
            return (model.ensemble_infer(folded, x, n_classes, use_pallas=True),)

        spec = jax.ShapeDtypeStruct((1, 16), jnp.float32)
        write_hlo(
            os.path.join(out_dir, f"gpumemnet_{short}.hlo.txt"),
            jax.jit(infer).lower(spec),
        )
        manifest[f"gpumemnet_{short}.hlo.txt"] = {
            "family": "mlp_ensemble",
            "arch": short,
            "n_classes": n_classes,
            "range_gb": range_gb,
            "inputs": [["f32", [1, 16]]],
        }

        # transformer-classifier variant (completeness / ablation benches)
        tpath = os.path.join(out_dir, f"gpumemnet_{short}_tf.npz")
        if os.path.exists(tpath):
            params, tn_classes, trange = load_transformer(tpath)

            def tinfer(x, seq, params=params):
                return (model.transformer_forward(params, x, seq, use_pallas=True),)

            sspec = jax.ShapeDtypeStruct((1, model.SEQ_LEN, 3), jnp.float32)
            write_hlo(
                os.path.join(out_dir, f"gpumemnet_{short}_tf.hlo.txt"),
                jax.jit(tinfer).lower(spec, sspec),
            )
            manifest[f"gpumemnet_{short}_tf.hlo.txt"] = {
                "family": "transformer",
                "arch": short,
                "n_classes": tn_classes,
                "range_gb": trange,
                "inputs": [["f32", [1, 16]], ["f32", [1, model.SEQ_LEN, 3]]],
            }

    with open(os.path.join(out_dir, "gpumemnet_manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)


def export_lm(out_dir: str, large: bool = False) -> None:
    cfg = livemodel.LARGE if large else livemodel.LmConfig()
    names = livemodel.param_names(cfg)
    n = len(names)

    init_fn = functools.partial(livemodel.flat_init, cfg, 0)
    write_hlo(os.path.join(out_dir, "lm_init.hlo.txt"), jax.jit(init_fn).lower())

    flat_step = livemodel.make_flat_step(cfg)
    p0 = livemodel.init(cfg, 0)
    specs = [jax.ShapeDtypeStruct(p0[x].shape, jnp.float32) for x in names]
    arg_specs = (
        specs * 3
        + [jax.ShapeDtypeStruct((), jnp.float32)]
        + [jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)]
    )
    write_hlo(os.path.join(out_dir, "lm_step.hlo.txt"), jax.jit(flat_step).lower(*arg_specs))

    n_params = int(sum(np.prod(p0[x].shape) for x in names))
    manifest = {
        "config": cfg._asdict(),
        "n_arrays": n,
        "param_names": names,
        "param_shapes": {x: list(p0[x].shape) for x in names},
        "n_params": n_params,
        "arg_layout": "params*n, m*n, v*n, step_f32_scalar, tokens_i32[batch, seq_len+1]",
        "out_layout": "params*n, m*n, v*n, loss_f32_scalar",
    }
    with open(os.path.join(out_dir, "lm_manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"  lm: {n} arrays, {n_params/1e6:.2f} M params")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true", help="export the ~110M-param LM")
    ap.add_argument("--skip-lm", action="store_true")
    ap.add_argument("--out", default=None, help="(compat) unused single-file output")
    args = ap.parse_args(argv)

    out_dir = artifacts_dir()
    os.makedirs(out_dir, exist_ok=True)
    print("exporting GPUMemNet estimators:")
    export_gpumemnet(out_dir)
    if not args.skip_lm:
        print("exporting live-mode LM trainer:")
        export_lm(out_dir, large=args.large)


if __name__ == "__main__":
    main()
