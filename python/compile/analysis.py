"""Dataset analyses (paper Figs. 3 & 4) + cross-language golden files.

* Fig. 3 — staircase growth: GPU memory vs MLP hidden width at bs=32
  (ImageNet-dim input), showing the allocator-pool plateaus.
* Fig. 4 — PCA of each dataset colored by memory class, showing that the
  discretized classes are separable (classification is well-posed).
* ``data/memsim_golden.json`` — random feature vectors + memsim outputs,
  pinning the Rust `workload::memsim` mirror to the Python reference.

Run as ``python -m compile.analysis`` from ``python/``.
"""

from __future__ import annotations

import json
import os
import random

import numpy as np

from . import dataset as ds
from . import memsim
from .memsim import TaskFeatures


def artifacts_dir() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", "artifacts"))


def data_dir() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", "data"))


def fig3_staircase(out_path: str) -> None:
    """Memory vs MLP width sweep (depth=3, bs=32, ImageNet input)."""
    rows = ["width,params_m,mem_gb"]
    for width in range(64, 8192 + 1, 64):
        dims = [150528, width, width, width, 1000]
        params = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
        acts = sum(dims[1:])
        f = TaskFeatures(
            arch="mlp",
            n_linear=4.0,
            params_m=params / 1e6,
            acts_m=acts / 1e6,
            batch_size=32.0,
            input_dim=150528.0,
            output_dim=1000.0,
            depth_total=4.0,
            width_max=float(width),
        )
        rows.append(f"{width},{params / 1e6:.3f},{memsim.measured_gb(f):.4f}")
    with open(out_path, "w") as fh:
        fh.write("\n".join(rows) + "\n")


def _pca2(X: np.ndarray) -> np.ndarray:
    Xc = X - X.mean(axis=0)
    Xc = Xc / (Xc.std(axis=0) + 1e-9)
    _u, _s, vt = np.linalg.svd(Xc, full_matrices=False)
    return Xc @ vt[:2].T


def fig4_pca(out_dir: str, n: int = 800) -> None:
    for arch in ("mlp", "cnn", "transformer"):
        samples = ds.generate(arch, n, seed=11)
        X = np.array([s.features for s in samples], dtype=np.float64)
        # normalize like the model does (log scales) for a meaningful PCA
        Xn = X.copy()
        for col in (4, 5, 10, 11, 12, 14):
            Xn[:, col] = np.log1p(np.maximum(Xn[:, col], 0.0))
        Xn[:, 6] = np.log2(np.maximum(Xn[:, 6], 1.0))
        pcs = _pca2(Xn)
        rg = 1.0 if arch == "mlp" else 8.0
        rows = ["pc1,pc2,label"]
        for i, s in enumerate(samples):
            rows.append(
                f"{pcs[i, 0]:.4f},{pcs[i, 1]:.4f},{memsim.label_for(s.mem_gb, rg)}"
            )
        with open(os.path.join(out_dir, f"fig4_{arch}.csv"), "w") as fh:
            fh.write("\n".join(rows) + "\n")


def memsim_golden(out_path: str, n: int = 64) -> None:
    rng = random.Random(1234)
    cases = []
    for _ in range(n):
        arch = rng.choice(["mlp", "cnn", "transformer"])
        f = TaskFeatures(
            arch=arch,
            n_linear=float(rng.randint(0, 64)),
            n_conv=float(rng.randint(0, 96) if arch == "cnn" else 0),
            n_batchnorm=float(rng.randint(0, 64)),
            n_dropout=float(rng.randint(0, 16)),
            params_m=rng.uniform(0.1, 900.0),
            acts_m=rng.uniform(0.01, 300.0),
            batch_size=float(rng.choice([1, 4, 8, 16, 32, 64, 128, 256, 512])),
            n_gpus=float(rng.choice([1, 1, 1, 2, 4])),
            input_dim=float(rng.choice([784, 3072, 150528, 30522])),
            output_dim=float(rng.choice([10, 100, 1000, 30522])),
            seq_or_spatial=float(rng.choice([0, 32, 224, 512, 1024])),
            depth_total=float(rng.randint(1, 96)),
            width_max=float(rng.choice([64, 512, 1024, 2048])),
        )
        cases.append(
            {
                "arch": arch,
                "features": f.to_vec(),
                "mem_gb": memsim.measured_gb(f),
                "label_1gb": memsim.label_for(memsim.measured_gb(f), 1.0),
                "label_8gb": memsim.label_for(memsim.measured_gb(f), 8.0),
            }
        )
    with open(out_path, "w") as fh:
        json.dump(cases, fh, indent=1)


def main() -> None:
    out = os.path.join(artifacts_dir(), "analysis")
    os.makedirs(out, exist_ok=True)
    os.makedirs(data_dir(), exist_ok=True)
    fig3_staircase(os.path.join(out, "fig3_staircase.csv"))
    fig4_pca(out)
    memsim_golden(os.path.join(data_dir(), "memsim_golden.json"))
    print(f"analysis written to {out}; memsim golden refreshed")


if __name__ == "__main__":
    main()
