"""Pallas kernel: fused transformer encoder block (GPUMemNet L1).

The Transformer-based GPUMemNet estimator (paper §3.2 / Fig. 5b) encodes
the per-layer (type, activations, parameters) tuple sequence with a stack
of single-head encoder blocks.  This kernel fuses one whole block —
LN → QKᵀ → softmax → ·V → out-proj → residual → LN → FFN → residual —
into a single pass so the [S, S] attention matrix and all intermediates
live in VMEM and never round-trip to HBM (the CUDA analogue would stage
them through shared memory; see DESIGN.md §Hardware-Adaptation).

grid = (B,): one grid step per sequence (S and D are tiny — S=32, D=32 —
so a full sequence's working set is ~24 KiB).  Weights use ``whole``
index maps and stay resident across steps.

Lowered with ``interpret=True`` for CPU PJRT (AOT recipe).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _kernel(
    x_ref,
    wq_ref,
    wk_ref,
    wv_ref,
    wo_ref,
    ln1_g_ref,
    ln1_b_ref,
    ln2_g_ref,
    ln2_b_ref,
    w1_ref,
    b1_ref,
    w2_ref,
    b2_ref,
    o_ref,
):
    x = x_ref[0]  # [S, D]
    d = x.shape[-1]
    h = _layer_norm(x, ln1_g_ref[...], ln1_b_ref[...])
    q = h @ wq_ref[...]
    k = h @ wk_ref[...]
    v = h @ wv_ref[...]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, x.dtype))
    att = (_softmax(scores) @ v) @ wo_ref[...]
    x = x + att
    h2 = _layer_norm(x, ln2_g_ref[...], ln2_b_ref[...])
    f = jnp.maximum(h2 @ w1_ref[...] + b1_ref[...], 0.0) @ w2_ref[...] + b2_ref[...]
    o_ref[0] = x + f


def encoder_block(x, p, *, interpret: bool = True):
    """Fused encoder block; same contract as ``ref.encoder_block``.

    x: f32[B, S, D]; p: weight dict (see ref.py). Returns f32[B, S, D].
    """
    B, S, D = x.shape
    F = p["w1"].shape[1]

    sample = lambda b: (b, 0, 0)  # noqa: E731 — one sequence per grid step
    whole2 = lambda b: (0, 0)  # noqa: E731
    whole1 = lambda b: (0,)  # noqa: E731

    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S, D), sample),
            pl.BlockSpec((D, D), whole2),  # wq
            pl.BlockSpec((D, D), whole2),  # wk
            pl.BlockSpec((D, D), whole2),  # wv
            pl.BlockSpec((D, D), whole2),  # wo
            pl.BlockSpec((D,), whole1),  # ln1_g
            pl.BlockSpec((D,), whole1),  # ln1_b
            pl.BlockSpec((D,), whole1),  # ln2_g
            pl.BlockSpec((D,), whole1),  # ln2_b
            pl.BlockSpec((D, F), whole2),  # w1
            pl.BlockSpec((F,), whole1),  # b1
            pl.BlockSpec((F, D), whole2),  # w2
            pl.BlockSpec((D,), whole1),  # b2
        ],
        out_specs=pl.BlockSpec((1, S, D), sample),
        out_shape=jax.ShapeDtypeStruct((B, S, D), x.dtype),
        interpret=interpret,
    )(
        x,
        p["wq"],
        p["wk"],
        p["wv"],
        p["wo"],
        p["ln1_g"],
        p["ln1_b"],
        p["ln2_g"],
        p["ln2_b"],
        p["w1"],
        p["b1"],
        p["w2"],
        p["b2"],
    )
