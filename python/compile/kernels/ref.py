"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has its semantics defined *here*; pytest
(``python/tests/test_kernel.py``) asserts the Pallas implementations match
these references across hypothesis-generated shapes/dtypes.  The L2 model
uses the reference path for training (fast on CPU) and the Pallas path for
the exported inference graph.
"""

from __future__ import annotations

import jax.numpy as jnp


def ensemble_mlp_forward(x, p):
    """Folded-BN inference forward for the MLP ensemble classifier.

    x: f32[B, D]                     (already normalized + padded)
    p: dict with
       w_in f32[M, D, D], b_in f32[M, D], s_in f32[M, D], t_in f32[M, D]
       w_h  f32[M, L, D, D], b_h f32[M, L, D], s_h f32[M, L, D], t_h f32[M, L, D]
       w_out f32[M, D, D], b_out f32[M, D]
    returns mean-over-members logits f32[B, D].

    Layer semantics per member: relu(bn(linear(x))) with BN folded into the
    affine (s, t); padding hidden layers are identity (w=I, s=1, t=0), which
    ReLU leaves intact because post-ReLU activations are non-negative.
    """
    M = p["w_in"].shape[0]
    L = p["w_h"].shape[1]
    acc = jnp.zeros((x.shape[0], p["w_out"].shape[2]), dtype=x.dtype)
    for m in range(M):
        h = x @ p["w_in"][m] + p["b_in"][m]
        h = jnp.maximum(h * p["s_in"][m] + p["t_in"][m], 0.0)
        for l in range(L):
            h2 = h @ p["w_h"][m, l] + p["b_h"][m, l]
            h = jnp.maximum(h2 * p["s_h"][m, l] + p["t_h"][m, l], 0.0)
        acc = acc + h @ p["w_out"][m] + p["b_out"][m]
    return acc / M


def softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def encoder_block(x, p):
    """Single-head pre-LN transformer encoder block.

    x: f32[B, S, D]
    p: dict with wq,wk,wv,wo f32[D, D]; ln1_g,ln1_b,ln2_g,ln2_b f32[D];
       w1 f32[D, F], b1 f32[F], w2 f32[F, D], b2 f32[D]
    returns f32[B, S, D]
    """
    d = x.shape[-1]
    h = layer_norm(x, p["ln1_g"], p["ln1_b"])
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    scores = jnp.einsum("bsd,btd->bst", q, k) / jnp.sqrt(jnp.asarray(d, x.dtype))
    attn = jnp.einsum("bst,btd->bsd", softmax(scores), v) @ p["wo"]
    x = x + attn
    h2 = layer_norm(x, p["ln2_g"], p["ln2_b"])
    f = jnp.maximum(h2 @ p["w1"] + p["b1"], 0.0) @ p["w2"] + p["b2"]
    return x + f
