"""Pallas kernel: fused ensemble-MLP classifier forward (GPUMemNet L1).

The GPUMemNet estimator is an *ensemble* of small MLP classifiers whose
predictions are averaged (paper §3.2 / Fig. 5a).  The naive formulation
launches M independent forwards and reduces; this kernel fuses the whole
ensemble into one pass:

* grid = (M,) — one grid step per ensemble member;
* each step keeps the member's full weight stack resident in VMEM
  (weights are (D, D)-padded with D=64; one member's stack is
  (2 + L)·D·D·4 B ≈ 96 KiB for L=4 — far under the ~16 MiB VMEM budget,
  see DESIGN.md §Hardware-Adaptation);
* the member's (L+2)-layer forward runs entirely in registers/VMEM —
  the only HBM traffic is the weight stream and one [B, D] accumulation;
* members accumulate into the output block, which stays revisited across
  the sequential grid (the standard Pallas reduction idiom: initialize at
  step 0 with ``pl.when``).

Heterogeneous member depth/width (paper: 1–8 hidden layers, decaying
widths) is encoded structurally: narrower members zero-pad weight columns;
shallower members use identity (w=I, BN folded to s=1, t=0) padding
layers, which are exact no-ops after ReLU since hidden activations are
non-negative.

On a real TPU the per-step work is D×D matmuls on the MXU; lowered here
with ``interpret=True`` because CPU PJRT cannot execute Mosaic
custom-calls (AOT recipe).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(
    x_ref,
    w_in_ref,
    b_in_ref,
    s_in_ref,
    t_in_ref,
    w_h_ref,
    b_h_ref,
    s_h_ref,
    t_h_ref,
    w_out_ref,
    b_out_ref,
    o_ref,
    *,
    n_hidden: int,
    n_members: int,
):
    m = pl.program_id(0)
    x = x_ref[...]  # [B, D]
    h = x @ w_in_ref[0] + b_in_ref[0]
    h = jnp.maximum(h * s_in_ref[0] + t_in_ref[0], 0.0)
    for l in range(n_hidden):  # static unroll: L is a compile-time constant
        h2 = h @ w_h_ref[0, l] + b_h_ref[0, l]
        h = jnp.maximum(h2 * s_h_ref[0, l] + t_h_ref[0, l], 0.0)
    logits = h @ w_out_ref[0] + b_out_ref[0]

    @pl.when(m == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += logits / n_members


def ensemble_mlp_forward(x, p, *, interpret: bool = True):
    """Fused ensemble forward; same contract as ``ref.ensemble_mlp_forward``.

    x: f32[B, D]; p: folded parameter dict (see ref.py). Returns f32[B, D]
    mean-over-members logits.
    """
    M, D, _ = p["w_in"].shape
    L = p["w_h"].shape[1]
    B = x.shape[0]

    member = lambda m: (m, 0)  # noqa: E731 — block index maps
    member3 = lambda m: (m, 0, 0)  # noqa: E731
    member4 = lambda m: (m, 0, 0, 0)  # noqa: E731
    whole = lambda m: (0, 0)  # noqa: E731

    return pl.pallas_call(
        functools.partial(_kernel, n_hidden=L, n_members=M),
        grid=(M,),
        in_specs=[
            pl.BlockSpec((B, D), whole),  # x — resident across all steps
            pl.BlockSpec((1, D, D), member3),  # w_in
            pl.BlockSpec((1, D), member),  # b_in
            pl.BlockSpec((1, D), member),  # s_in
            pl.BlockSpec((1, D), member),  # t_in
            pl.BlockSpec((1, L, D, D), member4),  # w_h
            pl.BlockSpec((1, L, D), member3),  # b_h
            pl.BlockSpec((1, L, D), member3),  # s_h
            pl.BlockSpec((1, L, D), member3),  # t_h
            pl.BlockSpec((1, D, D), member3),  # w_out
            pl.BlockSpec((1, D), member),  # b_out
        ],
        out_specs=pl.BlockSpec((B, D), whole),  # accumulated across steps
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        interpret=interpret,
    )(
        x,
        p["w_in"],
        p["b_in"],
        p["s_in"],
        p["t_in"],
        p["w_h"],
        p["b_h"],
        p["s_h"],
        p["t_h"],
        p["w_out"],
        p["b_out"],
    )
