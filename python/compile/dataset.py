"""Synthetic dataset generation for GPUMemNet (paper §3.1).

Implements the paper's dataset-collection principles:

* focus on *architecture types* (MLP / CNN / Transformer), not named models;
* representative feature ranges (no thousand-layer MLPs);
* approximately uniform coverage of the feature space;
* diverse shapes (uniform, pyramid, hourglass topologies);
* diverse layer mixes (batch-norm / dropout variants);
* varying input and output sizes.

Ground-truth "measured" memory comes from :mod:`memsim` (DESIGN.md §1),
with a small multiplicative measurement noise, then discretized into
fixed-size classes.

Each sample is ``(features[16], layer_seq[SEQ_LEN, 3], label)``; the
layer sequence feeds the Transformer-based estimator, the flat features
feed both.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass

from . import memsim
from .memsim import TaskFeatures, activation_encoding

SEQ_LEN = 32  # layer-sequence length fed to the transformer estimator
# layer type ids used in the (type, acts, params) tuples
LT_LINEAR, LT_CONV, LT_NORM, LT_ATTENTION, LT_FFN = 1.0, 2.0, 3.0, 4.0, 5.0

NOISE_STD = 0.02  # multiplicative measurement noise (sigma)

BATCH_SIZES = [8, 16, 32, 64, 128, 256, 512]
ACTIVATIONS = list(memsim.ACTIVATION_ANGLE.keys())


@dataclass
class Sample:
    features: list[float]  # 16 floats (DESIGN.md §6)
    layer_seq: list[list[float]]  # SEQ_LEN x (type, acts_m, params_m)
    mem_gb: float  # noisy "measured" memory
    mem_gb_clean: float  # memsim without noise
    arch: str


def _pad_seq(seq: list[list[float]]) -> list[list[float]]:
    """Pad/truncate the per-layer tuple sequence to SEQ_LEN.

    Long networks are *pooled* (adjacent tuples merged) instead of
    truncated so total params/acts are preserved.
    """
    if len(seq) > SEQ_LEN:
        merged: list[list[float]] = []
        group = max(1, math.ceil(len(seq) / SEQ_LEN))
        for i in range(0, len(seq), group):
            chunk = seq[i : i + group]
            merged.append(
                [
                    chunk[0][0],
                    sum(c[1] for c in chunk),
                    sum(c[2] for c in chunk),
                ]
            )
        seq = merged[:SEQ_LEN]
    while len(seq) < SEQ_LEN:
        seq.append([0.0, 0.0, 0.0])
    return seq


def _shape_widths(rng: random.Random, depth: int, w_max: int) -> list[int]:
    """Uniform / pyramid / hourglass width profiles (paper §3.1)."""
    kind = rng.choice(["uniform", "pyramid", "hourglass"])
    if kind == "uniform" or depth == 1:
        return [w_max] * depth
    if kind == "pyramid":
        # exponential decay towards the output
        w_min = max(8, w_max // rng.choice([4, 8, 16]))
        return [
            max(w_min, int(w_max * (w_min / w_max) ** (i / max(1, depth - 1))))
            for i in range(depth)
        ]
    # hourglass: narrow middle
    w_min = max(8, w_max // rng.choice([4, 8]))
    mid = (depth - 1) / 2.0
    return [
        max(
            w_min,
            int(w_min + (w_max - w_min) * abs(i - mid) / max(mid, 1.0)),
        )
        for i in range(depth)
    ]


# ---------------------------------------------------------------------------
# Architecture samplers
# ---------------------------------------------------------------------------


def sample_mlp(rng: random.Random) -> Sample:
    input_dim = rng.choice([784, 3072, 10240, 49152, 150528])
    output_dim = rng.choice([2, 10, 100, 365, 1000])
    depth = rng.randint(1, 10)
    w_max = rng.choice([64, 128, 256, 512, 1024, 2048, 4096, 8192, 12288])
    widths = _shape_widths(rng, depth, w_max)
    use_bn = rng.random() < 0.5
    n_dropout = rng.randint(0, depth)
    act = rng.choice(ACTIVATIONS)
    bs = rng.choice(BATCH_SIZES)

    dims = [input_dim] + widths + [output_dim]
    params = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
    acts = sum(dims[1:])  # per-sample activations stored for backward
    if use_bn:
        params += 2 * sum(widths)
        acts += sum(widths)

    seq = []
    for i in range(len(dims) - 1):
        seq.append(
            [
                LT_LINEAR,
                dims[i + 1] / 1e6,
                (dims[i] * dims[i + 1] + dims[i + 1]) / 1e6,
            ]
        )
        if use_bn and i < len(widths):
            seq.append([LT_NORM, dims[i + 1] / 1e6, 2 * dims[i + 1] / 1e6])

    cos, sin = activation_encoding(act)
    f = TaskFeatures(
        arch="mlp",
        n_linear=float(depth + 1),
        n_batchnorm=float(depth if use_bn else 0),
        n_dropout=float(n_dropout),
        params_m=params / 1e6,
        acts_m=acts / 1e6,
        batch_size=float(bs),
        n_gpus=1.0,
        act_cos=cos,
        act_sin=sin,
        input_dim=float(input_dim),
        output_dim=float(output_dim),
        seq_or_spatial=0.0,
        depth_total=float(depth + 1),
        width_max=float(w_max),
    )
    return _finish(rng, f, seq)


def sample_cnn(rng: random.Random) -> Sample:
    spatial = rng.choice([32, 64, 128, 224, 299])
    in_ch = 3
    n_stages = rng.randint(2, 5)
    convs_per_stage = rng.randint(1, 16)
    base_ch = rng.choice([16, 24, 32, 48, 64, 96, 128])
    output_dim = rng.choice([10, 100, 1000])
    act = rng.choice(["relu", "gelu", "silu", "leaky_relu"])
    # large batches only plausible at small resolutions
    bs = rng.choice(BATCH_SIZES if spatial <= 64 else BATCH_SIZES[:6])
    use_bn = rng.random() < 0.85
    n_dropout = rng.randint(0, 2)
    # some nets keep full resolution through the first stage(s), which
    # blows up activation memory — needed to cover the >8 GB classes
    late_downsample = rng.random() < 0.35

    params = 0.0
    acts = 0.0
    n_conv = 0
    seq = []
    ch = in_ch
    hw = spatial
    for s in range(n_stages):
        out_ch = base_ch * (2**s)
        for c in range(convs_per_stage):
            # downsample at stage start (unless late_downsample keeps the
            # first stage at full resolution)
            stride = 2 if c == 0 and not (late_downsample and s == 0) else 1
            hw = max(1, hw // stride)
            p = ch * out_ch * 9 + out_ch
            a = out_ch * hw * hw
            params += p
            acts += a
            n_conv += 1
            seq.append([LT_CONV, a / 1e6, p / 1e6])
            if use_bn:
                params += 2 * out_ch
                acts += a
                seq.append([LT_NORM, a / 1e6, 2 * out_ch / 1e6])
            ch = out_ch
    # global-average-pool head
    head_p = ch * output_dim + output_dim
    params += head_p
    acts += output_dim
    seq.append([LT_LINEAR, output_dim / 1e6, head_p / 1e6])

    cos, sin = activation_encoding(act)
    f = TaskFeatures(
        arch="cnn",
        n_linear=1.0,
        n_conv=float(n_conv),
        n_batchnorm=float(n_conv if use_bn else 0),
        n_dropout=float(n_dropout),
        params_m=params / 1e6,
        acts_m=acts / 1e6,
        batch_size=float(bs),
        n_gpus=1.0,
        act_cos=cos,
        act_sin=sin,
        input_dim=float(3 * spatial * spatial),
        output_dim=float(output_dim),
        seq_or_spatial=float(spatial),
        depth_total=float(n_conv + 1),
        width_max=float(base_ch * (2 ** (n_stages - 1))),
    )
    return _finish(rng, f, seq)


def sample_transformer(rng: random.Random) -> Sample:
    d_model = rng.choice([64, 128, 256, 384, 512, 768, 1024, 1280, 1536, 2048])
    n_layers = rng.randint(2, 48)
    n_heads = max(1, d_model // 64)
    d_ff = 4 * d_model
    seq_len = rng.choice([128, 256, 512, 1024, 2048])
    vocab = rng.choice([8192, 16384, 30522, 50257])
    bs = rng.choice([1, 2, 4, 8, 16, 32, 64])
    act = rng.choice(["gelu", "relu", "silu"])
    n_dropout = rng.randint(0, 3 * n_layers)

    embed_p = vocab * d_model + seq_len * d_model
    attn_p = 4 * d_model * d_model + 4 * d_model
    ffn_p = 2 * d_model * d_ff + d_model + d_ff
    norm_p = 4 * d_model
    params = embed_p + n_layers * (attn_p + ffn_p + norm_p) + d_model * vocab

    # stored activations per sample: ~10 d-wide tensors per block plus the
    # attention matrices (heads * seq^2)
    acts_block = seq_len * d_model * 10.0 + n_heads * seq_len * seq_len
    acts = seq_len * d_model + n_layers * acts_block + seq_len * vocab * 0.25

    seq = []
    seq.append([LT_LINEAR, seq_len * d_model / 1e6, embed_p / 1e6])
    for _ in range(n_layers):
        seq.append(
            [
                LT_ATTENTION,
                (seq_len * d_model * 4 + n_heads * seq_len * seq_len) / 1e6,
                attn_p / 1e6,
            ]
        )
        seq.append([LT_FFN, seq_len * (d_ff + d_model) / 1e6, ffn_p / 1e6])
        seq.append([LT_NORM, 2 * seq_len * d_model / 1e6, norm_p / 1e6])

    cos, sin = activation_encoding(act)
    f = TaskFeatures(
        arch="transformer",
        n_linear=float(6 * n_layers + 2),
        n_batchnorm=float(2 * n_layers + 1),  # layer norms
        n_dropout=float(n_dropout),
        params_m=params / 1e6,
        acts_m=acts / 1e6,
        batch_size=float(bs),
        n_gpus=1.0,
        act_cos=cos,
        act_sin=sin,
        input_dim=float(vocab),
        output_dim=float(vocab),
        seq_or_spatial=float(seq_len),
        depth_total=float(n_layers),
        width_max=float(d_model),
    )
    return _finish(rng, f, seq)


def _finish(rng: random.Random, f: TaskFeatures, seq: list[list[float]]) -> Sample:
    clean = memsim.measured_gb(f)
    noisy = clean * (1.0 + NOISE_STD * rng.gauss(0.0, 1.0))
    return Sample(
        features=f.to_vec(),
        layer_seq=_pad_seq(seq),
        mem_gb=max(noisy, 0.7),
        mem_gb_clean=clean,
        arch=f.arch,
    )


SAMPLERS = {"mlp": sample_mlp, "cnn": sample_cnn, "transformer": sample_transformer}


def generate(arch: str, n: int, seed: int = 0) -> list[Sample]:
    """Generate ``n`` samples for one architecture dataset.

    Rejection-samples towards a flatter class histogram ("uniform feature
    distribution", paper §3.1): over-full classes are resampled with
    probability proportional to how over-represented they are.
    """
    rng = random.Random(seed ^ hash(arch) & 0xFFFFFFFF)
    sampler = SAMPLERS[arch]
    range_gb = 1.0 if arch == "mlp" else 8.0
    n_classes = memsim.num_classes(range_gb)
    target = n / n_classes
    counts = [0] * n_classes
    out: list[Sample] = []
    attempts = 0
    while len(out) < n and attempts < n * 30:
        attempts += 1
        s = sampler(rng)
        c = memsim.label_for(s.mem_gb, range_gb)
        # soft balancing: accept with decreasing probability once a class
        # is over target (hard rejection starves classes that are simply
        # unreachable for an architecture)
        over = counts[c] / max(target, 1.0)
        if over > 1.0 and rng.random() < min(0.95, 1.0 - 1.0 / over):
            continue
        counts[c] += 1
        out.append(s)
    return out
