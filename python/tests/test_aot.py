"""AOT export path: HLO text emission + artifact/manifest integrity."""

import json
import os

import pytest
import jax
import jax.numpy as jnp

from compile import aot
from compile.train import artifacts_dir

ART = artifacts_dir()


class TestHloText:
    def test_lower_tiny_fn_to_hlo_text(self):
        def fn(x, y):
            return (jnp.matmul(x, y) + 2.0,)

        spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
        assert "HloModule" in text
        assert "ENTRY" in text
        # text interchange (not proto) — parsable header present
        assert "f32[2,2]" in text

    def test_pallas_kernel_lowers_to_plain_hlo(self):
        """interpret=True Pallas must lower without Mosaic custom-calls."""
        from compile.kernels import ensemble_mlp
        import numpy as np

        rng = np.random.default_rng(0)
        p = {
            "w_in": rng.normal(size=(2, 8, 8)).astype(np.float32),
            "b_in": rng.normal(size=(2, 8)).astype(np.float32),
            "s_in": rng.normal(size=(2, 8)).astype(np.float32),
            "t_in": rng.normal(size=(2, 8)).astype(np.float32),
            "w_h": rng.normal(size=(2, 1, 8, 8)).astype(np.float32),
            "b_h": rng.normal(size=(2, 1, 8)).astype(np.float32),
            "s_h": rng.normal(size=(2, 1, 8)).astype(np.float32),
            "t_h": rng.normal(size=(2, 1, 8)).astype(np.float32),
            "w_out": rng.normal(size=(2, 8, 8)).astype(np.float32),
            "b_out": rng.normal(size=(2, 8)).astype(np.float32),
        }

        def fn(x):
            return (ensemble_mlp.ensemble_mlp_forward(x, p),)

        spec = jax.ShapeDtypeStruct((1, 8), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec))
        assert "HloModule" in text
        assert "mosaic" not in text.lower()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "gpumemnet_manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestArtifacts:
    def test_manifest_files_exist(self):
        manifest = json.load(open(os.path.join(ART, "gpumemnet_manifest.json")))
        assert len(manifest) >= 3
        for fname, meta in manifest.items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), fname
            head = open(path).read(200)
            assert "HloModule" in head
            assert meta["n_classes"] >= 5
            assert meta["range_gb"] in (1.0, 2.0, 8.0)

    def test_lm_manifest_consistent(self):
        mpath = os.path.join(ART, "lm_manifest.json")
        if not os.path.exists(mpath):
            pytest.skip("lm artifacts not built")
        m = json.load(open(mpath))
        assert m["n_arrays"] == len(m["param_names"])
        assert set(m["param_names"]) == set(m["param_shapes"].keys())
        for f in ("lm_init.hlo.txt", "lm_step.hlo.txt"):
            assert os.path.exists(os.path.join(ART, f))

    def test_table1_exists_and_sane(self):
        t1 = os.path.join(ART, "table1.json")
        if not os.path.exists(t1):
            pytest.skip("table1 not built")
        rows = json.load(open(t1))
        assert len(rows) == 8  # paper Table 1 has 8 rows
        for r in rows:
            assert 0.0 <= r["accuracy"] <= 1.0
            assert 0.0 <= r["f1"] <= 1.0
