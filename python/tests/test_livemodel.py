"""Live-mode LM: training signal + flat-wrapper parity (the contract the
Rust runtime drives through lm_step.hlo.txt)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import livemodel

CFG = livemodel.LmConfig(vocab=128, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16, batch=4)


def batch(seed=0):
    rng = np.random.default_rng(seed)
    # learnable synthetic stream: next token = (token + 1) mod small-cycle
    start = rng.integers(0, 16, (CFG.batch, 1))
    steps = np.arange(CFG.seq_len + 1)[None, :]
    return ((start + steps) % 16).astype(np.int32)


class TestLm:
    def test_forward_shape(self):
        p = livemodel.init(CFG, 0)
        tokens = jnp.asarray(batch()[:, :-1])
        out = livemodel.forward(p, CFG, tokens)
        assert out.shape == (CFG.batch, CFG.seq_len, CFG.vocab)

    def test_causality(self):
        """Changing a future token must not change earlier logits."""
        p = livemodel.init(CFG, 0)
        t1 = batch()[:, :-1].copy()
        t2 = t1.copy()
        t2[:, -1] = (t2[:, -1] + 5) % CFG.vocab
        o1 = np.asarray(livemodel.forward(p, CFG, jnp.asarray(t1)))
        o2 = np.asarray(livemodel.forward(p, CFG, jnp.asarray(t2)))
        np.testing.assert_allclose(o1[:, :-1], o2[:, :-1], rtol=1e-5, atol=1e-5)

    def test_loss_decreases(self):
        p = livemodel.init(CFG, 0)
        m = jax.tree.map(jnp.zeros_like, p)
        v = jax.tree.map(jnp.zeros_like, p)
        step = jax.jit(
            lambda p, m, v, s, t: livemodel.train_step(p, m, v, s, CFG, t)
        )
        losses = []
        for i in range(1, 31):
            p, m, v, loss = step(p, m, v, jnp.asarray(float(i)), jnp.asarray(batch(i)))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.7

    def test_flat_wrapper_matches_dict_api(self):
        names = livemodel.param_names(CFG)
        n = len(names)
        flat0 = livemodel.flat_init(CFG, 0)
        assert len(flat0) == 3 * n

        tokens = jnp.asarray(batch(3))
        fs = livemodel.make_flat_step(CFG)
        out = fs(*flat0, jnp.asarray(1.0), tokens)
        assert len(out) == 3 * n + 1

        p = dict(zip(names, flat0[:n]))
        m = dict(zip(names, flat0[n : 2 * n]))
        v = dict(zip(names, flat0[2 * n :]))
        p2, m2, v2, loss = livemodel.train_step(p, m, v, jnp.asarray(1.0), CFG, tokens)
        np.testing.assert_allclose(float(out[-1]), float(loss), rtol=1e-6)
        for i, x in enumerate(names):
            np.testing.assert_allclose(out[i], p2[x], rtol=1e-6, atol=1e-6)

    def test_param_names_count_matches_init(self):
        p = livemodel.init(CFG, 0)
        assert sorted(livemodel.param_names(CFG)) == sorted(p.keys())
