"""memsim properties + golden-file self-consistency (mirrored in Rust)."""

import json
import math
import os

import pytest
from hypothesis import given, settings, strategies as st

from compile import memsim
from compile.memsim import TaskFeatures

DATA = os.path.join(os.path.dirname(__file__), "..", "..", "data")


def features(arch="cnn", **kw):
    base = dict(
        arch=arch,
        n_linear=2.0,
        n_conv=20.0 if arch == "cnn" else 0.0,
        params_m=25.0,
        acts_m=20.0,
        batch_size=32.0,
        n_gpus=1.0,
    )
    base.update(kw)
    return TaskFeatures(**base)


class TestMeasuredGb:
    def test_minimum_includes_context(self):
        f = features(params_m=0.001, acts_m=0.001)
        assert memsim.measured_gb(f) > 0.6  # CUDA context floor

    @settings(max_examples=60, deadline=None)
    @given(
        p=st.floats(0.1, 500.0),
        a=st.floats(0.01, 200.0),
        bs=st.sampled_from([1, 8, 32, 128, 512]),
        arch=st.sampled_from(["mlp", "cnn", "transformer"]),
    )
    def test_monotone_in_params_and_acts(self, p, a, bs, arch):
        f1 = features(arch, params_m=p, acts_m=a, batch_size=float(bs))
        f2 = features(arch, params_m=p * 1.5, acts_m=a, batch_size=float(bs))
        f3 = features(arch, params_m=p, acts_m=a * 1.5, batch_size=float(bs))
        m1 = memsim.measured_gb(f1)
        assert memsim.measured_gb(f2) >= m1
        assert memsim.measured_gb(f3) >= m1

    @settings(max_examples=40, deadline=None)
    @given(p=st.floats(1.0, 200.0), a=st.floats(1.0, 100.0))
    def test_multi_gpu_reduces_per_gpu_memory(self, p, a):
        f1 = features("transformer", params_m=p, acts_m=a, n_gpus=1.0)
        f2 = features("transformer", params_m=p, acts_m=a, n_gpus=2.0)
        assert memsim.measured_gb(f2) <= memsim.measured_gb(f1)

    def test_staircase_quantization(self):
        """Activation pool grows in 256 MiB steps -> plateaus exist."""
        vals = set()
        for a in [x / 100.0 for x in range(100, 200)]:
            f = features("mlp", params_m=1.0, acts_m=a, batch_size=32.0)
            vals.add(round(memsim.measured_gb(f), 9))
        # 100 distinct acts values must collapse onto few plateaus
        assert len(vals) < 25

    def test_pool_alignment(self):
        f = features("mlp", params_m=3.0, acts_m=2.0)
        b = memsim.measured_bytes(f) - memsim.CTX_BYTES
        assert b % (64.0 * memsim.MIB) == 0.0


class TestLabels:
    @settings(max_examples=60, deadline=None)
    @given(m=st.floats(0.01, 400.0), rg=st.sampled_from([1.0, 2.0, 8.0]))
    def test_label_bounds(self, m, rg):
        c = memsim.label_for(m, rg)
        assert 0 <= c < memsim.num_classes(rg)

    @settings(max_examples=60, deadline=None)
    @given(m=st.floats(0.01, 39.9), rg=st.sampled_from([1.0, 2.0, 8.0]))
    def test_estimate_upper_bounds_memory(self, m, rg):
        """Within the cap, the class upper edge never underestimates."""
        c = memsim.label_for(m, rg)
        assert memsim.estimate_from_label(c, rg) >= m - 1e-9

    def test_bucket_edges(self):
        assert memsim.label_for(0.5, 1.0) == 0
        assert memsim.label_for(1.0, 1.0) == 0
        assert memsim.label_for(1.0001, 1.0) == 1
        assert memsim.label_for(7.9, 8.0) == 0
        assert memsim.label_for(8.1, 8.0) == 1
        assert memsim.label_for(500.0, 8.0) == memsim.num_classes(8.0) - 1


class TestGolden:
    def test_golden_file_matches_current_formula(self):
        path = os.path.join(DATA, "memsim_golden.json")
        if not os.path.exists(path):
            pytest.skip("golden not generated yet (run compile.analysis)")
        cases = json.load(open(path))
        assert len(cases) >= 32
        for c in cases:
            f = TaskFeatures(
                arch=c["arch"],
                **dict(
                    zip(
                        [
                            "n_linear", "n_conv", "n_batchnorm", "n_dropout",
                            "params_m", "acts_m", "batch_size", "n_gpus",
                            "act_cos", "act_sin", "input_dim", "output_dim",
                            "seq_or_spatial", "depth_total", "width_max", "reserved",
                        ],
                        c["features"],
                    )
                ),
            )
            assert math.isclose(memsim.measured_gb(f), c["mem_gb"], rel_tol=1e-12)
            assert memsim.label_for(c["mem_gb"], 1.0) == c["label_1gb"]
            assert memsim.label_for(c["mem_gb"], 8.0) == c["label_8gb"]


class TestZooCalibration:
    def test_zoo_memsim_close_to_paper(self):
        path = os.path.join(DATA, "model_zoo.json")
        zoo = json.load(open(path))["models"]
        assert len(zoo) == 35
        for m in zoo:
            # calibration keeps memsim within one activation-pool step
            assert abs(m["memsim_gb"] - m["mem_gb"]) <= 0.26, m["name"]
