"""Pallas kernels vs pure-jnp oracle (ref.py) — the core L1 correctness
signal.  Hypothesis sweeps shapes/dtypes; assert_allclose against ref."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ensemble_mlp, ref, transformer_encoder

RTOL = {np.float32: 2e-5, np.float64: 1e-12}
ATOL = {np.float32: 2e-5, np.float64: 1e-12}


def make_ensemble_params(rng, M, L, D, dtype):
    def r(*shape, scale=0.1):
        return (rng.normal(size=shape) * scale).astype(dtype)

    return {
        "w_in": r(M, D, D),
        "b_in": r(M, D),
        "s_in": (rng.normal(size=(M, D)) * 0.5 + 1.0).astype(dtype),
        "t_in": r(M, D),
        "w_h": r(M, L, D, D),
        "b_h": r(M, L, D),
        "s_h": (rng.normal(size=(M, L, D)) * 0.5 + 1.0).astype(dtype),
        "t_h": r(M, L, D),
        "w_out": r(M, D, D),
        "b_out": r(M, D),
    }


def make_encoder_params(rng, D, F, dtype):
    def r(*shape, scale=0.2):
        return (rng.normal(size=shape) * scale).astype(dtype)

    p = {k: r(D, D) for k in ("wq", "wk", "wv", "wo")}
    p["ln1_g"] = (rng.normal(size=(D,)) * 0.1 + 1.0).astype(dtype)
    p["ln2_g"] = (rng.normal(size=(D,)) * 0.1 + 1.0).astype(dtype)
    p["ln1_b"] = r(D)
    p["ln2_b"] = r(D)
    p["w1"] = r(D, F)
    p["b1"] = r(F)
    p["w2"] = r(F, D)
    p["b2"] = r(D)
    return p


class TestEnsembleMlpKernel:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 12),
        l=st.integers(1, 6),
        d=st.sampled_from([8, 16, 32, 64]),
        b=st.integers(1, 16),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_shapes(self, m, l, d, b, seed):
        rng = np.random.default_rng(seed)
        p = make_ensemble_params(rng, m, l, d, np.float32)
        x = rng.normal(size=(b, d)).astype(np.float32)
        got = ensemble_mlp.ensemble_mlp_forward(x, p)
        want = ref.ensemble_mlp_forward(x, p)
        np.testing.assert_allclose(got, want, rtol=RTOL[np.float32], atol=ATOL[np.float32])

    def test_dtype_f32(self):
        rng = np.random.default_rng(3)
        p = make_ensemble_params(rng, 4, 2, 16, np.float32)
        x = rng.normal(size=(5, 16)).astype(np.float32)
        got = ensemble_mlp.ensemble_mlp_forward(x, p)
        want = ref.ensemble_mlp_forward(x, p)
        assert got.dtype == want.dtype
        np.testing.assert_allclose(got, want, rtol=RTOL[np.float32], atol=ATOL[np.float32])

    def test_dtype_f64(self):
        from jax.experimental import enable_x64

        with enable_x64():
            rng = np.random.default_rng(3)
            p = make_ensemble_params(rng, 4, 2, 16, np.float64)
            x = rng.normal(size=(5, 16)).astype(np.float64)
            got = ensemble_mlp.ensemble_mlp_forward(x, p)
            want = ref.ensemble_mlp_forward(x, p)
            assert got.dtype == want.dtype
            np.testing.assert_allclose(got, want, rtol=RTOL[np.float64], atol=ATOL[np.float64])

    def test_identity_padding_is_noop(self):
        """Identity hidden layers (w=I, s=1, t=0) must not change logits."""
        rng = np.random.default_rng(5)
        M, D, B = 3, 16, 4
        p1 = make_ensemble_params(rng, M, 1, D, np.float32)
        # same model with 3 extra identity layers appended
        eye = np.broadcast_to(np.eye(D, dtype=np.float32), (M, 3, D, D))
        p4 = dict(p1)
        p4["w_h"] = np.concatenate([p1["w_h"], eye], axis=1)
        p4["b_h"] = np.concatenate([p1["b_h"], np.zeros((M, 3, D), np.float32)], axis=1)
        p4["s_h"] = np.concatenate([p1["s_h"], np.ones((M, 3, D), np.float32)], axis=1)
        p4["t_h"] = np.concatenate([p1["t_h"], np.zeros((M, 3, D), np.float32)], axis=1)
        x = rng.normal(size=(B, D)).astype(np.float32)
        a = ensemble_mlp.ensemble_mlp_forward(x, p1)
        b = ensemble_mlp.ensemble_mlp_forward(x, p4)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    def test_mean_of_single_member_equals_member(self):
        rng = np.random.default_rng(9)
        p = make_ensemble_params(rng, 1, 2, 8, np.float32)
        x = rng.normal(size=(3, 8)).astype(np.float32)
        got = ensemble_mlp.ensemble_mlp_forward(x, p)
        want = ref.ensemble_mlp_forward(x, p)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestEncoderKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 8),
        s=st.sampled_from([4, 8, 16, 32]),
        d=st.sampled_from([8, 16, 32]),
        f=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_shapes(self, b, s, d, f, seed):
        rng = np.random.default_rng(seed)
        p = make_encoder_params(rng, d, f, np.float32)
        x = rng.normal(size=(b, s, d)).astype(np.float32)
        got = transformer_encoder.encoder_block(x, p)
        want = ref.encoder_block(x, p)
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)

    def test_residual_structure(self):
        """Zero weights -> block must reduce to (close to) identity + FFN bias."""
        D, F = 16, 32
        p = {k: np.zeros((D, D), np.float32) for k in ("wq", "wk", "wv", "wo")}
        p.update(
            ln1_g=np.ones(D, np.float32), ln1_b=np.zeros(D, np.float32),
            ln2_g=np.ones(D, np.float32), ln2_b=np.zeros(D, np.float32),
            w1=np.zeros((D, F), np.float32), b1=np.zeros(F, np.float32),
            w2=np.zeros((F, D), np.float32), b2=np.zeros(D, np.float32),
        )
        rng = np.random.default_rng(0)
        x = rng.normal(size=(2, 8, D)).astype(np.float32)
        got = transformer_encoder.encoder_block(x, p)
        np.testing.assert_allclose(got, x, rtol=1e-6, atol=1e-6)

    def test_softmax_rows_sum_to_one_internally(self):
        """Permuting batch order must permute outputs (no cross-sample mixing)."""
        rng = np.random.default_rng(4)
        p = make_encoder_params(rng, 16, 32, np.float32)
        x = rng.normal(size=(4, 8, 16)).astype(np.float32)
        perm = np.array([2, 0, 3, 1])
        a = transformer_encoder.encoder_block(x[perm], p)
        b = np.asarray(transformer_encoder.encoder_block(x, p))[perm]
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
