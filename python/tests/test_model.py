"""L2 model tests: BN folding, structural masks, training signal, and the
pallas-vs-ref parity of the full exported inference graph."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile import train as T


def tiny_data(n=256, n_classes=5, seed=0):
    rng = np.random.default_rng(seed)
    X = np.zeros((n, 16), np.float32)
    X[:, 4] = rng.uniform(0.5, 500.0, n)  # params_m
    X[:, 5] = rng.uniform(0.5, 200.0, n)  # acts_m
    X[:, 6] = rng.choice([8, 32, 128], n)  # batch size
    y = (np.log1p(X[:, 4] * X[:, 6]) * 0.45).astype(np.int32) % n_classes
    return X, y


class TestEnsemble:
    def test_init_shapes_and_masks(self):
        params, state, static, mask = model.init_ensemble(jax.random.PRNGKey(0), 5)
        M, L, D = model.N_MEMBERS, model.L_HIDDEN, model.D_PAD
        assert params.w_in.shape == (M, D, D)
        assert params.w_h.shape == (M, L, D, D)
        # identity padding layers must be exact identity and frozen
        for m in range(M):
            for l in range(static.depth[m], L):
                np.testing.assert_array_equal(params.w_h[m, l], np.eye(D))
                assert float(mask.w_h[m, l].sum()) == 0.0

    def test_member_widths_decay(self):
        ws = model.member_widths(None)
        assert ws[0] == model.MEMBER_W_MAX
        assert ws[-1] == model.MEMBER_W_MIN
        assert all(a >= b for a, b in zip(ws, ws[1:]))

    def test_train_reduces_loss(self):
        X, y = tiny_data()
        params, state, static, mask = model.init_ensemble(jax.random.PRNGKey(1), 5)
        m, v = model.adam_init(params)

        def loss_fn(p, st):
            logits, st2 = model.ensemble_train_forward(p, st, static, jnp.asarray(X))
            return model.cross_entropy(logits, jnp.asarray(y)), st2

        (l0, state2), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, state)
        for i in range(1, 40):
            (li, state), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, state)
            grads = jax.tree.map(lambda g, msk: g * msk, grads, mask)
            params, m, v = model.adam_update(params, grads, m, v, i, lr=3e-3)
        (l1, _), _ = jax.value_and_grad(loss_fn, has_aux=True)(params, state)
        assert float(l1) < float(l0) * 0.9

    def test_masked_params_stay_fixed_under_masked_updates(self):
        X, y = tiny_data(64)
        params, state, static, mask = model.init_ensemble(jax.random.PRNGKey(2), 5)
        m, v = model.adam_init(params)

        def loss_fn(p, st):
            logits, st2 = model.ensemble_train_forward(p, st, static, jnp.asarray(X))
            return model.cross_entropy(logits, jnp.asarray(y)), st2

        before = params.w_h
        for i in range(1, 4):
            (_, state), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, state)
            grads = jax.tree.map(lambda g, msk: g * msk, grads, mask)
            params, m, v = model.adam_update(params, grads, m, v, i)
        for mm in range(model.N_MEMBERS):
            for l in range(static.depth[mm], model.L_HIDDEN):
                np.testing.assert_array_equal(params.w_h[mm, l], before[mm, l])

    def test_fold_bn_matches_eval_forward(self):
        """Folded inference must equal a BN-eval-mode forward pass."""
        X, y = tiny_data(128)
        params, state, static, mask = model.init_ensemble(jax.random.PRNGKey(3), 5)
        m, v = model.adam_init(params)

        def loss_fn(p, st):
            logits, st2 = model.ensemble_train_forward(p, st, static, jnp.asarray(X))
            return model.cross_entropy(logits, jnp.asarray(y)), st2

        # a few steps so running stats are non-trivial
        for i in range(1, 6):
            (_, state), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, state)
            grads = jax.tree.map(lambda g, msk: g * msk, grads, mask)
            params, m, v = model.adam_update(params, grads, m, v, i)

        folded = model.fold_bn(params, state, static)
        got = model.ensemble_infer(folded, jnp.asarray(X[:16]), 5, use_pallas=False)

        # manual eval-mode forward with running stats
        x = model.pad_features(model.normalize_features(jnp.asarray(X[:16])))
        acc = 0.0
        for mm in range(model.N_MEMBERS):
            h = x @ params.w_in[mm] + params.b_in[mm]
            h = (h - state.mu_in[mm]) / jnp.sqrt(state.var_in[mm] + model.BN_EPS)
            h = h * params.g_in[mm] + params.be_in[mm]
            wv = (jnp.arange(model.D_PAD) < static.width[mm]).astype(jnp.float32)
            h = jnp.maximum(h * wv, 0.0)
            for l in range(model.L_HIDDEN):
                if l < static.depth[mm]:
                    h2 = h @ params.w_h[mm, l] + params.b_h[mm, l]
                    h2 = (h2 - state.mu_h[mm, l]) / jnp.sqrt(state.var_h[mm, l] + model.BN_EPS)
                    h2 = h2 * params.g_h[mm, l] + params.be_h[mm, l]
                    h = jnp.maximum(h2 * wv, 0.0)
            acc = acc + h @ params.w_out[mm] + params.b_out[mm]
        want = (acc / model.N_MEMBERS)[:, :5]
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_pallas_and_ref_inference_agree(self):
        params, state, static, _ = model.init_ensemble(jax.random.PRNGKey(4), 5)
        folded = model.fold_bn(params, state, static)
        X, _ = tiny_data(8)
        a = model.ensemble_infer(folded, jnp.asarray(X), 5, use_pallas=False)
        b = model.ensemble_infer(folded, jnp.asarray(X), 5, use_pallas=True)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


class TestTransformerClassifier:
    def test_forward_shapes(self):
        p = model.init_transformer(jax.random.PRNGKey(0), 5)
        X = np.zeros((4, 16), np.float32)
        S = np.zeros((4, model.SEQ_LEN, 3), np.float32)
        out = model.transformer_forward(p, jnp.asarray(X), jnp.asarray(S))
        assert out.shape == (4, 5)

    def test_pallas_and_ref_agree(self):
        rng = np.random.default_rng(0)
        p = model.init_transformer(jax.random.PRNGKey(1), 5)
        X = rng.uniform(0, 10, (4, 16)).astype(np.float32)
        S = rng.uniform(0, 3, (4, model.SEQ_LEN, 3)).astype(np.float32)
        a = model.transformer_forward(p, jnp.asarray(X), jnp.asarray(S), use_pallas=False)
        b = model.transformer_forward(p, jnp.asarray(X), jnp.asarray(S), use_pallas=True)
        np.testing.assert_allclose(a, b, rtol=5e-5, atol=5e-5)


class TestNormalization:
    def test_normalized_range(self):
        X = np.array(
            [[64, 96, 64, 16, 900, 300, 512, 4, 1, 0, 150528, 50257, 2048, 96, 8192, 0]],
            np.float32,
        )
        out = np.asarray(model.normalize_features(jnp.asarray(X)))
        assert np.all(np.abs(out) < 4.0)

    def test_padding(self):
        X = np.ones((2, 16), np.float32)
        out = model.pad_features(model.normalize_features(jnp.asarray(X)))
        assert out.shape == (2, model.D_PAD)
        assert np.all(np.asarray(out)[:, 16:] == 0.0)


class TestTrainHelpers:
    def test_stratified_split_preserves_classes(self):
        y = np.array([0] * 50 + [1] * 30 + [2] * 20)
        a, b = T.stratified_split(y, 0.7, 0)
        assert len(a) + len(b) == 100
        for c in (0, 1, 2):
            frac = np.mean(y[a] == c)
            assert abs(frac - np.mean(y == c)) < 0.05

    def test_kfold_partitions(self):
        y = np.array([0, 1] * 30)
        seen = []
        for tr, val in T.kfold(y, 3, 0):
            assert set(tr) & set(val) == set()
            seen.extend(val)
        assert sorted(seen) == list(range(60))

    def test_macro_f1_perfect(self):
        y = np.array([0, 1, 2, 2])
        assert T.macro_f1(y, y) == 1.0

    def test_macro_f1_worst(self):
        y = np.array([0, 0, 1, 1])
        p = np.array([1, 1, 0, 0])
        assert T.macro_f1(y, p) == 0.0
