"""Dataset generator properties (paper §3.1 principles)."""

import collections

import pytest
from hypothesis import given, settings, strategies as st

from compile import dataset as ds
from compile import memsim


class TestSamplers:
    @pytest.mark.parametrize("arch", ["mlp", "cnn", "transformer"])
    def test_feature_vector_shape(self, arch):
        samples = ds.generate(arch, 50, seed=3)
        assert len(samples) == 50
        for s in samples:
            assert len(s.features) == 16
            assert len(s.layer_seq) == ds.SEQ_LEN
            assert all(len(t) == 3 for t in s.layer_seq)
            assert s.mem_gb > 0.5
            assert s.arch == arch

    def test_determinism(self):
        a = ds.generate("cnn", 30, seed=9)
        b = ds.generate("cnn", 30, seed=9)
        assert [s.features for s in a] == [s.features for s in b]
        assert [s.mem_gb for s in a] == [s.mem_gb for s in b]

    def test_seeds_differ(self):
        a = ds.generate("cnn", 30, seed=1)
        b = ds.generate("cnn", 30, seed=2)
        assert [s.features for s in a] != [s.features for s in b]

    @pytest.mark.parametrize("arch", ["mlp", "cnn", "transformer"])
    def test_noise_is_small(self, arch):
        for s in ds.generate(arch, 60, seed=5):
            assert abs(s.mem_gb - s.mem_gb_clean) / s.mem_gb_clean < 0.15

    def test_mlp_counts_consistent(self):
        for s in ds.generate("mlp", 60, seed=7):
            f = s.features
            n_linear, n_conv, depth = f[0], f[1], f[13]
            assert n_conv == 0.0
            assert n_linear == depth  # hidden layers + output layer

    def test_cnn_has_convs(self):
        for s in ds.generate("cnn", 60, seed=7):
            assert s.features[1] >= 2.0  # n_conv
            assert s.features[12] > 0.0  # spatial

    def test_transformer_has_seq(self):
        for s in ds.generate("transformer", 60, seed=7):
            assert s.features[12] >= 128.0  # seq_len


class TestClassBalance:
    @pytest.mark.parametrize("arch,rg", [("mlp", 1.0), ("cnn", 8.0), ("transformer", 8.0)])
    def test_soft_balancing_spreads_classes(self, arch, rg):
        samples = ds.generate(arch, 400, seed=2)
        hist = collections.Counter(memsim.label_for(s.mem_gb, rg) for s in samples)
        # must cover at least 4 classes and no class may dominate > 75 %
        assert len(hist) >= 4
        assert max(hist.values()) / len(samples) < 0.75


class TestPadSeq:
    def test_pad_short(self):
        seq = [[1.0, 2.0, 3.0]]
        out = ds._pad_seq(list(seq))
        assert len(out) == ds.SEQ_LEN
        assert out[0] == [1.0, 2.0, 3.0]
        assert out[-1] == [0.0, 0.0, 0.0]

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(1, 400))
    def test_pool_preserves_totals(self, n):
        seq = [[1.0, float(i), float(2 * i)] for i in range(n)]
        out = ds._pad_seq(list(seq))
        assert len(out) == ds.SEQ_LEN
        total_acts = sum(t[1] for t in seq)
        total_params = sum(t[2] for t in seq)
        assert abs(sum(t[1] for t in out) - total_acts) < 1e-6 * max(1.0, total_acts)
        assert abs(sum(t[2] for t in out) - total_params) < 1e-6 * max(1.0, total_params)
