#!/usr/bin/env bash
# CI gate: formatting, lints, tier-1 tests, and a cluster-scale smoke run
# that doubles as the determinism acceptance check (DESIGN.md §3/§8).
set -euo pipefail
cd "$(dirname "$0")"

step() { printf '\n==== %s ====\n' "$*"; }

step "cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt unavailable — skipping (install rustfmt for full CI)"
fi

step "cargo clippy -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    # -A: style lints the existing codebase idiomatically trips (builder-less
    # config mutation, 7-arg recorder hook); correctness lints stay -D
    cargo clippy --all-targets -- -D warnings \
        -A clippy::field-reassign-with-default \
        -A clippy::too-many-arguments \
        -A clippy::needless-range-loop
else
    echo "clippy unavailable — skipping (install clippy for full CI)"
fi

step "cargo build --release"
cargo build --release

step "cargo test -q (tier-1)"
cargo test -q

step "cluster-scale smoke: 8x4 servers, 256 tasks, identical seeded reports"
BIN=target/release/carma
SMOKE_ARGS=(run --servers 8 --gpus-per-server 4 --estimator oracle --margin 2 --seed 7)
A="$("$BIN" "${SMOKE_ARGS[@]}")"
B="$("$BIN" "${SMOKE_ARGS[@]}")"
if [ "$A" != "$B" ]; then
    echo "DETERMINISM FAILURE: two identical seeded runs diverged" >&2
    diff <(printf '%s\n' "$A") <(printf '%s\n' "$B") >&2 || true
    exit 1
fi
printf '%s\n' "$A" | tail -n 4
echo "smoke OK: identical makespan/energy report across both runs"

step "sharded smoke: --shards 4 on the same trace, identical seeded reports"
SHARDED_ARGS=(run --servers 8 --gpus-per-server 4 --shards 4 --estimator oracle --margin 2 --seed 7)
C="$("$BIN" "${SHARDED_ARGS[@]}")"
D="$("$BIN" "${SHARDED_ARGS[@]}")"
if [ "$C" != "$D" ]; then
    echo "DETERMINISM FAILURE: two identical seeded --shards 4 runs diverged" >&2
    diff <(printf '%s\n' "$C") <(printf '%s\n' "$D") >&2 || true
    exit 1
fi
printf '%s\n' "$C" | tail -n 8
echo "sharded smoke OK: identical report at 4 shards across both runs"

step "threaded smoke: --engine-threads 4 results JSON vs serial, byte-for-byte"
THREAD_BASE=(run --servers 8 --gpus-per-server 4 --shards 4 --estimator oracle --margin 2 --seed 7 --json)
E="$("$BIN" "${THREAD_BASE[@]}")"
F="$("$BIN" "${THREAD_BASE[@]}" --engine-threads 4)"
if [ "$E" != "$F" ]; then
    echo "DETERMINISM FAILURE: --engine-threads 4 diverged from the serial engine" >&2
    diff <(printf '%s\n' "$E") <(printf '%s\n' "$F") >&2 || true
    exit 1
fi
printf '%s\n' "$F" | head -n 6
echo "threaded smoke OK: byte-identical results JSON at 1 and 4 engine threads"

step "gang smoke: distributed-job trace, threaded vs serial --json, byte-for-byte"
GANG_BASE=(run --servers 4 --gpus-per-server 4 --trace gang96 --shards 4 \
    --estimator oracle --margin 2 --seed 7 --json)
G="$("$BIN" "${GANG_BASE[@]}")"
H="$("$BIN" "${GANG_BASE[@]}" --engine-threads 4)"
if [ "$G" != "$H" ]; then
    echo "DETERMINISM FAILURE: gang trace diverged between serial and threaded engine" >&2
    diff <(printf '%s\n' "$G") <(printf '%s\n' "$H") >&2 || true
    exit 1
fi
if ! printf '%s\n' "$G" | grep -q '"partial_dispatches": 0'; then
    echo "GANG FAILURE: partial dispatch observed in results JSON (all-or-nothing violated)" >&2
    exit 1
fi
if printf '%s\n' "$G" | grep -q '"cross_server": 0,'; then
    echo "GANG FAILURE: no gang placed across servers" >&2
    exit 1
fi
echo "gang smoke OK: byte-identical JSON, cross-server gangs, zero partial dispatches"

step "placement smoke: fabric-aware singletons on/off --json at engine-threads {1,4}"
PLACE_BASE=(run --servers 2 --gpus-per-server 4 --fabric-profile dual-island \
    --estimator oracle --margin 2 --seed 7 --json)
for MODE in on off; do
    P1="$("$BIN" "${PLACE_BASE[@]}" --fabric-aware-singletons "$MODE")"
    P4="$("$BIN" "${PLACE_BASE[@]}" --fabric-aware-singletons "$MODE" --engine-threads 4)"
    if [ "$P1" != "$P4" ]; then
        echo "DETERMINISM FAILURE: fabric-aware-singletons=$MODE diverged across engine threads" >&2
        diff <(printf '%s\n' "$P1") <(printf '%s\n' "$P4") >&2 || true
        exit 1
    fi
    if ! printf '%s\n' "$P1" | grep -q '"placement"'; then
        echo "PLACEMENT FAILURE: results JSON lost the placement section (mode $MODE)" >&2
        exit 1
    fi
done
echo "placement smoke OK: byte-identical JSON across threads in both modes"

step "service smoke: open-loop --arrivals, shed accounting + thread determinism"
for KIND in poisson diurnal burst; do
    SVC_BASE=(run --servers 2 --gpus-per-server 4 --arrivals "$KIND" --rate 40 \
        --duration 420 --queue-cap 2 --shards 4 --estimator oracle --margin 2 \
        --seed 7 --json)
    S1="$("$BIN" "${SVC_BASE[@]}")"
    S4="$("$BIN" "${SVC_BASE[@]}" --engine-threads 4)"
    if [ "$S1" != "$S4" ]; then
        echo "DETERMINISM FAILURE: --arrivals $KIND diverged across engine threads" >&2
        diff <(printf '%s\n' "$S1") <(printf '%s\n' "$S4") >&2 || true
        exit 1
    fi
    if printf '%s\n' "$S1" | grep -q '"shed": 0,'; then
        echo "SERVICE FAILURE: saturating $KIND rate shed nothing" >&2
        exit 1
    fi
done
# low offered rate against a deep queue: the shedder must stay silent
LOW="$("$BIN" run --servers 2 --gpus-per-server 4 --arrivals poisson --rate 1 \
    --duration 420 --queue-cap 64 --estimator oracle --margin 2 --seed 7 --json)"
if ! printf '%s\n' "$LOW" | grep -q '"shed": 0,'; then
    echo "SERVICE FAILURE: low-rate run shed arrivals" >&2
    exit 1
fi
echo "service smoke OK: byte-identical JSON across threads, sheds only under saturation"

step "obs smoke: --trace-out byte-diff across engine threads, provenance + exposition"
TRACE_DIR="$(mktemp -d)"
OBS_BASE=(run --servers 2 --gpus-per-server 4 --shards 4 --estimator oracle --margin 2 \
    --seed 7 --json --explain-sample 16)
O1="$("$BIN" "${OBS_BASE[@]}" --trace-out "$TRACE_DIR/t1.jsonl")"
O4="$("$BIN" "${OBS_BASE[@]}" --trace-out "$TRACE_DIR/t4.jsonl" --engine-threads 4)"
if ! cmp -s "$TRACE_DIR/t1.jsonl" "$TRACE_DIR/t4.jsonl"; then
    echo "DETERMINISM FAILURE: event trace diverged across engine threads" >&2
    diff "$TRACE_DIR/t1.jsonl" "$TRACE_DIR/t4.jsonl" | head -n 20 >&2 || true
    exit 1
fi
if [ "$O1" != "$O4" ]; then
    echo "DETERMINISM FAILURE: traced runs' results JSON diverged across engine threads" >&2
    exit 1
fi
if ! grep -q '"ev":"decision"' "$TRACE_DIR/t1.jsonl"; then
    echo "OBS FAILURE: --explain-sample emitted no decision records" >&2
    exit 1
fi
if ! printf '%s\n' "$O1" | grep -q '"placement_decisions"'; then
    echo "OBS FAILURE: results JSON lost the placement_decisions section" >&2
    exit 1
fi
# --profile prints to stderr only: the compared stdout JSON must not move
P="$("$BIN" "${OBS_BASE[@]}" --profile 2>/dev/null)"
if [ "$O1" != "$P" ]; then
    echo "OBS FAILURE: --profile changed the results JSON" >&2
    exit 1
fi
"$BIN" run --servers 2 --gpus-per-server 4 --estimator oracle --margin 2 --seed 7 \
    --metrics-out "$TRACE_DIR/m.prom" >/dev/null
if ! grep -q '^carma_offered_total' "$TRACE_DIR/m.prom"; then
    echo "OBS FAILURE: metrics exposition lacks carma_offered_total" >&2
    exit 1
fi
rm -rf "$TRACE_DIR"
echo "obs smoke OK: byte-identical trace across threads, provenance + exposition present"

step "chaos smoke: --faults mixed fixed seed, byte-diff across engine threads"
CHAOS_DIR="$(mktemp -d)"
CHAOS_BASE=(run --servers 2 --gpus-per-server 4 --shards 4 --estimator oracle --margin 2 \
    --seed 7 --faults mixed --fault-rate 30 --fault-seed 7 --json)
X1="$("$BIN" "${CHAOS_BASE[@]}" --trace-out "$CHAOS_DIR/c1.jsonl")"
X4="$("$BIN" "${CHAOS_BASE[@]}" --trace-out "$CHAOS_DIR/c4.jsonl" --engine-threads 4)"
if [ "$X1" != "$X4" ]; then
    echo "DETERMINISM FAILURE: fault-injected results JSON diverged across engine threads" >&2
    diff <(printf '%s\n' "$X1") <(printf '%s\n' "$X4") >&2 || true
    exit 1
fi
if ! cmp -s "$CHAOS_DIR/c1.jsonl" "$CHAOS_DIR/c4.jsonl"; then
    echo "DETERMINISM FAILURE: fault-injected event trace diverged across engine threads" >&2
    diff "$CHAOS_DIR/c1.jsonl" "$CHAOS_DIR/c4.jsonl" | head -n 20 >&2 || true
    exit 1
fi
if ! printf '%s\n' "$X1" | grep -q '"resilience"'; then
    echo "CHAOS FAILURE: results JSON lost the resilience section" >&2
    exit 1
fi
if ! grep -q '"ev":"fault"' "$CHAOS_DIR/c1.jsonl"; then
    echo "CHAOS FAILURE: --faults mixed emitted no fault records" >&2
    exit 1
fi
# fault-free runs must still carry the (zeroed) resilience section
Z="$("$BIN" run --servers 2 --gpus-per-server 4 --estimator oracle --margin 2 --seed 7 --json)"
if ! printf '%s\n' "$Z" | grep -q '"resilience"'; then
    echo "CHAOS FAILURE: fault-free results JSON lost the resilience section" >&2
    exit 1
fi
echo "chaos smoke OK: byte-identical fault run across threads, resilience section always present"

step "trace-analyze smoke: replay the chaos traces, zero violations, byte-diff across threads"
# the analyzer exits non-zero on any invariant violation (DESIGN.md §16)
T1="$("$BIN" trace analyze "$CHAOS_DIR/c1.jsonl")"
T4="$("$BIN" trace analyze "$CHAOS_DIR/c4.jsonl")"
if [ "$T1" != "$T4" ]; then
    echo "DETERMINISM FAILURE: analyzer summary diverged across the thread-grid traces" >&2
    diff <(printf '%s\n' "$T1") <(printf '%s\n' "$T4") >&2 || true
    exit 1
fi
if ! printf '%s\n' "$T1" | grep -q '"violations": \[\]'; then
    echo "TRACE FAILURE: the engine's own chaos trace replayed with violations" >&2
    printf '%s\n' "$T1" >&2
    exit 1
fi
if ! printf '%s\n' "$T1" | grep -q '"time_accounting"'; then
    echo "TRACE FAILURE: analyzer summary lost the time_accounting section" >&2
    exit 1
fi
"$BIN" trace schema | grep -q '"gang_dispatch"' || {
    echo "TRACE FAILURE: trace schema lost the gang_dispatch record" >&2
    exit 1
}
rm -rf "$CHAOS_DIR"
echo "trace-analyze smoke OK: clean replay, byte-identical summary, schema published"

step "delta-views smoke: incremental snapshots vs full rebuild, byte-for-byte"
# DESIGN.md §17: per-server delta maintenance must be invisible in the
# results — identical JSON with delta views on/off, serial or threaded
DELTA_BASE=(run --servers 8 --gpus-per-server 4 --shards 4 --estimator oracle --margin 2 \
    --seed 7 --json)
V_ON1="$("$BIN" "${DELTA_BASE[@]}" --delta-views on)"
V_ON4="$("$BIN" "${DELTA_BASE[@]}" --delta-views on --engine-threads 4)"
V_OFF="$("$BIN" "${DELTA_BASE[@]}" --delta-views off)"
if [ "$V_ON1" != "$V_ON4" ]; then
    echo "DETERMINISM FAILURE: delta views diverged across engine threads" >&2
    diff <(printf '%s\n' "$V_ON1") <(printf '%s\n' "$V_ON4") >&2 || true
    exit 1
fi
if [ "$V_ON1" != "$V_OFF" ]; then
    echo "DETERMINISM FAILURE: delta-maintained views diverged from full rebuild" >&2
    diff <(printf '%s\n' "$V_ON1") <(printf '%s\n' "$V_OFF") >&2 || true
    exit 1
fi
# open-loop too: the arrival stream + shed path under delta maintenance
SVC_DELTA=(run --servers 2 --gpus-per-server 4 --arrivals poisson --rate 40 --duration 420 \
    --queue-cap 2 --shards 4 --estimator oracle --margin 2 --seed 7 --json)
W_ON4="$("$BIN" "${SVC_DELTA[@]}" --delta-views on --engine-threads 4)"
W_OFF="$("$BIN" "${SVC_DELTA[@]}" --delta-views off)"
if [ "$W_ON4" != "$W_OFF" ]; then
    echo "DETERMINISM FAILURE: open-loop delta views diverged from full rebuild" >&2
    diff <(printf '%s\n' "$W_ON4") <(printf '%s\n' "$W_OFF") >&2 || true
    exit 1
fi
echo "delta-views smoke OK: byte-identical results with incremental and full-rebuild snapshots"

step "perf ledger: bench smokes + scale repros write real BENCH_sim.json rows"
# 1-iteration smokes measure real (if noisy) rows; they land in the repo-root
# ledger so the perf trajectory stays populated every CI run
CARMA_BENCH_SMOKE=1 cargo bench --bench cluster_scale
CARMA_BENCH_SMOKE=1 cargo bench --bench shard_scale
CARMA_BENCH_SMOKE=1 cargo bench --bench gang_scale
# arena event core churn row (asserts 0 lane/arena reallocs internally)
CARMA_BENCH_SMOKE=1 cargo bench --bench sim_throughput
# the scale studies append their own comparison sections
"$BIN" repro placement_scale
"$BIN" repro service_scale
# observability tax: smoke mode keeps the run short and the gate wide — the
# dedicated 5% gate needs a quiet machine (`carma repro obs_overhead`)
CARMA_BENCH_SMOKE=1 "$BIN" repro obs_overhead
# chaos ledger: goodput degradation vs offered fault rate (smoke = 2 rates)
CARMA_BENCH_SMOKE=1 "$BIN" repro chaos_scale
# trace-analyze ledger: clean replay + sketch reproduction over shed/chaos traces
CARMA_BENCH_SMOKE=1 "$BIN" repro trace_analyze
# engine-scale ledger: delta views vs full rebuild + arena/lane no-realloc and
# recorder-memory assertions over the open-loop stream (gated ≥1.2x in smoke,
# ≥2x on a dedicated run)
CARMA_BENCH_SMOKE=1 "$BIN" repro engine_scale
for SECTION in shard_scale placement_scale service_scale obs_overhead chaos_scale trace_analyze engine_scale; do
    if ! grep -q "\"$SECTION\"" BENCH_sim.json; then
        echo "LEDGER FAILURE: BENCH_sim.json is missing the $SECTION section" >&2
        exit 1
    fi
done
echo "perf ledger OK: BENCH_sim.json carries shard_scale, placement_scale, service_scale, obs_overhead, chaos_scale, trace_analyze and engine_scale"

echo
echo "CI green."
