//! L3 hot-path micro-benchmarks: mapping decisions, queues, monitor,
//! allocator — the per-decision work CARMA does at each scheduling step.
//! Target: decision latency ≪ the 60 s monitoring window (DESIGN.md §Perf).

use carma::bench::{black_box, Bencher};
use carma::cluster::allocator::SegmentAllocator;
use carma::config::schema::PolicyKind;
use carma::coordinator::monitor::Monitor;
use carma::coordinator::policy::{self, GpuView, MappingRequest, Preconditions, ServerView};
use carma::coordinator::queue::TaskQueues;
use carma::util::rng::Rng;

fn views(n: usize) -> Vec<GpuView> {
    let mut rng = Rng::new(1);
    (0..n)
        .map(|id| GpuView {
            id,
            server: 0,
            free_gb: rng.range_f64(0.0, 40.0),
            smact_window: rng.f64(),
            n_tasks: rng.range_usize(0, 4),
            pinned: false,
            held: false,
            mig_free_instance: None,
            mig_instance_mem_gb: 0.0,
            mig_enabled: false,
        })
        .collect()
}

fn main() {
    let b = Bencher::default();
    println!("== policy selection (per mapping decision) ==");
    for policy in [
        PolicyKind::Exclusive,
        PolicyKind::RoundRobin,
        PolicyKind::Magm,
        PolicyKind::Lug,
    ] {
        let v = views(4);
        let mut rr = 0;
        let req = MappingRequest {
            n_gpus: 1,
            demand_gb: Some(8.0),
            exclusive: false,
        };
        let pre = Preconditions {
            smact_cap: Some(0.8),
            min_free_gb: Some(5.0),
        };
        b.bench(&format!("select_gpus/{}", policy.name()), || {
            black_box(policy::select_gpus(policy, &v, req, pre, &mut rr));
        })
        .report();
    }

    println!("\n== two-level cluster selection (8 servers × 4 GPUs) ==");
    let servers: Vec<ServerView> = (0..8)
        .map(|sid| ServerView {
            id: sid,
            power_w: 600.0,
            power_cap_w: Some(1400.0),
            gpus: views(4)
                .into_iter()
                .enumerate()
                .map(|(i, mut v)| {
                    v.id = sid * 4 + i;
                    v.server = sid;
                    v
                })
                .collect(),
        })
        .collect();
    for policy in [PolicyKind::Magm, PolicyKind::Lug, PolicyKind::RoundRobin] {
        let mut rr = 0;
        let req = MappingRequest {
            n_gpus: 1,
            demand_gb: Some(8.0),
            exclusive: false,
        };
        let pre = Preconditions {
            smact_cap: Some(0.8),
            min_free_gb: Some(5.0),
        };
        b.bench(&format!("select_two_level/{}", policy.name()), || {
            black_box(policy::select_two_level(policy, &servers, req, pre, &mut rr));
        })
        .report();
    }

    println!("\n== queues ==");
    b.bench("queue/submit+pop x64", || {
        let mut q = TaskQueues::new();
        for i in 0..64 {
            q.submit(i);
        }
        q.submit_recovery(99);
        while black_box(q.pop_next()).is_some() {}
    })
    .report();

    println!("\n== monitor (60s window @ 1Hz, 4 GPUs) ==");
    let mut m = Monitor::new(4, 60.0);
    let mut t = 0.0;
    b.bench("monitor/push+windowed_smact", || {
        t += 1.0;
        for g in 0..4 {
            m.push(g, t, 0.5);
        }
        black_box(m.windowed_smact(0));
    })
    .report();

    println!("\n== segment allocator (task lifecycle: 3 slabs, scatter) ==");
    let mut alloc = SegmentAllocator::new(40 * 1024);
    let mut live: Vec<Vec<u64>> = Vec::new();
    let mut rng = Rng::new(2);
    b.bench("allocator/task_alloc_free_cycle", || {
        if live.len() < 8 {
            let mut segs = Vec::new();
            for len in [665, rng.range_u64(256, 4096), rng.range_u64(256, 4096)] {
                if let Some(s) = alloc.alloc_scatter(len, 4) {
                    segs.extend(s);
                }
            }
            live.push(segs);
        } else {
            let segs = live.remove(rng.range_usize(0, live.len()));
            for s in segs {
                alloc.free(s);
            }
        }
        black_box(alloc.free_total());
    })
    .report();
}
