//! Gang-lane throughput: events/sec and wall cost of the fabric-aware
//! gang-scheduling run vs the server-local baseline on the 4×4-server,
//! 96-task mixed trace (DESIGN.md §11), plus the thread sweep on the gang
//! path (threads never change results — only wall time — asserted on the
//! full results JSON).
//!
//! Rows land in `BENCH_sim.json` (perf trajectory across PRs);
//! `CARMA_BENCH_SMOKE=1` runs a 1-iteration subset for CI.

use std::time::Instant;

use carma::bench::{black_box, save_bench_section, smoke_mode};
use carma::config::schema::{CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind};
use carma::coordinator::carma::run_trace;
use carma::estimators;
use carma::util::json::{self, Json};
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::{server_localize, trace_gang, TraceSpec};

const SERVERS: usize = 4;
const GPUS_PER_SERVER: usize = 4;
const TASKS: usize = 96;
const GANG_GPUS: usize = 2 * GPUS_PER_SERVER;

fn cfg(threads: usize) -> CarmaConfig {
    let mut cfg = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    cfg.cluster = ClusterConfig::homogeneous(SERVERS, GPUS_PER_SERVER, 40.0);
    cfg.coordinator.shards = 4;
    cfg.engine.threads = threads;
    cfg
}

/// Run one configuration `runs` times; returns (bench row, results JSON).
fn one(system: &str, trace: &TraceSpec, threads: usize, runs: u32) -> (Json, String) {
    let mut best_wall = f64::INFINITY;
    let mut events = 0u64;
    let mut makespan = 0.0f64;
    let mut json_text = String::new();
    for _ in 0..runs {
        let c = cfg(threads);
        let est = estimators::build(c.estimator, "artifacts").expect("estimator");
        let t0 = Instant::now();
        let out = run_trace(c, est, trace, system);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(out.report.completed, TASKS, "{system}: trace must complete");
        assert_eq!(
            out.report.gang.partial_dispatches, 0,
            "{system}: all-or-nothing violated"
        );
        best_wall = best_wall.min(wall);
        events = out.events;
        makespan = out.report.trace_total_min;
        json_text = out.report.to_json().to_string_pretty();
        black_box(&json_text);
    }
    println!(
        "{system:<22} threads {threads}: {makespan:>8.1} m makespan, {events:>8} events, \
         {:>8.0} ev/s wall {best_wall:.2}s",
        events as f64 / best_wall.max(1e-9)
    );
    let row = json::obj(vec![
        ("system", json::s(system)),
        ("threads", json::num(threads as f64)),
        ("makespan_min", json::num(makespan)),
        ("events", json::num(events as f64)),
        ("events_per_sec", json::num(events as f64 / best_wall.max(1e-9))),
        ("wall_s", json::num(best_wall)),
    ]);
    (row, json_text)
}

fn main() {
    let smoke = smoke_mode();
    let runs: u32 = if smoke { 1 } else { 3 };
    let zoo = ModelZoo::load();
    let total_gpus = SERVERS * GPUS_PER_SERVER;
    let gang_trace = trace_gang(&zoo, TASKS, total_gpus, GANG_GPUS, 42);
    let local_trace = server_localize(&gang_trace, GPUS_PER_SERVER);

    let mut rows: Vec<Json> = Vec::new();
    let thread_sweep: &[usize] = if smoke { &[1] } else { &[1, 4] };
    let mut gang_json: Option<String> = None;
    for &threads in thread_sweep {
        let (row, j) = one("gang", &gang_trace, threads, runs);
        // §10 on the gang path: threads change wall-clock only
        match &gang_json {
            None => gang_json = Some(j),
            Some(prev) => assert_eq!(*prev, j, "threads changed the gang results"),
        }
        rows.push(row);
    }
    let (row, _) = one("server-local", &local_trace, 1, runs);
    rows.push(row);
    save_bench_section("gang_scale", rows);
}
