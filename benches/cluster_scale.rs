//! Engine throughput as the cluster grows: simulated events per wall-clock
//! second for 1→8 servers under the default MAGM+MPS setup (DESIGN.md §Perf:
//! the coordinator must never be the bottleneck; this is the baseline the
//! ROADMAP's sharded-engine work has to beat).

use std::time::Instant;

use carma::bench::black_box;
use carma::config::schema::{CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind};
use carma::coordinator::carma::run_trace;
use carma::estimators;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::trace_cluster;

fn main() {
    let zoo = ModelZoo::load();
    println!(
        "{:<18} {:>6} {:>7} {:>12} {:>10} {:>12} {:>12}",
        "cluster", "gpus", "tasks", "sim-events", "wall(s)", "events/s", "tasks/s"
    );
    for servers in [1usize, 2, 4, 8] {
        let mut cfg = CarmaConfig {
            policy: PolicyKind::Magm,
            estimator: EstimatorKind::Oracle,
            safety_margin_gb: 2.0,
            ..Default::default()
        };
        cfg.cluster = ClusterConfig::homogeneous(servers, 4, 40.0);
        let gpus = cfg.cluster.total_gpus();
        let n_tasks = 8 * gpus;
        let trace = trace_cluster(&zoo, n_tasks, gpus, 42);

        // one warm-up + three measured runs (whole-trace granularity: a run
        // is seconds, not microseconds — the Bencher's calibration loop
        // would only add noise here)
        let est = estimators::build(cfg.estimator, "artifacts").unwrap();
        black_box(run_trace(cfg.clone(), est, &trace, "warmup").report.completed);
        let mut events = 0u64;
        let t0 = Instant::now();
        const RUNS: u32 = 3;
        for _ in 0..RUNS {
            let est = estimators::build(cfg.estimator, "artifacts").unwrap();
            let out = run_trace(cfg.clone(), est, &trace, "bench");
            assert_eq!(out.report.completed, n_tasks);
            events += out.events;
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<18} {:>6} {:>7} {:>12} {:>10.2} {:>12.0} {:>12.1}",
            format!("{servers}x4-server"),
            gpus,
            n_tasks,
            events / RUNS as u64,
            wall / RUNS as f64,
            events as f64 / wall,
            (RUNS as usize * n_tasks) as f64 / wall,
        );
    }
}
