//! Engine throughput as the cluster grows: simulated events per wall-clock
//! second for 1→8 servers under the default MAGM+MPS setup (DESIGN.md §Perf:
//! the coordinator must never be the bottleneck).
//!
//! Two sweeps:
//!  * the serial baseline (shards = 1, threads = 1) the PR-2 bench tracked;
//!  * the parallel engine at shards = 4 with threads ∈ {1, 4} — the PR-3
//!    acceptance row: at 8 servers, `--engine-threads 4` must deliver ≥ 2×
//!    events/sec over the threaded-off run on a ≥ 4-core machine, with
//!    byte-identical results (asserted here on the makespan bits).
//!
//! Every row is appended to the machine-readable `BENCH_sim.json` ledger so
//! the perf trajectory is tracked across PRs. `CARMA_BENCH_SMOKE=1` runs a
//! 1-iteration subset (ci.sh's bit-rot guard).

use std::time::Instant;

use carma::bench::{black_box, save_bench_section, smoke_mode};
use carma::config::schema::{CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind};
use carma::coordinator::carma::run_trace;
use carma::estimators;
use carma::util::json::{self, Json};
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::trace_cluster;

struct Row {
    servers: usize,
    gpus: usize,
    tasks: usize,
    shards: usize,
    threads: usize,
    events: u64,
    wall_s: f64,
    events_per_s: f64,
    makespan_min: f64,
    makespan_bits: u64,
}

fn measure(servers: usize, shards: usize, threads: usize, runs: u32, warmup: bool) -> Row {
    let zoo = ModelZoo::load();
    let mut cfg = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    cfg.cluster = ClusterConfig::homogeneous(servers, 4, 40.0);
    cfg.coordinator.shards = shards;
    cfg.engine.threads = threads;
    let gpus = cfg.cluster.total_gpus();
    let n_tasks = 8 * gpus;
    let trace = trace_cluster(&zoo, n_tasks, gpus, 42);

    // whole-trace granularity: a run is seconds, not microseconds — the
    // Bencher's calibration loop would only add noise here
    if warmup {
        let est = estimators::build(cfg.estimator, "artifacts").unwrap();
        black_box(run_trace(cfg.clone(), est, &trace, "warmup").report.completed);
    }
    let mut events = 0u64;
    let mut makespan_min = 0.0f64;
    let t0 = Instant::now();
    for _ in 0..runs {
        let est = estimators::build(cfg.estimator, "artifacts").unwrap();
        let out = run_trace(cfg.clone(), est, &trace, "bench");
        assert_eq!(out.report.completed, n_tasks);
        events += out.events;
        makespan_min = out.report.trace_total_min;
    }
    let wall = t0.elapsed().as_secs_f64();
    Row {
        servers,
        gpus,
        tasks: n_tasks,
        shards,
        threads,
        events: events / runs as u64,
        wall_s: wall / runs as f64,
        events_per_s: events as f64 / wall,
        makespan_min,
        makespan_bits: makespan_min.to_bits(),
    }
}

fn print_row(r: &Row) {
    println!(
        "{:<18} {:>6} {:>7} {:>7} {:>8} {:>12} {:>10.2} {:>12.0} {:>11.1}",
        format!("{}x4-server", r.servers),
        r.gpus,
        r.tasks,
        r.shards,
        r.threads,
        r.events,
        r.wall_s,
        r.events_per_s,
        r.makespan_min,
    );
}

fn to_json(r: &Row) -> Json {
    json::obj(vec![
        ("servers", json::num(r.servers as f64)),
        ("gpus", json::num(r.gpus as f64)),
        ("tasks", json::num(r.tasks as f64)),
        ("shards", json::num(r.shards as f64)),
        ("threads", json::num(r.threads as f64)),
        ("events", json::num(r.events as f64)),
        ("wall_s", json::num(r.wall_s)),
        ("events_per_s", json::num(r.events_per_s)),
        ("makespan_min", json::num(r.makespan_min)),
    ])
}

fn main() {
    let smoke = smoke_mode();
    let runs: u32 = if smoke { 1 } else { 3 };
    println!(
        "{:<18} {:>6} {:>7} {:>7} {:>8} {:>12} {:>10} {:>12} {:>11}",
        "cluster", "gpus", "tasks", "shards", "threads", "sim-events", "wall(s)", "events/s", "total(m)"
    );

    let mut rows: Vec<Row> = Vec::new();

    // serial baseline sweep (the PR-2 rows)
    let server_sweep: &[usize] = if smoke { &[2] } else { &[1, 2, 4, 8] };
    for &servers in server_sweep {
        let r = measure(servers, 1, 1, runs, !smoke);
        print_row(&r);
        rows.push(r);
    }

    // parallel engine at the acceptance point: 8 servers, shards = 4
    let par_servers = if smoke { 2 } else { 8 };
    let serial4 = measure(par_servers, 4, 1, runs, !smoke);
    print_row(&serial4);
    let threaded4 = measure(par_servers, 4, 4, runs, !smoke);
    print_row(&threaded4);
    assert_eq!(
        serial4.makespan_bits, threaded4.makespan_bits,
        "threaded results must be byte-identical to serial"
    );
    println!(
        "\n{}x4-server, 4 shards: threads 1→4 events/sec x{:.2} \
         (>= 2.0 expected on a >= 4-core runner)",
        par_servers,
        threaded4.events_per_s / serial4.events_per_s.max(1e-9),
    );

    rows.push(serial4);
    rows.push(threaded4);
    save_bench_section("cluster_scale", rows.iter().map(to_json).collect());
}
