//! Mapping throughput as the coordinator shards and the engine threads:
//! decisions per simulated minute (the metric the serial pipeline caps at
//! 1/min — paper §4.1) and wall-clock cost per run for shards ∈ {1, 2, 4, 8}
//! × engine threads ∈ {1, 4} on the 8×4-server, 256-task cluster trace
//! (DESIGN.md §9/§10). Threads never change results — only wall time — and
//! this bench asserts that on the makespan bits.
//!
//! Rows land in `BENCH_sim.json` (perf trajectory across PRs);
//! `CARMA_BENCH_SMOKE=1` runs a 1-iteration subset for CI.

use std::time::Instant;

use carma::bench::{black_box, save_bench_section, smoke_mode};
use carma::config::schema::{CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind};
use carma::coordinator::carma::run_trace;
use carma::estimators;
use carma::util::json::{self, Json};
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::trace_cluster;

const SERVERS: usize = 8;
const GPUS_PER_SERVER: usize = 4;
const TASKS: usize = 256;

fn main() {
    let smoke = smoke_mode();
    let runs: u32 = if smoke { 1 } else { 3 };
    let zoo = ModelZoo::load();
    let total_gpus = SERVERS * GPUS_PER_SERVER;
    let trace = trace_cluster(&zoo, TASKS, total_gpus, 42);

    println!(
        "{:<8} {:>8} {:>9} {:>9} {:>10} {:>13} {:>12} {:>10}",
        "shards", "threads", "total(m)", "wait(m)", "decisions", "dec/sim-min", "dec/wall-s", "wall(s)"
    );

    let shard_sweep: &[usize] = if smoke { &[4] } else { &[1, 2, 4, 8] };
    let thread_sweep: &[usize] = &[1, 4];
    let mut rows: Vec<Json> = Vec::new();
    for &shards in shard_sweep {
        let mut makespan_bits: Option<u64> = None;
        for &threads in thread_sweep {
            let mk_cfg = || {
                let mut cfg = CarmaConfig {
                    policy: PolicyKind::Magm,
                    estimator: EstimatorKind::Oracle,
                    safety_margin_gb: 2.0,
                    ..Default::default()
                };
                cfg.cluster = ClusterConfig::homogeneous(SERVERS, GPUS_PER_SERVER, 40.0);
                cfg.coordinator.shards = shards;
                cfg.engine.threads = threads;
                cfg
            };

            // one warm-up + `runs` measured whole-trace runs (same
            // granularity rationale as benches/cluster_scale.rs)
            if !smoke {
                let est = estimators::build(EstimatorKind::Oracle, "artifacts").unwrap();
                black_box(run_trace(mk_cfg(), est, &trace, "warmup").report.completed);
            }
            let mut decisions = 0u64;
            let mut events = 0u64;
            let mut last_total_min = 0.0;
            let mut last_wait_min = 0.0;
            let t0 = Instant::now();
            for _ in 0..runs {
                let est = estimators::build(EstimatorKind::Oracle, "artifacts").unwrap();
                let out = run_trace(mk_cfg(), est, &trace, "bench");
                assert_eq!(out.report.completed, TASKS);
                decisions += out.report.total_decisions();
                events += out.events;
                last_total_min = out.report.trace_total_min;
                last_wait_min = out.report.avg_waiting_min;
            }
            let wall = t0.elapsed().as_secs_f64();
            // bit-determinism across thread counts, per shard level
            match makespan_bits {
                None => makespan_bits = Some(last_total_min.to_bits()),
                Some(bits) => assert_eq!(
                    bits,
                    last_total_min.to_bits(),
                    "{shards} shards: threads changed the results"
                ),
            }
            let per_run_decisions = decisions / runs as u64;
            println!(
                "{:<8} {:>8} {:>9.1} {:>9.1} {:>10} {:>13.2} {:>12.0} {:>10.2}",
                shards,
                threads,
                last_total_min,
                last_wait_min,
                per_run_decisions,
                per_run_decisions as f64 / last_total_min.max(1e-9),
                decisions as f64 / wall,
                wall / runs as f64,
            );
            rows.push(json::obj(vec![
                ("servers", json::num(SERVERS as f64)),
                ("gpus", json::num(total_gpus as f64)),
                ("tasks", json::num(TASKS as f64)),
                ("shards", json::num(shards as f64)),
                ("threads", json::num(threads as f64)),
                ("decisions", json::num(per_run_decisions as f64)),
                ("events", json::num((events / runs as u64) as f64)),
                ("events_per_s", json::num(events as f64 / wall)),
                ("wall_s", json::num(wall / runs as f64)),
                ("makespan_min", json::num(last_total_min)),
                ("wait_min", json::num(last_wait_min)),
            ]));
        }
    }
    save_bench_section("shard_scale", rows);
}
