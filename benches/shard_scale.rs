//! Mapping throughput as the coordinator shards: decisions per simulated
//! minute (the metric the serial pipeline caps at 1/min — paper §4.1) and
//! wall-clock cost per run for shards ∈ {1, 2, 4, 8} on the 8×4-server,
//! 256-task cluster trace (DESIGN.md §9 / §Perf).

use std::time::Instant;

use carma::bench::black_box;
use carma::config::schema::{CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind};
use carma::coordinator::carma::run_trace;
use carma::estimators;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::trace_cluster;

fn main() {
    let zoo = ModelZoo::load();
    const SERVERS: usize = 8;
    const GPUS_PER_SERVER: usize = 4;
    const TASKS: usize = 256;
    let total_gpus = SERVERS * GPUS_PER_SERVER;
    let trace = trace_cluster(&zoo, TASKS, total_gpus, 42);

    println!(
        "{:<8} {:>9} {:>9} {:>10} {:>13} {:>12} {:>10}",
        "shards", "total(m)", "wait(m)", "decisions", "dec/sim-min", "dec/wall-s", "wall(s)"
    );
    for shards in [1usize, 2, 4, 8] {
        let mk_cfg = || {
            let mut cfg = CarmaConfig {
                policy: PolicyKind::Magm,
                estimator: EstimatorKind::Oracle,
                safety_margin_gb: 2.0,
                ..Default::default()
            };
            cfg.cluster = ClusterConfig::homogeneous(SERVERS, GPUS_PER_SERVER, 40.0);
            cfg.coordinator.shards = shards;
            cfg
        };

        // one warm-up + three measured whole-trace runs (same granularity
        // rationale as benches/cluster_scale.rs)
        let est = estimators::build(EstimatorKind::Oracle, "artifacts").unwrap();
        black_box(run_trace(mk_cfg(), est, &trace, "warmup").report.completed);
        const RUNS: u32 = 3;
        let mut decisions = 0u64;
        let mut last_total_min = 0.0;
        let mut last_wait_min = 0.0;
        let t0 = Instant::now();
        for _ in 0..RUNS {
            let est = estimators::build(EstimatorKind::Oracle, "artifacts").unwrap();
            let out = run_trace(mk_cfg(), est, &trace, "bench");
            assert_eq!(out.report.completed, TASKS);
            decisions += out.report.total_decisions();
            last_total_min = out.report.trace_total_min;
            last_wait_min = out.report.avg_waiting_min;
        }
        let wall = t0.elapsed().as_secs_f64();
        let per_run_decisions = decisions / RUNS as u64;
        println!(
            "{:<8} {:>9.1} {:>9.1} {:>10} {:>13.2} {:>12.0} {:>10.2}",
            shards,
            last_total_min,
            last_wait_min,
            per_run_decisions,
            per_run_decisions as f64 / last_total_min.max(1e-9),
            decisions as f64 / wall,
            wall / RUNS as f64,
        );
    }
}
