//! Estimator + parser latency benches against the paper's own budgets:
//! GPUMemNet ≤ 16 ms (A100) / 32 ms (EPYC CPU); submission parsing ≤ 2.6 ms
//! (paper §3.3 / §4.1).

use carma::bench::{black_box, Bencher};
use carma::estimators::gpumemnet::GpuMemNetEstimator;
use carma::estimators::{FakeTensorEstimator, HorusEstimator, MemoryEstimator};
use carma::workload::features::Arch;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::submission;
use carma::workload::task::TaskSpec;

fn main() {
    let b = Bencher::default();
    let zoo = ModelZoo::load();
    let task = TaskSpec::from_zoo(0, zoo.find("resnet50", "imagenet", 64).unwrap(), 1, 0.0);

    println!("== analytical estimators ==");
    b.bench("estimate/horus", || {
        black_box(HorusEstimator.estimate_gb(&task));
    })
    .report();
    b.bench("estimate/faketensor", || {
        black_box(FakeTensorEstimator.estimate_gb(&task));
    })
    .report();

    println!("\n== submission parser (paper budget: 2.6 ms) ==");
    let script = "#!/bin/bash\n#CARMA --model resnet50 --dataset imagenet --batch-size 64\n#CARMA --gpus 1 --epochs 1\npython train.py\n";
    b.bench("parse_script+resolve", || {
        let sub = submission::parse_script(script).unwrap();
        black_box(submission::resolve(&zoo, &sub, 0, 0.0).unwrap());
    })
    .report();

    println!("\n== GPUMemNet via PJRT (paper budget: 16 ms A100 / 32 ms CPU) ==");
    match GpuMemNetEstimator::load("artifacts") {
        Err(e) => println!("skipped (run `make artifacts`): {e}"),
        Ok(est) => {
            // uncached: defeat the feature cache by varying batch size
            let mut f = task.features;
            let mut bs = 0.0f32;
            b.bench("gpumemnet/uncached_inference", || {
                bs += 1.0;
                f.batch_size = bs as f64;
                let v = f.to_vec();
                black_box(est.estimate_features(Arch::Cnn, &v).unwrap());
            })
            .report();
            // cached (repeat models in a trace)
            let v = task.features.to_vec();
            b.bench("gpumemnet/cached_lookup", || {
                black_box(est.estimate_features(Arch::Cnn, &v).unwrap());
            })
            .report();

            // end-to-end budget check
            let r = b.bench("gpumemnet/fresh_feature_vector", {
                let mut i = 0.0f32;
                move || {
                    i += 1.0;
                    let mut f2 = task.features;
                    f2.acts_m += i as f64 * 1e-3;
                    black_box(est.estimate_features(Arch::Cnn, &f2.to_vec()).unwrap());
                }
            });
            r.report();
            let ms = r.mean_ns() / 1e6;
            println!(
                "  -> {:.3} ms/inference vs paper budget 16 ms (A100) / 32 ms (CPU): {}",
                ms,
                if ms < 16.0 { "WITHIN A100 budget" } else if ms < 32.0 { "within CPU budget" } else { "OVER budget" }
            );
        }
    }
}
