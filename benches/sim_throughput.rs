//! End-to-end simulation throughput: one bench per paper experiment class.
//! The whole 90-task trace must simulate in well under a second so the full
//! `repro all` grid (~40 runs) stays interactive (DESIGN.md §Perf: the
//! coordinator must never be the bottleneck).

use carma::bench::{black_box, smoke_mode, Bencher};
use carma::config::schema::{CarmaConfig, CollocationMode, EstimatorKind, PolicyKind};
use carma::coordinator::carma::run_trace;
use carma::estimators;
use carma::sim::{Engine, Event};
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::{trace_60, trace_90};

/// Arena event core under steady-state churn (DESIGN.md §17): a pre-sized
/// engine cycling schedule/pop through the slot free list must stay
/// allocation-free — the per-event cost here is the floor under every
/// simulation bench below.
fn bench_arena_event_core(b: &Bencher) {
    println!("\n== arena event core (schedule/pop churn, pre-sized lanes) ==");
    let depth = if smoke_mode() { 1_000 } else { 100_000 };
    let mut e = Engine::with_lane_capacities(5, depth + 16, depth / 4 + 16);
    // hold `depth` events pending so every cycle works a deep tournament
    for i in 0..depth {
        e.schedule_in_on(i % 5, 1.0 + i as f64, Event::TaskArrival(i));
    }
    let mut i = depth;
    let r = b.bench(&format!("schedule_pop_churn_{depth}_pending"), || {
        let (_, ev) = e.pop().expect("engine holds `depth` pending events");
        black_box(&ev);
        i += 1;
        e.schedule_in_on(i % 5, 1.0 + (i % 97) as f64, Event::TaskArrival(i));
    });
    r.report();
    r.report_throughput(1.0, "events");
    let s = e.stats();
    assert_eq!(s.lane_reallocs, 0, "churn bench must never grow a lane");
    assert_eq!(s.arena_reallocs, 0, "churn bench must never grow the arena");
    println!(
        "  arena high water {} of {} slots, 0 reallocs",
        s.arena_high_water, s.arena_capacity
    );
}

fn main() {
    let b = Bencher::default();
    let zoo = ModelZoo::load();
    let t90 = trace_90(&zoo, 42);
    let t60 = trace_60(&zoo, 42);

    println!("== full-trace simulation (fig8/fig9/fig11 building block) ==");
    for (name, policy, est) in [
        ("exclusive_90task", PolicyKind::Exclusive, EstimatorKind::None),
        ("magm_oracle_90task", PolicyKind::Magm, EstimatorKind::Oracle),
        ("rr_blind_90task (OOM+recovery)", PolicyKind::RoundRobin, EstimatorKind::None),
    ] {
        let r = b.bench(name, || {
            let mut cfg = CarmaConfig {
                policy,
                estimator: est,
                colloc: CollocationMode::Mps,
                ..Default::default()
            };
            if est == EstimatorKind::None {
                cfg.smact_cap = None;
            } else {
                cfg.safety_margin_gb = 2.0;
            }
            let e = estimators::build(est, "artifacts").unwrap();
            black_box(run_trace(cfg, e, &t90, "bench").report.completed);
        });
        r.report();
        r.report_throughput(90.0, "tasks");
    }

    println!("\n== 60-task stress trace ==");
    let r = b.bench("magm_horus_60task", || {
        let cfg = CarmaConfig {
            policy: PolicyKind::Magm,
            estimator: EstimatorKind::Horus,
            ..Default::default()
        };
        let e = estimators::build(EstimatorKind::Horus, "artifacts").unwrap();
        black_box(run_trace(cfg, e, &t60, "bench").report.completed);
    });
    r.report();

    bench_arena_event_core(&b);

    println!("\n== trace generation ==");
    b.bench("trace_90_generation", || {
        black_box(trace_90(&zoo, 7).tasks.len());
    })
    .report();

    println!("\n== zoo loading (embedded JSON parse) ==");
    b.bench("model_zoo_load", || {
        black_box(ModelZoo::load().entries.len());
    })
    .report();
}
