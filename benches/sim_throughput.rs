//! End-to-end simulation throughput: one bench per paper experiment class.
//! The whole 90-task trace must simulate in well under a second so the full
//! `repro all` grid (~40 runs) stays interactive (DESIGN.md §Perf: the
//! coordinator must never be the bottleneck).

use carma::bench::{black_box, Bencher};
use carma::config::schema::{CarmaConfig, CollocationMode, EstimatorKind, PolicyKind};
use carma::coordinator::carma::run_trace;
use carma::estimators;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::{trace_60, trace_90};

fn main() {
    let b = Bencher::default();
    let zoo = ModelZoo::load();
    let t90 = trace_90(&zoo, 42);
    let t60 = trace_60(&zoo, 42);

    println!("== full-trace simulation (fig8/fig9/fig11 building block) ==");
    for (name, policy, est) in [
        ("exclusive_90task", PolicyKind::Exclusive, EstimatorKind::None),
        ("magm_oracle_90task", PolicyKind::Magm, EstimatorKind::Oracle),
        ("rr_blind_90task (OOM+recovery)", PolicyKind::RoundRobin, EstimatorKind::None),
    ] {
        let r = b.bench(name, || {
            let mut cfg = CarmaConfig {
                policy,
                estimator: est,
                colloc: CollocationMode::Mps,
                ..Default::default()
            };
            if est == EstimatorKind::None {
                cfg.smact_cap = None;
            } else {
                cfg.safety_margin_gb = 2.0;
            }
            let e = estimators::build(est, "artifacts").unwrap();
            black_box(run_trace(cfg, e, &t90, "bench").report.completed);
        });
        r.report();
        r.report_throughput(90.0, "tasks");
    }

    println!("\n== 60-task stress trace ==");
    let r = b.bench("magm_horus_60task", || {
        let cfg = CarmaConfig {
            policy: PolicyKind::Magm,
            estimator: EstimatorKind::Horus,
            ..Default::default()
        };
        let e = estimators::build(EstimatorKind::Horus, "artifacts").unwrap();
        black_box(run_trace(cfg, e, &t60, "bench").report.completed);
    });
    r.report();

    println!("\n== trace generation ==");
    b.bench("trace_90_generation", || {
        black_box(trace_90(&zoo, 7).tasks.len());
    })
    .report();

    println!("\n== zoo loading (embedded JSON parse) ==");
    b.bench("model_zoo_load", || {
        black_box(ModelZoo::load().entries.len());
    })
    .report();
}
