# Data + artifact regeneration. The checked-in data/ files are enough for
# the default (surrogate) build; `artifacts` needs JAX and enables the
# PJRT-served estimator path (DESIGN.md §1–§2).

.PHONY: all data zoo golden artifacts ci

all: data

data: zoo golden

zoo:
	cd python && python3 -m compile.zoo

golden:
	cd python && python3 -c "import os; from compile import analysis; \
	os.makedirs(analysis.data_dir(), exist_ok=True); \
	analysis.memsim_golden(os.path.join(analysis.data_dir(), 'memsim_golden.json')); \
	print('data/memsim_golden.json refreshed')"

artifacts:
	cd python && python3 -m compile.aot

ci:
	./ci.sh
