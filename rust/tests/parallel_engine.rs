//! Parallel-engine determinism invariants (DESIGN.md §10): a fixed-seed
//! threaded run must be *byte-identical* to the serial run — same results
//! JSON, same event count, same energy bits — including on the hardest
//! ordering cases: OOM-heavy recovery traces (RecoveryDetect + Ramp
//! interleavings under adaptive backoff and pinned demotion) and
//! equal-timestamp arrival bursts (FIFO ties across the merge barrier).

use carma::config::schema::{
    ArrivalKind, CarmaConfig, ClusterConfig, EstimatorKind, FaultProfile, PolicyKind,
};
use carma::coordinator::carma::{run_service, run_trace, RunOutcome};
use carma::estimators;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::{trace_cluster, trace_gang, TraceSpec};

fn run_with(
    threads: usize,
    shards: usize,
    policy: PolicyKind,
    est: EstimatorKind,
    smact_cap: Option<f64>,
    margin: f64,
    trace: &TraceSpec,
) -> RunOutcome {
    let mut c = CarmaConfig {
        policy,
        estimator: est,
        smact_cap,
        safety_margin_gb: margin,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
    c.coordinator.shards = shards;
    c.engine.threads = threads;
    let e = estimators::build(est, "artifacts").unwrap();
    run_trace(c, e, trace, "parallel-test")
}

/// Full byte-level comparison of two runs: the results JSON (the artifact
/// ci.sh diffs), the handled-event count, and the energy/makespan bits.
fn assert_byte_identical(a: &RunOutcome, b: &RunOutcome, what: &str) {
    assert_eq!(a.events, b.events, "{what}: event streams diverged");
    assert_eq!(
        a.report.trace_total_min.to_bits(),
        b.report.trace_total_min.to_bits(),
        "{what}: makespan bits diverged"
    );
    assert_eq!(
        a.report.energy_mj.to_bits(),
        b.report.energy_mj.to_bits(),
        "{what}: energy bits diverged"
    );
    assert_eq!(
        a.report.avg_waiting_min.to_bits(),
        b.report.avg_waiting_min.to_bits(),
        "{what}: queueing-delay bits diverged"
    );
    assert_eq!(a.report.oom_crashes, b.report.oom_crashes, "{what}: OOM counts diverged");
    assert_eq!(
        a.report.to_json().to_string_pretty(),
        b.report.to_json().to_string_pretty(),
        "{what}: results JSON is not byte-identical"
    );
    // per-task timings, to the bit: dispatches, waits, completions
    assert_eq!(a.recorder.tasks.len(), b.recorder.tasks.len());
    for (i, (ta, tb)) in a.recorder.tasks.iter().zip(&b.recorder.tasks).enumerate() {
        assert_eq!(ta.assigned_shard, tb.assigned_shard, "{what}: task {i} shard");
        assert_eq!(ta.dispatches, tb.dispatches, "{what}: task {i} dispatches");
        assert_eq!(
            ta.dispatched_s.map(f64::to_bits),
            tb.dispatched_s.map(f64::to_bits),
            "{what}: task {i} dispatch time"
        );
        assert_eq!(ta.oom_crashes, tb.oom_crashes, "{what}: task {i} crashes");
    }
}

#[test]
fn threaded_matches_serial_on_oom_heavy_recovery_trace() {
    // blind Round-Robin with no preconditions on an overloaded 8-GPU pool:
    // the OOM storm exercises RecoveryDetect backoff, Ramp interleavings,
    // retry-budget demotion to pinned slots — the hardest ordering case the
    // commit protocol has to reproduce exactly
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 96, 8, 1);
    let serial = run_with(1, 4, PolicyKind::RoundRobin, EstimatorKind::None, None, 0.0, &trace);
    assert_eq!(serial.report.completed + serial.recorder.failed_total as usize, 96);
    assert!(
        serial.report.oom_crashes > 0,
        "trace must actually stress recovery (got no OOMs)"
    );
    let threaded = run_with(4, 4, PolicyKind::RoundRobin, EstimatorKind::None, None, 0.0, &trace);
    assert_byte_identical(&serial, &threaded, "oom-heavy threads=4");
    // and at an odd thread count that cannot divide the work evenly
    let threaded3 = run_with(3, 4, PolicyKind::RoundRobin, EstimatorKind::None, None, 0.0, &trace);
    assert_byte_identical(&serial, &threaded3, "oom-heavy threads=3");
}

#[test]
fn threaded_matches_serial_on_clean_oracle_trace() {
    // the no-OOM path: oracle + margin, default preconditions
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 96, 8, 7);
    let serial = run_with(1, 4, PolicyKind::Magm, EstimatorKind::Oracle, Some(0.8), 2.0, &trace);
    assert_eq!(serial.report.completed, 96);
    assert_eq!(serial.report.oom_crashes, 0);
    let threaded = run_with(4, 4, PolicyKind::Magm, EstimatorKind::Oracle, Some(0.8), 2.0, &trace);
    assert_byte_identical(&serial, &threaded, "oracle threads=4");
}

#[test]
fn threads_never_reorder_equal_timestamp_fifo_ties() {
    // stress: every task arrives at the same instant, so the whole trace is
    // one giant equal-timestamp frontier — submission FIFO must survive the
    // merge barrier at every thread count, byte for byte
    let zoo = ModelZoo::load();
    let mut trace = trace_cluster(&zoo, 64, 8, 3);
    for t in &mut trace.tasks {
        t.arrival_s = 0.0;
    }
    let serial = run_with(1, 4, PolicyKind::Magm, EstimatorKind::Oracle, Some(0.8), 2.0, &trace);
    assert_eq!(serial.report.completed, 64);
    let threaded = run_with(4, 4, PolicyKind::Magm, EstimatorKind::Oracle, Some(0.8), 2.0, &trace);
    assert_byte_identical(&serial, &threaded, "burst threads=4");

    // FIFO within each shard: among tasks routed to one shard, first
    // dispatches must follow submission order (ids here, as all arrivals
    // tie at t=0 and round-robin admission preserves id order per shard)
    for shard in 0..4usize {
        let mut mine: Vec<(usize, f64)> = threaded
            .recorder
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.assigned_shard == Some(shard))
            .map(|(i, t)| (i, t.dispatched_s.expect("completed trace")))
            .collect();
        mine.sort_by_key(|&(i, _)| i);
        let dispatches: Vec<f64> = mine.iter().map(|&(_, d)| d).collect();
        assert!(
            dispatches.windows(2).all(|w| w[0] <= w[1]),
            "shard {shard} reordered equal-timestamp ties: {dispatches:?}"
        );
    }
}

#[test]
fn auto_thread_count_completes_and_matches() {
    // threads = 0 (auto-detect) must behave like any other thread count:
    // same bytes, whatever the host's core count resolves to
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 48, 8, 11);
    let serial = run_with(1, 2, PolicyKind::Magm, EstimatorKind::Oracle, Some(0.8), 2.0, &trace);
    let auto = run_with(0, 2, PolicyKind::Magm, EstimatorKind::Oracle, Some(0.8), 2.0, &trace);
    assert_eq!(serial.report.completed, 48);
    assert_byte_identical(&serial, &auto, "auto threads");
}

// -- delta-view differential property suite (DESIGN.md §17) -----------------
//
// `engine.verify_views` re-derives every `ServerView` from scratch after
// EVERY commit and field-compares it (float bits included) against the
// delta-maintained snapshot — the handlers panic on the first divergence.
// Running it over traces that exercise each commit kind IS the differential
// property test: delta-maintained views == from-scratch rebuild after every
// dispatch, completion, OOM, shed, gang hold/expire, and fault
// strike/repair, at every shard and thread count.

#[test]
fn delta_views_match_rebuild_under_gang_fault_and_oom_commits() {
    // blind Round-Robin overload on 2×4 GPUs with distributed jobs and
    // mixed fault injection: dispatch, completion, OOM release, gang
    // hold/expire, and Gpu/Server/Link strike+repair commits all run under
    // the per-commit differential check
    let zoo = ModelZoo::load();
    let trace = trace_gang(&zoo, 36, 8, 4, 13);
    for &shards in &[1usize, 4] {
        let mut json_bits: Option<String> = None;
        for &threads in &[1usize, 4] {
            let mut c = CarmaConfig {
                policy: PolicyKind::RoundRobin,
                estimator: EstimatorKind::None,
                ..Default::default()
            };
            c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
            c.coordinator.shards = shards;
            c.engine.threads = threads;
            c.engine.verify_views = true;
            c.faults.profile = FaultProfile::Mixed;
            c.faults.rate_per_hour = 24.0;
            let e = estimators::build(EstimatorKind::None, "artifacts").unwrap();
            let out = run_trace(c, e, &trace, "delta-differential");
            assert!(
                out.view_stats.verified > 0,
                "differential check never ran ({shards} shards, {threads} threads)"
            );
            assert!(
                out.report.gang.gangs > 0,
                "trace must exercise the gang lane"
            );
            let j = out.report.to_json().to_string_pretty();
            match &json_bits {
                None => json_bits = Some(j),
                Some(prev) => assert_eq!(
                    *prev, j,
                    "{shards} shards: {threads} threads changed the verified run"
                ),
            }
        }
    }
}

#[test]
fn delta_views_match_rebuild_under_open_loop_shed_commits() {
    // saturating open-loop arrivals against tiny bounded queues: the shed
    // commit path (plus dispatch/completion churn) under the per-commit
    // differential check, swept over shards × threads × delta on/off —
    // every cell must produce the same verified bytes
    for &shards in &[1usize, 4] {
        let mut json_bits: Option<String> = None;
        for &delta in &[true, false] {
            for &threads in &[1usize, 4] {
                let mut c = CarmaConfig {
                    policy: PolicyKind::Magm,
                    estimator: EstimatorKind::Oracle,
                    smact_cap: Some(0.8),
                    safety_margin_gb: 2.0,
                    ..Default::default()
                };
                c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
                c.coordinator.shards = shards;
                c.engine.threads = threads;
                c.engine.delta_views = delta;
                c.engine.verify_views = true;
                c.service.arrivals = Some(ArrivalKind::Poisson);
                c.service.rate_per_min = 60.0;
                c.service.duration_s = 600.0;
                c.service.queue_cap = 2;
                let e = estimators::build(EstimatorKind::Oracle, "artifacts").unwrap();
                let out = run_service(c, e, "delta-differential-service");
                assert!(out.view_stats.verified > 0, "differential check never ran");
                assert!(
                    out.report.service.shed > 0,
                    "saturating rate must exercise the shed commit path"
                );
                let j = out.report.to_json().to_string_pretty();
                match &json_bits {
                    None => json_bits = Some(j),
                    Some(prev) => assert_eq!(
                        *prev, j,
                        "{shards} shards: delta={delta} threads={threads} \
                         changed the verified open-loop run"
                    ),
                }
            }
        }
    }
}

#[test]
fn threading_a_single_shard_is_still_identical() {
    // shards = 1 leaves no mapper fan-out, but the snapshot build still
    // runs through the pool — the degenerate case must stay byte-identical
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 32, 8, 5);
    let serial = run_with(1, 1, PolicyKind::Magm, EstimatorKind::Oracle, Some(0.8), 2.0, &trace);
    let threaded = run_with(4, 1, PolicyKind::Magm, EstimatorKind::Oracle, Some(0.8), 2.0, &trace);
    assert_eq!(serial.report.completed, 32);
    assert_byte_identical(&serial, &threaded, "1-shard threads=4");
}
