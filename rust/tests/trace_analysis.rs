//! Trace-native analysis invariants (DESIGN.md §16): the span
//! reconstruction partitions every task's lifetime exactly, the JCT
//! decomposition sums to the end-to-end time to within float residue, the
//! analyzer's sketches reproduce the run report's percentiles across the
//! shed, OOM and fault regimes, every record the engine emits passes the
//! published schema, and synthetic trace corruption trips the invariant
//! engine.

use carma::config::schema::{
    ArrivalKind, CarmaConfig, ClusterConfig, EstimatorKind, FaultProfile, PolicyKind, TimelineMode,
};
use carma::coordinator::carma::{run_service, run_trace, RunOutcome};
use carma::estimators;
use carma::obs::replay::{analyze_str, replay_str, validate_record, Analysis};
use carma::util::json::Json;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::{trace_60, trace_cluster};

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("carma_ta_{}_{name}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Run a configuration with `--trace-out`, hand back the trace text and
/// the run outcome it must agree with.
fn traced(
    mut c: CarmaConfig,
    name: &str,
    run: impl FnOnce(CarmaConfig) -> RunOutcome,
) -> (String, RunOutcome) {
    let path = tmp(name);
    c.obs.trace_out = Some(path.clone());
    let out = run(c);
    let text = std::fs::read_to_string(&path).expect("trace file written");
    let _ = std::fs::remove_file(&path);
    (text, out)
}

/// Closed-loop cluster run: 64 tasks over 2×4 GPUs, MAGM+oracle.
fn cluster_trace(name: &str, faults: Option<(FaultProfile, f64, u64)>) -> (String, RunOutcome) {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
    c.coordinator.shards = 2;
    if let Some((profile, rate, seed)) = faults {
        c.faults.profile = profile;
        c.faults.rate_per_hour = rate;
        c.faults.seed = seed;
    }
    traced(c, name, |c| {
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 64, 8, 13);
        let est = estimators::build(c.estimator, "artifacts").unwrap();
        run_trace(c, est, &trace, name)
    })
}

/// Blind round-robin over the 60-task trace: guaranteed OOM crashes.
fn oom_trace(name: &str) -> (String, RunOutcome) {
    let mut c = CarmaConfig {
        policy: PolicyKind::RoundRobin,
        estimator: EstimatorKind::None,
        ..Default::default()
    };
    c.smact_cap = None;
    traced(c, name, |c| {
        let zoo = ModelZoo::load();
        let trace = trace_60(&zoo, 1);
        let est = estimators::build(c.estimator, "artifacts").unwrap();
        run_trace(c, est, &trace, name)
    })
}

/// Saturating open-loop burst over 1×4 GPUs with a tight cap: sheds.
fn service_trace(name: &str) -> (String, RunOutcome) {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(1, 4, 40.0);
    c.coordinator.shards = 2;
    c.service.arrivals = Some(ArrivalKind::Burst);
    c.service.rate_per_min = 60.0;
    c.service.duration_s = 300.0;
    c.service.queue_cap = 2;
    c.obs.timeline = TimelineMode::Off;
    traced(c, name, |c| {
        let est = estimators::build(c.estimator, "artifacts").unwrap();
        run_service(c, est, name)
    })
}

/// Sketch-tolerance comparison: the documented ±5% bucket error, 6%
/// asserted (same slack as the recorder's own tests).
fn close(got: f64, want: f64) -> bool {
    (got - want).abs() <= want.abs().max(got.abs()) * 0.06 + 1e-9
}

/// The cross-regime contract: clean replay, exact conservation against
/// the report, sketch-faithful percentiles, exact span accounting.
fn assert_analysis_matches_report(ctx: &str, a: &Analysis, out: &RunOutcome) {
    let rep = &a.replay;
    assert!(rep.ok(), "{ctx}: replay violations: {:#?}", rep.violations);
    assert_eq!(rep.seq_gaps, 0, "{ctx}: trace has sequence gaps");
    assert_eq!(rep.non_terminal, 0, "{ctx}: tasks left non-terminal");
    let r = &out.report;
    assert_eq!(rep.offered, r.service.offered as u64, "{ctx}: offered");
    assert_eq!(rep.completed, r.completed as u64, "{ctx}: completed");
    assert_eq!(rep.shed, r.service.shed, "{ctx}: shed");
    assert_eq!(
        a.queue_delay.count(),
        out.recorder.queue_delay.count(),
        "{ctx}: queue-delay sample count"
    );
    assert_eq!(a.jct.count(), r.completed as u64, "{ctx}: JCT sample count");
    for (key, got, want) in [
        ("p50", a.queue_delay.percentile(50.0), r.service.queue_delay_p50_s),
        ("p99", a.queue_delay.percentile(99.0), r.service.queue_delay_p99_s),
        ("p999", a.queue_delay.percentile(99.9), r.service.queue_delay_p999_s),
    ] {
        assert!(
            close(got, want),
            "{ctx}: analyzer queue-delay {key} {got} vs report {want}"
        );
    }
    if a.jct.count() > 0 {
        assert!(
            close(a.jct.mean(), out.recorder.avg_jct_s()),
            "{ctx}: analyzer mean JCT {} vs report {}",
            a.jct.mean(),
            out.recorder.avg_jct_s()
        );
    }
}

#[test]
fn spans_partition_the_task_lifetime_with_exact_decomposition() {
    let (text, _) = cluster_trace("partition", None);
    let a = analyze_str(&text, 60.0);
    assert!(a.replay.ok(), "replay violations: {:#?}", a.replay.violations);
    assert!(!a.spans.tasks.is_empty());
    for t in &a.spans.tasks {
        // contiguous, gap-free, in order: a partition of [arrival, terminal]
        assert!(!t.spans.is_empty(), "task {} has no spans", t.task);
        assert_eq!(t.spans[0].start_s, t.arrival_s, "task {} first span", t.task);
        for w in t.spans.windows(2) {
            assert_eq!(
                w[0].end_s, w[1].start_s,
                "task {}: spans must be contiguous",
                t.task
            );
        }
        let last = t.spans.last().unwrap();
        assert_eq!(last.end_s, t.terminal_s, "task {} last span", t.task);
        for s in &t.spans {
            assert!(s.end_s >= s.start_s, "task {}: negative span", t.task);
        }
        // the decomposition sums to the end-to-end JCT exactly
        assert!(
            (t.decomposition.total_s() - t.jct_s()).abs() <= 1e-6,
            "task {}: decomposition {} != JCT {}",
            t.task,
            t.decomposition.total_s(),
            t.jct_s()
        );
    }
    // and the makespan is the last completion commit
    let max_complete = a
        .spans
        .tasks
        .iter()
        .filter(|t| t.outcome == "complete")
        .map(|t| t.terminal_s)
        .fold(0.0f64, f64::max);
    assert_eq!(a.spans.makespan_s, max_complete);
}

#[test]
fn analyzer_reproduces_the_report_across_shed_oom_and_fault_regimes() {
    let (text, out) = service_trace("svc");
    assert!(out.recorder.shed_total > 0, "burst run must shed");
    let a = analyze_str(&text, 60.0);
    assert!(a.replay.shed > 0, "sheds must surface in the replay");
    assert_analysis_matches_report("service", &a, &out);

    let (text, out) = oom_trace("oom");
    assert!(out.report.oom_crashes > 0, "blind run must OOM");
    let a = analyze_str(&text, 60.0);
    assert_analysis_matches_report("oom", &a, &out);
    let interrupted = a.spans.tasks.iter().any(|t| t.interruptions > 0);
    assert!(interrupted, "OOM crashes must open backoff spans");

    let (text, out) = cluster_trace("faults", Some((FaultProfile::Mixed, 60.0, 3)));
    let res = &out.report.resilience;
    assert!(res.faults_gpu + res.faults_server + res.faults_link > 0);
    let a = analyze_str(&text, 60.0);
    assert_analysis_matches_report("faults", &a, &out);
}

#[test]
fn every_emitted_record_passes_the_published_schema() {
    let (text, _) = cluster_trace("schema", Some((FaultProfile::Mixed, 60.0, 3)));
    assert!(!text.is_empty());
    for line in text.lines() {
        let rec = Json::parse(line).expect("every trace line parses");
        if let Err(e) = validate_record(&rec) {
            panic!("emitted record fails its own schema: {e}\n  {line}");
        }
    }
}

#[test]
fn synthetic_corruption_trips_the_invariant_engine() {
    let (text, _) = cluster_trace("corrupt", None);
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() > 10);
    assert!(replay_str(&text).ok(), "the uncorrupted trace must be clean");

    // dropping a mid-trace record leaves a sequence gap
    let mut dropped = lines.clone();
    dropped.remove(lines.len() / 2);
    let rep = replay_str(&dropped.join("\n"));
    assert!(rep.seq_gaps > 0, "a dropped record must count as a gap");
    assert!(!rep.ok());

    // swapping two adjacent records breaks strict (t, seq) order
    let mut swapped = lines.clone();
    swapped.swap(lines.len() / 2, lines.len() / 2 + 1);
    assert!(!replay_str(&swapped.join("\n")).ok(), "out-of-order records must violate");

    // duplicating a terminal record is an illegal lifecycle transition
    let dup = lines
        .iter()
        .find(|l| l.contains("\"ev\":\"complete\""))
        .expect("trace has completions");
    let mut duped: Vec<String> = lines.iter().map(|s| s.to_string()).collect();
    let mut forged = Json::parse(dup).unwrap();
    let seq = forged.f64_of("seq");
    let t = forged.f64_of("t");
    forged.set("seq", carma::util::json::num(seq + 100_000.0));
    forged.set("t", carma::util::json::num(t + 1e6));
    duped.push(forged.to_string_compact());
    assert!(
        !replay_str(&duped.join("\n")).ok(),
        "a double completion must violate the lifecycle"
    );

    // garbage bytes are a schema violation, not a crash
    let mut garbled: Vec<String> = lines.iter().map(|s| s.to_string()).collect();
    garbled.insert(lines.len() / 2, "{not json".to_string());
    assert!(!replay_str(&garbled.join("\n")).ok(), "garbage must violate");
}

#[test]
fn analysis_summary_is_byte_deterministic() {
    // same trace bytes in -> same summary bytes out, twice over
    let (text, _) = cluster_trace("det", Some((FaultProfile::Mixed, 60.0, 3)));
    let a = analyze_str(&text, 60.0).to_json().to_string_compact();
    let b = analyze_str(&text, 60.0).to_json().to_string_compact();
    assert_eq!(a, b);
}
