//! Gang-scheduling integration invariants (DESIGN.md §11): all-or-nothing
//! atomicity (also under an OOM-heavy trace), reservation-TTL expiry
//! releasing holds, no-starvation of large gangs under continuous
//! single-GPU arrivals, and bit-determinism of the gang path across engine
//! thread counts.

use carma::config::schema::{
    CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind, ShardAssign,
};
use carma::coordinator::carma::{run_trace, RunOutcome};
use carma::estimators;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::task::TaskSpec;
use carma::workload::trace::{server_localize, trace_gang, TraceSpec};

const SERVERS: usize = 4;
const GPUS: usize = 4;
const TASKS: usize = 96;
const GANG_GPUS: usize = 8;

fn gang_cfg() -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(SERVERS, GPUS, 40.0);
    c
}

fn run(c: CarmaConfig, trace: &TraceSpec) -> RunOutcome {
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    run_trace(c, est, trace, "gang-test")
}

#[test]
fn gangs_span_servers_all_or_nothing() {
    // 8-wide jobs on 4-GPU servers: they can only exist by spanning, and
    // every dispatch must place the full worker set atomically
    let zoo = ModelZoo::load();
    let trace = trace_gang(&zoo, TASKS, SERVERS * GPUS, GANG_GPUS, 42);
    let n_gangs = trace.tasks.iter().filter(|t| t.gang).count();
    assert!(n_gangs > 0);
    let out = run(gang_cfg(), &trace);
    assert_eq!(out.report.completed, TASKS, "every task (gangs included) completes");
    let g = &out.report.gang;
    assert_eq!(g.gangs, n_gangs);
    assert_eq!(g.completed, n_gangs);
    assert_eq!(g.partial_dispatches, 0, "all-or-nothing is an invariant");
    assert_eq!(g.cross_server, n_gangs, "8-wide gangs cannot fit one 4-GPU server");
    assert!(g.max_servers_spanned >= 2);
    // 8 GPUs over 4-GPU servers pack into 2 servers minimum; the fabric
    // ranking should rarely need more, but never fewer
    assert!(g.max_servers_spanned <= SERVERS);
}

#[test]
fn gang_atomicity_survives_oom_heavy_trace() {
    // blind round-robin, no preconditions, no estimator: the OOM/recovery
    // machinery fires constantly — atomicity and completion must survive,
    // and a crashed gang restarts whole (never a partial re-dispatch)
    let zoo = ModelZoo::load();
    let trace = trace_gang(&zoo, 48, SERVERS * GPUS, GANG_GPUS, 7);
    let mut c = gang_cfg();
    c.policy = PolicyKind::RoundRobin;
    c.estimator = EstimatorKind::None;
    c.safety_margin_gb = 0.0;
    c.smact_cap = None;
    let out = run(c, &trace);
    assert_eq!(out.report.completed, 48, "recovery must finish every task");
    assert!(out.report.oom_crashes > 0, "the blind trace should hit OOMs");
    assert_eq!(out.report.gang.partial_dispatches, 0);
    assert_eq!(out.recorder.failed_total, 0, "no task may exhaust its retry budget");
}

#[test]
fn hold_ttl_expires_and_releases_gpus() {
    // 2×2 cluster. Three long heavy singletons grab 3 of the 4 GPUs (they
    // are too big to collocate), then a 4-wide gang arrives: it can only
    // hold the leftover GPU, makes no further progress for far longer than
    // the 30 s TTL, and its hold must be torn down (and later re-acquired)
    // until the singletons drain. Everything still completes.
    let zoo = ModelZoo::load();
    let heavy: Vec<&_> = zoo
        .entries
        .iter()
        .filter(|e| e.weight_class == "heavy" && e.n_gpus == 1 && e.mem_gb > 20.0)
        .collect();
    let seed_entry = heavy.first().expect("a heavy 1-GPU zoo model");
    let mut tasks: Vec<TaskSpec> = Vec::new();
    for id in 0..3 {
        let mut t = TaskSpec::from_zoo(id, seed_entry, 1, 0.0);
        t.work_s = 1800.0; // 30 min: many TTL windows
        tasks.push(t);
    }
    let gang_entry = zoo
        .entries
        .iter()
        .find(|e| e.weight_class == "heavy" && e.mem_gb > 20.0)
        .unwrap();
    let mut g = TaskSpec::from_zoo(3, gang_entry, 1, 0.0).into_gang(4);
    g.work_s = 600.0;
    tasks.push(g);
    let trace = TraceSpec {
        name: "ttl-test".into(),
        tasks,
    };

    let mut c = gang_cfg();
    c.cluster = ClusterConfig::homogeneous(2, 2, 40.0);
    c.gang.hold_ttl_s = 30.0;
    let out = run(c, &trace);
    assert_eq!(out.report.completed, 4);
    let gs = &out.report.gang;
    assert_eq!(gs.gangs, 1);
    assert!(gs.holds_placed > 0, "the gang must have taken partial holds");
    assert!(
        gs.holds_expired > 0,
        "a stalled hold must be torn down at the TTL (placed {}, expired {})",
        gs.holds_placed,
        gs.holds_expired
    );
    assert_eq!(gs.partial_dispatches, 0);
}

#[test]
fn large_gang_not_starved_by_continuous_singletons() {
    // one 16-wide gang (the whole cluster) submitted early into a dense
    // singleton stream: without reservations the gang could wait forever —
    // the sticky-hold floor guarantees it eventually assembles all 16 GPUs
    let zoo = ModelZoo::load();
    let mut trace = trace_gang(&zoo, 80, SERVERS * GPUS, GANG_GPUS, 21);
    // strip the generated gangs, then make task 8 a cluster-wide gang
    for t in trace.tasks.iter_mut() {
        if t.gang {
            t.gang = false;
            t.n_gpus = 1;
            t.features.n_gpus = 1.0;
        }
    }
    let idx = 8;
    let arrival = trace.tasks[idx].arrival_s;
    let entry = zoo
        .entries
        .iter()
        .find(|e| e.weight_class == "heavy")
        .unwrap();
    trace.tasks[idx] = TaskSpec::from_zoo(idx, entry, 1, arrival).into_gang(SERVERS * GPUS);
    let out = run(gang_cfg(), &trace);
    assert_eq!(out.report.completed, 80, "the cluster-wide gang must not starve");
    let gs = &out.report.gang;
    assert_eq!(gs.gangs, 1);
    assert_eq!(gs.completed, 1);
    assert_eq!(gs.max_servers_spanned, SERVERS, "it needed every server");
    assert_eq!(gs.partial_dispatches, 0);
}

#[test]
fn gang_path_is_byte_identical_across_engine_threads() {
    // the §10 guarantee extended to §11: gang placement, holds, TTL expiry
    // and fabric speed factors all commit on the driver thread in
    // (time, seq) order — 4 engine threads must reproduce the serial run's
    // results JSON byte for byte, at 1 and 4 coordinator shards
    let zoo = ModelZoo::load();
    let trace = trace_gang(&zoo, TASKS, SERVERS * GPUS, GANG_GPUS, 13);
    for shards in [1usize, 4] {
        let mk = |threads: usize| {
            let mut c = gang_cfg();
            c.coordinator.shards = shards;
            c.engine.threads = threads;
            run(c, &trace)
        };
        let serial = mk(1);
        let threaded = mk(4);
        assert_eq!(serial.report.completed, TASKS, "{shards} shard(s)");
        assert_eq!(serial.events, threaded.events, "{shards} shard(s): event streams");
        assert_eq!(
            serial.report.to_json().to_string_pretty(),
            threaded.report.to_json().to_string_pretty(),
            "{shards} shard(s): full results JSON must be byte-identical"
        );
        assert!(serial.report.gang.cross_server > 0);
    }
}

#[test]
fn locality_assignment_completes_with_home_server_affinity() {
    // the topology-aware locality router (fabric home servers) must keep
    // the multi-server sharded pipeline complete and deterministic
    let zoo = ModelZoo::load();
    let trace = trace_gang(&zoo, 64, SERVERS * GPUS, GANG_GPUS, 5);
    let mk = || {
        let mut c = gang_cfg();
        c.coordinator.shards = 4;
        c.coordinator.assign = ShardAssign::Locality;
        run(c, &trace)
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.report.completed, 64);
    assert_eq!(
        a.report.trace_total_min.to_bits(),
        b.report.trace_total_min.to_bits()
    );
    assert_eq!(a.events, b.events);
}

#[test]
fn server_local_baseline_loses_to_gang_scheduling() {
    // the gang_scale acceptance claim in unit form: same workload, gangs
    // shrunk to one server at 2× wall time — the fabric-scheduled run must
    // strictly beat it on makespan
    let zoo = ModelZoo::load();
    let trace = trace_gang(&zoo, TASKS, SERVERS * GPUS, GANG_GPUS, 42);
    let local = server_localize(&trace, GPUS);
    let gang = run(gang_cfg(), &trace);
    let base = run(gang_cfg(), &local);
    assert_eq!(base.report.completed, TASKS);
    assert_eq!(base.report.gang.gangs, 0, "baseline has no gang-lane traffic");
    assert!(
        gang.report.trace_total_min < base.report.trace_total_min,
        "gang {:.1} m must strictly beat server-local {:.1} m",
        gang.report.trace_total_min,
        base.report.trace_total_min
    );
}
