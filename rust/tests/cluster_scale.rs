//! Cluster-scale integration invariants: a 32-GPU, 200+-task trace runs
//! deterministically under a fixed seed, two-level mapping keeps multi-GPU
//! tasks server-local, heterogeneous clusters complete, and the power
//! envelope only ever delays work (never loses it).

use carma::config::schema::{
    CarmaConfig, ClusterConfig, CollocationMode, EstimatorKind, PolicyKind, ServerConfig,
};
use carma::coordinator::carma::{run_trace, RunOutcome};
use carma::estimators;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::{trace_cluster, TraceSpec};

fn cluster_cfg(servers: usize, gpus: usize) -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        colloc: CollocationMode::Mps,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(servers, gpus, 40.0);
    c
}

fn run(c: CarmaConfig, trace: &TraceSpec) -> RunOutcome {
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    run_trace(c, est, trace, "test")
}

#[test]
fn acceptance_8x4_servers_200_tasks_deterministic() {
    // the PR's acceptance criterion: an 8-server × 4-GPU cluster completes
    // a ≥200-task trace with an identical makespan/energy report across two
    // seeded runs
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 256, 32, 42);
    assert!(trace.tasks.len() >= 200);

    let a = run(cluster_cfg(8, 4), &trace);
    let b = run(cluster_cfg(8, 4), &trace);
    assert_eq!(a.report.completed, 256);
    assert_eq!(b.report.completed, 256);
    assert_eq!(a.report.trace_total_min.to_bits(), b.report.trace_total_min.to_bits());
    assert_eq!(a.report.energy_mj.to_bits(), b.report.energy_mj.to_bits());
    assert_eq!(a.report.avg_waiting_min.to_bits(), b.report.avg_waiting_min.to_bits());
    assert_eq!(a.report.oom_crashes, b.report.oom_crashes);
    assert_eq!(a.events, b.events, "event streams must be identical");
}

#[test]
fn bigger_cluster_finishes_proportional_load() {
    // same per-GPU pressure on 1 vs 4 servers: both complete, and the big
    // cluster sustains far more aggregate work in similar simulated time
    let zoo = ModelZoo::load();
    let small_trace = trace_cluster(&zoo, 32, 4, 7);
    let big_trace = trace_cluster(&zoo, 128, 16, 7);
    let small = run(cluster_cfg(1, 4), &small_trace);
    let big = run(cluster_cfg(4, 4), &big_trace);
    assert_eq!(small.report.completed, 32);
    assert_eq!(big.report.completed, 128);
    // 4× the GPUs burn roughly 4× the energy for 4× the work — well more
    // than the single server, in any case
    assert!(big.report.energy_mj > small.report.energy_mj * 2.0);
}

#[test]
fn heterogeneous_cluster_completes() {
    let zoo = ModelZoo::load();
    let mut c = cluster_cfg(3, 4);
    c.cluster.servers[1] = ServerConfig {
        n_gpus: 2,
        mem_gb: 80.0,
        mig_slices: vec![],
    };
    c.cluster.servers[2] = ServerConfig {
        n_gpus: 4,
        mem_gb: 40.0,
        mig_slices: vec![0.5, 0.5],
    };
    let total = c.cluster.total_gpus();
    assert_eq!(total, 10);
    let trace = trace_cluster(&zoo, 60, total, 11);
    let out = run(c, &trace);
    assert_eq!(out.report.completed, 60, "heterogeneous cluster must finish");
}

#[test]
fn multi_gpu_tasks_complete_on_multi_server_clusters() {
    // the zoo's 2-GPU transformers must keep completing when the pool is
    // split across servers (two-level mapping keeps them server-local)
    let zoo = ModelZoo::load();
    // deterministically pick the first seed whose trace draws a 2-GPU model
    let mut seed = 5;
    let trace = loop {
        let t = trace_cluster(&zoo, 120, 8, seed);
        if t.tasks.iter().any(|t| t.n_gpus == 2) {
            break t;
        }
        seed += 1;
        assert!(seed < 25, "no 2-GPU task in 20 seeds — zoo changed?");
    };
    let out = run(cluster_cfg(4, 2), &trace);
    assert_eq!(out.report.completed, 120);
}

#[test]
fn impossible_gpu_count_fails_fast_instead_of_wedging() {
    // multi-GPU tasks never span servers; on a cluster of 1-GPU servers a
    // 2-GPU task must fail fast (surfaced to the user), not retry forever
    let zoo = ModelZoo::load();
    let mut seed = 5;
    let trace = loop {
        let t = trace_cluster(&zoo, 40, 4, seed);
        if t.tasks.iter().any(|t| t.n_gpus == 2) {
            break t;
        }
        seed += 1;
        assert!(seed < 25, "no 2-GPU task in 20 seeds — zoo changed?");
    };
    let two_gpu = trace.tasks.iter().filter(|t| t.n_gpus == 2).count();
    let out = run(cluster_cfg(4, 1), &trace);
    assert_eq!(out.recorder.failed_total as usize, two_gpu);
    assert_eq!(out.report.completed, trace.tasks.len() - two_gpu);
}

#[test]
fn power_envelope_delays_but_never_drops_work() {
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 48, 8, 3);
    let free = run(cluster_cfg(2, 4), &trace);
    let capped_cfg = || {
        let mut c = cluster_cfg(2, 4);
        // tight envelope: ~2 GPUs' active draw per 4-GPU server
        c.cluster.power_cap_w = Some(700.0);
        c
    };
    let capped = run(capped_cfg(), &trace);
    assert_eq!(free.report.completed, 48);
    assert_eq!(capped.report.completed, 48, "capped cluster must still finish");
    // the envelope is part of the deterministic state machine
    let again = run(capped_cfg(), &trace);
    assert_eq!(capped.report.trace_total_min.to_bits(), again.report.trace_total_min.to_bits());
    assert_eq!(capped.report.energy_mj.to_bits(), again.report.energy_mj.to_bits());
}

#[test]
fn single_server_cluster_reproduces_legacy_default() {
    // CarmaConfig::default() is still the paper's one-DGX setup; the
    // cluster refactor must not have changed its behavior
    let c = CarmaConfig::default();
    assert_eq!(c.cluster.n_servers(), 1);
    assert_eq!(c.cluster.total_gpus(), 4);
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 24, 4, 2);
    let mut cfg = cluster_cfg(1, 4);
    cfg.estimator = EstimatorKind::Oracle;
    let out = run(cfg, &trace);
    assert_eq!(out.report.completed, 24);
}
