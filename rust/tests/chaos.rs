//! Chaos integration invariants (DESIGN.md §15): seeded fault injection
//! must preserve conservation (`completed + failed + shed == offered`)
//! under every schedule, stay byte-deterministic at every shard/thread
//! count, never dispatch work onto quarantined hardware, tear down gang
//! reservations on dead servers, and keep gang placement all-or-nothing
//! across member loss.

use carma::config::schema::{
    CarmaConfig, ClusterConfig, EstimatorKind, FaultProfile, PolicyKind,
};
use carma::coordinator::carma::{run_trace, RunOutcome};
use carma::estimators;
use carma::obs::replay_str;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::{trace_cluster, trace_gang};

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("carma_chaos_{}_{name}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

const SERVERS: usize = 2;
const GPUS_PER_SERVER: usize = 4;

fn chaos_cfg(profile: FaultProfile, rate: f64, fault_seed: u64) -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(SERVERS, GPUS_PER_SERVER, 40.0);
    c.faults.profile = profile;
    c.faults.rate_per_hour = rate;
    c.faults.seed = fault_seed;
    c
}

fn chaos_run(mut c: CarmaConfig, shards: usize, threads: usize, trace_out: Option<String>) -> RunOutcome {
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 48, SERVERS * GPUS_PER_SERVER, 11);
    c.coordinator.shards = shards;
    c.engine.threads = threads;
    c.obs.trace_out = trace_out;
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    run_trace(c, est, &trace, "chaos")
}

/// `completed + failed + shed == offered` for a closed-loop run.
fn assert_conservation(out: &RunOutcome, ctx: &str) {
    let offered = out.recorder.offered();
    let terminal = out.report.completed
        + out.recorder.failed_total as usize
        + out.recorder.shed_total as usize;
    assert_eq!(
        terminal, offered,
        "{ctx}: {terminal} terminal of {offered} offered — a fault left tasks non-terminal"
    );
}

#[test]
fn conservation_holds_under_random_fault_schedules() {
    // the core invariant, property-style: sweep fault seeds × profiles and
    // assert every offered task reaches a terminal state under each
    // schedule — kills mid-ramp, mid-run and mid-recovery included
    for profile in [FaultProfile::Gpu, FaultProfile::Server, FaultProfile::Mixed] {
        for fault_seed in [1u64, 2, 3] {
            let out = chaos_run(chaos_cfg(profile, 45.0, fault_seed), 1, 1, None);
            assert_conservation(&out, &format!("{profile:?}/seed{fault_seed}"));
            let res = &out.report.resilience;
            assert!(
                res.faults_gpu + res.faults_server + res.faults_link > 0,
                "{profile:?}/seed{fault_seed}: schedule must strike"
            );
        }
    }
}

#[test]
fn fault_runs_are_byte_identical_across_threads_and_shards() {
    // the §10 guarantee extended over strikes, kills, health roll-backs
    // and degraded fabric costs: at a FIXED shard count, engine threads
    // change wall-clock only — results JSON AND trace bytes must match
    for shards in [1usize, 4] {
        let mut json_bits: Option<String> = None;
        let mut trace_bits: Option<Vec<u8>> = None;
        for threads in [1usize, 4] {
            let path = tmp(&format!("det_{shards}s_{threads}t"));
            let out = chaos_run(
                chaos_cfg(FaultProfile::Mixed, 30.0, 5),
                shards,
                threads,
                Some(path.clone()),
            );
            let b = std::fs::read(&path).expect("trace file written");
            let _ = std::fs::remove_file(&path);
            assert_conservation(&out, &format!("{shards}s/{threads}t"));
            let j = out.report.to_json().to_string_pretty();
            match &json_bits {
                None => json_bits = Some(j),
                Some(prev) => assert_eq!(
                    prev, &j,
                    "{shards} shards: {threads} threads changed the fault-run JSON"
                ),
            }
            match &trace_bits {
                None => trace_bits = Some(b),
                Some(prev) => assert_eq!(
                    prev, &b,
                    "{shards} shards: {threads} threads changed the fault-run trace bytes"
                ),
            }
        }
    }
}

#[test]
fn server_kill_leaves_no_task_non_terminal_and_no_dispatch_on_dead_hardware() {
    // replay the trace as a health state machine: `fault`/`repair` records
    // roll per-GPU outage counters forward, and every `dispatch` commit in
    // between must target only healthy devices — holds on a dead server
    // are invalidated rather than converted into placements
    let path = tmp("server_kill");
    let out = chaos_run(
        chaos_cfg(FaultProfile::Server, 40.0, 2),
        1,
        1,
        Some(path.clone()),
    );
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_conservation(&out, "server-kill");
    assert!(out.report.resilience.faults_server > 0, "servers must fail");

    let rep = replay_str(&text);
    assert!(rep.ok(), "replay violations: {:#?}", rep.violations);
    assert_eq!(
        rep.non_terminal, 0,
        "a server kill must leave no task non-terminal"
    );
    // the health check must have had teeth: some dispatch committed while
    // part of the cluster was down (and correctly avoided it)
    assert!(
        rep.dispatches_during_outage > 0,
        "no dispatch ever overlapped an outage — the avoidance check never engaged"
    );
}

#[test]
fn gang_atomicity_survives_member_loss() {
    // 8-GPU gangs spanning both servers under server faults: member loss
    // kills the whole gang (one TaskRun spans all members), relaunch is
    // all-or-nothing, and dead-server reservations dissolve instead of
    // dispatching partially
    let zoo = ModelZoo::load();
    let trace = trace_gang(&zoo, 36, SERVERS * GPUS_PER_SERVER, 2 * GPUS_PER_SERVER, 3);
    let mut c = chaos_cfg(FaultProfile::Server, 60.0, 4);
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    c.coordinator.shards = 2;
    let out = run_trace(c, est, &trace, "chaos-gang");
    assert_conservation(&out, "gang-chaos");
    assert!(out.report.resilience.faults_server > 0, "servers must fail");
    assert!(
        out.report.resilience.interruptions_server > 0,
        "a server loss must interrupt resident work"
    );
    assert_eq!(
        out.report.gang.partial_dispatches, 0,
        "all-or-nothing violated under faults"
    );
    assert!(out.report.gang.gangs > 0, "the trace must contain gangs");
}

#[test]
fn resilience_section_is_present_and_zeroed_without_faults() {
    let out = chaos_run(chaos_cfg(FaultProfile::None, 0.0, 1), 1, 1, None);
    assert_conservation(&out, "fault-free");
    let j = out.report.to_json();
    let res = j.get("resilience").expect("resilience section always present");
    for key in [
        "faults_gpu",
        "faults_server",
        "faults_link",
        "interruptions_gpu",
        "interruptions_server",
        "relaunches",
        "fault_failed",
        "repairs",
        "mttr_s",
        "downtime_gpu_s",
        "holds_invalidated",
    ] {
        assert_eq!(
            res.f64_of(key), 0.0,
            "fault-free run must zero resilience.{key}"
        );
    }
    assert_eq!(res.f64_of("availability"), 1.0);
    assert_eq!(res.f64_of("goodput"), 1.0, "fault-free goodput is 1.0");
}

#[test]
fn fault_free_bytes_match_a_build_without_fault_config() {
    // flipping the profile to None must byte-preserve the run vs simply
    // never touching [faults] at all — the degrade factor's 1.0 identity
    // and the empty schedule make chaos support free when off
    let a = chaos_run(chaos_cfg(FaultProfile::None, 12.0, 9), 2, 1, None);
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(SERVERS, GPUS_PER_SERVER, 40.0);
    let b = chaos_run(c, 2, 1, None);
    assert_eq!(
        a.report.to_json().to_string_pretty(),
        b.report.to_json().to_string_pretty(),
        "profile=none must byte-match an untouched config"
    );
    assert_eq!(a.events, b.events);
}
