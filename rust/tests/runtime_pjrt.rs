//! Runtime integration: AOT artifacts loaded and executed through PJRT —
//! the L3↔L2/L1 seam. Skipped when artifacts are not built.

use std::path::Path;

use carma::estimators::gpumemnet::GpuMemNetEstimator;
use carma::estimators::MemoryEstimator;
use carma::runtime::{LmTrainer, Runtime};
use carma::workload::model_zoo::ModelZoo;
use carma::workload::task::TaskSpec;

fn artifacts_ready() -> bool {
    Path::new("artifacts/gpumemnet_manifest.json").exists()
}

#[test]
fn gpumemnet_estimates_zoo_without_underestimating() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let est = GpuMemNetEstimator::load("artifacts").unwrap();
    let zoo = ModelZoo::load();
    let mut under = 0;
    for e in &zoo.entries {
        let t = TaskSpec::from_zoo(0, e, e.epochs[0], 0.0);
        let got = est.estimate_gb(&t).expect("estimate");
        assert!(got > 0.0 && got <= 40.0, "{}: {got}", e.key());
        if got < e.mem_gb {
            under += 1;
        }
    }
    // paper §3.3: "almost never underestimates"
    assert!(
        under * 10 <= zoo.entries.len(),
        "{under}/{} zoo entries underestimated",
        zoo.entries.len()
    );
}

#[test]
fn gpumemnet_is_deterministic_and_fast() {
    if !artifacts_ready() {
        return;
    }
    let est = GpuMemNetEstimator::load("artifacts").unwrap();
    let zoo = ModelZoo::load();
    let t = TaskSpec::from_zoo(0, zoo.find("resnet50", "imagenet", 64).unwrap(), 1, 0.0);
    let a = est.estimate_gb(&t).unwrap();
    let b = est.estimate_gb(&t).unwrap();
    assert_eq!(a, b);

    // paper budget: ≤16 ms on A100, 32 ms on EPYC CPU. Cached path must be
    // instant; uncached (distinct features) well under the budget.
    let start = std::time::Instant::now();
    for bs in [32, 64, 128] {
        for name in ["resnet50", "mobilenet_v2", "vgg16", "xception"] {
            if let Some(e) = zoo.find(name, "imagenet", bs) {
                let t = TaskSpec::from_zoo(0, e, 1, 0.0);
                est.estimate_gb(&t);
            }
        }
    }
    let per_call = start.elapsed().as_secs_f64() / 12.0;
    assert!(per_call < 0.032, "estimator {per_call}s/call exceeds the 32 ms budget");
}

#[test]
fn transformer_estimator_artifact_loads_and_runs() {
    if !artifacts_ready() {
        return;
    }
    // the Fig. 5b transformer-classifier variant (Pallas encoder inside)
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo("artifacts/gpumemnet_cnn_tf.hlo.txt").unwrap();
    let x = carma::runtime::pjrt::literal_f32(&[0.0; 16], &[1, 16]).unwrap();
    let seq = carma::runtime::pjrt::literal_f32(&vec![0.0; 32 * 3], &[1, 32, 3]).unwrap();
    let out = exe.run(&[x, seq]).unwrap();
    let logits = out[0].to_vec::<f32>().unwrap();
    assert!(logits.len() >= 5);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn lm_trainer_two_steps_reduce_loss_direction() {
    if !artifacts_ready() || !Path::new("artifacts/lm_step.hlo.txt").exists() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut tr = LmTrainer::load(&rt, "artifacts", 7).unwrap();
    let l1 = tr.step_synthetic().unwrap();
    let l2 = tr.step_synthetic().unwrap();
    assert!(l1.is_finite() && l2.is_finite());
    assert!(l1 > 0.0);
    assert_eq!(tr.steps_done(), 2);
    // two steps won't converge but must not explode
    assert!(l2 < l1 * 1.5, "loss exploded: {l1} -> {l2}");
}

#[test]
fn synth_batch_is_learnable_structure() {
    if !artifacts_ready() || !Path::new("artifacts/lm_step.hlo.txt").exists() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut tr = LmTrainer::load(&rt, "artifacts", 3).unwrap();
    let toks = tr.synth_batch();
    assert_eq!(toks.len(), tr.manifest.batch * (tr.manifest.seq_len + 1));
    // mostly consecutive (cyclic ramp with 2% noise)
    let s = tr.manifest.seq_len + 1;
    let mut consecutive = 0;
    let mut total = 0;
    for row in toks.chunks(s) {
        for w in row.windows(2) {
            total += 1;
            if w[1] == w[0] + 1 || w[1] == 0 || w[1] < w[0] {
                consecutive += 1;
            }
        }
    }
    assert!(consecutive as f64 / total as f64 > 0.9);
}
