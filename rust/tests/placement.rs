//! Placement-core acceptance tests (DESIGN.md §12, ISSUE 5):
//!
//! (a) `--fabric-aware-singletons off` byte-reproduces the SEED pipeline —
//!     proved two ways: a property test against a verbatim copy of the
//!     seed selection code (kept here as the reference model), and full
//!     trace runs across policies × shards {1,4} × threads {1,4} whose
//!     results JSON must be byte-identical across engine thread counts;
//! (b) with the switch on, a 2-GPU singleton on a dual-island server
//!     lands inside one island (and the blind pipeline demonstrably
//!     splits the same pair);
//! (c) gang planning is unchanged by the refactor — property-tested
//!     against a verbatim copy of the seed `plan_gang`;
//! plus the bounded work-stealing satellite: starved shards steal the
//! longest sibling queue's tail, deterministically, behind
//! `[coordinator] steal`.

use carma::config::schema::{
    CarmaConfig, ClusterConfig, EstimatorKind, FabricConfig, FabricProfile, PolicyKind,
    PowerConfig, ShardAssign,
};
use carma::coordinator::carma::{run_trace, RunOutcome};
use carma::coordinator::gang::{plan_gang, GangPlan, ReservationBook};
use carma::coordinator::policy::{
    select_two_level, GpuView, MappingRequest, Placement, Preconditions, ServerView,
};
use carma::cluster::topology::ClusterTopology;
use carma::cluster::Fabric;
use carma::estimators;
use carma::sim::TaskId;
use carma::testkit;
use carma::util::rng::Rng;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::task::TaskSpec;
use carma::workload::trace::{trace_pairs, TraceSpec};

/// Verbatim copy of the SEED selection pipeline (pre-refactor
/// `coordinator/policy.rs` and `coordinator/gang/mod.rs`), kept as the
/// reference model for the byte-reproduction contract. Production code
/// must never call this — it exists so test (a)/(c) can diff the unified
/// core against what the seed actually computed.
mod seed_reference {
    use super::*;
    use std::collections::BTreeMap;

    const FIT_SLACK_GB: f64 = 1.0 / 1024.0;

    fn passes(v: &GpuView, req: MappingRequest, pre: Preconditions) -> bool {
        if v.pinned || v.held {
            return false;
        }
        if v.mig_enabled {
            let Some(_) = v.mig_free_instance else {
                return false;
            };
            if let Some(d) = req.demand_gb {
                if d > v.mig_instance_mem_gb + FIT_SLACK_GB {
                    return false;
                }
            }
            return true;
        }
        if let Some(cap) = pre.smact_cap {
            if v.smact_window > cap {
                return false;
            }
        }
        if let Some(min_free) = pre.min_free_gb {
            if v.free_gb < min_free {
                return false;
            }
        }
        if let Some(d) = req.demand_gb {
            if v.free_gb + FIT_SLACK_GB < d {
                return false;
            }
        }
        true
    }

    fn exclusive(views: &[GpuView], req: MappingRequest) -> Option<Placement> {
        let idle: Vec<usize> = views
            .iter()
            .filter(|v| {
                if v.pinned || v.held {
                    return false;
                }
                if v.mig_enabled {
                    v.mig_free_instance.is_some()
                        && req
                            .demand_gb
                            .is_none_or(|d| d <= v.mig_instance_mem_gb + FIT_SLACK_GB)
                } else {
                    v.n_tasks == 0
                        && req.demand_gb.is_none_or(|d| d <= v.free_gb + FIT_SLACK_GB)
                }
            })
            .map(|v| v.id)
            .take(req.n_gpus)
            .collect();
        if idle.len() < req.n_gpus {
            return None;
        }
        Some(placement(views, idle))
    }

    fn placement(views: &[GpuView], gpus: Vec<usize>) -> Placement {
        let instances = gpus
            .iter()
            .map(|&g| {
                let v = views.iter().find(|v| v.id == g).unwrap();
                if v.mig_enabled {
                    v.mig_free_instance
                } else {
                    None
                }
            })
            .collect();
        Placement { gpus, instances }
    }

    pub fn select_gpus(
        policy: PolicyKind,
        views: &[GpuView],
        req: MappingRequest,
        pre: Preconditions,
        rr_cursor: &mut usize,
    ) -> Option<Placement> {
        if req.exclusive || policy == PolicyKind::Exclusive {
            return exclusive(views, req);
        }
        let mut eligible: Vec<&GpuView> =
            views.iter().filter(|v| passes(v, req, pre)).collect();
        if eligible.len() < req.n_gpus {
            return None;
        }
        match policy {
            PolicyKind::RoundRobin => {
                let mut ids: Vec<usize> = views.iter().map(|v| v.id).collect();
                ids.sort_unstable();
                let start = ids.iter().position(|&id| id >= *rr_cursor).unwrap_or(0);
                let mut chosen = Vec::new();
                for off in 0..ids.len() {
                    let id = ids[(start + off) % ids.len()];
                    if eligible.iter().any(|v| v.id == id) {
                        chosen.push(id);
                        if chosen.len() == req.n_gpus {
                            *rr_cursor = id + 1;
                            break;
                        }
                    }
                }
                if chosen.len() < req.n_gpus {
                    return None;
                }
                Some(placement(views, chosen))
            }
            PolicyKind::Magm => {
                eligible
                    .sort_by(|a, b| b.free_gb.total_cmp(&a.free_gb).then(a.id.cmp(&b.id)));
                Some(placement(
                    views,
                    eligible[..req.n_gpus].iter().map(|v| v.id).collect(),
                ))
            }
            PolicyKind::Lug => {
                eligible.sort_by(|a, b| {
                    a.smact_window
                        .total_cmp(&b.smact_window)
                        .then(a.id.cmp(&b.id))
                });
                Some(placement(
                    views,
                    eligible[..req.n_gpus].iter().map(|v| v.id).collect(),
                ))
            }
            PolicyKind::Mug => {
                eligible.sort_by(|a, b| {
                    b.smact_window
                        .total_cmp(&a.smact_window)
                        .then(a.id.cmp(&b.id))
                });
                Some(placement(
                    views,
                    eligible[..req.n_gpus].iter().map(|v| v.id).collect(),
                ))
            }
            PolicyKind::Exclusive => unreachable!(),
        }
    }

    pub fn select_two_level(
        policy: PolicyKind,
        servers: &[ServerView],
        req: MappingRequest,
        pre: Preconditions,
        rr_cursor: &mut usize,
    ) -> Option<Placement> {
        let admitted: Vec<&ServerView> = servers.iter().filter(|s| s.admits(req)).collect();
        if admitted.is_empty() {
            return None;
        }
        if req.exclusive || policy == PolicyKind::Exclusive {
            return admitted.iter().find_map(|s| exclusive(&s.gpus, req));
        }
        if policy == PolicyKind::RoundRobin {
            let mut flat: Vec<&GpuView> = admitted
                .iter()
                .flat_map(|s| s.gpus.iter())
                .filter(|v| passes(v, req, pre))
                .collect();
            flat.sort_unstable_by_key(|v| v.id);
            if flat.is_empty() {
                return None;
            }
            let start = flat.iter().position(|v| v.id >= *rr_cursor).unwrap_or(0);
            for off in 0..flat.len() {
                let first = flat[(start + off) % flat.len()];
                let host = admitted.iter().find(|s| s.id == first.server)?;
                let mut cursor = first.id;
                if let Some(p) =
                    select_gpus(PolicyKind::RoundRobin, &host.gpus, req, pre, &mut cursor)
                {
                    *rr_cursor = cursor;
                    return Some(p);
                }
            }
            return None;
        }
        let mut best: Option<(f64, Placement)> = None;
        for s in &admitted {
            let mut throwaway = 0usize;
            let Some(p) = select_gpus(policy, &s.gpus, req, pre, &mut throwaway) else {
                continue;
            };
            let score: f64 = p
                .gpus
                .iter()
                .map(|&g| {
                    let v = s.gpus.iter().find(|v| v.id == g).expect("chosen gpu");
                    match policy {
                        PolicyKind::Magm => v.free_gb,
                        PolicyKind::Lug => -v.smact_window,
                        PolicyKind::Mug => v.smact_window,
                        PolicyKind::RoundRobin | PolicyKind::Exclusive => unreachable!(),
                    }
                })
                .sum();
            if best.as_ref().is_none_or(|(b, _)| score > *b) {
                best = Some((score, p));
            }
        }
        best.map(|(_, p)| p)
    }

    fn gang_eligible(
        v: &GpuView,
        req: MappingRequest,
        pre: Preconditions,
        book: &ReservationBook,
        task: TaskId,
    ) -> bool {
        let fits =
            |v: &GpuView| req.demand_gb.is_none_or(|d| d <= v.free_gb + FIT_SLACK_GB);
        if book.holder(v.id) == Some(task) {
            return fits(v) && (!req.exclusive || v.n_tasks == 0);
        }
        if v.held || v.pinned || v.mig_enabled {
            return false;
        }
        if req.exclusive {
            return v.n_tasks == 0 && fits(v);
        }
        passes(v, req, pre)
    }

    pub fn plan_gang(
        views: &[ServerView],
        fabric: &Fabric,
        book: &ReservationBook,
        power_cfg: &PowerConfig,
        req: MappingRequest,
        pre: Preconditions,
        task: TaskId,
    ) -> GangPlan {
        let mut cands: Vec<(usize, Vec<usize>)> = Vec::new();
        for s in views {
            let own_slots = s
                .gpus
                .iter()
                .filter(|v| book.holder(v.id) == Some(task))
                .count();
            let mut elig: Vec<&GpuView> = s
                .gpus
                .iter()
                .filter(|v| gang_eligible(v, req, pre, book, task))
                .collect();
            if elig.is_empty() {
                continue;
            }
            let mut island_count: BTreeMap<usize, usize> = BTreeMap::new();
            for v in &elig {
                *island_count.entry(fabric.island_of(v.id)).or_insert(0) += 1;
            }
            elig.sort_by_key(|v| {
                let island = fabric.island_of(v.id);
                (
                    book.holder(v.id) != Some(task),
                    std::cmp::Reverse(island_count[&island]),
                    island,
                    v.n_tasks,
                    v.id,
                )
            });
            let k_max = match s.power_cap_w {
                None => elig.len(),
                Some(cap) => {
                    let slot_w = carma::cluster::power::reserved_w(power_cfg, 1);
                    let extra = if slot_w <= 0.0 {
                        elig.len()
                    } else {
                        ((cap - s.power_w) / slot_w).max(0.0).floor() as usize
                    };
                    (own_slots + extra).min(elig.len())
                }
            };
            elig.truncate(k_max);
            if !elig.is_empty() {
                cands.push((s.id, elig.iter().map(|v| v.id).collect()));
            }
        }
        cands.sort_by(|a, b| b.1.len().cmp(&a.1.len()).then(a.0.cmp(&b.0)));
        let available: usize = cands.iter().map(|(_, g)| g.len()).sum();
        if available >= req.n_gpus {
            let mut chosen = Vec::with_capacity(req.n_gpus);
            'fill: for (_, gpus) in &cands {
                for &g in gpus {
                    chosen.push(g);
                    if chosen.len() == req.n_gpus {
                        break 'fill;
                    }
                }
            }
            return GangPlan::Place(chosen);
        }
        let new_holds: Vec<usize> = cands
            .iter()
            .flat_map(|(_, gpus)| gpus.iter().copied())
            .filter(|&g| book.holder(g) != Some(task))
            .collect();
        GangPlan::Hold(new_holds)
    }
}

// -- random-cluster generator -----------------------------------------------

#[derive(Debug, Clone)]
struct Scenario {
    n_servers: usize,
    gpus_per: usize,
    servers: Vec<ServerView>,
    req: MappingRequest,
    pre: Preconditions,
    cursor: usize,
    /// GPUs held by "our" gang task (id 7) vs a foreign holder (id 99).
    own_holds: Vec<usize>,
    foreign_holds: Vec<usize>,
}

fn gen_scenario(rng: &mut Rng, size: usize) -> Scenario {
    let n_servers = 1 + size % 3;
    let gpus_per = 2 + size % 3;
    let mut own_holds = Vec::new();
    let mut foreign_holds = Vec::new();
    let mut servers = Vec::new();
    let mut gid = 0usize;
    for sid in 0..n_servers {
        let mut gpus = Vec::new();
        for _ in 0..gpus_per {
            let mig = rng.bool(0.15);
            let held = rng.bool(0.2);
            if held {
                if rng.bool(0.5) {
                    own_holds.push(gid);
                } else {
                    foreign_holds.push(gid);
                }
            }
            gpus.push(GpuView {
                id: gid,
                server: sid,
                free_gb: rng.range_f64(0.0, 40.0),
                smact_window: rng.f64(),
                n_tasks: rng.range_usize(0, 4),
                pinned: rng.bool(0.1),
                held,
                unhealthy: false,
                mig_free_instance: if mig && rng.bool(0.7) {
                    Some(rng.range_usize(0, 2))
                } else {
                    None
                },
                mig_instance_mem_gb: rng.range_f64(5.0, 20.0),
                mig_enabled: mig,
            });
            gid += 1;
        }
        let capped = rng.bool(0.3);
        servers.push(ServerView {
            id: sid,
            power_w: rng.range_f64(100.0, 1400.0),
            power_cap_w: capped.then(|| rng.range_f64(200.0, 1300.0)),
            gpus: gpus.into(),
        });
    }
    Scenario {
        n_servers,
        gpus_per,
        servers,
        req: MappingRequest {
            n_gpus: 1 + size % 3,
            demand_gb: rng.bool(0.6).then(|| rng.range_f64(1.0, 30.0)),
            exclusive: rng.bool(0.2),
        },
        pre: Preconditions {
            smact_cap: rng.bool(0.7).then(|| rng.f64()),
            min_free_gb: rng.bool(0.4).then(|| rng.range_f64(0.0, 20.0)),
        },
        cursor: rng.range_usize(0, n_servers * gpus_per + 2),
        own_holds,
        foreign_holds,
    }
}

#[test]
fn off_switch_matches_seed_reference_for_all_policies() {
    // test (a), model half: the unified core with fabric off must equal
    // the seed pipeline on every input — placement AND cursor
    let gen = |rng: &mut Rng, size: usize| gen_scenario(rng, size);
    testkit::forall(&gen, |sc: &Scenario| {
        for policy in [
            PolicyKind::Exclusive,
            PolicyKind::RoundRobin,
            PolicyKind::Magm,
            PolicyKind::Lug,
            PolicyKind::Mug,
        ] {
            let mut cur_new = sc.cursor;
            let mut cur_ref = sc.cursor;
            let new = select_two_level(policy, &sc.servers, sc.req, sc.pre, &mut cur_new);
            let reference =
                seed_reference::select_two_level(policy, &sc.servers, sc.req, sc.pre, &mut cur_ref);
            if new != reference {
                return Err(format!(
                    "{policy:?}: core {new:?} != seed {reference:?} (req {:?})",
                    sc.req
                ));
            }
            if cur_new != cur_ref {
                return Err(format!(
                    "{policy:?}: cursor diverged {cur_new} != {cur_ref}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn gang_planning_is_unchanged_by_the_refactor() {
    // test (c), model half: plan_gang (now a thin placement-core caller)
    // must equal the seed gang planner on every input, every profile
    let gen = |rng: &mut Rng, size: usize| {
        let sc = gen_scenario(rng, size);
        let profile = *rng.choice(&[
            FabricProfile::NvlinkIsland,
            FabricProfile::FlatPcie,
            FabricProfile::DualIsland,
        ]);
        let width = 2 + size % 6;
        (sc, profile, width)
    };
    testkit::forall(&gen, |(sc, profile, width): &(Scenario, FabricProfile, usize)| {
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(
            sc.n_servers,
            sc.gpus_per,
            40.0,
        ));
        let fabric = Fabric::new(
            &topo,
            &FabricConfig {
                profile: *profile,
                ..FabricConfig::default()
            },
        );
        let mut book = ReservationBook::new(&topo);
        for &g in &sc.own_holds {
            book.hold(g, 7);
        }
        for &g in &sc.foreign_holds {
            book.hold(g, 99);
        }
        let req = MappingRequest {
            n_gpus: *width,
            ..sc.req
        };
        let new = plan_gang(&sc.servers, &fabric, &book, &PowerConfig::default(), req, sc.pre, 7);
        let reference = seed_reference::plan_gang(
            &sc.servers,
            &fabric,
            &book,
            &PowerConfig::default(),
            req,
            sc.pre,
            7,
        );
        if new != reference {
            return Err(format!("core {new:?} != seed {reference:?} (req {req:?})"));
        }
        Ok(())
    });
}

// -- full-trace determinism + behavior --------------------------------------

fn base_cfg(profile: FabricProfile, aware: bool, shards: usize, threads: usize) -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
    c.fabric.profile = profile;
    c.placement.fabric_aware_singletons = aware;
    c.coordinator.shards = shards;
    c.engine.threads = threads;
    c
}

fn run(c: CarmaConfig, trace: &TraceSpec) -> RunOutcome {
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    run_trace(c, est, trace, "placement-test")
}

#[test]
fn off_switch_is_deterministic_across_policies_shards_threads() {
    // test (a), trace half: with the switch off, every (policy, shards)
    // combination is byte-identical across engine threads {1,4} — the seed
    // pipeline's §10 guarantee survives the extraction
    let zoo = ModelZoo::load();
    let trace = trace_pairs(&zoo, 48, 8, 3, 11);
    for policy in [PolicyKind::Magm, PolicyKind::Lug, PolicyKind::RoundRobin] {
        for shards in [1usize, 4] {
            let mut jsons = Vec::new();
            for threads in [1usize, 4] {
                let mut c = base_cfg(FabricProfile::DualIsland, false, shards, threads);
                c.policy = policy;
                let out = run(c, &trace);
                assert_eq!(out.report.completed, 48, "{policy:?}/{shards}/{threads}");
                jsons.push(out.report.to_json().to_string_pretty());
            }
            assert_eq!(
                jsons[0], jsons[1],
                "{policy:?}/{shards} shards: threads must not change results"
            );
        }
    }
}

#[test]
fn aware_mode_is_deterministic_and_beats_blind_on_fabric_cost() {
    // the acceptance criterion at test scale: island-aware mean achieved
    // fabric cost strictly below island-blind on the dual-island profile,
    // byte-identical across threads in both modes
    let zoo = ModelZoo::load();
    let trace = trace_pairs(&zoo, 48, 8, 3, 11);
    let mut mean_cost = Vec::new();
    for aware in [true, false] {
        let mut jsons = Vec::new();
        for threads in [1usize, 4] {
            let out = run(base_cfg(FabricProfile::DualIsland, aware, 4, threads), &trace);
            assert_eq!(out.report.completed, 48);
            assert!(out.report.placement.multi_gpu_singletons > 0);
            jsons.push(out.report.to_json().to_string_pretty());
            if threads == 1 {
                mean_cost.push(out.report.placement.mean_fabric_cost);
            }
        }
        assert_eq!(jsons[0], jsons[1], "aware={aware}: thread-count determinism");
    }
    assert!(
        mean_cost[0] < mean_cost[1],
        "island-aware {:.6} must strictly beat blind {:.6}",
        mean_cost[0],
        mean_cost[1]
    );
}

#[test]
fn single_island_and_flat_profiles_are_unchanged_by_the_switch() {
    // on nvlink-island substrates the island-aware decision is
    // definitionally the blind one; on flat-pcie every server-local set
    // costs the same (all links cross the switch), so the decision — and
    // crucially the Round-Robin cursor — must match too: the switch is a
    // byte-level no-op on both
    let zoo = ModelZoo::load();
    let trace = trace_pairs(&zoo, 48, 8, 3, 11);
    for profile in [FabricProfile::NvlinkIsland, FabricProfile::FlatPcie] {
        for policy in [PolicyKind::Magm, PolicyKind::RoundRobin] {
            let mk = |aware: bool| {
                let mut c = base_cfg(profile, aware, 4, 1);
                c.policy = policy;
                run(c, &trace)
            };
            let on = mk(true);
            let off = mk(false);
            assert_eq!(
                on.report.to_json().to_string_pretty(),
                off.report.to_json().to_string_pretty(),
                "{profile:?}/{policy:?}: switch must be a no-op"
            );
            assert_eq!(on.events, off.events, "{profile:?}/{policy:?}");
        }
    }

    // the hard case: spanning gangs load NICs, so a naive NIC tie-break
    // could divert policy-score ties between single-island servers — the
    // islands_matter gate must keep even gang traces byte-identical
    let gang_trace = carma::workload::trace::trace_gang(&zoo, 48, 16, 8, 7);
    let mk_gang = |aware: bool| {
        let mut c = base_cfg(FabricProfile::NvlinkIsland, aware, 4, 1);
        c.cluster = ClusterConfig::homogeneous(4, 4, 40.0);
        run(c, &gang_trace)
    };
    let on = mk_gang(true);
    let off = mk_gang(false);
    assert!(on.report.gang.cross_server > 0, "gangs must actually span (NIC load)");
    assert_eq!(
        on.report.to_json().to_string_pretty(),
        off.report.to_json().to_string_pretty(),
        "nvlink + spanning gangs: the switch must still be a byte-level no-op"
    );
}

#[test]
fn dual_island_pair_lands_inside_one_island() {
    // test (b) in driver form: a 1-GPU task occupies one island-0 device,
    // then a 2-GPU task arrives. Blind MAGM takes the two most-free
    // devices — which straddle the bridge — while the aware core keeps
    // the pair inside the fully-free island.
    let zoo = ModelZoo::load();
    let single = zoo
        .entries
        .iter()
        .find(|e| e.n_gpus == 1)
        .expect("single-GPU zoo entry");
    let pair = zoo
        .entries
        .iter()
        .find(|e| e.n_gpus == 2)
        .expect("2-GPU zoo entry");
    let trace = TraceSpec {
        name: "one-pair".into(),
        tasks: vec![
            TaskSpec::from_zoo(0, single, single.epochs[0], 0.0),
            TaskSpec::from_zoo(1, pair, pair.epochs[0], 10.0),
        ],
    };
    let mk = |aware: bool| {
        let mut c = base_cfg(FabricProfile::DualIsland, aware, 1, 1);
        c.cluster = ClusterConfig::homogeneous(1, 4, 40.0);
        run(c, &trace)
    };
    let aware = mk(true);
    assert_eq!(aware.report.completed, 2);
    assert_eq!(aware.report.placement.multi_gpu_singletons, 1);
    assert_eq!(
        aware.report.placement.single_island, 1,
        "aware: the pair must land inside one island"
    );
    assert_eq!(aware.recorder.tasks[1].islands_spanned, 1);
    let blind = mk(false);
    assert_eq!(blind.report.completed, 2);
    assert_eq!(
        blind.recorder.tasks[1].islands_spanned, 2,
        "blind: top-2 free devices straddle the PCIe bridge"
    );
    assert!(
        aware.recorder.tasks[1].fabric_cost < blind.recorder.tasks[1].fabric_cost,
        "achieved cost must drop when the pair stays on NVLink"
    );
}

// -- work stealing -----------------------------------------------------------

#[test]
fn starved_shards_steal_the_longest_sibling_tail() {
    // locality routing on a 2-server cluster homes every task onto shards
    // {0, 1}; shards 2 and 3 would idle forever. With stealing on they
    // must pick up backlog — deterministically, with everything finishing.
    let zoo = ModelZoo::load();
    let trace = trace_pairs(&zoo, 64, 8, 4, 7);
    let mk = |steal: bool, threads: usize| {
        let mut c = base_cfg(FabricProfile::NvlinkIsland, true, 4, threads);
        c.coordinator.assign = ShardAssign::Locality;
        c.coordinator.steal = steal;
        run(c, &trace)
    };
    let off = mk(false, 1);
    assert_eq!(off.report.completed, 64);
    assert_eq!(
        off.report.per_shard.iter().map(|s| s.steals).sum::<u64>(),
        0,
        "stealing must stay off by default"
    );
    assert_eq!(off.report.per_shard[2].tasks + off.report.per_shard[3].tasks, 0);

    let on = mk(true, 1);
    assert_eq!(on.report.completed, 64);
    let steals: u64 = on.report.per_shard.iter().map(|s| s.steals).sum();
    assert!(steals > 0, "starved shards must steal from the backlog");
    assert!(
        on.report.per_shard[2].steals + on.report.per_shard[3].steals > 0,
        "the permanently-unrouted shards must be among the thieves"
    );
    assert!(
        on.report.avg_waiting_min < off.report.avg_waiting_min,
        "stealing must cut queueing delay when half the mappers starve: \
         {:.2} !< {:.2}",
        on.report.avg_waiting_min,
        off.report.avg_waiting_min
    );

    // deterministic: repeat run bit-identical, and threads {1,4} byte-equal
    let again = mk(true, 1);
    assert_eq!(
        on.report.to_json().to_string_pretty(),
        again.report.to_json().to_string_pretty()
    );
    assert_eq!(on.events, again.events);
    let threaded = mk(true, 4);
    assert_eq!(
        on.report.to_json().to_string_pretty(),
        threaded.report.to_json().to_string_pretty(),
        "stealing must stay byte-deterministic under the parallel engine"
    );
}
