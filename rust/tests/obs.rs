//! Observability integration invariants (DESIGN.md §14): the streaming
//! event trace is byte-identical at every engine-thread count, covers the
//! whole task lifecycle including the shed and OOM paths, the profiler's
//! wall-clock data is structurally excluded from byte-compared artifacts,
//! and the metric sketches honour their documented error bound.

use carma::config::schema::{
    ArrivalKind, CarmaConfig, ClusterConfig, EstimatorKind, FaultProfile, PolicyKind, TimelineMode,
};
use carma::coordinator::carma::{run_service, run_trace, RunOutcome};
use carma::estimators;
use carma::obs::{replay_str, LogHistogram};
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::{trace_60, trace_cluster};

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("carma_obs_{}_{name}.jsonl", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn cluster_run(shards: usize, threads: usize, trace_out: Option<String>) -> RunOutcome {
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 64, 8, 13);
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
    c.coordinator.shards = shards;
    c.engine.threads = threads;
    c.obs.trace_out = trace_out;
    c.obs.explain_sample = 8;
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    run_trace(c, est, &trace, "obs")
}

#[test]
fn trace_is_byte_identical_across_engine_threads() {
    // the §10 guarantee extended to the trace sink: at a FIXED shard count,
    // engine threads change wall-clock only — the emitted byte stream
    // (including sampled decision records) must match exactly
    for shards in [1usize, 4] {
        let mut bytes: Option<Vec<u8>> = None;
        for threads in [1usize, 4] {
            let path = tmp(&format!("bytes_{shards}s_{threads}t"));
            let out = cluster_run(shards, threads, Some(path.clone()));
            let b = std::fs::read(&path).expect("trace file written");
            let _ = std::fs::remove_file(&path);
            assert_eq!(out.report.completed, 64);
            assert!(!b.is_empty(), "trace must not be empty");
            match &bytes {
                None => bytes = Some(b),
                Some(prev) => assert_eq!(
                    prev, &b,
                    "{shards} shards: {threads} engine threads changed the trace bytes"
                ),
            }
        }
    }
}

#[test]
fn trace_covers_the_lifecycle_in_commit_order() {
    let path = tmp("lifecycle");
    let out = cluster_run(4, 1, Some(path.clone()));
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(out.report.completed, 64);
    for ev in [
        "\"ev\":\"arrival\"",
        "\"ev\":\"route\"",
        "\"ev\":\"dispatch\"",
        "\"ev\":\"decision\"",
        "\"ev\":\"complete\"",
    ] {
        assert!(text.contains(ev), "trace must contain {ev}");
    }
    assert_eq!(
        text.matches("\"ev\":\"complete\"").count(),
        64,
        "every completion must be traced"
    );
    // the full invariant engine replays the trace clean: schema, strict
    // (t, seq) commit order, lifecycle legality, conservation
    let rep = replay_str(&text);
    assert!(rep.ok(), "replay violations: {:#?}", rep.violations);
    assert_eq!(rep.seq_gaps, 0, "the sink must not drop records");
    assert_eq!(rep.completed, 64, "replay must recount every completion");
    assert_eq!(rep.non_terminal, 0, "every offered task must reach a terminal state");
}

#[test]
fn oom_and_recovery_paths_are_traced() {
    let zoo = ModelZoo::load();
    let trace = trace_60(&zoo, 1);
    let path = tmp("oom");
    let mut c = CarmaConfig {
        policy: PolicyKind::RoundRobin,
        estimator: EstimatorKind::None,
        ..Default::default()
    };
    c.smact_cap = None; // blind collocation: OOMs are guaranteed
    c.obs.trace_out = Some(path.clone());
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    let out = run_trace(c, est, &trace, "rr-blind-obs");
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert!(out.report.oom_crashes > 0, "the blind run must OOM");
    assert!(text.contains("\"ev\":\"oom\""), "OOMs must be traced");
    assert!(text.contains("\"ev\":\"recovery\""), "recovery must be traced");
}

#[test]
fn fault_records_interleave_with_the_lifecycle_in_commit_order() {
    // DESIGN.md §15: strikes, detections, health transitions, relaunches
    // and repairs are ordinary engine events — they appear in the ONE
    // (t, seq) stream, interleaved with dispatches and completions, not
    // in a side channel
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 64, 8, 13);
    let path = tmp("faults");
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
    c.coordinator.shards = 2;
    c.faults.profile = FaultProfile::Mixed;
    c.faults.rate_per_hour = 60.0;
    c.faults.seed = 3;
    c.obs.trace_out = Some(path.clone());
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    let out = run_trace(c, est, &trace, "chaos-obs");
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    let res = &out.report.resilience;
    assert!(res.faults_gpu + res.faults_server + res.faults_link > 0);
    assert!(
        res.interruptions_gpu + res.interruptions_server > 0,
        "strikes at 60/h on a saturated cluster must kill residents"
    );
    for ev in [
        "\"ev\":\"fault\"",
        "\"ev\":\"quarantine\"",
        "\"ev\":\"detect\"",
        "\"ev\":\"relaunch\"",
        "\"ev\":\"repair\"",
        // the lifecycle keeps flowing around the chaos
        "\"ev\":\"dispatch\"",
        "\"ev\":\"complete\"",
    ] {
        assert!(text.contains(ev), "fault trace must contain {ev}");
    }
    // the interleaved stream replays clean through the invariant engine:
    // strict (t, seq) order across fault records, no dispatch ever lands
    // on quarantined hardware, and every task still terminates
    let rep = replay_str(&text);
    assert!(rep.ok(), "replay violations: {:#?}", rep.violations);
    assert_eq!(rep.seq_gaps, 0);
    assert_eq!(rep.non_terminal, 0, "fault schedules must not leak non-terminal tasks");
    assert_eq!(rep.terminal(), rep.offered, "conservation under chaos");
}

fn service_run(threads: usize, trace_out: Option<String>) -> RunOutcome {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(1, 4, 40.0);
    c.coordinator.shards = 2;
    c.engine.threads = threads;
    c.service.arrivals = Some(ArrivalKind::Burst);
    c.service.rate_per_min = 60.0;
    c.service.duration_s = 300.0;
    c.service.queue_cap = 2;
    c.obs.trace_out = trace_out;
    // stream-mode recorder: the long-run memory configuration
    c.obs.timeline = TimelineMode::Off;
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    run_service(c, est, "svc-obs")
}

#[test]
fn shed_path_is_traced_and_thread_invariant_in_stream_mode() {
    let mut bytes: Option<Vec<u8>> = None;
    for threads in [1usize, 4] {
        let path = tmp(&format!("svc_{threads}t"));
        let out = service_run(threads, Some(path.clone()));
        let b = std::fs::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(out.recorder.stream(), "service + timeline off must stream");
        assert!(out.recorder.tasks.is_empty(), "no per-task vector in stream mode");
        assert!(out.recorder.shed_total > 0, "saturation must shed");
        let text = std::str::from_utf8(&b).unwrap();
        assert!(text.contains("\"ev\":\"shed\""), "sheds must be traced");
        // the report still carries every aggregate section
        let j = out.report.to_json();
        assert!(j.get("service").is_some());
        assert!(j.get("placement_decisions").is_some());
        match &bytes {
            None => bytes = Some(b),
            Some(prev) => assert_eq!(
                prev, &b,
                "open-loop trace bytes changed with {threads} engine threads"
            ),
        }
    }
}

#[test]
fn profile_is_structurally_excluded_from_compared_artifacts() {
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 24, 8, 5);
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
    c.coordinator.shards = 4;
    c.engine.threads = 4;
    c.obs.profile = true;
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    let out = run_trace(c, est, &trace, "profiled");

    let profile = out.profile.expect("--profile must populate RunOutcome::profile");
    let ptxt = profile.to_string_pretty();
    for key in [
        "frontier_drain_s",
        "snapshot_build_s",
        "speculative_plan_s",
        "serial_commit_s",
        "wall_s",
        "events_per_sec",
    ] {
        assert!(ptxt.contains(key), "profile must report {key}");
    }
    // the byte-compared artifact must carry NO wall-clock key — determinism
    // by structure, not by discipline
    let report = out.report.to_json().to_string_pretty();
    for key in [
        "frontier_drain_s",
        "snapshot_build_s",
        "speculative_plan_s",
        "serial_commit_s",
        "wall_s",
        "events_per_sec",
    ] {
        assert!(!report.contains(key), "report leaked timing key {key}");
    }
    // and the profiler is off unless asked for
    let out2 = cluster_run(1, 1, None);
    assert!(out2.profile.is_none(), "profile must default to off");
}

#[test]
fn sketch_percentiles_stay_within_documented_error() {
    // deterministic LCG sample spanning 0.01..~1000s, vs exact
    // nearest-rank order statistics: ±5% relative error documented, 6%
    // asserted (bucket-midpoint slack)
    let mut h = LogHistogram::default();
    let mut vals = Vec::new();
    let mut x = 12345u64;
    for _ in 0..5000 {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let v = ((x >> 33) % 100_000) as f64 / 100.0 + 0.01;
        h.record(v);
        vals.push(v);
    }
    vals.sort_by(f64::total_cmp);
    for p in [50.0, 90.0, 99.0, 99.9] {
        let rank = ((p / 100.0) * (vals.len() - 1) as f64).round() as usize;
        let exact = vals[rank];
        let approx = h.percentile(p);
        assert!(
            (approx - exact).abs() <= exact * 0.06 + 1e-9,
            "p{p}: sketch {approx} vs exact {exact}"
        );
    }
}
