//! Sharded-coordinator integration invariants (DESIGN.md §9): the shard
//! sweep strictly improves makespan and queueing delay, fixed-seed sharded
//! runs are bit-identical, fairness holds under bursty arrivals (bounded
//! queueing delay, FIFO within a shard), and every assignment strategy
//! completes its trace.

use carma::config::schema::{
    CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind, ShardAssign,
};
use carma::coordinator::carma::{run_trace, RunOutcome};
use carma::estimators;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::{trace_cluster, TraceSpec};

fn sharded_cfg(servers: usize, gpus: usize, shards: usize) -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(servers, gpus, 40.0);
    c.coordinator.shards = shards;
    c
}

fn run(c: CarmaConfig, trace: &TraceSpec) -> RunOutcome {
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    run_trace(c, est, trace, "test")
}

#[test]
fn shard_scale_strictly_improves_makespan_and_wait() {
    // the PR's acceptance criterion, on the exact shard_scale setup: on the
    // 32-GPU / 256-task trace, makespan and mean queueing delay strictly
    // improve from 1 → 4 shards. Queueing delay is mapping-pipeline-bound,
    // so it must fall monotonically across 1 → 2 → 4; makespan must beat
    // the serial baseline at every shard count (at high K the GPUs
    // themselves, not the coordinator, bound the makespan).
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 256, 32, 42);
    let serial = run(sharded_cfg(8, 4, 1), &trace);
    assert_eq!(serial.report.completed, 256, "1 shard");
    let mut prev_wait = serial.report.avg_waiting_min;
    for shards in [2usize, 4] {
        let out = run(sharded_cfg(8, 4, shards), &trace);
        assert_eq!(out.report.completed, 256, "{shards} shard(s)");
        assert!(
            out.report.trace_total_min < serial.report.trace_total_min,
            "makespan must strictly improve 1→{shards} shards: {:.1}m !< {:.1}m",
            out.report.trace_total_min,
            serial.report.trace_total_min
        );
        assert!(
            out.report.avg_waiting_min < prev_wait,
            "queueing delay must strictly fall at {shards} shards: {:.1}m !< {:.1}m",
            out.report.avg_waiting_min,
            prev_wait
        );
        prev_wait = out.report.avg_waiting_min;
    }
}

#[test]
fn sharded_smoke_is_bit_identical_across_runs() {
    // the ci.sh determinism smoke in test form: same seed + 4 shards twice
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 256, 32, 7);
    let a = run(sharded_cfg(8, 4, 4), &trace);
    let b = run(sharded_cfg(8, 4, 4), &trace);
    assert_eq!(a.report.completed, 256);
    assert_eq!(a.report.trace_total_min.to_bits(), b.report.trace_total_min.to_bits());
    assert_eq!(a.report.avg_waiting_min.to_bits(), b.report.avg_waiting_min.to_bits());
    assert_eq!(a.report.energy_mj.to_bits(), b.report.energy_mj.to_bits());
    assert_eq!(a.report.oom_crashes, b.report.oom_crashes);
    assert_eq!(a.events, b.events, "event streams must be identical");
    for (sa, sb) in a.report.per_shard.iter().zip(&b.report.per_shard) {
        assert_eq!(sa.tasks, sb.tasks);
        assert_eq!(sa.decisions, sb.decisions);
        assert_eq!(sa.mean_wait_min.to_bits(), sb.mean_wait_min.to_bits());
    }
}

#[test]
fn fairness_bounded_delay_and_fifo_within_shard() {
    // bursty arrivals + 4 shards: no task may starve. Concretely: (a) every
    // task completes, (b) within a shard, first dispatches follow arrival
    // order (per-shard FIFO — recovery never reorders here: oracle+margin
    // produces no OOMs), (c) queueing delay stays bounded — no task waits
    // wildly beyond the pack
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 96, 16, 9);
    let out = run(sharded_cfg(4, 4, 4), &trace);
    assert_eq!(out.report.completed, 96);
    assert_eq!(out.report.oom_crashes, 0, "fairness check assumes no recovery traffic");

    for shard in 0..4 {
        // tasks of this shard in admission (= arrival-event) order: arrival
        // events pop by (time, submission seq), and arrivals are scheduled
        // in id order, so (arrival_s, id) reconstructs the shard's queue
        let mut mine: Vec<(usize, &carma::metrics::recorder::TaskTiming)> = out
            .recorder
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.assigned_shard == Some(shard))
            .collect();
        assert!(!mine.is_empty(), "round-robin routing must use every shard");
        mine.sort_by(|(ia, a), (ib, b)| {
            a.arrival_s.total_cmp(&b.arrival_s).then_with(|| ia.cmp(ib))
        });
        let dispatches: Vec<f64> = mine.iter().map(|(_, t)| t.dispatched_s.unwrap()).collect();
        assert!(
            dispatches.windows(2).all(|w| w[0] <= w[1]),
            "shard {shard} violated FIFO: dispatch times {dispatches:?}"
        );
    }

    // bounded delay: the longest wait may not dwarf the mean — linear queue
    // drain (one 60 s window per position) keeps max/mean small; starvation
    // would blow it up
    let waits: Vec<f64> = out
        .recorder
        .tasks
        .iter()
        .map(|t| t.dispatched_s.unwrap() - t.arrival_s)
        .collect();
    let mean = waits.iter().sum::<f64>() / waits.len() as f64;
    let max = waits.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        max <= 4.0 * mean + 900.0,
        "unbounded queueing delay: max {max:.0}s vs mean {mean:.0}s"
    );
}

#[test]
fn every_assignment_strategy_completes_and_spreads() {
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 64, 8, 3);
    for assign in [ShardAssign::RoundRobin, ShardAssign::LeastLoaded, ShardAssign::Locality] {
        let mut c = sharded_cfg(2, 4, 4);
        c.coordinator.assign = assign;
        let out = run(c, &trace);
        assert_eq!(out.report.completed, 64, "{assign:?}");
        let used = out.report.per_shard.iter().filter(|s| s.tasks > 0).count();
        assert!(used >= 2, "{assign:?} kept all work on one shard");
        assert_eq!(
            out.report.per_shard.iter().map(|s| s.tasks).sum::<usize>(),
            64,
            "{assign:?}: every task routed exactly once"
        );
    }
}

#[test]
fn default_config_stays_serial() {
    // one shard is the paper's pipeline: same completion + per-shard report
    // degenerates to a single entry owning every task and decision
    let zoo = ModelZoo::load();
    let trace = trace_cluster(&zoo, 48, 8, 11);
    let c = sharded_cfg(2, 4, 1);
    assert_eq!(c.coordinator.shards, 1);
    let out = run(c, &trace);
    assert_eq!(out.report.completed, 48);
    assert_eq!(out.report.per_shard.len(), 1);
    assert_eq!(out.report.per_shard[0].tasks, 48);
    assert!(out.report.per_shard[0].decisions >= 48);
}