//! Integration tests: whole-simulation invariants across policies, traces
//! and failure injection, plus property tests over random mini-traces.

use carma::config::schema::{CarmaConfig, CollocationMode, EstimatorKind, PolicyKind};
use carma::coordinator::carma::run_trace;
use carma::estimators;
use carma::testkit;
use carma::util::rng::Rng;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::task::TaskSpec;
use carma::workload::trace::{trace_60, trace_90, TraceSpec};

fn cfg(policy: PolicyKind, colloc: CollocationMode, est: EstimatorKind) -> CarmaConfig {
    CarmaConfig {
        policy,
        colloc,
        estimator: est,
        ..Default::default()
    }
}

fn run(c: CarmaConfig, trace: &TraceSpec) -> carma::metrics::report::RunReport {
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    run_trace(c, est, trace, "test").report
}

#[test]
fn every_policy_completes_both_traces() {
    let zoo = ModelZoo::load();
    for trace in [trace_90(&zoo, 7), trace_60(&zoo, 7)] {
        for policy in [
            PolicyKind::Exclusive,
            PolicyKind::RoundRobin,
            PolicyKind::Magm,
            PolicyKind::Lug,
            PolicyKind::Mug,
        ] {
            let r = run(cfg(policy, CollocationMode::Mps, EstimatorKind::Oracle), &trace);
            assert_eq!(
                r.completed, r.total_tasks,
                "{policy:?} on {} left tasks unfinished",
                trace.name
            );
            assert!(r.trace_total_min > 0.0);
            assert!(r.energy_mj > 0.0);
        }
    }
}

#[test]
fn every_collocation_mode_completes() {
    let zoo = ModelZoo::load();
    let trace = trace_90(&zoo, 11);
    for colloc in [CollocationMode::Streams, CollocationMode::Mps] {
        let r = run(cfg(PolicyKind::Magm, colloc, EstimatorKind::Oracle), &trace);
        assert_eq!(r.completed, 90, "{colloc:?}");
    }
    // MIG with 2 half instances per GPU
    let mut c = cfg(PolicyKind::Magm, CollocationMode::Mig, EstimatorKind::Oracle);
    c.cluster.servers[0].mig_slices = vec![0.75, 0.25];
    let r = run(c, &trace);
    assert_eq!(r.completed, 90, "MIG");
    assert_eq!(r.oom_crashes, 0, "MIG instances are isolated + demand-checked");
}

#[test]
fn timing_identities_hold() {
    let zoo = ModelZoo::load();
    let trace = trace_60(&zoo, 3);
    let r = run(cfg(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::Oracle), &trace);
    // JCT = waiting + execution (for completed tasks, averages add)
    assert!(
        (r.avg_jct_min - (r.avg_waiting_min + r.avg_execution_min)).abs() < 0.51,
        "JCT {} != wait {} + exec {}",
        r.avg_jct_min,
        r.avg_waiting_min,
        r.avg_execution_min
    );
    // the observation window bounds waiting from below
    assert!(r.avg_waiting_min >= 1.0);
    // execution can't beat the exclusive work time
    let min_work: f64 = trace.tasks.iter().map(|t| t.work_s).sum::<f64>() / 60.0 / 60.0;
    assert!(r.avg_execution_min >= min_work / 60.0);
}

#[test]
fn recovery_restores_every_crash() {
    let zoo = ModelZoo::load();
    let trace = trace_60(&zoo, 13);
    // worst case: blind RR, no preconditions -> many OOMs, all recovered
    let mut c = cfg(PolicyKind::RoundRobin, CollocationMode::Mps, EstimatorKind::None);
    c.smact_cap = None;
    let r = run(c, &trace);
    assert!(r.oom_crashes > 0, "blind RR should crash tasks");
    assert_eq!(r.completed, 60, "recovery must complete them all");
}

#[test]
fn estimator_reduces_oom_vs_blind() {
    let zoo = ModelZoo::load();
    let trace = trace_60(&zoo, 42);
    let blind = run(
        {
            let mut c = cfg(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::None);
            c.smact_cap = None;
            c
        },
        &trace,
    );
    let oracle = run(cfg(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::Oracle), &trace);
    assert!(
        oracle.oom_crashes < blind.oom_crashes,
        "oracle {} !< blind {}",
        oracle.oom_crashes,
        blind.oom_crashes
    );
}

#[test]
fn collocation_beats_exclusive_on_both_traces() {
    let zoo = ModelZoo::load();
    for (trace, min_gain) in [(trace_90(&zoo, 42), 0.15), (trace_60(&zoo, 42), 0.10)] {
        let excl = run(
            cfg(PolicyKind::Exclusive, CollocationMode::Mps, EstimatorKind::None),
            &trace,
        );
        let mut c = cfg(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::Oracle);
        c.safety_margin_gb = 2.0;
        let magm = run(c, &trace);
        assert!(
            magm.trace_total_min < excl.trace_total_min * (1.0 - min_gain),
            "{}: MAGM {:.0}m vs Exclusive {:.0}m",
            trace.name,
            magm.trace_total_min,
            excl.trace_total_min
        );
        assert!(magm.mean_smact > excl.mean_smact, "{}", trace.name);
    }
}

#[test]
fn smact_cap_lowers_utilization_ceiling() {
    let zoo = ModelZoo::load();
    let trace = trace_90(&zoo, 5);
    let tight = run(
        {
            let mut c = cfg(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::Oracle);
            c.smact_cap = Some(0.40);
            c
        },
        &trace,
    );
    let loose = run(
        {
            let mut c = cfg(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::Oracle);
            c.smact_cap = Some(0.95);
            c
        },
        &trace,
    );
    assert!(
        tight.mean_smact < loose.mean_smact + 1e-9,
        "tight {} vs loose {}",
        tight.mean_smact,
        loose.mean_smact
    );
}

// -- property tests over random mini-traces ---------------------------------

fn random_trace(rng: &mut Rng, size: usize) -> TraceSpec {
    let zoo = ModelZoo::load();
    let n = 3 + size % 20;
    let mut t = 0.0;
    let tasks = (0..n)
        .map(|id| {
            let e = zoo.entries[rng.range_usize(0, zoo.entries.len())].clone();
            let epochs = *rng.choice(&e.epochs);
            t += rng.exponential(120.0);
            TaskSpec::from_zoo(id, &e, epochs, t)
        })
        .collect();
    TraceSpec {
        name: format!("prop-{n}"),
        tasks,
    }
}

#[test]
fn prop_all_tasks_complete_under_any_policy() {
    let gen = |rng: &mut Rng, size: usize| {
        let trace = random_trace(rng, size);
        let policy = *rng.choice(&[
            PolicyKind::Exclusive,
            PolicyKind::RoundRobin,
            PolicyKind::Magm,
            PolicyKind::Lug,
            PolicyKind::Mug,
        ]);
        let est = *rng.choice(&[EstimatorKind::None, EstimatorKind::Oracle, EstimatorKind::Horus]);
        let colloc = *rng.choice(&[CollocationMode::Streams, CollocationMode::Mps]);
        let smact_cap = if rng.bool(0.5) { Some(rng.range_f64(0.3, 0.95)) } else { None };
        (trace.tasks.len(), policy, est, colloc, smact_cap, rng.next_u64())
    };
    testkit::forall_cfg(
        &testkit::Config {
            cases: 24,
            ..Default::default()
        },
        &gen,
        |&(n, policy, est, colloc, smact_cap, seed)| {
            let mut rng = Rng::new(seed);
            let trace = random_trace(&mut rng, n);
            let mut c = cfg(policy, colloc, est);
            c.smact_cap = smact_cap;
            let r = run(c, &trace);
            if r.completed != r.total_tasks {
                return Err(format!(
                    "{policy:?}/{est:?}/{colloc:?}: {}/{} completed",
                    r.completed, r.total_tasks
                ));
            }
            if r.avg_waiting_min < 0.0 || r.avg_execution_min < 0.0 {
                return Err("negative timing".into());
            }
            if r.mean_smact < 0.0 || r.mean_smact > 1.0 {
                return Err(format!("smact {} out of range", r.mean_smact));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_oracle_with_margin_never_underestimates_admission() {
    // with the oracle + 2GB margin, OOMs can only come from extreme
    // fragmentation; over random traces they must be rare (≈0)
    let gen = |rng: &mut Rng, size: usize| (size, rng.next_u64());
    testkit::forall_cfg(
        &testkit::Config {
            cases: 12,
            ..Default::default()
        },
        &gen,
        |&(size, seed)| {
            let mut rng = Rng::new(seed);
            let trace = random_trace(&mut rng, size);
            let mut c = cfg(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::Oracle);
            c.safety_margin_gb = 2.0;
            let r = run(c, &trace);
            if r.oom_crashes > 0 {
                return Err(format!("{} OOMs under oracle+margin", r.oom_crashes));
            }
            Ok(())
        },
    );
}
