//! Open-loop service mode (DESIGN.md §13): steady-state property tests.
//!
//! The invariants under test:
//!
//! * **Determinism** — the full results JSON is byte-identical across
//!   engine threads {1, 4} within every (arrival process, shards {1, 4})
//!   configuration, and the arrival stream itself is a pure function of
//!   the seed (shards/threads never perturb it).
//! * **Shed monotonicity** — raising the offered rate never lowers the
//!   shed count (same process, seed, cap and duration).
//! * **Terminal sheds** — no task is ever both shed and dispatched, and
//!   every offered task ends terminal (completed, failed, or shed).
//! * **Always-present steady-state metrics** — the `service` JSON section
//!   and its queueing-delay percentile keys exist in every report, open-
//!   or closed-loop, populated or empty.

use carma::config::schema::{
    ArrivalKind, CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind,
};
use carma::coordinator::carma::{run_service, run_trace, RunOutcome};
use carma::estimators;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::trace::trace_60;

const KINDS: &[ArrivalKind] = &[ArrivalKind::Poisson, ArrivalKind::Diurnal, ArrivalKind::Burst];

fn service_cfg(
    kind: ArrivalKind,
    rate_per_min: f64,
    duration_s: f64,
    queue_cap: usize,
    shards: usize,
    threads: usize,
) -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
    c.coordinator.shards = shards;
    c.engine.threads = threads;
    c.service.arrivals = Some(kind);
    c.service.rate_per_min = rate_per_min;
    c.service.duration_s = duration_s;
    c.service.queue_cap = queue_cap;
    c.service.seed = 42;
    c
}

fn run(c: CarmaConfig) -> RunOutcome {
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    run_service(c, est, "svc")
}

#[test]
fn results_json_byte_identical_across_threads_and_stable_across_shards() {
    // saturating rate on a small cluster so the shed path is exercised in
    // every cell of the sweep — determinism must cover it too
    for &kind in KINDS {
        for shards in [1usize, 4] {
            let mut json_bits: Option<String> = None;
            let mut offered: Option<usize> = None;
            for threads in [1usize, 4] {
                let out = run(service_cfg(kind, 40.0, 420.0, 2, shards, threads));
                let j = out.report.to_json().to_string_pretty();
                match &json_bits {
                    None => json_bits = Some(j),
                    Some(prev) => assert_eq!(
                        *prev, j,
                        "{kind:?}/{shards} shards: {threads} threads changed the JSON"
                    ),
                }
                // the arrival stream is a function of the seed alone: the
                // offered count must not depend on shards OR threads
                match offered {
                    None => offered = Some(out.report.service.offered),
                    Some(n) => assert_eq!(n, out.report.service.offered),
                }
            }
        }
    }
}

#[test]
fn arrival_stream_is_independent_of_shard_count() {
    // per-shard queueing differs across shard counts (so full JSON cannot
    // match), but the offered stream — count and shed-accounting total —
    // is generator-only state and must be identical
    for &kind in KINDS {
        let a = run(service_cfg(kind, 40.0, 420.0, 2, 1, 1));
        let b = run(service_cfg(kind, 40.0, 420.0, 2, 4, 1));
        assert_eq!(a.report.service.offered, b.report.service.offered, "{kind:?}");
        let totals = |o: &RunOutcome| {
            o.report.completed + o.recorder.failed_total as usize + o.report.service.shed as usize
        };
        assert_eq!(totals(&a), a.report.service.offered, "{kind:?}");
        assert_eq!(totals(&b), b.report.service.offered, "{kind:?}");
    }
}

#[test]
fn shed_count_is_monotone_in_offered_rate() {
    for &kind in KINDS {
        let mut prev_shed: u64 = 0;
        for rate in [2.0, 10.0, 40.0, 120.0] {
            let out = run(service_cfg(kind, rate, 420.0, 2, 1, 1));
            let shed = out.report.service.shed;
            assert!(
                shed >= prev_shed,
                "{kind:?}: shed count dropped from {prev_shed} to {shed} \
                 when the rate rose to {rate}/min"
            );
            prev_shed = shed;
        }
        assert!(prev_shed > 0, "{kind:?}: the top rate must shed");
    }
}

#[test]
fn no_task_is_both_shed_and_dispatched() {
    for &kind in KINDS {
        let out = run(service_cfg(kind, 60.0, 420.0, 2, 4, 1));
        assert!(out.report.service.shed > 0, "{kind:?}: saturation must shed");
        let mut sheds = 0u64;
        for t in &out.recorder.tasks {
            if t.shed_s.is_some() {
                sheds += 1;
                assert!(t.dispatched_s.is_none(), "{kind:?}: shed task dispatched");
                assert!(t.completed_s.is_none(), "{kind:?}: shed task completed");
            }
        }
        assert_eq!(sheds, out.report.service.shed, "{kind:?}: shed ledger drift");
        assert!(
            out.report.service.shed_at_door <= out.report.service.shed,
            "{kind:?}: door sheds must be a subset of all sheds"
        );
    }
}

#[test]
fn queue_delay_percentiles_always_present_in_json() {
    let keys = [
        "queue_delay_p50_s",
        "queue_delay_p99_s",
        "queue_delay_p999_s",
        "rejection_rate",
        "open_loop",
    ];
    // open-loop run
    let open = run(service_cfg(ArrivalKind::Poisson, 6.0, 420.0, 8, 1, 1));
    let open_json = open.report.to_json().to_string_pretty();
    for k in keys {
        assert!(open_json.contains(k), "open-loop JSON lacks '{k}'");
    }
    // closed-loop run: the service section is zeroed but still present,
    // with every percentile key populated (byte-diffability)
    let zoo = ModelZoo::load();
    let trace = trace_60(&zoo, 1);
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(1, 4, 40.0);
    let est = estimators::build(c.estimator, "artifacts").unwrap();
    let closed = run_trace(c, est, &trace, "closed");
    let closed_json = closed.report.to_json().to_string_pretty();
    for k in keys {
        assert!(closed_json.contains(k), "closed-loop JSON lacks '{k}'");
    }
    assert!(!closed.report.service.open_loop);
    assert_eq!(closed.report.service.shed, 0);
}

#[test]
fn windowed_utilization_populates_under_load() {
    let out = run(service_cfg(ArrivalKind::Burst, 30.0, 600.0, 8, 1, 1));
    let s = &out.report.service;
    assert!(s.util_windows > 0, "no utilization window ever closed");
    assert!(s.win_smact_peak >= s.win_smact_mean);
    assert!(s.win_mem_peak_gb >= s.win_mem_mean_gb);
    assert!(s.win_smact_peak > 0.0, "burst load must show up in the windows");
}
