//! carma — CLI entrypoint.
//!
//! ```text
//! carma repro <fig8|table4|...|all> [--artifacts DIR]
//! carma run   [--trace 60|90|N] [--policy magm] [--estimator gpumemnet]
//!             [--colloc mps] [--smact 0.8] [--min-free 5] [--margin 2]
//!             [--servers N] [--gpus-per-server G] [--power-cap W]
//!             [--shards K] [--shard-assign round-robin|least-loaded|locality]
//!             [--arrivals poisson|diurnal|burst] [--rate R] [--duration S]
//!             [--faults none|gpu|server|link|mixed] [--fault-rate R] [--fault-seed N]
//!             [--trace-out t.jsonl] [--explain-sample N] [--metrics-out m.prom]
//!             [--profile] [--timeline on|sparse|off]
//!             [--seed N] [--config carma.toml]
//! carma submit <script.carma> [--config carma.toml]   (parse + map one task)
//! carma zoo                                        (print the Table 3 zoo)
//! carma trace analyze <t.jsonl> [--window S] [--out PATH] [--format csv|json]
//! carma trace schema                               (print the record schema)
//! ```

use carma::cli;
use carma::config::schema::{
    ArrivalKind, CarmaConfig, CollocationMode, EstimatorKind, FabricProfile, FaultProfile,
    PolicyKind, ServerConfig, ShardAssign, TimelineMode,
};
use carma::coordinator::carma::{run_label, run_service, run_trace, RunOutcome};
use carma::estimators;
use carma::experiments;
use carma::metrics::report::RunReport;
use carma::obs::replay;
use carma::util::json;
use carma::workload::model_zoo::ModelZoo;
use carma::workload::submission;
use carma::workload::trace::{trace_60, trace_90, trace_cluster, trace_gang};

const VALUE_OPTS: &[&str] = &[
    "artifacts", "trace", "policy", "estimator", "colloc", "smact", "min-free", "margin",
    "servers", "gpus-per-server", "power-cap", "shards", "shard-assign", "engine-threads",
    "fabric-profile", "gang-hold-ttl", "fabric-aware-singletons", "delta-views",
    "seed", "config",
    "arrivals", "rate", "duration", "queue-cap",
    "faults", "fault-rate", "fault-seed",
    "trace-out", "explain-sample", "metrics-out", "timeline", "timeseries-out",
    "window", "out", "format",
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::parse(argv, VALUE_OPTS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("repro") => cmd_repro(&args),
        Some("run") => cmd_run(&args),
        Some("submit") => cmd_submit(&args),
        Some("zoo") => cmd_zoo(),
        Some("trace") => cmd_trace(&args),
        Some("help") | None => {
            usage();
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand '{other}'")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "CARMA — Collocation-Aware Resource Manager (paper reproduction)\n\n\
         USAGE:\n  carma repro <id|all> [--artifacts DIR]     regenerate a paper table/figure\n\
         \x20 carma run [options]                        run one configuration over a trace\n\
         \x20 carma submit <script> [--config FILE]      parse a submission script + map it\n\
         \x20 carma zoo                                  print the Table 3 model zoo\n\
         \x20 carma trace analyze <t.jsonl>              replay a --trace-out file: check\n\
         \x20   [--window S] [--out P] [--format csv|json]  every invariant, rebuild spans/\n\
         \x20                                            JCT accounting/percentiles/series\n\
         \x20                                            (exit 1 on any violation)\n\
         \x20 carma trace schema                         print the machine-readable trace\n\
         \x20                                            record schema (DESIGN.md §16)\n\n\
         RUN OPTIONS:\n  --trace 60|90|N    paper trace, or an N-task cluster-scaled trace\n\
         \x20                    (default: 60 on a single server, 8×GPUs tasks on a multi-server cluster)\n\
         \x20 --policy P         exclusive|rr|magm|lug|mug (default magm)\n\
         \x20 --estimator E      none|oracle|horus|faketensor|gpumemnet (default gpumemnet)\n\
         \x20 --colloc C         streams|mps|mig (default mps)\n\
         \x20 --smact X          SMACT precondition 0..1 (default 0.8; >=1 disables)\n\
         \x20 --min-free GB      memory precondition (default off)\n\
         \x20 --margin GB        safety margin on estimates (default 0)\n\
         \x20 --servers N        number of servers in the cluster (default 1)\n\
         \x20 --gpus-per-server G  GPUs per server (default 4)\n\
         \x20 --power-cap W      per-server power envelope in watts (default off)\n\
         \x20 --shards K         concurrent mapper shards (default 1 = serial paper pipeline)\n\
         \x20 --shard-assign S   round-robin|least-loaded|locality (default round-robin;\n\
         \x20                    locality routes by fabric home-server affinity)\n\
         \x20 --engine-threads T sim-engine worker threads (default 1 = serial; 0 = auto;\n\
         \x20                    results are byte-identical at any thread count)\n\
         \x20 --fabric-profile P nvlink-island|flat-pcie|dual-island interconnect model\n\
         \x20                    (default nvlink-island; see [fabric] in carma.toml)\n\
         \x20 --gang-hold-ttl S  gang partial-hold TTL in seconds (default 120)\n\
         \x20 --fabric-aware-singletons on|off\n\
         \x20                    rank server-local multi-GPU placements by island/fabric\n\
         \x20                    cost like gangs (default on; off = island-blind seed\n\
         \x20                    pipeline, byte-identical; DESIGN.md §12)\n\
         \x20 --delta-views on|off\n\
         \x20                    incremental per-server snapshot maintenance: a commit\n\
         \x20                    on server s rebuilds only views[s] (default on; off =\n\
         \x20                    full rebuild on any change, byte-identical; DESIGN.md §17)\n\
         \x20 --steal            bounded work stealing: an idle mapper that starves one\n\
         \x20                    observation window steals the longest sibling queue's\n\
         \x20                    tail (default off; deterministic, per-shard FIFO kept)\n\
         \x20 --arrivals A       poisson|diurnal|burst|off: open-loop service mode —\n\
         \x20                    arrivals stream from a seeded generator instead of a\n\
         \x20                    pre-materialized trace, with bounded admission + load\n\
         \x20                    shedding (default off; DESIGN.md §13)\n\
         \x20 --rate R           mean offered load in tasks/minute (default 6)\n\
         \x20 --duration S       arrival window in simulated seconds (default 3600;\n\
         \x20                    queued work still drains to completion after it closes)\n\
         \x20 --queue-cap N      per-shard bounded queue depth; arrivals routed to a\n\
         \x20                    full shard are shed (default 16)\n\
         \x20 --faults P         none|gpu|server|link|mixed: seeded fault injection —\n\
         \x20                    device loss, server power loss, link degradation with\n\
         \x20                    repair times; byte-deterministic at any shard/thread\n\
         \x20                    count (default none; DESIGN.md §15)\n\
         \x20 --fault-rate R     mean strikes per simulated hour (default 12)\n\
         \x20 --fault-seed N     fault-schedule seed, independent of --seed (default 1)\n\
         \x20 --json             print the run report as JSON only (determinism diffing)\n\
         \x20 --trace-out PATH   stream one JSONL record per lifecycle commit to PATH\n\
         \x20                    (deterministic (time, seq) order — byte-identical at\n\
         \x20                    any shard/thread count; DESIGN.md §14)\n\
         \x20 --explain-sample N emit every Nth committed placement decision as a\n\
         \x20                    `decision` trace record with full provenance (0 = off)\n\
         \x20 --metrics-out PATH write final counters/sketches as a Prometheus-style\n\
         \x20                    text exposition after the run\n\
         \x20 --timeseries-out PATH\n\
         \x20                    write the recorder's windowed utilization series as\n\
         \x20                    CSV (window_end_s,smact,mem_gb) — works in stream\n\
         \x20                    mode (--timeline off) too\n\
         \x20 --profile          per-phase engine wall-clock profile + worker-pool\n\
         \x20                    occupancy, printed to stderr (never in results JSON)\n\
         \x20 --timeline M       on|sparse|off per-GPU timeline retention (default\n\
         \x20                    sparse = one point per observation window; off also\n\
         \x20                    streams service-mode task aggregation, O(1) memory)\n\
         \x20 --seed N           trace + arrival-stream seed (default 42)\n\
         \x20 --config FILE      carma.toml overriding the defaults\n\
         \x20 --trace gangN      N-task mixed trace with distributed (gang) jobs\n\n\
         EXPERIMENTS: {}",
        experiments::ALL.join(", ")
    );
}

fn artifacts_dir(args: &cli::Args) -> String {
    args.opt("artifacts").unwrap_or("artifacts").to_string()
}

/// `--profile` output goes to stderr only: stdout may carry the `--json`
/// report that determinism smokes byte-compare, and wall-clock timings
/// must never leak into it (DESIGN.md §14).
fn print_profile(out: &RunOutcome) {
    if let Some(p) = &out.profile {
        eprintln!("profile: {}", p.to_string_pretty());
    }
}

fn cmd_repro(args: &cli::Args) -> Result<(), String> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    experiments::run(id, &artifacts_dir(args))
}

fn build_config(args: &cli::Args) -> Result<CarmaConfig, String> {
    let mut cfg = match args.opt("config") {
        Some(path) => CarmaConfig::from_file(path)?,
        None => CarmaConfig::default(),
    };
    if let Some(p) = args.opt("policy") {
        cfg.policy = PolicyKind::parse(p).ok_or_else(|| format!("unknown policy '{p}'"))?;
    }
    if let Some(e) = args.opt("estimator") {
        cfg.estimator = EstimatorKind::parse(e).ok_or_else(|| format!("unknown estimator '{e}'"))?;
    }
    if let Some(c) = args.opt("colloc") {
        cfg.colloc = CollocationMode::parse(c).ok_or_else(|| format!("unknown colloc '{c}'"))?;
    }
    if let Some(x) = args.opt_f64("smact").map_err(|e| e.to_string())? {
        cfg.smact_cap = if x >= 1.0 { None } else { Some(x) };
    }
    if let Some(x) = args.opt_f64("min-free").map_err(|e| e.to_string())? {
        cfg.min_free_gb = if x <= 0.0 { None } else { Some(x) };
    }
    if let Some(x) = args.opt_f64("margin").map_err(|e| e.to_string())? {
        cfg.safety_margin_gb = x;
    }
    let servers = args.opt_u64("servers").map_err(|e| e.to_string())?;
    let gpus_per_server = args.opt_u64("gpus-per-server").map_err(|e| e.to_string())?;
    if servers.is_some() || gpus_per_server.is_some() {
        // these flags rebuild a homogeneous cluster from server 0; silently
        // flattening a heterogeneous [cluster.serverK] config would run a
        // different cluster than the user configured
        if cfg.cluster.servers.windows(2).any(|w| w[0] != w[1]) {
            return Err(
                "--servers/--gpus-per-server would discard the config file's \
                 heterogeneous [cluster.serverK] layout; edit the TOML instead"
                    .into(),
            );
        }
        let base = cfg
            .cluster
            .servers
            .first()
            .cloned()
            .unwrap_or_else(ServerConfig::default);
        // same ranges the TOML path enforces — an absurd count must be a
        // config error, not an allocation abort
        let n = servers.unwrap_or(cfg.cluster.servers.len() as u64) as usize;
        if !(1..=10_000).contains(&n) {
            return Err(format!("--servers must be in 1..=10000, got {n}"));
        }
        let g = gpus_per_server.map(|x| x as usize).unwrap_or(base.n_gpus);
        if !(1..=1024).contains(&g) {
            return Err(format!("--gpus-per-server must be in 1..=1024, got {g}"));
        }
        cfg.cluster.servers = vec![ServerConfig { n_gpus: g, ..base }; n];
    }
    if let Some(w) = args.opt_f64("power-cap").map_err(|e| e.to_string())? {
        cfg.cluster.power_cap_w = if w <= 0.0 { None } else { Some(w) };
    }
    if let Some(k) = args.opt_u64("shards").map_err(|e| e.to_string())? {
        // range (1..=256) is enforced by cfg.validate() below
        cfg.coordinator.shards = k as usize;
    }
    if let Some(s) = args.opt("shard-assign") {
        cfg.coordinator.assign =
            ShardAssign::parse(s).ok_or_else(|| format!("unknown shard-assign '{s}'"))?;
    }
    if let Some(t) = args.opt_u64("engine-threads").map_err(|e| e.to_string())? {
        // range (0..=64, 0 = auto) is enforced by cfg.validate() below
        cfg.engine.threads = t as usize;
    }
    if let Some(p) = args.opt("fabric-profile") {
        cfg.fabric.profile =
            FabricProfile::parse(p).ok_or_else(|| format!("unknown fabric profile '{p}'"))?;
    }
    if let Some(t) = args.opt_f64("gang-hold-ttl").map_err(|e| e.to_string())? {
        // positivity is enforced by cfg.validate() below
        cfg.gang.hold_ttl_s = t;
    }
    if let Some(v) = args.opt("fabric-aware-singletons") {
        cfg.placement.fabric_aware_singletons = match v.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" => true,
            // off byte-reproduces the island-blind seed pipeline (§12)
            "off" | "false" | "0" => false,
            other => {
                return Err(format!(
                    "--fabric-aware-singletons expects on|off, got '{other}'"
                ))
            }
        };
    }
    if let Some(v) = args.opt("delta-views") {
        cfg.engine.delta_views = match v.to_ascii_lowercase().as_str() {
            "on" | "true" | "1" => true,
            // off rebuilds every ServerView on any state change (the PR-3
            // global-invalidation pipeline) — byte-identical, just slower
            "off" | "false" | "0" => false,
            other => return Err(format!("--delta-views expects on|off, got '{other}'")),
        };
    }
    if args.flag("steal") {
        cfg.coordinator.steal = true;
    }
    if let Some(a) = args.opt("arrivals") {
        cfg.service.arrivals = if a.eq_ignore_ascii_case("off") {
            None
        } else {
            Some(ArrivalKind::parse(a).ok_or_else(|| {
                format!("unknown arrival process '{a}' (poisson|diurnal|burst|off)")
            })?)
        };
    }
    if let Some(r) = args.opt_f64("rate").map_err(|e| e.to_string())? {
        // positivity is enforced by cfg.validate() below
        cfg.service.rate_per_min = r;
    }
    if let Some(d) = args.opt_f64("duration").map_err(|e| e.to_string())? {
        cfg.service.duration_s = d;
    }
    if let Some(c) = args.opt_u64("queue-cap").map_err(|e| e.to_string())? {
        // range (1..=1000000) is enforced by cfg.validate() below
        cfg.service.queue_cap = c as usize;
    }
    if let Some(f) = args.opt("faults") {
        cfg.faults.profile = FaultProfile::parse(f)
            .ok_or_else(|| format!("unknown fault profile '{f}' (none|gpu|server|link|mixed)"))?;
    }
    if let Some(r) = args.opt_f64("fault-rate").map_err(|e| e.to_string())? {
        // range (0..=100000) is enforced by cfg.validate() below
        cfg.faults.rate_per_hour = r;
    }
    if let Some(s) = args.opt_u64("fault-seed").map_err(|e| e.to_string())? {
        cfg.faults.seed = s;
    }
    if let Some(p) = args.opt("trace-out") {
        cfg.obs.trace_out = if p.is_empty() { None } else { Some(p.to_string()) };
    }
    if let Some(n) = args.opt_u64("explain-sample").map_err(|e| e.to_string())? {
        cfg.obs.explain_sample = n;
    }
    if let Some(p) = args.opt("metrics-out") {
        cfg.obs.metrics_out = if p.is_empty() { None } else { Some(p.to_string()) };
    }
    if let Some(p) = args.opt("timeseries-out") {
        cfg.obs.timeseries_out = if p.is_empty() { None } else { Some(p.to_string()) };
    }
    if args.flag("profile") {
        cfg.obs.profile = true;
    }
    if let Some(m) = args.opt("timeline") {
        cfg.obs.timeline = TimelineMode::parse(m)
            .ok_or_else(|| format!("unknown timeline mode '{m}' (on|sparse|off)"))?;
    }
    if let Some(s) = args.opt_u64("seed").map_err(|e| e.to_string())? {
        cfg.seed = s;
        // --seed seeds the whole run: trace generators AND the open-loop
        // arrival stream (a TOML [service] seed is still overridable here)
        cfg.service.seed = s;
    }
    cfg.artifacts_dir = artifacts_dir(args);
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &cli::Args) -> Result<(), String> {
    let cfg = build_config(args)?;
    if cfg.service.arrivals.is_some() {
        return cmd_run_service(args, cfg);
    }
    let zoo = ModelZoo::load();
    let total_gpus = cfg.cluster.total_gpus();
    let trace = match args.opt("trace") {
        Some("60") => trace_60(&zoo, cfg.seed),
        Some("90") => trace_90(&zoo, cfg.seed),
        Some(g) if g.starts_with("gang") => {
            // "gangN": N-task mixed trace where every 12th submission is a
            // distributed job twice as wide as the largest server
            // (DESIGN.md §11); bare "gang" sizes N as 6 tasks per GPU
            let n: usize = if g == "gang" {
                6 * total_gpus
            } else {
                g[4..]
                    .parse()
                    .map_err(|_| format!("unknown trace '{g}' (gang|gang<count>)"))?
            };
            if n == 0 {
                return Err("--trace gang task count must be >= 1".into());
            }
            if total_gpus < 2 {
                return Err("--trace gang needs a cluster of at least 2 GPUs".into());
            }
            let widest = cfg.cluster.servers.iter().map(|s| s.n_gpus).max().unwrap_or(1);
            let gang_gpus = (2 * widest).min(total_gpus).max(2);
            trace_gang(&zoo, n, total_gpus, gang_gpus, cfg.seed)
        }
        Some(n) => {
            let n: usize = n
                .parse()
                .map_err(|_| format!("unknown trace '{n}' (60|90|gangN|<task count>)"))?;
            if n == 0 {
                return Err("--trace task count must be >= 1".into());
            }
            trace_cluster(&zoo, n, total_gpus, cfg.seed)
        }
        // default: the paper trace on a single server, a proportionally
        // loaded trace (8 tasks per GPU) on a multi-server cluster
        None if cfg.cluster.n_servers() == 1 => trace_60(&zoo, cfg.seed),
        None => trace_cluster(&zoo, 8 * total_gpus, total_gpus, cfg.seed),
    };
    let est = estimators::build(cfg.estimator, &cfg.artifacts_dir)?;
    let label = run_label(&cfg, est.name());
    let shards = cfg.coordinator.shards;
    let json_only = args.flag("json");
    if json_only {
        // results JSON only — byte-diffable across engine thread counts
        // (ci.sh's threaded-determinism smoke relies on this)
        let out = run_trace(cfg, est, &trace, &label);
        let mut j = out.report.to_json();
        j.set("events", carma::util::json::num(out.events as f64));
        println!("{}", j.to_string_pretty());
        print_profile(&out);
        return Ok(());
    }
    println!(
        "running {} over {} ({} tasks, {} server(s) / {} GPUs, {} shard(s), {} engine thread(s), seed {})\n",
        label,
        trace.name,
        trace.tasks.len(),
        cfg.cluster.n_servers(),
        total_gpus,
        shards,
        cfg.engine.threads,
        cfg.seed
    );
    let out = run_trace(cfg, est, &trace, &label);
    println!("{}", RunReport::header());
    println!("{}", out.report.row());
    if shards > 1 {
        println!();
        for s in &out.report.per_shard {
            let stolen = if s.steals > 0 {
                format!(", {} stolen", s.steals)
            } else {
                String::new()
            };
            println!(
                "  shard {:>2}: {:>4} tasks, {:>4} decisions ({:.2}/min), mean wait {:.1} m{}",
                s.shard,
                s.tasks,
                s.decisions,
                s.decisions_per_min(out.report.trace_total_min),
                s.mean_wait_min,
                stolen,
            );
        }
    }
    let g = &out.report.gang;
    if g.gangs > 0 {
        println!(
            "\n  gang lane: {}/{} gangs completed, {} cross-server (max {} servers), \
             mean wait {:.1} m, frag excess {}, holds {}/{} expired, {} partial dispatches",
            g.completed,
            g.gangs,
            g.cross_server,
            g.max_servers_spanned,
            g.mean_wait_min,
            g.frag_excess,
            g.holds_expired,
            g.holds_placed,
            g.partial_dispatches,
        );
    }
    let p = &out.report.placement;
    if p.multi_gpu_singletons > 0 {
        println!(
            "\n  placement: {}/{} multi-GPU singletons island-local, \
             mean fabric cost {:.5} GB⁻¹·s (max {:.5})",
            p.single_island, p.multi_gpu_singletons, p.mean_fabric_cost, p.max_fabric_cost,
        );
    }
    println!("\n{} simulation events processed", out.events);
    print_profile(&out);
    Ok(())
}

/// Open-loop service mode (`--arrivals`, DESIGN.md §13): arrival-driven
/// scheduling with bounded admission and load shedding.
fn cmd_run_service(args: &cli::Args, cfg: CarmaConfig) -> Result<(), String> {
    if args.opt("trace").is_some() {
        return Err("--trace and --arrivals are mutually exclusive (open-loop \
                    service mode streams its own arrivals)"
            .into());
    }
    let kind = cfg
        .service
        .arrivals
        .ok_or("service mode needs --arrivals poisson|diurnal|burst")?;
    let est = estimators::build(cfg.estimator, &cfg.artifacts_dir)?;
    let label = format!("{}/{}", run_label(&cfg, est.name()), kind.name());
    let json_only = args.flag("json");
    if json_only {
        let out = run_service(cfg, est, &label);
        let mut j = out.report.to_json();
        j.set("events", carma::util::json::num(out.events as f64));
        println!("{}", j.to_string_pretty());
        print_profile(&out);
        return Ok(());
    }
    println!(
        "running {} open-loop ({} arrivals at {:.1}/min for {:.0}s, queue cap {}, \
         {} server(s) / {} GPUs, {} shard(s), {} engine thread(s), seed {})\n",
        label,
        kind.name(),
        cfg.service.rate_per_min,
        cfg.service.duration_s,
        cfg.service.queue_cap,
        cfg.cluster.n_servers(),
        cfg.cluster.total_gpus(),
        cfg.coordinator.shards,
        cfg.engine.threads,
        cfg.service.seed,
    );
    let out = run_service(cfg, est, &label);
    println!("{}", RunReport::header());
    println!("{}", out.report.row());
    let s = &out.report.service;
    println!(
        "\n  service: {} offered, {} shed ({} at the door), rejection rate {:.3}\n\
         \x20          queue delay p50 {:.1}s  p99 {:.1}s  p99.9 {:.1}s\n\
         \x20          {} util windows, SMACT mean {:.3} peak {:.3}, mem mean {:.1} GB peak {:.1} GB",
        s.offered,
        s.shed,
        s.shed_at_door,
        s.rejection_rate,
        s.queue_delay_p50_s,
        s.queue_delay_p99_s,
        s.queue_delay_p999_s,
        s.util_windows,
        s.win_smact_mean,
        s.win_smact_peak,
        s.win_mem_mean_gb,
        s.win_mem_peak_gb,
    );
    println!("\n{} simulation events processed", out.events);
    print_profile(&out);
    Ok(())
}

fn cmd_submit(args: &cli::Args) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .ok_or("usage: carma submit <script.carma>")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let t0 = std::time::Instant::now();
    let sub = submission::parse_script(&text).map_err(|e| e.to_string())?;
    let zoo = ModelZoo::load();
    let spec = submission::resolve(&zoo, &sub, 0, 0.0).map_err(|e| e.to_string())?;
    let parse_us = t0.elapsed().as_micros();

    let cfg = build_config(args)?;
    let est = estimators::build(cfg.estimator, &cfg.artifacts_dir)?;
    let t1 = std::time::Instant::now();
    let estimate = est.estimate_gb(&spec);
    let est_us = t1.elapsed().as_micros();

    println!("submission: {}", spec.label());
    println!("  parsed in {parse_us} µs (paper budget: 2.6 ms)");
    println!(
        "  {} estimate: {} (actual Table 3: {:.2} GB, {est_us} µs; paper budget: 16 ms)",
        est.name(),
        estimate
            .map(|e| format!("{e:.2} GB"))
            .unwrap_or_else(|| "n/a".into()),
        spec.mem_gb
    );
    println!(
        "  requires {} GPU(s), estimated work {:.1} min",
        spec.n_gpus,
        spec.work_s / 60.0
    );
    Ok(())
}

/// `carma trace <analyze|schema>` — the consume side of `--trace-out`
/// (DESIGN.md §16). `analyze` replays the trace through the invariant
/// engine, reconstructs spans/JCT accounting/percentiles/series, prints a
/// deterministic summary JSON, and exits non-zero if any invariant failed
/// (CI gates on that). `schema` prints the machine-readable record schema.
fn cmd_trace(args: &cli::Args) -> Result<(), String> {
    const USAGE: &str =
        "usage: carma trace analyze <trace.jsonl> [--window S] [--out PATH] \
         [--format csv|json] | carma trace schema";
    match args.positional.first().map(String::as_str) {
        Some("schema") => {
            println!("{}", replay::schema_json().to_string_pretty());
            Ok(())
        }
        Some("analyze") => {
            let path = args.positional.get(1).ok_or(USAGE)?;
            let window = args
                .opt_f64("window")
                .map_err(|e| e.to_string())?
                .unwrap_or(60.0);
            if window <= 0.0 {
                return Err("--window must be > 0".into());
            }
            let a = replay::analyze_file(path, window).map_err(|e| format!("{path}: {e}"))?;
            println!("{}", a.to_json().to_string_pretty());
            if let Some(out) = args.opt("out") {
                let format = args.opt("format").unwrap_or("csv");
                let text = match format {
                    // csv: just the derived time series (plotting-ready)
                    "csv" => a.series.to_csv(),
                    // json: the full reconstruction — summary, every task's
                    // spans + decomposition, and the windowed series
                    "json" => {
                        let full = json::obj(vec![
                            ("summary", a.to_json()),
                            (
                                "tasks",
                                json::arr(a.spans.tasks.iter().map(|t| t.to_json()).collect()),
                            ),
                            ("series", a.series.to_json()),
                        ]);
                        let mut s = full.to_string_pretty();
                        s.push('\n');
                        s
                    }
                    other => return Err(format!("unknown --format '{other}' (csv|json)")),
                };
                std::fs::write(out, text).map_err(|e| format!("{out}: {e}"))?;
            }
            let v = a.replay.violations.len();
            if v > 0 {
                return Err(format!(
                    "trace failed {v} invariant check(s) — see `violations` in the summary"
                ));
            }
            Ok(())
        }
        _ => Err(USAGE.into()),
    }
}

fn cmd_zoo() -> Result<(), String> {
    let zoo = ModelZoo::load();
    println!(
        "{:<20} {:<10} {:<7} {:>4} {:>5} {:>7} {:>7} {:>8} {:>6}",
        "model", "dataset", "class", "bs", "gpus", "ET(m)", "epochs", "mem(GB)", "SMACT"
    );
    for e in &zoo.entries {
        println!(
            "{:<20} {:<10} {:<7} {:>4} {:>5} {:>7.2} {:>7} {:>8.2} {:>6.2}",
            e.name,
            e.dataset,
            e.weight_class,
            e.batch_size,
            e.n_gpus,
            e.epoch_time_min,
            e.epochs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("/"),
            e.mem_gb,
            e.smact
        );
    }
    Ok(())
}
