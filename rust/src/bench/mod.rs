//! Criterion-style micro-bench harness (criterion is unavailable offline).
//!
//! Auto-tunes iteration count to a target measurement time, reports
//! mean/median/p95/stddev, and supports throughput annotation.  Used by
//! everything under `benches/`.

use std::time::{Duration, Instant};

use crate::util::json::{self, Json};
use crate::util::stats;

/// Where the perf-trajectory ledger lives (repo root when run via cargo);
/// override with the `CARMA_BENCH_JSON` env var.
pub fn bench_json_path() -> String {
    std::env::var("CARMA_BENCH_JSON").unwrap_or_else(|_| "BENCH_sim.json".to_string())
}

/// Benches run one measured iteration instead of their full sweep when
/// `CARMA_BENCH_SMOKE` is set (ci.sh uses this so the bench binaries cannot
/// bit-rot without anyone noticing).
pub fn smoke_mode() -> bool {
    std::env::var("CARMA_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Merge `rows` under `section` into the machine-readable bench ledger
/// (`BENCH_sim.json`), preserving every other section so the perf
/// trajectory accumulates across benches and PRs.
pub fn save_bench_section(section: &str, rows: Vec<Json>) {
    let path = bench_json_path();
    let mut doc = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .filter(|j| j.as_obj().is_some())
        .unwrap_or_else(|| json::obj(vec![]));
    doc.set(section, json::arr(rows));
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("  -> {path} [{section}]"),
        Err(e) => eprintln!("  !! could not write {path}: {e}"),
    }
}

pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }

    pub fn median_ns(&self) -> f64 {
        stats::median(&self.samples_ns)
    }

    pub fn p95_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 95.0)
    }

    pub fn stddev_ns(&self) -> f64 {
        stats::stddev(&self.samples_ns)
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>12}  median {:>12}  p95 {:>12}  (±{:>10}, {} samples × {} iters)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.median_ns()),
            fmt_ns(self.p95_ns()),
            fmt_ns(self.stddev_ns()),
            self.samples_ns.len(),
            self.iters_per_sample,
        );
    }

    pub fn report_throughput(&self, items: f64, unit: &str) {
        let per_sec = items / (self.mean_ns() * 1e-9);
        println!(
            "{:<44} {:>12}  ->  {:>12.1} {unit}/s",
            self.name,
            fmt_ns(self.mean_ns()),
            per_sec
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bencher {
    /// Target wall-clock per benchmark (warmup + measurement).
    pub target: Duration,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            target: Duration::from_millis(800),
            samples: 20,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            target: Duration::from_millis(200),
            samples: 10,
        }
    }

    /// Run `f` repeatedly; `f` must do one unit of work per call.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup + calibration: how many iters fit in target/samples?
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.target / 10 {
            f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_nanos() as f64 / calib_iters.max(1) as f64;
        let budget_ns = self.target.as_nanos() as f64 / self.samples as f64;
        let iters = ((budget_ns / per_iter.max(1.0)).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let s = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(s.elapsed().as_nanos() as f64 / iters as f64);
        }
        BenchResult {
            name: name.to_string(),
            samples_ns,
            iters_per_sample: iters,
        }
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let r = b.bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns() > 0.0);
        assert_eq!(r.samples_ns.len(), 10);
    }

    #[test]
    fn ordering_detected() {
        let b = Bencher::quick();
        let fast = b.bench("fast", || {
            black_box((0..10).sum::<u64>());
        });
        let slow = b.bench("slow", || {
            black_box((0..10_000).fold(0u64, |a, x| a ^ (x * 7)));
        });
        assert!(slow.mean_ns() > fast.mean_ns());
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
