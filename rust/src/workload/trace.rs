//! Trace generators (paper §5.1.2).
//!
//! The paper models its traces on the Philly trace [30] trimmed to one
//! server, with model types drawn from Table 3 following the execution-time
//! distribution of [41].  We mirror the published composition:
//!
//! * **90-task trace** — 65 % light / 27 % medium / 8 % heavy: benefits
//!   easily from collocation;
//! * **60-task trace** — 83 % medium / 17 % heavy: the collocation
//!   stress-test.
//!
//! Arrivals are bursty (Philly-like): geometric burst sizes at exponential
//! gaps, fully deterministic from the seed.

use crate::config::schema::ArrivalKind;
use crate::util::rng::Rng;

use super::model_zoo::{ModelZoo, ZooEntry};
use super::task::TaskSpec;

#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub name: String,
    pub tasks: Vec<TaskSpec>,
}

impl TraceSpec {
    pub fn total_work_s(&self) -> f64 {
        self.tasks.iter().map(|t| t.work_s).sum()
    }

    pub fn makespan_lower_bound_s(&self, n_gpus: usize) -> f64 {
        // perfect packing bound, ignoring memory: total gpu-seconds / gpus
        let gpu_s: f64 = self.tasks.iter().map(|t| t.work_s * t.n_gpus as f64).sum();
        gpu_s / n_gpus as f64
    }
}

/// The 90-task trace (paper §5.1.2): mostly light models.
pub fn trace_90(zoo: &ModelZoo, seed: u64) -> TraceSpec {
    // 65 % / 27 % / 8 % of 90 -> 59 / 24 / 7; Philly-like bursts at a mean
    // gap that keeps the server busy but not hopelessly backlogged
    compose(zoo, "trace-90", &[("light", 59), ("medium", 24), ("heavy", 7)], 240.0, seed)
}

/// The 60-task trace: heavier mix, collocation stress-test.
pub fn trace_60(zoo: &ModelZoo, seed: u64) -> TraceSpec {
    // 83 % / 17 % of 60 -> 50 / 10
    compose(zoo, "trace-60", &[("medium", 50), ("heavy", 10)], 300.0, seed)
}

/// Cluster-scale trace: the 90-task trace's 65/27/8 light/medium/heavy
/// composition scaled to `n_tasks`, with the mean inter-burst gap shrunk in
/// proportion to the GPU pool so an N-server cluster sees the same pressure
/// per GPU as the paper's single DGX (Philly-style multi-tenant load).
/// Fully deterministic from `seed`.
pub fn trace_cluster(zoo: &ModelZoo, n_tasks: usize, total_gpus: usize, seed: u64) -> TraceSpec {
    assert!(n_tasks > 0 && total_gpus > 0);
    let light = ((n_tasks as f64 * 0.65).round() as usize).min(n_tasks);
    let medium = ((n_tasks as f64 * 0.27).round() as usize).min(n_tasks - light);
    let heavy = n_tasks - light - medium;
    // trace-90's 240 s mean gap kept 4 GPUs loaded; scale per-GPU pressure
    let mean_gap_s = (240.0 * 4.0 / total_gpus as f64).max(1.0);
    compose(
        zoo,
        &format!("trace-cluster-{n_tasks}x{total_gpus}gpu"),
        &[("light", light), ("medium", medium), ("heavy", heavy)],
        mean_gap_s,
        seed,
    )
}

/// Mixed gang + singleton trace (DESIGN.md §11): the cluster composition of
/// [`trace_cluster`] with every `GANG_EVERY`-th submission widened into a
/// distributed data-parallel job of `gang_gpus` workers (drawn from the
/// heavy pool — the jobs that outgrow one server in multi-tenant traces,
/// Jeon et al.). Gangs carry the `gang` flag: all-or-nothing placement,
/// allowed to span servers. Fully deterministic from `seed`.
pub fn trace_gang(
    zoo: &ModelZoo,
    n_tasks: usize,
    total_gpus: usize,
    gang_gpus: usize,
    seed: u64,
) -> TraceSpec {
    assert!(n_tasks > 0 && total_gpus > 0);
    assert!(
        gang_gpus >= 2 && gang_gpus <= total_gpus,
        "gang width {gang_gpus} must fit the {total_gpus}-GPU cluster"
    );
    let mut t = trace_cluster(zoo, n_tasks, total_gpus, seed ^ 0x6A16);
    t.name = format!("trace-gang-{n_tasks}x{total_gpus}gpu-{gang_gpus}w");
    let mut rng = Rng::new(seed ^ 0x6A16_0001);
    let heavy = zoo.by_class("heavy");
    assert!(!heavy.is_empty(), "no heavy zoo entries for gang jobs");
    // clamp the first gang inside the trace so short traces (n <=
    // GANG_EVERY/2) still carry at least one distributed job — a "gang
    // trace" with zero gangs would silently test nothing
    let first = (GANG_EVERY / 2).min(n_tasks - 1);
    for i in (first..n_tasks).step_by(GANG_EVERY) {
        let e = *rng.choice(&heavy);
        let epochs = *rng.choice(&e.epochs);
        let arrival = t.tasks[i].arrival_s;
        t.tasks[i] = TaskSpec::from_zoo(i, e, epochs, arrival).into_gang(gang_gpus);
    }
    debug_assert!(t.tasks.iter().any(|task| task.gang));
    t
}

/// Every k-th submission of [`trace_gang`] is a distributed job (~8 %).
pub const GANG_EVERY: usize = 12;

/// Pair-heavy cluster trace (`repro placement_scale`, DESIGN.md §12): the
/// [`trace_cluster`] composition with every `every`-th submission replaced
/// by a server-local multi-GPU model from the zoo (the 2-GPU heavies), so
/// island-aware singleton placement has enough multi-GPU decisions to
/// measure. The replacements stay ordinary singletons — no `gang` flag;
/// they must fit one server. Fully deterministic from `seed`.
pub fn trace_pairs(
    zoo: &ModelZoo,
    n_tasks: usize,
    total_gpus: usize,
    every: usize,
    seed: u64,
) -> TraceSpec {
    assert!(n_tasks > 0 && every >= 1);
    let mut t = trace_cluster(zoo, n_tasks, total_gpus, seed ^ 0x9A13);
    t.name = format!("trace-pairs-{n_tasks}x{total_gpus}gpu");
    let mut rng = Rng::new(seed ^ 0x9A13_0001);
    let multi: Vec<&crate::workload::model_zoo::ZooEntry> =
        zoo.entries.iter().filter(|e| e.n_gpus >= 2).collect();
    assert!(!multi.is_empty(), "no multi-GPU zoo entries for pair traces");
    for i in (0..n_tasks).step_by(every) {
        let e = *rng.choice(&multi);
        let epochs = *rng.choice(&e.epochs);
        let arrival = t.tasks[i].arrival_s;
        t.tasks[i] = TaskSpec::from_zoo(i, e, epochs, arrival);
    }
    debug_assert!(t.tasks.iter().any(|task| task.n_gpus >= 2));
    t
}

/// The server-local-only baseline of `repro gang_scale` (DESIGN.md §11):
/// without cross-server gang scheduling, a distributed job must be shrunk
/// to the largest single server — same total GPU-seconds of work, so a
/// `gang_gpus`-wide job runs `gang_gpus / gpus_per_server` times longer on
/// its reduced worker set. Singletons are untouched.
pub fn server_localize(trace: &TraceSpec, gpus_per_server: usize) -> TraceSpec {
    assert!(gpus_per_server >= 1);
    let tasks = trace
        .tasks
        .iter()
        .map(|t| {
            if !t.gang || t.n_gpus <= gpus_per_server {
                let mut t = t.clone();
                t.gang = false;
                return t;
            }
            let mut local = t.clone();
            local.gang = false;
            local.work_s = t.work_s * t.n_gpus as f64 / gpus_per_server as f64;
            local.n_gpus = gpus_per_server;
            local.features.n_gpus = gpus_per_server as f64;
            local
        })
        .collect();
    TraceSpec {
        name: format!("{}-serverlocal", trace.name),
        tasks,
    }
}

/// Diurnal modulation of [`ArrivalGen`]: rate(t) = base × (1 + A·sin(2πt/P)).
pub const DIURNAL_AMPLITUDE: f64 = 0.8;
/// Period of the diurnal sine (a compressed "day" of one simulated hour).
pub const DIURNAL_PERIOD_S: f64 = 3600.0;
/// Rate multiplier inside the flash-crowd window of the burst process.
pub const BURST_FACTOR: f64 = 5.0;
/// The burst window spans [0.5, 0.625] of the arrival duration.
pub const BURST_START_FRAC: f64 = 0.5;
pub const BURST_END_FRAC: f64 = 0.625;

/// Streaming arrival generator for the open-loop service mode (DESIGN.md
/// §13): draws one submission at a time instead of materializing a trace
/// upfront, so the coordinator can run arrival-driven for as long as the
/// configured duration without holding a task list in memory.
///
/// All three processes are thinned Poisson: candidate gaps are exponential
/// at the process's peak rate and each candidate is accepted with
/// probability `rate(t)/peak`, which realizes the exact non-homogeneous
/// process while staying byte-deterministic from the seed — the draw
/// sequence depends only on the seed, never on shard or thread count.
/// Model composition follows the paper's 65/27/8 light/medium/heavy mix.
#[derive(Debug, Clone)]
pub struct ArrivalGen {
    kind: ArrivalKind,
    /// Mean offered load in tasks per second (`--rate` is per minute).
    rate_per_s: f64,
    duration_s: f64,
    rng: Rng,
    t: f64,
    next_id: usize,
    light: Vec<ZooEntry>,
    medium: Vec<ZooEntry>,
    heavy: Vec<ZooEntry>,
}

impl ArrivalGen {
    pub fn new(
        zoo: &ModelZoo,
        kind: ArrivalKind,
        rate_per_min: f64,
        duration_s: f64,
        seed: u64,
    ) -> ArrivalGen {
        assert!(rate_per_min > 0.0 && duration_s > 0.0);
        let clone_pool = |class: &str| -> Vec<ZooEntry> {
            let pool: Vec<ZooEntry> = zoo.by_class(class).into_iter().cloned().collect();
            assert!(!pool.is_empty(), "no zoo entries of class {class}");
            pool
        };
        ArrivalGen {
            kind,
            rate_per_s: rate_per_min / 60.0,
            duration_s,
            rng: Rng::new(seed ^ 0x5E21_0A11),
            t: 0.0,
            next_id: 0,
            light: clone_pool("light"),
            medium: clone_pool("medium"),
            heavy: clone_pool("heavy"),
        }
    }

    /// Instantaneous offered rate at time `t` (tasks per second).
    pub fn rate_at(&self, t: f64) -> f64 {
        match self.kind {
            ArrivalKind::Poisson => self.rate_per_s,
            ArrivalKind::Diurnal => {
                let phase = 2.0 * std::f64::consts::PI * t / DIURNAL_PERIOD_S;
                self.rate_per_s * (1.0 + DIURNAL_AMPLITUDE * phase.sin())
            }
            ArrivalKind::Burst => {
                let (lo, hi) = self.burst_window();
                if t >= lo && t < hi {
                    self.rate_per_s * BURST_FACTOR
                } else {
                    self.rate_per_s
                }
            }
        }
    }

    /// Peak of `rate_at` over the run — the thinning envelope.
    fn peak_rate(&self) -> f64 {
        match self.kind {
            ArrivalKind::Poisson => self.rate_per_s,
            ArrivalKind::Diurnal => self.rate_per_s * (1.0 + DIURNAL_AMPLITUDE),
            ArrivalKind::Burst => self.rate_per_s * BURST_FACTOR,
        }
    }

    /// The flash-crowd interval of the burst process (empty-rate processes
    /// report it too — handy for assertions and plots).
    pub fn burst_window(&self) -> (f64, f64) {
        (
            BURST_START_FRAC * self.duration_s,
            BURST_END_FRAC * self.duration_s,
        )
    }

    /// How many tasks this generator has emitted so far.
    pub fn emitted(&self) -> usize {
        self.next_id
    }

    /// Draw the next submission, or `None` once the arrival window closes.
    /// Times are nondecreasing; ids are sequential from 0.
    pub fn next_task(&mut self) -> Option<TaskSpec> {
        loop {
            self.t += self.rng.exponential(1.0 / self.peak_rate());
            if self.t > self.duration_s {
                return None;
            }
            // thinning: accept with rate(t)/peak — exact for the
            // non-homogeneous process, trivially exact for Poisson
            if self.rng.f64() < self.rate_at(self.t) / self.peak_rate() {
                let u = self.rng.f64();
                let pool = if u < 0.65 {
                    &self.light
                } else if u < 0.92 {
                    &self.medium
                } else {
                    &self.heavy
                };
                let e = self.rng.choice(pool).clone();
                let epochs = *self.rng.choice(&e.epochs);
                let id = self.next_id;
                self.next_id += 1;
                return Some(TaskSpec::from_zoo(id, &e, epochs, self.t));
            }
        }
    }
}

fn compose(
    zoo: &ModelZoo,
    name: &str,
    counts: &[(&str, usize)],
    mean_gap_s: f64,
    seed: u64,
) -> TraceSpec {
    let mut rng = Rng::new(seed ^ 0xCA12_AA00);
    let mut picks = Vec::new();
    for &(class, n) in counts {
        let pool = zoo.by_class(class);
        assert!(!pool.is_empty(), "no zoo entries of class {class}");
        for _ in 0..n {
            let e = *rng.choice(&pool);
            let epochs = *rng.choice(&e.epochs);
            picks.push((e.clone(), epochs));
        }
    }
    rng.shuffle(&mut picks);

    // bursty arrivals: geometric burst sizes, exponential inter-burst gaps
    let mut tasks = Vec::with_capacity(picks.len());
    let mut t = 0.0f64;
    let mut burst_left = 0usize;
    for (id, (e, epochs)) in picks.into_iter().enumerate() {
        if burst_left == 0 {
            t += rng.exponential(mean_gap_s);
            // geometric(0.45): mostly 1-3 tasks per burst
            burst_left = 1;
            while burst_left < 4 && rng.bool(0.45) {
                burst_left += 1;
            }
        }
        burst_left -= 1;
        tasks.push(TaskSpec::from_zoo(id, &e, epochs, t));
    }
    TraceSpec {
        name: name.to_string(),
        tasks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::task::WeightClass;

    fn zoo() -> ModelZoo {
        ModelZoo::load()
    }

    fn class_counts(t: &TraceSpec) -> (usize, usize, usize) {
        let l = t.tasks.iter().filter(|t| t.weight_class == WeightClass::Light).count();
        let m = t.tasks.iter().filter(|t| t.weight_class == WeightClass::Medium).count();
        let h = t.tasks.iter().filter(|t| t.weight_class == WeightClass::Heavy).count();
        (l, m, h)
    }

    #[test]
    fn trace_90_composition() {
        let t = trace_90(&zoo(), 42);
        assert_eq!(t.tasks.len(), 90);
        assert_eq!(class_counts(&t), (59, 24, 7));
    }

    #[test]
    fn trace_60_composition() {
        let t = trace_60(&zoo(), 42);
        assert_eq!(t.tasks.len(), 60);
        assert_eq!(class_counts(&t), (0, 50, 10));
    }

    #[test]
    fn arrivals_sorted_and_bursty() {
        let t = trace_90(&zoo(), 7);
        let arr: Vec<f64> = t.tasks.iter().map(|x| x.arrival_s).collect();
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        // bursts: some identical timestamps must exist
        let bursts = arr.windows(2).filter(|w| w[0] == w[1]).count();
        assert!(bursts > 5, "expected bursty arrivals, got {bursts} ties");
        // spread across a realistic submission window (> 1 h)
        assert!(arr.last().unwrap() - arr[0] > 3600.0);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = trace_60(&zoo(), 9);
        let b = trace_60(&zoo(), 9);
        assert_eq!(
            a.tasks.iter().map(|t| (t.name.clone(), t.arrival_s as u64)).collect::<Vec<_>>(),
            b.tasks.iter().map(|t| (t.name.clone(), t.arrival_s as u64)).collect::<Vec<_>>()
        );
        let c = trace_60(&zoo(), 10);
        assert_ne!(
            a.tasks.iter().map(|t| t.name.clone()).collect::<Vec<_>>(),
            c.tasks.iter().map(|t| t.name.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn trace_60_is_heavier_per_task() {
        let z = zoo();
        let t60 = trace_60(&z, 42);
        let t90 = trace_90(&z, 42);
        let avg60 = t60.total_work_s() / 60.0;
        let avg90 = t90.total_work_s() / 90.0;
        assert!(avg60 > 1.5 * avg90, "60-task avg {avg60}s vs 90-task {avg90}s");
    }

    #[test]
    fn ids_are_sequential() {
        let t = trace_90(&zoo(), 1);
        for (i, task) in t.tasks.iter().enumerate() {
            assert_eq!(task.id, i);
        }
    }

    #[test]
    fn cluster_trace_scales_count_and_composition() {
        let t = trace_cluster(&zoo(), 256, 32, 42);
        assert_eq!(t.tasks.len(), 256);
        let (l, m, h) = class_counts(&t);
        assert_eq!(l, 166); // 0.65 × 256, rounded
        assert_eq!(m, 69); // 0.27 × 256, rounded
        assert_eq!(h, 21);
        for (i, task) in t.tasks.iter().enumerate() {
            assert_eq!(task.id, i);
        }
        let arr: Vec<f64> = t.tasks.iter().map(|x| x.arrival_s).collect();
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn cluster_trace_is_deterministic_by_seed() {
        let a = trace_cluster(&zoo(), 200, 32, 9);
        let b = trace_cluster(&zoo(), 200, 32, 9);
        assert_eq!(
            a.tasks.iter().map(|t| (t.name.clone(), t.arrival_s.to_bits())).collect::<Vec<_>>(),
            b.tasks.iter().map(|t| (t.name.clone(), t.arrival_s.to_bits())).collect::<Vec<_>>()
        );
        let c = trace_cluster(&zoo(), 200, 32, 10);
        assert_ne!(
            a.tasks.iter().map(|t| t.name.clone()).collect::<Vec<_>>(),
            c.tasks.iter().map(|t| t.name.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gang_trace_mixes_distributed_jobs() {
        let t = trace_gang(&zoo(), 96, 16, 8, 42);
        assert_eq!(t.tasks.len(), 96);
        let gangs: Vec<_> = t.tasks.iter().filter(|t| t.gang).collect();
        assert_eq!(gangs.len(), 8, "every {GANG_EVERY}th submission is a gang");
        for g in &gangs {
            assert_eq!(g.n_gpus, 8);
            assert_eq!(g.features.n_gpus, 8.0, "features follow the widening");
            assert_eq!(g.weight_class, WeightClass::Heavy);
        }
        // ids stay sequential and arrivals sorted (the engine relies on it)
        for (i, task) in t.tasks.iter().enumerate() {
            assert_eq!(task.id, i);
        }
        let arr: Vec<f64> = t.tasks.iter().map(|x| x.arrival_s).collect();
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        // deterministic by seed
        let a = trace_gang(&zoo(), 96, 16, 8, 9);
        let b = trace_gang(&zoo(), 96, 16, 8, 9);
        assert_eq!(
            a.tasks.iter().map(|t| (t.name.clone(), t.gang)).collect::<Vec<_>>(),
            b.tasks.iter().map(|t| (t.name.clone(), t.gang)).collect::<Vec<_>>()
        );
        // short traces still carry at least one distributed job
        let tiny = trace_gang(&zoo(), 3, 16, 8, 1);
        assert_eq!(tiny.tasks.iter().filter(|t| t.gang).count(), 1);
    }

    #[test]
    fn pair_trace_mixes_multi_gpu_singletons() {
        let t = trace_pairs(&zoo(), 60, 8, 3, 42);
        assert_eq!(t.tasks.len(), 60);
        let pairs: Vec<_> = t.tasks.iter().filter(|t| t.n_gpus >= 2).collect();
        assert!(pairs.len() >= 20, "every 3rd submission is multi-GPU");
        assert!(t.tasks.iter().all(|t| !t.gang), "pairs are singletons, not gangs");
        for (i, task) in t.tasks.iter().enumerate() {
            assert_eq!(task.id, i);
        }
        let arr: Vec<f64> = t.tasks.iter().map(|x| x.arrival_s).collect();
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        // deterministic by seed
        let a = trace_pairs(&zoo(), 60, 8, 3, 9);
        let b = trace_pairs(&zoo(), 60, 8, 3, 9);
        assert_eq!(
            a.tasks.iter().map(|t| (t.name.clone(), t.n_gpus)).collect::<Vec<_>>(),
            b.tasks.iter().map(|t| (t.name.clone(), t.n_gpus)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn server_localize_conserves_gpu_seconds() {
        let t = trace_gang(&zoo(), 96, 16, 8, 42);
        let local = server_localize(&t, 4);
        assert_eq!(local.tasks.len(), 96);
        assert!(local.tasks.iter().all(|t| !t.gang), "baseline has no gangs");
        assert!(local.tasks.iter().all(|t| t.n_gpus <= 4));
        for (orig, loc) in t.tasks.iter().zip(&local.tasks) {
            let orig_gpu_s = orig.work_s * orig.n_gpus as f64;
            let loc_gpu_s = loc.work_s * loc.n_gpus as f64;
            assert!((orig_gpu_s - loc_gpu_s).abs() < 1e-6, "{}", orig.label());
            if orig.gang {
                assert!((loc.work_s - 2.0 * orig.work_s).abs() < 1e-6);
            } else {
                assert_eq!(loc.work_s, orig.work_s);
            }
        }
    }

    #[test]
    fn arrival_gen_times_nondecreasing_ids_sequential() {
        let z = zoo();
        for kind in [ArrivalKind::Poisson, ArrivalKind::Diurnal, ArrivalKind::Burst] {
            let mut g = ArrivalGen::new(&z, kind, 30.0, 4000.0, 42);
            let mut last_t = 0.0f64;
            let mut n = 0usize;
            while let Some(task) = g.next_task() {
                assert!(task.arrival_s >= last_t, "{kind:?} went backwards");
                assert!(task.arrival_s <= 4000.0);
                assert_eq!(task.id, n);
                last_t = task.arrival_s;
                n += 1;
            }
            assert!(n > 100, "{kind:?} emitted only {n} tasks");
            assert_eq!(g.emitted(), n);
            // the window stays closed once drained
            assert!(g.next_task().is_none());
        }
    }

    #[test]
    fn arrival_gen_deterministic_by_seed() {
        let z = zoo();
        for kind in [ArrivalKind::Poisson, ArrivalKind::Diurnal, ArrivalKind::Burst] {
            let drain = |seed: u64| {
                let mut g = ArrivalGen::new(&z, kind, 20.0, 2000.0, seed);
                let mut out = Vec::new();
                while let Some(t) = g.next_task() {
                    out.push((t.name.clone(), t.arrival_s.to_bits()));
                }
                out
            };
            assert_eq!(drain(9), drain(9), "{kind:?} not reproducible");
            assert_ne!(drain(9), drain(10), "{kind:?} ignores the seed");
        }
    }

    #[test]
    fn poisson_interarrival_mean_within_5pct() {
        // rate 60/min = 1/s -> mean gap must land within 5% of 1 s over 1e5
        // draws (the statistical error at that sample size is ~0.3%)
        let z = zoo();
        let mut g = ArrivalGen::new(&z, ArrivalKind::Poisson, 60.0, 200_000.0, 11);
        let mut prev = 0.0f64;
        let mut gaps = 0usize;
        let mut sum = 0.0f64;
        while gaps < 100_000 {
            let t = g.next_task().expect("window shorter than 1e5 draws").arrival_s;
            sum += t - prev;
            prev = t;
            gaps += 1;
        }
        let mean = sum / gaps as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean inter-arrival {mean}s");
    }

    #[test]
    fn burst_window_exceeds_3x_base_rate() {
        let z = zoo();
        let rate_per_min = 30.0; // base 0.5/s
        let mut g = ArrivalGen::new(&z, ArrivalKind::Burst, rate_per_min, 4000.0, 17);
        let (lo, hi) = g.burst_window();
        assert!((lo, hi) == (2000.0, 2500.0));
        let mut inside = 0usize;
        let mut outside = 0usize;
        while let Some(t) = g.next_task() {
            if t.arrival_s >= lo && t.arrival_s < hi {
                inside += 1;
            } else {
                outside += 1;
            }
        }
        let base = rate_per_min / 60.0;
        let in_rate = inside as f64 / (hi - lo);
        let out_rate = outside as f64 / (4000.0 - (hi - lo));
        assert!(
            in_rate > 3.0 * base,
            "in-window rate {in_rate}/s !> 3x base {base}/s"
        );
        assert!(out_rate < 1.5 * base, "off-window rate {out_rate}/s inflated");
    }

    #[test]
    fn diurnal_rate_modulates_around_base() {
        let z = zoo();
        let g = ArrivalGen::new(&z, ArrivalKind::Diurnal, 60.0, 7200.0, 1);
        // sine peak at t = P/4, trough at 3P/4
        let peak = g.rate_at(DIURNAL_PERIOD_S / 4.0);
        let trough = g.rate_at(3.0 * DIURNAL_PERIOD_S / 4.0);
        assert!((peak - 1.8).abs() < 1e-9, "peak {peak}");
        assert!((trough - 0.2).abs() < 1e-9, "trough {trough}");
        let p = ArrivalGen::new(&z, ArrivalKind::Poisson, 60.0, 7200.0, 1);
        assert_eq!(p.rate_at(123.0), 1.0);
    }

    #[test]
    fn cluster_trace_arrival_rate_scales_with_gpus() {
        // same task count, bigger pool -> denser arrivals
        let small = trace_cluster(&zoo(), 120, 4, 3);
        let big = trace_cluster(&zoo(), 120, 32, 3);
        let span = |t: &TraceSpec| {
            t.tasks.last().unwrap().arrival_s - t.tasks[0].arrival_s
        };
        assert!(
            span(&big) < span(&small) / 2.0,
            "32-GPU span {} !<< 4-GPU span {}",
            span(&big),
            span(&small)
        );
    }
}
