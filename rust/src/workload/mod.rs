//! Workload substrate (S6, S7): Table 3 model zoo, task specs, trace
//! generators, the SLURM-like submission parser, and the Rust mirror of the
//! memsim ground-truth memory model.

pub mod features;
pub mod memsim;
pub mod model_zoo;
pub mod submission;
pub mod task;
pub mod trace;

pub use features::{Arch, TaskFeatures};
pub use model_zoo::{ModelZoo, ZooEntry};
pub use task::{TaskSpec, WeightClass};
pub use trace::{trace_60, trace_90, trace_cluster, TraceSpec};
