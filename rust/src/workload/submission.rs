//! SLURM-like submission script parser (paper §4.1: "users submit their
//! training tasks … after describing them in a format similar to SLURM").
//!
//! ```text
//! #!/bin/bash
//! #CARMA --model resnet50 --dataset imagenet --batch-size 64
//! #CARMA --gpus 1 --epochs 1
//! python train.py ...
//! ```
//!
//! The parser extracts the directives, resolves the model against the zoo,
//! and produces a [`TaskSpec`].  The paper reports a 2.6 ms parse bound;
//! `benches/estimators.rs` tracks ours.

use crate::sim::TaskId;

use super::model_zoo::ModelZoo;
use super::task::TaskSpec;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Submission {
    pub model: String,
    pub dataset: String,
    pub batch_size: u32,
    pub gpus: Option<usize>,
    pub epochs: Option<u32>,
}

#[derive(Debug)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "submission parse error: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

/// Parse the `#CARMA` directives of a submission script.
pub fn parse_script(text: &str) -> Result<Submission, ParseError> {
    let mut sub = Submission::default();
    let mut saw_directive = false;
    for line in text.lines() {
        let line = line.trim();
        let Some(rest) = line.strip_prefix("#CARMA") else {
            continue;
        };
        saw_directive = true;
        let mut it = rest.split_whitespace().peekable();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| ParseError(format!("expected --option, got '{tok}'")))?;
            let val = it
                .next()
                .ok_or_else(|| ParseError(format!("--{key} needs a value")))?;
            match key {
                "model" => sub.model = val.to_string(),
                "dataset" => sub.dataset = val.to_string(),
                "batch-size" | "bs" => {
                    sub.batch_size = val
                        .parse()
                        .map_err(|_| ParseError(format!("bad batch size '{val}'")))?
                }
                "gpus" => {
                    sub.gpus = Some(
                        val.parse()
                            .map_err(|_| ParseError(format!("bad gpu count '{val}'")))?,
                    )
                }
                "epochs" => {
                    sub.epochs = Some(
                        val.parse()
                            .map_err(|_| ParseError(format!("bad epochs '{val}'")))?,
                    )
                }
                other => return Err(ParseError(format!("unknown directive --{other}"))),
            }
        }
    }
    if !saw_directive {
        return Err(ParseError("no #CARMA directives found".into()));
    }
    if sub.model.is_empty() || sub.dataset.is_empty() || sub.batch_size == 0 {
        return Err(ParseError(
            "--model, --dataset and --batch-size are required".into(),
        ));
    }
    Ok(sub)
}

/// Resolve a parsed submission against the zoo into a schedulable task.
pub fn resolve(
    zoo: &ModelZoo,
    sub: &Submission,
    id: TaskId,
    arrival_s: f64,
) -> Result<TaskSpec, ParseError> {
    let e = zoo
        .find(&sub.model, &sub.dataset, sub.batch_size)
        .ok_or_else(|| {
            ParseError(format!(
                "unknown model configuration {}:{} bs{}",
                sub.model, sub.dataset, sub.batch_size
            ))
        })?;
    let epochs = sub.epochs.unwrap_or(e.epochs[0]);
    let mut spec = TaskSpec::from_zoo(id, e, epochs, arrival_s);
    if let Some(g) = sub.gpus {
        if g != e.n_gpus {
            return Err(ParseError(format!(
                "model {} requires {} GPU(s), submission asked for {g}",
                sub.model, e.n_gpus
            )));
        }
    }
    spec.arrival_s = arrival_s;
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SCRIPT: &str = "#!/bin/bash\n\
        #CARMA --model resnet50 --dataset imagenet --batch-size 64\n\
        #CARMA --gpus 1 --epochs 1\n\
        python train.py --data /data/imagenet\n";

    #[test]
    fn parses_directives() {
        let s = parse_script(SCRIPT).unwrap();
        assert_eq!(s.model, "resnet50");
        assert_eq!(s.dataset, "imagenet");
        assert_eq!(s.batch_size, 64);
        assert_eq!(s.gpus, Some(1));
        assert_eq!(s.epochs, Some(1));
    }

    #[test]
    fn resolves_against_zoo() {
        let zoo = ModelZoo::load();
        let s = parse_script(SCRIPT).unwrap();
        let t = resolve(&zoo, &s, 5, 12.0).unwrap();
        assert_eq!(t.id, 5);
        assert_eq!(t.mem_gb, 8.54);
        assert_eq!(t.arrival_s, 12.0);
    }

    #[test]
    fn missing_required_fields() {
        assert!(parse_script("#CARMA --model resnet50\n").is_err());
        assert!(parse_script("python train.py\n").is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse_script("#CARMA --model x --dataset y --batch-size 8 --turbo yes\n").is_err());
    }

    #[test]
    fn unknown_model_rejected() {
        let zoo = ModelZoo::load();
        let s = parse_script("#CARMA --model llama --dataset web --batch-size 1\n").unwrap();
        assert!(resolve(&zoo, &s, 0, 0.0).is_err());
    }

    #[test]
    fn gpu_mismatch_rejected() {
        let zoo = ModelZoo::load();
        let s = parse_script("#CARMA --model gpt2_large --dataset wikitext2 --batch-size 8 --gpus 1\n")
            .unwrap();
        assert!(resolve(&zoo, &s, 0, 0.0).is_err()); // gpt2_large needs 2
    }

    #[test]
    fn defaults_epochs_from_zoo() {
        let zoo = ModelZoo::load();
        let s = parse_script("#CARMA --model resnet18 --dataset cifar100 --batch-size 32\n").unwrap();
        let t = resolve(&zoo, &s, 0, 0.0).unwrap();
        assert!((t.work_s - 0.33 * 60.0 * 20.0).abs() < 1e-9); // first epochs option
    }
}
