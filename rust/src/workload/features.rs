//! Shared GPUMemNet feature vector (DESIGN.md §6).
//!
//! The 16-slot layout is a cross-language contract with
//! `python/compile/memsim.py::TaskFeatures.to_vec` — the Python side trains
//! on it, the Rust side serves it (raw; normalization lives inside the
//! exported model).  `data/memsim_golden.json` pins the agreement.

use std::f64::consts::PI;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    Mlp,
    Cnn,
    Transformer,
}

impl Arch {
    pub fn parse(s: &str) -> Option<Arch> {
        Some(match s {
            "mlp" => Arch::Mlp,
            "cnn" => Arch::Cnn,
            "transformer" => Arch::Transformer,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Arch::Mlp => "mlp",
            Arch::Cnn => "cnn",
            Arch::Transformer => "transformer",
        }
    }
}

/// Activation-function sin/cos encoding (paper §3.2): two continuous
/// features instead of a one-hot. Mirrors memsim.ACTIVATION_ANGLE.
pub fn activation_encoding(name: &str) -> Option<(f64, f64)> {
    let angle = match name {
        "relu" => 0.0,
        "gelu" => PI / 3.0,
        "tanh" => 2.0 * PI / 3.0,
        "sigmoid" => PI,
        "silu" => 4.0 * PI / 3.0,
        "leaky_relu" => 5.0 * PI / 3.0,
        _ => return None,
    };
    Some((angle.cos(), angle.sin()))
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskFeatures {
    pub arch: Arch,
    pub n_linear: f64,
    pub n_conv: f64,
    pub n_batchnorm: f64,
    pub n_dropout: f64,
    pub params_m: f64,
    pub acts_m: f64,
    pub batch_size: f64,
    pub n_gpus: f64,
    pub act_cos: f64,
    pub act_sin: f64,
    pub input_dim: f64,
    pub output_dim: f64,
    pub seq_or_spatial: f64,
    pub depth_total: f64,
    pub width_max: f64,
    pub reserved: f64,
}

impl TaskFeatures {
    pub fn zeroed(arch: Arch) -> Self {
        TaskFeatures {
            arch,
            n_linear: 0.0,
            n_conv: 0.0,
            n_batchnorm: 0.0,
            n_dropout: 0.0,
            params_m: 0.0,
            acts_m: 0.0,
            batch_size: 32.0,
            n_gpus: 1.0,
            act_cos: 1.0,
            act_sin: 0.0,
            input_dim: 0.0,
            output_dim: 0.0,
            seq_or_spatial: 0.0,
            depth_total: 0.0,
            width_max: 0.0,
            reserved: 0.0,
        }
    }

    /// The wire layout fed to the GPUMemNet HLO executable (f32[1,16]).
    pub fn to_vec(&self) -> [f32; 16] {
        [
            self.n_linear as f32,
            self.n_conv as f32,
            self.n_batchnorm as f32,
            self.n_dropout as f32,
            self.params_m as f32,
            self.acts_m as f32,
            self.batch_size as f32,
            self.n_gpus as f32,
            self.act_cos as f32,
            self.act_sin as f32,
            self.input_dim as f32,
            self.output_dim as f32,
            self.seq_or_spatial as f32,
            self.depth_total as f32,
            self.width_max as f32,
            self.reserved as f32,
        ]
    }

    pub fn from_vec(arch: Arch, v: &[f64]) -> Self {
        assert_eq!(v.len(), 16, "feature vector must have 16 slots");
        TaskFeatures {
            arch,
            n_linear: v[0],
            n_conv: v[1],
            n_batchnorm: v[2],
            n_dropout: v[3],
            params_m: v[4],
            acts_m: v[5],
            batch_size: v[6],
            n_gpus: v[7],
            act_cos: v[8],
            act_sin: v[9],
            input_dim: v[10],
            output_dim: v[11],
            seq_or_spatial: v[12],
            depth_total: v[13],
            width_max: v[14],
            reserved: v[15],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_vec() {
        let mut f = TaskFeatures::zeroed(Arch::Cnn);
        f.n_conv = 53.0;
        f.params_m = 25.6;
        let v: Vec<f64> = f.to_vec().iter().map(|&x| x as f64).collect();
        let g = TaskFeatures::from_vec(Arch::Cnn, &v);
        assert_eq!(g.n_conv, 53.0);
        assert!((g.params_m - 25.6).abs() < 1e-5); // f32 wire precision
    }

    #[test]
    fn activation_angles_match_python() {
        let (c, s) = activation_encoding("relu").unwrap();
        assert!((c - 1.0).abs() < 1e-12 && s.abs() < 1e-12);
        let (c, s) = activation_encoding("gelu").unwrap();
        assert!((c - 0.5).abs() < 1e-12);
        assert!((s - (3.0f64).sqrt() / 2.0).abs() < 1e-12);
        assert!(activation_encoding("swishy").is_none());
    }

    #[test]
    fn arch_parse() {
        assert_eq!(Arch::parse("cnn"), Some(Arch::Cnn));
        assert_eq!(Arch::parse("transformer"), Some(Arch::Transformer));
        assert_eq!(Arch::parse("rnn"), None);
    }
}
