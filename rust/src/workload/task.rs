//! Task specification: one deep-learning training job as CARMA sees it.

use crate::sim::TaskId;

use super::features::TaskFeatures;
use super::model_zoo::ZooEntry;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WeightClass {
    Light,
    Medium,
    Heavy,
}

impl WeightClass {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "light" => WeightClass::Light,
            "medium" => WeightClass::Medium,
            "heavy" => WeightClass::Heavy,
            _ => return None,
        })
    }
}

/// A submitted training task (trace row / submission script).
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub id: TaskId,
    pub name: String,
    pub dataset: String,
    pub weight_class: WeightClass,
    pub n_gpus: usize,
    /// True peak GPU memory per GPU (paper Table 3) — the oracle/ground
    /// truth the simulator enforces. The coordinator must NOT read this
    /// except through the Oracle estimator.
    pub mem_gb: f64,
    /// Exclusive-execution work in seconds (= epoch time × epochs).
    pub work_s: f64,
    /// Solo SM-activity / memory-bandwidth demands.
    pub smact: f64,
    pub membw: f64,
    /// What the parser extracts for the estimators.
    pub features: TaskFeatures,
    /// Submission time (seconds into the trace).
    pub arrival_s: f64,
    /// Distributed (gang-scheduled) job: all `n_gpus` workers must start
    /// together — all-or-nothing placement, allowed to span servers over
    /// the fabric (DESIGN.md §11). Non-gang multi-GPU tasks keep the
    /// paper's server-local constraint.
    pub gang: bool,
}

impl TaskSpec {
    /// Build from a zoo entry + chosen epoch count + arrival time.
    pub fn from_zoo(id: TaskId, e: &ZooEntry, epochs: u32, arrival_s: f64) -> TaskSpec {
        TaskSpec {
            id,
            name: e.name.clone(),
            dataset: e.dataset.clone(),
            weight_class: WeightClass::parse(&e.weight_class).expect("zoo weight class"),
            n_gpus: e.n_gpus,
            mem_gb: e.mem_gb,
            work_s: e.epoch_time_min * 60.0 * epochs as f64,
            smact: e.smact,
            membw: e.membw,
            features: e.features,
            arrival_s,
            gang: false,
        }
    }

    /// Widen this task into a distributed data-parallel gang over
    /// `n_gpus` workers. Per-GPU memory, SMACT and bandwidth demands stay
    /// the workers' solo profile; `work_s` stays the per-worker wall time
    /// (data parallelism splits the batch, not the epoch walltime model).
    pub fn into_gang(mut self, n_gpus: usize) -> TaskSpec {
        assert!(n_gpus >= 2, "a gang needs at least two workers");
        self.n_gpus = n_gpus;
        self.features.n_gpus = n_gpus as f64;
        self.gang = true;
        self
    }

    pub fn label(&self) -> String {
        format!("#{} {}:{} bs{}", self.id, self.name, self.dataset, self.features.batch_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::model_zoo::ModelZoo;

    #[test]
    fn from_zoo_computes_work() {
        let zoo = ModelZoo::load();
        let e = zoo.find("resnet18", "cifar100", 32).unwrap();
        let t = TaskSpec::from_zoo(3, e, 20, 100.0);
        assert_eq!(t.id, 3);
        assert!((t.work_s - 0.33 * 60.0 * 20.0).abs() < 1e-9);
        assert_eq!(t.weight_class, WeightClass::Light);
        assert_eq!(t.arrival_s, 100.0);
        assert_eq!(t.mem_gb, 1.96);
    }

    #[test]
    fn heavy_transformer_work() {
        let zoo = ModelZoo::load();
        let e = zoo.find("xlnet_base", "wikitext2", 8).unwrap();
        let t = TaskSpec::from_zoo(0, e, 8, 0.0);
        // 8.95 min/epoch × 8 epochs ≈ 71.6 min
        assert!((t.work_s / 60.0 - 71.6).abs() < 0.1);
        assert_eq!(t.n_gpus, 2);
    }
}
