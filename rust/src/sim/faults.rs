//! Deterministic fault-injection schedules (DESIGN.md §15).
//!
//! A fault schedule is a *pure function* of `(profile, rate, duration,
//! seed, cluster shape)`: strikes arrive as a seeded Poisson process over
//! the injection window, each strike picks a fault kind per the profile, a
//! uniform target, and an exponential repair time — all from one private
//! RNG stream, so the schedule never depends on scheduler state, shard
//! count or thread count. The driver materializes the whole schedule up
//! front and enqueues every strike and repair as ordinary `(time, seq)`
//! engine events on the global lane, which is what keeps fault runs
//! byte-identical at any parallelism (the same argument as the open-loop
//! arrival generator, DESIGN.md §13).

use crate::config::schema::{FaultProfile, FaultsConfig};
use crate::util::rng::Rng;

/// What failed. `Gpu` is an XID-style single-device loss, `Server` a power
/// loss killing every resident task on the box, `Link` a NIC/interconnect
/// degradation (no kills — running work slows, placement keeps working).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Gpu,
    Server,
    Link,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Gpu => "gpu",
            FaultKind::Server => "server",
            FaultKind::Link => "link",
        }
    }
}

/// One scheduled fault: strike and repair instants plus the target —
/// a global GPU id for [`FaultKind::Gpu`], a server id otherwise.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    pub kind: FaultKind,
    pub target: usize,
    pub t_strike: f64,
    pub t_repair: f64,
}

impl FaultRecord {
    pub fn downtime_s(&self) -> f64 {
        self.t_repair - self.t_strike
    }
}

/// Repair times are exponential around the configured means but never
/// instantaneous — a zero-length outage would be invisible to every
/// counter while still churning the event queue.
const MIN_REPAIR_S: f64 = 1.0;

/// Generate the full fault schedule for a run. Pure: two calls with equal
/// arguments return byte-identical schedules. Strikes are sorted by time
/// (the Poisson clock is cumulative); an empty profile or zero rate yields
/// an empty schedule.
pub fn generate(cfg: &FaultsConfig, n_gpus: usize, n_servers: usize) -> Vec<FaultRecord> {
    if cfg.profile == FaultProfile::None || cfg.rate_per_hour <= 0.0 || n_gpus == 0 {
        return Vec::new();
    }
    let mut rng = Rng::new(cfg.seed ^ 0xFA_017_0B5E);
    let mean_gap_s = 3600.0 / cfg.rate_per_hour;
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        t += rng.exponential(mean_gap_s);
        if t > cfg.duration_s {
            break;
        }
        let kind = match cfg.profile {
            FaultProfile::None => unreachable!("filtered above"),
            FaultProfile::Gpu => FaultKind::Gpu,
            FaultProfile::Server => FaultKind::Server,
            FaultProfile::Link => FaultKind::Link,
            // mixed: device loss dominates real incident logs (Jeon et
            // al.); whole-box and fabric outages split the remainder
            FaultProfile::Mixed => {
                let u = rng.f64();
                if u < 0.5 {
                    FaultKind::Gpu
                } else if u < 0.75 {
                    FaultKind::Server
                } else {
                    FaultKind::Link
                }
            }
        };
        let (target, mean_repair_s) = match kind {
            FaultKind::Gpu => (rng.range_usize(0, n_gpus), cfg.gpu_repair_s),
            FaultKind::Server => (rng.range_usize(0, n_servers), cfg.server_repair_s),
            FaultKind::Link => (rng.range_usize(0, n_servers), cfg.link_repair_s),
        };
        let repair = rng.exponential(mean_repair_s).max(MIN_REPAIR_S);
        out.push(FaultRecord {
            kind,
            target,
            t_strike: t,
            t_repair: t + repair,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(profile: FaultProfile, rate: f64, seed: u64) -> FaultsConfig {
        FaultsConfig {
            profile,
            rate_per_hour: rate,
            seed,
            ..FaultsConfig::default()
        }
    }

    #[test]
    fn pure_function_of_seed() {
        let c = cfg(FaultProfile::Mixed, 60.0, 7);
        let a = generate(&c, 16, 4);
        let b = generate(&c, 16, 4);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.target, y.target);
            assert_eq!(x.t_strike.to_bits(), y.t_strike.to_bits());
            assert_eq!(x.t_repair.to_bits(), y.t_repair.to_bits());
        }
        let c2 = cfg(FaultProfile::Mixed, 60.0, 8);
        let other = generate(&c2, 16, 4);
        assert_ne!(
            a.iter().map(|r| r.t_strike.to_bits()).collect::<Vec<_>>(),
            other.iter().map(|r| r.t_strike.to_bits()).collect::<Vec<_>>(),
            "different seeds must draw different schedules"
        );
    }

    #[test]
    fn respects_window_targets_and_ordering() {
        let mut c = cfg(FaultProfile::Mixed, 120.0, 3);
        c.duration_s = 1800.0;
        let sched = generate(&c, 8, 2);
        assert!(!sched.is_empty());
        let mut last = 0.0f64;
        for r in &sched {
            assert!(r.t_strike >= last, "strikes must be time-sorted");
            assert!(r.t_strike <= c.duration_s, "strike outside the window");
            assert!(r.t_repair > r.t_strike, "repair must follow the strike");
            assert!(r.downtime_s() >= MIN_REPAIR_S);
            match r.kind {
                FaultKind::Gpu => assert!(r.target < 8),
                FaultKind::Server | FaultKind::Link => assert!(r.target < 2),
            }
            last = r.t_strike;
        }
    }

    #[test]
    fn single_kind_profiles_only_emit_that_kind() {
        for (profile, kind) in [
            (FaultProfile::Gpu, FaultKind::Gpu),
            (FaultProfile::Server, FaultKind::Server),
            (FaultProfile::Link, FaultKind::Link),
        ] {
            let sched = generate(&cfg(profile, 60.0, 11), 8, 2);
            assert!(!sched.is_empty());
            assert!(sched.iter().all(|r| r.kind == kind), "{profile:?} leaked kinds");
        }
    }

    #[test]
    fn off_profile_and_zero_rate_are_empty() {
        assert!(generate(&cfg(FaultProfile::None, 60.0, 1), 8, 2).is_empty());
        assert!(generate(&cfg(FaultProfile::Mixed, 0.0, 1), 8, 2).is_empty());
    }

    #[test]
    fn mixed_profile_covers_every_kind() {
        let sched = generate(&cfg(FaultProfile::Mixed, 600.0, 5), 16, 4);
        for kind in [FaultKind::Gpu, FaultKind::Server, FaultKind::Link] {
            assert!(
                sched.iter().any(|r| r.kind == kind),
                "mixed schedule missing {kind:?}"
            );
        }
    }
}
