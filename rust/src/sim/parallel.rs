//! Zero-dependency worker pool for the parallel deterministic engine
//! (DESIGN.md §10).
//!
//! The simulator's parallel mode never runs *event handlers* concurrently —
//! that would destroy bit-determinism. Instead the driver fans out the
//! expensive **pure** work (per-server monitor-snapshot construction and
//! per-shard mapping-policy scans) across this pool, then commits the
//! results on the calling thread in strict `(time, seq)` order. The pool
//! therefore only needs one primitive: an *ordered parallel map* — run
//! `f(i)` for `i in 0..n` on any thread, return the results indexed.
//!
//! Workers are spawned once and parked on a condvar between rounds; each
//! round allocates a fresh `Arc<RoundState>` so a straggler from a previous
//! round can never grab an index of (or otherwise observe) a newer round.
//! Jobs borrow the caller's stack — the erased pointers are only
//! dereferenced while `map` is still blocked waiting for the round's
//! completion latch, which is what makes the lifetime erasure sound.

use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Erased description of one round's job: `call(job, out, i)` invokes the
/// caller's closure for index `i` and writes the result into slot `i`.
#[derive(Clone, Copy)]
struct Round {
    job: *const (),
    out: *mut (),
    call: unsafe fn(*const (), *mut (), usize),
    n: usize,
}

// SAFETY: the raw pointers reference the `map` caller's stack. They are
// only dereferenced by `Round::call` for indices `i < n`, every index is
// claimed exactly once, and `map` does not return until all `n` indices
// have completed — so the pointees outlive every dereference. After the
// round completes, workers may still *hold* copies of these pointers (via
// their `Arc<RoundState>`), but `next >= n` guarantees they never
// dereference them again.
unsafe impl Send for Round {}
unsafe impl Sync for Round {}

/// Per-round shared state. Fresh per `map` call: a worker that wakes up
/// late and still holds the previous round's `Arc` can only touch that old
/// round's counters (whose indices are exhausted), never the new round's.
struct RoundState {
    desc: Round,
    /// Next index to claim (grows past `n` when the round is drained).
    next: AtomicUsize,
    /// Completed indices; the round is done when this reaches `n`.
    finished: AtomicUsize,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers park here between rounds.
    start: Condvar,
    /// The `map` caller parks here until `finished == n`.
    done: Condvar,
    /// Occupancy counters for the `--profile` report (obs::profile):
    /// rounds dispatched, jobs run by the caller, jobs run by workers.
    /// Relaxed — read only after the run drains, never for synchronization.
    rounds: AtomicU64,
    caller_jobs: AtomicU64,
    worker_jobs: AtomicU64,
}

struct Slot {
    /// Bumped once per round; workers use it to detect fresh work.
    generation: u64,
    round: Option<Arc<RoundState>>,
    shutdown: bool,
}

/// A fixed-size pool of `threads - 1` workers; the thread calling [`map`]
/// participates as the final worker. Single-caller by design: `map` must
/// not be re-entered from inside a job (the driver never does — jobs are
/// pure policy/snapshot computation).
///
/// [`map`]: WorkerPool::map
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Pool executing rounds on `threads` threads total (including the
    /// caller). `threads <= 1` spawns no workers — `map` then runs inline.
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                generation: 0,
                round: None,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
            rounds: AtomicU64::new(0),
            caller_jobs: AtomicU64::new(0),
            worker_jobs: AtomicU64::new(0),
        });
        let handles = (1..threads.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("carma-sim-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn sim worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Total threads participating in a round (workers + the caller).
    pub fn threads(&self) -> usize {
        self.handles.len() + 1
    }

    /// Occupancy counters since pool creation: `(threads, rounds,
    /// caller_jobs, worker_jobs)`. Inline rounds (no workers, or `n <= 1`)
    /// count toward `caller_jobs`.
    pub fn occupancy(&self) -> (usize, u64, u64, u64) {
        (
            self.threads(),
            self.shared.rounds.load(Ordering::Relaxed),
            self.shared.caller_jobs.load(Ordering::Relaxed),
            self.shared.worker_jobs.load(Ordering::Relaxed),
        )
    }

    /// Run `f(0), f(1), …, f(n-1)` across the pool (the calling thread
    /// participates) and return the results in index order. Blocks until
    /// every index has completed. `f` runs concurrently from several
    /// threads, hence `Sync`; results are `Send` back to the caller.
    pub fn map<T, F>(&self, n: usize, f: &F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        self.shared.rounds.fetch_add(1, Ordering::Relaxed);
        if self.handles.is_empty() || n == 1 {
            self.shared.caller_jobs.fetch_add(n as u64, Ordering::Relaxed);
            return (0..n).map(f).collect();
        }

        let mut out: Vec<MaybeUninit<T>> = Vec::with_capacity(n);
        // SAFETY: MaybeUninit slots are valid uninitialized.
        unsafe { out.set_len(n) };

        // monomorphized trampoline recovering the erased closure + output
        unsafe fn call_one<T, F: Fn(usize) -> T + Sync>(
            job: *const (),
            out: *mut (),
            i: usize,
        ) {
            let f = &*(job as *const F);
            let slot = (out as *mut MaybeUninit<T>).add(i);
            (*slot).write(f(i));
        }

        let round = Arc::new(RoundState {
            desc: Round {
                job: f as *const F as *const (),
                out: out.as_mut_ptr() as *mut (),
                call: call_one::<T, F>,
                n,
            },
            next: AtomicUsize::new(0),
            finished: AtomicUsize::new(0),
        });

        {
            let mut slot = self.shared.slot.lock().expect("pool lock");
            slot.generation += 1;
            slot.round = Some(round.clone());
            // wake only as many workers as there are jobs beyond the
            // caller's own: delta view maintenance produces many tiny
            // rounds (1-3 stale servers), and a full notify_all would pay
            // len(pool) futile wakeups per round. Missed wakeups are safe:
            // the caller drains every unclaimed index itself, and a busy
            // worker re-checks the generation without needing a signal.
            let helpers = (n - 1).min(self.handles.len());
            if helpers == self.handles.len() {
                self.shared.start.notify_all();
            } else {
                for _ in 0..helpers {
                    self.shared.start.notify_one();
                }
            }
        }

        // participate in the round
        run_round(&round, &self.shared.caller_jobs);

        // wait for stragglers (workers notify under the slot lock when the
        // finished counter reaches n, so this check-then-wait cannot miss)
        let mut slot = self.shared.slot.lock().expect("pool lock");
        while round.finished.load(Ordering::Acquire) < n {
            slot = self.shared.done.wait(slot).expect("pool wait");
        }
        drop(slot);

        // SAFETY: every index in 0..n was claimed exactly once via
        // `next.fetch_add` and written before the corresponding `finished`
        // increment (Release); the Acquire load above saw `finished == n`,
        // so all writes are visible and every slot is initialized.
        let ptr = out.as_mut_ptr() as *mut T;
        let cap = out.capacity();
        std::mem::forget(out);
        unsafe { Vec::from_raw_parts(ptr, n, cap) }
    }
}

/// Claim and execute indices of `round` until it is drained, signalling the
/// completion latch for the final index. Each executed job bumps `jobs`
/// (this thread's occupancy counter) *before* the Release on `finished`,
/// so a caller that has observed `finished == n` also sees every
/// occupancy increment of the round.
///
/// A panicking job aborts the process: unwinding would either free the
/// caller's results buffer while other threads still write through raw
/// pointers into it (caller-side panic) or strand the completion latch
/// short of `n` forever (worker-side panic). The jobs are pure, seeded
/// simulation reads — a panic in one is a bug, never data-dependent flow.
fn run_round(round: &RoundState, jobs: &AtomicU64) {
    let n = round.desc.n;
    loop {
        let i = round.next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            return;
        }
        // SAFETY: i < n, claimed exclusively by the fetch_add above; the
        // caller of `map` keeps job/out alive until `finished == n`.
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (round.desc.call)(round.desc.job, round.desc.out, i)
        }));
        if ok.is_err() {
            eprintln!("carma sim worker: parallel job panicked — aborting");
            std::process::abort();
        }
        jobs.fetch_add(1, Ordering::Relaxed);
        round.finished.fetch_add(1, Ordering::Release);
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen_gen = 0u64;
    loop {
        let round: Arc<RoundState> = {
            let mut slot = shared.slot.lock().expect("pool lock");
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.generation != seen_gen {
                    if let Some(r) = &slot.round {
                        seen_gen = slot.generation;
                        break r.clone();
                    }
                }
                slot = shared.start.wait(slot).expect("pool wait");
            }
        };
        run_round(&round, &shared.worker_jobs);
        if round.finished.load(Ordering::Acquire) >= round.desc.n {
            // this worker may have completed the final index — wake the
            // caller. Taking the slot lock orders the notify after the
            // caller's check-then-wait.
            let _slot = shared.slot.lock().expect("pool lock");
            shared.done.notify_all();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().expect("pool lock");
            slot.shutdown = true;
            self.shared.start.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Resolve a configured thread count: `0` = one thread per available core,
/// capped at 8 (the sim's fan-out width saturates well before that on the
/// cluster sizes the benches sweep).
pub fn resolve_threads(configured: usize) -> usize {
    if configured == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8)
    } else {
        configured
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_returns_results_in_index_order() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let out = pool.map(100, &|i| i * i);
        assert_eq!(out.len(), 100);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let pool = WorkerPool::new(3);
        assert!(pool.map(0, &|i| i).is_empty());
        assert_eq!(pool.map(1, &|i| i + 7), vec![7]);
    }

    #[test]
    fn single_threaded_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.map(5, &|i| i * 2), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn jobs_may_borrow_the_callers_stack() {
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let out = pool.map(10, &|i| data[i * 100] + 1);
        assert_eq!(out, vec![1, 101, 201, 301, 401, 501, 601, 701, 801, 901]);
    }

    #[test]
    fn repeated_rounds_reuse_the_same_workers() {
        // many small rounds: exercises the generation handshake and the
        // straggler-isolation (fresh RoundState per round)
        let pool = WorkerPool::new(4);
        for round in 0..200u64 {
            let out = pool.map(8, &|i| round * 1_000 + i as u64);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, round * 1_000 + i as u64);
            }
        }
    }

    #[test]
    fn tiny_rounds_on_wide_pools_complete() {
        // rounds smaller than the pool (the delta-views snapshot pattern)
        // must complete even though only a subset of workers is woken
        let pool = WorkerPool::new(8);
        for round in 0..500usize {
            let n = 2 + round % 3;
            let out = pool.map(n, &|i| i + round);
            assert_eq!(out, (0..n).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn uneven_work_still_completes() {
        let pool = WorkerPool::new(4);
        let out = pool.map(32, &|i| {
            // skew: a few indices are much heavier
            let spins: u64 = if i % 7 == 0 { 200_000 } else { 10 };
            (0..spins).fold(i as u64, |a, x| a.wrapping_add(x ^ a.rotate_left(3)))
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn results_deterministic_regardless_of_scheduling() {
        let pool = WorkerPool::new(4);
        let a = pool.map(64, &|i| (i as u64).wrapping_mul(0x9E37_79B9));
        let b = pool.map(64, &|i| (i as u64).wrapping_mul(0x9E37_79B9));
        assert_eq!(a, b);
    }

    #[test]
    fn occupancy_counts_rounds_and_jobs() {
        let pool = WorkerPool::new(1);
        pool.map(5, &|i| i);
        pool.map(3, &|i| i);
        let (threads, rounds, caller, workers) = pool.occupancy();
        assert_eq!((threads, rounds, caller, workers), (1, 2, 8, 0));

        let pool = WorkerPool::new(4);
        for _ in 0..10 {
            pool.map(64, &|i| i * 3);
        }
        let (threads, rounds, caller, workers) = pool.occupancy();
        assert_eq!(threads, 4);
        assert_eq!(rounds, 10);
        // every job ran exactly once, split between caller and workers
        assert_eq!(caller + workers, 640);
    }

    #[test]
    fn resolve_threads_semantics() {
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(4), 4);
        let auto = resolve_threads(0);
        assert!((1..=8).contains(&auto), "auto resolved to {auto}");
    }
}
