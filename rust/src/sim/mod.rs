//! Discrete-event simulation engine (S1, DESIGN.md §3).
//!
//! Continuous-time processor-sharing semantics with event-driven analytic
//! integration: between events every running task advances at a constant
//! speed factor (from the interference model); whenever GPU residency
//! changes, speeds are recomputed and completion events are re-scheduled.
//! Stale completions are guarded by per-task versions.

pub mod engine;
pub mod faults;
pub mod parallel;

pub use engine::{Engine, EngineStats, Event, TaskId};
pub use parallel::WorkerPool;
