//! Event heap + simulated clock.
//!
//! The engine supports multiple *lanes* — independent event sources (one per
//! coordinator shard plus a global lane) merged deterministically on pop by
//! `(time, seq)` with a single global sequence counter. Because the merge
//! order is a total order independent of which lane an event sits in, a
//! multi-lane engine pops the exact same stream a single-heap engine would —
//! fixed-seed runs stay bit-identical at any shard count (DESIGN.md §9).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

pub type TaskId = usize;

/// Simulation events. Timestamps are seconds of simulated time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A task from the trace reaches the submit interface.
    TaskArrival(TaskId),
    /// The 1-minute observation window for the selected task elapsed
    /// (paper §4.1); the mapper may now decide.
    WindowDone(TaskId),
    /// Periodic re-attempt at mapping the named shard's selected-but-
    /// unmappable task.
    RetryMapping(usize),
    /// Memory-ramp stage `k` of a dispatched task (staircase allocation).
    Ramp(TaskId, u8),
    /// Task finished its work. Version-guarded: stale completions (scheduled
    /// before a speed change) are ignored.
    Completion(TaskId, u64),
    /// DCGM-like sampling tick (monitor + energy integration).
    MonitorSample,
    /// The recovery loop noticed an OOM error file (paper §4.2: CARMA
    /// "iteratively checks the error files"); small detection delay.
    RecoveryDetect(TaskId),
}

#[derive(Debug)]
struct Entry {
    t: f64,
    seq: u64,
    ev: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, FIFO tiebreak.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue + clock. Monotonicity is enforced: scheduling in the past
/// panics (it would silently corrupt causality).
///
/// One or more lanes back the queue; `schedule`/`schedule_in` target lane 0,
/// the sharded coordinator gives each shard its own lane via `schedule_on`.
#[derive(Debug)]
pub struct Engine {
    lanes: Vec<BinaryHeap<Entry>>,
    now: f64,
    seq: u64,
    pops: u64,
}

impl Default for Engine {
    fn default() -> Self {
        Engine {
            lanes: vec![BinaryHeap::new()],
            now: 0.0,
            seq: 0,
            pops: 0,
        }
    }
}

impl Engine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the heap for a known workload (cluster traces schedule one
    /// arrival per task up front; re-allocation on the hot path is wasted
    /// work at 32+ GPU scale).
    pub fn with_capacity(n: usize) -> Self {
        Engine {
            lanes: vec![BinaryHeap::with_capacity(n)],
            ..Self::default()
        }
    }

    /// `n_lanes` independent event sources (>= 1); lane 0 is pre-sized for
    /// `capacity` events (the arrival bulk always lands there).
    pub fn with_lanes(n_lanes: usize, capacity: usize) -> Self {
        let n = n_lanes.max(1);
        let mut lanes = Vec::with_capacity(n);
        lanes.push(BinaryHeap::with_capacity(capacity));
        for _ in 1..n {
            lanes.push(BinaryHeap::new());
        }
        Engine {
            lanes,
            ..Self::default()
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Total events popped since construction (throughput accounting).
    pub fn events_processed(&self) -> u64 {
        self.pops
    }

    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.is_empty())
    }

    /// Schedule `ev` at absolute time `t` (>= now) on lane 0.
    pub fn schedule(&mut self, t: f64, ev: Event) {
        self.schedule_on(0, t, ev);
    }

    pub fn schedule_in(&mut self, dt: f64, ev: Event) {
        self.schedule_in_on(0, dt, ev);
    }

    /// Schedule on a specific lane. The global `seq` counter makes the merge
    /// order identical to a single shared heap.
    pub fn schedule_on(&mut self, lane: usize, t: f64, ev: Event) {
        assert!(
            t >= self.now - 1e-9,
            "scheduling into the past: t={t} now={}",
            self.now
        );
        self.seq += 1;
        self.lanes[lane].push(Entry {
            t: t.max(self.now),
            seq: self.seq,
            ev,
        });
    }

    pub fn schedule_in_on(&mut self, lane: usize, dt: f64, ev: Event) {
        assert!(dt >= 0.0, "negative delay {dt}");
        self.schedule_on(lane, self.now + dt, ev);
    }

    /// Pop the globally next event — the minimum `(t, seq)` across all lane
    /// heads — advancing the clock.
    ///
    /// The head scan is linear in the lane count; callers keep lane counts
    /// small (the coordinator caps `shards` at 256). A tournament tree over
    /// lane heads is the upgrade path if lane counts ever grow past that.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let mut best: Option<usize> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            let Some(head) = lane.peek() else { continue };
            let earlier = match best {
                None => true,
                Some(b) => {
                    let bh = self.lanes[b].peek().expect("best lane has a head");
                    head.t.total_cmp(&bh.t).then_with(|| head.seq.cmp(&bh.seq))
                        == Ordering::Less
                }
            };
            if earlier {
                best = Some(i);
            }
        }
        let e = self.lanes[best?].pop().expect("peeked lane pops");
        debug_assert!(e.t >= self.now - 1e-9);
        self.now = e.t.max(self.now);
        self.pops += 1;
        Some((self.now, e.ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut e = Engine::new();
        e.schedule(3.0, Event::MonitorSample);
        e.schedule(1.0, Event::TaskArrival(0));
        e.schedule(2.0, Event::TaskArrival(1));
        let order: Vec<f64> = std::iter::from_fn(|| e.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut e = Engine::new();
        e.schedule(5.0, Event::TaskArrival(10));
        e.schedule(5.0, Event::TaskArrival(11));
        e.schedule(5.0, Event::TaskArrival(12));
        let ids: Vec<_> = std::iter::from_fn(|| e.pop())
            .map(|(_, ev)| match ev {
                Event::TaskArrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![10, 11, 12]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut e = Engine::new();
        e.schedule(1.0, Event::MonitorSample);
        e.schedule(4.0, Event::MonitorSample);
        e.pop();
        assert_eq!(e.now(), 1.0);
        e.schedule_in(1.5, Event::MonitorSample);
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 2.5);
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 4.0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past() {
        let mut e = Engine::new();
        e.schedule(5.0, Event::MonitorSample);
        e.pop();
        e.schedule(1.0, Event::MonitorSample);
    }

    #[test]
    fn fifo_holds_under_interleaved_scheduling() {
        // FIFO on ties must survive pops interleaved with schedules — the
        // heap never compares stale seq numbers across epochs
        let mut e = Engine::new();
        e.schedule(1.0, Event::TaskArrival(0));
        e.schedule(5.0, Event::TaskArrival(1));
        assert!(matches!(e.pop(), Some((_, Event::TaskArrival(0)))));
        e.schedule(5.0, Event::TaskArrival(2));
        e.schedule(5.0, Event::TaskArrival(3));
        let ids: Vec<_> = std::iter::from_fn(|| e.pop())
            .map(|(_, ev)| match ev {
                Event::TaskArrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![1, 2, 3], "earlier-scheduled ties pop first");
    }

    #[test]
    fn fifo_stress_thousands_of_equal_timestamps() {
        // cluster traces put whole arrival bursts on one timestamp; ordering
        // must stay submission-FIFO at scale
        let mut e = Engine::with_capacity(4096);
        for i in 0..4096 {
            e.schedule(42.0, Event::TaskArrival(i));
        }
        for want in 0..4096 {
            match e.pop() {
                Some((t, Event::TaskArrival(got))) => {
                    assert_eq!(t, 42.0);
                    assert_eq!(got, want);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(e.events_processed(), 4096);
        assert!(e.is_empty());
    }

    #[test]
    fn earliest_first_across_mixed_magnitudes() {
        let mut e = Engine::new();
        let times = [86_400.0, 0.5, 3_600.0, 0.5, 59.999, 60.0, 7.25];
        for (i, &t) in times.iter().enumerate() {
            e.schedule(t, Event::TaskArrival(i));
        }
        let popped: Vec<(f64, usize)> = std::iter::from_fn(|| e.pop())
            .map(|(t, ev)| match ev {
                Event::TaskArrival(i) => (t, i),
                _ => unreachable!(),
            })
            .collect();
        let ts: Vec<f64> = popped.iter().map(|&(t, _)| t).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{ts:?}");
        // the two 0.5s ties keep submission order (ids 1 then 3)
        assert_eq!(popped[0].1, 1);
        assert_eq!(popped[1].1, 3);
    }

    #[test]
    fn lanes_merge_by_time_then_seq() {
        // per-shard lanes must pop the exact stream one shared heap would
        let mut e = Engine::with_lanes(3, 8);
        e.schedule_on(1, 5.0, Event::TaskArrival(0)); // seq 1
        e.schedule_on(2, 3.0, Event::TaskArrival(1)); // seq 2
        e.schedule_on(0, 5.0, Event::TaskArrival(2)); // seq 3 (ties with seq 1)
        e.schedule_on(2, 1.0, Event::TaskArrival(3)); // seq 4
        let ids: Vec<_> = std::iter::from_fn(|| e.pop())
            .map(|(_, ev)| match ev {
                Event::TaskArrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![3, 1, 0, 2], "time order, FIFO-by-seq on ties");
        assert_eq!(e.events_processed(), 4);
    }

    #[test]
    fn lane_stream_identical_to_single_heap() {
        // same schedule sequence through 1 lane and through 4 lanes must pop
        // identically — the bit-determinism guarantee the sharded
        // coordinator relies on (DESIGN.md §9)
        let times = [7.0, 2.0, 2.0, 9.5, 0.0, 7.0, 3.25, 2.0];
        let mut single = Engine::new();
        let mut sharded = Engine::with_lanes(4, 8);
        for (i, &t) in times.iter().enumerate() {
            single.schedule(t, Event::TaskArrival(i));
            sharded.schedule_on(i % 4, t, Event::TaskArrival(i));
        }
        let drain = |e: &mut Engine| -> Vec<(u64, Event)> {
            std::iter::from_fn(|| e.pop()).map(|(t, ev)| (t.to_bits(), ev)).collect()
        };
        assert_eq!(drain(&mut single), drain(&mut sharded));
    }

    #[test]
    fn lanes_advance_one_clock() {
        let mut e = Engine::with_lanes(2, 4);
        e.schedule_on(1, 10.0, Event::MonitorSample);
        e.pop();
        assert_eq!(e.now(), 10.0);
        // now lane 0 scheduling is relative to the shared clock
        e.schedule_in_on(0, 5.0, Event::MonitorSample);
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 15.0);
        assert!(e.is_empty());
        assert_eq!(e.n_lanes(), 2);
    }

    #[test]
    fn version_guard_pattern() {
        // completions carry versions; the consumer drops stale ones
        let mut e = Engine::new();
        e.schedule(1.0, Event::Completion(0, 1));
        e.schedule(2.0, Event::Completion(0, 2));
        let current_version = 2u64;
        let mut fired = 0;
        while let Some((_, ev)) = e.pop() {
            if let Event::Completion(_, v) = ev {
                if v == current_version {
                    fired += 1;
                }
            }
        }
        assert_eq!(fired, 1);
    }
}
