//! Event heap + simulated clock.
//!
//! The engine supports multiple *lanes* — independent event sources (one per
//! coordinator shard plus a global lane) merged deterministically on pop by
//! `(time, seq)` with a single global sequence counter. Because the merge
//! order is a total order independent of which lane an event sits in, a
//! multi-lane engine pops the exact same stream a single-heap engine would —
//! fixed-seed runs stay bit-identical at any shard count (DESIGN.md §9).
//!
//! Lane heads are merged through a *tournament index*: a small binary
//! min-heap of lane ids keyed by each lane's head `(time, seq)`. A pop or
//! push touches O(log lanes) index nodes instead of scanning every lane
//! head, so the merge stays cheap at the 256-shard ceiling (DESIGN.md §10).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

pub type TaskId = usize;

/// Simulation events. Timestamps are seconds of simulated time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A task from the trace reaches the submit interface.
    TaskArrival(TaskId),
    /// The 1-minute observation window for the selected task elapsed
    /// (paper §4.1); the mapper may now decide.
    WindowDone(TaskId),
    /// Periodic re-attempt at mapping the named shard's selected-but-
    /// unmappable task.
    RetryMapping(usize),
    /// Memory-ramp stage `k` of a dispatched task (staircase allocation).
    Ramp(TaskId, u8),
    /// Task finished its work. Version-guarded: stale completions (scheduled
    /// before a speed change) are ignored.
    Completion(TaskId, u64),
    /// DCGM-like sampling tick (monitor + energy integration).
    MonitorSample,
    /// The recovery loop noticed an OOM error file (paper §4.2: CARMA
    /// "iteratively checks the error files"); small detection delay.
    RecoveryDetect(TaskId),
    /// Periodic re-attempt at placing the head-of-lane gang (DESIGN.md §11).
    GangRetry,
    /// A gang's partial hold reached its TTL. Version-guarded: the second
    /// field is the hold epoch the expiry was armed for — re-acquired holds
    /// bump the epoch, so stale expiries are dropped.
    GangHoldExpire(TaskId, u64),
    /// The named shard's mapper has idled one full observation window
    /// beside a non-empty sibling queue (DESIGN.md §12): on commit it may
    /// steal one task from the longest sibling queue's tail. Event-ordered
    /// like everything else, so stealing is deterministic by construction.
    StealCheck(usize),
    /// Open-loop service mode (DESIGN.md §13): the streaming arrival
    /// generator's next submission reaches the intake at this timestamp.
    /// The task's spec is held by the coordinator (not the event) so the
    /// event stays `Eq`; handling it admits the task and draws + schedules
    /// the following arrival, always on the driver thread in commit order —
    /// which keeps the arrival stream byte-identical at any shard or
    /// thread count.
    ServiceArrival,
    /// Fault injection (DESIGN.md §15): the indexed entry of the run's
    /// precomputed fault schedule strikes now. The payload is an index
    /// into the coordinator-held `Vec<FaultRecord>` (the `ServiceArrival`
    /// pattern: the coordinator owns the payload so the event stays `Eq`).
    FaultStrike(usize),
    /// The indexed fault's repair completes now; health states roll back
    /// and quarantined capacity returns to the placement filter.
    FaultRepair(usize),
}

/// Heap node: the event payload lives in the arena (`Engine::arena`), the
/// heap only moves this 24-byte key around during sifts. `idx`/`gen` form a
/// generational index into the arena: `gen` must match the slot's current
/// generation, which catches any stale handle after a slot is recycled
/// through the free list (debug builds assert it on pop).
#[derive(Debug, Clone, Copy)]
struct Entry {
    t: f64,
    seq: u64,
    idx: u32,
    gen: u32,
}

/// One arena slot. `ev` is `None` while the slot sits on the free list.
#[derive(Debug)]
struct Slot {
    gen: u32,
    ev: Option<Event>,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, FIFO tiebreak.
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// `pos[lane]` sentinel: the lane is empty and absent from the index heap.
const ABSENT: usize = usize::MAX;

/// The event queue + clock. Monotonicity is enforced: scheduling in the past
/// panics (it would silently corrupt causality).
///
/// One or more lanes back the queue; `schedule`/`schedule_in` target lane 0,
/// the sharded coordinator gives each shard its own lane via `schedule_on`.
#[derive(Debug)]
pub struct Engine {
    lanes: Vec<BinaryHeap<Entry>>,
    /// Tournament index: binary min-heap of lane ids, ordered by each
    /// lane's head `(t, seq)`. Only non-empty lanes appear.
    index: Vec<usize>,
    /// `pos[lane]` = the lane's slot in `index` (ABSENT when empty).
    pos: Vec<usize>,
    /// Event arena: payloads live here exactly once; heap entries carry a
    /// generational `(idx, gen)` handle. Slots are recycled through `free`,
    /// so `arena.len()` only grows when more events are pending than ever
    /// before — it doubles as the high-water mark of concurrent events.
    arena: Vec<Slot>,
    /// Recycled arena slot indices (LIFO: hot slots stay cache-warm).
    free: Vec<u32>,
    now: f64,
    seq: u64,
    pops: u64,
    /// Discrete time quantum: bumped every time a pop advances the clock to
    /// a strictly later timestamp. Ties (and bit-distinct-but-equal floats
    /// like `-0.0` vs `0.0`) share a quantum, which makes this the correct
    /// cache key for time-derived state — `now.to_bits()` is not.
    quantum: u64,
    /// Lane-heap grows past their pre-sized capacity (perf regression
    /// counter: a correctly pre-sized run never reallocates mid-run).
    lane_reallocs: u64,
    /// Arena grows past its pre-sized capacity.
    arena_reallocs: u64,
}

/// Allocation-behavior counters for perf accounting (`--profile`, the
/// `engine_scale` study and the pre-sizing regression tests).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    /// Peak number of simultaneously pending events (arena slots ever used).
    pub arena_high_water: usize,
    /// Current arena capacity (pre-sized at construction).
    pub arena_capacity: usize,
    /// Times any lane heap grew beyond its pre-sized capacity.
    pub lane_reallocs: u64,
    /// Times the arena grew beyond its pre-sized capacity.
    pub arena_reallocs: u64,
}

impl Default for Engine {
    fn default() -> Self {
        Engine {
            lanes: vec![BinaryHeap::new()],
            index: Vec::with_capacity(1),
            pos: vec![ABSENT],
            arena: Vec::new(),
            free: Vec::new(),
            now: 0.0,
            seq: 0,
            pops: 0,
            quantum: 0,
            lane_reallocs: 0,
            arena_reallocs: 0,
        }
    }
}

impl Engine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the heap for a known workload (cluster traces schedule one
    /// arrival per task up front; re-allocation on the hot path is wasted
    /// work at 32+ GPU scale).
    pub fn with_capacity(n: usize) -> Self {
        Engine {
            lanes: vec![BinaryHeap::with_capacity(n)],
            arena: Vec::with_capacity(n),
            free: Vec::with_capacity(n),
            ..Self::default()
        }
    }

    /// `n_lanes` independent event sources (>= 1); lane 0 is pre-sized for
    /// `capacity` events (the arrival bulk always lands there) and every
    /// other lane for an even share of the same volume.
    pub fn with_lanes(n_lanes: usize, capacity: usize) -> Self {
        let n = n_lanes.max(1);
        Self::with_lane_capacities(n, capacity, (capacity / n).max(16))
    }

    /// Fully explicit pre-sizing: lane 0 (the global lane) holds `lane0`
    /// events, each per-shard lane holds `per_lane`. The sharded driver
    /// passes its expected per-shard event volume here so high shard counts
    /// never reallocate lane heaps on the hot path (DESIGN.md §10).
    pub fn with_lane_capacities(n_lanes: usize, lane0: usize, per_lane: usize) -> Self {
        let n = n_lanes.max(1);
        let mut lanes = Vec::with_capacity(n);
        lanes.push(BinaryHeap::with_capacity(lane0));
        for _ in 1..n {
            lanes.push(BinaryHeap::with_capacity(per_lane));
        }
        // the arena holds every pending event across all lanes
        let total = lane0 + (n - 1) * per_lane;
        Engine {
            lanes,
            index: Vec::with_capacity(n),
            pos: vec![ABSENT; n],
            arena: Vec::with_capacity(total),
            free: Vec::with_capacity(total),
            now: 0.0,
            seq: 0,
            pops: 0,
            quantum: 0,
            lane_reallocs: 0,
            arena_reallocs: 0,
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Total events popped since construction (throughput accounting).
    pub fn events_processed(&self) -> u64 {
        self.pops
    }

    /// Discrete time quantum: increments exactly when a pop advances the
    /// clock to a strictly later timestamp, so all events sharing one
    /// timestamp — including bit-distinct-but-equal floats — share one
    /// quantum. The coordinator keys time-derived caches on this instead of
    /// `now.to_bits()`.
    pub fn quantum(&self) -> u64 {
        self.quantum
    }

    /// Allocation counters (pre-sizing regression accounting).
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            arena_high_water: self.arena.len(),
            arena_capacity: self.arena.capacity(),
            lane_reallocs: self.lane_reallocs,
            arena_reallocs: self.arena_reallocs,
        }
    }

    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Schedule `ev` at absolute time `t` (>= now) on lane 0.
    pub fn schedule(&mut self, t: f64, ev: Event) {
        self.schedule_on(0, t, ev);
    }

    pub fn schedule_in(&mut self, dt: f64, ev: Event) {
        self.schedule_in_on(0, dt, ev);
    }

    /// Schedule on a specific lane. The global `seq` counter makes the merge
    /// order identical to a single shared heap.
    pub fn schedule_on(&mut self, lane: usize, t: f64, ev: Event) {
        assert!(
            t >= self.now - 1e-9,
            "scheduling into the past: t={t} now={}",
            self.now
        );
        self.seq += 1;
        let (idx, gen) = self.alloc_slot(ev);
        if self.lanes[lane].len() == self.lanes[lane].capacity() {
            self.lane_reallocs += 1;
        }
        self.lanes[lane].push(Entry {
            t: t.max(self.now),
            seq: self.seq,
            idx,
            gen,
        });
        // the lane's head can only get earlier (or stay) on push
        if self.pos[lane] == ABSENT {
            self.pos[lane] = self.index.len();
            self.index.push(lane);
        }
        self.sift_up(self.pos[lane]);
    }

    pub fn schedule_in_on(&mut self, lane: usize, dt: f64, ev: Event) {
        assert!(dt >= 0.0, "negative delay {dt}");
        self.schedule_on(lane, self.now + dt, ev);
    }

    /// Pop the globally next event — the minimum `(t, seq)` across all lane
    /// heads, read off the tournament index root — advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let best = *self.index.first()?;
        let e = self.lanes[best].pop().expect("indexed lane is non-empty");
        if self.lanes[best].is_empty() {
            self.remove_root();
        } else {
            // the lane's head got later (or equal): restore downwards
            self.sift_down(0);
        }
        debug_assert!(e.t >= self.now - 1e-9);
        if e.t > self.now {
            // strictly later timestamp: a new time quantum begins. Numeric
            // comparison (not to_bits) so -0.0 / 0.0 share quantum 0.
            self.quantum += 1;
        }
        self.now = e.t.max(self.now);
        self.pops += 1;
        let ev = self.free_slot(e.idx, e.gen);
        Some((self.now, ev))
    }

    /// Timestamp of the globally next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        let best = *self.index.first()?;
        Some(self.lanes[best].peek().expect("indexed lane head").t)
    }

    /// Drain the *frontier* — every pending event sharing the earliest
    /// timestamp (the current time quantum) — into `buf` in `(time, seq)`
    /// order, advancing the clock to that timestamp. Returns the number of
    /// events drained (0 when the queue is empty).
    ///
    /// This is the merge barrier of the parallel engine (DESIGN.md §10):
    /// the caller may plan work for the whole quantum at once, but must
    /// still commit results in the order `buf` delivers them. Events
    /// scheduled *at* the frontier time while the batch is being processed
    /// carry higher sequence numbers than everything drained here, so the
    /// next `pop_frontier` call delivers them in exactly the position a
    /// serial `pop` loop would have.
    pub fn pop_frontier(&mut self, buf: &mut Vec<(f64, Event)>) -> usize {
        buf.clear();
        let Some((t, ev)) = self.pop() else { return 0 };
        buf.push((t, ev));
        while let Some(head_t) = self.peek_time() {
            if head_t.total_cmp(&t).is_gt() {
                break;
            }
            let e = self.pop().expect("peeked engine pops");
            buf.push(e);
        }
        buf.len()
    }

    // -- event arena ---------------------------------------------------------

    /// Store `ev` in a recycled (or fresh) arena slot; returns its handle.
    #[inline]
    fn alloc_slot(&mut self, ev: Event) -> (u32, u32) {
        match self.free.pop() {
            Some(idx) => {
                let slot = &mut self.arena[idx as usize];
                debug_assert!(slot.ev.is_none(), "free-listed slot is vacant");
                slot.ev = Some(ev);
                (idx, slot.gen)
            }
            None => {
                if self.arena.len() == self.arena.capacity() {
                    self.arena_reallocs += 1;
                }
                let idx = u32::try_from(self.arena.len()).expect("arena indices fit u32");
                self.arena.push(Slot { gen: 0, ev: Some(ev) });
                (idx, 0)
            }
        }
    }

    /// Take the event out of slot `idx`, retire the generation and recycle
    /// the slot.
    #[inline]
    fn free_slot(&mut self, idx: u32, gen: u32) -> Event {
        let slot = &mut self.arena[idx as usize];
        debug_assert_eq!(slot.gen, gen, "stale generational handle on pop");
        let ev = slot.ev.take().expect("popped entry points at a live slot");
        slot.gen = slot.gen.wrapping_add(1);
        self.free.push(idx);
        ev
    }

    // -- tournament index maintenance ----------------------------------------

    /// `(t, seq)` key of a lane's head. Only called for indexed lanes.
    #[inline]
    fn head_key(&self, lane: usize) -> (f64, u64) {
        let h = self.lanes[lane].peek().expect("indexed lane head");
        (h.t, h.seq)
    }

    #[inline]
    fn head_lt(&self, a: usize, b: usize) -> bool {
        let (ta, sa) = self.head_key(a);
        let (tb, sb) = self.head_key(b);
        ta.total_cmp(&tb).then_with(|| sa.cmp(&sb)) == Ordering::Less
    }

    #[inline]
    fn swap_nodes(&mut self, i: usize, j: usize) {
        self.index.swap(i, j);
        self.pos[self.index[i]] = i;
        self.pos[self.index[j]] = j;
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.head_lt(self.index[i], self.index[parent]) {
                self.swap_nodes(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut smallest = i;
            if l < self.index.len() && self.head_lt(self.index[l], self.index[smallest]) {
                smallest = l;
            }
            if r < self.index.len() && self.head_lt(self.index[r], self.index[smallest]) {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.swap_nodes(i, smallest);
            i = smallest;
        }
    }

    /// Remove the index root (its lane just became empty).
    fn remove_root(&mut self) {
        let root_lane = self.index[0];
        self.pos[root_lane] = ABSENT;
        let last = self.index.pop().expect("root exists");
        if !self.index.is_empty() {
            self.index[0] = last;
            self.pos[last] = 0;
            self.sift_down(0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut e = Engine::new();
        e.schedule(3.0, Event::MonitorSample);
        e.schedule(1.0, Event::TaskArrival(0));
        e.schedule(2.0, Event::TaskArrival(1));
        let order: Vec<f64> = std::iter::from_fn(|| e.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut e = Engine::new();
        e.schedule(5.0, Event::TaskArrival(10));
        e.schedule(5.0, Event::TaskArrival(11));
        e.schedule(5.0, Event::TaskArrival(12));
        let ids: Vec<_> = std::iter::from_fn(|| e.pop())
            .map(|(_, ev)| match ev {
                Event::TaskArrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![10, 11, 12]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut e = Engine::new();
        e.schedule(1.0, Event::MonitorSample);
        e.schedule(4.0, Event::MonitorSample);
        e.pop();
        assert_eq!(e.now(), 1.0);
        e.schedule_in(1.5, Event::MonitorSample);
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 2.5);
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 4.0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past() {
        let mut e = Engine::new();
        e.schedule(5.0, Event::MonitorSample);
        e.pop();
        e.schedule(1.0, Event::MonitorSample);
    }

    #[test]
    fn fifo_holds_under_interleaved_scheduling() {
        // FIFO on ties must survive pops interleaved with schedules — the
        // heap never compares stale seq numbers across epochs
        let mut e = Engine::new();
        e.schedule(1.0, Event::TaskArrival(0));
        e.schedule(5.0, Event::TaskArrival(1));
        assert!(matches!(e.pop(), Some((_, Event::TaskArrival(0)))));
        e.schedule(5.0, Event::TaskArrival(2));
        e.schedule(5.0, Event::TaskArrival(3));
        let ids: Vec<_> = std::iter::from_fn(|| e.pop())
            .map(|(_, ev)| match ev {
                Event::TaskArrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![1, 2, 3], "earlier-scheduled ties pop first");
    }

    #[test]
    fn fifo_stress_thousands_of_equal_timestamps() {
        // cluster traces put whole arrival bursts on one timestamp; ordering
        // must stay submission-FIFO at scale
        let mut e = Engine::with_capacity(4096);
        for i in 0..4096 {
            e.schedule(42.0, Event::TaskArrival(i));
        }
        for want in 0..4096 {
            match e.pop() {
                Some((t, Event::TaskArrival(got))) => {
                    assert_eq!(t, 42.0);
                    assert_eq!(got, want);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(e.events_processed(), 4096);
        assert!(e.is_empty());
    }

    #[test]
    fn earliest_first_across_mixed_magnitudes() {
        let mut e = Engine::new();
        let times = [86_400.0, 0.5, 3_600.0, 0.5, 59.999, 60.0, 7.25];
        for (i, &t) in times.iter().enumerate() {
            e.schedule(t, Event::TaskArrival(i));
        }
        let popped: Vec<(f64, usize)> = std::iter::from_fn(|| e.pop())
            .map(|(t, ev)| match ev {
                Event::TaskArrival(i) => (t, i),
                _ => unreachable!(),
            })
            .collect();
        let ts: Vec<f64> = popped.iter().map(|&(t, _)| t).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{ts:?}");
        // the two 0.5s ties keep submission order (ids 1 then 3)
        assert_eq!(popped[0].1, 1);
        assert_eq!(popped[1].1, 3);
    }

    #[test]
    fn lanes_merge_by_time_then_seq() {
        // per-shard lanes must pop the exact stream one shared heap would
        let mut e = Engine::with_lanes(3, 8);
        e.schedule_on(1, 5.0, Event::TaskArrival(0)); // seq 1
        e.schedule_on(2, 3.0, Event::TaskArrival(1)); // seq 2
        e.schedule_on(0, 5.0, Event::TaskArrival(2)); // seq 3 (ties with seq 1)
        e.schedule_on(2, 1.0, Event::TaskArrival(3)); // seq 4
        let ids: Vec<_> = std::iter::from_fn(|| e.pop())
            .map(|(_, ev)| match ev {
                Event::TaskArrival(i) => i,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, vec![3, 1, 0, 2], "time order, FIFO-by-seq on ties");
        assert_eq!(e.events_processed(), 4);
    }

    #[test]
    fn lane_stream_identical_to_single_heap() {
        // same schedule sequence through 1 lane and through 4 lanes must pop
        // identically — the bit-determinism guarantee the sharded
        // coordinator relies on (DESIGN.md §9)
        let times = [7.0, 2.0, 2.0, 9.5, 0.0, 7.0, 3.25, 2.0];
        let mut single = Engine::new();
        let mut sharded = Engine::with_lanes(4, 8);
        for (i, &t) in times.iter().enumerate() {
            single.schedule(t, Event::TaskArrival(i));
            sharded.schedule_on(i % 4, t, Event::TaskArrival(i));
        }
        let drain = |e: &mut Engine| -> Vec<(u64, Event)> {
            std::iter::from_fn(|| e.pop()).map(|(t, ev)| (t.to_bits(), ev)).collect()
        };
        assert_eq!(drain(&mut single), drain(&mut sharded));
    }

    #[test]
    fn lanes_advance_one_clock() {
        let mut e = Engine::with_lanes(2, 4);
        e.schedule_on(1, 10.0, Event::MonitorSample);
        e.pop();
        assert_eq!(e.now(), 10.0);
        // now lane 0 scheduling is relative to the shared clock
        e.schedule_in_on(0, 5.0, Event::MonitorSample);
        let (t, _) = e.pop().unwrap();
        assert_eq!(t, 15.0);
        assert!(e.is_empty());
        assert_eq!(e.n_lanes(), 2);
    }

    #[test]
    fn version_guard_pattern() {
        // completions carry versions; the consumer drops stale ones
        let mut e = Engine::new();
        e.schedule(1.0, Event::Completion(0, 1));
        e.schedule(2.0, Event::Completion(0, 2));
        let current_version = 2u64;
        let mut fired = 0;
        while let Some((_, ev)) = e.pop() {
            if let Event::Completion(_, v) = ev {
                if v == current_version {
                    fired += 1;
                }
            }
        }
        assert_eq!(fired, 1);
    }

    #[test]
    fn tournament_index_matches_single_lane_under_random_interleaving() {
        // the index heap must produce exactly the single-heap stream under
        // arbitrary schedule/pop interleavings at many lane counts — this is
        // the invariant that keeps threaded runs byte-identical
        use crate::util::rng::Rng;
        for lanes in [2usize, 3, 7, 16, 64] {
            let mut rng = Rng::new(0xBEEF ^ lanes as u64);
            let mut single = Engine::new();
            let mut multi = Engine::with_lanes(lanes, 32);
            let mut popped_s = Vec::new();
            let mut popped_m = Vec::new();
            let mut id = 0usize;
            for _ in 0..2_000 {
                if rng.bool(0.6) || single.is_empty() {
                    // schedule at/after the current clock; coarse timestamps
                    // force plenty of exact ties
                    let t = single.now() + (rng.range_usize(0, 8) as f64) * 0.5;
                    single.schedule(t, Event::TaskArrival(id));
                    multi.schedule_on(rng.range_usize(0, lanes), t, Event::TaskArrival(id));
                    id += 1;
                } else {
                    popped_s.push(single.pop().map(|(t, ev)| (t.to_bits(), ev)));
                    popped_m.push(multi.pop().map(|(t, ev)| (t.to_bits(), ev)));
                }
            }
            while let Some(e) = single.pop() {
                popped_s.push(Some((e.0.to_bits(), e.1)));
            }
            while let Some(e) = multi.pop() {
                popped_m.push(Some((e.0.to_bits(), e.1)));
            }
            assert_eq!(popped_s, popped_m, "{lanes} lanes diverged");
            assert!(multi.is_empty() && single.is_empty());
        }
    }

    #[test]
    fn pop_frontier_drains_exactly_one_time_quantum() {
        let mut e = Engine::with_lanes(3, 8);
        e.schedule_on(0, 5.0, Event::TaskArrival(0));
        e.schedule_on(1, 5.0, Event::TaskArrival(1));
        e.schedule_on(2, 9.0, Event::TaskArrival(2));
        e.schedule_on(1, 5.0, Event::TaskArrival(3));
        let mut buf = Vec::new();
        assert_eq!(e.pop_frontier(&mut buf), 3);
        assert_eq!(e.now(), 5.0);
        let ids: Vec<usize> = buf
            .iter()
            .map(|(t, ev)| {
                assert_eq!(*t, 5.0);
                match ev {
                    Event::TaskArrival(i) => *i,
                    _ => unreachable!(),
                }
            })
            .collect();
        assert_eq!(ids, vec![0, 1, 3], "frontier keeps (time, seq) order");
        // an event scheduled AT the frontier time lands in the next quantum,
        // after everything already drained — exactly where a serial pop loop
        // would deliver it
        e.schedule_on(0, 5.0, Event::TaskArrival(4));
        assert_eq!(e.pop_frontier(&mut buf), 1);
        assert_eq!(e.now(), 5.0);
        assert!(matches!(buf[0], (_, Event::TaskArrival(4))));
        assert_eq!(e.pop_frontier(&mut buf), 1);
        assert!(matches!(buf[0], (_, Event::TaskArrival(2))));
        assert_eq!(e.pop_frontier(&mut buf), 0);
        assert!(buf.is_empty());
    }

    #[test]
    fn frontier_stream_equals_pop_stream() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let mut a = Engine::with_lanes(5, 16);
        let mut b = Engine::with_lanes(5, 16);
        for i in 0..500 {
            let lane = rng.range_usize(0, 5);
            let t = (rng.range_usize(0, 40) as f64) * 0.25;
            a.schedule_on(lane, t, Event::TaskArrival(i));
            b.schedule_on(lane, t, Event::TaskArrival(i));
        }
        let mut via_pop = Vec::new();
        while let Some((t, ev)) = a.pop() {
            via_pop.push((t.to_bits(), ev));
        }
        let mut via_frontier = Vec::new();
        let mut buf = Vec::new();
        while b.pop_frontier(&mut buf) > 0 {
            for (t, ev) in buf.drain(..) {
                via_frontier.push((t.to_bits(), ev));
            }
        }
        assert_eq!(via_pop, via_frontier);
    }

    #[test]
    fn lane_capacities_pre_size_every_lane() {
        // all lanes must be usable and pre-sized (no panics, normal merge)
        let mut e = Engine::with_lane_capacities(4, 128, 32);
        assert_eq!(e.n_lanes(), 4);
        for i in 0..64 {
            e.schedule_on(i % 4, (i / 4) as f64, Event::TaskArrival(i));
        }
        assert_eq!(e.len(), 64);
        let mut last = -1.0f64;
        while let Some((t, _)) = e.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(e.events_processed(), 64);
    }

    #[test]
    fn quantum_advances_only_on_strictly_later_times() {
        let mut e = Engine::new();
        e.schedule(0.0, Event::TaskArrival(0));
        e.schedule(0.0, Event::TaskArrival(1));
        e.schedule(1.0, Event::TaskArrival(2));
        e.schedule(1.0, Event::TaskArrival(3));
        e.schedule(2.5, Event::TaskArrival(4));
        assert_eq!(e.quantum(), 0);
        e.pop();
        e.pop();
        assert_eq!(e.quantum(), 0, "ties at t=0 share the initial quantum");
        e.pop();
        assert_eq!(e.quantum(), 1);
        e.pop();
        assert_eq!(e.quantum(), 1, "ties share a quantum");
        e.pop();
        assert_eq!(e.quantum(), 2);
    }

    #[test]
    fn quantum_treats_negative_zero_as_equal_time() {
        // regression for the snapshot cache-key fix: -0.0 and 0.0 have
        // different bit patterns but are the same instant — keying a cache
        // on now.to_bits() would silently rebuild between these two pops
        let mut e = Engine::new();
        assert_ne!((-0.0f64).to_bits(), 0.0f64.to_bits());
        e.schedule(-0.0, Event::TaskArrival(0));
        e.schedule(0.0, Event::TaskArrival(1));
        e.pop();
        let q0 = e.quantum();
        e.pop();
        assert_eq!(e.quantum(), q0, "-0.0 and 0.0 must share one quantum");
        assert_eq!(e.quantum(), 0);
    }

    #[test]
    fn arena_recycles_slots_and_reports_high_water() {
        let mut e = Engine::with_capacity(8);
        // steady-state schedule/pop cycles must reuse one slot forever
        for i in 0..1_000 {
            e.schedule_in(1.0, Event::TaskArrival(i));
            e.pop();
        }
        assert_eq!(e.stats().arena_high_water, 1, "free list recycles the slot");
        assert_eq!(e.stats().arena_reallocs, 0);
        // high water follows the max number of simultaneously pending events
        for i in 0..5 {
            e.schedule_in(1.0, Event::TaskArrival(i));
        }
        while e.pop().is_some() {}
        assert_eq!(e.stats().arena_high_water, 5);
    }

    #[test]
    fn presized_engine_never_reallocates_under_load() {
        // tournament + arena audit at scale: a correctly pre-sized engine
        // must not grow any lane heap or the arena mid-run, and the merged
        // stream must stay (time, seq)-ordered
        use crate::util::rng::Rng;
        const N: usize = 100_000;
        let lanes = 5;
        let per = N / lanes + 16;
        let mut e = Engine::with_lane_capacities(lanes, per, per);
        let mut rng = Rng::new(0xA11E);
        let mut pending = 0usize;
        let mut popped = 0usize;
        let mut last = (0.0f64, 0u64);
        let mut scheduled = 0usize;
        while scheduled < N || pending > 0 {
            if scheduled < N && (pending == 0 || rng.bool(0.55)) && pending < per {
                let t = e.now() + (rng.range_usize(0, 16) as f64) * 0.125;
                e.schedule_on(rng.range_usize(0, lanes), t, Event::TaskArrival(scheduled));
                scheduled += 1;
                pending += 1;
            } else {
                let (t, _) = e.pop().expect("pending events");
                assert!(t >= last.0);
                last = (t, 0);
                popped += 1;
                pending -= 1;
            }
        }
        assert_eq!(popped, N);
        let s = e.stats();
        assert_eq!(s.lane_reallocs, 0, "pre-sized lanes must never grow");
        assert_eq!(s.arena_reallocs, 0, "pre-sized arena must never grow");
        assert!(s.arena_high_water <= per);
    }
}
