//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `carma <subcommand> [positional...] [--key value] [--flag]`.
//! Flags and options may appear in any order after the subcommand.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Option names that take a value; everything else starting with `--` is a
/// boolean flag.
pub fn parse<I: IntoIterator<Item = String>>(
    argv: I,
    value_opts: &[&str],
) -> Result<Args, CliError> {
    let mut out = Args::default();
    let mut it = argv.into_iter().peekable();
    while let Some(arg) = it.next() {
        if let Some(name) = arg.strip_prefix("--") {
            // --key=value form
            if let Some((k, v)) = name.split_once('=') {
                if !value_opts.contains(&k) {
                    return Err(CliError(format!("unknown option --{k}")));
                }
                out.options.insert(k.to_string(), v.to_string());
                continue;
            }
            if value_opts.contains(&name) {
                let v = it
                    .next()
                    .ok_or_else(|| CliError(format!("option --{name} needs a value")))?;
                out.options.insert(name.to_string(), v);
            } else {
                out.flags.push(name.to_string());
            }
        } else if out.subcommand.is_none() {
            out.subcommand = Some(arg);
        } else {
            out.positional.push(arg);
        }
    }
    Ok(out)
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name} expects a number, got '{s}'"))),
        }
    }

    pub fn opt_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<u64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name} expects an integer, got '{s}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    const OPTS: &[&str] = &["policy", "seed", "trace"];

    #[test]
    fn basic() {
        let a = parse(argv("repro fig8 --policy magm --verbose"), OPTS).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("repro"));
        assert_eq!(a.positional, vec!["fig8"]);
        assert_eq!(a.opt("policy"), Some("magm"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn eq_form() {
        let a = parse(argv("run --seed=7"), OPTS).unwrap();
        assert_eq!(a.opt_u64("seed").unwrap(), Some(7));
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(argv("run --policy"), OPTS).is_err());
    }

    #[test]
    fn unknown_eq_option_errors() {
        assert!(parse(argv("run --nope=3"), OPTS).is_err());
    }

    #[test]
    fn numeric_validation() {
        let a = parse(argv("run --seed abc"), OPTS).unwrap();
        assert!(a.opt_u64("seed").is_err());
    }
}
