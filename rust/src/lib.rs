//! CARMA — Collocation-Aware Resource Manager with GPU Memory Estimator.
//!
//! Reproduction of the paper's system as a three-layer Rust + JAX + Pallas
//! stack (see DESIGN.md):
//!
//! * [`coordinator`] — the paper's contribution: task-level collocation-aware
//!   task→GPU mapping with policies, preconditions, monitoring and recovery;
//! * [`cluster`] + [`sim`] — the simulated substrate: an N-server cluster of
//!   A100 servers (DGX Station by default; segment allocator with real
//!   fragmentation, interference + power models, discrete-event engine,
//!   topology in DESIGN.md §8);
//! * [`estimators`] — Oracle / Horus / FakeTensor / GPUMemNet memory
//!   estimators; with the `pjrt` feature GPUMemNet runs AOT-compiled
//!   JAX+Pallas graphs through [`runtime`] (PJRT CPU, `xla` crate) — Python
//!   is never on this path; the default build serves the bit-deterministic
//!   classifier surrogate instead (DESIGN.md §5);
//! * [`workload`] — Table 3 model zoo, trace generators, submission parser,
//!   the memsim ground-truth mirror;
//! * [`experiments`] — one module per paper table/figure;
//! * [`util`], [`config`], [`cli`], [`bench`], [`testkit`] — infrastructure
//!   substrates built in-repo (the offline registry only carries the `xla`
//!   crate closure; DESIGN.md §1).

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod estimators;
pub mod experiments;
pub mod metrics;
pub mod obs;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;
pub mod workload;
