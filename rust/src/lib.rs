//! CARMA — Collocation-Aware Resource Manager with GPU Memory Estimator.
//!
//! Reproduction of the paper's system as a three-layer Rust + JAX + Pallas
//! stack (see DESIGN.md):
//!
//! * [`coordinator`] — the paper's contribution: task-level collocation-aware
//!   task→GPU mapping with policies, preconditions, monitoring and recovery;
//! * [`cluster`] + [`sim`] — the simulated 4×A100 DGX substrate (segment
//!   allocator with real fragmentation, interference + power models,
//!   discrete-event engine);
//! * [`estimators`] — Oracle / Horus / FakeTensor / GPUMemNet memory
//!   estimators; GPUMemNet runs AOT-compiled JAX+Pallas graphs through
//!   [`runtime`] (PJRT CPU, `xla` crate) — Python is never on this path;
//! * [`workload`] — Table 3 model zoo, trace generators, submission parser,
//!   the memsim ground-truth mirror;
//! * [`experiments`] — one module per paper table/figure;
//! * [`util`], [`config`], [`cli`], [`bench`], [`testkit`] — infrastructure
//!   substrates built in-repo (the offline registry only carries the `xla`
//!   crate closure; DESIGN.md §1).

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod estimators;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;
pub mod workload;
