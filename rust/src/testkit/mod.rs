//! Mini property-testing kit (proptest is unavailable offline — DESIGN.md §1).
//!
//! `forall` drives a generator through N cases; on failure it attempts a
//! bounded greedy shrink (re-generating with smaller size hints) and panics
//! with the seed + minimal counterexample debug string, so failures are
//! reproducible with `CARMA_PROP_SEED`.

use crate::util::rng::Rng;

/// Size-aware generator: `size` starts small and grows across cases, so
/// early cases are simple and later ones stress.
pub trait Gen {
    type Item: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng, size: usize) -> Self::Item;
}

impl<T, F> Gen for F
where
    T: std::fmt::Debug + Clone,
    F: Fn(&mut Rng, usize) -> T,
{
    type Item = T;
    fn generate(&self, rng: &mut Rng, size: usize) -> T {
        self(rng, size)
    }
}

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("CARMA_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config {
            cases: 64,
            seed,
            max_size: 50,
        }
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panic on first failure with
/// a shrunk counterexample.
pub fn forall_cfg<G, P>(cfg: &Config, gen: &G, prop: P)
where
    G: Gen,
    P: Fn(&G::Item) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let input = gen.generate(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // bounded shrink: try progressively smaller sizes with forked rngs
            let mut best: (G::Item, String) = (input, msg);
            'shrink: for shrink_size in (1..size).rev() {
                for attempt in 0..8 {
                    let mut r2 = Rng::new(cfg.seed ^ (attempt + 1) ^ ((shrink_size as u64) << 32));
                    let candidate = gen.generate(&mut r2, shrink_size);
                    if let Err(m) = prop(&candidate) {
                        best = (candidate, m);
                        continue 'shrink;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={}, case={}, rerun with CARMA_PROP_SEED={}):\n  input: {:?}\n  error: {}",
                cfg.seed, case, cfg.seed, best.0, best.1
            );
        }
    }
}

pub fn forall<G, P>(gen: &G, prop: P)
where
    G: Gen,
    P: Fn(&G::Item) -> Result<(), String>,
{
    forall_cfg(&Config::default(), gen, prop)
}

/// Assertion helpers returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let gen = |rng: &mut Rng, size: usize| rng.range_usize(0, size + 1);
        forall(&gen, |&x| {
            if x <= 50 {
                Ok(())
            } else {
                Err(format!("{x} > 50"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        let gen = |rng: &mut Rng, size: usize| rng.range_usize(0, size + 2);
        forall(&gen, |&x| {
            if x < 3 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn sizes_grow_within_bounds() {
        let cfg = Config {
            cases: 10,
            seed: 1,
            max_size: 100,
        };
        let gen = |_: &mut Rng, size: usize| size;
        forall_cfg(&cfg, &gen, |&s| {
            if (1..=100).contains(&s) {
                Ok(())
            } else {
                Err("size out of bounds".into())
            }
        });
    }
}
