//! Segment allocator for simulated GPU memory (S2).
//!
//! GPUs lack virtual memory (paper §1): a training task that cannot get
//! its reservation mapped crashes with OOM even when total free memory
//! would suffice.  This allocator reproduces that failure mode honestly:
//! best-fit over an explicit free list in 1 MiB granules (contiguous
//! `alloc`) plus page-backed `alloc_scatter` (a slab may span a bounded
//! number of holes), coalescing on free — so fragmentation *emerges* from
//! the allocation history (paper §4.2's motivating scenario is pinned as
//! a test below).

use std::collections::BTreeMap;

pub type SegId = u64;

#[derive(Debug, Clone)]
pub struct SegmentAllocator {
    capacity: u64,
    /// Free holes, keyed by offset -> length. BTreeMap keeps address order
    /// for coalescing.
    free: BTreeMap<u64, u64>,
    /// Live segments: id -> (offset, length).
    live: BTreeMap<SegId, (u64, u64)>,
    next_id: SegId,
    /// Cached Σ holes — read every monitor tick (hot path), updated on
    /// alloc/free (§Perf: replaces an O(#holes) walk per sample).
    free_sum: u64,
}

impl SegmentAllocator {
    /// `capacity` in MiB granules.
    pub fn new(capacity: u64) -> Self {
        let mut free = BTreeMap::new();
        if capacity > 0 {
            free.insert(0, capacity);
        }
        SegmentAllocator {
            capacity,
            free,
            live: BTreeMap::new(),
            next_id: 1,
            free_sum: capacity,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Total free MiB (what `nvidia-smi` would report). O(1) — cached.
    pub fn free_total(&self) -> u64 {
        self.free_sum
    }

    pub fn used_total(&self) -> u64 {
        self.capacity - self.free_total()
    }

    /// Largest contiguous hole — the real constraint for new allocations.
    pub fn largest_hole(&self) -> u64 {
        self.free.values().copied().max().unwrap_or(0)
    }

    pub fn live_segments(&self) -> usize {
        self.live.len()
    }

    /// Best-fit allocation (the CUDA driver/caching allocators approximate
    /// best-fit to limit fragmentation). Returns None on OOM (no hole
    /// fits), which for a GPU means the allocating task crashes.
    pub fn alloc(&mut self, len: u64) -> Option<SegId> {
        if len == 0 {
            return None;
        }
        let (off, hole_len) = self
            .free
            .iter()
            .filter(|(_, &l)| l >= len)
            .min_by_key(|(&o, &l)| (l, o))
            .map(|(&o, &l)| (o, l))?;
        self.free.remove(&off);
        if hole_len > len {
            self.free.insert(off + len, hole_len - len);
        }
        self.free_sum -= len;
        let id = self.next_id;
        self.next_id += 1;
        self.live.insert(id, (off, len));
        Some(id)
    }

    /// Scatter allocation: satisfy `len` from up to `max_frags` holes
    /// (largest-first).  Models CUDA's page-backed physical memory: a
    /// process's buffer need not be physically contiguous, but the mapping
    /// hardware bounds how shredded a large slab may be.  Returns None —
    /// an OOM for the allocating task — when the free memory is
    /// insufficient OR too fragmented (the paper's §4.2 scenario).
    pub fn alloc_scatter(&mut self, len: u64, max_frags: usize) -> Option<Vec<SegId>> {
        if len == 0 {
            return None;
        }
        if self.free_sum < len {
            return None;
        }
        // feasibility: do the `max_frags` largest holes cover `len`?
        let mut holes: Vec<u64> = self.free.values().copied().collect();
        holes.sort_unstable_by(|a, b| b.cmp(a));
        let coverage: u64 = holes.iter().take(max_frags).sum();
        if coverage < len {
            return None;
        }
        let mut remaining = len;
        let mut segs = Vec::new();
        while remaining > 0 {
            // take the largest hole
            let (&off, &hole_len) = self
                .free
                .iter()
                .max_by_key(|(&o, &l)| (l, std::cmp::Reverse(o)))
                .expect("feasibility checked");
            let take = hole_len.min(remaining);
            self.free.remove(&off);
            if hole_len > take {
                self.free.insert(off + take, hole_len - take);
            }
            self.free_sum -= take;
            let id = self.next_id;
            self.next_id += 1;
            self.live.insert(id, (off, take));
            segs.push(id);
            remaining -= take;
        }
        Some(segs)
    }

    /// Free a segment, coalescing with adjacent holes.
    pub fn free(&mut self, id: SegId) {
        let (off, len) = match self.live.remove(&id) {
            Some(x) => x,
            None => return, // double-free tolerated (recovery path)
        };
        self.free_sum += len;
        let mut new_off = off;
        let mut new_len = len;
        // coalesce with predecessor
        if let Some((&prev_off, &prev_len)) = self.free.range(..off).next_back() {
            if prev_off + prev_len == off {
                self.free.remove(&prev_off);
                new_off = prev_off;
                new_len += prev_len;
            }
        }
        // coalesce with successor
        if let Some((&next_off, &next_len)) = self.free.range(off + len..).next() {
            if off + len == next_off {
                self.free.remove(&next_off);
                new_len += next_len;
            }
        }
        self.free.insert(new_off, new_len);
    }

    /// Invariant check (used by property tests): holes are sorted, disjoint,
    /// non-adjacent (coalesced), and free+live cover exactly the capacity.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut prev_end: Option<u64> = None;
        for (&off, &len) in &self.free {
            if len == 0 {
                return Err(format!("zero-length hole at {off}"));
            }
            if let Some(pe) = prev_end {
                if off < pe {
                    return Err("overlapping holes".into());
                }
                if off == pe {
                    return Err(format!("uncoalesced holes at {off}"));
                }
            }
            prev_end = Some(off + len);
        }
        let mut spans: Vec<(u64, u64)> = self
            .free
            .iter()
            .map(|(&o, &l)| (o, l))
            .chain(self.live.values().copied())
            .collect();
        spans.sort_unstable();
        let mut cursor = 0;
        for (o, l) in spans {
            if o != cursor {
                return Err(format!("gap or overlap at {o} (expected {cursor})"));
            }
            cursor = o + l;
        }
        if cursor != self.capacity {
            return Err(format!("coverage ends at {cursor}, capacity {}", self.capacity));
        }
        let computed: u64 = self.free.values().sum();
        if computed != self.free_sum {
            return Err(format!("free_sum cache {} != computed {computed}", self.free_sum));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Rng;

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = SegmentAllocator::new(100);
        let s1 = a.alloc(40).unwrap();
        let s2 = a.alloc(40).unwrap();
        assert!(a.alloc(40).is_none()); // OOM
        assert_eq!(a.free_total(), 20);
        a.free(s1);
        a.free(s2);
        assert_eq!(a.free_total(), 100);
        assert_eq!(a.largest_hole(), 100); // fully coalesced
        a.check_invariants().unwrap();
    }

    #[test]
    fn paper_4_2_fragmentation_scenario() {
        // "free GPU memory fragmented in two chunks like 5GB and 4GB and a
        //  new task needs 8GB: monitors report 9GB free, but OOM happens."
        let gb = 1024;
        let mut a = SegmentAllocator::new(40 * gb);
        let head = a.alloc(5 * gb).unwrap(); // will become the 5GB hole
        let keep1 = a.alloc(26 * gb).unwrap(); // long-running resident task
        let tail = a.alloc(4 * gb).unwrap(); // will become the 4GB hole
        let _keep2 = a.alloc(5 * gb).unwrap();
        a.free(head);
        a.free(tail);
        assert_eq!(a.free_total(), 9 * gb); // monitor sees 9 GB free
        assert_eq!(a.largest_hole(), 5 * gb);
        assert!(a.alloc(8 * gb).is_none()); // ...but the 8 GB task OOMs
        let _ = keep1;
        a.check_invariants().unwrap();
    }

    #[test]
    fn best_fit_prefers_tightest_hole() {
        let mut a = SegmentAllocator::new(100);
        let s1 = a.alloc(10).unwrap();
        let _s2 = a.alloc(10).unwrap();
        a.free(s1); // 10-unit hole at 0, 80-unit hole at 20
        let s3 = a.alloc(5).unwrap();
        // best fit: s3 must sit in the tighter 10-unit hole (offset 0)
        assert_eq!(a.live.get(&s3).unwrap().0, 0);
        let s4 = a.alloc(8).unwrap();
        // 5-unit hole left at 5 cannot take 8 -> goes to the big hole
        assert_eq!(a.live.get(&s4).unwrap().0, 20);
        a.check_invariants().unwrap();
    }

    #[test]
    fn zero_len_rejected() {
        let mut a = SegmentAllocator::new(10);
        assert!(a.alloc(0).is_none());
    }

    #[test]
    fn double_free_is_noop() {
        let mut a = SegmentAllocator::new(10);
        let s = a.alloc(5).unwrap();
        a.free(s);
        a.free(s);
        assert_eq!(a.free_total(), 10);
        a.check_invariants().unwrap();
    }

    #[test]
    fn prop_invariants_under_random_workload() {
        let gen = |rng: &mut Rng, size: usize| {
            let ops: Vec<(bool, u64)> = (0..size * 4)
                .map(|_| (rng.bool(0.6), rng.range_u64(1, 64)))
                .collect();
            ops
        };
        testkit::forall(&gen, |ops| {
            let mut a = SegmentAllocator::new(1024);
            let mut ids: Vec<SegId> = Vec::new();
            for &(is_alloc, len) in ops {
                if is_alloc {
                    if let Some(id) = a.alloc(len) {
                        ids.push(id);
                    }
                } else if !ids.is_empty() {
                    let id = ids.remove((len as usize) % ids.len());
                    a.free(id);
                }
                a.check_invariants()?;
                if a.free_total() > 0 && a.largest_hole() == 0 {
                    return Err("free>0 but no hole".into());
                }
            }
            Ok(())
        });
    }
}
