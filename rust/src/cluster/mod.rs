//! Simulated cluster substrate (S2–S4, DESIGN.md §1, §8): N servers of
//! A100 GPUs with a fragmentation-capable segment allocator, the
//! per-collocation-mode interference model, and the power/energy model.

pub mod allocator;
pub mod fabric;
pub mod gpu;
pub mod interference;
pub mod power;
pub mod topology;

pub use allocator::{SegId, SegmentAllocator};
pub use fabric::{Fabric, LinkClass};
pub use gpu::{Gpu, ResidentTask, Server};
pub use interference::speed_factors;
pub use power::gpu_power_w;
pub use topology::{Cluster, ClusterTopology, ServerSpec};
