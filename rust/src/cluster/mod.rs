//! Simulated DGX Station A100 substrate (S2–S4, DESIGN.md §1):
//! GPU devices with a fragmentation-capable segment allocator, the
//! per-collocation-mode interference model, and the power/energy model.

pub mod allocator;
pub mod gpu;
pub mod interference;
pub mod power;

pub use allocator::{SegId, SegmentAllocator};
pub use gpu::{Gpu, ResidentTask, Server};
pub use interference::speed_factors;
pub use power::gpu_power_w;
