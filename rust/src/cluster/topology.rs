//! Cluster topology (DESIGN.md §8): N heterogeneous servers, globally
//! numbered GPUs.
//!
//! The substrate generalizes the paper's single DGX Station to a cluster:
//! every GPU carries a *global* id (server 0's GPUs first, then server 1's,
//! …), so the coordinator, monitor and recorder keep indexing by one flat id
//! while mapping decisions gain a server dimension (two-level mapping,
//! `coordinator::policy::select_two_level`). Non-gang multi-GPU tasks are
//! placed within one server — crossing the NVLink boundary is reserved for
//! explicitly gang-scheduled distributed jobs, which pay the fabric's
//! link costs for it (`cluster::fabric`, DESIGN.md §11).

use crate::config::schema::{ClusterConfig, ServerConfig};

use super::gpu::{Gpu, Server};

/// Static description of one server in the cluster.
#[derive(Debug, Clone)]
pub struct ServerSpec {
    pub id: usize,
    /// Global id of this server's first GPU.
    pub gpu_offset: usize,
    pub cfg: ServerConfig,
    /// Power envelope (W) shared by every server (from `ClusterConfig`).
    pub power_cap_w: Option<f64>,
}

impl ServerSpec {
    pub fn n_gpus(&self) -> usize {
        self.cfg.n_gpus
    }

    /// Does this server own global GPU id `g`?
    pub fn owns_gpu(&self, g: usize) -> bool {
        g >= self.gpu_offset && g < self.gpu_offset + self.cfg.n_gpus
    }
}

/// Immutable cluster shape derived from [`ClusterConfig`].
///
/// ```
/// use carma::config::schema::ClusterConfig;
/// use carma::cluster::topology::ClusterTopology;
///
/// let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(8, 4, 40.0));
/// assert_eq!(topo.n_servers(), 8);
/// assert_eq!(topo.total_gpus(), 32);
/// // GPU 13 lives on server 3 (global numbering: server 0 owns GPUs 0..4)
/// assert_eq!(topo.server_of_gpu(13), 3);
/// ```
#[derive(Debug, Clone)]
pub struct ClusterTopology {
    pub servers: Vec<ServerSpec>,
    total_gpus: usize,
}

impl ClusterTopology {
    pub fn from_config(cfg: &ClusterConfig) -> ClusterTopology {
        let mut servers = Vec::with_capacity(cfg.servers.len());
        let mut offset = 0;
        for (id, s) in cfg.servers.iter().enumerate() {
            servers.push(ServerSpec {
                id,
                gpu_offset: offset,
                cfg: s.clone(),
                power_cap_w: cfg.power_cap_w,
            });
            offset += s.n_gpus;
        }
        ClusterTopology {
            servers,
            total_gpus: offset,
        }
    }

    pub fn n_servers(&self) -> usize {
        self.servers.len()
    }

    pub fn total_gpus(&self) -> usize {
        self.total_gpus
    }

    /// Server index owning global GPU id `g`. Panics on out-of-range ids —
    /// those indicate a coordinator bug, not a recoverable condition.
    pub fn server_of_gpu(&self, g: usize) -> usize {
        assert!(g < self.total_gpus, "gpu {g} outside cluster ({} GPUs)", self.total_gpus);
        // clusters are small (≤ hundreds of servers); linear scan beats a
        // binary search for the sizes we simulate and stays trivially correct
        self.servers
            .iter()
            .position(|s| s.owns_gpu(g))
            .expect("offsets cover every gpu id")
    }

    /// Largest per-GPU memory on any server.
    pub fn max_server_mem_gb(&self) -> f64 {
        self.servers.iter().map(|s| s.cfg.mem_gb).fold(0.0, f64::max)
    }

    /// Static scheduling ceilings — `(max GPUs on one server, max memory one
    /// schedulable target offers)` — over servers that can ever admit work.
    /// A server whose idle draw (`idle_w × n_gpus`) already meets its power
    /// envelope is permanently filtered by the two-level mapper, so it must
    /// not count toward capacity: a task that only fits there would wait
    /// forever instead of failing fast.
    pub fn admissible_ceilings(&self, idle_w: f64) -> (usize, f64) {
        let mut max_gpus = 0usize;
        let mut max_gb = 0.0f64;
        for s in &self.servers {
            let idle_floor = idle_w * s.cfg.n_gpus as f64;
            if s.power_cap_w.is_some_and(|cap| idle_floor >= cap) {
                continue;
            }
            max_gpus = max_gpus.max(s.cfg.n_gpus);
            max_gb = max_gb.max(s.cfg.max_target_gb());
        }
        (max_gpus, max_gb)
    }
}

/// The live cluster: one [`Server`] of [`Gpu`]s per [`ServerSpec`], GPUs
/// globally numbered.
///
/// ```
/// use carma::config::schema::ClusterConfig;
/// use carma::cluster::topology::{Cluster, ClusterTopology};
///
/// let cluster = Cluster::new(ClusterTopology::from_config(
///     &ClusterConfig::homogeneous(2, 4, 40.0),
/// ));
/// assert_eq!(cluster.n_gpus(), 8);
/// // ids are global: server 1's first GPU is id 4
/// assert_eq!(cluster.servers[1].gpus[0].id, 4);
/// assert_eq!(cluster.gpu(6).id, 6);
/// ```
#[derive(Debug, Clone)]
pub struct Cluster {
    pub topo: ClusterTopology,
    pub servers: Vec<Server>,
}

impl Cluster {
    pub fn new(topo: ClusterTopology) -> Cluster {
        let servers = topo
            .servers
            .iter()
            .map(|s| Server::with_gpu_offset(&s.cfg, s.gpu_offset))
            .collect();
        Cluster { topo, servers }
    }

    pub fn n_gpus(&self) -> usize {
        self.topo.total_gpus()
    }

    pub fn n_servers(&self) -> usize {
        self.servers.len()
    }

    /// GPU by global id.
    pub fn gpu(&self, g: usize) -> &Gpu {
        let s = self.topo.server_of_gpu(g);
        let srv = &self.servers[s];
        &srv.gpus[g - self.topo.servers[s].gpu_offset]
    }

    pub fn gpu_mut(&mut self, g: usize) -> &mut Gpu {
        let s = self.topo.server_of_gpu(g);
        let off = self.topo.servers[s].gpu_offset;
        &mut self.servers[s].gpus[g - off]
    }

    /// All GPUs in global-id order.
    pub fn iter_gpus(&self) -> impl Iterator<Item = &Gpu> {
        self.servers.iter().flat_map(|s| s.gpus.iter())
    }

    /// Total live allocator segments across the cluster (debug/metrics).
    pub fn total_live_segments(&self) -> usize {
        self.servers.iter().map(|s| s.total_live_segments()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ClusterConfig;

    #[test]
    fn homogeneous_numbering() {
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(3, 4, 40.0));
        assert_eq!(topo.total_gpus(), 12);
        assert_eq!(topo.server_of_gpu(0), 0);
        assert_eq!(topo.server_of_gpu(3), 0);
        assert_eq!(topo.server_of_gpu(4), 1);
        assert_eq!(topo.server_of_gpu(11), 2);
        assert_eq!(topo.servers[2].gpu_offset, 8);
    }

    #[test]
    fn heterogeneous_numbering() {
        let mut cfg = ClusterConfig::homogeneous(2, 4, 40.0);
        cfg.servers[0].n_gpus = 2;
        cfg.servers[1].mem_gb = 80.0;
        let topo = ClusterTopology::from_config(&cfg);
        assert_eq!(topo.total_gpus(), 6);
        assert_eq!(topo.server_of_gpu(1), 0);
        assert_eq!(topo.server_of_gpu(2), 1);
        assert_eq!(topo.max_server_mem_gb(), 80.0);

        let cluster = Cluster::new(topo);
        assert_eq!(cluster.gpu(2).id, 2);
        assert!((cluster.gpu(2).free_gb() - 80.0).abs() < 1e-9);
        assert!((cluster.gpu(1).free_gb() - 40.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "outside cluster")]
    fn out_of_range_gpu_panics() {
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(1, 4, 40.0));
        topo.server_of_gpu(4);
    }

    #[test]
    fn mig_capacity() {
        let mut cfg = ClusterConfig::homogeneous(2, 4, 40.0);
        cfg.servers[1].mig_slices = vec![0.5, 0.25, 0.25];
        let topo = ClusterTopology::from_config(&cfg);
        assert_eq!(topo.admissible_ceilings(52.0), (4, 40.0)); // server 0 whole GPU
        cfg.servers[0].mig_slices = vec![0.5, 0.5];
        let topo = ClusterTopology::from_config(&cfg);
        assert_eq!(topo.admissible_ceilings(52.0), (4, 20.0));
    }

    #[test]
    fn power_capped_servers_excluded_from_ceilings() {
        // a big server whose idle draw meets the envelope can never admit —
        // it must not count toward the scheduling ceilings
        let mut cfg = ClusterConfig::homogeneous(2, 2, 40.0);
        cfg.servers[1] = crate::config::schema::ServerConfig {
            n_gpus: 8,
            mem_gb: 80.0,
            mig_slices: vec![],
        };
        cfg.power_cap_w = Some(300.0); // idle floors: 104 W (ok), 416 W (never)
        let topo = ClusterTopology::from_config(&cfg);
        assert_eq!(topo.admissible_ceilings(52.0), (2, 40.0));
        // without a cap both count
        cfg.power_cap_w = None;
        let topo = ClusterTopology::from_config(&cfg);
        assert_eq!(topo.admissible_ceilings(52.0), (8, 80.0));
    }

    #[test]
    fn gpu_mut_reaches_the_same_device() {
        let mut cluster = Cluster::new(ClusterTopology::from_config(
            &ClusterConfig::homogeneous(2, 2, 40.0),
        ));
        let seg = cluster.gpu_mut(3).alloc.alloc(1024).unwrap();
        assert!(cluster.gpu(3).free_gb() < 40.0);
        assert!((cluster.gpu(2).free_gb() - 40.0).abs() < 1e-9);
        cluster.gpu_mut(3).alloc.free(seg);
        assert!((cluster.gpu(3).free_gb() - 40.0).abs() < 1e-9);
    }
}
