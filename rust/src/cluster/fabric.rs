//! Interconnect fabric model (DESIGN.md §11): NVLink islands within a
//! server, PCIe across islands, NIC hops across servers.
//!
//! The cluster substrate (§8) models interference at the device level only;
//! distributed (gang-scheduled) jobs additionally contend on *links* — the
//! NVLink domain inside a server, the PCIe switch between islands, and the
//! server NIC for cross-server collectives (Elvinger et al.: interference
//! extends "one level deeper" than the SM). The fabric gives placement a
//! path-cost function to rank candidate GPU sets (fewer links crossed =
//! cheaper collectives) and tracks per-server NIC occupancy so concurrent
//! gangs sharing an uplink slow each other (`interference::fabric_factor`).
//!
//! Everything here is pure bookkeeping over the static topology — no
//! floating-point accumulation ordering depends on thread count, so the
//! deterministic-engine guarantee (§10) extends to fabric-aware runs.

use crate::config::schema::{FabricConfig, FabricProfile};
use crate::sim::TaskId;

use super::interference;
use super::topology::ClusterTopology;

/// Link classes a pair of GPUs can communicate over, cheapest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkClass {
    /// Same device (no fabric traffic).
    Local,
    /// Same NVLink island (intra-server, full-bandwidth domain).
    NvLink,
    /// Same server, different island (through the PCIe switch).
    Pcie,
    /// Different servers (through both NICs).
    Nic,
}

/// Static fabric shape + per-server NIC occupancy.
#[derive(Debug, Clone)]
pub struct Fabric {
    /// Server owning each global GPU id.
    gpu_server: Vec<usize>,
    /// Global island id of each GPU (islands numbered server 0 first).
    gpu_island: Vec<usize>,
    /// Server owning each island.
    island_server: Vec<usize>,
    /// Islands eligible for home-server affinity: the islands of power-
    /// *alive* servers (a server whose idle floor already meets its
    /// envelope can never host work, so cycling affinity onto it skews
    /// every run after a power-down). Defaults to every island.
    affinity_islands: Vec<usize>,
    /// Distinct servers among `affinity_islands`.
    alive_servers: usize,
    /// Islands per server (indexed lookup — `server_islands` sits on the
    /// per-decision placement path).
    server_island_count: Vec<usize>,
    /// GPUs per server (for [`Fabric::islands_matter`]).
    server_gpu_count: Vec<usize>,
    n_servers: usize,
    /// Per-GB transfer cost (1/bandwidth) for each link class.
    cost_intra_island: f64,
    cost_cross_island: f64,
    cost_cross_server: f64,
    /// Aggregate membw demand of running gangs on each server's NIC.
    nic_load: Vec<f64>,
    /// Per-server link-health multiplier (DESIGN.md §15): 1.0 healthy,
    /// `faults.degrade_factor` while a `LinkFault` is outstanding. Scales
    /// the NIC path cost and divides gang speed. Exactly 1.0 means every
    /// fabric expression reduces to its fault-free value bit-for-bit, so
    /// runs without faults stay byte-identical to pre-chaos builds.
    link_degrade: Vec<f64>,
    /// Contention slope / per-extra-server sync penalty (from `[fabric]`).
    contention_alpha: f64,
    cross_penalty: f64,
}

impl Fabric {
    pub fn new(topo: &ClusterTopology, cfg: &FabricConfig) -> Fabric {
        let mut gpu_server = Vec::with_capacity(topo.total_gpus());
        let mut gpu_island = Vec::with_capacity(topo.total_gpus());
        let mut island_server = Vec::new();
        let mut server_island_count = Vec::with_capacity(topo.n_servers());
        let mut server_gpu_count = Vec::with_capacity(topo.n_servers());
        for s in &topo.servers {
            let isl = cfg.island_gpus(s.cfg.n_gpus);
            let first_island = island_server.len();
            let n_islands = s.cfg.n_gpus.div_ceil(isl);
            server_island_count.push(n_islands);
            server_gpu_count.push(s.cfg.n_gpus);
            for _ in 0..n_islands {
                island_server.push(s.id);
            }
            for i in 0..s.cfg.n_gpus {
                gpu_server.push(s.id);
                gpu_island.push(first_island + i / isl);
            }
        }
        // FlatPcie has no NVLink domain: intra-island pairs pay PCIe cost
        let intra = match cfg.profile {
            FabricProfile::FlatPcie => 1.0 / cfg.pcie_gbps,
            _ => 1.0 / cfg.nvlink_gbps,
        };
        Fabric {
            gpu_server,
            gpu_island,
            affinity_islands: (0..island_server.len()).collect(),
            alive_servers: topo.n_servers(),
            server_island_count,
            server_gpu_count,
            island_server,
            n_servers: topo.n_servers(),
            cost_intra_island: intra,
            cost_cross_island: 1.0 / cfg.pcie_gbps,
            cost_cross_server: 1.0 / cfg.nic_gbps,
            nic_load: vec![0.0; topo.n_servers()],
            link_degrade: vec![1.0; topo.n_servers()],
            contention_alpha: cfg.contention_alpha,
            cross_penalty: cfg.cross_penalty,
        }
    }

    pub fn n_islands(&self) -> usize {
        self.island_server.len()
    }

    pub fn island_of(&self, gpu: usize) -> usize {
        self.gpu_island[gpu]
    }

    pub fn server_of(&self, gpu: usize) -> usize {
        self.gpu_server[gpu]
    }

    /// NVLink islands on one server (precomputed — this sits on the
    /// per-decision placement path).
    pub fn server_islands(&self, server: usize) -> usize {
        self.server_island_count[server]
    }

    /// Can island structure influence a placement on this server at all?
    /// Only when 1 < islands < GPUs: a single-island (nvlink) server's
    /// island-aware decision is definitionally the island-blind one, and a
    /// singleton-island (flat-pcie) server has no island that could host a
    /// multi-GPU set. The placement core turns its fabric terms off
    /// entirely when no admitted server passes this test, which is what
    /// makes the `--fabric-aware-singletons` switch a STRUCTURAL no-op on
    /// those substrates — NIC tie-breaks included (DESIGN.md §12).
    pub fn islands_matter(&self, server: usize) -> bool {
        let islands = self.server_island_count[server];
        islands > 1 && islands < self.server_gpu_count[server]
    }

    /// Restrict home-server affinity to the power-alive servers
    /// (`alive[s]` = server `s` can ever admit work under its envelope).
    /// Affinity cycles the surviving islands; with fewer than two alive
    /// servers no affinity remains and [`Fabric::home_server`] returns
    /// `None` (the shard router falls back to hashing).
    pub fn set_alive(&mut self, alive: &[bool]) {
        debug_assert_eq!(alive.len(), self.n_servers);
        self.affinity_islands = (0..self.island_server.len())
            .filter(|&i| alive.get(self.island_server[i]).copied().unwrap_or(true))
            .collect();
        let mut seen = vec![false; self.n_servers];
        self.alive_servers = 0;
        for &i in &self.affinity_islands {
            let s = self.island_server[i];
            if !seen[s] {
                seen[s] = true;
                self.alive_servers += 1;
            }
        }
    }

    /// Link class connecting two GPUs.
    pub fn link_class(&self, a: usize, b: usize) -> LinkClass {
        if a == b {
            LinkClass::Local
        } else if self.gpu_island[a] == self.gpu_island[b] {
            LinkClass::NvLink
        } else if self.gpu_server[a] == self.gpu_server[b] {
            LinkClass::Pcie
        } else {
            LinkClass::Nic
        }
    }

    /// Per-GB transfer cost between two GPUs (0 for the same device).
    /// Cross-server paths pay each endpoint's NIC separately, scaled by
    /// that server's link-health multiplier — a degraded uplink makes every
    /// placement through it look proportionally worse to the placement
    /// core, which is how fault avoidance steers gangs around flaky links.
    pub fn path_cost(&self, a: usize, b: usize) -> f64 {
        match self.link_class(a, b) {
            LinkClass::Local => 0.0,
            LinkClass::NvLink => self.cost_intra_island,
            LinkClass::Pcie => self.cost_cross_island,
            // cross-server traffic leaves one NIC and enters another
            LinkClass::Nic => {
                self.cost_cross_server
                    * (self.link_degrade[self.gpu_server[a]] + self.link_degrade[self.gpu_server[b]])
            }
        }
    }

    /// Cost of ANY candidate GPU set — spanning gangs and server-local
    /// singleton sets alike (the placement core's fabric term, DESIGN.md
    /// §12): the ring-all-reduce approximation, per-GB cost summed over
    /// consecutive pairs of the id-sorted set (plus the wrap link). Lower =
    /// tighter placement; 0 for sets of fewer than two devices.
    pub fn set_cost(&self, gpus: &[usize]) -> f64 {
        if gpus.len() < 2 {
            return 0.0;
        }
        let mut sorted = gpus.to_vec();
        sorted.sort_unstable();
        let mut cost = 0.0;
        for w in sorted.windows(2) {
            cost += self.path_cost(w[0], w[1]);
        }
        cost + self.path_cost(sorted[0], sorted[sorted.len() - 1])
    }

    /// [`Fabric::set_cost`] under its historical gang-side name.
    pub fn gang_cost(&self, gpus: &[usize]) -> f64 {
        self.set_cost(gpus)
    }

    /// Distinct islands a GPU set touches (the singleton placement metric
    /// beside `servers_spanned` for gangs).
    pub fn islands_spanned(&self, gpus: &[usize]) -> usize {
        let mut islands: Vec<usize> = gpus.iter().map(|&g| self.gpu_island[g]).collect();
        islands.sort_unstable();
        islands.dedup();
        islands.len()
    }

    /// Distinct servers a GPU set touches.
    pub fn servers_spanned(&self, gpus: &[usize]) -> usize {
        let mut seen = vec![false; self.n_servers];
        let mut n = 0;
        for &g in gpus {
            let s = self.gpu_server[g];
            if !seen[s] {
                seen[s] = true;
                n += 1;
            }
        }
        n
    }

    /// Home-server affinity for shard routing (DESIGN.md §11): arrivals
    /// cycle over fabric islands, islands belong to servers — so the
    /// `locality` strategy groups tasks by server topology rather than raw
    /// id stickiness. Cycles only the islands of power-*alive* servers
    /// ([`Fabric::set_alive`]); `None` when fewer than two alive servers
    /// remain (no affinity: the caller falls back to hashed routing).
    pub fn home_server(&self, task: TaskId) -> Option<usize> {
        if self.alive_servers <= 1 || self.affinity_islands.is_empty() {
            return None;
        }
        Some(self.island_server[self.affinity_islands[task % self.affinity_islands.len()]])
    }

    // -- link occupancy -----------------------------------------------------

    /// A gang spanning several servers starts driving collectives over
    /// every spanned server's NIC: add its bandwidth demand there.
    pub fn occupy_links(&mut self, gpus: &[usize], membw: f64) {
        for s in self.spanned_list(gpus) {
            self.nic_load[s] += membw;
        }
    }

    /// Inverse of [`Fabric::occupy_links`] — called when the gang releases.
    pub fn release_links(&mut self, gpus: &[usize], membw: f64) {
        for s in self.spanned_list(gpus) {
            self.nic_load[s] = (self.nic_load[s] - membw).max(0.0);
        }
    }

    pub fn nic_load(&self, server: usize) -> f64 {
        self.nic_load[server]
    }

    // -- link health (DESIGN.md §15) ----------------------------------------

    /// Set one server's link-health multiplier: 1.0 = healthy, >1.0 = a
    /// `LinkFault` is outstanding (per-GB NIC cost scales up, gang speed
    /// scales down). Called only from commit-side fault handlers, so the
    /// time-varying costs stay deterministic at any thread count.
    pub fn set_link_degrade(&mut self, server: usize, factor: f64) {
        debug_assert!(factor >= 1.0, "degrade factor below healthy: {factor}");
        self.link_degrade[server] = factor;
    }

    /// Current link-health multiplier of a server (1.0 when healthy).
    pub fn link_degrade(&self, server: usize) -> f64 {
        self.link_degrade[server]
    }

    /// Speed factor of a *running* gang on this placement: the cross-server
    /// synchronization penalty plus NIC contention from other gangs sharing
    /// any of its uplinks (`interference::fabric_factor`). 1.0 for
    /// server-local placements.
    pub fn gang_speed_factor(&self, gpus: &[usize], own_membw: f64) -> f64 {
        let spanned = self.spanned_list(gpus);
        if spanned.len() <= 1 {
            return 1.0;
        }
        let mut other = 0.0f64;
        let mut worst_degrade = 1.0f64;
        for &s in &spanned {
            other = other.max((self.nic_load[s] - own_membw).max(0.0));
            worst_degrade = worst_degrade.max(self.link_degrade[s]);
        }
        // the slowest uplink paces the collective: divide by the worst
        // link-health multiplier (exactly 1.0 when every link is healthy)
        interference::fabric_factor(spanned.len(), other, self.cross_penalty, self.contention_alpha)
            / worst_degrade
    }

    /// Sorted distinct servers of a GPU set.
    fn spanned_list(&self, gpus: &[usize]) -> Vec<usize> {
        let mut servers: Vec<usize> = gpus.iter().map(|&g| self.gpu_server[g]).collect();
        servers.sort_unstable();
        servers.dedup();
        servers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ClusterConfig;

    fn fabric(profile: FabricProfile, servers: usize, gpus: usize) -> Fabric {
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(servers, gpus, 40.0));
        let cfg = FabricConfig {
            profile,
            ..FabricConfig::default()
        };
        Fabric::new(&topo, &cfg)
    }

    #[test]
    fn link_classes_by_distance() {
        // 2 servers × 4 GPUs, dual islands of 2: 0-1 nvlink, 0-2 pcie, 0-4 nic
        let f = fabric(FabricProfile::DualIsland, 2, 4);
        assert_eq!(f.link_class(0, 0), LinkClass::Local);
        assert_eq!(f.link_class(0, 1), LinkClass::NvLink);
        assert_eq!(f.link_class(0, 2), LinkClass::Pcie);
        assert_eq!(f.link_class(0, 4), LinkClass::Nic);
        assert!(f.path_cost(0, 1) < f.path_cost(0, 2));
        assert!(f.path_cost(0, 2) < f.path_cost(0, 4));
        assert_eq!(f.path_cost(3, 3), 0.0);
    }

    #[test]
    fn default_profile_is_one_island_per_server() {
        let f = fabric(FabricProfile::NvlinkIsland, 2, 4);
        assert_eq!(f.n_islands(), 2);
        assert_eq!(f.link_class(0, 3), LinkClass::NvLink);
        assert_eq!(f.link_class(0, 4), LinkClass::Nic);
    }

    #[test]
    fn flat_pcie_has_no_nvlink_advantage() {
        let f = fabric(FabricProfile::FlatPcie, 1, 4);
        // every intra-server pair pays the PCIe cost
        assert_eq!(f.path_cost(0, 1), f.path_cost(0, 3));
        assert!(f.path_cost(0, 1) > 1.0 / 300.0);
    }

    #[test]
    fn gang_cost_prefers_tighter_placements() {
        let f = fabric(FabricProfile::NvlinkIsland, 2, 4);
        let local = f.gang_cost(&[0, 1, 2, 3]);
        let split = f.gang_cost(&[0, 1, 4, 5]);
        assert!(local < split, "server-local {local} !< cross-server {split}");
        assert_eq!(f.gang_cost(&[2]), 0.0);
        assert_eq!(f.servers_spanned(&[0, 1, 2, 3]), 1);
        assert_eq!(f.servers_spanned(&[0, 1, 4, 5]), 2);
    }

    #[test]
    fn home_server_cycles_islands_and_falls_back_when_single() {
        let f = fabric(FabricProfile::DualIsland, 2, 4);
        // 4 islands: tasks 0..4 land on servers 0,0,1,1 then wrap
        assert_eq!(f.home_server(0), Some(0));
        assert_eq!(f.home_server(1), Some(0));
        assert_eq!(f.home_server(2), Some(1));
        assert_eq!(f.home_server(3), Some(1));
        assert_eq!(f.home_server(4), Some(0));
        let single = fabric(FabricProfile::NvlinkIsland, 1, 4);
        assert_eq!(single.home_server(7), None, "no affinity on one server");
    }

    #[test]
    fn set_cost_counts_island_crossings() {
        // dual-island 1×4: pair inside island 0 rides NVLink; a split pair
        // pays PCIe both ways — the singleton placement core ranks on this
        let f = fabric(FabricProfile::DualIsland, 1, 4);
        assert_eq!(f.server_islands(0), 2);
        assert!(f.set_cost(&[0, 1]) < f.set_cost(&[1, 2]));
        assert_eq!(f.set_cost(&[0, 1]), f.gang_cost(&[0, 1]), "gang_cost is the alias");
        assert_eq!(f.islands_spanned(&[0, 1]), 1);
        assert_eq!(f.islands_spanned(&[1, 2]), 2);
        assert_eq!(f.islands_spanned(&[3]), 1);
        let single = fabric(FabricProfile::NvlinkIsland, 2, 4);
        assert_eq!(single.server_islands(0), 1);
        assert_eq!(single.server_islands(1), 1);
        // islands matter only strictly between 1 and the GPU count:
        // dual-island yes; nvlink (1 island) and flat-pcie (all singleton
        // islands) definitionally decide like the blind pipeline
        assert!(f.islands_matter(0));
        assert!(!single.islands_matter(0));
        let flat = fabric(FabricProfile::FlatPcie, 1, 4);
        assert_eq!(flat.server_islands(0), 4);
        assert!(!flat.islands_matter(0));
    }

    #[test]
    fn dead_servers_drop_out_of_affinity() {
        // 3 servers, dual islands: 6 islands cycling servers 0,0,1,1,2,2.
        // Server 1 powers down -> affinity cycles the 4 surviving islands.
        let mut f = fabric(FabricProfile::DualIsland, 3, 4);
        assert_eq!(f.home_server(2), Some(1));
        f.set_alive(&[true, false, true]);
        let homes: Vec<usize> = (0..4).map(|t| f.home_server(t).unwrap()).collect();
        assert_eq!(homes, vec![0, 0, 2, 2]);
        assert_eq!(f.home_server(4), Some(0), "cycle wraps over alive islands only");
        // one alive server left: no affinity remains
        f.set_alive(&[true, false, false]);
        assert_eq!(f.home_server(0), None);
    }

    #[test]
    fn link_occupancy_roundtrip_and_contention() {
        let mut f = fabric(FabricProfile::NvlinkIsland, 2, 4);
        let gang = [0usize, 1, 4, 5]; // spans both servers
        f.occupy_links(&gang, 0.4);
        assert!((f.nic_load(0) - 0.4).abs() < 1e-12);
        assert!((f.nic_load(1) - 0.4).abs() < 1e-12);
        // alone on the link: sync penalty only, no contention term
        let solo = f.gang_speed_factor(&gang, 0.4);
        assert!(solo < 1.0 && solo > 0.5, "cross-server sync penalty: {solo}");
        // a second gang on the same uplinks adds contention
        let gang2 = [2usize, 3, 6, 7];
        f.occupy_links(&gang2, 0.5);
        let contended = f.gang_speed_factor(&gang, 0.4);
        assert!(contended < solo, "shared NIC must slow the gang: {contended} !< {solo}");
        f.release_links(&gang2, 0.5);
        assert!((f.gang_speed_factor(&gang, 0.4) - solo).abs() < 1e-12);
        f.release_links(&gang, 0.4);
        assert_eq!(f.nic_load(0), 0.0);
        // server-local placements never pay fabric costs
        assert_eq!(f.gang_speed_factor(&[0, 1, 2, 3], 0.9), 1.0);
    }

    #[test]
    fn link_degradation_scales_costs_and_speed() {
        let mut f = fabric(FabricProfile::NvlinkIsland, 2, 4);
        let gang = [0usize, 1, 4, 5];
        let healthy_cost = f.path_cost(0, 4);
        let healthy_speed = f.gang_speed_factor(&gang, 0.4);
        // degrade server 1's uplink 4x: cross-server paths touching it get
        // pricier, the spanning gang slows, intra-server paths are untouched
        f.set_link_degrade(1, 4.0);
        assert_eq!(f.link_degrade(1), 4.0);
        assert!(f.path_cost(0, 4) > healthy_cost);
        assert_eq!(f.path_cost(0, 1), fabric(FabricProfile::NvlinkIsland, 2, 4).path_cost(0, 1));
        let degraded_speed = f.gang_speed_factor(&gang, 0.4);
        assert!(
            degraded_speed < healthy_speed,
            "degraded uplink must slow the gang: {degraded_speed} !< {healthy_speed}"
        );
        // repair restores the fault-free numbers bit-for-bit
        f.set_link_degrade(1, 1.0);
        assert_eq!(f.path_cost(0, 4).to_bits(), healthy_cost.to_bits());
        assert_eq!(f.gang_speed_factor(&gang, 0.4).to_bits(), healthy_speed.to_bits());
    }
}
