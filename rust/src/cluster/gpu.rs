//! GPU device + server model (S2).

use crate::config::schema::{CollocationMode, InterferenceConfig, ServerConfig};
use crate::sim::TaskId;
use crate::util::units::{gb_to_mib, mib_to_gb};

use super::allocator::{SegId, SegmentAllocator};
use super::interference::{self, Demand};

/// A task currently resident on (dispatched to) a GPU.
#[derive(Debug, Clone)]
pub struct ResidentTask {
    pub task: TaskId,
    /// Solo SM-activity demand (from the model zoo).
    pub smact: f64,
    /// Solo memory-bandwidth demand.
    pub membw: f64,
    /// MIG instance index (0 when MIG is off).
    pub instance: usize,
    /// Dispatch time — SM activity ramps up over the training warm-up
    /// (data loading, cuDNN autotune), which is what the monitor's window
    /// actually observes.
    pub dispatched_at: f64,
}

/// Seconds for a freshly dispatched task's SM activity to reach its solo
/// level. The monitor's 60 s window therefore *lags* — the reason the
/// paper's preconditioned runs still admit a few tasks too many (Table 4/6).
pub const SMACT_RAMP_S: f64 = 120.0;

/// One simulated A100 (40 GB HBM2, Table 2).
#[derive(Debug, Clone)]
pub struct Gpu {
    pub id: usize,
    pub alloc: SegmentAllocator,
    pub resident: Vec<ResidentTask>,
    /// MIG instance compute fractions (empty = MIG disabled). CARMA never
    /// reconfigures instances, it only dispatches to them (paper §4.4).
    pub mig_slices: Vec<f64>,
    /// Which task occupies each MIG instance (exclusive dispatch).
    pub mig_occupancy: Vec<Option<TaskId>>,
}

impl Gpu {
    pub fn new(id: usize, mem_gb: f64, mig_slices: Vec<f64>) -> Self {
        let occ = vec![None; mig_slices.len()];
        Gpu {
            id,
            alloc: SegmentAllocator::new(gb_to_mib(mem_gb)),
            resident: Vec::new(),
            mig_slices,
            mig_occupancy: occ,
        }
    }

    pub fn mig_enabled(&self) -> bool {
        !self.mig_slices.is_empty()
    }

    pub fn free_gb(&self) -> f64 {
        mib_to_gb(self.alloc.free_total())
    }

    pub fn used_gb(&self) -> f64 {
        mib_to_gb(self.alloc.used_total())
    }

    pub fn largest_hole_gb(&self) -> f64 {
        mib_to_gb(self.alloc.largest_hole())
    }

    /// Total HBM capacity of this GPU (GB).
    pub fn capacity_gb(&self) -> f64 {
        mib_to_gb(self.alloc.capacity())
    }

    pub fn n_tasks(&self) -> usize {
        self.resident.len()
    }

    /// Steady-state demands (interference / speed computation).
    fn demands(&self) -> Vec<Demand> {
        self.resident
            .iter()
            .map(|r| Demand {
                smact: r.smact,
                membw: r.membw,
                instance_frac: if self.mig_enabled() {
                    self.mig_slices[r.instance]
                } else {
                    1.0
                },
            })
            .collect()
    }

    /// (task, speed factor) for every resident task under `mode`.
    pub fn speeds(
        &self,
        mode: CollocationMode,
        cfg: &InterferenceConfig,
    ) -> Vec<(TaskId, f64)> {
        let d = self.demands();
        let f = interference::speed_factors(mode, &d, cfg);
        self.resident
            .iter()
            .zip(f)
            .map(|(r, s)| (r.task, s))
            .collect()
    }

    /// Effective SM activity as a DCGM monitor would report it at `now`
    /// (warm-up ramp included).  Allocation-free: this runs once per GPU
    /// per 1 Hz monitor tick — the simulator's hottest loop (§Perf).
    pub fn effective_smact(&self, mode: CollocationMode, now: f64) -> f64 {
        if self.resident.is_empty() {
            return 0.0;
        }
        let ramped = |r: &ResidentTask| {
            r.smact * ((now - r.dispatched_at) / SMACT_RAMP_S).clamp(0.0, 1.0)
        };
        match mode {
            CollocationMode::Mps => {
                1.0 - self
                    .resident
                    .iter()
                    .map(|r| 1.0 - ramped(r).min(1.0))
                    .product::<f64>()
            }
            CollocationMode::Streams => {
                self.resident.iter().map(ramped).sum::<f64>().min(1.0)
            }
            CollocationMode::Mig => self
                .resident
                .iter()
                .map(|r| ramped(r).min(self.mig_slices[r.instance]))
                .sum::<f64>()
                .min(1.0),
        }
    }

    /// Find a free MIG instance with at least `frac_needed` compute if any.
    pub fn free_mig_instance(&self) -> Option<usize> {
        self.mig_occupancy.iter().position(|o| o.is_none())
    }

    pub fn add_resident(&mut self, r: ResidentTask) {
        if self.mig_enabled() {
            debug_assert!(self.mig_occupancy[r.instance].is_none());
            self.mig_occupancy[r.instance] = Some(r.task);
        }
        self.resident.push(r);
    }

    pub fn remove_resident(&mut self, task: TaskId) {
        if let Some(pos) = self.resident.iter().position(|r| r.task == task) {
            let r = self.resident.swap_remove(pos);
            if self.mig_enabled() {
                self.mig_occupancy[r.instance] = None;
            }
        }
    }
}

/// The simulated server: N GPUs (DGX Station A100: 4). In a cluster the
/// GPUs carry *global* ids (see `cluster::topology`, which owns the
/// id-offset bookkeeping).
#[derive(Debug, Clone)]
pub struct Server {
    pub gpus: Vec<Gpu>,
}

impl Server {
    pub fn new(cfg: &ServerConfig) -> Self {
        Self::with_gpu_offset(cfg, 0)
    }

    /// Build with globally numbered GPUs: ids `offset..offset + n_gpus`.
    pub fn with_gpu_offset(cfg: &ServerConfig, offset: usize) -> Self {
        Server {
            gpus: (0..cfg.n_gpus)
                .map(|i| Gpu::new(offset + i, cfg.mem_gb, cfg.mig_slices.clone()))
                .collect(),
        }
    }

    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn idle_gpus(&self) -> Vec<usize> {
        self.gpus
            .iter()
            .filter(|g| g.resident.is_empty())
            .map(|g| g.id)
            .collect()
    }

    /// Total segments live across the server (debug/metrics).
    pub fn total_live_segments(&self) -> usize {
        self.gpus.iter().map(|g| g.alloc.live_segments()).sum()
    }
}

/// Segments a task holds on one GPU (owned by the task runtime so an OOM or
/// completion can free everything it allocated).
#[derive(Debug, Clone, Default)]
pub struct TaskSegments {
    pub gpu: usize,
    pub segs: Vec<SegId>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ServerConfig;

    fn server() -> Server {
        Server::new(&ServerConfig {
            n_gpus: 4,
            mem_gb: 40.0,
            mig_slices: vec![],
        })
    }

    #[test]
    fn construction() {
        let s = server();
        assert_eq!(s.n_gpus(), 4);
        assert_eq!(s.idle_gpus(), vec![0, 1, 2, 3]);
        assert!((s.gpus[0].free_gb() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn residency_tracking() {
        let mut s = server();
        s.gpus[1].add_resident(ResidentTask {
            task: 7,
            smact: 0.5,
            membw: 0.4,
            instance: 0,
            dispatched_at: 0.0,
        });
        assert_eq!(s.idle_gpus(), vec![0, 2, 3]);
        assert_eq!(s.gpus[1].n_tasks(), 1);
        assert!(s.gpus[1].effective_smact(CollocationMode::Mps, 1e9) > 0.4);
        s.gpus[1].remove_resident(7);
        assert_eq!(s.idle_gpus(), vec![0, 1, 2, 3]);
        assert_eq!(s.gpus[1].effective_smact(CollocationMode::Mps, 1e9), 0.0);
    }

    #[test]
    fn speeds_collocated() {
        let mut g = Gpu::new(0, 40.0, vec![]);
        for t in 0..2 {
            g.add_resident(ResidentTask {
                task: t,
                smact: 0.4,
                membw: 0.3,
                instance: 0,
                dispatched_at: 0.0,
            });
        }
        let sp = g.speeds(CollocationMode::Mps, &InterferenceConfig::default());
        assert_eq!(sp.len(), 2);
        assert!(sp[0].1 > 0.85 && sp[0].1 < 1.0);
    }

    #[test]
    fn mig_instances() {
        let mut g = Gpu::new(0, 40.0, vec![0.5, 0.25, 0.25]);
        assert!(g.mig_enabled());
        let i = g.free_mig_instance().unwrap();
        g.add_resident(ResidentTask {
            task: 1,
            smact: 0.3,
            membw: 0.2,
            instance: i,
            dispatched_at: 0.0,
        });
        assert_eq!(g.free_mig_instance(), Some(1));
        g.add_resident(ResidentTask {
            task: 2,
            smact: 0.3,
            membw: 0.2,
            instance: 1,
            dispatched_at: 0.0,
        });
        g.add_resident(ResidentTask {
            task: 3,
            smact: 0.3,
            membw: 0.2,
            instance: 2,
            dispatched_at: 0.0,
        });
        assert_eq!(g.free_mig_instance(), None);
        g.remove_resident(2);
        assert_eq!(g.free_mig_instance(), Some(1));
    }

    #[test]
    fn allocation_affects_free_gb() {
        let mut g = Gpu::new(0, 40.0, vec![]);
        let seg = g.alloc.alloc(gb_to_mib(13.5)).unwrap();
        assert!((g.free_gb() - 26.5).abs() < 0.01);
        g.alloc.free(seg);
        assert!((g.free_gb() - 40.0).abs() < 1e-9);
    }
}
