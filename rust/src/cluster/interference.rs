//! Interference model: per-task speed factors under collocation (S3).
//!
//! Calibrated to the qualitative findings of [31] (paper §2.1 / §5.2):
//!
//! * **MPS** — fine-grained SM sharing.  Below compute saturation
//!   (ΣSMACT ≤ 1) tasks run near full speed with a mild cache/bandwidth
//!   interference term; above saturation each task's speed degrades to its
//!   proportional compute share (1/Σ).  Memory-bandwidth oversubscription
//!   adds a second contention term.
//! * **streams** — default-stream submission serializes kernels: tasks
//!   time-share the whole GPU, so n collocated tasks each run at ~1/n plus
//!   a context-switch penalty ("execution time may become longer than
//!   back-to-back", paper §2.1).  Waiting time still improves because
//!   everything starts immediately — exactly the Fig. 8 streams result.
//! * **MIG** — isolated instances: no cross-task interference; a task whose
//!   solo SMACT exceeds its instance's compute fraction is slowed
//!   proportionally (reduced capacity, paper §2.1).

use crate::config::schema::{CollocationMode, InterferenceConfig};

/// Per-task demand as observed when running alone.
#[derive(Debug, Clone, Copy)]
pub struct Demand {
    /// Solo SM activity (0..1).
    pub smact: f64,
    /// Solo memory-bandwidth utilization (0..1).
    pub membw: f64,
    /// MIG compute fraction of the instance the task runs in (1.0 = whole
    /// GPU / MIG off).
    pub instance_frac: f64,
}

/// Speed factor (0..1] for every co-resident task on one GPU.
pub fn speed_factors(
    mode: CollocationMode,
    tasks: &[Demand],
    cfg: &InterferenceConfig,
) -> Vec<f64> {
    match mode {
        CollocationMode::Mps => mps(tasks, cfg),
        CollocationMode::Streams => streams(tasks, cfg),
        CollocationMode::Mig => mig(tasks),
    }
}

/// Per-co-runner MPS scheduling overhead (context switching, L2 thrash —
/// grows with the *number* of clients, independent of their load).
const MPS_PER_CLIENT_PENALTY: f64 = 0.05;

fn mps(tasks: &[Demand], cfg: &InterferenceConfig) -> Vec<f64> {
    let d: f64 = tasks.iter().map(|t| t.smact).sum();
    let b: f64 = tasks.iter().map(|t| t.membw).sum();
    let n = tasks.len() as f64;
    tasks
        .iter()
        .map(|t| {
            // compute share: full speed until the SMs saturate, then
            // proportional sharing
            let compute = if d <= 1.0 { 1.0 } else { 1.0 / d };
            // cache / L2 / scheduler interference from co-runners
            let others = (d - t.smact).max(0.0);
            let interf =
                1.0 / (1.0 + cfg.mps_alpha * others + MPS_PER_CLIENT_PENALTY * (n - 1.0));
            // HBM bandwidth contention once oversubscribed
            let bw = 1.0 / (1.0 + cfg.membw_alpha * (b - 1.0).max(0.0));
            compute * interf * bw
        })
        .collect()
}

/// Streams contend far harder than MPS: no client-server QoS, kernels from
/// different processes thrash SMs/L2 when they overlap and serialize when
/// they don't.
const STREAMS_ALPHA_FACTOR: f64 = 9.0;

fn streams(tasks: &[Demand], cfg: &InterferenceConfig) -> Vec<f64> {
    if tasks.len() <= 1 {
        return vec![1.0; tasks.len()];
    }
    let n = tasks.len() as f64;
    let d: f64 = tasks.iter().map(|t| t.smact).sum();
    let b: f64 = tasks.iter().map(|t| t.membw).sum();
    let alpha = cfg.mps_alpha * STREAMS_ALPHA_FACTOR;
    tasks
        .iter()
        .map(|t| {
            let compute = if d <= 1.0 { 1.0 } else { 1.0 / d };
            // launch/sync serialization on top of the contention term —
            // heavy pairs end at or below back-to-back throughput
            // ("execution time may become longer than back-to-back", §2.1;
            // Fig. 8a: streams ≈ marginal total-time benefit vs Exclusive)
            let penalty = 1.0 / (1.0 + cfg.streams_penalty * (n - 1.0));
            let others = (d - t.smact).max(0.0);
            let interf = 1.0 / (1.0 + alpha * others);
            let bw = 1.0 / (1.0 + cfg.membw_alpha * (b - 1.0).max(0.0));
            compute * penalty * interf * bw
        })
        .collect()
}

fn mig(tasks: &[Demand]) -> Vec<f64> {
    tasks
        .iter()
        .map(|t| {
            // isolation: only the instance's reduced capacity matters
            if t.smact <= t.instance_frac {
                1.0
            } else {
                (t.instance_frac / t.smact).max(0.05)
            }
        })
        .collect()
}

/// Cross-GPU (fabric) interference term (DESIGN.md §11): the speed factor a
/// *distributed* gang pays for running across servers. Two components, both
/// below the SM level (Elvinger et al.):
///
/// * a synchronization penalty growing with the number of servers spanned —
///   every collective crosses the NIC instead of staying in the NVLink
///   domain;
/// * a contention term from *other* gangs' aggregate bandwidth demand on
///   the busiest NIC this gang shares (`Fabric` tracks link occupancy).
///
/// Server-local placements (spanned <= 1) never pay either term.
pub fn fabric_factor(
    spanned_servers: usize,
    other_nic_load: f64,
    cross_penalty: f64,
    contention_alpha: f64,
) -> f64 {
    if spanned_servers <= 1 {
        return 1.0;
    }
    let sync = 1.0 / (1.0 + cross_penalty * (spanned_servers as f64 - 1.0));
    let contention = 1.0 / (1.0 + contention_alpha * other_nic_load.max(0.0));
    sync * contention
}

/// Effective GPU-level SM activity for monitoring/power: fraction of time at
/// least one warp is active (paper §5.1.3).
pub fn effective_smact(mode: CollocationMode, tasks: &[Demand]) -> f64 {
    if tasks.is_empty() {
        return 0.0;
    }
    match mode {
        // SMACT = fraction of time at least one warp is active (§5.1.3);
        // with MPS the tasks' active phases overlap ~independently, so the
        // observed activity is 1 - Π(1 - s_i), NOT the sum — two 0.6-SMACT
        // tasks read ~0.84, which is also why the paper's 80 % cap keeps
        // collocated GPUs out of the >90 % high-power mode (§4.4)
        CollocationMode::Mps => {
            1.0 - tasks.iter().map(|t| 1.0 - t.smact.min(1.0)).product::<f64>()
        }
        // serialized default-stream kernels cannot overlap: active time
        // accumulates additively up to saturation — the monitor reads high
        // and the GPU burns power serving interleaved kernels
        CollocationMode::Streams => tasks.iter().map(|t| t.smact).sum::<f64>().min(1.0),
        // instances are independent; report aggregate occupied fraction
        CollocationMode::Mig => tasks
            .iter()
            .map(|t| t.smact.min(t.instance_frac))
            .sum::<f64>()
            .min(1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> InterferenceConfig {
        InterferenceConfig::default()
    }

    fn d(smact: f64) -> Demand {
        Demand {
            smact,
            membw: smact * 0.9,
            instance_frac: 1.0,
        }
    }

    #[test]
    fn solo_task_full_speed() {
        for mode in [CollocationMode::Mps, CollocationMode::Streams, CollocationMode::Mig] {
            let f = speed_factors(mode, &[d(0.6)], &cfg());
            assert!((f[0] - 1.0).abs() < 1e-9, "{mode:?}");
        }
    }

    #[test]
    fn mps_light_pair_near_full_speed() {
        let f = speed_factors(CollocationMode::Mps, &[d(0.3), d(0.3)], &cfg());
        assert!(f[0] > 0.9 && f[0] < 1.0, "light MPS pair should barely slow: {f:?}");
    }

    #[test]
    fn mps_oversubscription_degrades_proportionally() {
        let f = speed_factors(CollocationMode::Mps, &[d(0.8), d(0.8)], &cfg());
        assert!(f[0] < 0.65, "oversubscribed MPS must slow: {f:?}");
        assert!(f[0] > 0.4);
    }

    #[test]
    fn mps_asymmetric_hurts_light_task_more() {
        let f = speed_factors(CollocationMode::Mps, &[d(0.2), d(0.9)], &cfg());
        // the light task suffers more interference from the heavy co-runner
        assert!(f[0] < f[1], "{f:?}");
    }

    #[test]
    fn streams_heavy_pair_at_most_back_to_back() {
        // two medium tasks: aggregate throughput must not beat serial
        // execution ("may become longer than back-to-back", §2.1)
        let f = speed_factors(CollocationMode::Streams, &[d(0.6), d(0.6)], &cfg());
        assert!(f[0] <= 0.5 + 1e-9, "streams thrash: {f:?}");
        assert!((f[0] - f[1]).abs() < 1e-12);
    }

    #[test]
    fn streams_worse_than_mps() {
        for demand in [0.3, 0.6, 0.9] {
            let s = speed_factors(CollocationMode::Streams, &[d(demand), d(demand)], &cfg());
            let m = speed_factors(CollocationMode::Mps, &[d(demand), d(demand)], &cfg());
            assert!(m[0] > s[0] * 1.15, "demand {demand}: mps={m:?} streams={s:?}");
        }
    }

    #[test]
    fn mig_isolated_no_interference() {
        let t = Demand {
            smact: 0.3,
            membw: 0.3,
            instance_frac: 0.5,
        };
        let f = speed_factors(CollocationMode::Mig, &[t, t, t], &cfg());
        assert!(f.iter().all(|&x| (x - 1.0).abs() < 1e-9));
    }

    #[test]
    fn mig_reduced_capacity_slows_heavy_task() {
        let t = Demand {
            smact: 0.9,
            membw: 0.5,
            instance_frac: 0.4,
        };
        let f = speed_factors(CollocationMode::Mig, &[t], &cfg());
        assert!((f[0] - 0.4 / 0.9).abs() < 1e-9);
    }

    #[test]
    fn effective_smact_modes() {
        assert_eq!(effective_smact(CollocationMode::Mps, &[]), 0.0);
        // MPS overlap model: 1 - (1-0.6)^2 = 0.84
        let pair = [d(0.6), d(0.6)];
        assert!((effective_smact(CollocationMode::Mps, &pair) - 0.84).abs() < 1e-9);
        // streams accumulate additively: 0.6 + 0.6 capped at 1.0
        assert!((effective_smact(CollocationMode::Streams, &pair) - 1.0).abs() < 1e-9);
        let light = [d(0.3), d(0.3)];
        assert!((effective_smact(CollocationMode::Mps, &light) - 0.51).abs() < 1e-9);
        assert!((effective_smact(CollocationMode::Streams, &light) - 0.6).abs() < 1e-9);
    }

    #[test]
    fn fabric_factor_penalizes_span_and_contention() {
        // server-local gangs pay nothing, regardless of link load
        assert_eq!(fabric_factor(1, 5.0, 0.15, 0.5), 1.0);
        assert_eq!(fabric_factor(0, 5.0, 0.15, 0.5), 1.0);
        // spanning servers costs sync; more servers cost more
        let two = fabric_factor(2, 0.0, 0.15, 0.5);
        let four = fabric_factor(4, 0.0, 0.15, 0.5);
        assert!(two < 1.0 && four < two, "two={two} four={four}");
        // co-runner bandwidth on the shared NIC adds contention
        let contended = fabric_factor(2, 0.8, 0.15, 0.5);
        assert!(contended < two);
        // negative "other load" is clamped, never a speedup
        assert_eq!(fabric_factor(2, -1.0, 0.15, 0.5), two);
        assert!(fabric_factor(8, 10.0, 0.15, 0.5) > 0.0);
    }

    #[test]
    fn speed_factors_always_in_unit_interval() {
        use crate::testkit;
        use crate::util::rng::Rng;
        let gen = |rng: &mut Rng, size: usize| {
            let n = 1 + size % 6;
            (0..n)
                .map(|_| Demand {
                    smact: rng.range_f64(0.05, 1.0),
                    membw: rng.range_f64(0.0, 1.0),
                    instance_frac: *rng.choice(&[1.0, 0.5, 0.25]),
                })
                .collect::<Vec<_>>()
        };
        testkit::forall(&gen, |tasks| {
            for mode in [CollocationMode::Mps, CollocationMode::Streams, CollocationMode::Mig] {
                for &f in &speed_factors(mode, tasks, &cfg()) {
                    if !(f > 0.0 && f <= 1.0 + 1e-12) {
                        return Err(format!("factor {f} out of range under {mode:?}"));
                    }
                }
                let e = effective_smact(mode, tasks);
                if !(0.0..=1.0 + 1e-12).contains(&e) {
                    return Err(format!("effective smact {e} out of range"));
                }
            }
            Ok(())
        });
    }
}
