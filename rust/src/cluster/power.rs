//! GPU power model (S4) — idle / active / high-power modes.
//!
//! Paper §4.4: "capping the SMACT around 80% leads to more energy-efficient
//! runs compared to >90%, where the GPU switches to the higher-power mode by
//! default to match the load."  We model draw as idle floor, an affine
//! active region, and a boost step above the threshold.  Constants are
//! calibrated so the exclusive 60-task trace lands near the paper's 33.2 MJ
//! (DESIGN.md §7); Table 7 compares *relative* energy across policies.

use crate::config::schema::PowerConfig;

/// Instantaneous draw of one GPU given its effective SMACT.
///
/// The active region is mildly *concave* (`u^0.7`): a DL training kernel at
/// 60 % SM activity already draws much of peak power (clocks/HBM are up),
/// so stacking a second task adds less power than it adds utilization —
/// the physical reason collocation saves energy (paper §5.6: shorter trace
/// at higher utilization beats longer trace at medium utilization).
pub const POWER_EXPONENT: f64 = 0.7;

pub fn gpu_power_w(cfg: &PowerConfig, active_tasks: usize, smact: f64) -> f64 {
    if active_tasks == 0 {
        return cfg.idle_w;
    }
    let u = smact.clamp(0.0, 1.0);
    let mut p = cfg.base_w + (cfg.peak_w - cfg.base_w) * u.powf(POWER_EXPONENT);
    if u > cfg.boost_threshold {
        // high-power mode: clocks boost to match the load
        let depth = (u - cfg.boost_threshold) / (1.0 - cfg.boost_threshold);
        p += cfg.boost_w * depth;
    }
    p
}

/// Anticipated draw of reserved-but-not-yet-dispatched gang slots
/// (DESIGN.md §11). A gang hold promises the GPU to a pending gang: when
/// the gang commits, the device jumps from its idle floor to at least the
/// active base draw. The power-envelope filter must count that headroom
/// *now* — otherwise singleton admissions can fill the envelope while the
/// gang is accumulating holds, and the gang's own commit would overshoot
/// `--power-cap` at dispatch time.
pub fn reserved_w(cfg: &PowerConfig, reserved_slots: usize) -> f64 {
    reserved_slots as f64 * (cfg.base_w - cfg.idle_w).max(0.0)
}

/// Whole power slots a headroom budget admits, capped at `max`. A
/// non-positive per-slot draw means the envelope cannot bind — the cap
/// alone limits. The one place the slot division lives: the gang planner's
/// per-server contribution cap and the static gang ceiling both call it
/// (DESIGN.md §12), so the two cannot drift.
pub fn slots_in_headroom(headroom_w: f64, slot_w: f64, max: usize) -> usize {
    if slot_w <= 0.0 {
        max
    } else {
        (((headroom_w / slot_w).max(0.0).floor()) as usize).min(max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PowerConfig {
        PowerConfig::default()
    }

    #[test]
    fn idle_floor() {
        assert_eq!(gpu_power_w(&cfg(), 0, 0.0), cfg().idle_w);
        // idle GPUs still consume energy "due to being on" (paper §4.3 MUG)
        assert!(gpu_power_w(&cfg(), 0, 0.0) > 0.0);
    }

    #[test]
    fn monotone_in_utilization() {
        let c = cfg();
        let mut prev = 0.0;
        for i in 0..=20 {
            let u = i as f64 / 20.0;
            let p = gpu_power_w(&c, 1, u);
            assert!(p >= prev);
            prev = p;
        }
    }

    #[test]
    fn boost_mode_above_threshold() {
        let c = cfg();
        let p80 = gpu_power_w(&c, 1, 0.80);
        let p95 = gpu_power_w(&c, 1, 0.95);
        let affine_95 = c.base_w + (c.peak_w - c.base_w) * 0.95;
        assert!(p95 > affine_95, "boost must add draw above {}", c.boost_threshold);
        assert!(p95 - p80 > (c.peak_w - c.base_w) * 0.15);
    }

    #[test]
    fn full_load_peak_plus_boost() {
        let c = cfg();
        let p = gpu_power_w(&c, 2, 1.0);
        assert!((p - (c.peak_w + c.boost_w)).abs() < 1e-9);
    }

    #[test]
    fn active_but_low_util_above_idle() {
        let c = cfg();
        assert!(gpu_power_w(&c, 1, 0.0) > c.idle_w);
    }

    #[test]
    fn headroom_slot_division() {
        // 43 W slots (default): 100 W admits 2, capped by max, never negative
        assert_eq!(slots_in_headroom(100.0, 43.0, 8), 2);
        assert_eq!(slots_in_headroom(100.0, 43.0, 1), 1);
        assert_eq!(slots_in_headroom(-10.0, 43.0, 8), 0);
        // degenerate slot draw: the envelope cannot bind
        assert_eq!(slots_in_headroom(5.0, 0.0, 8), 8);
    }

    #[test]
    fn reserved_slots_count_toward_the_envelope() {
        let c = cfg();
        // each held slot anticipates the idle -> base jump (43 W default)
        assert_eq!(reserved_w(&c, 0), 0.0);
        assert!((reserved_w(&c, 2) - 2.0 * (c.base_w - c.idle_w)).abs() < 1e-9);
        // a degenerate config (base below idle) must not go negative
        let weird = PowerConfig {
            base_w: 10.0,
            idle_w: 52.0,
            ..cfg()
        };
        assert_eq!(reserved_w(&weird, 3), 0.0);
    }
}
