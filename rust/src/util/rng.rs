//! Deterministic PRNG (SplitMix64 seeding + xoshiro256** core).
//!
//! Every stochastic component of the simulator (trace arrivals, allocator
//! jitter, property-test generators) draws from this generator so whole
//! experiments are reproducible from a single seed.

/// xoshiro256** — fast, high-quality, no external deps.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. per-subsystem) from this one.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [lo, hi) (hi exclusive). Panics if lo >= hi.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        // Lemire-style rejection-free-enough for simulation purposes
        lo + self.next_u64() % (hi - lo)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Exponential with the given mean (inter-arrival times).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.range_u64(5, 10);
            assert!((5..10).contains(&x));
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(21);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
