//! Unit constants + conversions shared across the simulator and memsim.

pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * MIB;

/// Megabytes used as the allocator's address-space unit (1 MiB granules).
pub const MIB_PER_GIB: u64 = 1024;

pub fn gb_to_mib(gb: f64) -> u64 {
    (gb * MIB_PER_GIB as f64).round() as u64
}

pub fn mib_to_gb(mib: u64) -> f64 {
    mib as f64 / MIB_PER_GIB as f64
}

pub fn minutes(m: f64) -> f64 {
    m * 60.0
}

pub fn to_minutes(secs: f64) -> f64 {
    secs / 60.0
}

/// Joules -> megajoules.
pub fn to_mj(joules: f64) -> f64 {
    joules / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_gb_mib() {
        assert_eq!(gb_to_mib(40.0), 40 * 1024);
        assert!((mib_to_gb(gb_to_mib(13.57)) - 13.57).abs() < 1e-3);
    }

    #[test]
    fn time_units() {
        assert_eq!(minutes(2.0), 120.0);
        assert_eq!(to_minutes(90.0), 1.5);
    }
}
