//! Small statistics helpers shared by metrics, benches and experiments.

/// Running mean/variance (Welford) — used by the monitor's SMACT windows
/// and the bench harness.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation; `p` in [0, 100]. Delegates to the
/// one shared implementation in `obs::aggregate` (DESIGN.md §14).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    crate::obs::aggregate::percentile_exact(xs, p)
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(Welford::new().stddev(), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }
}
