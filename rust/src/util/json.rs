//! Minimal JSON reader/writer (serde is unavailable offline — DESIGN.md §1).
//!
//! Parses the artifact manifests, `data/model_zoo.json`, and the golden
//! files; writes experiment reports.  Supports the full JSON value model
//! except exotic number forms; numbers are kept as f64 (adequate for all
//! repo data).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `obj["k"]` as f64 or panic with a useful message (loader-path sugar).
    pub fn f64_of(&self, key: &str) -> f64 {
        self.get(key)
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing/invalid numeric field '{key}'"))
    }

    pub fn str_of(&self, key: &str) -> &str {
        self.get(key)
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("missing/invalid string field '{key}'"))
    }

    /// Insert/overwrite a field on an object (writer-path sugar). Panics on
    /// non-objects — that is a caller bug, not data-dependent.
    pub fn set(&mut self, key: &str, v: Json) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), v);
            }
            other => panic!("Json::set on non-object {other:?}"),
        }
    }

    // -- writer ---------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line form for streaming sinks (one JSONL record per line —
    /// DESIGN.md §14). Same writer as [`to_string_pretty`], no padding.
    ///
    /// [`to_string_pretty`]: Json::to_string_pretty
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad unicode escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad unicode escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad unicode escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Convenience builders for report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].str_of("b"), "x");
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, "x"], "b": {"c": true, "d": null}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn compact_is_single_line_and_roundtrips() {
        let v = Json::parse(r#"{"a": [1, 2.5, "x"], "b": {"c": true, "d": null}}"#).unwrap();
        let c = v.to_string_compact();
        assert!(!c.contains('\n') && !c.contains(": "), "{c}");
        assert_eq!(c, r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#);
        assert_eq!(Json::parse(&c).unwrap(), v);
    }

    #[test]
    fn set_inserts_and_overwrites() {
        let mut v = Json::parse(r#"{"a": 1}"#).unwrap();
        v.set("b", num(2.0));
        v.set("a", num(3.0));
        assert_eq!(v.f64_of("a"), 3.0);
        assert_eq!(v.f64_of("b"), 2.0);
    }

    #[test]
    fn real_zoo_parses() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/data/model_zoo.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("models").unwrap().as_arr().unwrap().len() >= 30);
        }
    }
}
