//! Infrastructure substrates built in-repo (the offline crate registry only
//! carries the `xla` crate's dependency closure — DESIGN.md §1).

pub mod json;
pub mod rng;
pub mod stats;
pub mod units;
