//! Task queues (paper §4.1 / §4.2): FIFO primary queue + higher-priority
//! recovery queue for OOM-crashed tasks.

use std::collections::VecDeque;

use crate::sim::TaskId;

#[derive(Debug, Default)]
pub struct TaskQueues {
    main: VecDeque<TaskId>,
    recovery: VecDeque<TaskId>,
}

impl TaskQueues {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn submit(&mut self, task: TaskId) {
        self.main.push_back(task);
    }

    /// OOM-crashed tasks are re-queued with priority (paper §4.2) so they
    /// are rescheduled promptly.
    pub fn submit_recovery(&mut self, task: TaskId) {
        self.recovery.push_back(task);
    }

    /// FIFO within each queue; recovery drains first.
    pub fn pop_next(&mut self) -> Option<(TaskId, bool)> {
        if let Some(t) = self.recovery.pop_front() {
            return Some((t, true));
        }
        self.main.pop_front().map(|t| (t, false))
    }

    pub fn is_empty(&self) -> bool {
        self.main.is_empty() && self.recovery.is_empty()
    }

    pub fn len(&self) -> usize {
        self.main.len() + self.recovery.len()
    }

    pub fn recovery_len(&self) -> usize {
        self.recovery.len()
    }

    pub fn main_len(&self) -> usize {
        self.main.len()
    }

    /// Remove and return the TAIL of the primary queue — the most recently
    /// submitted task (work stealing, DESIGN.md §12). Taking the tail
    /// leaves the relative order of every remaining task untouched, so
    /// per-shard FIFO holds for non-stolen tasks; recovery tasks are never
    /// stolen (recovery re-queues stay on the shard that owns the task).
    pub fn steal_tail(&mut self) -> Option<TaskId> {
        self.main.pop_back()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = TaskQueues::new();
        q.submit(1);
        q.submit(2);
        q.submit(3);
        assert_eq!(q.pop_next(), Some((1, false)));
        assert_eq!(q.pop_next(), Some((2, false)));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn recovery_has_priority() {
        let mut q = TaskQueues::new();
        q.submit(1);
        q.submit(2);
        q.submit_recovery(9);
        assert_eq!(q.pop_next(), Some((9, true)));
        assert_eq!(q.pop_next(), Some((1, false)));
        assert_eq!(q.recovery_len(), 0);
    }

    #[test]
    fn steal_takes_the_tail_and_preserves_fifo() {
        let mut q = TaskQueues::new();
        for t in 1..=4 {
            q.submit(t);
        }
        q.submit_recovery(9);
        assert_eq!(q.main_len(), 4);
        assert_eq!(q.steal_tail(), Some(4), "newest task is stolen");
        // remaining order untouched; recovery still drains first, unstolen
        assert_eq!(q.pop_next(), Some((9, true)));
        assert_eq!(q.pop_next(), Some((1, false)));
        assert_eq!(q.pop_next(), Some((2, false)));
        assert_eq!(q.pop_next(), Some((3, false)));
        q.submit_recovery(8);
        assert_eq!(q.steal_tail(), None, "recovery queue is never stealable");
        assert_eq!(q.pop_next(), Some((8, true)));
    }

    #[test]
    fn empty() {
        let mut q = TaskQueues::new();
        assert!(q.is_empty());
        assert_eq!(q.pop_next(), None);
    }
}
