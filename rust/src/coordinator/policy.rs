//! Task→GPU mapping policies + preconditions (paper §4.3), generalized to
//! the cluster's two-level decision (DESIGN.md §8): a *server filter*
//! (power envelope, enough GPUs for the request) followed by the per-GPU
//! policy over the surviving servers' devices.
//!
//! This module owns the view/request TYPES and the seed-stable selection
//! API; the selection LOGIC lives in the shared placement core
//! (`coordinator::placement`, DESIGN.md §12) — [`select_gpus`] and
//! [`select_two_level`] are thin island-blind callers of it, which is the
//! byte-reproduction contract of `--fabric-aware-singletons off`. Pure
//! functions over monitor snapshots, so every policy is unit- and
//! property-testable without the simulator.

use crate::config::schema::PolicyKind;
use crate::coordinator::placement;

/// What the mapper knows about one GPU at decision time.
#[derive(Debug, Clone, Copy)]
pub struct GpuView {
    /// Global GPU id (cluster-wide numbering, `cluster::topology`).
    pub id: usize,
    /// Server this GPU belongs to.
    pub server: usize,
    /// Free memory as the monitor reports it (total, NOT largest hole —
    /// fragmentation is invisible to the monitor, paper §4.2).
    pub free_gb: f64,
    /// Windowed average SMACT (paper §4.1).
    pub smact_window: f64,
    pub n_tasks: usize,
    /// A resident task holds this GPU exclusively (recovery demotion,
    /// §4.2 + DESIGN.md §9): no collocation is admitted until it leaves —
    /// otherwise a newcomer's ramp could re-OOM the very task the final
    /// recovery attempt promised a safe slot.
    pub pinned: bool,
    /// A pending gang reserves this GPU (DESIGN.md §11): singleton mappers
    /// must backfill *around* it, never onto it — otherwise continuous
    /// arrivals could erode the capacity the gang already accumulated.
    pub held: bool,
    /// The device or its server is quarantined by an outstanding fault
    /// (DESIGN.md §15): never a placement target until repaired. Checked
    /// before every other eligibility filter.
    pub unhealthy: bool,
    /// MIG: a free instance index if one exists (None when MIG off or full).
    pub mig_free_instance: Option<usize>,
    /// MIG: memory capacity of that free instance.
    pub mig_instance_mem_gb: f64,
    pub mig_enabled: bool,
}

/// What the mapper knows about one server at decision time (the first level
/// of the two-level mapping).
///
/// The per-GPU views are behind an `Arc` so cloning a `ServerView` is a
/// refcount bump: the delta-maintained snapshot (DESIGN.md §17) carries
/// untouched servers forward from the previous snapshot without copying or
/// re-allocating their GPU arrays.
#[derive(Debug, Clone)]
pub struct ServerView {
    pub id: usize,
    /// Instantaneous power draw across the server's GPUs (W).
    pub power_w: f64,
    /// Power envelope (W); a server drawing at/above it is filtered out.
    pub power_cap_w: Option<f64>,
    /// Per-GPU views, global ids (shared, immutable once built).
    pub gpus: std::sync::Arc<[GpuView]>,
}

impl ServerView {
    /// Mutable access to the GPU views while this `ServerView` is still
    /// uniquely owned (construction-time fixups and tests). Panics once the
    /// view has been shared — published snapshots are immutable.
    pub fn gpus_mut(&mut self) -> &mut [GpuView] {
        std::sync::Arc::get_mut(&mut self.gpus).expect("ServerView.gpus is shared, not mutable")
    }

    /// First-level filter: can this server accept the request at all?
    /// Multi-GPU tasks never span servers, so a server must own enough
    /// GPUs; a server at its power envelope takes no new work.
    pub fn admits(&self, req: MappingRequest) -> bool {
        if self.gpus.len() < req.n_gpus {
            return false;
        }
        match self.power_cap_w {
            Some(cap) => self.power_w < cap,
            None => true,
        }
    }
}

/// One mapping request.
#[derive(Debug, Clone, Copy)]
pub struct MappingRequest {
    pub n_gpus: usize,
    /// Estimated memory demand per GPU (estimator output + safety margin);
    /// None = no estimate (blind collocation, §5.3).
    pub demand_gb: Option<f64>,
    /// Force exclusive placement (Exclusive policy or recovery re-run §4.2).
    pub exclusive: bool,
}

/// Preconditions (paper §4.3): GPUs must have ≤ u SMACT and ≥ m GB free to
/// be collocation candidates.
#[derive(Debug, Clone, Copy, Default)]
pub struct Preconditions {
    pub smact_cap: Option<f64>,
    pub min_free_gb: Option<f64>,
}

/// A mapping decision: chosen GPU ids (+ MIG instance per GPU if enabled).
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub gpus: Vec<usize>,
    pub instances: Vec<Option<usize>>,
}

/// Select GPUs for a request. `rr_cursor` carries Round-Robin state across
/// calls. Returns None when no eligible set exists right now (the task
/// waits and the mapper retries).
pub fn select_gpus(
    policy: PolicyKind,
    views: &[GpuView],
    req: MappingRequest,
    pre: Preconditions,
    rr_cursor: &mut usize,
) -> Option<Placement> {
    placement::select_flat(policy, views, req, pre, rr_cursor)
}

/// Two-level cluster mapping (DESIGN.md §8): filter servers (power
/// envelope, capacity for the request), then run the per-GPU policy and
/// pick the best server by the same criterion. Multi-GPU requests are
/// always satisfied within a single server.
///
/// ```
/// use carma::config::schema::PolicyKind;
/// use carma::coordinator::policy::{
///     select_two_level, GpuView, MappingRequest, Preconditions, ServerView,
/// };
///
/// let gpu = |id, server, free_gb| GpuView {
///     id, server, free_gb,
///     smact_window: 0.2, n_tasks: 1, pinned: false, held: false, unhealthy: false,
///     mig_free_instance: None, mig_instance_mem_gb: 0.0, mig_enabled: false,
/// };
/// let servers = [
///     ServerView { id: 0, power_w: 400.0, power_cap_w: None,
///                  gpus: vec![gpu(0, 0, 10.0), gpu(1, 0, 12.0)].into() },
///     ServerView { id: 1, power_w: 400.0, power_cap_w: None,
///                  gpus: vec![gpu(2, 1, 30.0), gpu(3, 1, 5.0)].into() },
/// ];
/// let req = MappingRequest { n_gpus: 1, demand_gb: Some(8.0), exclusive: false };
/// let mut rr = 0;
/// let p = select_two_level(PolicyKind::Magm, &servers, req, Preconditions::default(), &mut rr)
///     .unwrap();
/// assert_eq!(p.gpus, vec![2]); // most free memory across the whole cluster
/// ```
pub fn select_two_level(
    policy: PolicyKind,
    servers: &[ServerView],
    req: MappingRequest,
    pre: Preconditions,
    rr_cursor: &mut usize,
) -> Option<Placement> {
    placement::select_singleton(policy, servers, req, pre, rr_cursor, None)
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::eligibility::FIT_SLACK_GB;

    fn view(id: usize, free: f64, smact: f64, n: usize) -> GpuView {
        GpuView {
            id,
            server: 0,
            free_gb: free,
            smact_window: smact,
            n_tasks: n,
            pinned: false,
            held: false,
            unhealthy: false,
            mig_free_instance: None,
            mig_instance_mem_gb: 0.0,
            mig_enabled: false,
        }
    }

    fn sview(id: usize, gpus: Vec<GpuView>) -> ServerView {
        ServerView {
            id,
            power_w: 0.0,
            power_cap_w: None,
            gpus: gpus.into_iter().map(|mut v| {
                v.server = id;
                v
            }).collect::<Vec<_>>().into(),
        }
    }

    fn req(n: usize, demand: Option<f64>) -> MappingRequest {
        MappingRequest {
            n_gpus: n,
            demand_gb: demand,
            exclusive: false,
        }
    }

    #[test]
    fn exclusive_needs_idle() {
        let views = [view(0, 40.0, 0.0, 0), view(1, 20.0, 0.5, 1)];
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Exclusive,
            &views,
            req(1, Some(10.0)),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![0]);
        // two idle GPUs required but only one idle
        assert!(select_gpus(
            PolicyKind::Exclusive,
            &views,
            req(2, None),
            Preconditions::default(),
            &mut rr
        )
        .is_none());
    }

    #[test]
    fn magm_picks_most_free_memory() {
        let views = [view(0, 8.0, 0.3, 1), view(1, 30.0, 0.5, 1), view(2, 16.0, 0.1, 1)];
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Magm,
            &views,
            req(1, Some(5.0)),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![1]);
    }

    #[test]
    fn lug_picks_least_utilized() {
        let views = [view(0, 8.0, 0.3, 1), view(1, 30.0, 0.5, 1), view(2, 16.0, 0.1, 1)];
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Lug,
            &views,
            req(1, None),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![2]);
    }

    #[test]
    fn mug_picks_most_utilized() {
        let views = [view(0, 8.0, 0.3, 1), view(1, 30.0, 0.5, 1), view(2, 16.0, 0.1, 1)];
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Mug,
            &views,
            req(1, None),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![1]);
    }

    #[test]
    fn round_robin_cycles() {
        let views = [view(0, 40.0, 0.0, 0), view(1, 40.0, 0.0, 0), view(2, 40.0, 0.0, 0)];
        let mut rr = 0;
        let mut order = Vec::new();
        for _ in 0..5 {
            let p = select_gpus(
                PolicyKind::RoundRobin,
                &views,
                req(1, None),
                Preconditions::default(),
                &mut rr,
            )
            .unwrap();
            order.push(p.gpus[0]);
        }
        assert_eq!(order, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn preconditions_filter() {
        let views = [view(0, 3.0, 0.9, 2), view(1, 10.0, 0.5, 1)];
        let mut rr = 0;
        let pre = Preconditions {
            smact_cap: Some(0.8),
            min_free_gb: Some(5.0),
        };
        let p = select_gpus(PolicyKind::Magm, &views, req(1, None), pre, &mut rr).unwrap();
        assert_eq!(p.gpus, vec![1]);
        // nothing eligible -> None
        let pre_tight = Preconditions {
            smact_cap: Some(0.4),
            min_free_gb: Some(20.0),
        };
        assert!(select_gpus(PolicyKind::Magm, &views, req(1, None), pre_tight, &mut rr).is_none());
    }

    #[test]
    fn pinned_gpu_rejects_all_collocation() {
        // even a blind request (no demand, no preconditions) must not land
        // on a GPU held exclusively by a recovery-demoted task
        let mut held = view(0, 35.0, 0.1, 1);
        held.pinned = true;
        let views = [held, view(1, 5.0, 0.9, 3)];
        let mut rr = 0;
        for policy in [PolicyKind::RoundRobin, PolicyKind::Magm, PolicyKind::Lug, PolicyKind::Mug] {
            let p = select_gpus(policy, &views, req(1, None), Preconditions::default(), &mut rr)
                .unwrap();
            assert_eq!(p.gpus, vec![1], "{policy:?} must avoid the pinned GPU");
        }
    }

    #[test]
    fn gang_held_gpu_rejects_backfill_and_exclusive() {
        // a pending gang's hold must deflect every singleton policy — the
        // backfill rule of DESIGN.md §11: around the holds, never onto them
        let mut held = view(0, 40.0, 0.0, 0);
        held.held = true;
        let views = [held, view(1, 5.0, 0.9, 3)];
        let mut rr = 0;
        for policy in [PolicyKind::RoundRobin, PolicyKind::Magm, PolicyKind::Lug, PolicyKind::Mug] {
            let p = select_gpus(policy, &views, req(1, None), Preconditions::default(), &mut rr)
                .unwrap();
            assert_eq!(p.gpus, vec![1], "{policy:?} must avoid the held GPU");
        }
        // exclusive placement is blocked too, even though the device is idle
        assert!(select_gpus(
            PolicyKind::Exclusive,
            &views[..1],
            req(1, None),
            Preconditions::default(),
            &mut rr
        )
        .is_none());
    }

    #[test]
    fn pinned_mig_gpu_rejects_instances_and_exclusive() {
        // MIG instances share the device allocator in the sim: a pinned
        // resident blocks sibling-instance placement AND exclusive targeting
        let pinned_mig = GpuView {
            id: 0,
            server: 0,
            free_gb: 30.0,
            smact_window: 0.1,
            n_tasks: 1,
            pinned: true,
            held: false,
            unhealthy: false,
            mig_free_instance: Some(1),
            mig_instance_mem_gb: 10.0,
            mig_enabled: true,
        };
        let mut rr = 0;
        assert!(select_gpus(
            PolicyKind::Magm,
            &[pinned_mig],
            req(1, Some(8.0)),
            Preconditions::default(),
            &mut rr
        )
        .is_none());
        let excl = MappingRequest {
            n_gpus: 1,
            demand_gb: Some(8.0),
            exclusive: true,
        };
        assert!(select_gpus(
            PolicyKind::Magm,
            &[pinned_mig],
            excl,
            Preconditions::default(),
            &mut rr
        )
        .is_none());
    }

    #[test]
    fn demand_check_uses_monitor_free_memory() {
        let views = [view(0, 6.0, 0.2, 1)];
        let mut rr = 0;
        assert!(select_gpus(
            PolicyKind::Magm,
            &views,
            req(1, Some(8.0)),
            Preconditions::default(),
            &mut rr
        )
        .is_none());
        assert!(select_gpus(
            PolicyKind::Magm,
            &views,
            req(1, Some(5.0)),
            Preconditions::default(),
            &mut rr
        )
        .is_some());
    }

    #[test]
    fn mig_requires_free_instance_and_fit() {
        let mig_view = GpuView {
            id: 0,
            server: 0,
            free_gb: 40.0,
            smact_window: 0.2,
            n_tasks: 1,
            pinned: false,
            held: false,
            unhealthy: false,
            mig_free_instance: Some(1),
            mig_instance_mem_gb: 10.0,
            mig_enabled: true,
        };
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Magm,
            &[mig_view],
            req(1, Some(8.0)),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.instances, vec![Some(1)]);
        assert!(select_gpus(
            PolicyKind::Magm,
            &[mig_view],
            req(1, Some(12.0)),
            Preconditions::default(),
            &mut rr
        )
        .is_none());
    }

    // -- two-level (cluster) mapping -----------------------------------------

    #[test]
    fn two_level_magm_picks_best_gpu_cluster_wide() {
        let servers = [
            sview(0, vec![view(0, 8.0, 0.2, 1), view(1, 12.0, 0.2, 1)]),
            sview(1, vec![view(2, 30.0, 0.2, 1), view(3, 5.0, 0.2, 1)]),
        ];
        let mut rr = 0;
        let p = select_two_level(
            PolicyKind::Magm,
            &servers,
            req(1, Some(4.0)),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![2]);
    }

    #[test]
    fn two_level_multi_gpu_never_spans_servers() {
        // best two GPUs by free memory sit on *different* servers; a 2-GPU
        // task must take the best same-server pair instead
        let servers = [
            sview(0, vec![view(0, 39.0, 0.1, 0), view(1, 10.0, 0.1, 1)]),
            sview(1, vec![view(2, 38.0, 0.1, 0), view(3, 30.0, 0.1, 1)]),
        ];
        let mut rr = 0;
        let p = select_two_level(
            PolicyKind::Magm,
            &servers,
            req(2, Some(5.0)),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![2, 3], "39+10 < 38+30: server 1 hosts the pair");
    }

    #[test]
    fn two_level_power_envelope_filters_servers() {
        let mut hot = sview(0, vec![view(0, 40.0, 0.0, 0)]);
        hot.power_w = 1300.0;
        hot.power_cap_w = Some(1200.0);
        let mut cool = sview(1, vec![view(1, 20.0, 0.0, 0)]);
        cool.power_w = 400.0;
        cool.power_cap_w = Some(1200.0);
        let servers = [hot, cool];
        let mut rr = 0;
        let p = select_two_level(
            PolicyKind::Magm,
            &servers,
            req(1, Some(4.0)),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![1], "server 0 is over its power envelope");
        // both over cap -> nothing schedulable
        let mut all_hot = servers.clone();
        all_hot[1].power_w = 1250.0;
        assert!(select_two_level(
            PolicyKind::Magm,
            &all_hot,
            req(1, None),
            Preconditions::default(),
            &mut rr
        )
        .is_none());
    }

    #[test]
    fn two_level_round_robin_cycles_across_servers() {
        let servers = [
            sview(0, vec![view(0, 40.0, 0.0, 0), view(1, 40.0, 0.0, 0)]),
            sview(1, vec![view(2, 40.0, 0.0, 0), view(3, 40.0, 0.0, 0)]),
        ];
        let mut rr = 0;
        let mut order = Vec::new();
        for _ in 0..6 {
            let p = select_two_level(
                PolicyKind::RoundRobin,
                &servers,
                req(1, None),
                Preconditions::default(),
                &mut rr,
            )
            .unwrap();
            order.push(p.gpus[0]);
        }
        assert_eq!(order, vec![0, 1, 2, 3, 0, 1]);
    }

    #[test]
    fn two_level_exclusive_takes_first_idle_server() {
        let servers = [
            sview(0, vec![view(0, 40.0, 0.3, 1), view(1, 40.0, 0.3, 1)]),
            sview(1, vec![view(2, 40.0, 0.0, 0), view(3, 40.0, 0.0, 0)]),
        ];
        let mut rr = 0;
        let p = select_two_level(
            PolicyKind::Exclusive,
            &servers,
            req(2, None),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![2, 3]);
    }

    #[test]
    fn two_level_single_server_matches_flat_selection() {
        // a 1-server cluster must behave exactly like the flat mapper
        let gpus = vec![view(0, 8.0, 0.3, 1), view(1, 30.0, 0.5, 1), view(2, 16.0, 0.1, 1)];
        for policy in [PolicyKind::Magm, PolicyKind::Lug, PolicyKind::Mug] {
            let mut rr1 = 0;
            let mut rr2 = 0;
            let flat = select_gpus(policy, &gpus, req(1, None), Preconditions::default(), &mut rr1);
            let two = select_two_level(
                policy,
                &[sview(0, gpus.clone())],
                req(1, None),
                Preconditions::default(),
                &mut rr2,
            );
            assert_eq!(flat, two, "{policy:?}");
        }
    }

    #[test]
    fn prop_selection_respects_preconditions() {
        use crate::testkit;
        use crate::util::rng::Rng;
        let gen = |rng: &mut Rng, size: usize| {
            let n = 2 + size % 6;
            let views: Vec<GpuView> = (0..n)
                .map(|i| view(i, rng.range_f64(0.0, 40.0), rng.f64(), rng.range_usize(0, 4)))
                .collect();
            let demand = if rng.bool(0.5) {
                Some(rng.range_f64(1.0, 30.0))
            } else {
                None
            };
            (views, demand, rng.f64(), rng.range_f64(0.0, 20.0))
        };
        testkit::forall(&gen, |(views, demand, cap, min_free)| {
            let pre = Preconditions {
                smact_cap: Some(*cap),
                min_free_gb: Some(*min_free),
            };
            let mut rr = 0;
            for policy in [PolicyKind::RoundRobin, PolicyKind::Magm, PolicyKind::Lug, PolicyKind::Mug]
            {
                if let Some(p) = select_gpus(
                    policy,
                    views,
                    MappingRequest {
                        n_gpus: 1,
                        demand_gb: *demand,
                        exclusive: false,
                    },
                    pre,
                    &mut rr,
                ) {
                    let v = views.iter().find(|v| v.id == p.gpus[0]).unwrap();
                    if v.smact_window > *cap {
                        return Err(format!("{policy:?} violated smact cap"));
                    }
                    if v.free_gb < *min_free {
                        return Err(format!("{policy:?} violated min free"));
                    }
                    if let Some(d) = demand {
                        // allow the allocator-granularity fit slack
                        if v.free_gb + 2.0 * FIT_SLACK_GB < *d {
                            return Err(format!("{policy:?} violated demand check"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
