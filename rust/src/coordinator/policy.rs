//! Task→GPU mapping policies + preconditions (paper §4.3).
//!
//! Pure selection logic over monitor snapshots, so every policy is unit- and
//! property-testable without the simulator.

use crate::config::schema::PolicyKind;

/// What the mapper knows about one GPU at decision time.
#[derive(Debug, Clone, Copy)]
pub struct GpuView {
    pub id: usize,
    /// Free memory as the monitor reports it (total, NOT largest hole —
    /// fragmentation is invisible to the monitor, paper §4.2).
    pub free_gb: f64,
    /// Windowed average SMACT (paper §4.1).
    pub smact_window: f64,
    pub n_tasks: usize,
    /// MIG: a free instance index if one exists (None when MIG off or full).
    pub mig_free_instance: Option<usize>,
    /// MIG: memory capacity of that free instance.
    pub mig_instance_mem_gb: f64,
    pub mig_enabled: bool,
}

/// One mapping request.
#[derive(Debug, Clone, Copy)]
pub struct MappingRequest {
    pub n_gpus: usize,
    /// Estimated memory demand per GPU (estimator output + safety margin);
    /// None = no estimate (blind collocation, §5.3).
    pub demand_gb: Option<f64>,
    /// Force exclusive placement (Exclusive policy or recovery re-run §4.2).
    pub exclusive: bool,
}

/// Preconditions (paper §4.3): GPUs must have ≤ u SMACT and ≥ m GB free to
/// be collocation candidates.
#[derive(Debug, Clone, Copy, Default)]
pub struct Preconditions {
    pub smact_cap: Option<f64>,
    pub min_free_gb: Option<f64>,
}

/// A mapping decision: chosen GPU ids (+ MIG instance per GPU if enabled).
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub gpus: Vec<usize>,
    pub instances: Vec<Option<usize>>,
}

/// Select GPUs for a request. `rr_cursor` carries Round-Robin state across
/// calls. Returns None when no eligible set exists right now (the task
/// waits and the mapper retries).
pub fn select_gpus(
    policy: PolicyKind,
    views: &[GpuView],
    req: MappingRequest,
    pre: Preconditions,
    rr_cursor: &mut usize,
) -> Option<Placement> {
    if req.exclusive || policy == PolicyKind::Exclusive {
        return exclusive(views, req);
    }

    let mut eligible: Vec<&GpuView> = views.iter().filter(|v| passes(v, req, pre)).collect();
    if eligible.len() < req.n_gpus {
        return None;
    }

    match policy {
        PolicyKind::RoundRobin => {
            // cyclic order starting after the last assignment
            let n = views.len();
            let mut chosen = Vec::new();
            for off in 0..n {
                let id = (*rr_cursor + off) % n;
                if eligible.iter().any(|v| v.id == id) {
                    chosen.push(id);
                    if chosen.len() == req.n_gpus {
                        *rr_cursor = (id + 1) % n;
                        break;
                    }
                }
            }
            if chosen.len() < req.n_gpus {
                return None;
            }
            Some(placement(views, chosen))
        }
        PolicyKind::Magm => {
            // most available GPU memory first (paper: minimizes OOM odds)
            eligible.sort_by(|a, b| b.free_gb.total_cmp(&a.free_gb).then(a.id.cmp(&b.id)));
            Some(placement(
                views,
                eligible[..req.n_gpus].iter().map(|v| v.id).collect(),
            ))
        }
        PolicyKind::Lug => {
            // least utilized first (minimizes interference)
            eligible.sort_by(|a, b| {
                a.smact_window
                    .total_cmp(&b.smact_window)
                    .then(a.id.cmp(&b.id))
            });
            Some(placement(
                views,
                eligible[..req.n_gpus].iter().map(|v| v.id).collect(),
            ))
        }
        PolicyKind::Mug => {
            // most utilized first (consolidation; keeps idle GPUs idle)
            eligible.sort_by(|a, b| {
                b.smact_window
                    .total_cmp(&a.smact_window)
                    .then(a.id.cmp(&b.id))
            });
            Some(placement(
                views,
                eligible[..req.n_gpus].iter().map(|v| v.id).collect(),
            ))
        }
        PolicyKind::Exclusive => unreachable!(),
    }
}

fn passes(v: &GpuView, req: MappingRequest, pre: Preconditions) -> bool {
    if v.mig_enabled {
        // MIG: needs a free instance whose memory fits the (known) demand;
        // instances are dispatched exclusively (paper §4.4)
        let Some(_) = v.mig_free_instance else {
            return false;
        };
        if let Some(d) = req.demand_gb {
            if d > v.mig_instance_mem_gb {
                return false;
            }
        }
        return true;
    }
    if let Some(cap) = pre.smact_cap {
        if v.smact_window > cap {
            return false;
        }
    }
    if let Some(min_free) = pre.min_free_gb {
        if v.free_gb < min_free {
            return false;
        }
    }
    if let Some(d) = req.demand_gb {
        if v.free_gb < d {
            return false;
        }
    }
    true
}

fn exclusive(views: &[GpuView], req: MappingRequest) -> Option<Placement> {
    // idle GPUs only (or free MIG instances when MIG is on)
    let idle: Vec<usize> = views
        .iter()
        .filter(|v| {
            if v.mig_enabled {
                v.mig_free_instance.is_some()
                    && req.demand_gb.is_none_or(|d| d <= v.mig_instance_mem_gb)
            } else {
                v.n_tasks == 0
            }
        })
        .map(|v| v.id)
        .take(req.n_gpus)
        .collect();
    if idle.len() < req.n_gpus {
        return None;
    }
    Some(placement(views, idle))
}

fn placement(views: &[GpuView], gpus: Vec<usize>) -> Placement {
    let instances = gpus
        .iter()
        .map(|&g| {
            let v = views.iter().find(|v| v.id == g).unwrap();
            if v.mig_enabled {
                v.mig_free_instance
            } else {
                None
            }
        })
        .collect();
    Placement { gpus, instances }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(id: usize, free: f64, smact: f64, n: usize) -> GpuView {
        GpuView {
            id,
            free_gb: free,
            smact_window: smact,
            n_tasks: n,
            mig_free_instance: None,
            mig_instance_mem_gb: 0.0,
            mig_enabled: false,
        }
    }

    fn req(n: usize, demand: Option<f64>) -> MappingRequest {
        MappingRequest {
            n_gpus: n,
            demand_gb: demand,
            exclusive: false,
        }
    }

    #[test]
    fn exclusive_needs_idle() {
        let views = [view(0, 40.0, 0.0, 0), view(1, 20.0, 0.5, 1)];
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Exclusive,
            &views,
            req(1, Some(10.0)),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![0]);
        // two idle GPUs required but only one idle
        assert!(select_gpus(
            PolicyKind::Exclusive,
            &views,
            req(2, None),
            Preconditions::default(),
            &mut rr
        )
        .is_none());
    }

    #[test]
    fn magm_picks_most_free_memory() {
        let views = [view(0, 8.0, 0.3, 1), view(1, 30.0, 0.5, 1), view(2, 16.0, 0.1, 1)];
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Magm,
            &views,
            req(1, Some(5.0)),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![1]);
    }

    #[test]
    fn lug_picks_least_utilized() {
        let views = [view(0, 8.0, 0.3, 1), view(1, 30.0, 0.5, 1), view(2, 16.0, 0.1, 1)];
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Lug,
            &views,
            req(1, None),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![2]);
    }

    #[test]
    fn mug_picks_most_utilized() {
        let views = [view(0, 8.0, 0.3, 1), view(1, 30.0, 0.5, 1), view(2, 16.0, 0.1, 1)];
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Mug,
            &views,
            req(1, None),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.gpus, vec![1]);
    }

    #[test]
    fn round_robin_cycles() {
        let views = [view(0, 40.0, 0.0, 0), view(1, 40.0, 0.0, 0), view(2, 40.0, 0.0, 0)];
        let mut rr = 0;
        let mut order = Vec::new();
        for _ in 0..5 {
            let p = select_gpus(
                PolicyKind::RoundRobin,
                &views,
                req(1, None),
                Preconditions::default(),
                &mut rr,
            )
            .unwrap();
            order.push(p.gpus[0]);
        }
        assert_eq!(order, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn preconditions_filter() {
        let views = [view(0, 3.0, 0.9, 2), view(1, 10.0, 0.5, 1)];
        let mut rr = 0;
        let pre = Preconditions {
            smact_cap: Some(0.8),
            min_free_gb: Some(5.0),
        };
        let p = select_gpus(PolicyKind::Magm, &views, req(1, None), pre, &mut rr).unwrap();
        assert_eq!(p.gpus, vec![1]);
        // nothing eligible -> None
        let pre_tight = Preconditions {
            smact_cap: Some(0.4),
            min_free_gb: Some(20.0),
        };
        assert!(select_gpus(PolicyKind::Magm, &views, req(1, None), pre_tight, &mut rr).is_none());
    }

    #[test]
    fn demand_check_uses_monitor_free_memory() {
        let views = [view(0, 6.0, 0.2, 1)];
        let mut rr = 0;
        assert!(select_gpus(
            PolicyKind::Magm,
            &views,
            req(1, Some(8.0)),
            Preconditions::default(),
            &mut rr
        )
        .is_none());
        assert!(select_gpus(
            PolicyKind::Magm,
            &views,
            req(1, Some(5.0)),
            Preconditions::default(),
            &mut rr
        )
        .is_some());
    }

    #[test]
    fn mig_requires_free_instance_and_fit() {
        let mig_view = GpuView {
            id: 0,
            free_gb: 40.0,
            smact_window: 0.2,
            n_tasks: 1,
            mig_free_instance: Some(1),
            mig_instance_mem_gb: 10.0,
            mig_enabled: true,
        };
        let mut rr = 0;
        let p = select_gpus(
            PolicyKind::Magm,
            &[mig_view],
            req(1, Some(8.0)),
            Preconditions::default(),
            &mut rr,
        )
        .unwrap();
        assert_eq!(p.instances, vec![Some(1)]);
        assert!(select_gpus(
            PolicyKind::Magm,
            &[mig_view],
            req(1, Some(12.0)),
            Preconditions::default(),
            &mut rr
        )
        .is_none());
    }

    #[test]
    fn prop_selection_respects_preconditions() {
        use crate::testkit;
        use crate::util::rng::Rng;
        let gen = |rng: &mut Rng, size: usize| {
            let n = 2 + size % 6;
            let views: Vec<GpuView> = (0..n)
                .map(|i| view(i, rng.range_f64(0.0, 40.0), rng.f64(), rng.range_usize(0, 4)))
                .collect();
            let demand = if rng.bool(0.5) {
                Some(rng.range_f64(1.0, 30.0))
            } else {
                None
            };
            (views, demand, rng.f64(), rng.range_f64(0.0, 20.0))
        };
        testkit::forall(&gen, |(views, demand, cap, min_free)| {
            let pre = Preconditions {
                smact_cap: Some(*cap),
                min_free_gb: Some(*min_free),
            };
            let mut rr = 0;
            for policy in [PolicyKind::RoundRobin, PolicyKind::Magm, PolicyKind::Lug, PolicyKind::Mug]
            {
                if let Some(p) = select_gpus(
                    policy,
                    views,
                    MappingRequest {
                        n_gpus: 1,
                        demand_gb: *demand,
                        exclusive: false,
                    },
                    pre,
                    &mut rr,
                ) {
                    let v = views.iter().find(|v| v.id == p.gpus[0]).unwrap();
                    if v.smact_window > *cap {
                        return Err(format!("{policy:?} violated smact cap"));
                    }
                    if v.free_gb < *min_free {
                        return Err(format!("{policy:?} violated min free"));
                    }
                    if let Some(d) = demand {
                        if v.free_gb < *d {
                            return Err(format!("{policy:?} violated demand check"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
