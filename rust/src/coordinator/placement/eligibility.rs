//! The single eligibility filter of the placement core (DESIGN.md §12).
//!
//! Every placement path — the per-shard singleton mappers and the gang
//! lane alike — answers "can this GPU host this request right now?" here.
//! Before the extraction the answer lived in three places
//! (`policy::passes`, the inline idle filter of `policy::exclusive`, and
//! `gang::gang_eligible`) that had already drifted into duplicated
//! MIG/pinned/held/fit checks; a fourth copy was inevitable. One
//! predicate, one truth: the checks keep their exact seed semantics, so
//! the island-blind pipeline stays byte-reproducible.

use crate::coordinator::gang::ReservationBook;
use crate::coordinator::policy::{GpuView, MappingRequest, Preconditions};
use crate::sim::TaskId;

/// Allocator-granularity slack for demand-vs-free comparisons: free memory
/// is reported in whole MiB, so a demand derived from the exact configured
/// capacity (e.g. the force-exclusive clamp to `mem_gb`) can sit up to one
/// MiB above the reported value — without slack such a task never fits
/// anywhere and the serial mapper livelocks.
pub const FIT_SLACK_GB: f64 = 1.0 / 1024.0;

/// Who is asking. Singletons and gangs share every check; the two real
/// differences — a gang may keep targeting its OWN holds (fit-only
/// revalidation), and gangs never target MIG-partitioned devices — are
/// carried here instead of being forked into parallel pipelines.
#[derive(Clone, Copy)]
pub enum Requester<'a> {
    /// A shard mapper placing a server-local task.
    Singleton,
    /// The gang lane planning `task`, consulting the reservation book.
    Gang {
        book: &'a ReservationBook,
        task: TaskId,
    },
}

/// Which filter cut a GPU out of the eligible set (DESIGN.md §14 decision
/// provenance). One variant per rejecting check of [`classify`], in check
/// order; the discriminant doubles as the index into per-reason count
/// arrays (`Explain::rejects`, the report's `placement_decisions.rejects`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// A gang requester targeting a MIG-partitioned device (gangs take
    /// whole GPUs only, DESIGN.md §11).
    GangMig = 0,
    /// Pinned resident (recovery demotion) or a foreign gang hold.
    PinnedOrHeld = 1,
    /// MIG device with no free instance.
    MigBusy = 2,
    /// Exclusive request on a non-idle device.
    NotIdle = 3,
    /// Windowed SMACT above the precondition cap (paper §4.3).
    SmactCap = 4,
    /// Free memory below the precondition floor (paper §4.3).
    MinFree = 5,
    /// The (estimated) demand does not fit the free memory — device-level,
    /// MIG-instance-level, or the fit revalidation of a gang's own hold.
    NoFit = 6,
    /// Quarantined by an outstanding fault (DESIGN.md §15): the device or
    /// its server is down. Checked before every other filter — even the
    /// holder of a gang reservation must not dispatch onto dead hardware.
    Unhealthy = 7,
}

impl RejectReason {
    pub const COUNT: usize = 8;
    pub const ALL: [RejectReason; RejectReason::COUNT] = [
        RejectReason::GangMig,
        RejectReason::PinnedOrHeld,
        RejectReason::MigBusy,
        RejectReason::NotIdle,
        RejectReason::SmactCap,
        RejectReason::MinFree,
        RejectReason::NoFit,
        RejectReason::Unhealthy,
    ];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            RejectReason::GangMig => "gang_mig",
            RejectReason::PinnedOrHeld => "pinned_or_held",
            RejectReason::MigBusy => "mig_busy",
            RejectReason::NotIdle => "not_idle",
            RejectReason::SmactCap => "smact_cap",
            RejectReason::MinFree => "min_free",
            RejectReason::NoFit => "no_fit",
            RejectReason::Unhealthy => "unhealthy",
        }
    }
}

/// Can `v` host one worker of this request right now?
///
/// * A device the gang requester already holds re-validates only the
///   memory fit (preconditions were checked at acquisition and nothing new
///   is admitted onto a hold) — an underestimating resident can outgrow
///   what was seen, and committing the gang onto it would be a known-
///   doomed dispatch (§4.2); idle-only additionally under exclusive
///   (recovery demotion).
/// * A pinned or (foreign-)held device is never a target — the hold owns
///   the whole device even under MIG, whose instances share the device
///   allocator in the simulation.
/// * MIG needs a free instance whose memory fits the (known) demand;
///   instances dispatch exclusively (paper §4.4), so the preconditions do
///   not apply. Gangs target whole GPUs only (DESIGN.md §11).
/// * Exclusive requests need an idle device big enough for a known demand
///   — on heterogeneous clusters an idle small GPU is not a valid
///   exclusive target for a large task.
/// * Everything else passes the paper's preconditions (SMACT cap, minimum
///   free memory, §4.3) plus the demand fit.
pub fn eligible(v: &GpuView, req: MappingRequest, pre: Preconditions, who: Requester) -> bool {
    classify(v, req, pre, who).is_none()
}

/// [`eligible`] with provenance: `None` = the device can host the request,
/// `Some(reason)` names the FIRST filter that cut it (check order is fixed,
/// so the per-reason counts are deterministic). This is the one
/// implementation — `eligible` is `classify(..).is_none()` — so the
/// provenance can never drift from the decision.
pub fn classify(
    v: &GpuView,
    req: MappingRequest,
    pre: Preconditions,
    who: Requester,
) -> Option<RejectReason> {
    // health first: a quarantined device is not a target for ANYONE —
    // not even the gang holding a reservation on it (the hold is being
    // invalidated by the fault path; racing a dispatch onto it would
    // commit work to hardware that just died)
    if v.unhealthy {
        return Some(RejectReason::Unhealthy);
    }
    let fits = req.demand_gb.is_none_or(|d| d <= v.free_gb + FIT_SLACK_GB);
    if let Requester::Gang { book, task } = who {
        if book.holder(v.id) == Some(task) {
            if !fits {
                return Some(RejectReason::NoFit);
            }
            if req.exclusive && v.n_tasks > 0 {
                return Some(RejectReason::NotIdle);
            }
            return None;
        }
        if v.mig_enabled {
            return Some(RejectReason::GangMig);
        }
    }
    if v.pinned || v.held {
        return Some(RejectReason::PinnedOrHeld);
    }
    if v.mig_enabled {
        if v.mig_free_instance.is_none() {
            return Some(RejectReason::MigBusy);
        }
        return if req
            .demand_gb
            .is_none_or(|d| d <= v.mig_instance_mem_gb + FIT_SLACK_GB)
        {
            None
        } else {
            Some(RejectReason::NoFit)
        };
    }
    if req.exclusive {
        if v.n_tasks > 0 {
            return Some(RejectReason::NotIdle);
        }
        return if fits { None } else { Some(RejectReason::NoFit) };
    }
    if let Some(cap) = pre.smact_cap {
        if v.smact_window > cap {
            return Some(RejectReason::SmactCap);
        }
    }
    if let Some(min_free) = pre.min_free_gb {
        if v.free_gb < min_free {
            return Some(RejectReason::MinFree);
        }
    }
    if fits {
        None
    } else {
        Some(RejectReason::NoFit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(id: usize, free: f64, smact: f64, n: usize) -> GpuView {
        GpuView {
            id,
            server: 0,
            free_gb: free,
            smact_window: smact,
            n_tasks: n,
            pinned: false,
            held: false,
            unhealthy: false,
            mig_free_instance: None,
            mig_instance_mem_gb: 0.0,
            mig_enabled: false,
        }
    }

    fn req(n: usize, demand: Option<f64>, exclusive: bool) -> MappingRequest {
        MappingRequest {
            n_gpus: n,
            demand_gb: demand,
            exclusive,
        }
    }

    #[test]
    fn preconditions_and_fit_for_singletons() {
        let pre = Preconditions {
            smact_cap: Some(0.8),
            min_free_gb: Some(5.0),
        };
        let ok = view(0, 10.0, 0.5, 1);
        assert!(eligible(&ok, req(1, Some(8.0), false), pre, Requester::Singleton));
        let hot = view(1, 10.0, 0.9, 1);
        assert!(!eligible(&hot, req(1, None, false), pre, Requester::Singleton));
        let tight = view(2, 3.0, 0.1, 1);
        assert!(!eligible(&tight, req(1, None, false), pre, Requester::Singleton));
        let small = view(3, 6.0, 0.1, 1);
        assert!(!eligible(&small, req(1, Some(8.0), false), Preconditions::default(), Requester::Singleton));
    }

    #[test]
    fn exclusive_needs_idle_and_capacity() {
        let idle = view(0, 40.0, 0.0, 0);
        let busy = view(1, 40.0, 0.3, 1);
        assert!(eligible(&idle, req(1, Some(10.0), true), Preconditions::default(), Requester::Singleton));
        assert!(!eligible(&busy, req(1, None, true), Preconditions::default(), Requester::Singleton));
        let small_idle = view(2, 8.0, 0.0, 0);
        assert!(!eligible(&small_idle, req(1, Some(20.0), true), Preconditions::default(), Requester::Singleton));
    }

    #[test]
    fn pinned_held_and_mig_rules() {
        let mut pinned = view(0, 40.0, 0.0, 1);
        pinned.pinned = true;
        assert!(!eligible(&pinned, req(1, None, false), Preconditions::default(), Requester::Singleton));
        let mut held = view(1, 40.0, 0.0, 0);
        held.held = true;
        assert!(!eligible(&held, req(1, None, true), Preconditions::default(), Requester::Singleton));
        let mut mig = view(2, 40.0, 0.1, 1);
        mig.mig_enabled = true;
        mig.mig_free_instance = Some(1);
        mig.mig_instance_mem_gb = 10.0;
        assert!(eligible(&mig, req(1, Some(8.0), false), Preconditions::default(), Requester::Singleton));
        assert!(!eligible(&mig, req(1, Some(12.0), false), Preconditions::default(), Requester::Singleton));
        mig.mig_free_instance = None;
        assert!(!eligible(&mig, req(1, None, false), Preconditions::default(), Requester::Singleton));
    }

    #[test]
    fn gang_holds_revalidate_fit_only() {
        use crate::cluster::topology::ClusterTopology;
        use crate::config::schema::ClusterConfig;
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(1, 4, 40.0));
        let mut book = ReservationBook::new(&topo);
        book.hold(0, 7);
        let who = Requester::Gang { book: &book, task: 7 };
        // own hold: the precondition-violating SMACT no longer matters…
        let mut own = view(0, 10.0, 0.99, 1);
        own.held = true;
        let pre = Preconditions { smact_cap: Some(0.5), min_free_gb: None };
        assert!(eligible(&own, req(4, Some(8.0), false), pre, who));
        // …but a regressed memory fit drops it out of the dispatchable set
        assert!(!eligible(&own, req(4, Some(12.0), false), pre, who));
        // exclusive gangs additionally need the hold idle
        assert!(!eligible(&own, req(4, Some(8.0), true), pre, who));
        // a foreign hold or MIG device is never a gang target
        let mut foreign = view(1, 40.0, 0.0, 0);
        foreign.held = true;
        assert!(!eligible(&foreign, req(4, None, false), pre, who));
        let mut mig = view(2, 40.0, 0.0, 0);
        mig.mig_enabled = true;
        mig.mig_free_instance = Some(0);
        mig.mig_instance_mem_gb = 20.0;
        assert!(!eligible(&mig, req(4, None, false), pre, who));
        assert!(eligible(&mig, req(4, None, false), pre, Requester::Singleton), "singletons may");
    }

    #[test]
    fn classify_names_the_first_failing_filter() {
        let pre = Preconditions {
            smact_cap: Some(0.8),
            min_free_gb: Some(5.0),
        };
        let hot = view(0, 10.0, 0.9, 1);
        assert_eq!(
            classify(&hot, req(1, None, false), pre, Requester::Singleton),
            Some(RejectReason::SmactCap)
        );
        let tight = view(1, 3.0, 0.1, 1);
        assert_eq!(
            classify(&tight, req(1, None, false), pre, Requester::Singleton),
            Some(RejectReason::MinFree)
        );
        let small = view(2, 6.0, 0.1, 1);
        assert_eq!(
            classify(&small, req(1, Some(8.0), false), Preconditions::default(), Requester::Singleton),
            Some(RejectReason::NoFit)
        );
        let busy = view(3, 40.0, 0.3, 1);
        assert_eq!(
            classify(&busy, req(1, None, true), Preconditions::default(), Requester::Singleton),
            Some(RejectReason::NotIdle)
        );
        let mut pinned = view(4, 40.0, 0.0, 1);
        pinned.pinned = true;
        assert_eq!(
            classify(&pinned, req(1, None, false), Preconditions::default(), Requester::Singleton),
            Some(RejectReason::PinnedOrHeld)
        );
        let mut mig = view(5, 40.0, 0.1, 1);
        mig.mig_enabled = true;
        assert_eq!(
            classify(&mig, req(1, None, false), Preconditions::default(), Requester::Singleton),
            Some(RejectReason::MigBusy)
        );
        let ok = view(6, 10.0, 0.5, 1);
        assert_eq!(classify(&ok, req(1, Some(8.0), false), pre, Requester::Singleton), None);
    }

    #[test]
    fn unhealthy_beats_every_other_filter() {
        use crate::cluster::topology::ClusterTopology;
        use crate::config::schema::ClusterConfig;
        // an otherwise perfect device is cut by health alone
        let mut down = view(0, 40.0, 0.0, 0);
        down.unhealthy = true;
        assert_eq!(
            classify(&down, req(1, Some(8.0), false), Preconditions::default(), Requester::Singleton),
            Some(RejectReason::Unhealthy)
        );
        // even the gang HOLDING the device must not dispatch onto it
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(1, 4, 40.0));
        let mut book = ReservationBook::new(&topo);
        book.hold(0, 7);
        down.held = true;
        assert_eq!(
            classify(
                &down,
                req(4, Some(8.0), false),
                Preconditions::default(),
                Requester::Gang { book: &book, task: 7 }
            ),
            Some(RejectReason::Unhealthy)
        );
    }

    #[test]
    fn reject_reason_index_and_names_are_stable() {
        for (i, r) in RejectReason::ALL.iter().enumerate() {
            assert_eq!(r.index(), i, "{:?} discriminant drifted", r);
        }
        let names: std::collections::BTreeSet<_> =
            RejectReason::ALL.iter().map(|r| r.name()).collect();
        assert_eq!(names.len(), RejectReason::COUNT, "duplicate reason name");
    }
}

