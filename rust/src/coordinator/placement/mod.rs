//! The fabric-aware placement core (DESIGN.md §12): ONE pipeline —
//! eligibility → enumerate → cost → commit — behind every placement
//! decision in the coordinator.
//!
//! Before this subsystem existed the coordinator ran two divergent
//! pipelines: `policy::select_two_level` placed server-local (singleton)
//! tasks island-blind, while `gang::plan_gang` ranked gang candidates by
//! fabric cost — duplicating the precondition checks, the power-slot math
//! and the candidate ranking between them. Both are now thin callers of
//! this module:
//!
//! * [`eligibility`] — the single per-GPU filter (MIG instances, pinned
//!   residents, gang holds, power-implied idleness, SMACT/memory
//!   preconditions, demand fit) shared by singletons and gangs;
//! * [`enumerate`] — the deterministic candidate enumerator: per-server
//!   policy-ordered sets, island-local alternatives on multi-island
//!   servers, the gang island-packing order, per-server power-slot caps;
//! * [`cost`] — the pluggable [`CostModel`]: OOM-risk / utilization
//!   policy term + fabric ring cost + NIC occupancy, compared
//!   lexicographically.
//!
//! **Byte-reproduction contract.** With `fabric: None` (the
//! `--fabric-aware-singletons off` switch) every function here reproduces
//! the seed pipeline bit-for-bit: the enumerator emits exactly the seed
//! candidate, the cost model's fabric and NIC terms are constant zero, and
//! the comparison degenerates to the seed's strict policy ordering. With
//! `fabric: Some(_)` the contract is structural: [`select_singleton`]
//! drops the handle for any decision where no admitted server has
//! `Fabric::islands_matter` (1 < islands < GPUs) — so single-island
//! (nvlink) and singleton-island (flat-pcie) substrates decide identically
//! either way, NIC tie-breaks included, and only genuinely multi-island
//! substrates (dual-island, custom `island_size`) can diverge.
//!
//! **Determinism.** Everything is a pure function of the monitor snapshot
//! (no clocks, no RNG, no maps with nondeterministic iteration); f64
//! comparisons use `total_cmp` and sums run in enumeration order, so the
//! speculative (worker-thread) and inline paths of DESIGN.md §10 compute
//! identical plans at every shard and thread count.

pub mod cost;
pub mod eligibility;
pub mod enumerate;

pub use cost::{CostModel, SetScore};
pub use eligibility::{RejectReason, Requester};

use crate::cluster::Fabric;
use crate::config::schema::{PolicyKind, PowerConfig};
use crate::coordinator::gang::{GangPlan, ReservationBook};
use crate::coordinator::policy::{
    GpuView, MappingRequest, Placement, Preconditions, ServerView,
};
use crate::sim::TaskId;

/// Flat (single device pool) selection — the per-server scan the
/// two-level mapping builds on, and the public seed API of
/// `policy::select_gpus`. `rr_cursor` carries Round-Robin state across
/// calls. Returns None when no eligible set exists right now.
pub fn select_flat(
    policy: PolicyKind,
    views: &[GpuView],
    req: MappingRequest,
    pre: Preconditions,
    rr_cursor: &mut usize,
) -> Option<Placement> {
    if req.exclusive || policy == PolicyKind::Exclusive {
        return exclusive_flat(views, req, pre);
    }

    let mut eligible: Vec<&GpuView> = views
        .iter()
        .filter(|v| eligibility::eligible(v, req, pre, Requester::Singleton))
        .collect();
    if eligible.len() < req.n_gpus {
        return None;
    }

    if policy == PolicyKind::RoundRobin {
        // cyclic order over the ids actually present, starting at the
        // cursor — ids need not be contiguous or 0-based (per-server
        // slices carry global ids)
        let mut ids: Vec<usize> = views.iter().map(|v| v.id).collect();
        ids.sort_unstable();
        let start = ids.iter().position(|&id| id >= *rr_cursor).unwrap_or(0);
        let mut chosen = Vec::new();
        for off in 0..ids.len() {
            let id = ids[(start + off) % ids.len()];
            if eligible.iter().any(|v| v.id == id) {
                chosen.push(id);
                if chosen.len() == req.n_gpus {
                    *rr_cursor = id + 1;
                    break;
                }
            }
        }
        if chosen.len() < req.n_gpus {
            return None;
        }
        return Some(placement(views, chosen));
    }

    enumerate::policy_order(&mut eligible, policy);
    Some(placement(
        views,
        eligible[..req.n_gpus].iter().map(|v| v.id).collect(),
    ))
}

/// Provenance of one singleton placement decision (DESIGN.md §14): who was
/// filtered out and why, how many candidate sets were ranked, and the
/// winning candidate's lexicographic cost terms. Filled by
/// [`select_singleton_explained`] from the same snapshot the decision used,
/// so the explanation can never disagree with the commit. Plain counters —
/// `Send`, cheap to clone, deterministic (census runs in view order).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Explain {
    /// Servers whose `admits` gate passed.
    pub servers_admitted: usize,
    /// Servers cut by the admission gate (power envelope / capacity).
    pub servers_rejected: usize,
    /// GPUs on admitted servers that survived every eligibility filter.
    pub gpus_eligible: usize,
    /// Per-reason reject counts, indexed by [`RejectReason::index`].
    pub rejects: [u64; RejectReason::COUNT],
    /// Candidate GPU sets actually ranked (sortable policies enumerate
    /// many; exclusive/RR commit the first workable set, so 0 or 1).
    pub candidates: usize,
    /// The committed candidate's score terms (sortable policies only —
    /// exclusive/RR pick positionally and never compute a score).
    pub winner: Option<SetScore>,
}

/// Two-level cluster selection for server-local (singleton) tasks: filter
/// servers (power envelope, capacity), enumerate candidate GPU sets per
/// surviving server, rank them with the [`CostModel`], commit the best.
/// `fabric: None` is the island-blind seed decision; `fabric: Some(_)`
/// additionally ranks by island boundaries and NVLink/PCIe cost exactly
/// like the gang planner does. Multi-GPU requests never span servers.
pub fn select_singleton(
    policy: PolicyKind,
    servers: &[ServerView],
    req: MappingRequest,
    pre: Preconditions,
    rr_cursor: &mut usize,
    fabric: Option<&Fabric>,
) -> Option<Placement> {
    select_singleton_explained(policy, servers, req, pre, rr_cursor, fabric).0
}

/// [`select_singleton`] plus decision provenance. The placement result is
/// identical — the explanation is a read-only census over the same views
/// (the per-GPU reject classification re-runs [`eligibility::classify`],
/// which IS the filter the enumerator applies).
pub fn select_singleton_explained(
    policy: PolicyKind,
    servers: &[ServerView],
    req: MappingRequest,
    pre: Preconditions,
    rr_cursor: &mut usize,
    fabric: Option<&Fabric>,
) -> (Option<Placement>, Explain) {
    let mut ex = Explain::default();
    let admitted: Vec<&ServerView> = servers.iter().filter(|s| s.admits(req)).collect();
    ex.servers_admitted = admitted.len();
    ex.servers_rejected = servers.len() - admitted.len();
    if admitted.is_empty() {
        return (None, ex);
    }

    // island-aware ranking only where island structure can matter at all:
    // a cluster of single-island (nvlink) and singleton-island (flat-pcie)
    // servers decides identically to the blind pipeline BY CONSTRUCTION —
    // including the NIC tie-break, which must not leak divergence into
    // substrates the off-switch contract promises unchanged (§12)
    let fabric = fabric.filter(|f| admitted.iter().any(|s| f.islands_matter(s.id)));

    // per-GPU census under the EFFECTIVE request (the exclusive paths
    // upgrade the request before filtering, and so must the census)
    let eff = if req.exclusive || policy == PolicyKind::Exclusive {
        MappingRequest {
            exclusive: true,
            ..req
        }
    } else {
        req
    };
    for s in &admitted {
        for v in &s.gpus {
            match eligibility::classify(v, eff, pre, Requester::Singleton) {
                None => ex.gpus_eligible += 1,
                Some(r) => ex.rejects[r.index()] += 1,
            }
        }
    }

    if req.exclusive || policy == PolicyKind::Exclusive {
        // lowest-id admitted server with enough idle targets
        let p = admitted
            .iter()
            .find_map(|s| exclusive_on_server(s, eff, pre, fabric));
        ex.candidates = usize::from(p.is_some());
        return (p, ex);
    }

    if policy == PolicyKind::RoundRobin {
        let p = select_round_robin(&admitted, req, pre, rr_cursor, fabric);
        ex.candidates = usize::from(p.is_some());
        return (p, ex);
    }

    // sortable policies (MAGM / LUG / MUG): enumerate candidates per
    // admitted server, score each, keep the strictly best — ties go to
    // the earliest enumerated (servers ascending, blind set first)
    let model = CostModel { policy, fabric };
    let mut best: Option<(SetScore, Placement)> = None;
    for s in &admitted {
        for cand in
            enumerate::server_candidates(s, req, pre, policy, fabric, Requester::Singleton)
        {
            ex.candidates += 1;
            let score = model.score(s, &cand);
            if best.as_ref().is_none_or(|(b, _)| score.better_than(b)) {
                best = Some((score, placement(&s.gpus, cand)));
            }
        }
    }
    ex.winner = best.as_ref().map(|(sc, _)| *sc);
    (best.map(|(_, p)| p), ex)
}

/// One all-or-nothing placement attempt for a gang (DESIGN.md §11),
/// running entirely on the shared core: collect eligible GPUs under the
/// same filter the singleton mappers use, cap each server's contribution
/// by its power envelope (reserved slots included), rank candidates in
/// island-packing order — fewest servers, then fullest islands, then the
/// quietest devices — and either place the full worker set or propose new
/// holds on everything eligible. Pure function of its inputs.
pub fn plan_gang(
    views: &[ServerView],
    fabric: &Fabric,
    book: &ReservationBook,
    power_cfg: &PowerConfig,
    req: MappingRequest,
    pre: Preconditions,
    task: TaskId,
) -> GangPlan {
    let who = Requester::Gang { book, task };
    // per server: fabric-ranked eligible GPU ids, power-capped
    let mut cands: Vec<(usize, Vec<usize>)> = Vec::new();
    for s in views {
        let own_slots = s
            .gpus
            .iter()
            .filter(|v| book.holder(v.id) == Some(task))
            .count();
        let mut elig = enumerate::eligible_views(s, req, pre, who);
        if elig.is_empty() {
            continue;
        }
        enumerate::island_packed_order(&mut elig, fabric, &|g| book.holder(g) == Some(task));
        let k_max = enumerate::power_slot_cap(s, own_slots, power_cfg, elig.len());
        elig.truncate(k_max);
        if !elig.is_empty() {
            cands.push((s.id, elig.iter().map(|v| v.id).collect()));
        }
    }

    // fewest servers spanned: fill the best-stocked server first
    cands.sort_by(|a, b| b.1.len().cmp(&a.1.len()).then(a.0.cmp(&b.0)));
    let available: usize = cands.iter().map(|(_, g)| g.len()).sum();
    if available >= req.n_gpus {
        let mut chosen = Vec::with_capacity(req.n_gpus);
        'fill: for (_, gpus) in &cands {
            for &g in gpus {
                chosen.push(g);
                if chosen.len() == req.n_gpus {
                    break 'fill;
                }
            }
        }
        return GangPlan::Place(chosen);
    }
    // partial: claim everything eligible we do not hold yet
    let new_holds: Vec<usize> = cands
        .iter()
        .flat_map(|(_, gpus)| gpus.iter().copied())
        .filter(|&g| book.holder(g) != Some(task))
        .collect();
    GangPlan::Hold(new_holds)
}

/// Exclusive placement over a flat device pool: idle devices only (or
/// free MIG instances when MIG is on), first `n_gpus` in view order — the
/// seed behavior, byte-for-byte.
fn exclusive_flat(views: &[GpuView], req: MappingRequest, pre: Preconditions) -> Option<Placement> {
    let excl = MappingRequest {
        exclusive: true,
        ..req
    };
    let idle: Vec<usize> = views
        .iter()
        .filter(|v| eligibility::eligible(v, excl, pre, Requester::Singleton))
        .map(|v| v.id)
        .take(req.n_gpus)
        .collect();
    if idle.len() < req.n_gpus {
        return None;
    }
    Some(placement(views, idle))
}

/// Exclusive placement on one server. Island-blind: first `n_gpus` idle
/// devices in view order (seed). Island-aware on a multi-island server:
/// the idle devices in island-packing order, so an exclusive pair lands
/// inside one island when any island can host it.
fn exclusive_on_server(
    s: &ServerView,
    excl: MappingRequest,
    pre: Preconditions,
    fabric: Option<&Fabric>,
) -> Option<Placement> {
    let mut idle = enumerate::eligible_views(s, excl, pre, Requester::Singleton);
    if idle.len() < excl.n_gpus {
        return None;
    }
    if let Some(f) = fabric {
        if excl.n_gpus >= 2 && f.islands_matter(s.id) {
            enumerate::island_packed_order(&mut idle, f, &|_| false);
        }
    }
    let ids: Vec<usize> = idle[..excl.n_gpus].iter().map(|v| v.id).collect();
    Some(placement(&s.gpus, ids))
}

/// Cluster-wide Round-Robin: cycle over eligible GPUs cluster-wide; the
/// first pick fixes the host server, the remaining GPUs of a multi-GPU
/// request come from that same server — cyclically in blind mode (seed),
/// same-island-first on a multi-island host in island-aware mode.
fn select_round_robin(
    admitted: &[&ServerView],
    req: MappingRequest,
    pre: Preconditions,
    rr_cursor: &mut usize,
    fabric: Option<&Fabric>,
) -> Option<Placement> {
    let mut flat: Vec<&GpuView> = admitted
        .iter()
        .flat_map(|s| s.gpus.iter())
        .filter(|v| eligibility::eligible(v, req, pre, Requester::Singleton))
        .collect();
    flat.sort_unstable_by_key(|v| v.id);
    if flat.is_empty() {
        return None;
    }
    let start = flat.iter().position(|v| v.id >= *rr_cursor).unwrap_or(0);
    for off in 0..flat.len() {
        let first = flat[(start + off) % flat.len()];
        let host = admitted.iter().find(|s| s.id == first.server)?;
        // island-aware completion only where island structure can actually
        // influence the pick: the host's islands must matter AND the
        // eligible partners must be island-MIXED relative to the first
        // pick — with all partners on the first pick's island or none, the
        // island order degenerates to the cyclic one, so the seed path
        // below keeps its exact cursor semantics.
        if let Some(f) = fabric.filter(|f| req.n_gpus >= 2 && f.islands_matter(host.id)) {
            // `flat` already holds every eligible device cluster-wide —
            // the host's partners are its slice of it, minus the first pick
            let partners: Vec<&GpuView> = flat
                .iter()
                .filter(|v| v.server == host.id && v.id != first.id)
                .copied()
                .collect();
            let first_island = f.island_of(first.id);
            let same = partners.iter().any(|v| f.island_of(v.id) == first_island);
            let diff = partners.iter().any(|v| f.island_of(v.id) != first_island);
            if same && diff {
                if let Some(p) = rr_complete_on_island(host, first, partners, req, f, rr_cursor)
                {
                    return Some(p);
                }
                continue;
            }
        }
        let mut cursor = first.id; // the first pick itself starts the cycle
        if let Some(p) = select_flat(PolicyKind::RoundRobin, &host.gpus, req, pre, &mut cursor) {
            *rr_cursor = cursor;
            return Some(p);
        }
    }
    None
}

/// Island-aware completion of a multi-GPU Round-Robin pick: the cursor
/// fixed the first device; partners come from the host's other eligible
/// devices, same-island first, then cyclic id order from the first pick —
/// the cycle semantics survive while the set stays island-tight whenever
/// the host allows it. The cursor resumes right after the FIRST pick (it
/// tracks the rotation of first picks; partners are island-guided, not
/// cycle-guided), so consecutive decisions keep rotating across devices.
fn rr_complete_on_island(
    host: &ServerView,
    first: &GpuView,
    mut partners: Vec<&GpuView>,
    req: MappingRequest,
    fabric: &Fabric,
    rr_cursor: &mut usize,
) -> Option<Placement> {
    if partners.len() + 1 < req.n_gpus {
        return None;
    }
    // cyclic position from the first pick over the host's id-sorted cycle
    let mut ids: Vec<usize> = host.gpus.iter().map(|v| v.id).collect();
    ids.sort_unstable();
    let n_ids = ids.len();
    let pos0 = ids.iter().position(|&id| id == first.id).expect("first on host");
    let cyc = |id: usize| -> usize {
        let p = ids.iter().position(|&x| x == id).expect("gpu on host");
        (p + n_ids - pos0) % n_ids
    };
    let first_island = fabric.island_of(first.id);
    partners.sort_by_key(|v| (fabric.island_of(v.id) != first_island, cyc(v.id)));
    let mut chosen = vec![first.id];
    chosen.extend(partners[..req.n_gpus - 1].iter().map(|v| v.id));
    *rr_cursor = first.id + 1;
    Some(placement(&host.gpus, chosen))
}

/// Materialize a chosen id set against its views (MIG instance lookup).
fn placement(views: &[GpuView], gpus: Vec<usize>) -> Placement {
    let instances = gpus
        .iter()
        .map(|&g| {
            let v = views.iter().find(|v| v.id == g).unwrap();
            if v.mig_enabled {
                v.mig_free_instance
            } else {
                None
            }
        })
        .collect();
    Placement { gpus, instances }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology::ClusterTopology;
    use crate::config::schema::{ClusterConfig, FabricConfig, FabricProfile};

    fn view(id: usize, server: usize, free: f64, smact: f64, n: usize) -> GpuView {
        GpuView {
            id,
            server,
            free_gb: free,
            smact_window: smact,
            n_tasks: n,
            pinned: false,
            held: false,
            unhealthy: false,
            mig_free_instance: None,
            mig_instance_mem_gb: 0.0,
            mig_enabled: false,
        }
    }

    fn sview(id: usize, gpus: Vec<GpuView>) -> ServerView {
        ServerView {
            id,
            power_w: 0.0,
            power_cap_w: None,
            gpus: gpus.into(),
        }
    }

    fn req(n: usize, demand: Option<f64>) -> MappingRequest {
        MappingRequest {
            n_gpus: n,
            demand_gb: demand,
            exclusive: false,
        }
    }

    fn dual_island(servers: usize, gpus: usize) -> Fabric {
        let topo =
            ClusterTopology::from_config(&ClusterConfig::homogeneous(servers, gpus, 40.0));
        Fabric::new(
            &topo,
            &FabricConfig {
                profile: FabricProfile::DualIsland,
                ..FabricConfig::default()
            },
        )
    }

    #[test]
    fn island_aware_pair_lands_inside_one_island() {
        // the ISSUE's acceptance shape: dual-island server, the two
        // most-free GPUs straddle the PCIe bridge — blind MAGM splits the
        // pair, the fabric-aware core keeps it on NVLink
        let f = dual_island(1, 4);
        let servers = [sview(
            0,
            vec![
                view(0, 0, 20.0, 0.1, 1),
                view(1, 0, 22.0, 0.1, 1),
                view(2, 0, 39.0, 0.1, 1),
                view(3, 0, 5.0, 0.1, 1),
            ],
        )];
        let mut rr = 0;
        let blind = select_singleton(
            PolicyKind::Magm,
            &servers,
            req(2, Some(4.0)),
            Preconditions::default(),
            &mut rr,
            None,
        )
        .unwrap();
        assert_eq!(blind.gpus, vec![2, 1], "blind: top free memory, split");
        let aware = select_singleton(
            PolicyKind::Magm,
            &servers,
            req(2, Some(4.0)),
            Preconditions::default(),
            &mut rr,
            Some(&f),
        )
        .unwrap();
        // both islands can host the pair; equal ring cost, so the policy
        // term picks the roomier island (39 + 5 > 22 + 20)
        assert_eq!(aware.gpus, vec![2, 3], "aware: best island-local pair");
        assert_eq!(f.islands_spanned(&aware.gpus), 1);
        assert!(f.set_cost(&aware.gpus) < f.set_cost(&blind.gpus));
    }

    #[test]
    fn aware_falls_back_to_split_when_no_island_fits() {
        let f = dual_island(1, 4);
        // only one eligible device per island: the pair must split — and
        // then it must be the seed (blind) pair, not something new
        let servers = [sview(
            0,
            vec![view(0, 0, 30.0, 0.1, 1), view(2, 0, 25.0, 0.1, 1)],
        )];
        let mut rr = 0;
        let aware = select_singleton(
            PolicyKind::Magm,
            &servers,
            req(2, Some(4.0)),
            Preconditions::default(),
            &mut rr,
            Some(&f),
        )
        .unwrap();
        assert_eq!(aware.gpus, vec![0, 2]);
    }

    #[test]
    fn single_gpu_requests_ignore_islands() {
        let f = dual_island(2, 4);
        let servers = [
            sview(0, (0..4).map(|g| view(g, 0, 10.0 + g as f64, 0.1, 1)).collect()),
            sview(1, (4..8).map(|g| view(g, 1, 30.0 - g as f64, 0.1, 1)).collect()),
        ];
        let mut rr1 = 0;
        let mut rr2 = 0;
        let blind = select_singleton(
            PolicyKind::Magm,
            &servers,
            req(1, None),
            Preconditions::default(),
            &mut rr1,
            None,
        );
        let aware = select_singleton(
            PolicyKind::Magm,
            &servers,
            req(1, None),
            Preconditions::default(),
            &mut rr2,
            Some(&f),
        );
        assert_eq!(blind, aware, "n=1 sets have zero ring cost everywhere");
    }

    #[test]
    fn exclusive_pair_packs_an_island() {
        let f = dual_island(1, 4);
        // gpu 1 busy: island 0 can't host an idle pair, island 1 can —
        // blind exclusive would take {0, 2} (first idle in id order)
        let servers = [sview(
            0,
            vec![
                view(0, 0, 40.0, 0.0, 0),
                view(1, 0, 40.0, 0.3, 1),
                view(2, 0, 40.0, 0.0, 0),
                view(3, 0, 40.0, 0.0, 0),
            ],
        )];
        let excl = MappingRequest {
            n_gpus: 2,
            demand_gb: Some(8.0),
            exclusive: true,
        };
        let mut rr = 0;
        let blind =
            select_singleton(PolicyKind::Magm, &servers, excl, Preconditions::default(), &mut rr, None)
                .unwrap();
        assert_eq!(blind.gpus, vec![0, 2]);
        let aware = select_singleton(
            PolicyKind::Magm,
            &servers,
            excl,
            Preconditions::default(),
            &mut rr,
            Some(&f),
        )
        .unwrap();
        assert_eq!(aware.gpus, vec![2, 3], "the fully-idle island hosts the pair");
    }

    #[test]
    fn round_robin_pair_stays_on_the_first_picks_island() {
        let f = dual_island(1, 4);
        let servers = [sview(0, (0..4).map(|g| view(g, 0, 40.0, 0.0, 0)).collect())];
        // cursor at 2: blind RR would take {2, 3}; island-aware the same —
        // but from cursor 1 blind takes {1, 2} (split) while aware keeps
        // the pair with 1's island partner 0
        let mut rr = 1;
        let blind = select_singleton(
            PolicyKind::RoundRobin,
            &servers,
            req(2, None),
            Preconditions::default(),
            &mut rr,
            None,
        )
        .unwrap();
        assert_eq!(blind.gpus, vec![1, 2]);
        let mut rr = 1;
        let aware = select_singleton(
            PolicyKind::RoundRobin,
            &servers,
            req(2, None),
            Preconditions::default(),
            &mut rr,
            Some(&f),
        )
        .unwrap();
        assert_eq!(aware.gpus, vec![1, 0], "partner from island 0, not across");
        assert_eq!(rr, 2, "cursor rotates past the first pick");
    }

    #[test]
    fn explained_matches_plain_and_counts_the_census() {
        let servers = [sview(
            0,
            vec![
                view(0, 0, 20.0, 0.1, 1),
                view(1, 0, 2.0, 0.1, 1),  // demand won't fit
                view(2, 0, 39.0, 0.9, 1), // over the SMACT cap
                view(3, 0, 30.0, 0.1, 1),
            ],
        )];
        let pre = Preconditions {
            smact_cap: Some(0.8),
            min_free_gb: None,
        };
        let mut rr1 = 0;
        let mut rr2 = 0;
        let plain =
            select_singleton(PolicyKind::Magm, &servers, req(1, Some(4.0)), pre, &mut rr1, None);
        let (p, ex) = select_singleton_explained(
            PolicyKind::Magm,
            &servers,
            req(1, Some(4.0)),
            pre,
            &mut rr2,
            None,
        );
        assert_eq!(p, plain, "explanation must not perturb the decision");
        assert_eq!(ex.servers_admitted, 1);
        assert_eq!(ex.servers_rejected, 0);
        assert_eq!(ex.gpus_eligible, 2);
        assert_eq!(ex.rejects[RejectReason::NoFit.index()], 1);
        assert_eq!(ex.rejects[RejectReason::SmactCap.index()], 1);
        assert!(ex.candidates >= 1);
        let w = ex.winner.expect("sortable policy records the winning score");
        assert_eq!(w.fabric_cost, 0.0, "blind mode: fabric term is zero");
    }

    #[test]
    fn explained_exclusive_census_uses_the_upgraded_request() {
        // Exclusive policy upgrades the request before filtering; a busy
        // device must therefore count as not_idle, not as eligible.
        let servers = [sview(
            0,
            vec![view(0, 0, 40.0, 0.0, 0), view(1, 0, 40.0, 0.3, 1)],
        )];
        let mut rr = 0;
        let (p, ex) = select_singleton_explained(
            PolicyKind::Exclusive,
            &servers,
            req(1, Some(4.0)),
            Preconditions::default(),
            &mut rr,
            None,
        );
        assert!(p.is_some());
        assert_eq!(ex.gpus_eligible, 1);
        assert_eq!(ex.rejects[RejectReason::NotIdle.index()], 1);
        assert_eq!(ex.candidates, 1, "exclusive commits the first workable set");
        assert!(ex.winner.is_none(), "positional paths never score");
    }

    #[test]
    fn cross_server_tie_prefers_quiet_nic() {
        let mut f = dual_island(2, 4);
        f.occupy_links(&[0, 4], 0.7); // both NICs loaded…
        f.release_links(&[4], 0.7); // …server 1's released again
        let mk = |sid: usize, base: usize| {
            sview(sid, (base..base + 4).map(|g| view(g, sid, 20.0, 0.1, 1)).collect())
        };
        let servers = [mk(0, 0), mk(1, 4)];
        let mut rr = 0;
        let aware = select_singleton(
            PolicyKind::Magm,
            &servers,
            req(2, Some(4.0)),
            Preconditions::default(),
            &mut rr,
            Some(&f),
        )
        .unwrap();
        assert_eq!(aware.gpus, vec![4, 5], "identical sets otherwise: quiet NIC wins");
    }
}
