//! The pluggable cost model of the placement core (DESIGN.md §12).
//!
//! A candidate GPU set is scored on three lexicographic axes:
//!
//! 1. **fabric cost** — [`Fabric::set_cost`], the ring-all-reduce per-GB
//!    transfer cost of the set: island boundaries and the NVLink/PCIe/NIC
//!    bandwidth classes surface here. Absent (constant 0) in island-blind
//!    mode, which is what byte-reproduces the seed ranking.
//! 2. **policy term** — the per-GPU criterion of the configured policy
//!    summed over the set in selection order: the OOM-risk term (MAGM
//!    ranks by free memory, paper §4.3) or the utilization-cap term
//!    (LUG/MUG rank by windowed SMACT).
//! 3. **NIC occupancy** — the host server's uplink load, so among
//!    placements equal on both axes above the quietest server wins:
//!    landing beside a spanning gang's loaded NIC invites the contention
//!    term of `interference::fabric_factor` onto future spanning work.
//!
//! Lexicographic rather than weighted: the fabric term only breaks into
//! the decision when island structure actually differs between candidate
//! sets, and a zeroed fabric + NIC term reduces the order to the seed's
//! pure policy comparison — the two properties the `[placement]` off
//! switch's byte-reproduction contract rests on.

use crate::cluster::Fabric;
use crate::config::schema::PolicyKind;
use crate::coordinator::policy::{GpuView, ServerView};

/// Scoring context: the policy supplies the risk/utilization term, the
/// optional fabric supplies the interconnect terms. `fabric: None` is the
/// island-blind (seed) model.
pub struct CostModel<'a> {
    pub policy: PolicyKind,
    pub fabric: Option<&'a Fabric>,
}

/// One candidate set's score, compared lexicographically by
/// [`SetScore::better_than`]. Full ties keep the earlier-enumerated
/// candidate (servers ascending, the island-blind set before island
/// sets), which pins determinism at every shard/thread count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SetScore {
    pub fabric_cost: f64,
    pub policy: f64,
    pub nic_load: f64,
}

impl CostModel<'_> {
    /// The per-GPU policy criterion (higher = better target).
    pub fn gpu_term(&self, v: &GpuView) -> f64 {
        match self.policy {
            PolicyKind::Magm => v.free_gb,
            PolicyKind::Lug => -v.smact_window,
            PolicyKind::Mug => v.smact_window,
            // cursor- and idleness-driven policies carry no criterion
            PolicyKind::RoundRobin | PolicyKind::Exclusive => 0.0,
        }
    }

    /// Score `set` (ids in selection order — the f64 sum order is part of
    /// the bit-reproducibility contract) hosted on `server`.
    pub fn score(&self, server: &ServerView, set: &[usize]) -> SetScore {
        let policy: f64 = set
            .iter()
            .map(|&g| {
                let v = server
                    .gpus
                    .iter()
                    .find(|v| v.id == g)
                    .expect("chosen gpu in view");
                self.gpu_term(v)
            })
            .sum();
        SetScore {
            fabric_cost: self.fabric.map_or(0.0, |f| f.set_cost(set)),
            policy,
            nic_load: self.fabric.map_or(0.0, |f| f.nic_load(server.id)),
        }
    }
}

impl SetScore {
    /// Strictly better: cheaper fabric, then stronger policy term, then a
    /// quieter NIC. Equal scores return false — the first enumerated
    /// candidate wins, exactly as the seed's strict `score > best` did.
    pub fn better_than(&self, other: &SetScore) -> bool {
        use std::cmp::Ordering;
        match self.fabric_cost.total_cmp(&other.fabric_cost) {
            Ordering::Less => true,
            Ordering::Greater => false,
            Ordering::Equal => match self.policy.total_cmp(&other.policy) {
                Ordering::Greater => true,
                Ordering::Less => false,
                Ordering::Equal => self.nic_load < other.nic_load,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology::ClusterTopology;
    use crate::config::schema::{ClusterConfig, FabricConfig, FabricProfile};

    fn view(id: usize, server: usize, free: f64, smact: f64) -> GpuView {
        GpuView {
            id,
            server,
            free_gb: free,
            smact_window: smact,
            n_tasks: 1,
            pinned: false,
            held: false,
            unhealthy: false,
            mig_free_instance: None,
            mig_instance_mem_gb: 0.0,
            mig_enabled: false,
        }
    }

    fn server(id: usize, gpus: Vec<GpuView>) -> ServerView {
        ServerView {
            id,
            power_w: 0.0,
            power_cap_w: None,
            gpus: gpus.into(),
        }
    }

    #[test]
    fn blind_model_is_pure_policy_comparison() {
        let s = server(0, vec![view(0, 0, 10.0, 0.2), view(1, 0, 30.0, 0.6)]);
        let m = CostModel {
            policy: PolicyKind::Magm,
            fabric: None,
        };
        let a = m.score(&s, &[0]);
        let b = m.score(&s, &[1]);
        assert_eq!(a.fabric_cost, 0.0);
        assert_eq!(a.nic_load, 0.0);
        assert!(b.better_than(&a), "30 GB free beats 10");
        assert!(!a.better_than(&b));
        assert!(!a.better_than(&a), "ties are not better (first wins)");
        let lug = CostModel {
            policy: PolicyKind::Lug,
            fabric: None,
        };
        assert!(lug.score(&s, &[0]).better_than(&lug.score(&s, &[1])));
    }

    #[test]
    fn fabric_term_dominates_policy_term() {
        // dual-island 1×4: islands {0,1} and {2,3}. The split pair has more
        // free memory but crosses PCIe — the island pair must win.
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(1, 4, 40.0));
        let fabric = Fabric::new(
            &topo,
            &FabricConfig {
                profile: FabricProfile::DualIsland,
                ..FabricConfig::default()
            },
        );
        let s = server(
            0,
            vec![
                view(0, 0, 20.0, 0.1),
                view(1, 0, 20.0, 0.1),
                view(2, 0, 39.0, 0.1),
                view(3, 0, 5.0, 0.1),
            ],
        );
        let m = CostModel {
            policy: PolicyKind::Magm,
            fabric: Some(&fabric),
        };
        let island_pair = m.score(&s, &[0, 1]);
        let split_pair = m.score(&s, &[2, 1]);
        assert!(island_pair.fabric_cost < split_pair.fabric_cost);
        assert!(split_pair.policy > island_pair.policy);
        assert!(island_pair.better_than(&split_pair), "fabric axis ranks first");
    }

    #[test]
    fn nic_occupancy_breaks_full_ties() {
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(2, 2, 40.0));
        let mut fabric = Fabric::new(&topo, &FabricConfig::default());
        fabric.occupy_links(&[0, 2], 0.5); // both servers' NICs loaded…
        fabric.release_links(&[2], 0.5); // …then server 1's released
        let s0 = server(0, vec![view(0, 0, 10.0, 0.2), view(1, 0, 10.0, 0.2)]);
        let s1 = server(1, vec![view(2, 1, 10.0, 0.2), view(3, 1, 10.0, 0.2)]);
        let m = CostModel {
            policy: PolicyKind::Magm,
            fabric: Some(&fabric),
        };
        let on_loaded = m.score(&s0, &[0, 1]);
        let on_quiet = m.score(&s1, &[2, 3]);
        assert_eq!(on_loaded.fabric_cost, on_quiet.fabric_cost);
        assert_eq!(on_loaded.policy, on_quiet.policy);
        assert!(on_quiet.better_than(&on_loaded), "quiet NIC wins the tie");
    }
}
