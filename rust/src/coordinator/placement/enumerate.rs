//! Deterministic candidate enumeration of the placement core (DESIGN.md
//! §12): which GPU sets are even on the table for a request, in which
//! order. Everything here is a pure function of the monitor snapshot, so
//! candidates are identical on every shard and at every engine thread
//! count — the cost model then ranks them, and full ties resolve to the
//! earliest enumerated set.

use std::collections::BTreeMap;

use crate::cluster::{power, Fabric};
use crate::config::schema::{PolicyKind, PowerConfig};
use crate::coordinator::policy::{GpuView, MappingRequest, Preconditions, ServerView};

use super::eligibility::{self, Requester};

/// One server's eligible devices, in view (= ascending id) order.
pub fn eligible_views<'a>(
    s: &'a ServerView,
    req: MappingRequest,
    pre: Preconditions,
    who: Requester,
) -> Vec<&'a GpuView> {
    s.gpus
        .iter()
        .filter(|v| eligibility::eligible(v, req, pre, who))
        .collect()
}

/// The seed policy ordering (most-free / least-utilized / most-utilized
/// first, ids break ties) — the island-blind ranking every candidate
/// inherits within itself. Cursor- and idleness-driven policies keep view
/// order.
pub fn policy_order(elig: &mut [&GpuView], policy: PolicyKind) {
    match policy {
        PolicyKind::Magm => {
            elig.sort_by(|a, b| b.free_gb.total_cmp(&a.free_gb).then(a.id.cmp(&b.id)))
        }
        PolicyKind::Lug => elig.sort_by(|a, b| {
            a.smact_window
                .total_cmp(&b.smact_window)
                .then(a.id.cmp(&b.id))
        }),
        PolicyKind::Mug => elig.sort_by(|a, b| {
            b.smact_window
                .total_cmp(&a.smact_window)
                .then(a.id.cmp(&b.id))
        }),
        PolicyKind::RoundRobin | PolicyKind::Exclusive => {}
    }
}

/// Eligible-device histogram per island.
fn island_histogram(elig: &[&GpuView], fabric: &Fabric) -> BTreeMap<usize, usize> {
    let mut h = BTreeMap::new();
    for v in elig {
        *h.entry(fabric.island_of(v.id)).or_insert(0usize) += 1;
    }
    h
}

/// Island-packing order, shared verbatim between the gang planner and the
/// island-aware singleton paths: devices the requester already holds
/// first (keep what we have), then islands with the most eligible devices
/// (a set that fills whole islands crosses the fewest links), then island
/// id, then the quietest devices, then id.
pub fn island_packed_order(elig: &mut [&GpuView], fabric: &Fabric, held_by_us: &dyn Fn(usize) -> bool) {
    let count = island_histogram(elig, fabric);
    elig.sort_by_key(|v| {
        let island = fabric.island_of(v.id);
        (
            !held_by_us(v.id),
            std::cmp::Reverse(count[&island]),
            island,
            v.n_tasks,
            v.id,
        )
    });
}

/// Candidate GPU sets of one server for a sortable-policy request,
/// enumeration order = preference order on full ties. Island-blind mode
/// (`fabric: None`) emits exactly the seed candidate — the policy-ordered
/// top-n. Island-aware mode appends one candidate per island that can
/// host the whole request (the policy-ordered top-n *within* that island,
/// islands ascending), so the cost model can trade a split set for an
/// island-local one; sets identical to the seed candidate are skipped, so
/// single-island servers enumerate exactly one candidate and bit-
/// reproduce the blind decision.
pub fn server_candidates(
    s: &ServerView,
    req: MappingRequest,
    pre: Preconditions,
    policy: PolicyKind,
    fabric: Option<&Fabric>,
    who: Requester,
) -> Vec<Vec<usize>> {
    let mut elig = eligible_views(s, req, pre, who);
    if elig.len() < req.n_gpus {
        return Vec::new();
    }
    policy_order(&mut elig, policy);
    let blind: Vec<usize> = elig[..req.n_gpus].iter().map(|v| v.id).collect();
    let mut cands = vec![blind];
    if let Some(f) = fabric {
        if req.n_gpus >= 2 && f.islands_matter(s.id) {
            for (&island, &n) in island_histogram(&elig, f).iter() {
                if n < req.n_gpus {
                    continue;
                }
                let set: Vec<usize> = elig
                    .iter()
                    .filter(|v| f.island_of(v.id) == island)
                    .take(req.n_gpus)
                    .map(|v| v.id)
                    .collect();
                if !cands.contains(&set) {
                    cands.push(set);
                }
            }
        }
    }
    cands
}

/// Power-envelope cap on a server's contribution to a gang: adding k
/// freshly-activated GPUs must keep the server under its cap. `s.power_w`
/// already includes the reserve for the requester's own holds, which a
/// dispatch merely converts to real draw — so only slots beyond
/// `own_slots` need headroom (DESIGN.md §11).
pub fn power_slot_cap(
    s: &ServerView,
    own_slots: usize,
    power_cfg: &PowerConfig,
    elig: usize,
) -> usize {
    match s.power_cap_w {
        None => elig,
        Some(cap) => {
            let slot_w = power::reserved_w(power_cfg, 1);
            let extra = power::slots_in_headroom(cap - s.power_w, slot_w, elig);
            (own_slots + extra).min(elig)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology::ClusterTopology;
    use crate::config::schema::{ClusterConfig, FabricConfig, FabricProfile};

    fn view(id: usize, free: f64, smact: f64, n: usize) -> GpuView {
        GpuView {
            id,
            server: 0,
            free_gb: free,
            smact_window: smact,
            n_tasks: n,
            pinned: false,
            held: false,
            unhealthy: false,
            mig_free_instance: None,
            mig_instance_mem_gb: 0.0,
            mig_enabled: false,
        }
    }

    fn server(gpus: Vec<GpuView>) -> ServerView {
        ServerView {
            id: 0,
            power_w: 0.0,
            power_cap_w: None,
            gpus: gpus.into(),
        }
    }

    fn req(n: usize, demand: Option<f64>) -> MappingRequest {
        MappingRequest {
            n_gpus: n,
            demand_gb: demand,
            exclusive: false,
        }
    }

    fn dual_island() -> Fabric {
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(1, 4, 40.0));
        Fabric::new(
            &topo,
            &FabricConfig {
                profile: FabricProfile::DualIsland,
                ..FabricConfig::default()
            },
        )
    }

    #[test]
    fn blind_mode_emits_exactly_the_seed_candidate() {
        let s = server(vec![
            view(0, 8.0, 0.1, 1),
            view(1, 30.0, 0.1, 1),
            view(2, 16.0, 0.1, 1),
            view(3, 25.0, 0.1, 1),
        ]);
        let c = server_candidates(
            &s,
            req(2, Some(5.0)),
            Preconditions::default(),
            PolicyKind::Magm,
            None,
            Requester::Singleton,
        );
        assert_eq!(c, vec![vec![1, 3]], "policy-ordered top-2, nothing else");
        // too few eligible -> no candidates at all
        let c = server_candidates(
            &s,
            req(5, None),
            Preconditions::default(),
            PolicyKind::Magm,
            None,
            Requester::Singleton,
        );
        assert!(c.is_empty());
    }

    #[test]
    fn island_mode_appends_island_local_sets() {
        let f = dual_island(); // islands {0,1} and {2,3}
        let s = server(vec![
            view(0, 20.0, 0.1, 1),
            view(1, 22.0, 0.1, 1),
            view(2, 39.0, 0.1, 1),
            view(3, 5.0, 0.1, 1),
        ]);
        let c = server_candidates(
            &s,
            req(2, Some(4.0)),
            Preconditions::default(),
            PolicyKind::Magm,
            Some(&f),
            Requester::Singleton,
        );
        // blind top-2 = {2, 1} (39 + 22); island 0 = {1, 0}; island 1 = {2, 3}
        assert_eq!(c, vec![vec![2, 1], vec![1, 0], vec![2, 3]]);
        // an island too small to host the pair contributes nothing
        let s = server(vec![view(0, 20.0, 0.1, 1), view(2, 39.0, 0.1, 1), view(3, 5.0, 0.1, 1)]);
        let c = server_candidates(
            &s,
            req(2, Some(4.0)),
            Preconditions::default(),
            PolicyKind::Magm,
            Some(&f),
            Requester::Singleton,
        );
        assert_eq!(c, vec![vec![2, 0], vec![2, 3]], "island 0 has one device only");
    }

    #[test]
    fn island_candidates_dedupe_against_blind() {
        // single-island server: the island set IS the blind set — exactly
        // one candidate may remain or the off-switch contract breaks
        let topo = ClusterTopology::from_config(&ClusterConfig::homogeneous(1, 4, 40.0));
        let f = Fabric::new(&topo, &FabricConfig::default());
        let s = server(vec![view(0, 8.0, 0.1, 1), view(1, 30.0, 0.1, 1)]);
        let c = server_candidates(
            &s,
            req(2, None),
            Preconditions::default(),
            PolicyKind::Magm,
            Some(&f),
            Requester::Singleton,
        );
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn packing_order_matches_the_gang_ranking() {
        let f = dual_island();
        let views = [
            view(0, 40.0, 0.1, 2),
            view(1, 40.0, 0.1, 0),
            view(2, 40.0, 0.1, 1),
            view(3, 40.0, 0.1, 0),
        ];
        let mut elig: Vec<&GpuView> = views.iter().collect();
        // no holds: fullest-island tie -> island id -> quietest -> id
        island_packed_order(&mut elig, &f, &|_| false);
        let order: Vec<usize> = elig.iter().map(|v| v.id).collect();
        assert_eq!(order, vec![1, 0, 3, 2]);
        // holding gpu 2 pulls it to the front regardless of island order
        let mut elig: Vec<&GpuView> = views.iter().collect();
        island_packed_order(&mut elig, &f, &|g| g == 2);
        let order: Vec<usize> = elig.iter().map(|v| v.id).collect();
        assert_eq!(order, vec![2, 1, 0, 3]);
    }

    #[test]
    fn power_slot_cap_counts_own_holds_as_free() {
        let pw = PowerConfig::default(); // slot = 43 W
        let mut s = server(vec![view(0, 40.0, 0.0, 0); 4]);
        assert_eq!(power_slot_cap(&s, 0, &pw, 4), 4, "no cap -> all eligible");
        s.power_cap_w = Some(300.0);
        s.power_w = 250.0; // 50 W headroom -> 1 fresh slot
        assert_eq!(power_slot_cap(&s, 0, &pw, 4), 1);
        // two own holds already reserved in power_w: they ride along free
        assert_eq!(power_slot_cap(&s, 2, &pw, 4), 3);
        s.power_w = 320.0; // over the cap: only own holds remain
        assert_eq!(power_slot_cap(&s, 2, &pw, 4), 2);
    }
}
