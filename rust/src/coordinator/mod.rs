//! CARMA coordinator (S8) — the paper's contribution (§4).
//!
//! End-to-end task management (Fig. 7): submission queue → parser/features →
//! memory estimator → monitoring window → collocation-policy mapping →
//! dispatch, plus the OOM recovery path (§4.2) with its higher-priority
//! queue and adaptive backoff/demotion.
//!
//! Mapping runs behind the sharded subsystem ([`shard`], DESIGN.md §9): a
//! global admission layer feeds N per-shard mapper workers whose
//! observation windows overlap; `shards = 1` (the default) is the paper's
//! serial pipeline, event-for-event.
//!
//! Every placement decision — singleton mappers and the gang lane alike —
//! funnels through the fabric-aware placement core ([`placement`],
//! DESIGN.md §12): one eligibility filter, one candidate enumerator, one
//! cost model.

pub mod carma;
pub mod gang;
pub mod monitor;
pub mod placement;
pub mod policy;
pub mod queue;
pub mod shard;

pub use carma::{Carma, RunOutcome};
pub use gang::{GangLane, GangPlan, ReservationBook};
pub use monitor::Monitor;
pub use placement::{CostModel, Requester, SetScore};
pub use policy::{GpuView, MappingRequest, Placement, Preconditions, ServerView};
pub use queue::TaskQueues;
pub use shard::{Admission, Mapper};
