//! CARMA coordinator (S8) — the paper's contribution (§4).
//!
//! End-to-end task management (Fig. 7): submission queue → parser/features →
//! memory estimator → monitoring window → collocation-policy mapping →
//! dispatch, plus the OOM recovery path (§4.2) with its higher-priority
//! queue and exclusive re-execution.

pub mod carma;
pub mod monitor;
pub mod policy;
pub mod queue;

pub use carma::{Carma, RunOutcome};
pub use monitor::Monitor;
pub use policy::{GpuView, MappingRequest, Placement, Preconditions, ServerView};
pub use queue::TaskQueues;
