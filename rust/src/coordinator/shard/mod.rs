//! Sharded coordination (DESIGN.md §9): a global [`Admission`] front-end
//! that owns arrival intake, the per-shard primary/recovery queues and
//! cluster-wide capacity accounting, feeding N per-shard [`Mapper`] workers.
//!
//! The paper's pipeline observes ONE selected task for a full monitoring
//! window before every mapping decision (§4.1, Fig. 7), capping mapping
//! throughput at one task per window regardless of cluster size. Sharding
//! overlaps K observation windows: each mapper runs its own select →
//! observe → map state machine over the shared cluster view, while
//! admission keeps task routing deterministic and FIFO within a shard.
//! With `shards = 1` the subsystem degenerates to the paper's serial
//! coordinator, event-for-event.

pub mod admission;
pub mod mapper;

pub use admission::Admission;
pub use mapper::{MapPlan, Mapper, PlanOutcome};
