//! Global admission layer (DESIGN.md §9): arrival intake, per-shard
//! primary/recovery queues, shard routing, and cluster-wide capacity
//! accounting.
//!
//! Admission is the single front door: every arriving task is routed to
//! exactly one shard (per the configured [`ShardAssign`] strategy) and
//! stays there — recovery re-queues return to the same shard's
//! higher-priority queue, so FIFO order and recovery priority hold *within*
//! a shard exactly as the paper's single queue pair did (§4.1/§4.2).
//! Admission also owns the static scheduling ceilings (largest admissible
//! GPU count / memory target across servers, power envelopes excluded), so
//! permanently-unschedulable work fails fast in one place.

use crate::config::schema::ShardAssign;
use crate::sim::TaskId;

use crate::coordinator::queue::TaskQueues;

#[derive(Debug)]
pub struct Admission {
    strategy: ShardAssign,
    /// One FIFO primary + priority recovery queue pair per shard.
    queues: Vec<TaskQueues>,
    /// Shard each task was routed to (sticky for the task's lifetime).
    shard_of: Vec<Option<usize>>,
    /// Round-robin routing cursor (fresh arrivals only).
    rr_next: usize,
    /// Static ceilings from `ClusterTopology::admissible_ceilings`:
    /// (max GPUs on one admissible server, max memory one target offers).
    max_gpus: usize,
    max_target_gb: f64,
}

impl Admission {
    pub fn new(
        n_shards: usize,
        n_tasks: usize,
        strategy: ShardAssign,
        ceilings: (usize, f64),
    ) -> Self {
        assert!(n_shards >= 1, "admission needs at least one shard");
        Admission {
            strategy,
            queues: (0..n_shards).map(|_| TaskQueues::new()).collect(),
            shard_of: vec![None; n_tasks],
            rr_next: 0,
            max_gpus: ceilings.0,
            max_target_gb: ceilings.1,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.queues.len()
    }

    /// Route an arriving task to a shard and enqueue it. `mapper_load[s]`
    /// is shard `s`'s current load (queued + under observation), consulted
    /// by the least-loaded strategy.
    pub fn submit(&mut self, id: TaskId, mapper_load: &[usize]) -> usize {
        let n = self.queues.len();
        let shard = match self.strategy {
            ShardAssign::RoundRobin => {
                let s = self.rr_next % n;
                self.rr_next += 1;
                s
            }
            ShardAssign::LeastLoaded => {
                debug_assert_eq!(mapper_load.len(), n);
                let mut best = 0usize;
                for s in 1..n {
                    if mapper_load[s] < mapper_load[best] {
                        best = s;
                    }
                }
                best
            }
            ShardAssign::Locality => id % n,
        };
        self.shard_of[id] = Some(shard);
        self.queues[shard].submit(id);
        shard
    }

    /// Re-queue an OOM-crashed task with priority (paper §4.2) on the shard
    /// that already owns it — recovery never migrates a task.
    pub fn submit_recovery(&mut self, id: TaskId) -> usize {
        let shard = self.shard_of[id].expect("recovery of a never-admitted task");
        self.queues[shard].submit_recovery(id);
        shard
    }

    /// Next task for shard `shard`: recovery queue first, then FIFO primary.
    pub fn pop_next(&mut self, shard: usize) -> Option<(TaskId, bool)> {
        self.queues[shard].pop_next()
    }

    pub fn shard_of(&self, id: TaskId) -> Option<usize> {
        self.shard_of.get(id).copied().flatten()
    }

    pub fn queue_len(&self, shard: usize) -> usize {
        self.queues[shard].len()
    }

    /// Total queued tasks across every shard.
    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    /// Cluster-wide capacity accounting: can this request EVER be placed?
    /// Both checks are static (independent of occupancy): a per-GPU demand
    /// above every schedulable target, or a GPU count no single admissible
    /// server owns (multi-GPU tasks never span servers), can never succeed
    /// no matter how long the task waits.
    pub fn admissible(
        &self,
        n_gpus: usize,
        demand_gb: Option<f64>,
    ) -> Result<(), &'static str> {
        if let Some(d) = demand_gb {
            if d > self.max_target_gb + 1e-9 {
                return Err("demand exceeds every schedulable target");
            }
        }
        if n_gpus > self.max_gpus {
            return Err("needs more GPUs than any admissible server owns");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adm(n_shards: usize, strategy: ShardAssign) -> Admission {
        Admission::new(n_shards, 16, strategy, (4, 40.0))
    }

    #[test]
    fn round_robin_cycles_shards() {
        let mut a = adm(3, ShardAssign::RoundRobin);
        let shards: Vec<usize> = (0..6).map(|id| a.submit(id, &[0; 3])).collect();
        assert_eq!(shards, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(a.len(), 6);
        assert_eq!(a.queue_len(1), 2);
        assert_eq!(a.shard_of(4), Some(1));
        assert_eq!(a.shard_of(9), None, "not yet admitted");
    }

    #[test]
    fn least_loaded_picks_emptiest_with_low_id_ties() {
        let mut a = adm(3, ShardAssign::LeastLoaded);
        assert_eq!(a.submit(0, &[2, 1, 1]), 1, "ties break to the lower id");
        assert_eq!(a.submit(1, &[2, 2, 1]), 2);
        assert_eq!(a.submit(2, &[0, 0, 0]), 0);
    }

    #[test]
    fn locality_is_sticky_by_task_id() {
        let mut a = adm(4, ShardAssign::Locality);
        assert_eq!(a.submit(5, &[0; 4]), 1);
        assert_eq!(a.submit(8, &[0; 4]), 0);
        assert_eq!(a.submit(11, &[0; 4]), 3);
    }

    #[test]
    fn recovery_returns_to_the_same_shard_with_priority() {
        let mut a = adm(2, ShardAssign::RoundRobin);
        a.submit(0, &[0; 2]); // shard 0
        a.submit(1, &[0; 2]); // shard 1
        a.submit(2, &[0; 2]); // shard 0
        let (t, rec) = a.pop_next(0).unwrap();
        assert_eq!((t, rec), (0, false));
        assert_eq!(a.submit_recovery(0), 0, "recovery never migrates");
        // recovery drains before the shard's primary queue
        assert_eq!(a.pop_next(0), Some((0, true)));
        assert_eq!(a.pop_next(0), Some((2, false)));
        assert_eq!(a.pop_next(0), None);
        assert_eq!(a.pop_next(1), Some((1, false)));
        assert!(a.is_empty());
    }

    #[test]
    fn fifo_within_each_shard() {
        let mut a = adm(2, ShardAssign::RoundRobin);
        for id in 0..8 {
            a.submit(id, &[0; 2]);
        }
        // shard 0 got 0,2,4,6; shard 1 got 1,3,5,7 — each pops in order
        let order0: Vec<TaskId> =
            std::iter::from_fn(|| a.pop_next(0)).map(|(t, _)| t).collect();
        assert_eq!(order0, vec![0, 2, 4, 6]);
        let order1: Vec<TaskId> =
            std::iter::from_fn(|| a.pop_next(1)).map(|(t, _)| t).collect();
        assert_eq!(order1, vec![1, 3, 5, 7]);
    }

    #[test]
    fn capacity_accounting_rejects_impossible_requests() {
        let a = adm(1, ShardAssign::RoundRobin);
        assert!(a.admissible(4, Some(39.0)).is_ok());
        assert!(a.admissible(1, Some(40.5)).is_err());
        assert!(a.admissible(5, None).is_err());
        assert!(a.admissible(1, None).is_ok());
    }

    #[test]
    fn one_shard_is_one_queue_pair() {
        // the serial degenerate case: everything lands on shard 0
        let mut a = adm(1, ShardAssign::Locality);
        for id in 0..4 {
            assert_eq!(a.submit(id, &[0]), 0);
        }
        let order: Vec<TaskId> =
            std::iter::from_fn(|| a.pop_next(0)).map(|(t, _)| t).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }
}
