//! Global admission layer (DESIGN.md §9/§11): arrival intake, per-shard
//! primary/recovery queues, shard routing, the dedicated gang lane, and
//! cluster-wide capacity accounting.
//!
//! Admission is the single front door: every arriving singleton task is
//! routed to exactly one shard (per the configured [`ShardAssign`]
//! strategy) and stays there — recovery re-queues return to the same
//! shard's higher-priority queue, so FIFO order and recovery priority hold
//! *within* a shard exactly as the paper's single queue pair did
//! (§4.1/§4.2). One bounded exception (DESIGN.md §12, `[coordinator]
//! steal`): an idle mapper that has starved a full observation window may
//! steal the TAIL of the longest sibling primary queue — taking the
//! newest task leaves every remaining task's relative order intact, and
//! the stolen task re-homes to the thief for the rest of its lifetime. Tasks flagged `gang` bypass the shards entirely: they join
//! the gang lane, a single FIFO (+ recovery priority) queue drained by the
//! driver's all-or-nothing gang scheduler (DESIGN.md §11). Admission also
//! owns the static scheduling ceilings (largest admissible GPU count /
//! memory target across servers, power envelopes excluded — and the
//! cluster-wide GPU pool for gangs), so permanently-unschedulable work
//! fails fast in one place.

use crate::config::schema::ShardAssign;
use crate::sim::TaskId;

use crate::coordinator::queue::TaskQueues;

/// SplitMix64 finalizer — the no-affinity `locality` fallback hash. A raw
/// `id % shards` routes every arithmetic stride in the trace onto the same
/// few shards; the mixer spreads ids uniformly while staying a pure,
/// seedless function of the id (deterministic across runs and restarts).
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[derive(Debug)]
pub struct Admission {
    strategy: ShardAssign,
    /// One FIFO primary + priority recovery queue pair per shard.
    queues: Vec<TaskQueues>,
    /// The dedicated gang lane (DESIGN.md §11): FIFO + recovery priority.
    gang: TaskQueues,
    /// Shard each task was routed to (sticky for the task's lifetime;
    /// gang-lane tasks never get one).
    shard_of: Vec<Option<usize>>,
    /// Round-robin routing cursor (fresh arrivals only).
    rr_next: usize,
    /// Bounded per-shard queue depth (open-loop service mode, DESIGN.md
    /// §13): an arrival routed to a shard already holding this many queued
    /// tasks is shed. `None` = unbounded intake — the closed-loop seed
    /// behavior. Recovery re-queues bypass the cap: the task is already
    /// admitted and holds progress.
    queue_cap: Option<usize>,
    /// Static ceilings from `ClusterTopology::admissible_ceilings`:
    /// (max GPUs on one admissible server, max memory one target offers).
    max_gpus: usize,
    max_target_gb: f64,
    /// Best-case assemblable whole-GPU pool — the gang fail-fast bound
    /// (`gang::gang_gpu_ceiling`: MIG partitioning, power-dead servers and
    /// power-slot headroom intersected per server).
    max_cluster_gpus: usize,
}

impl Admission {
    pub fn new(
        n_shards: usize,
        n_tasks: usize,
        strategy: ShardAssign,
        ceilings: (usize, f64),
        cluster_gpus: usize,
    ) -> Self {
        assert!(n_shards >= 1, "admission needs at least one shard");
        Admission {
            strategy,
            queues: (0..n_shards).map(|_| TaskQueues::new()).collect(),
            gang: TaskQueues::new(),
            shard_of: vec![None; n_tasks],
            rr_next: 0,
            queue_cap: None,
            max_gpus: ceilings.0,
            max_target_gb: ceilings.1,
            max_cluster_gpus: cluster_gpus,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.queues.len()
    }

    /// Bound every shard's queue depth (open-loop service mode, DESIGN.md
    /// §13). Closed-loop runs never call this — intake stays unbounded,
    /// byte-preserving the seed behavior.
    pub fn with_queue_cap(mut self, cap: usize) -> Self {
        assert!(cap >= 1, "a zero queue cap would shed every arrival");
        self.queue_cap = Some(cap);
        self
    }

    /// Pure routing decision — which shard `submit` would pick, with no
    /// state change. Split out so bounded intake can shed an arrival
    /// without advancing the round-robin cursor (a shed must leave the
    /// router exactly as it found it, or shard routing would depend on how
    /// many tasks were dropped before this one).
    fn route(&self, id: TaskId, mapper_load: &[usize], home: Option<usize>) -> usize {
        let n = self.queues.len();
        match self.strategy {
            ShardAssign::RoundRobin => self.rr_next % n,
            ShardAssign::LeastLoaded => {
                debug_assert_eq!(mapper_load.len(), n);
                let mut best = 0usize;
                for s in 1..n {
                    if mapper_load[s] < mapper_load[best] {
                        best = s;
                    }
                }
                best
            }
            // server-topology-aware stickiness: tasks sharing a home server
            // land on the same mapper, so its observation windows and RR
            // cursor stay warm for that server's devices. With no affinity
            // (single alive server) the fallback *hashes* the id: raw
            // id-modulo correlates with every stride pattern in the trace
            // and skews routing, e.g. after a power-down thins the cycle
            ShardAssign::Locality => match home {
                Some(h) => h % n,
                None => (splitmix64(id as u64) % n as u64) as usize,
            },
        }
    }

    /// Commit an accepted routing decision: advance the cursor, record the
    /// sticky home shard and enqueue.
    fn commit(&mut self, id: TaskId, shard: usize) {
        if matches!(self.strategy, ShardAssign::RoundRobin) {
            self.rr_next += 1;
        }
        if id >= self.shard_of.len() {
            // open-loop intake: ids stream in unbounded, grow the map
            self.shard_of.resize(id + 1, None);
        }
        self.shard_of[id] = Some(shard);
        self.queues[shard].submit(id);
    }

    /// Route an arriving singleton task to a shard and enqueue it.
    /// `mapper_load[s]` is shard `s`'s current load (queued + under
    /// observation), consulted by the least-loaded strategy. `home` is the
    /// task's home-server affinity from the fabric model (DESIGN.md §11),
    /// consulted by the locality strategy — `None` (no affinity, e.g. a
    /// single-server cluster) falls back to sticky id-modulo routing.
    pub fn submit(&mut self, id: TaskId, mapper_load: &[usize], home: Option<usize>) -> usize {
        let shard = self.route(id, mapper_load, home);
        self.commit(id, shard);
        shard
    }

    /// Bounded intake (open-loop service mode, DESIGN.md §13): route
    /// exactly like [`submit`], but shed the arrival — leaving the router
    /// untouched — when the routed shard's queue already sits at the cap.
    /// The shed policy is newest-first by construction: the task that
    /// finds the queue full is the one dropped, deterministically.
    pub fn try_submit(
        &mut self,
        id: TaskId,
        mapper_load: &[usize],
        home: Option<usize>,
    ) -> Result<usize, &'static str> {
        let shard = self.route(id, mapper_load, home);
        if self.backpressured(shard) {
            return Err("routed shard's queue at capacity");
        }
        self.commit(id, shard);
        Ok(shard)
    }

    /// The named shard's queue sits at the configured cap (always `false`
    /// with unbounded intake).
    pub fn backpressured(&self, shard: usize) -> bool {
        self.queue_cap
            .is_some_and(|cap| self.queues[shard].len() >= cap)
    }

    /// Every shard sits at the cap — the cluster-wide backpressure signal:
    /// the intake sheds at the door without consulting the router.
    pub fn saturated(&self) -> bool {
        self.queue_cap
            .is_some_and(|cap| self.queues.iter().all(|q| q.len() >= cap))
    }

    /// Enqueue an arriving gang task on the dedicated lane (DESIGN.md §11).
    pub fn submit_gang(&mut self, id: TaskId) {
        self.gang.submit(id);
    }

    /// Re-queue an OOM-crashed task with priority (paper §4.2) on the shard
    /// that already owns it — recovery never migrates a task.
    pub fn submit_recovery(&mut self, id: TaskId) -> usize {
        let shard = self
            .shard_of
            .get(id)
            .copied()
            .flatten()
            .expect("recovery of a never-admitted task");
        self.queues[shard].submit_recovery(id);
        shard
    }

    /// Re-queue an OOM-crashed gang with priority on the gang lane.
    pub fn submit_gang_recovery(&mut self, id: TaskId) {
        self.gang.submit_recovery(id);
    }

    /// Next task for shard `shard`: recovery queue first, then FIFO primary.
    pub fn pop_next(&mut self, shard: usize) -> Option<(TaskId, bool)> {
        self.queues[shard].pop_next()
    }

    /// Longest sibling *primary* queue — the steal victim for an idle
    /// `thief` shard (DESIGN.md §12). Ties go to the lowest shard id;
    /// `None` when no sibling has stealable (non-recovery) backlog.
    pub fn steal_victim(&self, thief: usize) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None; // (len, shard)
        for s in 0..self.queues.len() {
            if s == thief {
                continue;
            }
            let len = self.queues[s].main_len();
            if len > 0 && best.is_none_or(|(bl, _)| len > bl) {
                best = Some((len, s));
            }
        }
        best.map(|(_, s)| s)
    }

    /// Steal the tail of `victim`'s primary queue — the most recently
    /// submitted task — and re-home it to `thief`: its window, ramp and
    /// completion events ride the thief's lane from here on, and a later
    /// recovery re-queue returns to the thief (stickiness follows the
    /// steal). FIFO for every task remaining on the victim is untouched.
    pub fn steal_tail(&mut self, victim: usize, thief: usize) -> Option<TaskId> {
        let id = self.queues[victim].steal_tail()?;
        self.shard_of[id] = Some(thief);
        Some(id)
    }

    /// Any sibling of `thief` has stealable backlog right now.
    pub fn has_steal_victim(&self, thief: usize) -> bool {
        self.steal_victim(thief).is_some()
    }

    /// Next gang off the dedicated lane (recovery first, then FIFO).
    pub fn pop_next_gang(&mut self) -> Option<(TaskId, bool)> {
        self.gang.pop_next()
    }

    pub fn shard_of(&self, id: TaskId) -> Option<usize> {
        self.shard_of.get(id).copied().flatten()
    }

    pub fn queue_len(&self, shard: usize) -> usize {
        self.queues[shard].len()
    }

    pub fn gang_queue_len(&self) -> usize {
        self.gang.len()
    }

    /// Total queued tasks across every shard and the gang lane.
    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum::<usize>() + self.gang.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty()) && self.gang.is_empty()
    }

    /// Cluster-wide capacity accounting: can this request EVER be placed?
    /// All checks are static (independent of occupancy). Singleton /
    /// server-local multi-GPU requests are bounded by the largest
    /// admissible server; gang requests lift the server-local constraint,
    /// so their bound is the whole admissible GPU pool (DESIGN.md §11).
    pub fn admissible(
        &self,
        n_gpus: usize,
        demand_gb: Option<f64>,
        gang: bool,
    ) -> Result<(), &'static str> {
        if let Some(d) = demand_gb {
            if d > self.max_target_gb + 1e-9 {
                return Err("demand exceeds every schedulable target");
            }
        }
        if gang {
            if n_gpus > self.max_cluster_gpus {
                return Err("gang needs more GPUs than the admissible cluster pool");
            }
        } else if n_gpus > self.max_gpus {
            return Err("needs more GPUs than any admissible server owns");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adm(n_shards: usize, strategy: ShardAssign) -> Admission {
        Admission::new(n_shards, 16, strategy, (4, 40.0), 8)
    }

    #[test]
    fn round_robin_cycles_shards() {
        let mut a = adm(3, ShardAssign::RoundRobin);
        let shards: Vec<usize> = (0..6).map(|id| a.submit(id, &[0; 3], None)).collect();
        assert_eq!(shards, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(a.len(), 6);
        assert_eq!(a.queue_len(1), 2);
        assert_eq!(a.shard_of(4), Some(1));
        assert_eq!(a.shard_of(9), None, "not yet admitted");
    }

    #[test]
    fn least_loaded_picks_emptiest_with_low_id_ties() {
        let mut a = adm(3, ShardAssign::LeastLoaded);
        assert_eq!(a.submit(0, &[2, 1, 1], None), 1, "ties break to the lower id");
        assert_eq!(a.submit(1, &[2, 2, 1], None), 2);
        assert_eq!(a.submit(2, &[0, 0, 0], None), 0);
    }

    #[test]
    fn locality_is_sticky_by_hashed_id_without_affinity() {
        // no affinity -> splitmix64(id) % shards: sticky for a given id,
        // but uncorrelated with arithmetic strides in the trace (the old
        // raw id-modulo skewed routing whenever home_server thinned out)
        let mut a = adm(4, ShardAssign::Locality);
        assert_eq!(a.submit(5, &[0; 4], None), 2);
        assert_eq!(a.submit(8, &[0; 4], None), 2);
        assert_eq!(a.submit(11, &[0; 4], None), 1);
        // hashing spreads a contiguous id range across every shard
        let mut b = adm(4, ShardAssign::Locality);
        let mut hit = [false; 4];
        for id in 0..16 {
            hit[b.submit(id, &[0; 4], None)] = true;
        }
        assert!(hit.iter().all(|&h| h), "16 sequential ids must reach all 4 shards");
    }

    #[test]
    fn stealing_takes_longest_sibling_tail_and_rehomes() {
        let mut a = adm(3, ShardAssign::Locality);
        // shard 0: tasks 0,3,6 — shard 1: 1,4 — shard 2: empty (thief)
        for id in [0usize, 3, 6] {
            a.submit(id, &[0; 3], Some(0));
        }
        for id in [1usize, 4] {
            a.submit(id, &[0; 3], Some(1));
        }
        assert_eq!(a.steal_victim(2), Some(0), "longest primary queue");
        assert!(a.has_steal_victim(2));
        assert_eq!(a.steal_tail(0, 2), Some(6), "tail = newest task");
        assert_eq!(a.shard_of(6), Some(2), "stolen task re-homes to the thief");
        // victim's FIFO is untouched
        assert_eq!(a.pop_next(0), Some((0, false)));
        assert_eq!(a.pop_next(0), Some((3, false)));
        assert_eq!(a.pop_next(0), None);
        // ties go to the lowest shard id
        let mut t = adm(3, ShardAssign::RoundRobin);
        t.submit(0, &[0; 3], None); // shard 0
        t.submit(1, &[0; 3], None); // shard 1
        assert_eq!(t.steal_victim(2), Some(0));
        // recovery backlog alone is not stealable
        let mut r = adm(2, ShardAssign::RoundRobin);
        r.submit(0, &[0; 2], None);
        assert_eq!(r.pop_next(0), Some((0, false)));
        r.submit_recovery(0);
        assert_eq!(r.steal_victim(1), None);
        assert!(!r.has_steal_victim(1));
    }

    #[test]
    fn locality_routes_by_home_server_affinity() {
        // fabric affinity overrides the raw id: tasks sharing a home server
        // land on the same mapper regardless of their ids
        let mut a = adm(4, ShardAssign::Locality);
        assert_eq!(a.submit(5, &[0; 4], Some(2)), 2);
        assert_eq!(a.submit(8, &[0; 4], Some(2)), 2);
        assert_eq!(a.submit(11, &[0; 4], Some(7)), 3, "server id wraps over shards");
        // other strategies ignore affinity entirely
        let mut rr = adm(2, ShardAssign::RoundRobin);
        assert_eq!(rr.submit(0, &[0; 2], Some(1)), 0);
        assert_eq!(rr.submit(1, &[0; 2], Some(1)), 1);
    }

    #[test]
    fn recovery_returns_to_the_same_shard_with_priority() {
        let mut a = adm(2, ShardAssign::RoundRobin);
        a.submit(0, &[0; 2], None); // shard 0
        a.submit(1, &[0; 2], None); // shard 1
        a.submit(2, &[0; 2], None); // shard 0
        let (t, rec) = a.pop_next(0).unwrap();
        assert_eq!((t, rec), (0, false));
        assert_eq!(a.submit_recovery(0), 0, "recovery never migrates");
        // recovery drains before the shard's primary queue
        assert_eq!(a.pop_next(0), Some((0, true)));
        assert_eq!(a.pop_next(0), Some((2, false)));
        assert_eq!(a.pop_next(0), None);
        assert_eq!(a.pop_next(1), Some((1, false)));
        assert!(a.is_empty());
    }

    #[test]
    fn fifo_within_each_shard() {
        let mut a = adm(2, ShardAssign::RoundRobin);
        for id in 0..8 {
            a.submit(id, &[0; 2], None);
        }
        // shard 0 got 0,2,4,6; shard 1 got 1,3,5,7 — each pops in order
        let order0: Vec<TaskId> =
            std::iter::from_fn(|| a.pop_next(0)).map(|(t, _)| t).collect();
        assert_eq!(order0, vec![0, 2, 4, 6]);
        let order1: Vec<TaskId> =
            std::iter::from_fn(|| a.pop_next(1)).map(|(t, _)| t).collect();
        assert_eq!(order1, vec![1, 3, 5, 7]);
    }

    #[test]
    fn gang_lane_is_fifo_with_recovery_priority() {
        let mut a = adm(2, ShardAssign::RoundRobin);
        a.submit_gang(4);
        a.submit_gang(7);
        a.submit(0, &[0; 2], None);
        assert_eq!(a.gang_queue_len(), 2);
        assert_eq!(a.len(), 3, "gang lane counts toward total backlog");
        assert_eq!(a.shard_of(4), None, "gangs never bind to a shard");
        assert_eq!(a.pop_next_gang(), Some((4, false)));
        a.submit_gang_recovery(4);
        assert_eq!(a.pop_next_gang(), Some((4, true)), "recovery drains first");
        assert_eq!(a.pop_next_gang(), Some((7, false)));
        assert_eq!(a.pop_next_gang(), None);
        assert!(!a.is_empty(), "singleton still queued");
    }

    #[test]
    fn capacity_accounting_rejects_impossible_requests() {
        let a = adm(1, ShardAssign::RoundRobin);
        assert!(a.admissible(4, Some(39.0), false).is_ok());
        assert!(a.admissible(1, Some(40.5), false).is_err());
        assert!(a.admissible(5, None, false).is_err());
        assert!(a.admissible(1, None, false).is_ok());
        // gangs are bounded by the cluster pool, not one server
        assert!(a.admissible(5, Some(39.0), true).is_ok());
        assert!(a.admissible(8, None, true).is_ok());
        assert!(a.admissible(9, None, true).is_err());
        assert!(a.admissible(5, Some(40.5), true).is_err(), "demand cap still applies");
    }

    #[test]
    fn bounded_intake_sheds_at_cap_without_moving_the_cursor() {
        let mut a = adm(2, ShardAssign::RoundRobin).with_queue_cap(1);
        assert_eq!(a.try_submit(0, &[0; 2], None), Ok(0));
        assert_eq!(a.try_submit(1, &[0; 2], None), Ok(1));
        // both shards at cap: saturated, and the next arrival is shed
        assert!(a.backpressured(0) && a.backpressured(1));
        assert!(a.saturated());
        assert!(a.try_submit(2, &[0; 2], None).is_err());
        assert_eq!(a.shard_of(2), None, "a shed task never gets a home shard");
        assert_eq!(a.len(), 2);
        // the shed did NOT advance the round-robin cursor: after shard 0
        // drains, the next accepted arrival routes to shard 0 again
        assert_eq!(a.pop_next(0), Some((0, false)));
        assert!(!a.saturated());
        assert_eq!(a.try_submit(3, &[0; 2], None), Ok(0));
    }

    #[test]
    fn recovery_bypasses_the_queue_cap() {
        let mut a = adm(1, ShardAssign::RoundRobin).with_queue_cap(1);
        assert_eq!(a.try_submit(0, &[0], None), Ok(0));
        assert_eq!(a.pop_next(0), Some((0, false)));
        assert_eq!(a.try_submit(1, &[0], None), Ok(0));
        // shard 0 is at cap; the crashed task still re-queues with priority
        assert!(a.backpressured(0));
        assert_eq!(a.submit_recovery(0), 0);
        assert_eq!(a.pop_next(0), Some((0, true)));
    }

    #[test]
    fn open_intake_grows_the_shard_map() {
        // n_tasks = 16 at construction, but open-loop ids stream past it
        let mut a = adm(2, ShardAssign::Locality);
        assert!(a.try_submit(40, &[0; 2], None).is_ok());
        assert!(a.shard_of(40).is_some());
        assert_eq!(a.shard_of(39), None);
        // unbounded intake never backpressures
        assert!(!a.backpressured(0) && !a.saturated());
    }

    #[test]
    fn one_shard_is_one_queue_pair() {
        // the serial degenerate case: everything lands on shard 0
        let mut a = adm(1, ShardAssign::Locality);
        for id in 0..4 {
            assert_eq!(a.submit(id, &[0], None), 0);
        }
        let order: Vec<TaskId> =
            std::iter::from_fn(|| a.pop_next(0)).map(|(t, _)| t).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }
}
