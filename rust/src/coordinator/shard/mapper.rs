//! Per-shard mapper worker state (DESIGN.md §9) and speculative mapping
//! plans (DESIGN.md §10).
//!
//! Each mapper runs the paper's select → observe → map loop (§4.1) for its
//! own head-of-queue task: at most one task is under observation per shard,
//! so K shards hold K observation windows open concurrently. The mapping
//! decision itself (preconditions, estimator demand, per-GPU policy) stays
//! in the driver — the mapper is the replicated piece of coordinator state
//! that used to be the serial `selected`/`window_done`/`rr_cursor` fields.
//!
//! Under the parallel engine a mapper may additionally hold a [`MapPlan`]:
//! a mapping decision computed *speculatively* on a worker thread against a
//! read snapshot of the cluster. A plan is committed only if the snapshot
//! it was computed against is still current — otherwise it is discarded and
//! the decision is recomputed inline, which is what keeps threaded runs
//! byte-identical to serial ones. `Mapper` is plain owned data (`Send`), so
//! plan inputs can cross threads freely.

use crate::coordinator::placement::Explain;
use crate::coordinator::policy::Placement;
use crate::sim::TaskId;

/// What a speculative mapping computation decided for one shard.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanOutcome {
    /// A placement was found; the second field is the shard's Round-Robin
    /// cursor *after* the pick (applied on commit only).
    Place(Placement, usize),
    /// Nothing eligible right now — the shard schedules a retry.
    NoFit,
    /// Statically unschedulable (admission ceilings) — fail the task fast.
    Inadmissible(&'static str),
}

/// A speculative mapping decision for one shard, tagged with the exact
/// state it was computed against. Commit-time validation requires all four
/// tags to match the live state; any mismatch means the cluster moved under
/// the plan and the serial recompute path runs instead (DESIGN.md §10).
#[derive(Debug, Clone)]
pub struct MapPlan {
    /// Driver state-epoch the snapshot belonged to. This stays the *global*
    /// epoch even under delta view maintenance (DESIGN.md §17): a mapping
    /// decision reads every server's view, so a commit on any server must
    /// invalidate in-flight plans — only the snapshot *rebuild* narrows to
    /// the touched servers.
    pub epoch: u64,
    /// Engine time quantum the snapshot belonged to — the discrete
    /// `(time, seq)` frontier counter, not `now.to_bits()`, so numerically
    /// equal but bit-distinct timestamps (`-0.0`) can't fail validation.
    pub quantum: u64,
    /// Task the plan maps (must still be the shard's selected task).
    pub task: TaskId,
    /// RR cursor the scan started from (must be unchanged on commit).
    pub cursor_in: usize,
    /// Memory demand the task was admitted with (estimate + margin, after
    /// the capacity clamp) — recorded on the task at dispatch.
    pub demand_gb: Option<f64>,
    /// Final-retry recovery demotion: dispatch pinned-exclusive (§4.2).
    pub demoted: bool,
    pub outcome: PlanOutcome,
    /// Decision provenance from the placement core (DESIGN.md §14) —
    /// plain counters, computed on the same snapshot as `outcome` and
    /// recorded at commit time only (a discarded plan discards its
    /// explanation with it).
    pub explain: Explain,
}

/// A mapper's shard index is its position in the driver's mapper vector
/// (not stored here — derivable state can't desynchronize).
#[derive(Debug, Clone, Default)]
pub struct Mapper {
    /// Head-of-queue task under observation / awaiting mapping.
    pub selected: Option<TaskId>,
    /// The observation window for `selected` has elapsed.
    pub window_done: bool,
    /// A RetryMapping event for this shard is already in flight.
    pub retry_scheduled: bool,
    /// A StealCheck event for this shard is already in flight (at most one
    /// pending steal probe per shard, DESIGN.md §12).
    pub steal_scheduled: bool,
    /// Round-Robin policy cursor — per shard, so concurrent mappers keep
    /// independent cycles (with one shard this is the old global cursor).
    pub rr_cursor: usize,
    /// Speculative mapping plan awaiting validation + commit, if any.
    pub plan: Option<MapPlan>,
}

impl Mapper {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn idle(&self) -> bool {
        self.selected.is_none()
    }

    /// Ready to (re-)attempt a mapping decision: a task is selected and its
    /// observation window has elapsed.
    pub fn ready(&self) -> bool {
        self.selected.is_some() && self.window_done
    }

    /// Start observing `id` (a fresh window begins).
    pub fn select(&mut self, id: TaskId) {
        debug_assert!(self.selected.is_none(), "mapper already busy");
        self.selected = Some(id);
        self.window_done = false;
        self.plan = None;
    }

    /// The selected task was dispatched (or failed) — back to idle.
    pub fn clear(&mut self) {
        self.selected = None;
        self.window_done = false;
        self.plan = None;
    }

    /// Consume the cached plan if it matches the live `(epoch, quantum,
    /// task, cursor)` state; a stale plan is dropped either way.
    pub fn take_valid_plan(&mut self, epoch: u64, quantum: u64, task: TaskId) -> Option<MapPlan> {
        let plan = self.plan.take()?;
        let valid = plan.epoch == epoch
            && plan.quantum == quantum
            && plan.task == task
            && plan.cursor_in == self.rr_cursor;
        valid.then_some(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_observe_clear_cycle() {
        let mut m = Mapper::new();
        assert!(m.idle());
        assert!(!m.ready());
        m.select(7);
        assert_eq!(m.selected, Some(7));
        assert!(!m.ready(), "window not elapsed yet");
        m.window_done = true;
        assert!(m.ready());
        m.clear();
        assert!(m.idle());
        assert!(!m.window_done, "clear resets the window");
    }

    #[test]
    fn plan_validation_rejects_every_stale_dimension() {
        let plan = |cursor_in| MapPlan {
            epoch: 5,
            quantum: 42,
            task: 3,
            cursor_in,
            demand_gb: Some(10.0),
            demoted: false,
            outcome: PlanOutcome::NoFit,
            explain: Explain::default(),
        };
        let mut m = Mapper::new();
        m.select(3);
        m.window_done = true;

        m.plan = Some(plan(0));
        assert!(m.take_valid_plan(5, 42, 3).is_some());
        assert!(m.plan.is_none(), "plan is consumed");

        m.plan = Some(plan(0));
        assert!(m.take_valid_plan(6, 42, 3).is_none(), "stale epoch");
        m.plan = Some(plan(0));
        assert!(m.take_valid_plan(5, 43, 3).is_none(), "clock moved");
        m.plan = Some(plan(0));
        assert!(m.take_valid_plan(5, 42, 4).is_none(), "different task");
        m.plan = Some(plan(9));
        assert!(m.take_valid_plan(5, 42, 3).is_none(), "cursor moved");
        assert!(m.plan.is_none(), "stale plans are dropped, not kept");
    }

    #[test]
    fn mapper_and_plans_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<Mapper>();
        assert_send::<MapPlan>();
        assert_send::<PlanOutcome>();
    }
}
