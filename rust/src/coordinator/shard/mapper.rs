//! Per-shard mapper worker state (DESIGN.md §9).
//!
//! Each mapper runs the paper's select → observe → map loop (§4.1) for its
//! own head-of-queue task: at most one task is under observation per shard,
//! so K shards hold K observation windows open concurrently. The mapping
//! decision itself (preconditions, estimator demand, per-GPU policy) stays
//! in the driver — the mapper is the replicated piece of coordinator state
//! that used to be the serial `selected`/`window_done`/`rr_cursor` fields.

use crate::sim::TaskId;

/// A mapper's shard index is its position in the driver's mapper vector
/// (not stored here — derivable state can't desynchronize).
#[derive(Debug, Clone, Default)]
pub struct Mapper {
    /// Head-of-queue task under observation / awaiting mapping.
    pub selected: Option<TaskId>,
    /// The observation window for `selected` has elapsed.
    pub window_done: bool,
    /// A RetryMapping event for this shard is already in flight.
    pub retry_scheduled: bool,
    /// Round-Robin policy cursor — per shard, so concurrent mappers keep
    /// independent cycles (with one shard this is the old global cursor).
    pub rr_cursor: usize,
}

impl Mapper {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn idle(&self) -> bool {
        self.selected.is_none()
    }

    /// Ready to (re-)attempt a mapping decision: a task is selected and its
    /// observation window has elapsed.
    pub fn ready(&self) -> bool {
        self.selected.is_some() && self.window_done
    }

    /// Start observing `id` (a fresh window begins).
    pub fn select(&mut self, id: TaskId) {
        debug_assert!(self.selected.is_none(), "mapper already busy");
        self.selected = Some(id);
        self.window_done = false;
    }

    /// The selected task was dispatched (or failed) — back to idle.
    pub fn clear(&mut self) {
        self.selected = None;
        self.window_done = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_observe_clear_cycle() {
        let mut m = Mapper::new();
        assert!(m.idle());
        assert!(!m.ready());
        m.select(7);
        assert_eq!(m.selected, Some(7));
        assert!(!m.ready(), "window not elapsed yet");
        m.window_done = true;
        assert!(m.ready());
        m.clear();
        assert!(m.idle());
        assert!(!m.window_done, "clear resets the window");
    }
}
