//! Fabric-aware gang scheduling (DESIGN.md §11): all-or-nothing placement
//! of distributed jobs across servers.
//!
//! The paper's task model caps every multi-GPU task to one server; real
//! multi-tenant traces are dominated by gang-scheduled distributed jobs
//! with locality constraints (Jeon et al.). This subsystem adds a dedicated
//! *gang lane* beside the sharded mappers: arrivals flagged `gang` are
//! routed here by admission, observed for one monitoring window, and then
//! placed **atomically** — either every worker dispatches in the same event
//! or nothing does; a partial dispatch is unrepresentable.
//!
//! While a gang waits for capacity it may take **partial holds**: per-GPU
//! reservations (the [`ReservationBook`]) that block newcomers from the
//! held devices, so continuously arriving singletons cannot starve a large
//! gang — they backfill *around* the holds instead. Holds carry a TTL: a
//! hold that makes no progress for `gang.hold_ttl_s` is torn down and its
//! GPUs returned to the backfill pool (a gang must not deadlock the
//! admission layer); after `gang.max_hold_expiries` teardowns the holds
//! turn sticky — the anti-starvation floor.
//!
//! Placement packs candidate GPU sets for minimum fabric cost
//! (`cluster::fabric`): fill the fewest servers, and within a server the
//! fewest NVLink islands, so collectives cross as few links as possible —
//! with one uniform cost per link class this structural greedy IS the
//! `gang_cost` minimizer, and the achieved cost of every dispatch is
//! recorded in the run metrics. Per-server power envelopes are honored at
//! *commit* time including reserved slots (`power::reserved_w`), so a
//! gang dispatch can never overshoot the cap.
//!
//! Since the placement-core extraction (DESIGN.md §12) the planner itself
//! — eligibility, island packing, power-slot caps — lives in
//! `coordinator::placement`, shared verbatim with the singleton mappers;
//! this module keeps the gang-lifecycle state ([`ReservationBook`],
//! [`GangLane`], the fail-fast ceiling) and re-exports [`plan_gang`].

use crate::cluster::power;
use crate::cluster::topology::ClusterTopology;
use crate::config::schema::PowerConfig;
use crate::sim::TaskId;

pub use crate::cluster::Fabric;
/// The gang planner itself lives in the shared placement core (DESIGN.md
/// §12): one eligibility filter + candidate enumerator + power-slot cap
/// for gangs AND singletons. Re-exported under its historical home.
pub use crate::coordinator::placement::plan_gang;

/// Per-GPU reservation ledger of pending gang holds. One gang is in the
/// placing state at a time (the lane head), so holders never conflict —
/// the per-task indirection keeps release idempotent and auditable.
#[derive(Debug, Clone)]
pub struct ReservationBook {
    holder: Vec<Option<TaskId>>,
    /// Server owning each GPU — an immutable cache of
    /// `ClusterTopology::server_of_gpu`, captured at construction from the
    /// same topology every other component derives from.
    server_of: Vec<usize>,
    /// Reserved-but-not-dispatched slots per server (power accounting).
    server_slots: Vec<usize>,
}

impl ReservationBook {
    pub fn new(topo: &ClusterTopology) -> ReservationBook {
        let server_of: Vec<usize> =
            (0..topo.total_gpus()).map(|g| topo.server_of_gpu(g)).collect();
        ReservationBook {
            holder: vec![None; topo.total_gpus()],
            server_slots: vec![0; topo.n_servers()],
            server_of,
        }
    }

    pub fn holder(&self, gpu: usize) -> Option<TaskId> {
        self.holder[gpu]
    }

    pub fn is_held(&self, gpu: usize) -> bool {
        self.holder[gpu].is_some()
    }

    /// Reserved slots on `server` (counted by the power-envelope filter).
    pub fn server_slots(&self, server: usize) -> usize {
        self.server_slots[server]
    }

    /// Total holds across the cluster.
    pub fn total(&self) -> usize {
        self.server_slots.iter().sum()
    }

    pub fn holds_of(&self, task: TaskId) -> usize {
        self.holder.iter().filter(|h| **h == Some(task)).count()
    }

    /// Place a hold. The hold claims the whole device against newcomers
    /// (`GpuView::held`), so no per-GPU demand needs tracking here — the
    /// placement core's eligibility filter (DESIGN.md §12) re-validates
    /// the memory fit on held devices at every attempt (an underestimating
    /// resident can outgrow what was seen at acquisition). Panics on a
    /// double-hold — that is a scheduler bug, not a recoverable condition.
    pub fn hold(&mut self, gpu: usize, task: TaskId) {
        assert!(
            self.holder[gpu].is_none(),
            "gpu {gpu} already held by {:?}",
            self.holder[gpu]
        );
        self.holder[gpu] = Some(task);
        self.server_slots[self.server_of[gpu]] += 1;
    }

    /// Tasks holding at least one GPU on `server`, deduplicated and sorted.
    /// The fault path (DESIGN.md §15) uses this to invalidate every hold on
    /// a dead server — a reservation on quarantined hardware would wedge
    /// the gang lane until the TTL fired, and the power accounting would
    /// keep charging slots to a box that cannot dispatch.
    pub fn holders_on_server(&self, server: usize) -> Vec<TaskId> {
        let mut out: Vec<TaskId> = (0..self.holder.len())
            .filter(|&g| self.server_of[g] == server)
            .filter_map(|g| self.holder[g])
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Release every hold `task` owns; returns the freed GPU ids.
    pub fn release_all(&mut self, task: TaskId) -> Vec<usize> {
        let mut freed = Vec::new();
        for g in 0..self.holder.len() {
            if self.holder[g] == Some(task) {
                self.holder[g] = None;
                self.server_slots[self.server_of[g]] -= 1;
                freed.push(g);
            }
        }
        freed
    }
}

/// The gang lane's select → observe → place state machine (the gang-side
/// analog of [`crate::coordinator::shard::Mapper`]). At most one gang — the
/// lane head — is in the placing state, so holds never deadlock across
/// gangs by construction.
#[derive(Debug, Clone, Default)]
pub struct GangLane {
    /// Lane-head gang under observation / accumulating holds.
    pub active: Option<TaskId>,
    /// Its observation window has elapsed.
    pub window_done: bool,
    /// A GangRetry event is already in flight.
    pub retry_scheduled: bool,
    /// Hold-generation counter: every (re-)acquisition bumps it and arms a
    /// fresh TTL expiry carrying the new epoch — so progress renews the
    /// lease by construction, and stale expiry events (older epochs) are
    /// dropped on arrival.
    pub hold_epoch: u64,
    /// TTL teardowns suffered by the lane head so far. Never refunded while
    /// the same gang stays active — at `gang.max_hold_expiries` the holds
    /// turn sticky, which is what makes starvation impossible.
    pub expiries: u32,
}

impl GangLane {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn ready(&self) -> bool {
        self.active.is_some() && self.window_done
    }

    pub fn select(&mut self, id: TaskId) {
        debug_assert!(self.active.is_none(), "gang lane already busy");
        self.active = Some(id);
        self.window_done = false;
        self.expiries = 0;
    }

    /// The active gang dispatched or failed — back to idle. (Holds are
    /// released by the caller, which owns the book.) Bumps the hold epoch:
    /// an expiry armed during this headship must not fire into a later
    /// headship of the *same* gang (OOM recovery re-selects it) and burn
    /// the fresh teardown budget on zero actual holds.
    pub fn clear(&mut self) {
        self.active = None;
        self.window_done = false;
        self.expiries = 0;
        self.hold_epoch += 1;
    }
}

/// What one placement attempt decided.
#[derive(Debug, Clone, PartialEq)]
pub enum GangPlan {
    /// A full worker set exists: dispatch these GPUs atomically.
    Place(Vec<usize>),
    /// Not enough eligible GPUs yet: newly acquire holds on these (may be
    /// empty — then the gang just waits for the next retry/kick).
    Hold(Vec<usize>),
}

/// Static best-case GPU capacity the gang scheduler can ever assemble: per
/// server, zero if the server is MIG-partitioned (gangs target whole GPUs)
/// or its idle draw already meets the power envelope, else its GPU count
/// capped by the slots an *idle* server's power headroom admits; summed
/// over servers. The per-server intersection matters — taking cluster-wide
/// minima of independently-computed bounds would over-estimate capacity on
/// heterogeneous mixes (e.g. a MIG server with power headroom next to a
/// power-dead whole-GPU server) and let a permanently unplaceable gang
/// retry forever instead of failing fast (DESIGN.md §11).
pub fn gang_gpu_ceiling(
    topo: &ClusterTopology,
    power_cfg: &PowerConfig,
    cap_w: Option<f64>,
) -> usize {
    let slot_w = power::reserved_w(power_cfg, 1);
    topo.servers
        .iter()
        .map(|s| {
            if !s.cfg.mig_slices.is_empty() {
                return 0;
            }
            let Some(cap) = cap_w else { return s.cfg.n_gpus };
            let idle_floor = power_cfg.idle_w * s.cfg.n_gpus as f64;
            if idle_floor >= cap {
                0
            } else {
                // same slot division as the planner's per-server cap
                // (power::slots_in_headroom) — the static bound and the
                // live bound cannot drift
                power::slots_in_headroom(cap - idle_floor, slot_w, s.cfg.n_gpus)
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::{ClusterConfig, FabricConfig, PowerConfig};
    use crate::coordinator::policy::{GpuView, MappingRequest, Preconditions, ServerView};

    fn topo(servers: usize, gpus: usize) -> ClusterTopology {
        ClusterTopology::from_config(&ClusterConfig::homogeneous(servers, gpus, 40.0))
    }

    fn fabric(servers: usize, gpus: usize) -> Fabric {
        Fabric::new(&topo(servers, gpus), &FabricConfig::default())
    }

    fn view(id: usize, server: usize, free: f64, n: usize) -> GpuView {
        GpuView {
            id,
            server,
            free_gb: free,
            smact_window: 0.1,
            n_tasks: n,
            pinned: false,
            held: false,
            unhealthy: false,
            mig_free_instance: None,
            mig_instance_mem_gb: 0.0,
            mig_enabled: false,
        }
    }

    fn sview(id: usize, gpus: Vec<GpuView>) -> ServerView {
        ServerView {
            id,
            power_w: 0.0,
            power_cap_w: None,
            gpus: gpus.into(),
        }
    }

    fn req(n: usize, demand: Option<f64>) -> MappingRequest {
        MappingRequest {
            n_gpus: n,
            demand_gb: demand,
            exclusive: false,
        }
    }

    fn two_by_four() -> Vec<ServerView> {
        vec![
            sview(0, (0..4).map(|g| view(g, 0, 40.0, 0)).collect()),
            sview(1, (4..8).map(|g| view(g, 1, 40.0, 0)).collect()),
        ]
    }

    #[test]
    fn reservation_book_roundtrip() {
        let mut b = ReservationBook::new(&topo(2, 4));
        assert_eq!(b.total(), 0);
        b.hold(1, 9);
        b.hold(5, 9);
        assert!(b.is_held(1) && b.is_held(5) && !b.is_held(0));
        assert_eq!(b.holder(5), Some(9));
        assert_eq!(b.server_slots(0), 1);
        assert_eq!(b.server_slots(1), 1);
        assert_eq!(b.holds_of(9), 2);
        assert_eq!(b.holders_on_server(0), vec![9]);
        assert_eq!(b.holders_on_server(1), vec![9]);
        let freed = b.release_all(9);
        assert_eq!(freed, vec![1, 5]);
        assert_eq!(b.total(), 0);
        assert!(b.release_all(9).is_empty(), "release is idempotent");
    }

    #[test]
    #[should_panic(expected = "already held")]
    fn double_hold_panics() {
        let mut b = ReservationBook::new(&topo(1, 4));
        b.hold(0, 1);
        b.hold(0, 2);
    }

    #[test]
    fn lane_state_machine() {
        let mut l = GangLane::new();
        assert!(!l.ready());
        l.select(3);
        assert!(!l.ready(), "window not elapsed");
        l.window_done = true;
        assert!(l.ready());
        l.expiries = 2;
        let epoch_before = l.hold_epoch;
        l.clear();
        assert!(l.active.is_none() && !l.window_done);
        assert_eq!(l.expiries, 0, "the teardown budget is per headship");
        assert!(
            l.hold_epoch > epoch_before,
            "ending a headship must invalidate its in-flight expiries"
        );
    }

    #[test]
    fn place_fills_one_server_before_spanning() {
        let f = fabric(2, 4);
        let b = ReservationBook::new(&topo(2, 4));
        let views = two_by_four();
        // 4-wide gang fits entirely on one server: never spans
        let plan = plan_gang(&views, &f, &b, &PowerConfig::default(), req(4, Some(8.0)),
                             Preconditions::default(), 7);
        assert_eq!(plan, GangPlan::Place(vec![0, 1, 2, 3]));
        // 6-wide gang must span; it fills server 0 then takes 2 from server 1
        let plan = plan_gang(&views, &f, &b, &PowerConfig::default(), req(6, Some(8.0)),
                             Preconditions::default(), 7);
        match plan {
            GangPlan::Place(g) => {
                assert_eq!(g.len(), 6);
                assert_eq!(f.servers_spanned(&g), 2);
                assert_eq!(g[..4], [0, 1, 2, 3]);
            }
            other => panic!("expected Place, got {other:?}"),
        }
    }

    #[test]
    fn partial_capacity_becomes_holds() {
        let f = fabric(2, 4);
        let mut b = ReservationBook::new(&topo(2, 4));
        let mut views = two_by_four();
        // only 3 GPUs can take the demand right now
        for v in views[0].gpus_mut().iter_mut().skip(2) {
            v.free_gb = 1.0;
        }
        for v in views[1].gpus_mut().iter_mut().skip(1) {
            v.free_gb = 1.0;
        }
        let plan = plan_gang(&views, &f, &b, &PowerConfig::default(), req(6, Some(8.0)),
                             Preconditions::default(), 7);
        let GangPlan::Hold(new) = plan else { panic!("expected Hold") };
        let mut sorted = new.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 4]);
        // book the holds; a re-plan proposes no duplicates
        for &g in &new {
            b.hold(g, 7);
        }
        let plan = plan_gang(&views, &f, &b, &PowerConfig::default(), req(6, Some(8.0)),
                             Preconditions::default(), 7);
        assert_eq!(plan, GangPlan::Hold(vec![]), "already holding everything eligible");
    }

    #[test]
    fn held_and_pinned_devices_are_not_eligible_for_others() {
        let f = fabric(2, 4);
        let mut b = ReservationBook::new(&topo(2, 4));
        b.hold(0, 99); // another gang's hold (defensive: lane
                                  // heads rotate, stale holds must block)
        let mut views = two_by_four();
        views[0].gpus_mut()[0].held = true;
        views[0].gpus_mut()[1].pinned = true;
        let plan = plan_gang(&views, &f, &b, &PowerConfig::default(), req(8, Some(8.0)),
                             Preconditions::default(), 7);
        let GangPlan::Hold(new) = plan else { panic!("expected Hold") };
        assert!(!new.contains(&0), "held by another task");
        assert!(!new.contains(&1), "pinned");
        assert_eq!(new.len(), 6);
    }

    #[test]
    fn exclusive_request_needs_idle_devices() {
        let f = fabric(2, 4);
        let b = ReservationBook::new(&topo(2, 4));
        let mut views = two_by_four();
        for v in views[0].gpus_mut().iter_mut() {
            v.n_tasks = 1; // busy but roomy
        }
        let excl = MappingRequest {
            n_gpus: 4,
            demand_gb: Some(8.0),
            exclusive: true,
        };
        let plan = plan_gang(&views, &f, &b, &PowerConfig::default(), excl,
                             Preconditions::default(), 7);
        assert_eq!(plan, GangPlan::Place(vec![4, 5, 6, 7]), "only server 1 is idle");
    }

    #[test]
    fn power_envelope_caps_per_server_slots() {
        let f = fabric(2, 4);
        let b = ReservationBook::new(&topo(2, 4));
        let pw = PowerConfig::default(); // slot = 43 W
        let mut views = two_by_four();
        for s in views.iter_mut() {
            s.power_cap_w = Some(300.0);
        }
        views[0].power_w = 250.0; // headroom 50 W -> 1 slot
        views[1].power_w = 100.0; // headroom 200 W -> 4 slots
        let plan = plan_gang(&views, &f, &b, &pw, req(5, Some(8.0)),
                             Preconditions::default(), 7);
        match plan {
            GangPlan::Place(g) => {
                assert_eq!(g.len(), 5);
                assert_eq!(g[..4], [4, 5, 6, 7], "server 1 first (more slots)");
                assert_eq!(f.servers_spanned(&g), 2);
            }
            other => panic!("expected Place, got {other:?}"),
        }
        // 6 wide cannot fit under the envelopes: 4 + 1 slots available
        let plan = plan_gang(&views, &f, &b, &pw, req(6, Some(8.0)),
                             Preconditions::default(), 7);
        let GangPlan::Hold(new) = plan else { panic!("expected Hold") };
        assert_eq!(new.len(), 5);
    }

    #[test]
    fn gang_ceiling_bounds_width_per_server() {
        let t = topo(2, 4);
        let pw = PowerConfig::default(); // idle 52, slot 43
        assert_eq!(gang_gpu_ceiling(&t, &pw, None), 8, "no cap: whole pool");
        // idle floor 208 W; (400-208)/43 = 4.46 -> 4 slots, but capped at 4 GPUs
        assert_eq!(gang_gpu_ceiling(&t, &pw, Some(400.0)), 8);
        // (300-208)/43 = 2.1 -> 2 slots per server
        assert_eq!(gang_gpu_ceiling(&t, &pw, Some(300.0)), 4);
        // cap below the idle floor: the server can never admit anything
        assert_eq!(gang_gpu_ceiling(&t, &pw, Some(200.0)), 0);
    }

    #[test]
    fn gang_ceiling_intersects_mig_and_power_per_server() {
        // the review-found livelock shape: a MIG server with power headroom
        // next to a power-dead whole-GPU server — independently computed
        // bounds would each report capacity, but NO gang worker can ever be
        // placed; the per-server intersection reports zero so admission
        // fails such a gang fast instead of retrying forever
        let mut cfg = ClusterConfig::homogeneous(2, 4, 40.0);
        cfg.servers[0].mig_slices = vec![0.5, 0.5]; // MIG: no gang targets
        cfg.servers[1].n_gpus = 16; // idle floor 832 W >= 500 W cap: dead
        cfg.power_cap_w = Some(500.0);
        let t = ClusterTopology::from_config(&cfg);
        let pw = PowerConfig::default();
        assert_eq!(gang_gpu_ceiling(&t, &pw, Some(500.0)), 0);
        // make server 1 healthy again: only ITS capacity counts
        cfg.servers[1].n_gpus = 4;
        let t = ClusterTopology::from_config(&cfg);
        // (500-208)/43 = 6.8 -> capped at the server's 4 GPUs
        assert_eq!(gang_gpu_ceiling(&t, &pw, Some(500.0)), 4);
        // MIG alone zeroes a server even without any power cap
        assert_eq!(gang_gpu_ceiling(&t, &pw, None), 4);
    }

    #[test]
    fn island_packing_prefers_filled_islands() {
        // dual-island server: 2 eligible GPUs on island 0, 1 on island 1 —
        // the pair is taken first so collectives stay on NVLink
        let t = topo(1, 4);
        let f = Fabric::new(
            &t,
            &FabricConfig {
                profile: crate::config::schema::FabricProfile::DualIsland,
                ..FabricConfig::default()
            },
        );
        let b = ReservationBook::new(&t);
        let mut views = vec![sview(0, (0..4).map(|g| view(g, 0, 40.0, 0)).collect())];
        views[0].gpus_mut()[1].free_gb = 1.0; // island 0 = {0,1}: gpu 1 ineligible
        let plan = plan_gang(&views, &f, &b, &PowerConfig::default(), req(2, Some(8.0)),
                             Preconditions::default(), 7);
        assert_eq!(plan, GangPlan::Place(vec![2, 3]), "whole island beats a split pair");
    }
}
