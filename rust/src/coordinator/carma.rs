//! The CARMA simulation driver: end-to-end task management (paper §4.1,
//! Fig. 7) over the simulated cluster substrate (DESIGN.md §8).
//!
//! Event flow per task: arrival → admission (shard routing) → per-shard
//! queue → selection (recovery queue first) → 1-minute observation window →
//! two-level mapping (server filter → preconditions + estimator → per-GPU
//! policy) → dispatch → staircase memory ramp (may OOM → recovery) →
//! processor-sharing execution under the interference model → completion.
//!
//! Mapping is sharded (DESIGN.md §9): `cfg.coordinator.shards` mapper
//! workers each run their own observe→map state machine on their own event
//! lane, so K shards keep K observation windows open concurrently instead
//! of serializing them. One shard — the default — reproduces the paper's
//! serial pipeline event-for-event.

use crate::cluster::gpu::ResidentTask;
use crate::cluster::power::gpu_power_w;
use crate::cluster::topology::{Cluster, ClusterTopology};
use crate::config::schema::{CarmaConfig, CollocationMode, EstimatorKind, PolicyKind};
use crate::estimators::MemoryEstimator;
use crate::metrics::recorder::Recorder;
use crate::metrics::report::RunReport;
use crate::sim::{Engine, Event, TaskId};
use crate::util::units::GIB;
use crate::workload::memsim;
use crate::workload::task::TaskSpec;
use crate::workload::trace::TraceSpec;

use super::monitor::Monitor;
use super::policy::{self, GpuView, MappingRequest, Placement, Preconditions, ServerView};
use super::shard::{Admission, Mapper};

/// Seconds between memory-ramp stages (training warm-up allocations).
const RAMP_INTERVAL_S: f64 = 8.0;
/// Recovery loop's error-file polling delay (paper §4.2). Repeat offenders
/// back off exponentially from this base: 5 s, 10 s, 20 s, … (ROADMAP
/// "Adaptive recovery").
const RECOVERY_DETECT_S: f64 = 5.0;
/// Retry cadence when the selected task cannot be mapped yet.
const RETRY_S: f64 = 15.0;

/// Event lane of a coordinator shard (lane 0 is the global lane: arrivals,
/// monitor samples, recovery detection).
fn lane(shard: usize) -> usize {
    1 + shard
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RunState {
    Pending,  // not yet arrived
    Queued,   // in a queue
    Selected, // head-of-queue, being observed / awaiting mapping
    Running,
    Crashed, // OOM, awaiting recovery detection
    Done,
    /// Permanently unschedulable (demand exceeds every target's capacity)
    /// or crashed more than MAX_OOM_RETRIES times — surfaced to the user
    /// instead of looping forever.
    Failed,
}

/// Bounded recovery (paper §6 lists "more adaptive recovery methods" as
/// future work; we cap restarts so a pathological task cannot wedge the
/// queue).
const MAX_OOM_RETRIES: u32 = 3;

struct TaskRun {
    spec: TaskSpec,
    state: RunState,
    gpus: Vec<usize>,
    instances: Vec<Option<usize>>,
    /// Allocated segment ids per occupied GPU (parallel to `gpus`).
    segs: Vec<Vec<crate::cluster::allocator::SegId>>,
    /// Remaining ramp segment sizes (bytes, per GPU — same on each).
    ramp: Vec<f64>,
    next_ramp: usize,
    remaining_s: f64,
    speed: f64,
    last_progress_t: f64,
    version: u64,
    in_recovery: bool,
    /// Estimate the mapper admitted this task with (per GPU). While the
    /// memory ramp is still in flight, the coordinator counts the not-yet-
    /// allocated remainder as *reserved* so back-to-back admissions don't
    /// overcommit the same free memory (Fig. 7 mapping step).
    admitted_est_gb: Option<f64>,
    /// Final-retry recovery demotion (§4.2 + DESIGN.md §9): the task holds
    /// its GPUs exclusively — no collocation is admitted onto them — so the
    /// last permitted attempt cannot be re-crashed by a newcomer's ramp.
    pinned: bool,
}

/// Outcome of a full trace run.
pub struct RunOutcome {
    pub report: RunReport,
    pub recorder: Recorder,
    /// Simulation events processed (throughput accounting, `benches/`).
    pub events: u64,
}

pub struct Carma {
    pub cfg: CarmaConfig,
    engine: Engine,
    cluster: Cluster,
    tasks: Vec<TaskRun>,
    /// Global admission layer: intake, per-shard queues, capacity ceilings.
    admission: Admission,
    /// Per-shard mapper workers (observe→map state machines).
    mappers: Vec<Mapper>,
    estimator: Box<dyn MemoryEstimator>,
    monitor: Monitor,
    recorder: Recorder,
    done_count: usize,
}

impl Carma {
    pub fn new(cfg: CarmaConfig, estimator: Box<dyn MemoryEstimator>, trace: &TraceSpec) -> Carma {
        let cluster = Cluster::new(ClusterTopology::from_config(&cfg.cluster));
        let n = trace.tasks.len();
        let monitor = Monitor::new(cluster.n_gpus(), cfg.monitor.window_s);
        let shards = cfg.coordinator.shards;
        let mut recorder = Recorder::new(n, cluster.n_gpus());
        recorder.n_shards = shards;
        let admission = Admission::new(
            shards,
            n,
            cfg.coordinator.assign,
            cluster.topo.admissible_ceilings(cfg.power.idle_w),
        );
        let tasks = trace
            .tasks
            .iter()
            .map(|spec| TaskRun {
                spec: spec.clone(),
                state: RunState::Pending,
                gpus: Vec::new(),
                instances: Vec::new(),
                segs: Vec::new(),
                ramp: Vec::new(),
                next_ramp: 0,
                remaining_s: spec.work_s,
                speed: 0.0,
                last_progress_t: 0.0,
                version: 0,
                in_recovery: false,
                admitted_est_gb: None,
                pinned: false,
            })
            .collect();
        Carma {
            cfg,
            engine: Engine::with_lanes(1 + shards, 2 * n + 16),
            cluster,
            tasks,
            admission,
            mappers: vec![Mapper::new(); shards],
            estimator,
            monitor,
            recorder,
            done_count: 0,
        }
    }

    /// Run the whole trace to completion; returns the paper's metric set.
    pub fn run(mut self, label: &str) -> RunOutcome {
        for t in &self.tasks {
            self.engine
                .schedule(t.spec.arrival_s, Event::TaskArrival(t.spec.id));
        }
        self.engine
            .schedule_in(self.cfg.monitor.sample_period_s, Event::MonitorSample);

        let mut guard: u64 = 0;
        while let Some((_, ev)) = self.engine.pop() {
            guard += 1;
            assert!(
                guard < 200_000_000,
                "simulation did not converge (event storm)"
            );
            match ev {
                Event::TaskArrival(id) => self.on_arrival(id),
                Event::WindowDone(id) => self.on_window_done(id),
                Event::RetryMapping(shard) => self.on_retry(shard),
                Event::Ramp(id, stage) => self.on_ramp(id, stage),
                Event::Completion(id, v) => self.on_completion(id, v),
                Event::MonitorSample => self.on_monitor_sample(),
                Event::RecoveryDetect(id) => self.on_recovery_detect(id),
            }
            if self.done_count == self.tasks.len() {
                break;
            }
        }
        assert_eq!(
            self.done_count,
            self.tasks.len(),
            "trace ended with unfinished tasks (queue deadlock?)"
        );
        RunOutcome {
            report: RunReport::from_recorder(label, &self.recorder),
            recorder: self.recorder,
            events: self.engine.events_processed(),
        }
    }

    // -- event handlers -----------------------------------------------------

    fn on_arrival(&mut self, id: TaskId) {
        let t = self.engine.now();
        self.recorder.on_arrival(id, t);
        self.tasks[id].state = RunState::Queued;
        let loads = self.shard_loads();
        let shard = self.admission.submit(id, &loads);
        self.recorder.on_assigned(id, shard);
        self.feed(shard);
    }

    /// Per-shard load (queued + under observation) for least-loaded routing.
    fn shard_loads(&self) -> Vec<usize> {
        self.mappers
            .iter()
            .enumerate()
            .map(|(s, m)| self.admission.queue_len(s) + usize::from(m.selected.is_some()))
            .collect()
    }

    /// Hand shard `shard`'s mapper its next task, if it is idle and one is
    /// queued (the sharded generalization of the serial "select next").
    fn feed(&mut self, shard: usize) {
        if self.mappers[shard].selected.is_some() {
            return;
        }
        if let Some((id, _rec)) = self.admission.pop_next(shard) {
            self.mappers[shard].select(id);
            self.tasks[id].state = RunState::Selected;
            // observe the GPUs for one window before deciding (paper §4.1)
            self.engine
                .schedule_in_on(lane(shard), self.cfg.monitor.window_s, Event::WindowDone(id));
        }
    }

    fn on_window_done(&mut self, id: TaskId) {
        let Some(shard) = self.admission.shard_of(id) else {
            return;
        };
        if self.mappers[shard].selected != Some(id) {
            return; // stale (task got re-queued by recovery etc.)
        }
        self.mappers[shard].window_done = true;
        self.attempt_map(shard);
    }

    fn on_retry(&mut self, shard: usize) {
        self.mappers[shard].retry_scheduled = false;
        if self.mappers[shard].ready() {
            self.attempt_map(shard);
        }
    }

    fn schedule_retry(&mut self, shard: usize) {
        if !self.mappers[shard].retry_scheduled {
            self.mappers[shard].retry_scheduled = true;
            self.engine
                .schedule_in_on(lane(shard), RETRY_S, Event::RetryMapping(shard));
        }
    }

    /// Re-attempt every shard whose selected task already finished its
    /// window — resources just changed (completion / OOM release).
    fn kick_mappers(&mut self) {
        for shard in 0..self.mappers.len() {
            if self.mappers[shard].ready() {
                self.attempt_map(shard);
            }
        }
    }

    /// Try to map shard `shard`'s selected task; on success dispatch + feed
    /// the shard its next task.
    fn attempt_map(&mut self, shard: usize) {
        let Some(id) = self.mappers[shard].selected else { return };
        let views = self.server_views();
        let crashes = self.recorder.tasks[id].oom_crashes;
        let spec = &self.tasks[id].spec;

        // estimator + safety margin; estimates at/above every server's GPU
        // capacity degrade to exclusive placement (the estimator "takes the
        // collocation potential away", §5.4)
        let max_mem = self.cluster.topo.max_server_mem_gb();
        let raw_est = self.estimator.estimate_gb(spec);
        let mut demand = raw_est.map(|e| e + self.cfg.safety_margin_gb);
        // adaptive recovery (ROADMAP): early retries re-enter normal
        // collocation-aware mapping; the FINAL permitted retry is demoted to
        // a *pinned* exclusive slot, so it cannot be crashed again
        let demoted = self.tasks[id].in_recovery && crashes >= MAX_OOM_RETRIES;
        let mut force_exclusive = demoted;
        if let Some(d) = demand {
            if d >= max_mem {
                demand = Some(max_mem);
                force_exclusive = true;
            }
        }
        // GPUMemNet's class grid tops out at the 40 GB training capacity
        // (DESIGN.md §5); on servers with more memory a *saturated* raw
        // estimate means "at least this much", not a point estimate —
        // degrade to exclusive instead of collocating on it (margin excluded:
        // a 39 GB point estimate + 2 GB margin is not saturation)
        if self.cfg.estimator == EstimatorKind::GpuMemNet
            && raw_est.is_some_and(|e| e >= memsim::GPU_CAPACITY_GB)
        {
            force_exclusive = true;
        }

        let req = MappingRequest {
            n_gpus: spec.n_gpus,
            demand_gb: demand,
            exclusive: force_exclusive,
        };
        let pre = Preconditions {
            smact_cap: self.cfg.smact_cap,
            min_free_gb: self.cfg.min_free_gb,
        };
        // permanently unschedulable? — fail fast instead of retrying
        // forever. Admission owns the static ceilings (capacity accounting
        // across servers, power-envelope-dead servers excluded): a demand
        // larger than every schedulable target, or a GPU count no single
        // admissible server owns (multi-GPU tasks never span servers), can
        // never be placed no matter how long the task waits.
        if let Err(why) = self.admission.admissible(req.n_gpus, demand) {
            self.fail_task(id, why);
            return;
        }

        match policy::select_two_level(
            self.cfg.policy,
            &views,
            req,
            pre,
            &mut self.mappers[shard].rr_cursor,
        ) {
            Some(p) => {
                self.tasks[id].admitted_est_gb = demand;
                self.tasks[id].pinned = demoted;
                // clear BEFORE dispatch: a first-ramp OOM inside dispatch
                // reaches kick_mappers, which must not re-enter this shard
                // for the task it is mid-dispatching (clear emits no events,
                // so the schedule order is unchanged)
                self.mappers[shard].clear();
                self.dispatch(id, p);
                self.feed(shard);
            }
            None => self.schedule_retry(shard),
        }
    }

    fn fail_task(&mut self, id: TaskId, why: &str) {
        eprintln!("carma: task {} failed permanently: {why}", self.tasks[id].spec.label());
        self.tasks[id].state = RunState::Failed;
        self.recorder.on_failed(id);
        self.done_count += 1;
        if let Some(shard) = self.admission.shard_of(id) {
            if self.mappers[shard].selected == Some(id) {
                self.mappers[shard].clear();
                self.feed(shard);
            }
        }
    }

    /// Reserved-but-not-yet-allocated memory on a GPU: for each resident
    /// task admitted with an estimate, the part of the estimate its ramp
    /// has not claimed yet.
    fn pending_reserved_gb(&self, gpu: usize) -> f64 {
        self.cluster
            .gpu(gpu)
            .resident
            .iter()
            .map(|r| {
                let t = &self.tasks[r.task];
                match t.admitted_est_gb {
                    Some(est) => {
                        let allocated: f64 =
                            t.ramp.iter().take(t.next_ramp).sum::<f64>() / GIB;
                        (est - allocated).max(0.0)
                    }
                    None => 0.0,
                }
            })
            .sum()
    }

    /// Build the two-level mapping input: per-server power draw + per-GPU
    /// monitor snapshots (global GPU ids).
    fn server_views(&self) -> Vec<ServerView> {
        let now = self.engine.now();
        self.cluster
            .servers
            .iter()
            .zip(&self.cluster.topo.servers)
            .map(|(srv, spec)| {
                let gpus: Vec<GpuView> = srv
                    .gpus
                    .iter()
                    .map(|g| {
                        let inst = g.free_mig_instance();
                        GpuView {
                            id: g.id,
                            server: spec.id,
                            free_gb: (g.free_gb() - self.pending_reserved_gb(g.id)).max(0.0),
                            smact_window: self.monitor.windowed_smact(g.id),
                            n_tasks: g.n_tasks(),
                            pinned: g.resident.iter().any(|r| self.tasks[r.task].pinned),
                            mig_free_instance: inst,
                            mig_instance_mem_gb: inst
                                .map(|i| g.capacity_gb() * g.mig_slices[i])
                                .unwrap_or(0.0),
                            mig_enabled: g.mig_enabled(),
                        }
                    })
                    .collect();
                // instantaneous draw is only consulted by the power-envelope
                // filter; skip the O(GPUs × residents) walk when no cap is set
                let power_w: f64 = if spec.power_cap_w.is_some() {
                    srv.gpus
                        .iter()
                        .map(|g| {
                            gpu_power_w(
                                &self.cfg.power,
                                g.n_tasks(),
                                g.effective_smact(self.cfg.colloc, now),
                            )
                        })
                        .sum()
                } else {
                    0.0
                };
                ServerView {
                    id: spec.id,
                    power_w,
                    power_cap_w: spec.power_cap_w,
                    gpus,
                }
            })
            .collect()
    }

    fn dispatch(&mut self, id: TaskId, p: Placement) {
        let now = self.engine.now();
        self.recorder.on_dispatch(id, now);

        // staircase memory ramp: memsim's segment shape scaled so the total
        // equals the task's true peak memory (paper Table 3 ground truth)
        let (ramp, smact, membw, spec_id);
        {
            let spec = &self.tasks[id].spec;
            let shape = memsim::ramp_segments_bytes(&spec.features);
            let total: f64 = shape.iter().sum();
            let scale = (spec.mem_gb * GIB) / total.max(1.0);
            ramp = shape.into_iter().map(|b| b * scale).collect::<Vec<f64>>();
            smact = spec.smact;
            membw = spec.membw;
            spec_id = spec.id;
        }
        debug_assert_eq!(spec_id, id);

        let task = &mut self.tasks[id];
        task.state = RunState::Running;
        task.gpus = p.gpus.clone();
        task.instances = p.instances.clone();
        task.segs = vec![Vec::new(); p.gpus.len()];
        task.ramp = ramp;
        task.next_ramp = 0;
        task.last_progress_t = now;

        for (k, &g) in p.gpus.iter().enumerate() {
            self.cluster.gpu_mut(g).add_resident(ResidentTask {
                task: id,
                smact,
                membw,
                instance: p.instances[k].unwrap_or(0),
                dispatched_at: now,
            });
        }
        // first allocation (CUDA context) happens immediately
        self.on_ramp(id, 0);
        if self.tasks[id].state == RunState::Running {
            let gpus = self.tasks[id].gpus.clone();
            self.recompute_speeds(&gpus);
        }
    }

    /// Allocate the next ramp segment on every occupied GPU. Any failure =
    /// OOM for THIS task (the subsequently-arriving one), paper §1.
    fn on_ramp(&mut self, id: TaskId, stage: u8) {
        if self.tasks[id].state != RunState::Running || self.tasks[id].next_ramp != stage as usize {
            return; // stale ramp event (task crashed / completed / restarted)
        }
        let seg_bytes = match self.tasks[id].ramp.get(stage as usize) {
            Some(&b) => b,
            None => return,
        };
        let seg_mib = (seg_bytes / (1024.0 * 1024.0)).ceil().max(1.0) as u64;
        let gpus = self.tasks[id].gpus.clone();
        for (k, &g) in gpus.iter().enumerate() {
            // page-backed scatter allocation: a slab may span a few holes,
            // but shredded-beyond-repair free memory still OOMs (§4.2)
            match self.cluster.gpu_mut(g).alloc.alloc_scatter(seg_mib, 4) {
                Some(segs) => self.tasks[id].segs[k].extend(segs),
                None => {
                    self.oom(id);
                    return;
                }
            }
        }
        self.tasks[id].next_ramp += 1;
        if self.tasks[id].next_ramp < self.tasks[id].ramp.len() {
            let l = self.task_lane(id);
            self.engine
                .schedule_in_on(l, RAMP_INTERVAL_S, Event::Ramp(id, stage + 1));
        }
    }

    /// Event lane of the shard owning `id` (admission routing is sticky, so
    /// every admitted task has one).
    fn task_lane(&self, id: TaskId) -> usize {
        lane(self.admission.shard_of(id).expect("task was admitted"))
    }

    fn oom(&mut self, id: TaskId) {
        self.recorder.on_oom(id);
        self.release(id);
        let task = &mut self.tasks[id];
        task.state = RunState::Crashed;
        task.version += 1; // invalidate any scheduled completion
        task.remaining_s = task.spec.work_s; // restart from scratch
        task.in_recovery = true;
        let crashes = self.recorder.tasks[id].oom_crashes;
        if crashes > MAX_OOM_RETRIES {
            self.fail_task(id, "exceeded OOM retry budget");
            // the failed task's memory was released above — waiting mappers
            // get the same immediate kick the recoverable path gives them
            self.kick_mappers();
            return;
        }
        // adaptive backoff (ROADMAP "Adaptive recovery"): a repeat offender
        // waits 2× longer before each re-queue — 5 s, 10 s, 20 s — giving
        // the GPUs it keeps crashing on time to drain before the final,
        // demoted-to-exclusive attempt
        let backoff = RECOVERY_DETECT_S * (1u64 << (crashes - 1).min(6)) as f64;
        self.engine.schedule_in(backoff, Event::RecoveryDetect(id));
        // freed memory may unblock a waiting mapper
        self.kick_mappers();
    }

    fn on_recovery_detect(&mut self, id: TaskId) {
        if self.tasks[id].state != RunState::Crashed {
            return;
        }
        self.tasks[id].state = RunState::Queued;
        let shard = self.admission.submit_recovery(id);
        self.feed(shard);
    }

    /// Free all segments + residency of a task and update speeds.
    fn release(&mut self, id: TaskId) {
        let gpus = self.tasks[id].gpus.clone();
        let segs = std::mem::take(&mut self.tasks[id].segs);
        for (k, &g) in gpus.iter().enumerate() {
            for seg in &segs[k] {
                self.cluster.gpu_mut(g).alloc.free(*seg);
            }
            self.cluster.gpu_mut(g).remove_resident(id);
        }
        self.tasks[id].gpus.clear();
        self.tasks[id].instances.clear();
        self.recompute_speeds(&gpus);
    }

    fn on_completion(&mut self, id: TaskId, version: u64) {
        if self.tasks[id].state != RunState::Running || self.tasks[id].version != version {
            return; // stale
        }
        self.progress_update(id);
        debug_assert!(
            self.tasks[id].remaining_s < 1e-6,
            "completion fired with {}s of work left",
            self.tasks[id].remaining_s
        );
        self.release(id);
        self.tasks[id].state = RunState::Done;
        self.done_count += 1;
        self.recorder.on_completion(id, self.engine.now());
        self.kick_mappers();
    }

    fn progress_update(&mut self, id: TaskId) {
        let now = self.engine.now();
        let t = &mut self.tasks[id];
        t.remaining_s = (t.remaining_s - (now - t.last_progress_t) * t.speed).max(0.0);
        t.last_progress_t = now;
    }

    /// Re-derive speed factors for every task touching `gpus` (including
    /// multi-GPU tasks' partner devices) and reschedule their completions.
    fn recompute_speeds(&mut self, gpus: &[usize]) {
        use std::collections::BTreeSet;
        let mut affected: BTreeSet<TaskId> = BTreeSet::new();
        for &g in gpus {
            for r in &self.cluster.gpu(g).resident {
                affected.insert(r.task);
            }
        }
        // include partner GPUs of multi-GPU tasks
        let mut all_gpus: BTreeSet<usize> = gpus.iter().copied().collect();
        for &id in &affected {
            for &g in &self.tasks[id].gpus {
                all_gpus.insert(g);
            }
        }
        let mut more: BTreeSet<TaskId> = BTreeSet::new();
        for &g in &all_gpus {
            for r in &self.cluster.gpu(g).resident {
                more.insert(r.task);
            }
        }

        // per-GPU speed tables
        let mut table: std::collections::BTreeMap<(usize, TaskId), f64> =
            std::collections::BTreeMap::new();
        for &g in &all_gpus {
            for (tid, f) in self.cluster.gpu(g).speeds(self.cfg.colloc, &self.cfg.interference) {
                table.insert((g, tid), f);
            }
        }

        let now = self.engine.now();
        for id in more {
            if self.tasks[id].state != RunState::Running {
                continue;
            }
            self.progress_update(id);
            let speed = self.tasks[id]
                .gpus
                .iter()
                .map(|&g| *table.get(&(g, id)).unwrap_or(&1.0))
                .fold(f64::INFINITY, f64::min);
            let speed = if speed.is_finite() { speed } else { 0.0 };
            let t = &mut self.tasks[id];
            t.speed = speed;
            t.version += 1;
            if speed > 1e-9 {
                let eta = now + t.remaining_s / speed;
                let v = t.version;
                let l = self.task_lane(id);
                self.engine.schedule_on(l, eta, Event::Completion(id, v));
            }
        }
    }

    fn on_monitor_sample(&mut self) {
        let now = self.engine.now();
        let dt = self.cfg.monitor.sample_period_s;
        for g in 0..self.cluster.n_gpus() {
            let gpu = self.cluster.gpu(g);
            let smact = gpu.effective_smact(self.cfg.colloc, now);
            let mem = gpu.used_gb();
            let power = gpu_power_w(&self.cfg.power, gpu.n_tasks(), smact);
            self.monitor.push(g, now, smact);
            self.recorder.on_sample(g, now, dt, mem, smact, power);
        }
        if self.done_count < self.tasks.len() {
            self.engine.schedule_in(dt, Event::MonitorSample);
        }
    }

    // -- test/inspection hooks ------------------------------------------------

    /// Total queued tasks across every shard.
    pub fn queue_len(&self) -> usize {
        self.admission.len()
    }

    pub fn n_shards(&self) -> usize {
        self.mappers.len()
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }
}

/// Convenience: run one configuration over a trace.
pub fn run_trace(
    cfg: CarmaConfig,
    estimator: Box<dyn MemoryEstimator>,
    trace: &TraceSpec,
    label: &str,
) -> RunOutcome {
    Carma::new(cfg, estimator, trace).run(label)
}

/// Label helper used by the experiments: "MAGM+MPS+GPUMemNet(80%,5GB)".
pub fn run_label(cfg: &CarmaConfig, estimator_name: &str) -> String {
    let mut s = format!("{}+{}", cfg.policy.name(), cfg.colloc.name());
    if estimator_name != "none" {
        s.push('+');
        s.push_str(estimator_name);
    }
    let mut pre = Vec::new();
    if let Some(c) = cfg.smact_cap {
        pre.push(format!("{:.0}%", c * 100.0));
    }
    if let Some(m) = cfg.min_free_gb {
        pre.push(format!("{m:.0}GB"));
    }
    if cfg.safety_margin_gb > 0.0 {
        pre.push(format!("+{:.0}GBmargin", cfg.safety_margin_gb));
    }
    if !pre.is_empty() {
        s.push_str(&format!("({})", pre.join(",")));
    }
    if cfg.policy == PolicyKind::Exclusive {
        return format!("Exclusive ({})", CollocationMode::Mps.name());
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::EstimatorKind;
    use crate::estimators;
    use crate::workload::model_zoo::ModelZoo;
    use crate::workload::trace::{trace_60, trace_90, trace_cluster};

    fn cfg(policy: PolicyKind, est: EstimatorKind) -> (CarmaConfig, Box<dyn MemoryEstimator>) {
        let mut c = CarmaConfig::default();
        c.policy = policy;
        c.estimator = est;
        let e = estimators::build(est, "artifacts").unwrap();
        (c, e)
    }

    #[test]
    fn exclusive_completes_trace_without_oom() {
        let zoo = ModelZoo::load();
        let trace = trace_90(&zoo, 1);
        let (mut c, e) = cfg(PolicyKind::Exclusive, EstimatorKind::None);
        c.smact_cap = None;
        let out = run_trace(c, e, &trace, "excl");
        assert_eq!(out.report.completed, 90);
        assert_eq!(out.report.oom_crashes, 0, "exclusive can never OOM");
        assert!(out.report.trace_total_min > 60.0);
    }

    #[test]
    fn oracle_magm_beats_exclusive() {
        let zoo = ModelZoo::load();
        let trace = trace_90(&zoo, 1);

        let (mut ce, ee) = cfg(PolicyKind::Exclusive, EstimatorKind::None);
        ce.smact_cap = None;
        let excl = run_trace(ce, ee, &trace, "excl");

        let (mut cm, em) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        cm.safety_margin_gb = 2.0;
        let magm = run_trace(cm, em, &trace, "magm");

        assert_eq!(magm.report.completed, 90);
        assert_eq!(magm.report.oom_crashes, 0, "oracle + margin must avoid OOM");
        assert!(
            magm.report.trace_total_min < excl.report.trace_total_min * 0.9,
            "MAGM {:.1}m should beat Exclusive {:.1}m by >10%",
            magm.report.trace_total_min,
            excl.report.trace_total_min
        );
        assert!(magm.report.mean_smact > excl.report.mean_smact);
    }

    #[test]
    fn blind_collocation_ooms_then_recovers() {
        let zoo = ModelZoo::load();
        let trace = trace_60(&zoo, 1);
        let (mut c, e) = cfg(PolicyKind::RoundRobin, EstimatorKind::None);
        c.smact_cap = None; // no preconditions at all
        let out = run_trace(c, e, &trace, "rr-blind");
        assert_eq!(out.report.completed, 60, "recovery must finish every task");
        assert!(
            out.report.oom_crashes > 0,
            "blind RR on the heavy trace should hit OOMs"
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let zoo = ModelZoo::load();
        let trace = trace_60(&zoo, 3);
        let (c1, e1) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        let (c2, e2) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        let a = run_trace(c1, e1, &trace, "a");
        let b = run_trace(c2, e2, &trace, "b");
        assert_eq!(a.report.trace_total_min, b.report.trace_total_min);
        assert_eq!(a.report.energy_mj, b.report.energy_mj);
        assert_eq!(a.report.oom_crashes, b.report.oom_crashes);
    }

    #[test]
    fn waiting_time_includes_window() {
        let zoo = ModelZoo::load();
        let trace = trace_90(&zoo, 5);
        let (c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        let out = run_trace(c, e, &trace, "w");
        // every task waits at least the 60s observation window
        assert!(out.report.avg_waiting_min >= 1.0);
    }

    #[test]
    fn cluster_run_completes_and_spreads_load() {
        use crate::config::schema::ClusterConfig;
        use crate::workload::trace::trace_cluster;
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 96, 8, 1);
        let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
        c.safety_margin_gb = 2.0;
        let out = run_trace(c, e, &trace, "cluster-2x4");
        assert_eq!(out.report.completed, 96);
        assert_eq!(out.report.oom_crashes, 0);
        assert!(out.events > 96, "events counter must track the run");
        // both servers' GPUs must have done real work: the recorder holds 8
        // per-GPU energy integrals and idle-only GPUs sit at idle power
        assert_eq!(out.recorder.energy_j.len(), 8);
        let idle_only: f64 = out.recorder.energy_j.iter().cloned().fold(f64::INFINITY, f64::min);
        let busiest: f64 = out.recorder.energy_j.iter().cloned().fold(0.0, f64::max);
        assert!(busiest > idle_only, "load must spread beyond one GPU");
        assert!(
            out.recorder.energy_j[4..].iter().sum::<f64>() > 0.0,
            "server 1's GPUs never sampled"
        );
    }

    #[test]
    fn sharded_mapping_overlaps_windows() {
        use crate::config::schema::ClusterConfig;
        // 4 mappers on a 2×4 cluster: everything completes, the per-shard
        // counters are populated, and overlapping observation windows cut
        // queueing delay vs the serial coordinator
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 64, 8, 1);
        let mk = |shards: usize| {
            let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
            c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
            c.safety_margin_gb = 2.0;
            c.coordinator.shards = shards;
            run_trace(c, e, &trace, &format!("{shards}-shard"))
        };
        let serial = mk(1);
        let sharded = mk(4);
        assert_eq!(serial.report.completed, 64);
        assert_eq!(sharded.report.completed, 64);
        assert_eq!(serial.report.per_shard.len(), 1);
        assert_eq!(sharded.report.per_shard.len(), 4);
        assert_eq!(
            sharded.report.per_shard.iter().map(|s| s.tasks).sum::<usize>(),
            64,
            "admission routes every task to exactly one shard"
        );
        assert!(
            sharded.report.avg_waiting_min < serial.report.avg_waiting_min,
            "4 shards {:.1}m waiting !< serial {:.1}m",
            sharded.report.avg_waiting_min,
            serial.report.avg_waiting_min
        );
    }

    #[test]
    fn sharded_run_is_deterministic() {
        use crate::config::schema::{ClusterConfig, ShardAssign};
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 48, 8, 5);
        for assign in [ShardAssign::RoundRobin, ShardAssign::LeastLoaded, ShardAssign::Locality] {
            let mk = || {
                let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
                c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
                c.safety_margin_gb = 2.0;
                c.coordinator.shards = 4;
                c.coordinator.assign = assign;
                run_trace(c, e, &trace, "det")
            };
            let a = mk();
            let b = mk();
            assert_eq!(a.report.completed, 48, "{assign:?}");
            assert_eq!(
                a.report.trace_total_min.to_bits(),
                b.report.trace_total_min.to_bits(),
                "{assign:?}"
            );
            assert_eq!(a.report.energy_mj.to_bits(), b.report.energy_mj.to_bits());
            assert_eq!(a.events, b.events, "{assign:?}: event streams must match");
        }
    }

    #[test]
    fn adaptive_recovery_completes_blind_collocation() {
        // blind RR, no preconditions: tasks OOM, retry collocated with
        // doubled detection delays, and the final demoted (pinned exclusive)
        // attempt always lands — nothing may exhaust the retry budget
        let zoo = ModelZoo::load();
        let trace = trace_60(&zoo, 1);
        let (mut c, e) = cfg(PolicyKind::RoundRobin, EstimatorKind::None);
        c.smact_cap = None;
        let out = run_trace(c, e, &trace, "rr-adaptive");
        assert_eq!(out.report.completed, 60, "adaptive recovery must finish every task");
        assert!(out.report.oom_crashes > 0);
        assert_eq!(out.recorder.failed_total, 0, "no task may fail its retry budget");
    }

    #[test]
    fn labels() {
        let mut c = CarmaConfig::default();
        c.min_free_gb = Some(5.0);
        assert_eq!(run_label(&c, "GPUMemNet"), "MAGM+MPS+GPUMemNet(80%,5GB)");
        c.policy = PolicyKind::Exclusive;
        assert!(run_label(&c, "none").starts_with("Exclusive"));
    }
}
