//! The CARMA simulation driver: end-to-end task management (paper §4.1,
//! Fig. 7) over the simulated cluster substrate (DESIGN.md §8).
//!
//! Event flow per task: arrival → admission (shard routing) → per-shard
//! queue → selection (recovery queue first) → 1-minute observation window →
//! two-level mapping (server filter → preconditions + estimator → per-GPU
//! policy) → dispatch → staircase memory ramp (may OOM → recovery) →
//! processor-sharing execution under the interference model → completion.
//!
//! Mapping is sharded (DESIGN.md §9): `cfg.coordinator.shards` mapper
//! workers each run their own observe→map state machine on their own event
//! lane, so K shards keep K observation windows open concurrently instead
//! of serializing them. One shard — the default — reproduces the paper's
//! serial pipeline event-for-event.
//!
//! With `cfg.engine.threads > 1` the driver additionally runs the
//! conservative parallel step (DESIGN.md §10): at each merge barrier it
//! drains the frontier of events sharing the current time quantum, fans the
//! per-shard mapper work — the O(GPUs) monitor-snapshot build and the
//! policy scans — out across a [`WorkerPool`], and commits every result on
//! this thread in strict `(time, seq)` order. Speculative plans are tagged
//! with the `(state_epoch, quantum)` they were computed against and are
//! discarded (and recomputed inline) whenever a commit moved the cluster
//! under them, which is what makes a threaded run byte-identical to the
//! serial one rather than merely statistically close.
//!
//! Snapshot maintenance is *incremental* (DESIGN.md §17): alongside the
//! global `state_epoch` each server carries its own epoch, bumped only by
//! commits that touch it. A dispatch on server `s` therefore rebuilds only
//! `views[s]` on the next snapshot — the other servers' views carry
//! forward by `Arc` bump. Plans still validate against the GLOBAL epoch (a
//! mapping decision reads every server's view), so the narrowing changes
//! which `ServerView`s get rebuilt, never which plans commit.

use std::sync::Arc;

use crate::cluster::fabric::Fabric;
use crate::cluster::gpu::ResidentTask;
use crate::cluster::power::{self, gpu_power_w};
use crate::cluster::topology::{Cluster, ClusterTopology};
use crate::config::schema::{CarmaConfig, CollocationMode, EstimatorKind, PolicyKind, TimelineMode};
use crate::estimators::MemoryEstimator;
use crate::metrics::recorder::{DecisionOutcome, Recorder};
use crate::metrics::report::RunReport;
use crate::obs::{Phase, Profiler, TraceSink};
use crate::sim::faults::{self, FaultKind, FaultRecord};
use crate::sim::parallel::{resolve_threads, WorkerPool};
use crate::sim::{Engine, EngineStats, Event, TaskId};
use crate::util::json::{self, Json};
use crate::util::units::GIB;
use crate::workload::memsim;
use crate::workload::model_zoo::ModelZoo;
use crate::workload::task::TaskSpec;
use crate::workload::trace::{ArrivalGen, TraceSpec};

use super::gang::{self, GangLane, GangPlan, ReservationBook};
use super::monitor::Monitor;
use super::placement::{self, Explain, RejectReason};
use super::policy::{GpuView, MappingRequest, Placement, Preconditions, ServerView};
use super::shard::{Admission, MapPlan, Mapper, PlanOutcome};

/// Seconds between memory-ramp stages (training warm-up allocations).
const RAMP_INTERVAL_S: f64 = 8.0;
/// Recovery loop's error-file polling delay (paper §4.2). Repeat offenders
/// back off exponentially from this base: 5 s, 10 s, 20 s, … (ROADMAP
/// "Adaptive recovery").
const RECOVERY_DETECT_S: f64 = 5.0;
/// Retry cadence when the selected task cannot be mapped yet.
const RETRY_S: f64 = 15.0;

/// Event lane of a coordinator shard (lane 0 is the global lane: arrivals,
/// monitor samples, recovery detection).
fn lane(shard: usize) -> usize {
    1 + shard
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RunState {
    Pending,  // not yet arrived
    Queued,   // in a queue
    Selected, // head-of-queue, being observed / awaiting mapping
    Running,
    Crashed, // OOM, awaiting recovery detection
    Done,
    /// Permanently unschedulable (demand exceeds every target's capacity)
    /// or crashed more than MAX_OOM_RETRIES times — surfaced to the user
    /// instead of looping forever.
    Failed,
    /// Rejected at admission by the open-loop load shedder (DESIGN.md §13):
    /// the arrival found its routed shard's bounded queue full. Terminal —
    /// a shed task never queues, runs, or recovers.
    Shed,
}

/// Bounded recovery (paper §6 lists "more adaptive recovery methods" as
/// future work; we cap restarts so a pathological task cannot wedge the
/// queue).
const MAX_OOM_RETRIES: u32 = 3;

struct TaskRun {
    spec: TaskSpec,
    state: RunState,
    gpus: Vec<usize>,
    instances: Vec<Option<usize>>,
    /// Allocated segment ids per occupied GPU (parallel to `gpus`).
    segs: Vec<Vec<crate::cluster::allocator::SegId>>,
    /// Remaining ramp segment sizes (bytes, per GPU — same on each).
    ramp: Vec<f64>,
    next_ramp: usize,
    remaining_s: f64,
    speed: f64,
    last_progress_t: f64,
    version: u64,
    in_recovery: bool,
    /// Estimate the mapper admitted this task with (per GPU). While the
    /// memory ramp is still in flight, the coordinator counts the not-yet-
    /// allocated remainder as *reserved* so back-to-back admissions don't
    /// overcommit the same free memory (Fig. 7 mapping step).
    admitted_est_gb: Option<f64>,
    /// Final-retry recovery demotion (§4.2 + DESIGN.md §9): the task holds
    /// its GPUs exclusively — no collocation is admitted onto them — so the
    /// last permitted attempt cannot be re-crashed by a newcomer's ramp.
    pinned: bool,
    /// Fault-kill relaunch counter (the OOM retry budget's fault twin,
    /// DESIGN.md §15): a task interrupted more than
    /// `cfg.faults.max_relaunches` times fails permanently.
    fault_relaunches: u32,
    /// Cause of the most recent kill, consumed by recovery detection to
    /// label the re-queue (`relaunch` record vs the OOM `recovery` one).
    last_fault: Option<FaultKind>,
}

/// Per-domain outage counters (DESIGN.md §15). Overlapping faults on one
/// domain stack: a device is quarantined — invisible to placement — while
/// its own counter or its server's counter is non-zero, and rolls back to
/// healthy only when the last outstanding repair lands. Link outages
/// degrade (fabric costs up, gangs slow) without quarantining.
struct Health {
    gpu_outages: Vec<u32>,
    server_outages: Vec<u32>,
    link_outages: Vec<u32>,
}

impl Health {
    fn new(n_gpus: usize, n_servers: usize) -> Health {
        Health {
            gpu_outages: vec![0; n_gpus],
            server_outages: vec![0; n_servers],
            link_outages: vec![0; n_servers],
        }
    }

    /// Quarantined ⇒ filtered out by `RejectReason::Unhealthy` before any
    /// other eligibility check — even the holder of a gang reservation
    /// must not dispatch onto dead hardware.
    fn quarantined(&self, gpu: usize, server: usize) -> bool {
        self.gpu_outages[gpu] > 0 || self.server_outages[server] > 0
    }
}

/// Outcome of a full trace run.
pub struct RunOutcome {
    pub report: RunReport,
    pub recorder: Recorder,
    /// Simulation events processed (throughput accounting, `benches/`).
    pub events: u64,
    /// Engine self-profile (`--profile`, DESIGN.md §14). Wall-clock data
    /// lives HERE — a dedicated field printed to stderr — and never inside
    /// `report`, so byte-compared artifacts stay timing-free by structure,
    /// not by discipline.
    pub profile: Option<Json>,
    /// View-maintenance counters (DESIGN.md §17): how often the snapshot
    /// cache hit, how many rebuilds were full vs delta, servers rebuilt vs
    /// carried forward. Deterministic (no wall-clock), but kept out of the
    /// report — they describe the engine, not the schedule.
    pub view_stats: ViewStats,
    /// Event-arena + lane-storage counters from the engine
    /// ([`EngineStats`]): high-water marks and mid-run reallocation counts.
    pub engine_stats: EngineStats,
}

/// Snapshot-maintenance counters (DESIGN.md §17), surfaced on
/// [`RunOutcome`] and in the `--profile` JSON's `views` section.
#[derive(Debug, Clone, Copy, Default)]
pub struct ViewStats {
    /// `snapshot()` calls satisfied entirely from cache (no server rebuilt).
    pub snapshot_hits: u64,
    /// Rebuilds that reconstructed every server view.
    pub full_rebuilds: u64,
    /// Rebuilds that spliced a strict subset of fresh views into the
    /// carried-forward vector.
    pub delta_applies: u64,
    /// Total server views built from scratch.
    pub servers_rebuilt: u64,
    /// Total server views carried forward by `Arc` bump.
    pub servers_reused: u64,
    /// Differential checks run by the `verify_views` paranoia hook.
    pub verified: u64,
}

impl ViewStats {
    /// Fraction of snapshot requests (hit or rebuild) served from cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.snapshot_hits + self.full_rebuilds + self.delta_applies;
        if total == 0 {
            0.0
        } else {
            self.snapshot_hits as f64 / total as f64
        }
    }
}

/// Inputs of one shard's speculative mapping scan — everything the pure
/// [`compute_plan`] needs besides the shared snapshot. Built on the driver
/// thread (the estimator is not `Sync`); plain owned data, so it crosses
/// into the worker pool freely.
struct PlanJob {
    shard: usize,
    task: TaskId,
    req: MappingRequest,
    demoted: bool,
    cursor_in: usize,
    admissible: Result<(), &'static str>,
}

/// The cached monitor snapshot the mapping scans read, tagged with the
/// per-server epochs and the engine quantum it was built under. Shared
/// (`Arc`) so parallel plan rounds reference one copy; under delta
/// maintenance (DESIGN.md §17) a partial rebuild clones this vector —
/// each carried-forward `ServerView` is an `Arc` bump — and splices in
/// only the stale servers' fresh views.
struct ViewsCache {
    /// Global `state_epoch` at build time (the full-rebuild cache key when
    /// `engine.delta_views` is off — the PR-3 baseline).
    epoch: u64,
    /// Per-server epochs at build time; server `s` is stale iff its live
    /// epoch moved.
    epochs: Vec<u64>,
    /// Engine `(time, seq)` quantum at build time. Only power-capped
    /// servers read the clock (instantaneous draw), so a quantum mismatch
    /// alone staleness-marks just those.
    quantum: u64,
    views: Arc<Vec<ServerView>>,
}

pub struct Carma {
    pub cfg: CarmaConfig,
    engine: Engine,
    cluster: Cluster,
    tasks: Vec<TaskRun>,
    /// Global admission layer: intake, per-shard queues, capacity ceilings.
    admission: Admission,
    /// Per-shard mapper workers (observe→map state machines).
    mappers: Vec<Mapper>,
    estimator: Box<dyn MemoryEstimator>,
    monitor: Monitor,
    recorder: Recorder,
    done_count: usize,
    /// Events handled by the driver (== events popped in a full run; kept
    /// separately so the parallel frontier drain cannot over-count events
    /// that were popped but never processed after the final completion).
    processed: u64,
    /// Monotone state-version counter: bumped (the `touch_*` family) on
    /// every mutation that can change a mapping decision's inputs — GPU
    /// residency, allocations, ramp progress, pinning, holds, monitor
    /// samples, fabric occupancy. Plan validity is keyed on
    /// `(state_epoch, quantum)`.
    state_epoch: u64,
    /// Per-server state versions (DESIGN.md §17): `server_epochs[s]` moves
    /// only when a commit touches server `s`, so the snapshot rebuild can
    /// narrow to exactly the touched views. Fabric-only commits bump the
    /// global epoch without moving any of these.
    server_epochs: Vec<u64>,
    /// Precomputed GPU → owning-server table (`topo.server_of_gpu` is a
    /// linear scan; `touch_gpus` runs on every dispatch/release).
    server_of: Vec<usize>,
    views_cache: Option<ViewsCache>,
    /// View-maintenance counters surfaced on [`RunOutcome`] / `--profile`.
    view_stats: ViewStats,
    /// Worker pool of the parallel engine (None ⇒ serial, the default).
    pool: Option<WorkerPool>,
    /// Interconnect topology + NIC occupancy (DESIGN.md §11).
    fabric: Fabric,
    /// The gang lane's select → observe → place state machine.
    gang_lane: GangLane,
    /// Pending gang holds (per-GPU reservations the mappers must respect).
    book: ReservationBook,
    /// Materialized fault schedule (DESIGN.md §15): `FaultStrike(i)` /
    /// `FaultRepair(i)` events index into this vector — the
    /// `ServiceArrival` pattern, the coordinator owns the payload so the
    /// event type stays `Eq`.
    faults: Vec<FaultRecord>,
    /// Per-domain outage counters feeding the `Unhealthy` placement filter
    /// and the time-varying fabric degradation.
    health: Health,
    /// Open-loop service mode (DESIGN.md §13): the streaming arrival
    /// generator. `None` = closed-loop trace replay (the default).
    arrival_gen: Option<ArrivalGen>,
    /// The drawn-but-not-yet-arrived submission: exactly one arrival event
    /// is in flight at a time, and its spec waits here until the
    /// `ServiceArrival` commits on the driver thread — which is what keeps
    /// the arrival stream byte-identical at every shard/thread count.
    pending_arrival: Option<TaskSpec>,
    /// True while the generator may still emit (run loops must not exit on
    /// an all-done task set before intake closes).
    intake_open: bool,
    /// Streaming event-trace sink (`--trace-out`, DESIGN.md §14). Fed only
    /// from the driver thread at commit points, so the byte stream is
    /// identical at every engine-thread count for free.
    trace: Option<TraceSink>,
    /// Emit a full `decision` provenance record every Nth mapping decision
    /// (0 = never; the aggregate report section is always on).
    explain_sample: u64,
    /// Per-phase wall-clock + pool occupancy self-profiler (`--profile`).
    profiler: Profiler,
}

impl Carma {
    pub fn new(cfg: CarmaConfig, estimator: Box<dyn MemoryEstimator>, trace: &TraceSpec) -> Carma {
        let cluster = Cluster::new(ClusterTopology::from_config(&cfg.cluster));
        let n = trace.tasks.len();
        let service = cfg.service.arrivals.is_some();
        assert!(
            !service || n == 0,
            "open-loop service mode streams its own arrivals; pass an empty trace"
        );
        // expected offered load sizes the event lanes when the trace is
        // empty (open-loop runs grow the task set as arrivals commit)
        let n_est = if service {
            n.max((cfg.service.rate_per_min / 60.0 * cfg.service.duration_s).ceil() as usize)
        } else {
            n
        };
        let monitor = Monitor::new(cluster.n_gpus(), cfg.monitor.window_s);
        let shards = cfg.coordinator.shards;
        let threads = resolve_threads(cfg.engine.threads);
        let mut recorder = Recorder::new(n, cluster.n_gpus());
        recorder.n_shards = shards;
        if service {
            recorder.open_loop = true;
            recorder.util_window_s = cfg.monitor.window_s;
        }
        // a requested time-series artifact turns on utilization windowing
        // in closed-loop runs too (service mode already windows)
        if cfg.obs.timeseries_out.is_some() && recorder.util_window_s == 0.0 {
            recorder.util_window_s = cfg.monitor.window_s;
        }
        // timeline retention (DESIGN.md §14): `on` keeps the seed's dense
        // stride, `sparse` keeps ~one point per monitoring window, `off`
        // keeps none. Open-loop runs with `off` additionally drop the
        // per-task timing vector: terminal events fold into streaming
        // aggregates, so recorder memory is O(buckets + GPUs + in-flight).
        recorder.timeline_stride = match cfg.obs.timeline {
            TimelineMode::On => 15,
            TimelineMode::Sparse => {
                ((cfg.monitor.window_s / cfg.monitor.sample_period_s).round() as u64).max(1)
            }
            TimelineMode::Off => 0,
        };
        if service && cfg.obs.timeline == TimelineMode::Off {
            recorder.enable_stream();
        }
        let trace_sink = cfg.obs.trace_out.as_deref().and_then(|p| match TraceSink::create(p) {
            Ok(t) => Some(t),
            Err(e) => {
                eprintln!("carma: --trace-out {p}: {e} (tracing disabled)");
                None
            }
        });
        let explain_sample = cfg.obs.explain_sample;
        let profiler = Profiler::new(cfg.obs.profile);
        // gang fail-fast ceiling: best-case assemblable whole-GPU capacity,
        // intersected per server (MIG partitioning, power-dead servers and
        // power-slot headroom all on the same server subset) — a gang wider
        // than this can never be placed, even on a drained cluster
        // (DESIGN.md §11)
        let gang_ceiling =
            gang::gang_gpu_ceiling(&cluster.topo, &cfg.power, cfg.cluster.power_cap_w);
        let mut admission = Admission::new(
            shards,
            n,
            cfg.coordinator.assign,
            cluster.topo.admissible_ceilings(cfg.power.idle_w),
            gang_ceiling,
        );
        if service {
            admission = admission.with_queue_cap(cfg.service.queue_cap);
        }
        let mut fabric = Fabric::new(&cluster.topo, &cfg.fabric);
        // home-server affinity skips power-dead servers (a server whose
        // idle floor meets its envelope can never admit work): after a
        // "power-down" the locality router cycles the survivors only
        let alive: Vec<bool> = cluster
            .topo
            .servers
            .iter()
            .map(|s| {
                !s.power_cap_w
                    .is_some_and(|cap| cfg.power.idle_w * s.cfg.n_gpus as f64 >= cap)
            })
            .collect();
        fabric.set_alive(&alive);
        let book = ReservationBook::new(&cluster.topo);
        // deterministic chaos (DESIGN.md §15): the whole fault schedule is
        // a pure function of `(profile, rate, duration, seed, shape)`,
        // materialized here and enqueued as ordinary global-lane events in
        // `run()` — never drawn mid-run, so fault runs stay byte-identical
        // at every shard/thread count
        let faults = faults::generate(&cfg.faults, cluster.n_gpus(), cluster.n_servers());
        let health = Health::new(cluster.n_gpus(), cluster.n_servers());
        let tasks = trace
            .tasks
            .iter()
            .map(|spec| TaskRun {
                spec: spec.clone(),
                state: RunState::Pending,
                gpus: Vec::new(),
                instances: Vec::new(),
                segs: Vec::new(),
                ramp: Vec::new(),
                next_ramp: 0,
                remaining_s: spec.work_s,
                speed: 0.0,
                last_progress_t: 0.0,
                version: 0,
                in_recovery: false,
                admitted_est_gb: None,
                pinned: false,
                fault_relaunches: 0,
                last_fault: None,
            })
            .collect();
        let arrival_gen = cfg.service.arrivals.map(|kind| {
            ArrivalGen::new(
                &ModelZoo::load(),
                kind,
                cfg.service.rate_per_min,
                cfg.service.duration_s,
                cfg.service.seed,
            )
        });
        // lane 0 carries the arrival bulk + monitor/recovery traffic + the
        // full fault schedule (strike and repair per record); each shard
        // lane sees its share of the window/ramp/completion churn (~8
        // events per task in flight across reschedules). Closed-loop runs
        // size on the trace length. Open-loop runs are bounded by the LIVE
        // set instead — exactly one ServiceArrival is ever in flight and
        // the bounded queues cap the backlog — so lane storage must not
        // scale with total offered load (a million-task sweep would
        // otherwise pre-allocate hundreds of MB up front). The min() keeps
        // short service runs on the exact trace-length sizing.
        let lane0_full = 2 * n_est + 2 * faults.len() + 16;
        let per_lane_full = (8 * n_est) / shards.max(1) + 16;
        let (lane0_cap, per_lane_cap) = if service {
            // 64 pending events per device is generous slack: residency is
            // memory-bounded at a handful of tasks per GPU, and each live
            // task holds one ramp + one live completion + a tail of stale
            // (version-guarded) completions awaiting their old etas
            let live = 64 * cluster.n_gpus() + shards * cfg.service.queue_cap + 64;
            (
                lane0_full.min(2 * live + 2 * faults.len() + 16),
                per_lane_full.min((8 * live) / shards.max(1) + 16),
            )
        } else {
            (lane0_full, per_lane_full)
        };
        let server_of: Vec<usize> = (0..cluster.n_gpus())
            .map(|g| cluster.topo.server_of_gpu(g))
            .collect();
        let n_servers = cluster.n_servers();
        Carma {
            cfg,
            engine: Engine::with_lane_capacities(1 + shards, lane0_cap, per_lane_cap),
            cluster,
            tasks,
            admission,
            mappers: vec![Mapper::new(); shards],
            estimator,
            monitor,
            recorder,
            done_count: 0,
            processed: 0,
            state_epoch: 0,
            server_epochs: vec![0; n_servers],
            server_of,
            views_cache: None,
            view_stats: ViewStats::default(),
            pool: (threads > 1).then(|| WorkerPool::new(threads)),
            fabric,
            gang_lane: GangLane::new(),
            book,
            faults,
            health,
            intake_open: arrival_gen.is_some(),
            arrival_gen,
            pending_arrival: None,
            trace: trace_sink,
            explain_sample,
            profiler,
        }
    }

    /// Threads the engine actually runs on (1 = serial).
    pub fn engine_threads(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.threads())
    }

    /// Run the whole trace to completion; returns the paper's metric set.
    /// In open-loop service mode the trace is empty and arrivals stream in
    /// from the generator instead (DESIGN.md §13).
    pub fn run(mut self, label: &str) -> RunOutcome {
        // run-start `meta` record: the cluster shape the replay engine
        // needs to expand server-domain faults into GPU ids and to compute
        // utilization denominators from the trace alone (DESIGN.md §16).
        // Same shard count → same bytes; threads never appear in the trace.
        if self.trace.is_some() {
            let total = self.cluster.n_gpus();
            let servers: Vec<Json> = self
                .cluster
                .topo
                .servers
                .iter()
                .map(|s| json::num(s.cfg.n_gpus as f64))
                .collect();
            let shards = self.cfg.coordinator.shards;
            let seed = self.cfg.seed;
            self.trace_event("meta", || {
                vec![
                    ("gpus", json::num(total as f64)),
                    ("servers", json::arr(servers)),
                    ("shards", json::num(shards as f64)),
                    ("seed", json::num(seed as f64)),
                ]
            });
        }
        if self.intake_open {
            self.schedule_next_arrival();
        } else {
            for t in &self.tasks {
                self.engine
                    .schedule(t.spec.arrival_s, Event::TaskArrival(t.spec.id));
            }
        }
        self.engine
            .schedule_in(self.cfg.monitor.sample_period_s, Event::MonitorSample);
        // the fault schedule — strikes AND repairs — goes in up front on
        // the global lane (DESIGN.md §15); the generator guarantees
        // `t_repair > t_strike`, so the `(time, seq)` merge order never
        // repairs a fault before it lands
        for i in 0..self.faults.len() {
            let (strike, repair) = (self.faults[i].t_strike, self.faults[i].t_repair);
            self.engine.schedule(strike, Event::FaultStrike(i));
            self.engine.schedule(repair, Event::FaultRepair(i));
        }

        if self.pool.is_some() {
            self.run_parallel();
        } else {
            self.run_serial();
        }
        assert!(!self.intake_open, "run ended with the arrival stream open");
        assert_eq!(
            self.done_count,
            self.tasks.len(),
            "trace ended with unfinished tasks (queue deadlock?)"
        );
        // fold any straggling in-flight timings BEFORE the report reads the
        // streaming aggregates (no-op in full-recording mode)
        self.recorder.finalize();
        if let Some(t) = self.trace.as_mut() {
            t.flush();
        }
        // copy the sink's loss counter BEFORE the registry renders and the
        // report reads the recorder — `obs.trace_dropped` and
        // `carma_trace_dropped_total` must both see it
        if let Some(t) = self.trace.as_ref() {
            self.recorder.trace_dropped = t.dropped();
        }
        // the recorder's windowed utilization series as a first-class
        // artifact (`--timeseries-out`): plain running state, so it works
        // identically in stream (timeline = off) and full modes
        if let Some(path) = self.cfg.obs.timeseries_out.as_deref() {
            let mut text = String::from("window_end_s,smact,mem_gb\n");
            for &(t, smact, mem) in &self.recorder.util_windows {
                text.push_str(&format!("{t},{smact},{mem}\n"));
            }
            if let Err(e) = std::fs::write(path, text) {
                eprintln!("carma: --timeseries-out {path}: {e}");
            }
        }
        if let Some(path) = self.cfg.obs.metrics_out.as_deref() {
            if let Err(e) = std::fs::write(path, self.recorder.registry().render()) {
                eprintln!("carma: --metrics-out {path}: {e}");
            }
        }
        let engine_stats = self.engine.stats();
        let vs = self.view_stats;
        let profile = self.profiler.enabled().then(|| {
            // view-maintenance + arena counters ride the profile JSON
            // (stderr only): deterministic, but engine-descriptive — they
            // never belong in the byte-compared report
            let extra = vec![
                (
                    "views",
                    json::obj(vec![
                        ("snapshot_hits", json::num(vs.snapshot_hits as f64)),
                        ("full_rebuilds", json::num(vs.full_rebuilds as f64)),
                        ("delta_applies", json::num(vs.delta_applies as f64)),
                        ("servers_rebuilt", json::num(vs.servers_rebuilt as f64)),
                        ("servers_reused", json::num(vs.servers_reused as f64)),
                        ("cache_hit_rate", json::num(vs.hit_rate())),
                    ]),
                ),
                (
                    "arena",
                    json::obj(vec![
                        ("high_water", json::num(engine_stats.arena_high_water as f64)),
                        ("capacity", json::num(engine_stats.arena_capacity as f64)),
                        ("lane_reallocs", json::num(engine_stats.lane_reallocs as f64)),
                        ("arena_reallocs", json::num(engine_stats.arena_reallocs as f64)),
                    ]),
                ),
            ];
            self.profiler
                .to_json(self.processed, self.pool.as_ref().map(|p| p.occupancy()), extra)
        });
        RunOutcome {
            report: RunReport::from_recorder(label, &self.recorder),
            recorder: self.recorder,
            events: self.processed,
            profile,
            view_stats: vs,
            engine_stats,
        }
    }

    /// All work drained AND no further arrivals can come — the only state
    /// the run loops may exit in. `done_count == tasks.len()` alone is not
    /// enough in open-loop mode: the current task set can be fully done
    /// while the next arrival is still in flight.
    fn drained(&self) -> bool {
        !self.intake_open && self.done_count == self.tasks.len()
    }

    fn run_serial(&mut self) {
        loop {
            let t0 = self.profiler.start();
            let popped = self.engine.pop();
            self.profiler.add(Phase::FrontierDrain, t0);
            let Some((_, ev)) = popped else { break };
            self.count_event();
            let t1 = self.profiler.start();
            self.handle_event(ev);
            self.profiler.add(Phase::SerialCommit, t1);
            if self.cfg.engine.verify_views {
                self.verify_views();
            }
            if self.drained() {
                break;
            }
        }
    }

    /// The conservative parallel loop (DESIGN.md §10): drain the frontier of
    /// the current time quantum, speculatively plan the quantum's mapper
    /// work on the pool, then commit the events one by one in `(time, seq)`
    /// order exactly as the serial loop would.
    fn run_parallel(&mut self) {
        let mut buf: Vec<(f64, Event)> = Vec::new();
        'quantum: loop {
            let t0 = self.profiler.start();
            let drained = self.engine.pop_frontier(&mut buf);
            self.profiler.add(Phase::FrontierDrain, t0);
            if drained == 0 {
                break;
            }
            self.preplan_frontier(&buf);
            for (_, ev) in buf.drain(..) {
                self.count_event();
                let t1 = self.profiler.start();
                self.handle_event(ev);
                self.profiler.add(Phase::SerialCommit, t1);
                if self.cfg.engine.verify_views {
                    self.verify_views();
                }
                if self.drained() {
                    break 'quantum;
                }
            }
        }
    }

    fn count_event(&mut self) {
        self.processed += 1;
        assert!(
            self.processed < 200_000_000,
            "simulation did not converge (event storm)"
        );
    }

    fn handle_event(&mut self, ev: Event) {
        match ev {
            Event::TaskArrival(id) => self.on_arrival(id),
            Event::WindowDone(id) => self.on_window_done(id),
            Event::RetryMapping(shard) => self.on_retry(shard),
            Event::Ramp(id, stage) => self.on_ramp(id, stage),
            Event::Completion(id, v) => self.on_completion(id, v),
            Event::MonitorSample => self.on_monitor_sample(),
            Event::RecoveryDetect(id) => self.on_recovery_detect(id),
            Event::GangRetry => self.on_gang_retry(),
            Event::GangHoldExpire(id, epoch) => self.on_gang_hold_expire(id, epoch),
            Event::StealCheck(shard) => self.on_steal_check(shard),
            Event::ServiceArrival => self.on_service_arrival(),
            Event::FaultStrike(i) => self.on_fault_strike(i),
            Event::FaultRepair(i) => self.on_fault_repair(i),
        }
    }

    // -- state-epoch maintenance (DESIGN.md §17) -----------------------------
    //
    // Every mutation that can change a mapping decision's inputs bumps the
    // GLOBAL epoch — that is what invalidates speculative plans (a plan
    // reads every server's view, so any commit anywhere must discard it).
    // The PER-SERVER epochs are the delta-maintenance refinement: only the
    // servers a commit actually touched are marked, so the next snapshot
    // rebuilds exactly those views and carries the rest forward.

    /// Cluster-wide change (monitor samples: every window shifted).
    fn touch_all(&mut self) {
        self.state_epoch += 1;
        for e in &mut self.server_epochs {
            *e += 1;
        }
    }

    /// One server's state changed (its health, typically).
    fn touch_server(&mut self, s: usize) {
        self.state_epoch += 1;
        self.server_epochs[s] += 1;
    }

    /// The servers owning `gpus` changed — the common dispatch / release /
    /// ramp / hold shape. Repeat servers may be bumped more than once;
    /// staleness only needs the epoch to have *moved*.
    fn touch_gpus(&mut self, gpus: &[usize]) {
        self.state_epoch += 1;
        let mut last = usize::MAX;
        for &g in gpus {
            let s = self.server_of[g];
            if s != last {
                self.server_epochs[s] += 1;
                last = s;
            }
        }
    }

    /// Only the fabric changed (link degrade / restore): plans rank with
    /// fabric costs and must invalidate, but no server view embeds fabric
    /// state, so no rebuild is owed.
    fn touch_fabric(&mut self) {
        self.state_epoch += 1;
    }

    /// Emit one trace record at the current simulated time. The field
    /// closure only runs when tracing is on, so a disabled trace costs one
    /// branch per call site. Called exclusively from commit-side handlers
    /// (driver thread, `(time, seq)` order) — never from speculative plans —
    /// which is what makes the byte stream thread-count invariant.
    fn trace_event(&mut self, kind: &str, fields: impl FnOnce() -> Vec<(&'static str, Json)>) {
        if self.trace.is_none() {
            return;
        }
        let now = self.engine.now();
        if let Some(t) = self.trace.as_mut() {
            t.emit(now, kind, fields());
        }
    }

    // -- event handlers -----------------------------------------------------

    fn on_arrival(&mut self, id: TaskId) {
        let t = self.engine.now();
        self.recorder.on_arrival(id, t);
        self.tasks[id].state = RunState::Queued;
        let gang = self.tasks[id].spec.gang;
        let n_gpus = self.tasks[id].spec.n_gpus;
        self.trace_event("arrival", || {
            vec![
                ("task", json::num(id as f64)),
                ("gang", json::num(u64::from(gang) as f64)),
                // requested width: lets replay check gang atomicity
                // (dispatch width == request) from the trace alone
                ("n_gpus", json::num(n_gpus as f64)),
            ]
        });
        if gang {
            // distributed jobs bypass the shards: dedicated lane + the
            // all-or-nothing gang scheduler (DESIGN.md §11)
            self.recorder.on_gang_arrival(id);
            self.admission.submit_gang(id);
            self.trace_event("route", || {
                vec![("task", json::num(id as f64)), ("lane", json::s("gang"))]
            });
            self.feed_gang();
            return;
        }
        let loads = self.shard_loads();
        let home = self.fabric.home_server(id);
        let shard = self.admission.submit(id, &loads, home);
        self.recorder.on_assigned(id, shard);
        self.trace_event("route", || {
            vec![("task", json::num(id as f64)), ("shard", json::num(shard as f64))]
        });
        self.feed(shard);
        // the new backlog may give an idle sibling something to steal
        self.arm_steal_checks();
    }

    // -- open-loop service mode (DESIGN.md §13) ------------------------------

    /// Draw the next submission from the arrival generator and schedule its
    /// `ServiceArrival` on the global lane; close the intake when the
    /// generator's window ends. Exactly one arrival is in flight at a time,
    /// and the generator only advances here — on the driver thread, in
    /// commit order — so the stream is identical at every thread count.
    fn schedule_next_arrival(&mut self) {
        let Some(gen) = self.arrival_gen.as_mut() else {
            self.intake_open = false;
            return;
        };
        match gen.next_task() {
            Some(spec) => {
                let t = spec.arrival_s;
                self.pending_arrival = Some(spec);
                self.engine.schedule(t, Event::ServiceArrival);
            }
            None => self.intake_open = false,
        }
    }

    /// An open-loop arrival commits: materialize the pending spec as a new
    /// task, run it through bounded admission — shed at the door if every
    /// shard's queue sits at the cap, shed on per-shard backpressure if the
    /// routed shard is full — then draw the next arrival.
    fn on_service_arrival(&mut self) {
        let Some(spec) = self.pending_arrival.take() else {
            return;
        };
        let id = spec.id;
        debug_assert_eq!(id, self.tasks.len(), "arrival ids must be sequential");
        let remaining = spec.work_s;
        self.tasks.push(TaskRun {
            spec,
            state: RunState::Pending,
            gpus: Vec::new(),
            instances: Vec::new(),
            segs: Vec::new(),
            ramp: Vec::new(),
            next_ramp: 0,
            remaining_s: remaining,
            speed: 0.0,
            last_progress_t: 0.0,
            version: 0,
            in_recovery: false,
            admitted_est_gb: None,
            pinned: false,
            fault_relaunches: 0,
            last_fault: None,
        });
        self.recorder.ensure_task(id);
        let t = self.engine.now();
        self.recorder.on_arrival(id, t);
        self.tasks[id].state = RunState::Queued;
        let gang = self.tasks[id].spec.gang;
        let n_gpus = self.tasks[id].spec.n_gpus;
        self.trace_event("arrival", || {
            vec![
                ("task", json::num(id as f64)),
                ("gang", json::num(u64::from(gang) as f64)),
                ("n_gpus", json::num(n_gpus as f64)),
            ]
        });
        if gang {
            // the generator emits singletons only, but route a gang the
            // closed-loop way if one ever shows up (gangs are never shed:
            // the bounded queues guard the shard mappers, not the gang lane)
            self.recorder.on_gang_arrival(id);
            self.admission.submit_gang(id);
            self.trace_event("route", || {
                vec![("task", json::num(id as f64)), ("lane", json::s("gang"))]
            });
            self.feed_gang();
            self.schedule_next_arrival();
            return;
        }
        if self.admission.saturated() {
            // cluster-wide ceiling: every shard's queue is at the cap —
            // shed at the door, before routing
            self.shed(id, true);
        } else {
            let loads = self.shard_loads();
            let home = self.fabric.home_server(id);
            match self.admission.try_submit(id, &loads, home) {
                Ok(shard) => {
                    self.recorder.on_assigned(id, shard);
                    self.trace_event("route", || {
                        vec![("task", json::num(id as f64)), ("shard", json::num(shard as f64))]
                    });
                    self.feed(shard);
                    self.arm_steal_checks();
                }
                // per-shard backpressure: the routed shard is full (routing
                // is not retried — sticky/locality semantics stay intact)
                Err(_) => self.shed(id, false),
            }
        }
        self.schedule_next_arrival();
    }

    /// Deterministic load shedding: the newest arrival is the one dropped,
    /// terminally. Sheds count toward `done_count` so drain/termination
    /// accounting holds.
    fn shed(&mut self, id: TaskId, at_door: bool) {
        self.tasks[id].state = RunState::Shed;
        self.recorder.on_shed(id, self.engine.now(), at_door);
        self.trace_event("shed", || {
            vec![
                ("task", json::num(id as f64)),
                ("at_door", json::num(u64::from(at_door) as f64)),
            ]
        });
        self.done_count += 1;
    }

    /// Per-shard load (queued + under observation) for least-loaded routing.
    fn shard_loads(&self) -> Vec<usize> {
        self.mappers
            .iter()
            .enumerate()
            .map(|(s, m)| self.admission.queue_len(s) + usize::from(m.selected.is_some()))
            .collect()
    }

    /// Hand shard `shard`'s mapper its next task, if it is idle and one is
    /// queued (the sharded generalization of the serial "select next").
    fn feed(&mut self, shard: usize) {
        if self.mappers[shard].selected.is_some() {
            return;
        }
        if let Some((id, _rec)) = self.admission.pop_next(shard) {
            self.mappers[shard].select(id);
            self.tasks[id].state = RunState::Selected;
            // queue → observation-window boundary: the span reconstruction
            // splits queueing delay from window wait on this record
            self.trace_event("select", || {
                vec![("task", json::num(id as f64)), ("shard", json::num(shard as f64))]
            });
            // observe the GPUs for one window before deciding (paper §4.1)
            self.engine
                .schedule_in_on(lane(shard), self.cfg.monitor.window_s, Event::WindowDone(id));
        } else {
            // the shard just went idle with an empty queue: if a sibling
            // has backlog, start the one-window starvation probe (§12)
            self.arm_steal_checks();
        }
    }

    fn on_window_done(&mut self, id: TaskId) {
        if self.tasks[id].spec.gang {
            if self.gang_lane.active == Some(id) {
                self.gang_lane.window_done = true;
                self.attempt_gang();
            }
            return;
        }
        let Some(shard) = self.admission.shard_of(id) else {
            return;
        };
        if self.mappers[shard].selected != Some(id) {
            return; // stale (task got re-queued by recovery etc.)
        }
        self.mappers[shard].window_done = true;
        self.attempt_map(shard);
    }

    fn on_retry(&mut self, shard: usize) {
        self.mappers[shard].retry_scheduled = false;
        if self.mappers[shard].ready() {
            self.attempt_map(shard);
        }
    }

    // -- bounded work stealing (DESIGN.md §12) -------------------------------

    /// Arm a StealCheck one observation window out for every shard that is
    /// idle with an empty queue while a sibling has stealable backlog. At
    /// most one probe per shard is in flight; probes ride the shard's own
    /// event lane, so stealing commits in `(time, seq)` order like every
    /// other decision — determinism by construction.
    fn arm_steal_checks(&mut self) {
        if !self.cfg.coordinator.steal || self.mappers.len() < 2 {
            return;
        }
        for shard in 0..self.mappers.len() {
            if self.mappers[shard].selected.is_some()
                || self.mappers[shard].steal_scheduled
                || self.admission.queue_len(shard) > 0
                || !self.admission.has_steal_victim(shard)
            {
                continue;
            }
            self.mappers[shard].steal_scheduled = true;
            self.engine.schedule_in_on(
                lane(shard),
                self.cfg.monitor.window_s,
                Event::StealCheck(shard),
            );
        }
    }

    /// The probe fired: if the shard is STILL idle-empty — it starved a
    /// full observation window while work existed elsewhere — steal one
    /// task from the longest sibling queue's tail and start observing it.
    /// A shard that got work through the normal path meanwhile just lets
    /// the probe lapse (re-armed on the next backlog growth).
    fn on_steal_check(&mut self, shard: usize) {
        self.mappers[shard].steal_scheduled = false;
        if self.mappers[shard].selected.is_some() {
            return;
        }
        if self.admission.queue_len(shard) > 0 {
            self.feed(shard);
            return;
        }
        let Some(victim) = self.admission.steal_victim(shard) else {
            return;
        };
        let Some(id) = self.admission.steal_tail(victim, shard) else {
            return;
        };
        self.recorder.on_stolen(id, shard);
        self.trace_event("steal", || {
            vec![
                ("task", json::num(id as f64)),
                ("thief", json::num(shard as f64)),
                ("victim", json::num(victim as f64)),
            ]
        });
        self.mappers[shard].select(id);
        self.tasks[id].state = RunState::Selected;
        self.trace_event("select", || {
            vec![("task", json::num(id as f64)), ("shard", json::num(shard as f64))]
        });
        self.engine
            .schedule_in_on(lane(shard), self.cfg.monitor.window_s, Event::WindowDone(id));
    }

    fn schedule_retry(&mut self, shard: usize) {
        if !self.mappers[shard].retry_scheduled {
            self.mappers[shard].retry_scheduled = true;
            self.engine
                .schedule_in_on(lane(shard), RETRY_S, Event::RetryMapping(shard));
        }
    }

    // -- gang lane (DESIGN.md §11) -------------------------------------------

    /// Promote the next queued gang to the lane head, if the lane is idle.
    /// Like the shard mappers, a gang is observed for one monitoring window
    /// before its first placement attempt (paper §4.1).
    fn feed_gang(&mut self) {
        if self.gang_lane.active.is_some() {
            return;
        }
        if let Some((id, _rec)) = self.admission.pop_next_gang() {
            self.gang_lane.select(id);
            self.tasks[id].state = RunState::Selected;
            self.trace_event("select", || {
                vec![("task", json::num(id as f64)), ("lane", json::s("gang"))]
            });
            self.engine
                .schedule_in(self.cfg.monitor.window_s, Event::WindowDone(id));
        }
    }

    /// Resources changed (completion / OOM release): give the gang lane the
    /// first claim on them, before the singleton mappers sweep.
    fn kick_gang(&mut self) {
        if self.gang_lane.active.is_none() {
            self.feed_gang();
        } else if self.gang_lane.ready() {
            self.attempt_gang();
        }
    }

    fn schedule_gang_retry(&mut self) {
        if !self.gang_lane.retry_scheduled {
            self.gang_lane.retry_scheduled = true;
            self.engine
                .schedule_in(self.cfg.gang.retry_s, Event::GangRetry);
        }
    }

    fn on_gang_retry(&mut self) {
        self.gang_lane.retry_scheduled = false;
        if self.gang_lane.ready() {
            self.attempt_gang();
        }
    }

    /// One all-or-nothing placement attempt for the lane-head gang: place
    /// the full worker set atomically, or extend the partial holds and keep
    /// waiting. Runs entirely on the driver thread in event order, so the
    /// byte-determinism guarantee (§10) holds untouched.
    fn attempt_gang(&mut self) {
        let Some(id) = self.gang_lane.active else { return };
        if !self.gang_lane.window_done {
            return;
        }
        let (req, demoted) = self.mapping_request(id);
        if let Err(why) = self.admission.admissible(req.n_gpus, req.demand_gb, true) {
            self.fail_task(id, why);
            return;
        }
        let views = self.snapshot();
        let plan = gang::plan_gang(
            &views,
            &self.fabric,
            &self.book,
            &self.cfg.power,
            req,
            self.preconditions(),
            id,
        );
        drop(views);
        match plan {
            GangPlan::Place(gpus) => {
                debug_assert_eq!(gpus.len(), req.n_gpus, "all-or-nothing violated");
                let spanned = self.fabric.servers_spanned(&gpus);
                let min_span = self.min_span(req.n_gpus);
                let cost = self.fabric.gang_cost(&gpus);
                let freed = self.book.release_all(id);
                if !freed.is_empty() {
                    self.touch_gpus(&freed);
                }
                self.recorder
                    .on_gang_dispatch(id, gpus.len(), req.n_gpus, spanned, min_span, cost);
                self.trace_event("gang_dispatch", || {
                    vec![
                        ("task", json::num(id as f64)),
                        ("gpus", json::num(gpus.len() as f64)),
                        ("servers", json::num(spanned as f64)),
                        ("cost", json::num(cost)),
                    ]
                });
                self.tasks[id].admitted_est_gb = req.demand_gb;
                self.tasks[id].pinned = demoted;
                // clear BEFORE dispatch (same re-entrancy rule as the shard
                // mappers): a first-ramp OOM inside dispatch reaches the
                // kick path, which must not re-enter the in-flight gang
                self.gang_lane.clear();
                if spanned > 1 {
                    let membw = self.tasks[id].spec.membw;
                    self.fabric.occupy_links(&gpus, membw);
                }
                let n = gpus.len();
                self.dispatch(id, Placement { gpus, instances: vec![None; n] });
                self.feed_gang();
            }
            GangPlan::Hold(new_holds) => {
                if !new_holds.is_empty() {
                    self.touch_gpus(&new_holds);
                    self.recorder.on_gang_holds(new_holds.len() as u64);
                    let held: Vec<Json> =
                        new_holds.iter().map(|&g| json::num(g as f64)).collect();
                    self.trace_event("gang_hold", || {
                        vec![
                            ("task", json::num(id as f64)),
                            ("holds", json::num(new_holds.len() as f64)),
                            // the held device ids: replay tracks the
                            // reservation set to prove no foreign dispatch
                            // ever lands on a held GPU
                            ("gpus", json::arr(held)),
                        ]
                    });
                    for &g in &new_holds {
                        self.book.hold(g, id);
                    }
                    // every acquisition re-arms a fresh TTL under a new
                    // epoch — progress IS the lease renewal; the expiry
                    // armed for the previous epoch becomes a dropped stale.
                    // Once the teardown budget is spent the holds are
                    // sticky: no further expiry is armed.
                    self.gang_lane.hold_epoch += 1;
                    if self.gang_lane.expiries < self.cfg.gang.max_hold_expiries {
                        let epoch = self.gang_lane.hold_epoch;
                        self.engine
                            .schedule_in(self.cfg.gang.hold_ttl_s, Event::GangHoldExpire(id, epoch));
                    }
                }
                self.schedule_gang_retry();
            }
        }
    }

    /// Fewest servers a `n_gpus`-wide gang could possibly span (for the
    /// fragmentation counter): the packing bound over the largest server.
    fn min_span(&self, n_gpus: usize) -> usize {
        let biggest = self
            .cluster
            .topo
            .servers
            .iter()
            .map(|s| s.cfg.n_gpus)
            .max()
            .unwrap_or(1)
            .max(1);
        n_gpus.div_ceil(biggest)
    }

    /// A partial hold reached its TTL with no progress since it was armed
    /// (DESIGN.md §11) — acquisitions bump the epoch, so an expiry that
    /// still matches means nothing new was claimed for a full TTL. Tear
    /// the holds down so the backfill pool gets its GPUs back. The
    /// teardown budget is never refunded; once spent, no further expiry is
    /// armed and the holds are sticky (the anti-starvation floor under
    /// continuous singleton arrivals).
    fn on_gang_hold_expire(&mut self, id: TaskId, epoch: u64) {
        if self.gang_lane.active != Some(id) || self.gang_lane.hold_epoch != epoch {
            return; // stale: re-acquisitions bumped the epoch, or dispatched
        }
        self.gang_lane.expiries += 1;
        let freed = self.book.release_all(id);
        if !freed.is_empty() {
            self.touch_gpus(&freed);
            self.recorder.on_gang_holds_expired(freed.len() as u64);
            let freed_ids: Vec<Json> = freed.iter().map(|&g| json::num(g as f64)).collect();
            self.trace_event("gang_hold_expire", || {
                vec![
                    ("task", json::num(id as f64)),
                    ("freed", json::num(freed.len() as f64)),
                    ("gpus", json::arr(freed_ids)),
                ]
            });
            // the released devices are fair game for waiting singletons
            self.kick_mappers();
        }
        self.schedule_gang_retry();
    }

    /// Running gang tasks' GPUs, excluding `except` — the devices whose
    /// speeds depend on shared NIC links and must be recomputed when
    /// fabric occupancy changes.
    fn other_gang_gpus(&self, except: TaskId) -> Vec<usize> {
        let mut gpus = Vec::new();
        for t in &self.tasks {
            if t.spec.id != except && t.spec.gang && t.state == RunState::Running {
                gpus.extend(t.gpus.iter().copied());
            }
        }
        gpus
    }

    /// Re-attempt every shard whose selected task already finished its
    /// window — resources just changed (completion / OOM release).
    ///
    /// Parallel mode plans all ready shards in one pool round, commits in
    /// ascending shard order, and re-plans the remainder whenever a commit
    /// dispatched something (moving the cluster under the open plans). The
    /// commit sequence is exactly the serial sweep's, so outcomes are
    /// bit-identical; only the redundant scans are elided.
    fn kick_mappers(&mut self) {
        let k = self.mappers.len();
        if k == 1 {
            // serial-coordinator fast path: no round bookkeeping to allocate
            if self.mappers[0].ready() {
                self.attempt_map(0);
            }
            return;
        }
        let mut attempted = vec![false; k];
        loop {
            let pending: Vec<usize> = (0..k)
                .filter(|&s| !attempted[s] && self.mappers[s].ready())
                .collect();
            if pending.is_empty() {
                return;
            }
            self.preplan(&pending);
            let epoch0 = self.state_epoch;
            let mut invalidated = false;
            for &s in &pending {
                attempted[s] = true;
                // a nested kick (first-ramp OOM inside a dispatch) may have
                // already dispatched or failed this shard's task
                if !self.mappers[s].ready() {
                    continue;
                }
                self.attempt_map(s);
                if self.state_epoch != epoch0 {
                    invalidated = true;
                    break;
                }
            }
            if !invalidated {
                return;
            }
        }
    }

    /// Speculatively plan the named shards' mapping scans on the worker
    /// pool against the current snapshot. Pure fan-out: plans are only
    /// consumed by `attempt_map` after validating that the state they were
    /// computed against is still live.
    fn preplan(&mut self, shards: &[usize]) {
        if self.pool.is_none() || shards.len() < 2 {
            return;
        }
        let views = self.snapshot();
        let jobs: Vec<PlanJob> = shards.iter().filter_map(|&s| self.plan_job(s)).collect();
        if jobs.len() < 2 {
            return;
        }
        let epoch = self.state_epoch;
        let quantum = self.engine.quantum();
        let policy = self.cfg.policy;
        let pre = self.preconditions();
        let t0 = self.profiler.start();
        let plans: Vec<MapPlan> = {
            let pool = self.pool.as_ref().expect("pool checked above");
            let views_ref: &[ServerView] = &views;
            let jobs_ref = &jobs;
            let fabric = self.placement_fabric();
            pool.map(jobs_ref.len(), &|i| {
                compute_plan(views_ref, policy, pre, fabric, &jobs_ref[i], epoch, quantum)
            })
        };
        self.profiler.add(Phase::SpeculativePlan, t0);
        for (job, plan) in jobs.iter().zip(plans) {
            self.mappers[job.shard].plan = Some(plan);
        }
    }

    /// Plan ahead for a whole drained time quantum: shards whose
    /// WindowDone/RetryMapping events sit in the frontier will attempt a
    /// mapping when their event commits — scan for them all at once.
    fn preplan_frontier(&mut self, batch: &[(f64, Event)]) {
        if self.pool.is_none() || batch.len() < 2 {
            return;
        }
        let mut shards: Vec<usize> = Vec::new();
        for (_, ev) in batch {
            let s = match ev {
                Event::WindowDone(id) => match self.admission.shard_of(*id) {
                    Some(s) if self.mappers[s].selected == Some(*id) => s,
                    _ => continue,
                },
                Event::RetryMapping(s) if self.mappers[*s].ready() => *s,
                _ => continue,
            };
            if !shards.contains(&s) {
                shards.push(s);
            }
        }
        self.preplan(&shards);
    }

    fn preconditions(&self) -> Preconditions {
        Preconditions {
            smact_cap: self.cfg.smact_cap,
            min_free_gb: self.cfg.min_free_gb,
        }
    }

    /// The fabric handle the singleton placement core ranks with —
    /// `None` under `--fabric-aware-singletons off`, which byte-reproduces
    /// the island-blind seed pipeline (DESIGN.md §12).
    fn placement_fabric(&self) -> Option<&Fabric> {
        self.cfg
            .placement
            .fabric_aware_singletons
            .then_some(&self.fabric)
    }

    /// Demand + placement-mode derivation for one task (paper §4.1/§5.4):
    /// estimator + safety margin; estimates at/above every server's GPU
    /// capacity degrade to exclusive placement (the estimator "takes the
    /// collocation potential away"); the final permitted recovery retry is
    /// demoted to a *pinned* exclusive slot (ROADMAP "Adaptive recovery").
    /// Shared verbatim by the serial and speculative paths — one source of
    /// truth, so the two cannot drift.
    fn mapping_request(&self, id: TaskId) -> (MappingRequest, bool) {
        let crashes = self.recorder.oom_crashes_of(id);
        let spec = &self.tasks[id].spec;
        let max_mem = self.cluster.topo.max_server_mem_gb();
        let raw_est = self.estimator.estimate_gb(spec);
        let mut demand = raw_est.map(|e| e + self.cfg.safety_margin_gb);
        // adaptive recovery: early retries re-enter normal collocation-aware
        // mapping; the FINAL permitted retry is demoted to a pinned
        // exclusive slot, so it cannot be crashed again
        let demoted = self.tasks[id].in_recovery && crashes >= MAX_OOM_RETRIES;
        let mut force_exclusive = demoted;
        if let Some(d) = demand {
            if d >= max_mem {
                demand = Some(max_mem);
                force_exclusive = true;
            }
        }
        // GPUMemNet's class grid tops out at the 40 GB training capacity
        // (DESIGN.md §5); on servers with more memory a *saturated* raw
        // estimate means "at least this much", not a point estimate —
        // degrade to exclusive instead of collocating on it (margin
        // excluded: a 39 GB point estimate + 2 GB margin is not saturation)
        if self.cfg.estimator == EstimatorKind::GpuMemNet
            && raw_est.is_some_and(|e| e >= memsim::GPU_CAPACITY_GB)
        {
            force_exclusive = true;
        }
        (
            MappingRequest {
                n_gpus: spec.n_gpus,
                demand_gb: demand,
                exclusive: force_exclusive,
            },
            demoted,
        )
    }

    /// Everything one shard's mapping scan needs besides the snapshot.
    /// Runs on the driver thread (the estimator holds a `RefCell` cache).
    fn plan_job(&self, shard: usize) -> Option<PlanJob> {
        let id = self.mappers[shard].selected?;
        let (req, demoted) = self.mapping_request(id);
        // permanently unschedulable? — fail fast instead of retrying
        // forever. Admission owns the static ceilings (capacity accounting
        // across servers, power-envelope-dead servers excluded): a demand
        // larger than every schedulable target, or a GPU count no single
        // admissible server owns (non-gang multi-GPU tasks never span
        // servers), can never be placed no matter how long the task waits.
        let admissible = self.admission.admissible(req.n_gpus, req.demand_gb, false);
        Some(PlanJob {
            shard,
            task: id,
            req,
            demoted,
            cursor_in: self.mappers[shard].rr_cursor,
            admissible,
        })
    }

    /// Try to map shard `shard`'s selected task: consume a still-valid
    /// speculative plan, or compute the decision inline against the shared
    /// snapshot; then commit — dispatch + feed the shard its next task,
    /// schedule a retry, or fail the task fast.
    fn attempt_map(&mut self, shard: usize) {
        let Some(id) = self.mappers[shard].selected else { return };
        let epoch = self.state_epoch;
        let quantum = self.engine.quantum();
        let plan = match self.mappers[shard].take_valid_plan(epoch, quantum, id) {
            Some(p) => p,
            None => {
                let job = self.plan_job(shard).expect("selected task plans");
                let views = self.snapshot();
                compute_plan(
                    &views,
                    self.cfg.policy,
                    self.preconditions(),
                    self.placement_fabric(),
                    &job,
                    epoch,
                    quantum,
                )
            }
        };
        // decision provenance (DESIGN.md §14): the explanation rides the
        // committed plan, so discarded speculative scans never count
        let outcome_kind = match &plan.outcome {
            PlanOutcome::Place(..) => DecisionOutcome::Placed,
            PlanOutcome::NoFit => DecisionOutcome::NoFit,
            PlanOutcome::Inadmissible(_) => DecisionOutcome::Inadmissible,
        };
        self.recorder.on_decision(outcome_kind, &plan.explain);
        if self.explain_sample > 0
            && (self.recorder.decisions.decisions - 1) % self.explain_sample == 0
        {
            let ex = plan.explain.clone();
            let outcome_name = match outcome_kind {
                DecisionOutcome::Placed => "place",
                DecisionOutcome::NoFit => "no_fit",
                DecisionOutcome::Inadmissible => "inadmissible",
            };
            self.trace_event("decision", || {
                let mut f = vec![
                    ("task", json::num(id as f64)),
                    ("shard", json::num(shard as f64)),
                    ("outcome", json::s(outcome_name)),
                    ("servers_admitted", json::num(ex.servers_admitted as f64)),
                    ("servers_rejected", json::num(ex.servers_rejected as f64)),
                    ("gpus_eligible", json::num(ex.gpus_eligible as f64)),
                    ("candidates", json::num(ex.candidates as f64)),
                    (
                        "rejects",
                        json::obj(
                            RejectReason::ALL
                                .iter()
                                .map(|r| (r.name(), json::num(ex.rejects[r.index()] as f64)))
                                .collect(),
                        ),
                    ),
                ];
                if let Some(w) = &ex.winner {
                    f.push((
                        "winner",
                        json::obj(vec![
                            ("fabric_cost", json::num(w.fabric_cost)),
                            ("policy", json::num(w.policy)),
                            ("nic_load", json::num(w.nic_load)),
                        ]),
                    ));
                }
                f
            });
        }
        match plan.outcome {
            PlanOutcome::Inadmissible(why) => self.fail_task(id, why),
            PlanOutcome::NoFit => self.schedule_retry(shard),
            PlanOutcome::Place(p, cursor_out) => {
                self.mappers[shard].rr_cursor = cursor_out;
                self.tasks[id].admitted_est_gb = plan.demand_gb;
                self.tasks[id].pinned = plan.demoted;
                // achieved interconnect cost of the singleton placement —
                // recorded in BOTH island-blind and island-aware modes, so
                // `repro placement_scale` can compare them head to head
                self.recorder.on_singleton_dispatch(
                    id,
                    p.gpus.len(),
                    self.fabric.set_cost(&p.gpus),
                    self.fabric.islands_spanned(&p.gpus),
                );
                // clear BEFORE dispatch: a first-ramp OOM inside dispatch
                // reaches kick_mappers, which must not re-enter this shard
                // for the task it is mid-dispatching (clear emits no events,
                // so the schedule order is unchanged)
                self.mappers[shard].clear();
                self.dispatch(id, p);
                self.feed(shard);
            }
        }
    }

    fn fail_task(&mut self, id: TaskId, why: &str) {
        eprintln!("carma: task {} failed permanently: {why}", self.tasks[id].spec.label());
        self.tasks[id].state = RunState::Failed;
        self.recorder.on_failed(id);
        self.trace_event("fail", || {
            vec![("task", json::num(id as f64)), ("why", json::s(why))]
        });
        self.done_count += 1;
        if self.tasks[id].spec.gang {
            if self.gang_lane.active == Some(id) {
                let freed = self.book.release_all(id);
                if !freed.is_empty() {
                    self.touch_gpus(&freed);
                }
                self.gang_lane.clear();
                self.feed_gang();
            }
            return;
        }
        if let Some(shard) = self.admission.shard_of(id) {
            if self.mappers[shard].selected == Some(id) {
                self.mappers[shard].clear();
                self.feed(shard);
            }
        }
    }

    /// Build (or reuse) the snapshot of per-server power and per-GPU
    /// monitor views the mapping scans read, maintained *incrementally*
    /// (DESIGN.md §17): only servers whose epoch moved since the cached
    /// build — plus, across a quantum boundary, the power-capped servers
    /// whose instantaneous draw reads the clock — are rebuilt; the rest
    /// carry forward by `Arc` bump. With `engine.delta_views` off, any
    /// change rebuilds everything (the PR-3 baseline, kept as the
    /// perf-comparison and bisection arm). With a pool, the per-server
    /// construction — the O(GPUs) hot path — fans out.
    fn snapshot(&mut self) -> Arc<Vec<ServerView>> {
        let now = self.engine.now();
        let quantum = self.engine.quantum();
        let n_servers = self.cluster.servers.len();
        let stale: Vec<usize> = match &self.views_cache {
            None => (0..n_servers).collect(),
            Some(c) if self.cfg.engine.delta_views => (0..n_servers)
                .filter(|&s| {
                    c.epochs[s] != self.server_epochs[s]
                        || (c.quantum != quantum
                            && self.cluster.topo.servers[s].power_cap_w.is_some())
                })
                .collect(),
            Some(c) if c.epoch == self.state_epoch && c.quantum == quantum => Vec::new(),
            Some(_) => (0..n_servers).collect(),
        };
        if stale.is_empty() {
            self.view_stats.snapshot_hits += 1;
            return self.views_cache.as_ref().expect("hit implies a cache").views.clone();
        }
        let t0 = self.profiler.start();
        let fresh: Vec<ServerView> = {
            let cluster = &self.cluster;
            let monitor = &self.monitor;
            let tasks = &self.tasks;
            let cfg = &self.cfg;
            let book = &self.book;
            let health = &self.health;
            let stale_ref = &stale;
            match self.pool.as_ref() {
                Some(pool) if stale.len() >= 2 => pool.map(stale.len(), &|i| {
                    build_server_view(cluster, monitor, tasks, cfg, book, health, stale_ref[i], now)
                }),
                _ => stale
                    .iter()
                    .map(|&s| build_server_view(cluster, monitor, tasks, cfg, book, health, s, now))
                    .collect(),
            }
        };
        let views = if stale.len() == n_servers {
            self.view_stats.full_rebuilds += 1;
            fresh
        } else {
            // splice the fresh views into the carried-forward vector: each
            // reused `ServerView` clone is an `Arc` refcount bump, not a
            // per-GPU copy
            self.view_stats.delta_applies += 1;
            let cache = self.views_cache.as_ref().expect("partial rebuild implies a cache");
            let mut views: Vec<ServerView> = cache.views.as_ref().clone();
            for (v, &s) in fresh.into_iter().zip(&stale) {
                views[s] = v;
            }
            views
        };
        self.view_stats.servers_rebuilt += stale.len() as u64;
        self.view_stats.servers_reused += (n_servers - stale.len()) as u64;
        self.profiler.add(Phase::SnapshotBuild, t0);
        let views = Arc::new(views);
        self.views_cache = Some(ViewsCache {
            epoch: self.state_epoch,
            epochs: self.server_epochs.clone(),
            quantum,
            views: views.clone(),
        });
        views
    }

    /// Differential paranoia hook (`cfg.engine.verify_views`, the property
    /// suite's backbone): rebuild every server view from scratch and
    /// compare it field-for-field — floats by bits — against what
    /// `snapshot()` serves. Any divergence means a `touch_*` call site
    /// under-classified a commit; panic with enough context to find it.
    /// Pure reads plus a deterministic cache fill, so enabling it cannot
    /// change a run's schedule or artifacts.
    fn verify_views(&mut self) {
        let views = self.snapshot();
        let now = self.engine.now();
        for s in 0..self.cluster.servers.len() {
            let fresh = build_server_view(
                &self.cluster,
                &self.monitor,
                &self.tasks,
                &self.cfg,
                &self.book,
                &self.health,
                s,
                now,
            );
            assert_view_eq(&views[s], &fresh, s, now);
        }
        self.view_stats.verified += 1;
    }

    fn dispatch(&mut self, id: TaskId, p: Placement) {
        // residency, reservations and pinning are about to change — on
        // exactly the target devices' servers
        self.touch_gpus(&p.gpus);
        let now = self.engine.now();
        self.recorder.on_dispatch(id, now);
        self.trace_event("dispatch", || {
            vec![
                ("task", json::num(id as f64)),
                (
                    "gpus",
                    json::arr(p.gpus.iter().map(|&g| json::num(g as f64)).collect()),
                ),
            ]
        });

        // staircase memory ramp: memsim's segment shape scaled so the total
        // equals the task's true peak memory (paper Table 3 ground truth)
        let (ramp, smact, membw, spec_id);
        {
            let spec = &self.tasks[id].spec;
            let shape = memsim::ramp_segments_bytes(&spec.features);
            let total: f64 = shape.iter().sum();
            let scale = (spec.mem_gb * GIB) / total.max(1.0);
            ramp = shape.into_iter().map(|b| b * scale).collect::<Vec<f64>>();
            smact = spec.smact;
            membw = spec.membw;
            spec_id = spec.id;
        }
        debug_assert_eq!(spec_id, id);

        let task = &mut self.tasks[id];
        task.state = RunState::Running;
        task.gpus = p.gpus.clone();
        task.instances = p.instances.clone();
        task.segs = vec![Vec::new(); p.gpus.len()];
        task.ramp = ramp;
        task.next_ramp = 0;
        task.last_progress_t = now;

        for (k, &g) in p.gpus.iter().enumerate() {
            self.cluster.gpu_mut(g).add_resident(ResidentTask {
                task: id,
                smact,
                membw,
                instance: p.instances[k].unwrap_or(0),
                dispatched_at: now,
            });
        }
        // first allocation (CUDA context) happens immediately
        self.on_ramp(id, 0);
        if self.tasks[id].state == RunState::Running {
            let mut gpus = self.tasks[id].gpus.clone();
            if self.tasks[id].spec.gang {
                // a spanning gang's NIC load slows other gangs on shared
                // uplinks — recompute them in the same sweep
                gpus.extend(self.other_gang_gpus(id));
            }
            self.recompute_speeds(&gpus);
        }
    }

    /// Allocate the next ramp segment on every occupied GPU. Any failure =
    /// OOM for THIS task (the subsequently-arriving one), paper §1.
    fn on_ramp(&mut self, id: TaskId, stage: u8) {
        if self.tasks[id].state != RunState::Running || self.tasks[id].next_ramp != stage as usize {
            return; // stale ramp event (task crashed / completed / restarted)
        }
        let seg_bytes = match self.tasks[id].ramp.get(stage as usize) {
            Some(&b) => b,
            None => return,
        };
        let gpus = self.tasks[id].gpus.clone();
        // free memory is about to shrink (or the task to crash)
        self.touch_gpus(&gpus);
        let seg_mib = (seg_bytes / (1024.0 * 1024.0)).ceil().max(1.0) as u64;
        for (k, &g) in gpus.iter().enumerate() {
            // page-backed scatter allocation: a slab may span a few holes,
            // but shredded-beyond-repair free memory still OOMs (§4.2)
            match self.cluster.gpu_mut(g).alloc.alloc_scatter(seg_mib, 4) {
                Some(segs) => self.tasks[id].segs[k].extend(segs),
                None => {
                    self.oom(id);
                    return;
                }
            }
        }
        self.tasks[id].next_ramp += 1;
        if self.tasks[id].next_ramp < self.tasks[id].ramp.len() {
            let l = self.task_lane(id);
            self.engine
                .schedule_in_on(l, RAMP_INTERVAL_S, Event::Ramp(id, stage + 1));
        }
    }

    /// Event lane of the shard owning `id` (admission routing is sticky, so
    /// every shard-admitted task has one). Gang-lane tasks live on the
    /// global lane 0 — the merge order is a total order either way (§9).
    fn task_lane(&self, id: TaskId) -> usize {
        match self.admission.shard_of(id) {
            Some(s) => lane(s),
            None => 0,
        }
    }

    fn oom(&mut self, id: TaskId) {
        self.recorder.on_oom(id);
        self.release(id);
        let task = &mut self.tasks[id];
        task.state = RunState::Crashed;
        task.version += 1; // invalidate any scheduled completion
        task.remaining_s = task.spec.work_s; // restart from scratch
        task.in_recovery = true;
        let crashes = self.recorder.oom_crashes_of(id);
        self.trace_event("oom", || {
            vec![
                ("task", json::num(id as f64)),
                ("crashes", json::num(crashes as f64)),
            ]
        });
        if crashes > MAX_OOM_RETRIES {
            self.fail_task(id, "exceeded OOM retry budget");
            // the failed task's memory was released above — the gang lane
            // and waiting mappers get the same immediate kick the
            // recoverable path gives them
            self.kick_gang();
            self.kick_mappers();
            return;
        }
        // adaptive backoff (ROADMAP "Adaptive recovery"): a repeat offender
        // waits 2× longer before each re-queue — 5 s, 10 s, 20 s — giving
        // the GPUs it keeps crashing on time to drain before the final,
        // demoted-to-exclusive attempt
        let backoff = RECOVERY_DETECT_S * (1u64 << (crashes - 1).min(6)) as f64;
        self.engine.schedule_in(backoff, Event::RecoveryDetect(id));
        // freed memory may unblock the gang lane or a waiting mapper
        self.kick_gang();
        self.kick_mappers();
    }

    fn on_recovery_detect(&mut self, id: TaskId) {
        if self.tasks[id].state != RunState::Crashed {
            return;
        }
        self.tasks[id].state = RunState::Queued;
        // a fault-killed task re-queues as a `relaunch` (cause attached);
        // the OOM path keeps its original `recovery` record
        match self.tasks[id].last_fault.take() {
            Some(kind) => {
                self.recorder.on_fault_relaunch();
                self.trace_event("relaunch", || {
                    vec![
                        ("task", json::num(id as f64)),
                        ("cause", json::s(kind.name())),
                    ]
                });
            }
            None => self.trace_event("recovery", || vec![("task", json::num(id as f64))]),
        }
        if self.tasks[id].spec.gang {
            self.admission.submit_gang_recovery(id);
            self.feed_gang();
            return;
        }
        let shard = self.admission.submit_recovery(id);
        self.feed(shard);
    }

    // -- fault injection + failure-domain recovery (DESIGN.md §15) -----------

    /// Global GPU ids owned by `server`.
    fn server_gpus(&self, server: usize) -> Vec<usize> {
        let s = &self.cluster.topo.servers[server];
        (s.gpu_offset..s.gpu_offset + s.cfg.n_gpus).collect()
    }

    /// Running tasks resident on any of `gpus`, deduped ascending — the
    /// deterministic kill order for a domain loss.
    fn residents_of(&self, gpus: &[usize]) -> Vec<TaskId> {
        let mut ids: Vec<TaskId> = gpus
            .iter()
            .flat_map(|&g| self.cluster.gpu(g).resident.iter().map(|r| r.task))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Devices whose speeds may shift when server `server`'s uplinks
    /// change: its own GPUs plus every running gang's (spanning gangs pay
    /// the degraded-link factor wherever their members sit).
    fn link_affected_gpus(&self, server: usize) -> Vec<usize> {
        let mut gpus = self.server_gpus(server);
        gpus.extend(self.other_gang_gpus(usize::MAX));
        gpus
    }

    fn trace_quarantine(&mut self, domain: &'static str, target: usize, state: &'static str) {
        self.trace_event("quarantine", || {
            vec![
                ("domain", json::s(domain)),
                ("target", json::num(target as f64)),
                ("state", json::s(state)),
            ]
        });
    }

    /// Tear down every gang reservation held by the named holders: a gang
    /// cannot dispatch onto dead hardware, so its partial holds return to
    /// the pool immediately (the TTL teardown's fault twin — same release
    /// path, no expiry budget spent).
    fn invalidate_holders(&mut self, holders: Vec<TaskId>) {
        for id in holders {
            let freed = self.book.release_all(id);
            if freed.is_empty() {
                continue;
            }
            self.touch_gpus(&freed);
            self.recorder.on_holds_invalidated(freed.len() as u64);
            let freed_ids: Vec<Json> = freed.iter().map(|&g| json::num(g as f64)).collect();
            self.trace_event("holds_invalidated", || {
                vec![
                    ("task", json::num(id as f64)),
                    ("freed", json::num(freed.len() as f64)),
                    ("gpus", json::arr(freed_ids)),
                ]
            });
            // the gang stays lane-active; its next attempt re-plans around
            // the quarantined devices
            self.schedule_gang_retry();
        }
    }

    /// A scheduled fault lands (paper §4.2's failure model generalized,
    /// DESIGN.md §15): health rolls forward, resident work on the failed
    /// domain dies into the recovery lane, reservations on it dissolve,
    /// and link faults re-price the fabric instead of killing anything.
    fn on_fault_strike(&mut self, i: usize) {
        let rec = self.faults[i].clone();
        self.recorder.on_fault(rec.kind);
        self.trace_event("fault", || {
            vec![
                ("kind", json::s(rec.kind.name())),
                ("target", json::num(rec.target as f64)),
                ("downtime_s", json::num(rec.downtime_s())),
            ]
        });
        match rec.kind {
            FaultKind::Gpu => {
                let g = rec.target;
                self.touch_server(self.server_of[g]);
                self.health.gpu_outages[g] += 1;
                if self.health.gpu_outages[g] == 1 {
                    self.trace_quarantine("gpu", g, "quarantined");
                }
                let holders: Vec<TaskId> = self.book.holder(g).into_iter().collect();
                self.invalidate_holders(holders);
                for id in self.residents_of(&[g]) {
                    self.fault_kill(id, FaultKind::Gpu);
                }
            }
            FaultKind::Server => {
                let s = rec.target;
                self.touch_server(s);
                self.health.server_outages[s] += 1;
                if self.health.server_outages[s] == 1 {
                    self.trace_quarantine("server", s, "quarantined");
                }
                let holders = self.book.holders_on_server(s);
                self.invalidate_holders(holders);
                let gpus = self.server_gpus(s);
                for id in self.residents_of(&gpus) {
                    self.fault_kill(id, FaultKind::Server);
                }
            }
            FaultKind::Link => {
                let s = rec.target;
                // link outages re-price the fabric; no view embeds it
                self.touch_fabric();
                self.health.link_outages[s] += 1;
                self.fabric
                    .set_link_degrade(s, self.cfg.faults.degrade_factor);
                self.trace_quarantine("link", s, "degraded");
                let affected = self.link_affected_gpus(s);
                self.recompute_speeds(&affected);
            }
        }
        // surviving capacity re-ranks: gang lane first, then the mappers
        self.kick_gang();
        self.kick_mappers();
    }

    /// The indexed fault's repair completes: outage counters roll back
    /// (overlapping faults keep the domain down until the LAST repair),
    /// degraded links restore to exactly factor 1.0 — bit-reproducing the
    /// fault-free fabric arithmetic — and waiting work gets a kick.
    fn on_fault_repair(&mut self, i: usize) {
        let rec = self.faults[i].clone();
        let mut gpu_seconds = 0.0;
        match rec.kind {
            FaultKind::Gpu => {
                self.touch_server(self.server_of[rec.target]);
                self.health.gpu_outages[rec.target] -= 1;
                gpu_seconds = rec.downtime_s();
            }
            FaultKind::Server => {
                let s = rec.target;
                self.touch_server(s);
                self.health.server_outages[s] -= 1;
                gpu_seconds = rec.downtime_s() * self.cluster.topo.servers[s].cfg.n_gpus as f64;
            }
            FaultKind::Link => {
                let s = rec.target;
                self.touch_fabric();
                self.health.link_outages[s] -= 1;
                if self.health.link_outages[s] == 0 {
                    self.fabric.set_link_degrade(s, 1.0);
                }
                let affected = self.link_affected_gpus(s);
                self.recompute_speeds(&affected);
            }
        }
        self.recorder.on_fault_repair(rec.downtime_s(), gpu_seconds);
        self.trace_event("repair", || {
            vec![
                ("kind", json::s(rec.kind.name())),
                ("target", json::num(rec.target as f64)),
            ]
        });
        // restored capacity: the gang lane gets first claim, as everywhere
        self.kick_gang();
        self.kick_mappers();
    }

    /// Kill a Running task because its failure domain died — the OOM
    /// crash path generalized (DESIGN.md §15): all progress is lost, every
    /// member GPU releases (a gang relaunches all-or-nothing by
    /// construction — one `TaskRun` spans all members), and the task
    /// re-queues through recovery detection with exponential backoff under
    /// a per-cause relaunch budget.
    fn fault_kill(&mut self, id: TaskId, kind: FaultKind) {
        if self.tasks[id].state != RunState::Running {
            return;
        }
        self.recorder.on_fault_interruption(kind);
        self.trace_event("detect", || {
            vec![
                ("task", json::num(id as f64)),
                ("cause", json::s(kind.name())),
            ]
        });
        self.release(id);
        let task = &mut self.tasks[id];
        task.state = RunState::Crashed;
        task.version += 1; // invalidate any scheduled completion
        task.remaining_s = task.spec.work_s; // restart from scratch
        task.in_recovery = true;
        task.fault_relaunches += 1;
        task.last_fault = Some(kind);
        let n = task.fault_relaunches;
        if n > self.cfg.faults.max_relaunches {
            self.recorder.on_fault_failed();
            self.tasks[id].last_fault = None;
            self.fail_task(id, "exceeded fault relaunch budget");
            return;
        }
        // same exponential backoff ladder as the OOM path: a task whose
        // domain keeps dying waits 2× longer before each re-queue
        let backoff = RECOVERY_DETECT_S * (1u64 << (n - 1).min(6)) as f64;
        self.engine.schedule_in(backoff, Event::RecoveryDetect(id));
    }

    /// Free all segments + residency of a task and update speeds.
    fn release(&mut self, id: TaskId) {
        let gpus = self.tasks[id].gpus.clone();
        self.touch_gpus(&gpus);
        let segs = std::mem::take(&mut self.tasks[id].segs);
        for (k, &g) in gpus.iter().enumerate() {
            for seg in &segs[k] {
                self.cluster.gpu_mut(g).alloc.free(*seg);
            }
            self.cluster.gpu_mut(g).remove_resident(id);
        }
        self.tasks[id].gpus.clear();
        self.tasks[id].instances.clear();
        let mut affected = gpus.clone();
        if self.tasks[id].spec.gang && self.fabric.servers_spanned(&gpus) > 1 {
            // the departing gang's NIC load disappears: every other gang
            // sharing its uplinks speeds up — fold them into the recompute
            let membw = self.tasks[id].spec.membw;
            self.fabric.release_links(&gpus, membw);
            affected.extend(self.other_gang_gpus(id));
        }
        self.recompute_speeds(&affected);
    }

    fn on_completion(&mut self, id: TaskId, version: u64) {
        if self.tasks[id].state != RunState::Running || self.tasks[id].version != version {
            return; // stale
        }
        self.progress_update(id);
        debug_assert!(
            self.tasks[id].remaining_s < 1e-6,
            "completion fired with {}s of work left",
            self.tasks[id].remaining_s
        );
        self.release(id);
        self.tasks[id].state = RunState::Done;
        self.done_count += 1;
        self.recorder.on_completion(id, self.engine.now());
        self.trace_event("complete", || vec![("task", json::num(id as f64))]);
        // the gang lane gets first claim on the freed devices (§11), then
        // the singleton mappers sweep
        self.kick_gang();
        self.kick_mappers();
    }

    fn progress_update(&mut self, id: TaskId) {
        let now = self.engine.now();
        let t = &mut self.tasks[id];
        t.remaining_s = (t.remaining_s - (now - t.last_progress_t) * t.speed).max(0.0);
        t.last_progress_t = now;
    }

    /// Re-derive speed factors for every task touching `gpus` (including
    /// multi-GPU tasks' partner devices) and reschedule their completions.
    fn recompute_speeds(&mut self, gpus: &[usize]) {
        use std::collections::BTreeSet;
        let mut affected: BTreeSet<TaskId> = BTreeSet::new();
        for &g in gpus {
            for r in &self.cluster.gpu(g).resident {
                affected.insert(r.task);
            }
        }
        // include partner GPUs of multi-GPU tasks
        let mut all_gpus: BTreeSet<usize> = gpus.iter().copied().collect();
        for &id in &affected {
            for &g in &self.tasks[id].gpus {
                all_gpus.insert(g);
            }
        }
        let mut more: BTreeSet<TaskId> = BTreeSet::new();
        for &g in &all_gpus {
            for r in &self.cluster.gpu(g).resident {
                more.insert(r.task);
            }
        }

        // per-GPU speed tables
        let mut table: std::collections::BTreeMap<(usize, TaskId), f64> =
            std::collections::BTreeMap::new();
        for &g in &all_gpus {
            for (tid, f) in self.cluster.gpu(g).speeds(self.cfg.colloc, &self.cfg.interference) {
                table.insert((g, tid), f);
            }
        }

        let now = self.engine.now();
        for id in more {
            if self.tasks[id].state != RunState::Running {
                continue;
            }
            self.progress_update(id);
            let speed = self.tasks[id]
                .gpus
                .iter()
                .map(|&g| *table.get(&(g, id)).unwrap_or(&1.0))
                .fold(f64::INFINITY, f64::min);
            let speed = if speed.is_finite() { speed } else { 0.0 };
            // cross-GPU fabric term (§11): a spanning gang pays the
            // synchronization + shared-NIC contention factor on top of the
            // per-device interference model
            let speed = if self.tasks[id].spec.gang {
                speed * self.fabric.gang_speed_factor(&self.tasks[id].gpus, self.tasks[id].spec.membw)
            } else {
                speed
            };
            let t = &mut self.tasks[id];
            t.speed = speed;
            t.version += 1;
            if speed > 1e-9 {
                let eta = now + t.remaining_s / speed;
                let v = t.version;
                let l = self.task_lane(id);
                self.engine.schedule_on(l, eta, Event::Completion(id, v));
            }
        }
    }

    fn on_monitor_sample(&mut self) {
        // the windowed-SMACT inputs of every future mapping decision change
        // on every server at once
        self.touch_all();
        let now = self.engine.now();
        let dt = self.cfg.monitor.sample_period_s;
        for g in 0..self.cluster.n_gpus() {
            let gpu = self.cluster.gpu(g);
            let smact = gpu.effective_smact(self.cfg.colloc, now);
            let mem = gpu.used_gb();
            let power = gpu_power_w(&self.cfg.power, gpu.n_tasks(), smact);
            self.monitor.push(g, now, smact);
            self.recorder.on_sample(g, now, dt, mem, smact, power);
        }
        // keep sampling while work remains OR the intake can still emit:
        // open-loop idle gaps must stay covered so utilization windows keep
        // closing on schedule (DESIGN.md §13)
        if self.done_count < self.tasks.len() || self.intake_open {
            self.engine.schedule_in(dt, Event::MonitorSample);
        }
    }

    // -- test/inspection hooks ------------------------------------------------

    /// Total queued tasks across every shard and the gang lane.
    pub fn queue_len(&self) -> usize {
        self.admission.len()
    }

    pub fn n_shards(&self) -> usize {
        self.mappers.len()
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Live gang holds across the cluster (test/inspection).
    pub fn gang_holds(&self) -> usize {
        self.book.total()
    }
}

/// The pure mapping scan (runs on worker threads): preconditions + the
/// O(GPUs) placement-core selection over the shared snapshot. Everything
/// here is a function of `(views, fabric, job)` only — no mutable driver
/// state — so the speculative and inline paths are the same code, and
/// fabric-aware runs stay byte-identical at every thread count (the
/// fabric's NIC occupancy only changes under `touch_*`ed commits).
fn compute_plan(
    views: &[ServerView],
    policy: PolicyKind,
    pre: Preconditions,
    fabric: Option<&Fabric>,
    job: &PlanJob,
    epoch: u64,
    quantum: u64,
) -> MapPlan {
    let (outcome, explain) = match job.admissible {
        // statically unschedulable: the placement core never ran, so there
        // is no census to report
        Err(why) => (PlanOutcome::Inadmissible(why), Explain::default()),
        Ok(()) => {
            let mut cursor = job.cursor_in;
            let (pick, ex) = placement::select_singleton_explained(
                policy, views, job.req, pre, &mut cursor, fabric,
            );
            match pick {
                Some(p) => (PlanOutcome::Place(p, cursor), ex),
                None => (PlanOutcome::NoFit, ex),
            }
        }
    };
    MapPlan {
        epoch,
        quantum,
        task: job.task,
        cursor_in: job.cursor_in,
        demand_gb: job.req.demand_gb,
        demoted: job.demoted,
        outcome,
        explain,
    }
}

/// One server's slice of the two-level mapping input: instantaneous power
/// draw + per-GPU monitor snapshots (global GPU ids). A free function over
/// the driver's `Sync` fields so snapshot construction can fan out across
/// the pool without capturing the (non-`Sync`) estimator.
fn build_server_view(
    cluster: &Cluster,
    monitor: &Monitor,
    tasks: &[TaskRun],
    cfg: &CarmaConfig,
    book: &ReservationBook,
    health: &Health,
    server: usize,
    now: f64,
) -> ServerView {
    let srv = &cluster.servers[server];
    let spec = &cluster.topo.servers[server];
    let gpus: Vec<GpuView> = srv
        .gpus
        .iter()
        .map(|g| {
            let inst = g.free_mig_instance();
            GpuView {
                id: g.id,
                server: spec.id,
                free_gb: (g.free_gb() - pending_reserved_gb(cluster, tasks, g.id)).max(0.0),
                smact_window: monitor.windowed_smact(g.id),
                n_tasks: g.n_tasks(),
                pinned: g.resident.iter().any(|r| tasks[r.task].pinned),
                held: book.is_held(g.id),
                unhealthy: health.quarantined(g.id, spec.id),
                mig_free_instance: inst,
                mig_instance_mem_gb: inst
                    .map(|i| g.capacity_gb() * g.mig_slices[i])
                    .unwrap_or(0.0),
                mig_enabled: g.mig_enabled(),
            }
        })
        .collect();
    // instantaneous draw is only consulted by the power-envelope filter;
    // skip the O(GPUs × residents) walk when no cap is set. Reserved gang
    // slots count toward the envelope (power::reserved_w, §11): singleton
    // admissions must not fill the headroom a pending gang's commit needs.
    let power_w: f64 = if spec.power_cap_w.is_some() {
        srv.gpus
            .iter()
            .map(|g| {
                gpu_power_w(
                    &cfg.power,
                    g.n_tasks(),
                    g.effective_smact(cfg.colloc, now),
                )
            })
            .sum::<f64>()
            + power::reserved_w(&cfg.power, book.server_slots(spec.id))
    } else {
        0.0
    };
    ServerView {
        id: spec.id,
        power_w,
        power_cap_w: spec.power_cap_w,
        gpus: gpus.into(),
    }
}

/// Field-for-field comparison of a cached vs freshly-built [`ServerView`]
/// — floats by bits — for the `verify_views` differential hook.
fn assert_view_eq(cached: &ServerView, fresh: &ServerView, server: usize, now: f64) {
    let ctx = |field: &str| format!("verify_views: server {server} diverged on {field} at t={now}");
    assert_eq!(cached.id, fresh.id, "{}", ctx("id"));
    assert_eq!(cached.power_w.to_bits(), fresh.power_w.to_bits(), "{}", ctx("power_w"));
    assert_eq!(
        cached.power_cap_w.map(f64::to_bits),
        fresh.power_cap_w.map(f64::to_bits),
        "{}",
        ctx("power_cap_w")
    );
    assert_eq!(cached.gpus.len(), fresh.gpus.len(), "{}", ctx("gpus.len"));
    for (c, f) in cached.gpus.iter().zip(fresh.gpus.iter()) {
        let gctx = |field: &str| {
            format!("verify_views: server {server} gpu {} diverged on {field} at t={now}", f.id)
        };
        assert_eq!(c.id, f.id, "{}", gctx("id"));
        assert_eq!(c.server, f.server, "{}", gctx("server"));
        assert_eq!(c.free_gb.to_bits(), f.free_gb.to_bits(), "{}", gctx("free_gb"));
        assert_eq!(
            c.smact_window.to_bits(),
            f.smact_window.to_bits(),
            "{}",
            gctx("smact_window")
        );
        assert_eq!(c.n_tasks, f.n_tasks, "{}", gctx("n_tasks"));
        assert_eq!(c.pinned, f.pinned, "{}", gctx("pinned"));
        assert_eq!(c.held, f.held, "{}", gctx("held"));
        assert_eq!(c.unhealthy, f.unhealthy, "{}", gctx("unhealthy"));
        assert_eq!(c.mig_free_instance, f.mig_free_instance, "{}", gctx("mig_free_instance"));
        assert_eq!(
            c.mig_instance_mem_gb.to_bits(),
            f.mig_instance_mem_gb.to_bits(),
            "{}",
            gctx("mig_instance_mem_gb")
        );
        assert_eq!(c.mig_enabled, f.mig_enabled, "{}", gctx("mig_enabled"));
    }
}

/// Reserved-but-not-yet-allocated memory on a GPU: for each resident task
/// admitted with an estimate, the part of the estimate its ramp has not
/// claimed yet.
fn pending_reserved_gb(cluster: &Cluster, tasks: &[TaskRun], gpu: usize) -> f64 {
    cluster
        .gpu(gpu)
        .resident
        .iter()
        .map(|r| {
            let t = &tasks[r.task];
            match t.admitted_est_gb {
                Some(est) => {
                    let allocated: f64 = t.ramp.iter().take(t.next_ramp).sum::<f64>() / GIB;
                    (est - allocated).max(0.0)
                }
                None => 0.0,
            }
        })
        .sum()
}

/// Convenience: run one configuration over a trace.
pub fn run_trace(
    cfg: CarmaConfig,
    estimator: Box<dyn MemoryEstimator>,
    trace: &TraceSpec,
    label: &str,
) -> RunOutcome {
    Carma::new(cfg, estimator, trace).run(label)
}

/// Convenience: run one configuration in open-loop service mode
/// (`cfg.service.arrivals` selects the process; DESIGN.md §13). Arrivals
/// stream from the seeded generator instead of a pre-materialized trace.
pub fn run_service(
    cfg: CarmaConfig,
    estimator: Box<dyn MemoryEstimator>,
    label: &str,
) -> RunOutcome {
    assert!(
        cfg.service.arrivals.is_some(),
        "run_service needs cfg.service.arrivals set"
    );
    let empty = TraceSpec {
        name: "service".to_string(),
        tasks: Vec::new(),
    };
    Carma::new(cfg, estimator, &empty).run(label)
}

/// Label helper used by the experiments: "MAGM+MPS+GPUMemNet(80%,5GB)".
pub fn run_label(cfg: &CarmaConfig, estimator_name: &str) -> String {
    let mut s = format!("{}+{}", cfg.policy.name(), cfg.colloc.name());
    if estimator_name != "none" {
        s.push('+');
        s.push_str(estimator_name);
    }
    let mut pre = Vec::new();
    if let Some(c) = cfg.smact_cap {
        pre.push(format!("{:.0}%", c * 100.0));
    }
    if let Some(m) = cfg.min_free_gb {
        pre.push(format!("{m:.0}GB"));
    }
    if cfg.safety_margin_gb > 0.0 {
        pre.push(format!("+{:.0}GBmargin", cfg.safety_margin_gb));
    }
    if !pre.is_empty() {
        s.push_str(&format!("({})", pre.join(",")));
    }
    if cfg.policy == PolicyKind::Exclusive {
        return format!("Exclusive ({})", CollocationMode::Mps.name());
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::EstimatorKind;
    use crate::estimators;
    use crate::workload::model_zoo::ModelZoo;
    use crate::workload::trace::{trace_60, trace_90, trace_cluster};

    fn cfg(policy: PolicyKind, est: EstimatorKind) -> (CarmaConfig, Box<dyn MemoryEstimator>) {
        let mut c = CarmaConfig::default();
        c.policy = policy;
        c.estimator = est;
        let e = estimators::build(est, "artifacts").unwrap();
        (c, e)
    }

    #[test]
    fn exclusive_completes_trace_without_oom() {
        let zoo = ModelZoo::load();
        let trace = trace_90(&zoo, 1);
        let (mut c, e) = cfg(PolicyKind::Exclusive, EstimatorKind::None);
        c.smact_cap = None;
        let out = run_trace(c, e, &trace, "excl");
        assert_eq!(out.report.completed, 90);
        assert_eq!(out.report.oom_crashes, 0, "exclusive can never OOM");
        assert!(out.report.trace_total_min > 60.0);
    }

    #[test]
    fn oracle_magm_beats_exclusive() {
        let zoo = ModelZoo::load();
        let trace = trace_90(&zoo, 1);

        let (mut ce, ee) = cfg(PolicyKind::Exclusive, EstimatorKind::None);
        ce.smact_cap = None;
        let excl = run_trace(ce, ee, &trace, "excl");

        let (mut cm, em) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        cm.safety_margin_gb = 2.0;
        let magm = run_trace(cm, em, &trace, "magm");

        assert_eq!(magm.report.completed, 90);
        assert_eq!(magm.report.oom_crashes, 0, "oracle + margin must avoid OOM");
        assert!(
            magm.report.trace_total_min < excl.report.trace_total_min * 0.9,
            "MAGM {:.1}m should beat Exclusive {:.1}m by >10%",
            magm.report.trace_total_min,
            excl.report.trace_total_min
        );
        assert!(magm.report.mean_smact > excl.report.mean_smact);
    }

    #[test]
    fn blind_collocation_ooms_then_recovers() {
        let zoo = ModelZoo::load();
        let trace = trace_60(&zoo, 1);
        let (mut c, e) = cfg(PolicyKind::RoundRobin, EstimatorKind::None);
        c.smact_cap = None; // no preconditions at all
        let out = run_trace(c, e, &trace, "rr-blind");
        assert_eq!(out.report.completed, 60, "recovery must finish every task");
        assert!(
            out.report.oom_crashes > 0,
            "blind RR on the heavy trace should hit OOMs"
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let zoo = ModelZoo::load();
        let trace = trace_60(&zoo, 3);
        let (c1, e1) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        let (c2, e2) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        let a = run_trace(c1, e1, &trace, "a");
        let b = run_trace(c2, e2, &trace, "b");
        assert_eq!(a.report.trace_total_min, b.report.trace_total_min);
        assert_eq!(a.report.energy_mj, b.report.energy_mj);
        assert_eq!(a.report.oom_crashes, b.report.oom_crashes);
    }

    #[test]
    fn waiting_time_includes_window() {
        let zoo = ModelZoo::load();
        let trace = trace_90(&zoo, 5);
        let (c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        let out = run_trace(c, e, &trace, "w");
        // every task waits at least the 60s observation window
        assert!(out.report.avg_waiting_min >= 1.0);
    }

    #[test]
    fn cluster_run_completes_and_spreads_load() {
        use crate::config::schema::ClusterConfig;
        use crate::workload::trace::trace_cluster;
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 96, 8, 1);
        let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
        c.safety_margin_gb = 2.0;
        let out = run_trace(c, e, &trace, "cluster-2x4");
        assert_eq!(out.report.completed, 96);
        assert_eq!(out.report.oom_crashes, 0);
        assert!(out.events > 96, "events counter must track the run");
        // both servers' GPUs must have done real work: the recorder holds 8
        // per-GPU energy integrals and idle-only GPUs sit at idle power
        assert_eq!(out.recorder.energy_j.len(), 8);
        let idle_only: f64 = out.recorder.energy_j.iter().cloned().fold(f64::INFINITY, f64::min);
        let busiest: f64 = out.recorder.energy_j.iter().cloned().fold(0.0, f64::max);
        assert!(busiest > idle_only, "load must spread beyond one GPU");
        assert!(
            out.recorder.energy_j[4..].iter().sum::<f64>() > 0.0,
            "server 1's GPUs never sampled"
        );
    }

    #[test]
    fn sharded_mapping_overlaps_windows() {
        use crate::config::schema::ClusterConfig;
        // 4 mappers on a 2×4 cluster: everything completes, the per-shard
        // counters are populated, and overlapping observation windows cut
        // queueing delay vs the serial coordinator
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 64, 8, 1);
        let mk = |shards: usize| {
            let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
            c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
            c.safety_margin_gb = 2.0;
            c.coordinator.shards = shards;
            run_trace(c, e, &trace, &format!("{shards}-shard"))
        };
        let serial = mk(1);
        let sharded = mk(4);
        assert_eq!(serial.report.completed, 64);
        assert_eq!(sharded.report.completed, 64);
        assert_eq!(serial.report.per_shard.len(), 1);
        assert_eq!(sharded.report.per_shard.len(), 4);
        assert_eq!(
            sharded.report.per_shard.iter().map(|s| s.tasks).sum::<usize>(),
            64,
            "admission routes every task to exactly one shard"
        );
        assert!(
            sharded.report.avg_waiting_min < serial.report.avg_waiting_min,
            "4 shards {:.1}m waiting !< serial {:.1}m",
            sharded.report.avg_waiting_min,
            serial.report.avg_waiting_min
        );
    }

    #[test]
    fn sharded_run_is_deterministic() {
        use crate::config::schema::{ClusterConfig, ShardAssign};
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 48, 8, 5);
        for assign in [ShardAssign::RoundRobin, ShardAssign::LeastLoaded, ShardAssign::Locality] {
            let mk = || {
                let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
                c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
                c.safety_margin_gb = 2.0;
                c.coordinator.shards = 4;
                c.coordinator.assign = assign;
                run_trace(c, e, &trace, "det")
            };
            let a = mk();
            let b = mk();
            assert_eq!(a.report.completed, 48, "{assign:?}");
            assert_eq!(
                a.report.trace_total_min.to_bits(),
                b.report.trace_total_min.to_bits(),
                "{assign:?}"
            );
            assert_eq!(a.report.energy_mj.to_bits(), b.report.energy_mj.to_bits());
            assert_eq!(a.events, b.events, "{assign:?}: event streams must match");
        }
    }

    #[test]
    fn threaded_run_is_byte_identical_to_serial() {
        use crate::config::schema::ClusterConfig;
        // the §10 guarantee in unit form: same trace, shards=4, threads 1
        // vs 4 — every reported metric matches to the bit, including the
        // handled-event count (the merge barrier must not re-order or
        // over-count)
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 64, 8, 13);
        let mk = |threads: usize| {
            let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
            c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
            c.safety_margin_gb = 2.0;
            c.coordinator.shards = 4;
            c.engine.threads = threads;
            run_trace(c, e, &trace, "threaded")
        };
        let serial = mk(1);
        let threaded = mk(4);
        assert_eq!(serial.report.completed, 64);
        assert_eq!(threaded.report.completed, 64);
        assert_eq!(serial.events, threaded.events, "event streams must match");
        assert_eq!(
            serial.report.trace_total_min.to_bits(),
            threaded.report.trace_total_min.to_bits()
        );
        assert_eq!(serial.report.energy_mj.to_bits(), threaded.report.energy_mj.to_bits());
        assert_eq!(
            serial.report.avg_waiting_min.to_bits(),
            threaded.report.avg_waiting_min.to_bits()
        );
        assert_eq!(serial.report.oom_crashes, threaded.report.oom_crashes);
        assert_eq!(
            serial.report.to_json().to_string_pretty(),
            threaded.report.to_json().to_string_pretty(),
            "full results JSON must be byte-identical"
        );
    }

    #[test]
    fn snapshot_inputs_are_sync() {
        // the parallel snapshot/plan closures capture exactly these; a
        // non-Sync field sneaking in would break the build far from here
        fn assert_sync<T: Sync>() {}
        assert_sync::<Cluster>();
        assert_sync::<Monitor>();
        assert_sync::<CarmaConfig>();
        assert_sync::<TaskRun>();
        assert_sync::<ReservationBook>();
        assert_sync::<Fabric>();
        assert_sync::<Health>();
        fn assert_send<T: Send>() {}
        assert_send::<PlanJob>();
    }

    #[test]
    fn adaptive_recovery_completes_blind_collocation() {
        // blind RR, no preconditions: tasks OOM, retry collocated with
        // doubled detection delays, and the final demoted (pinned exclusive)
        // attempt always lands — nothing may exhaust the retry budget
        let zoo = ModelZoo::load();
        let trace = trace_60(&zoo, 1);
        let (mut c, e) = cfg(PolicyKind::RoundRobin, EstimatorKind::None);
        c.smact_cap = None;
        let out = run_trace(c, e, &trace, "rr-adaptive");
        assert_eq!(out.report.completed, 60, "adaptive recovery must finish every task");
        assert!(out.report.oom_crashes > 0);
        assert_eq!(out.recorder.failed_total, 0, "no task may fail its retry budget");
    }

    #[test]
    fn gpu_faults_interrupt_and_conserve_tasks() {
        use crate::config::schema::FaultProfile;
        let zoo = ModelZoo::load();
        let trace = trace_60(&zoo, 1);
        let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        c.safety_margin_gb = 2.0;
        c.faults.profile = FaultProfile::Gpu;
        c.faults.rate_per_hour = 60.0;
        let out = run_trace(c, e, &trace, "chaos-gpu");
        let res = &out.report.resilience;
        assert!(res.faults_gpu > 0, "schedule must strike inside the window");
        // conservation invariant: every offered task terminal
        assert_eq!(
            out.report.completed
                + out.recorder.failed_total as usize
                + out.recorder.shed_total as usize,
            out.recorder.tasks.len()
        );
        assert!(out.report.to_json().get("resilience").is_some());
    }

    #[test]
    fn fault_runs_are_deterministic_across_repeats() {
        use crate::config::schema::FaultProfile;
        let zoo = ModelZoo::load();
        let trace = trace_60(&zoo, 2);
        let mk = || {
            let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
            c.safety_margin_gb = 2.0;
            c.faults.profile = FaultProfile::Mixed;
            c.faults.rate_per_hour = 30.0;
            run_trace(c, e, &trace, "chaos-det")
        };
        let a = mk();
        let b = mk();
        assert_eq!(
            a.report.to_json().to_string_pretty(),
            b.report.to_json().to_string_pretty(),
            "fault runs must be byte-identical across repeats"
        );
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn labels() {
        let mut c = CarmaConfig::default();
        c.min_free_gb = Some(5.0);
        assert_eq!(run_label(&c, "GPUMemNet"), "MAGM+MPS+GPUMemNet(80%,5GB)");
        c.policy = PolicyKind::Exclusive;
        assert!(run_label(&c, "none").starts_with("Exclusive"));
    }

    fn service_cfg(
        kind: crate::config::schema::ArrivalKind,
        rate_per_min: f64,
        duration_s: f64,
        queue_cap: usize,
    ) -> (CarmaConfig, Box<dyn MemoryEstimator>) {
        use crate::config::schema::ClusterConfig;
        let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        c.cluster = ClusterConfig::homogeneous(1, 4, 40.0);
        c.safety_margin_gb = 2.0;
        c.service.arrivals = Some(kind);
        c.service.rate_per_min = rate_per_min;
        c.service.duration_s = duration_s;
        c.service.queue_cap = queue_cap;
        (c, e)
    }

    #[test]
    fn open_loop_low_rate_completes_without_sheds() {
        use crate::config::schema::ArrivalKind;
        // ~20 offered tasks against a cap of 64: the queue can never fill,
        // so nothing may be shed and everything admitted must finish
        let (c, e) = service_cfg(ArrivalKind::Poisson, 1.0, 1200.0, 64);
        let out = run_service(c, e, "svc-low");
        assert!(out.recorder.tasks.len() > 1, "generator must emit tasks");
        assert_eq!(out.recorder.shed_total, 0, "low rate must not shed");
        assert_eq!(out.report.completed + out.recorder.failed_total as usize,
                   out.recorder.tasks.len());
        assert!(out.report.service.open_loop);
        assert!(out.report.service.util_windows > 0, "windows must close");
    }

    #[test]
    fn open_loop_saturating_rate_sheds_terminally() {
        use crate::config::schema::ArrivalKind;
        // ~300 offered tasks against one shard capped at 2: most arrivals
        // must shed, and a shed task is terminal — never dispatched
        let (c, e) = service_cfg(ArrivalKind::Burst, 60.0, 300.0, 2);
        let out = run_service(c, e, "svc-hot");
        assert!(out.recorder.shed_total > 0, "saturation must shed");
        let mut terminal = 0usize;
        for t in &out.recorder.tasks {
            if t.shed_s.is_some() {
                assert!(t.dispatched_s.is_none(), "shed task was dispatched");
                assert!(t.completed_s.is_none(), "shed task completed");
                terminal += 1;
            }
        }
        assert_eq!(terminal as u64, out.recorder.shed_total);
        assert!(
            out.report.service.rejection_rate > 0.0
                && out.report.service.rejection_rate < 1.0
        );
    }

    #[test]
    fn open_loop_run_is_deterministic_across_repeats() {
        use crate::config::schema::ArrivalKind;
        let mk = || {
            let (c, e) = service_cfg(ArrivalKind::Diurnal, 12.0, 600.0, 4);
            run_service(c, e, "svc-det")
        };
        let a = mk();
        let b = mk();
        assert_eq!(
            a.report.to_json().to_string_pretty(),
            b.report.to_json().to_string_pretty(),
            "open-loop JSON must be byte-identical across repeats"
        );
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn stream_mode_service_run_keeps_no_per_task_state() {
        use crate::config::schema::ArrivalKind;
        // `[obs] timeline = "off"` in open-loop mode flips the recorder to
        // streaming aggregation: no per-task vector, no timeline points,
        // yet the report sections stay populated (DESIGN.md §14)
        let (mut c, e) = service_cfg(ArrivalKind::Poisson, 6.0, 600.0, 4);
        c.obs.timeline = TimelineMode::Off;
        let out = run_service(c, e, "svc-stream");
        assert!(out.recorder.stream(), "service + timeline off must stream");
        assert!(out.recorder.tasks.is_empty(), "per-task vector must stay empty");
        assert!(
            out.recorder.timelines.iter().all(|t| t.is_empty()),
            "timeline off must keep no points"
        );
        assert!(out.report.total_tasks > 0, "offered count survives streaming");
        assert_eq!(
            out.report.completed
                + out.recorder.failed_total as usize
                + out.recorder.shed_total as usize,
            out.report.total_tasks,
            "every offered task must reach a terminal state"
        );
        // the report JSON still carries every section, including percentiles
        let j = out.report.to_json();
        assert!(j.get("service").is_some());
        assert!(j.get("placement_decisions").is_some());
    }

    #[test]
    fn delta_views_off_is_byte_identical_to_on() {
        use crate::config::schema::ClusterConfig;
        // the §17 off-switch contract: delta maintenance changes which
        // views get rebuilt, never what any decision reads — so the full
        // report must match to the byte with the optimization disabled
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 64, 8, 11);
        let mk = |delta: bool| {
            let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
            c.cluster = ClusterConfig::homogeneous(4, 2, 40.0);
            c.safety_margin_gb = 2.0;
            c.coordinator.shards = 4;
            c.engine.delta_views = delta;
            run_trace(c, e, &trace, "delta")
        };
        let on = mk(true);
        let off = mk(false);
        assert_eq!(on.events, off.events);
        assert_eq!(
            on.report.to_json().to_string_pretty(),
            off.report.to_json().to_string_pretty(),
            "delta views must not move a single report byte"
        );
        assert!(
            on.view_stats.servers_reused > 0,
            "a 4-server run must carry some views forward"
        );
        assert!(on.view_stats.delta_applies > 0, "narrow rebuilds must occur");
        assert_eq!(
            off.view_stats.delta_applies, 0,
            "the off arm must only do full rebuilds"
        );
    }

    #[test]
    fn verify_views_hook_passes_on_a_full_run() {
        use crate::config::schema::ClusterConfig;
        // the differential checker replays every commit: any
        // under-classified touch_* site panics inside the run
        let zoo = ModelZoo::load();
        let trace = trace_cluster(&zoo, 32, 8, 3);
        let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        c.cluster = ClusterConfig::homogeneous(2, 4, 40.0);
        c.safety_margin_gb = 2.0;
        c.coordinator.shards = 2;
        c.engine.verify_views = true;
        let out = run_trace(c, e, &trace, "verify");
        assert_eq!(out.report.completed, 32);
        assert!(
            out.view_stats.verified > 64,
            "the hook must run after every committed event (got {})",
            out.view_stats.verified
        );
    }

    #[test]
    fn open_loop_lanes_are_sized_by_live_set_not_offered_load() {
        use crate::config::schema::ArrivalKind;
        // ~600 offered tasks on 4 GPUs: lane storage must be bounded by
        // the live set (device count × churn), never the offered total,
        // and the pre-sizing must hold — no lane or arena realloc mid-run
        let (c, e) = service_cfg(ArrivalKind::Poisson, 60.0, 600.0, 8);
        let offered = (c.service.rate_per_min / 60.0 * c.service.duration_s) as usize;
        let out = run_service(c, e, "svc-presize");
        assert!(out.recorder.tasks.len() > offered / 2, "load must materialize");
        assert_eq!(out.engine_stats.lane_reallocs, 0, "lanes re-allocated mid-run");
        assert_eq!(out.engine_stats.arena_reallocs, 0, "arena re-allocated mid-run");
        assert!(
            out.engine_stats.arena_high_water < out.engine_stats.arena_capacity,
            "high water {} must sit under the pre-sized capacity {}",
            out.engine_stats.arena_high_water,
            out.engine_stats.arena_capacity
        );
    }

    #[test]
    fn decision_provenance_populates_report() {
        let zoo = ModelZoo::load();
        let trace = trace_90(&zoo, 1);
        let (mut c, e) = cfg(PolicyKind::Magm, EstimatorKind::Oracle);
        c.safety_margin_gb = 2.0;
        let out = run_trace(c, e, &trace, "prov");
        let d = &out.report.decisions;
        assert!(d.decisions >= 90, "every mapping attempt must be counted");
        assert!(d.placed >= 90, "every task dispatches at least once");
        assert_eq!(d.inadmissible, 0);
        assert!(
            d.servers_admitted + d.servers_rejected >= d.decisions,
            "per-decision server census must cover at least one server each"
        );
        assert!(out.report.to_json().get("placement_decisions").is_some());
    }
}
