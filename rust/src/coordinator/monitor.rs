//! Monitoring unit (paper §4.1, S5): DCGM-like per-GPU SMACT sampling with
//! a sliding decision window.
//!
//! "One data point is not enough for making a decision about the load of a
//! GPU, so we observe SMACT over 1 minute and use the average value."

use std::collections::VecDeque;

#[derive(Debug)]
pub struct Monitor {
    window_s: f64,
    /// Per-GPU (timestamp, smact) samples within the window.
    samples: Vec<VecDeque<(f64, f64)>>,
}

impl Monitor {
    pub fn new(n_gpus: usize, window_s: f64) -> Self {
        Monitor {
            window_s,
            samples: vec![VecDeque::new(); n_gpus],
        }
    }

    pub fn push(&mut self, gpu: usize, t: f64, smact: f64) {
        let q = &mut self.samples[gpu];
        q.push_back((t, smact));
        let cutoff = t - self.window_s;
        while q.front().is_some_and(|&(ts, _)| ts < cutoff) {
            q.pop_front();
        }
    }

    /// Windowed average SMACT — the value mapping decisions use.
    pub fn windowed_smact(&self, gpu: usize) -> f64 {
        let q = &self.samples[gpu];
        if q.is_empty() {
            return 0.0;
        }
        q.iter().map(|&(_, s)| s).sum::<f64>() / q.len() as f64
    }

    pub fn sample_count(&self, gpu: usize) -> usize {
        self.samples[gpu].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windowed_average() {
        let mut m = Monitor::new(1, 60.0);
        for i in 0..30 {
            m.push(0, i as f64, 0.2);
        }
        for i in 30..60 {
            m.push(0, i as f64, 0.8);
        }
        assert!((m.windowed_smact(0) - 0.5).abs() < 0.02);
    }

    #[test]
    fn window_evicts_old_samples() {
        let mut m = Monitor::new(1, 60.0);
        for i in 0..200 {
            m.push(0, i as f64, if i < 140 { 1.0 } else { 0.0 });
        }
        // at t=199 the window is [139, 199]: one sample of 1.0, 60 of 0.0
        assert!(m.windowed_smact(0) < 0.05);
        assert!(m.sample_count(0) <= 62);
    }

    #[test]
    fn empty_is_idle() {
        let m = Monitor::new(2, 60.0);
        assert_eq!(m.windowed_smact(1), 0.0);
    }
}
