//! Run-time metric collection (paper §5.1.3).
//!
//! Two collection modes (DESIGN.md §14):
//!
//! * **Full** (closed-loop default): one [`TaskTiming`] per task, report
//!   aggregates computed over the vector. Exact, O(tasks) memory.
//! * **Stream** (open-loop service runs with `[obs] timeline = "off"`,
//!   via [`Recorder::enable_stream`]): only *in-flight* tasks keep a
//!   [`TaskTiming`] (a `BTreeMap` keyed by id); every terminal event
//!   (completion, shed, permanent failure) folds the record into
//!   [`StreamAgg`] running sums and drops it. Memory is O(in-flight +
//!   histogram buckets + GPUs) no matter how many tasks the arrival
//!   process offers. [`Recorder::finalize`] folds the stragglers so the
//!   report covers tasks still queued at the horizon, exactly like the
//!   full-mode aggregation does.
//!
//! Queue-delay and JCT percentiles come from [`LogHistogram`] sketches in
//! BOTH modes (±5% relative error, `obs::sketch`), so the report keys
//! cannot drift between modes. Stream-mode means run in terminal-event
//! order rather than task-id order, which can differ in the last float
//! bits from full mode — within one mode they are deterministic.

use std::collections::BTreeMap;

use crate::coordinator::placement::{Explain, RejectReason};
use crate::obs::{LogHistogram, Registry};
use crate::sim::faults::FaultKind;
use crate::sim::TaskId;

/// One downsampled monitoring sample for one GPU (drives Fig. 12).
#[derive(Debug, Clone, Copy)]
pub struct TimelinePoint {
    pub t: f64,
    pub mem_used_gb: f64,
    pub smact: f64,
    pub power_w: f64,
}

#[derive(Debug, Clone, Default)]
pub struct TaskTiming {
    pub arrival_s: f64,
    pub dispatched_s: Option<f64>,
    pub completed_s: Option<f64>,
    pub oom_crashes: u32,
    /// Coordinator shard admission routed this task to (DESIGN.md §9).
    pub assigned_shard: Option<usize>,
    /// Mapping decisions that dispatched this task (> 1 after recovery).
    pub dispatches: u32,
    /// Routed to the gang lane (distributed job, DESIGN.md §11).
    pub gang: bool,
    /// Servers the (last) gang dispatch spanned.
    pub servers_spanned: usize,
    /// Spanned servers beyond the packing minimum at dispatch — the
    /// placement-fragmentation count of this gang.
    pub span_excess: usize,
    /// Fabric ring cost of the placed set (`Fabric::set_cost`): per-GB
    /// collective transfer cost, a function of the `[fabric]` bandwidth
    /// classes and how many islands/servers the placement crosses.
    /// Recorded for gang AND singleton dispatches (DESIGN.md §12).
    pub fabric_cost: f64,
    /// GPUs of the (last) dispatch (singleton bookkeeping: the placement
    /// section aggregates multi-GPU singletons only).
    pub placed_gpus: usize,
    /// NVLink islands the (last) singleton dispatch spanned.
    pub islands_spanned: usize,
    /// Shard that stole this task off its original queue, if any
    /// (DESIGN.md §12; `assigned_shard` keeps the original routing).
    pub stolen_by: Option<usize>,
    /// Shed at intake by the bounded admission layer (open-loop service
    /// mode, DESIGN.md §13). Terminal: a shed task never queues, dispatches
    /// or runs — `dispatched_s`/`completed_s` stay `None`.
    pub shed_s: Option<f64>,
}

/// How a committed mapping decision resolved (the three [`PlanOutcome`]
/// shapes, minus the plan bookkeeping).
///
/// [`PlanOutcome`]: crate::coordinator::shard::mapper::PlanOutcome
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionOutcome {
    Placed,
    NoFit,
    Inadmissible,
}

/// Aggregated decision provenance (DESIGN.md §14): every committed
/// singleton mapping decision folds its [`Explain`] census here, so the
/// report's `placement_decisions` section can say *why* the cluster looked
/// the way it did — how many GPUs each eligibility filter cut, how many
/// candidate sets were ranked — without keeping per-decision state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DecisionAgg {
    /// Mapping decisions committed (every `attempt_map` resolution).
    pub decisions: u64,
    pub placed: u64,
    pub no_fit: u64,
    pub inadmissible: u64,
    /// Σ servers passing / failing the admission gate per decision.
    pub servers_admitted: u64,
    pub servers_rejected: u64,
    /// Σ GPUs surviving every eligibility filter per decision.
    pub gpus_eligible: u64,
    /// Σ candidate GPU sets ranked per decision.
    pub candidates: u64,
    /// Σ per-reason eligibility rejects, indexed by [`RejectReason::index`].
    pub rejects: [u64; RejectReason::COUNT],
}

impl DecisionAgg {
    pub fn record(&mut self, outcome: DecisionOutcome, ex: &Explain) {
        self.decisions += 1;
        match outcome {
            DecisionOutcome::Placed => self.placed += 1,
            DecisionOutcome::NoFit => self.no_fit += 1,
            DecisionOutcome::Inadmissible => self.inadmissible += 1,
        }
        self.servers_admitted += ex.servers_admitted as u64;
        self.servers_rejected += ex.servers_rejected as u64;
        self.gpus_eligible += ex.gpus_eligible as u64;
        self.candidates += ex.candidates as u64;
        for (acc, n) in self.rejects.iter_mut().zip(ex.rejects.iter()) {
            *acc += n;
        }
    }
}

/// Per-shard running sums for stream mode — the fields `report::shard_stats`
/// needs, folded at terminal events instead of scanned at the end.
#[derive(Debug, Clone, Default)]
pub struct ShardAgg {
    pub tasks: usize,
    pub decisions: u64,
    pub wait_sum: f64,
    pub waited: usize,
    pub steals: u64,
}

/// Stream-mode running aggregates: everything the report computes by
/// scanning `Recorder::tasks`, kept as O(shards) sums instead. Fields
/// mirror the full-mode aggregation in `metrics::report` one for one.
#[derive(Debug, Clone, Default)]
pub struct StreamAgg {
    /// Arrivals offered (stream-mode stand-in for `tasks.len()`).
    pub arrivals: u64,
    pub completed: u64,
    pub wait_sum: f64,
    pub waited: u64,
    pub exec_sum: f64,
    pub execed: u64,
    pub jct_sum: f64,
    pub jcted: u64,
    pub per_shard: Vec<ShardAgg>,
    // gang lane (report::gang_stats)
    pub gangs: usize,
    pub gang_completed: usize,
    pub cross_server: usize,
    pub max_servers_spanned: usize,
    pub frag_excess: usize,
    pub gang_wait_sum: f64,
    pub gang_cost_sum: f64,
    pub gang_waited: usize,
    pub gang_max_wait_s: f64,
    // singleton placement (report::placement_stats)
    pub multi_gpu_singletons: usize,
    pub single_island: usize,
    pub place_cost_sum: f64,
    pub place_max_cost: f64,
}

impl StreamAgg {
    fn shard_mut(&mut self, shard: usize) -> &mut ShardAgg {
        if shard >= self.per_shard.len() {
            self.per_shard.resize_with(shard + 1, ShardAgg::default);
        }
        &mut self.per_shard[shard]
    }

    /// Fold one finished (or abandoned) task record — the exact per-task
    /// contributions `report::{shard,gang,placement}_stats` and the
    /// recorder's mean aggregates read off the full-mode vector.
    fn fold(&mut self, t: &TaskTiming) {
        if let Some(d) = t.dispatched_s {
            let w = d - t.arrival_s;
            self.wait_sum += w;
            self.waited += 1;
            if let Some(c) = t.completed_s {
                self.exec_sum += c - d;
                self.execed += 1;
            }
        }
        if let Some(c) = t.completed_s {
            self.jct_sum += c - t.arrival_s;
            self.jcted += 1;
            self.completed += 1;
        }
        if let Some(s) = t.assigned_shard {
            let e = self.shard_mut(s);
            e.tasks += 1;
            e.decisions += t.dispatches as u64;
            if let Some(d) = t.dispatched_s {
                e.wait_sum += d - t.arrival_s;
                e.waited += 1;
            }
        }
        if let Some(thief) = t.stolen_by {
            self.shard_mut(thief).steals += 1;
        }
        if t.gang {
            self.gangs += 1;
            if t.completed_s.is_some() {
                self.gang_completed += 1;
            }
            if t.servers_spanned > 1 {
                self.cross_server += 1;
            }
            self.max_servers_spanned = self.max_servers_spanned.max(t.servers_spanned);
            self.frag_excess += t.span_excess;
            if let Some(d) = t.dispatched_s {
                let w = d - t.arrival_s;
                self.gang_wait_sum += w;
                self.gang_cost_sum += t.fabric_cost;
                self.gang_waited += 1;
                self.gang_max_wait_s = self.gang_max_wait_s.max(w);
            }
        } else if t.placed_gpus >= 2 {
            self.multi_gpu_singletons += 1;
            if t.islands_spanned <= 1 {
                self.single_island += 1;
            }
            self.place_cost_sum += t.fabric_cost;
            self.place_max_cost = self.place_max_cost.max(t.fabric_cost);
        }
    }
}

/// Collects everything the evaluation section reports.
#[derive(Debug)]
pub struct Recorder {
    pub tasks: Vec<TaskTiming>,
    pub timelines: Vec<Vec<TimelinePoint>>, // per GPU
    pub energy_j: Vec<f64>,                 // per GPU
    /// Time-weighted SMACT integral per GPU (for mean utilization).
    smact_integral: Vec<f64>,
    mem_integral: Vec<f64>,
    pub oom_total: u64,
    pub failed_total: u64,
    /// Gang-lane counters (DESIGN.md §11).
    pub gang_holds_placed: u64,
    pub gang_holds_expired: u64,
    /// Dispatches whose placed GPU count differed from the request — MUST
    /// stay zero; a nonzero value means all-or-nothing was violated and the
    /// results JSON makes that observable.
    pub gang_partial_dispatches: u64,
    /// Configured coordinator shard count (DESIGN.md §9) — the report's
    /// per-shard stats cover all of them, including shards that never
    /// received a task (e.g. least-loaded routing under light arrivals).
    pub n_shards: usize,
    pub first_arrival_s: Option<f64>,
    pub last_completion_s: f64,
    /// Keep every k-th monitor sample in the timeline (1 Hz base rate).
    pub timeline_stride: u64,
    /// Open-loop service mode active (DESIGN.md §13) — reported so the
    /// JSON distinguishes a batch run's zeros from a quiet service run.
    pub open_loop: bool,
    /// Arrivals dropped at intake by the bounded admission layer.
    pub shed_total: u64,
    /// Subset of `shed_total` dropped at the door while every shard sat at
    /// the cap (cluster-wide backpressure rather than one unlucky route).
    pub shed_at_door: u64,
    /// Sliding utilization windows (DESIGN.md §13): window length in
    /// seconds; 0.0 disables windowing — the closed-loop default.
    pub util_window_s: f64,
    /// Completed windows: (window_end_t, mean SMACT, mean mem GB), each a
    /// GPU-time-weighted mean over one window.
    pub util_windows: Vec<(f64, f64, f64)>,
    /// Queue-delay (first dispatch − arrival) sketch, fed in both modes —
    /// the report's `queue_delay_p*` keys read percentiles off it.
    pub queue_delay: LogHistogram,
    /// Job-completion-time sketch (completion − arrival), both modes.
    pub jct: LogHistogram,
    /// Aggregated decision provenance (`placement_decisions` section).
    pub decisions: DecisionAgg,
    /// Fault-injection counters (DESIGN.md §15) — plain running sums, so
    /// they work identically in full and stream collection modes and feed
    /// the report's always-present `resilience` section (all zero when
    /// faults are off). Strikes indexed Gpu/Server/Link.
    pub faults_injected: [u64; 3],
    /// Resident tasks killed by a fault, indexed by the striking kind
    /// (link faults kill nothing — index 2 stays zero by construction).
    pub fault_interruptions: [u64; 3],
    /// Fault-cause re-queues admitted back into the scheduler.
    pub fault_relaunches: u64,
    /// Tasks permanently failed because the per-cause relaunch budget ran
    /// out (subset of `failed_total`).
    pub fault_failed: u64,
    /// Completed repairs and their summed outage time (MTTR numerator).
    pub fault_repairs: u64,
    pub repair_time_sum_s: f64,
    /// GPU-seconds of quarantined capacity (availability denominator uses
    /// `n_gpus × trace_total_s`).
    pub downtime_gpu_s: f64,
    /// Gang reservations invalidated because their server died.
    pub holds_invalidated: u64,
    /// Trace records lost to failed writes (copied off the sink post-run;
    /// 0 when tracing is off or healthy). Surfaced in the report `obs`
    /// section and as `carma_trace_dropped_total`.
    pub trace_dropped: u64,
    /// Stream mode on: per-task records live only while in flight.
    stream: bool,
    /// In-flight task records (stream mode only), keyed by task id — a
    /// BTreeMap so iteration (finalize) is deterministic.
    live: BTreeMap<TaskId, TaskTiming>,
    /// Peak size of the in-flight map — the O(in-flight) memory claim of
    /// stream mode (DESIGN.md §14/§17), asserted by `repro engine_scale`
    /// over million-task sweeps.
    pub live_high_water: usize,
    /// Stream-mode running aggregates (complete only after `finalize`).
    pub agg: StreamAgg,
    win_smact_acc: f64,
    win_mem_acc: f64,
    win_time_acc: f64,
    win_start_s: f64,
    sample_count: u64,
    integrated_until: f64,
}

impl Recorder {
    pub fn new(n_tasks: usize, n_gpus: usize) -> Self {
        Recorder {
            tasks: vec![TaskTiming::default(); n_tasks],
            timelines: vec![Vec::new(); n_gpus],
            energy_j: vec![0.0; n_gpus],
            smact_integral: vec![0.0; n_gpus],
            mem_integral: vec![0.0; n_gpus],
            oom_total: 0,
            failed_total: 0,
            gang_holds_placed: 0,
            gang_holds_expired: 0,
            gang_partial_dispatches: 0,
            n_shards: 1,
            first_arrival_s: None,
            last_completion_s: 0.0,
            timeline_stride: 15,
            open_loop: false,
            shed_total: 0,
            shed_at_door: 0,
            util_window_s: 0.0,
            util_windows: Vec::new(),
            queue_delay: LogHistogram::default(),
            jct: LogHistogram::default(),
            decisions: DecisionAgg::default(),
            faults_injected: [0; 3],
            fault_interruptions: [0; 3],
            fault_relaunches: 0,
            fault_failed: 0,
            fault_repairs: 0,
            repair_time_sum_s: 0.0,
            downtime_gpu_s: 0.0,
            holds_invalidated: 0,
            trace_dropped: 0,
            stream: false,
            live: BTreeMap::new(),
            live_high_water: 0,
            agg: StreamAgg::default(),
            win_smact_acc: 0.0,
            win_mem_acc: 0.0,
            win_time_acc: 0.0,
            win_start_s: 0.0,
            sample_count: 0,
        integrated_until: 0.0,
        }
    }

    /// Switch to stream collection (DESIGN.md §14) — open-loop service
    /// runs with the timeline off call this before the first arrival.
    /// Per-task records then live only while the task is in flight.
    pub fn enable_stream(&mut self) {
        assert!(
            self.tasks.is_empty(),
            "stream mode must be enabled before any task is recorded"
        );
        self.stream = true;
    }

    /// Stream collection active (the report aggregates off `agg`, not
    /// `tasks`).
    pub fn stream(&self) -> bool {
        self.stream
    }

    /// Tasks the arrival process offered: the per-task table in full mode,
    /// the arrival counter in stream mode (where the table stays empty).
    pub fn offered(&self) -> usize {
        if self.stream {
            self.agg.arrivals as usize
        } else {
            self.tasks.len()
        }
    }

    /// OOM crashes recorded against `task` so far (0 once folded — the
    /// coordinator only asks while the task is in flight).
    pub fn oom_crashes_of(&self, task: TaskId) -> u32 {
        if self.stream {
            self.live.get(&task).map_or(0, |t| t.oom_crashes)
        } else {
            self.tasks[task].oom_crashes
        }
    }

    /// The live record for `task`: the table slot in full mode, the
    /// in-flight map entry in stream mode.
    fn timing_mut(&mut self, task: TaskId) -> &mut TaskTiming {
        if self.stream {
            self.live.entry(task).or_default();
            self.live_high_water = self.live_high_water.max(self.live.len());
            self.live.get_mut(&task).expect("just inserted")
        } else {
            &mut self.tasks[task]
        }
    }

    /// Stream mode: fold `task`'s record into the running aggregates and
    /// drop it. No-op in full mode or for an already-folded id.
    fn fold_terminal(&mut self, task: TaskId) {
        if !self.stream {
            return;
        }
        if let Some(t) = self.live.remove(&task) {
            self.agg.fold(&t);
        }
    }

    /// Fold every still-in-flight record (tasks queued or running at the
    /// horizon) so the stream aggregates cover exactly what a full-mode
    /// scan would. Call once, after the last event. Full mode: no-op.
    pub fn finalize(&mut self) {
        if !self.stream {
            return;
        }
        let leftovers: Vec<TaskId> = self.live.keys().copied().collect();
        for task in leftovers {
            self.fold_terminal(task);
        }
    }

    /// Open-loop intake: extend the per-task table to cover `task` (ids
    /// stream in sequentially; closed-loop runs pre-size in `new`).
    /// Stream mode keeps no table — records appear on first touch.
    pub fn ensure_task(&mut self, task: TaskId) {
        if !self.stream && task >= self.tasks.len() {
            self.tasks.resize(task + 1, TaskTiming::default());
        }
    }

    pub fn on_arrival(&mut self, task: TaskId, t: f64) {
        self.timing_mut(task).arrival_s = t;
        self.first_arrival_s = Some(self.first_arrival_s.map_or(t, |x: f64| x.min(t)));
        if self.stream {
            self.agg.arrivals += 1;
        }
    }

    /// Admission routed `task` to `shard` (recorded once, at first intake).
    pub fn on_assigned(&mut self, task: TaskId, shard: usize) {
        let tt = self.timing_mut(task);
        if tt.assigned_shard.is_none() {
            tt.assigned_shard = Some(shard);
        }
    }

    pub fn on_dispatch(&mut self, task: TaskId, t: f64) {
        // waiting time keeps the FIRST dispatch (the paper counts time in
        // queue before execution first begins); re-dispatches after OOM only
        // bump the decision counter. map_or keeps this total: a re-dispatch
        // recorded before the first set is just taken as the first.
        let tt = self.timing_mut(task);
        tt.dispatches += 1;
        let first = tt.dispatched_s.is_none();
        tt.dispatched_s = Some(tt.dispatched_s.map_or(t, |d| d.min(t)));
        if first {
            let delay = (t - tt.arrival_s).max(0.0);
            self.queue_delay.record(delay);
        }
    }

    pub fn on_completion(&mut self, task: TaskId, t: f64) {
        let tt = self.timing_mut(task);
        tt.completed_s = Some(t);
        let jct = (t - tt.arrival_s).max(0.0);
        self.jct.record(jct);
        self.last_completion_s = self.last_completion_s.max(t);
        self.fold_terminal(task);
    }

    /// Task permanently failed (unschedulable / retry budget exhausted).
    pub fn on_failed(&mut self, task: TaskId) {
        self.failed_total += 1;
        self.fold_terminal(task);
    }

    /// Intake shed `task` at time `t` (open-loop service mode, DESIGN.md
    /// §13). `at_door` = dropped under cluster-wide backpressure (every
    /// shard at the cap) rather than one full routed queue.
    pub fn on_shed(&mut self, task: TaskId, t: f64, at_door: bool) {
        self.timing_mut(task).shed_s = Some(t);
        self.shed_total += 1;
        if at_door {
            self.shed_at_door += 1;
        }
        self.fold_terminal(task);
    }

    /// A committed mapping decision with its provenance census
    /// (DESIGN.md §14).
    pub fn on_decision(&mut self, outcome: DecisionOutcome, ex: &Explain) {
        self.decisions.record(outcome, ex);
    }

    /// Admission routed `task` to the gang lane (DESIGN.md §11).
    pub fn on_gang_arrival(&mut self, task: TaskId) {
        self.timing_mut(task).gang = true;
    }

    /// A gang dispatched: `placed` GPUs of `requested` across `spanned`
    /// servers (`min_span` = the packing minimum for this width) at fabric
    /// ring cost `fabric_cost`.
    pub fn on_gang_dispatch(
        &mut self,
        task: TaskId,
        placed: usize,
        requested: usize,
        spanned: usize,
        min_span: usize,
        fabric_cost: f64,
    ) {
        if placed != requested {
            self.gang_partial_dispatches += 1;
        }
        let tt = self.timing_mut(task);
        tt.servers_spanned = spanned;
        tt.span_excess = spanned.saturating_sub(min_span);
        tt.fabric_cost = fabric_cost;
    }

    /// A singleton (server-local) task dispatched onto `placed` GPUs at
    /// achieved fabric ring cost `fabric_cost` across `islands` islands
    /// (DESIGN.md §12). Recorded on every dispatch regardless of the
    /// island-aware switch, so blind and aware runs compare head to head.
    pub fn on_singleton_dispatch(
        &mut self,
        task: TaskId,
        placed: usize,
        fabric_cost: f64,
        islands: usize,
    ) {
        let tt = self.timing_mut(task);
        tt.placed_gpus = placed;
        tt.fabric_cost = fabric_cost;
        tt.islands_spanned = islands;
    }

    /// Shard `thief` stole this task off its original queue (§12).
    pub fn on_stolen(&mut self, task: TaskId, thief: usize) {
        self.timing_mut(task).stolen_by = Some(thief);
    }

    pub fn on_gang_holds(&mut self, n: u64) {
        self.gang_holds_placed += n;
    }

    pub fn on_gang_holds_expired(&mut self, n: u64) {
        self.gang_holds_expired += n;
    }

    pub fn on_oom(&mut self, task: TaskId) {
        self.timing_mut(task).oom_crashes += 1;
        self.oom_total += 1;
    }

    // -- fault / resilience hooks (DESIGN.md §15) ---------------------------

    /// A scheduled fault struck.
    pub fn on_fault(&mut self, kind: FaultKind) {
        self.faults_injected[kind_index(kind)] += 1;
    }

    /// A resident task was killed by a `kind` fault.
    pub fn on_fault_interruption(&mut self, kind: FaultKind) {
        self.fault_interruptions[kind_index(kind)] += 1;
    }

    /// A fault-killed task was re-queued for another attempt.
    pub fn on_fault_relaunch(&mut self) {
        self.fault_relaunches += 1;
    }

    /// A fault-killed task exhausted its relaunch budget (the caller also
    /// records the generic `on_failed`).
    pub fn on_fault_failed(&mut self) {
        self.fault_failed += 1;
    }

    /// A fault repaired after `downtime_s`, having quarantined
    /// `gpu_seconds` of capacity (0 for link faults — degraded devices
    /// keep serving).
    pub fn on_fault_repair(&mut self, downtime_s: f64, gpu_seconds: f64) {
        self.fault_repairs += 1;
        self.repair_time_sum_s += downtime_s;
        self.downtime_gpu_s += gpu_seconds;
    }

    /// Gang reservations invalidated because their server died.
    pub fn on_holds_invalidated(&mut self, n: u64) {
        self.holds_invalidated += n;
    }

    /// Integrate one monitoring interval `dt` for GPU `gpu`.
    pub fn on_sample(
        &mut self,
        gpu: usize,
        t: f64,
        dt: f64,
        mem_used_gb: f64,
        smact: f64,
        power_w: f64,
    ) {
        self.energy_j[gpu] += power_w * dt;
        self.smact_integral[gpu] += smact * dt;
        self.mem_integral[gpu] += mem_used_gb * dt;
        if gpu == 0 {
            self.sample_count += 1;
        }
        // stride 0 = timeline off ([obs] timeline = "off"): no points kept
        if self.timeline_stride > 0 && self.sample_count % self.timeline_stride == 0 {
            self.timelines[gpu].push(TimelinePoint {
                t,
                mem_used_gb,
                smact,
                power_w,
            });
        }
        if self.util_window_s > 0.0 {
            self.win_smact_acc += smact * dt;
            self.win_mem_acc += mem_used_gb * dt;
            if gpu + 1 == self.energy_j.len() {
                self.win_time_acc += dt;
                if t - self.win_start_s >= self.util_window_s - 1e-9 {
                    let denom =
                        (self.win_time_acc * self.energy_j.len() as f64).max(1e-9);
                    self.util_windows.push((
                        t,
                        self.win_smact_acc / denom,
                        self.win_mem_acc / denom,
                    ));
                    self.win_smact_acc = 0.0;
                    self.win_mem_acc = 0.0;
                    self.win_time_acc = 0.0;
                    self.win_start_s = t;
                }
            }
        }
        if gpu + 1 == self.energy_j.len() {
            self.integrated_until = t;
        }
    }

    // -- aggregates ---------------------------------------------------------

    pub fn trace_total_s(&self) -> f64 {
        self.last_completion_s - self.first_arrival_s.unwrap_or(0.0)
    }

    pub fn avg_waiting_s(&self) -> f64 {
        if self.stream {
            return ratio(self.agg.wait_sum, self.agg.waited);
        }
        avg(self.tasks.iter().filter_map(|t| {
            t.dispatched_s.map(|d| d - t.arrival_s)
        }))
    }

    pub fn avg_execution_s(&self) -> f64 {
        if self.stream {
            return ratio(self.agg.exec_sum, self.agg.execed);
        }
        avg(self.tasks.iter().filter_map(|t| {
            match (t.dispatched_s, t.completed_s) {
                (Some(d), Some(c)) => Some(c - d),
                _ => None,
            }
        }))
    }

    pub fn avg_jct_s(&self) -> f64 {
        if self.stream {
            return ratio(self.agg.jct_sum, self.agg.jcted);
        }
        avg(self.tasks.iter().filter_map(|t| {
            t.completed_s.map(|c| c - t.arrival_s)
        }))
    }

    pub fn total_energy_mj(&self) -> f64 {
        self.energy_j.iter().sum::<f64>() / 1e6
    }

    /// Mean SM activity across GPUs over the trace (paper's "GPU
    /// utilization over time").
    pub fn mean_smact(&self) -> f64 {
        let t = self.integrated_until.max(1e-9);
        self.smact_integral.iter().sum::<f64>() / (t * self.smact_integral.len() as f64)
    }

    pub fn mean_mem_used_gb(&self) -> f64 {
        let t = self.integrated_until.max(1e-9);
        self.mem_integral.iter().sum::<f64>() / (t * self.mem_integral.len() as f64)
    }

    pub fn completed_count(&self) -> usize {
        if self.stream {
            return self.agg.completed as usize;
        }
        self.tasks.iter().filter(|t| t.completed_s.is_some()).count()
    }

    /// Prometheus-style metric registry over the run's counters, gauges
    /// and sketches — rendered to `--metrics-out` (DESIGN.md §14).
    pub fn registry(&self) -> Registry {
        let mut reg = Registry::new();
        reg.counter(
            "carma_offered_total",
            "Tasks the arrival process offered",
            self.offered() as f64,
        );
        reg.counter(
            "carma_completed_total",
            "Tasks that ran to completion",
            self.completed_count() as f64,
        );
        reg.counter(
            "carma_shed_total",
            "Arrivals dropped at intake by the bounded admission layer",
            self.shed_total as f64,
        );
        reg.counter(
            "carma_oom_total",
            "OOM crashes across all tasks",
            self.oom_total as f64,
        );
        reg.counter(
            "carma_failed_total",
            "Tasks permanently failed",
            self.failed_total as f64,
        );
        reg.counter(
            "carma_decisions_total",
            "Singleton mapping decisions committed",
            self.decisions.decisions as f64,
        );
        reg.counter(
            "carma_energy_joules_total",
            "Total GPU energy integrated over the run",
            self.total_energy_mj() * 1e6,
        );
        reg.gauge(
            "carma_mean_smact",
            "Mean SM activity across GPUs over the trace",
            self.mean_smact(),
        );
        reg.gauge(
            "carma_mean_mem_used_gb",
            "Mean used GPU memory (GB per GPU) over the trace",
            self.mean_mem_used_gb(),
        );
        reg.counter(
            "carma_fault_strikes_total",
            "Fault-injection strikes committed (all kinds)",
            self.faults_injected.iter().sum::<u64>() as f64,
        );
        reg.counter(
            "carma_fault_interruptions_total",
            "Resident tasks killed by faults",
            self.fault_interruptions.iter().sum::<u64>() as f64,
        );
        reg.counter(
            "carma_fault_relaunches_total",
            "Fault-cause re-queues admitted back into the scheduler",
            self.fault_relaunches as f64,
        );
        reg.counter(
            "carma_fault_repairs_total",
            "Completed fault repairs",
            self.fault_repairs as f64,
        );
        reg.counter(
            "carma_fault_downtime_gpu_seconds_total",
            "GPU-seconds of quarantined capacity",
            self.downtime_gpu_s,
        );
        reg.counter(
            "carma_trace_dropped_total",
            "Trace records lost to failed writes",
            self.trace_dropped as f64,
        );
        reg.histogram(
            "carma_queue_delay_seconds",
            "Queueing delay (first dispatch - arrival)",
            &self.queue_delay,
        );
        reg.histogram(
            "carma_jct_seconds",
            "Job completion time (completion - arrival)",
            &self.jct,
        );
        reg
    }
}

/// Index of a fault kind in the per-kind counter arrays (Gpu/Server/Link).
pub fn kind_index(kind: FaultKind) -> usize {
    match kind {
        FaultKind::Gpu => 0,
        FaultKind::Server => 1,
        FaultKind::Link => 2,
    }
}

fn avg(it: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = it.collect();
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

fn ratio(sum: f64, n: u64) -> f64 {
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_pipeline() {
        let mut r = Recorder::new(2, 1);
        r.on_arrival(0, 10.0);
        r.on_arrival(1, 20.0);
        r.on_dispatch(0, 70.0);
        r.on_completion(0, 170.0);
        r.on_dispatch(1, 200.0);
        r.on_completion(1, 260.0);
        assert_eq!(r.avg_waiting_s(), (60.0 + 180.0) / 2.0);
        assert_eq!(r.avg_execution_s(), (100.0 + 60.0) / 2.0);
        assert_eq!(r.avg_jct_s(), (160.0 + 240.0) / 2.0);
        assert_eq!(r.trace_total_s(), 250.0);
        assert_eq!(r.completed_count(), 2);
    }

    #[test]
    fn energy_and_utilization_integrals() {
        let mut r = Recorder::new(1, 2);
        for i in 0..100 {
            let t = (i + 1) as f64;
            r.on_sample(0, t, 1.0, 10.0, 0.5, 200.0);
            r.on_sample(1, t, 1.0, 0.0, 0.0, 50.0);
        }
        assert!((r.total_energy_mj() - (200.0 + 50.0) * 100.0 / 1e6).abs() < 1e-12);
        assert!((r.mean_smact() - 0.25).abs() < 1e-9);
        assert!((r.mean_mem_used_gb() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn dispatch_keeps_first_time_and_counts_decisions() {
        let mut r = Recorder::new(1, 1);
        r.on_arrival(0, 0.0);
        r.on_dispatch(0, 60.0);
        r.on_dispatch(0, 200.0); // recovery re-dispatch
        assert_eq!(r.tasks[0].dispatched_s, Some(60.0));
        assert_eq!(r.tasks[0].dispatches, 2);
        assert_eq!(r.avg_waiting_s(), 60.0);
    }

    #[test]
    fn shard_assignment_is_sticky() {
        let mut r = Recorder::new(2, 1);
        r.on_assigned(0, 3);
        r.on_assigned(0, 1); // later calls don't reroute the record
        r.on_assigned(1, 0);
        assert_eq!(r.tasks[0].assigned_shard, Some(3));
        assert_eq!(r.tasks[1].assigned_shard, Some(0));
    }

    #[test]
    fn gang_counters() {
        let mut r = Recorder::new(3, 1);
        r.on_gang_arrival(2);
        assert!(r.tasks[2].gang && !r.tasks[0].gang);
        r.on_gang_holds(3);
        r.on_gang_holds_expired(2);
        r.on_gang_dispatch(2, 8, 8, 2, 2, 0.25);
        assert_eq!(r.gang_holds_placed, 3);
        assert_eq!(r.gang_holds_expired, 2);
        assert_eq!(r.gang_partial_dispatches, 0);
        assert_eq!(r.tasks[2].servers_spanned, 2);
        assert_eq!(r.tasks[2].span_excess, 0);
        assert_eq!(r.tasks[2].fabric_cost, 0.25);
        // a fragmented dispatch records its excess; a partial one trips the
        // all-or-nothing alarm
        r.on_gang_dispatch(2, 8, 8, 4, 2, 0.5);
        assert_eq!(r.tasks[2].span_excess, 2);
        r.on_gang_dispatch(2, 5, 8, 2, 2, 0.25);
        assert_eq!(r.gang_partial_dispatches, 1);
    }

    #[test]
    fn singleton_placement_and_steal_hooks() {
        let mut r = Recorder::new(2, 1);
        r.on_singleton_dispatch(0, 2, 0.0625, 2);
        assert_eq!(r.tasks[0].placed_gpus, 2);
        assert_eq!(r.tasks[0].islands_spanned, 2);
        assert!((r.tasks[0].fabric_cost - 0.0625).abs() < 1e-12);
        // a recovery re-dispatch overwrites with the newest placement
        r.on_singleton_dispatch(0, 2, 0.007, 1);
        assert_eq!(r.tasks[0].islands_spanned, 1);
        r.on_assigned(1, 0);
        r.on_stolen(1, 3);
        assert_eq!(r.tasks[1].stolen_by, Some(3));
        assert_eq!(r.tasks[1].assigned_shard, Some(0), "original routing kept");
    }

    #[test]
    fn oom_counting() {
        let mut r = Recorder::new(3, 1);
        r.on_oom(1);
        r.on_oom(1);
        r.on_oom(2);
        assert_eq!(r.oom_total, 3);
        assert_eq!(r.tasks[1].oom_crashes, 2);
    }

    #[test]
    fn shed_lifecycle_and_open_growth() {
        let mut r = Recorder::new(0, 1);
        assert!(!r.open_loop);
        r.ensure_task(0);
        r.on_arrival(0, 5.0);
        r.ensure_task(1);
        r.on_arrival(1, 7.0);
        r.on_shed(1, 7.0, false);
        r.ensure_task(2);
        r.on_arrival(2, 9.0);
        r.on_shed(2, 9.0, true);
        assert_eq!(r.tasks.len(), 3);
        assert_eq!(r.shed_total, 2);
        assert_eq!(r.shed_at_door, 1);
        assert_eq!(r.tasks[0].shed_s, None);
        assert_eq!(r.tasks[1].shed_s, Some(7.0));
        // shed tasks never dispatch: waiting/JCT aggregates skip them
        r.on_dispatch(0, 20.0);
        r.on_completion(0, 40.0);
        assert_eq!(r.avg_waiting_s(), 15.0);
        assert_eq!(r.completed_count(), 1);
        // re-ensuring an existing id is a no-op
        r.ensure_task(1);
        assert_eq!(r.tasks.len(), 3);
    }

    #[test]
    fn utilization_windows_close_on_schedule() {
        let mut r = Recorder::new(1, 2);
        r.util_window_s = 10.0;
        for i in 0..100 {
            let t = (i + 1) as f64;
            r.on_sample(0, t, 1.0, 8.0, 0.6, 200.0);
            r.on_sample(1, t, 1.0, 4.0, 0.2, 100.0);
        }
        assert_eq!(r.util_windows.len(), 10);
        for &(_, smact, mem) in &r.util_windows {
            assert!((smact - 0.4).abs() < 1e-9, "window smact {smact}");
            assert!((mem - 6.0).abs() < 1e-9, "window mem {mem}");
        }
        // windowing off by default: no accumulation
        let mut q = Recorder::new(1, 1);
        q.on_sample(0, 1.0, 1.0, 1.0, 0.5, 60.0);
        assert!(q.util_windows.is_empty());
    }

    #[test]
    fn timeline_downsampling() {
        let mut r = Recorder::new(1, 1);
        r.timeline_stride = 10;
        for i in 0..100 {
            r.on_sample(0, i as f64, 1.0, 1.0, 0.1, 60.0);
        }
        assert_eq!(r.timelines[0].len(), 10);
    }

    #[test]
    fn timeline_stride_zero_keeps_no_points() {
        let mut r = Recorder::new(1, 1);
        r.timeline_stride = 0;
        for i in 0..100 {
            r.on_sample(0, i as f64, 1.0, 1.0, 0.1, 60.0);
        }
        assert!(r.timelines[0].is_empty());
        // the integrals are untouched by the timeline switch
        assert!(r.total_energy_mj() > 0.0);
    }

    #[test]
    fn queue_delay_and_jct_sketches_feed_in_full_mode() {
        let mut r = Recorder::new(2, 1);
        r.on_arrival(0, 0.0);
        r.on_dispatch(0, 30.0);
        r.on_dispatch(0, 500.0); // re-dispatch: NOT a second delay sample
        r.on_completion(0, 600.0);
        r.on_arrival(1, 10.0);
        r.on_dispatch(1, 20.0);
        assert_eq!(r.queue_delay.count(), 2);
        assert_eq!(r.jct.count(), 1);
        // ±5% sketch guarantee around the nearest-rank order statistics
        // (delays sorted [10, 30]: p50 rank rounds to the second element)
        assert!((r.queue_delay.percentile(50.0) - 30.0).abs() <= 30.0 * 0.06);
        assert!((r.jct.percentile(50.0) - 600.0).abs() <= 600.0 * 0.06);
    }

    #[test]
    fn stream_mode_folds_terminals_and_matches_full_aggregates() {
        let mut full = Recorder::new(3, 1);
        let mut st = Recorder::new(0, 1);
        st.enable_stream();
        assert!(st.stream());
        for r in [&mut full, &mut st] {
            r.ensure_task(0);
            r.on_arrival(0, 0.0);
            r.on_assigned(0, 0);
            r.on_dispatch(0, 60.0);
            r.on_completion(0, 300.0);
            r.ensure_task(1);
            r.on_arrival(1, 10.0);
            r.on_assigned(1, 1);
            r.on_shed(1, 10.0, true);
            r.ensure_task(2);
            r.on_arrival(2, 20.0);
            r.on_assigned(2, 0);
            r.on_dispatch(2, 80.0); // still running at the horizon
        }
        // in-flight record answers queries, folded ones are gone
        assert_eq!(st.oom_crashes_of(2), 0);
        st.finalize();
        full.finalize(); // full mode: no-op
        assert!(st.tasks.is_empty(), "stream keeps no per-task table");
        assert!(st.live.is_empty(), "finalize drains the in-flight map");
        // each task reached a terminal fold (or the horizon) before the
        // next arrived, so the in-flight map never held more than one
        assert_eq!(st.live_high_water, 1, "peak in-flight map size");
        assert_eq!(full.live_high_water, 0, "full mode never touches the map");
        assert_eq!(st.offered(), full.offered());
        assert_eq!(st.completed_count(), full.completed_count());
        assert!((st.avg_waiting_s() - full.avg_waiting_s()).abs() < 1e-9);
        assert!((st.avg_jct_s() - full.avg_jct_s()).abs() < 1e-9);
        assert!((st.avg_execution_s() - full.avg_execution_s()).abs() < 1e-9);
        assert_eq!(st.shed_total, 1);
        assert_eq!(st.agg.per_shard.len(), 2);
        assert_eq!(st.agg.per_shard[0].tasks, 2);
        assert_eq!(st.agg.per_shard[1].tasks, 1);
        assert_eq!(st.queue_delay.count(), full.queue_delay.count());
    }

    #[test]
    fn fault_counters_accumulate_by_kind() {
        let mut r = Recorder::new(1, 4);
        r.on_fault(FaultKind::Gpu);
        r.on_fault(FaultKind::Gpu);
        r.on_fault(FaultKind::Server);
        r.on_fault(FaultKind::Link);
        assert_eq!(r.faults_injected, [2, 1, 1]);
        r.on_fault_interruption(FaultKind::Server);
        r.on_fault_interruption(FaultKind::Server);
        r.on_fault_interruption(FaultKind::Gpu);
        assert_eq!(r.fault_interruptions, [1, 2, 0]);
        r.on_fault_relaunch();
        r.on_fault_relaunch();
        r.on_fault_failed();
        assert_eq!(r.fault_relaunches, 2);
        assert_eq!(r.fault_failed, 1);
        r.on_fault_repair(300.0, 300.0);
        r.on_fault_repair(100.0, 400.0); // server fault: 4 GPUs down
        assert_eq!(r.fault_repairs, 2);
        assert!((r.repair_time_sum_s - 400.0).abs() < 1e-12);
        assert!((r.downtime_gpu_s - 700.0).abs() < 1e-12);
        r.on_holds_invalidated(3);
        assert_eq!(r.holds_invalidated, 3);
        let text = r.registry().render();
        for series in [
            "carma_fault_strikes_total 4",
            "carma_fault_interruptions_total 3",
            "carma_fault_relaunches_total 2",
            "carma_fault_repairs_total 2",
        ] {
            assert!(text.contains(series), "missing {series} in:\n{text}");
        }
    }

    #[test]
    fn decision_provenance_aggregates() {
        let mut r = Recorder::new(1, 1);
        let mut ex = Explain::default();
        ex.servers_admitted = 2;
        ex.servers_rejected = 1;
        ex.gpus_eligible = 5;
        ex.candidates = 3;
        ex.rejects[RejectReason::NoFit.index()] = 2;
        r.on_decision(DecisionOutcome::Placed, &ex);
        r.on_decision(DecisionOutcome::NoFit, &ex);
        assert_eq!(r.decisions.decisions, 2);
        assert_eq!(r.decisions.placed, 1);
        assert_eq!(r.decisions.no_fit, 1);
        assert_eq!(r.decisions.inadmissible, 0);
        assert_eq!(r.decisions.servers_admitted, 4);
        assert_eq!(r.decisions.gpus_eligible, 10);
        assert_eq!(r.decisions.candidates, 6);
        assert_eq!(r.decisions.rejects[RejectReason::NoFit.index()], 4);
    }

    #[test]
    fn registry_renders_the_core_series() {
        let mut r = Recorder::new(1, 1);
        r.on_arrival(0, 0.0);
        r.on_dispatch(0, 30.0);
        r.on_completion(0, 90.0);
        let text = r.registry().render();
        for series in [
            "carma_offered_total",
            "carma_completed_total",
            "carma_queue_delay_seconds_bucket",
            "carma_jct_seconds_count",
        ] {
            assert!(text.contains(series), "missing {series} in:\n{text}");
        }
    }
}
