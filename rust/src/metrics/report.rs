//! Run report: the metric set every paper experiment prints.

use crate::util::json::{self, Json};
use crate::util::units::to_minutes;

use super::recorder::Recorder;

#[derive(Debug, Clone)]
pub struct RunReport {
    pub label: String,
    pub trace_total_min: f64,
    pub avg_waiting_min: f64,
    pub avg_execution_min: f64,
    pub avg_jct_min: f64,
    pub oom_crashes: u64,
    pub energy_mj: f64,
    pub mean_smact: f64,
    pub mean_mem_used_gb: f64,
    pub completed: usize,
    pub total_tasks: usize,
}

impl RunReport {
    pub fn from_recorder(label: &str, r: &Recorder) -> RunReport {
        RunReport {
            label: label.to_string(),
            trace_total_min: to_minutes(r.trace_total_s()),
            avg_waiting_min: to_minutes(r.avg_waiting_s()),
            avg_execution_min: to_minutes(r.avg_execution_s()),
            avg_jct_min: to_minutes(r.avg_jct_s()),
            oom_crashes: r.oom_total,
            energy_mj: r.total_energy_mj(),
            mean_smact: r.mean_smact(),
            mean_mem_used_gb: r.mean_mem_used_gb(),
            completed: r.completed_count(),
            total_tasks: r.tasks.len(),
        }
    }

    pub fn header() -> String {
        format!(
            "{:<42} {:>9} {:>9} {:>9} {:>9} {:>6} {:>9} {:>7} {:>8}",
            "run", "total(m)", "wait(m)", "exec(m)", "JCT(m)", "#OOM", "E(MJ)", "SMACT", "mem(GB)"
        )
    }

    pub fn row(&self) -> String {
        format!(
            "{:<42} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>6} {:>9.2} {:>6.1}% {:>8.1}",
            self.label,
            self.trace_total_min,
            self.avg_waiting_min,
            self.avg_execution_min,
            self.avg_jct_min,
            self.oom_crashes,
            self.energy_mj,
            self.mean_smact * 100.0,
            self.mean_mem_used_gb,
        )
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("label", json::s(&self.label)),
            ("trace_total_min", json::num(self.trace_total_min)),
            ("avg_waiting_min", json::num(self.avg_waiting_min)),
            ("avg_execution_min", json::num(self.avg_execution_min)),
            ("avg_jct_min", json::num(self.avg_jct_min)),
            ("oom_crashes", json::num(self.oom_crashes as f64)),
            ("energy_mj", json::num(self.energy_mj)),
            ("mean_smact", json::num(self.mean_smact)),
            ("mean_mem_used_gb", json::num(self.mean_mem_used_gb)),
            ("completed", json::num(self.completed as f64)),
            ("total_tasks", json::num(self.total_tasks as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_from_recorder() {
        let mut r = Recorder::new(1, 1);
        r.on_arrival(0, 0.0);
        r.on_dispatch(0, 60.0);
        r.on_completion(0, 660.0);
        r.on_sample(0, 1.0, 660.0, 5.0, 0.5, 200.0);
        let rep = RunReport::from_recorder("test", &r);
        assert!((rep.trace_total_min - 11.0).abs() < 1e-9);
        assert!((rep.avg_waiting_min - 1.0).abs() < 1e-9);
        assert!((rep.avg_execution_min - 10.0).abs() < 1e-9);
        assert_eq!(rep.completed, 1);
        let j = rep.to_json();
        assert_eq!(j.f64_of("oom_crashes"), 0.0);
        assert!(!rep.row().is_empty());
        assert!(!RunReport::header().is_empty());
    }
}
