//! Run report: the metric set every paper experiment prints.

use crate::coordinator::placement::RejectReason;
use crate::util::json::{self, Json};
use crate::util::units::to_minutes;

use super::recorder::{DecisionAgg, Recorder};

/// Per-shard counters of the sharded coordinator (DESIGN.md §9). A serial
/// run reports exactly one entry (shard 0).
#[derive(Debug, Clone)]
pub struct ShardStat {
    pub shard: usize,
    /// Tasks admission routed to this shard (original routing — a later
    /// steal does not reattribute the task).
    pub tasks: usize,
    /// Mapping decisions this shard's mapper dispatched (re-dispatches
    /// after recovery included).
    pub decisions: u64,
    /// Mean queueing delay (first dispatch − arrival) of this shard's tasks.
    pub mean_wait_min: f64,
    /// Tasks this shard stole off sibling queues (DESIGN.md §12; zero
    /// unless `[coordinator] steal` is on).
    pub steals: u64,
}

impl ShardStat {
    /// Mapping throughput in decisions per simulated minute.
    pub fn decisions_per_min(&self, trace_total_min: f64) -> f64 {
        if trace_total_min <= 0.0 {
            0.0
        } else {
            self.decisions as f64 / trace_total_min
        }
    }
}

/// Gang-lane counters (DESIGN.md §11). All zeros when the trace has no
/// distributed jobs — the section is always present so results JSON stays
/// byte-diffable across configurations of the same binary.
#[derive(Debug, Clone, Default)]
pub struct GangStat {
    /// Distributed jobs admission routed to the gang lane.
    pub gangs: usize,
    pub completed: usize,
    /// Gangs whose dispatch spanned more than one server.
    pub cross_server: usize,
    /// Highest server count any single gang spanned.
    pub max_servers_spanned: usize,
    /// Mean queueing delay (first dispatch − arrival) of gang tasks.
    pub mean_wait_min: f64,
    pub max_wait_min: f64,
    /// Σ over gangs of (servers spanned − packing minimum): the placement
    /// fragmentation the fabric-cost ranking is trying to minimize.
    pub frag_excess: usize,
    /// Mean fabric ring cost (`Fabric::gang_cost`, per-GB collective
    /// transfer cost) over dispatched gangs — the `[fabric]` bandwidth
    /// classes surface here.
    pub mean_fabric_cost: f64,
    /// Partial-hold lifecycle counters.
    pub holds_placed: u64,
    pub holds_expired: u64,
    /// Dispatches violating all-or-nothing — MUST be zero, observable in
    /// the results JSON (the §11 acceptance invariant).
    pub partial_dispatches: u64,
}

/// Singleton placement counters (DESIGN.md §12). Always present — zeros
/// when the trace has no multi-GPU server-local tasks — so results JSON
/// stays byte-diffable across configurations of the same binary. The
/// achieved fabric cost is recorded in island-blind and island-aware runs
/// alike, which is what `repro placement_scale` compares.
#[derive(Debug, Clone, Default)]
pub struct PlacementStat {
    /// Multi-GPU (≥ 2 device) server-local tasks that dispatched.
    pub multi_gpu_singletons: usize,
    /// Of those, dispatches that landed entirely inside one NVLink island.
    pub single_island: usize,
    /// Mean achieved fabric ring cost (`Fabric::set_cost`) over their
    /// LAST dispatches — the gang section's `mean_fabric_cost` twin.
    pub mean_fabric_cost: f64,
    pub max_fabric_cost: f64,
}

/// Steady-state service counters (open-loop mode, DESIGN.md §13). Always
/// present — zeros/batch values in closed-loop runs — so results JSON stays
/// byte-diffable across configurations of the same binary. The queueing-
/// delay percentiles are computed over every dispatched task in either
/// mode, so the keys are always populated.
#[derive(Debug, Clone, Default)]
pub struct ServiceStat {
    /// Open-loop service run (arrival-driven intake with bounded queues).
    pub open_loop: bool,
    /// Tasks the arrival process offered (= total_tasks in open mode).
    pub offered: usize,
    /// Arrivals dropped at intake by the bounded admission layer.
    pub shed: u64,
    /// Subset of `shed` dropped under cluster-wide backpressure (every
    /// shard at the queue cap).
    pub shed_at_door: u64,
    /// shed / offered (0 when nothing was offered).
    pub rejection_rate: f64,
    /// Queueing delay (first dispatch − arrival) percentiles, seconds.
    pub queue_delay_p50_s: f64,
    pub queue_delay_p99_s: f64,
    pub queue_delay_p999_s: f64,
    /// Completed sliding utilization windows (0 in closed-loop runs).
    pub util_windows: usize,
    /// Mean / peak of the per-window GPU-time-weighted SMACT means.
    pub win_smact_mean: f64,
    pub win_smact_peak: f64,
    /// Mean / peak of the per-window memory means (GB per GPU).
    pub win_mem_mean_gb: f64,
    pub win_mem_peak_gb: f64,
}

/// Fault-injection and recovery counters (DESIGN.md §15). Always present
/// — all zeros (availability 1.0) when faults are off — so results JSON
/// stays byte-diffable across configurations of the same binary.
#[derive(Debug, Clone, Default)]
pub struct ResilienceStat {
    /// Fault strikes committed, by kind.
    pub faults_gpu: u64,
    pub faults_server: u64,
    pub faults_link: u64,
    /// Resident tasks killed, by the striking fault's kind (link faults
    /// degrade but never kill).
    pub interruptions_gpu: u64,
    pub interruptions_server: u64,
    /// Fault-cause re-queues admitted back into the scheduler.
    pub relaunches: u64,
    /// Tasks permanently failed on an exhausted relaunch budget.
    pub fault_failed: u64,
    /// Completed repairs and their mean outage (MTTR).
    pub repairs: u64,
    pub mttr_s: f64,
    /// GPU-seconds of quarantined capacity over the run.
    pub downtime_gpu_s: f64,
    /// 1 − downtime / (GPUs × trace length): fraction of capacity-time
    /// that stayed placeable. Exactly 1.0 without faults.
    pub availability: f64,
    /// completed / offered — the survival headline under chaos.
    pub goodput: f64,
    /// Gang reservations invalidated because their server died.
    pub holds_invalidated: u64,
}

#[derive(Debug, Clone)]
pub struct RunReport {
    pub label: String,
    pub trace_total_min: f64,
    pub avg_waiting_min: f64,
    pub avg_execution_min: f64,
    pub avg_jct_min: f64,
    pub oom_crashes: u64,
    pub energy_mj: f64,
    pub mean_smact: f64,
    pub mean_mem_used_gb: f64,
    pub completed: usize,
    pub total_tasks: usize,
    /// Per-shard queueing delay and mapping throughput — one entry per
    /// configured coordinator shard (idle shards report zero tasks).
    pub per_shard: Vec<ShardStat>,
    /// Gang-lane counters (zeros when the trace has no distributed jobs).
    pub gang: GangStat,
    /// Singleton placement counters (zeros without multi-GPU singletons).
    pub placement: PlacementStat,
    /// Steady-state service counters (zeros in closed-loop batch runs,
    /// except the queue-delay percentiles which are always computed).
    pub service: ServiceStat,
    /// Aggregated decision provenance (DESIGN.md §14): outcome counts and
    /// the eligibility-filter census summed over every committed singleton
    /// mapping decision. Always present, zeros when nothing was decided.
    pub decisions: DecisionAgg,
    /// Fault-injection and recovery counters (DESIGN.md §15): zeros with
    /// availability 1.0 when faults are off.
    pub resilience: ResilienceStat,
    /// Trace records lost to failed writes (`obs` section) — 0 when
    /// tracing is off or healthy, non-zero flags an incomplete trace file
    /// that `carma trace analyze` would under-count.
    pub trace_dropped: u64,
}

impl RunReport {
    pub fn from_recorder(label: &str, r: &Recorder) -> RunReport {
        RunReport {
            label: label.to_string(),
            trace_total_min: to_minutes(r.trace_total_s()),
            avg_waiting_min: to_minutes(r.avg_waiting_s()),
            avg_execution_min: to_minutes(r.avg_execution_s()),
            avg_jct_min: to_minutes(r.avg_jct_s()),
            oom_crashes: r.oom_total,
            energy_mj: r.total_energy_mj(),
            mean_smact: r.mean_smact(),
            mean_mem_used_gb: r.mean_mem_used_gb(),
            completed: r.completed_count(),
            total_tasks: r.offered(),
            per_shard: shard_stats(r),
            gang: gang_stats(r),
            placement: placement_stats(r),
            service: service_stats(r),
            decisions: r.decisions.clone(),
            resilience: resilience_stats(r),
            trace_dropped: r.trace_dropped,
        }
    }

    /// Total mapping decisions across shards (dispatches incl. recovery).
    pub fn total_decisions(&self) -> u64 {
        self.per_shard.iter().map(|s| s.decisions).sum()
    }

    pub fn header() -> String {
        format!(
            "{:<42} {:>9} {:>9} {:>9} {:>9} {:>6} {:>9} {:>7} {:>8}",
            "run", "total(m)", "wait(m)", "exec(m)", "JCT(m)", "#OOM", "E(MJ)", "SMACT", "mem(GB)"
        )
    }

    pub fn row(&self) -> String {
        format!(
            "{:<42} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>6} {:>9.2} {:>6.1}% {:>8.1}",
            self.label,
            self.trace_total_min,
            self.avg_waiting_min,
            self.avg_execution_min,
            self.avg_jct_min,
            self.oom_crashes,
            self.energy_mj,
            self.mean_smact * 100.0,
            self.mean_mem_used_gb,
        )
    }

    pub fn to_json(&self) -> Json {
        let placement = json::obj(vec![
            (
                "multi_gpu_singletons",
                json::num(self.placement.multi_gpu_singletons as f64),
            ),
            ("single_island", json::num(self.placement.single_island as f64)),
            (
                "mean_fabric_cost",
                json::num(self.placement.mean_fabric_cost),
            ),
            ("max_fabric_cost", json::num(self.placement.max_fabric_cost)),
        ]);
        let gang = json::obj(vec![
            ("gangs", json::num(self.gang.gangs as f64)),
            ("completed", json::num(self.gang.completed as f64)),
            ("cross_server", json::num(self.gang.cross_server as f64)),
            ("max_servers_spanned", json::num(self.gang.max_servers_spanned as f64)),
            ("mean_wait_min", json::num(self.gang.mean_wait_min)),
            ("max_wait_min", json::num(self.gang.max_wait_min)),
            ("frag_excess", json::num(self.gang.frag_excess as f64)),
            ("mean_fabric_cost", json::num(self.gang.mean_fabric_cost)),
            ("holds_placed", json::num(self.gang.holds_placed as f64)),
            ("holds_expired", json::num(self.gang.holds_expired as f64)),
            ("partial_dispatches", json::num(self.gang.partial_dispatches as f64)),
        ]);
        let shards = self
            .per_shard
            .iter()
            .map(|s| {
                json::obj(vec![
                    ("shard", json::num(s.shard as f64)),
                    ("tasks", json::num(s.tasks as f64)),
                    ("decisions", json::num(s.decisions as f64)),
                    ("mean_wait_min", json::num(s.mean_wait_min)),
                    ("steals", json::num(s.steals as f64)),
                ])
            })
            .collect();
        let service = json::obj(vec![
            ("open_loop", json::num(if self.service.open_loop { 1.0 } else { 0.0 })),
            ("offered", json::num(self.service.offered as f64)),
            ("shed", json::num(self.service.shed as f64)),
            ("shed_at_door", json::num(self.service.shed_at_door as f64)),
            ("rejection_rate", json::num(self.service.rejection_rate)),
            ("queue_delay_p50_s", json::num(self.service.queue_delay_p50_s)),
            ("queue_delay_p99_s", json::num(self.service.queue_delay_p99_s)),
            ("queue_delay_p999_s", json::num(self.service.queue_delay_p999_s)),
            ("util_windows", json::num(self.service.util_windows as f64)),
            ("win_smact_mean", json::num(self.service.win_smact_mean)),
            ("win_smact_peak", json::num(self.service.win_smact_peak)),
            ("win_mem_mean_gb", json::num(self.service.win_mem_mean_gb)),
            ("win_mem_peak_gb", json::num(self.service.win_mem_peak_gb)),
        ]);
        let resilience = json::obj(vec![
            ("faults_gpu", json::num(self.resilience.faults_gpu as f64)),
            ("faults_server", json::num(self.resilience.faults_server as f64)),
            ("faults_link", json::num(self.resilience.faults_link as f64)),
            (
                "interruptions_gpu",
                json::num(self.resilience.interruptions_gpu as f64),
            ),
            (
                "interruptions_server",
                json::num(self.resilience.interruptions_server as f64),
            ),
            ("relaunches", json::num(self.resilience.relaunches as f64)),
            ("fault_failed", json::num(self.resilience.fault_failed as f64)),
            ("repairs", json::num(self.resilience.repairs as f64)),
            ("mttr_s", json::num(self.resilience.mttr_s)),
            ("downtime_gpu_s", json::num(self.resilience.downtime_gpu_s)),
            ("availability", json::num(self.resilience.availability)),
            ("goodput", json::num(self.resilience.goodput)),
            (
                "holds_invalidated",
                json::num(self.resilience.holds_invalidated as f64),
            ),
        ]);
        let rejects = json::obj(
            RejectReason::ALL
                .iter()
                .map(|r| (r.name(), json::num(self.decisions.rejects[r.index()] as f64)))
                .collect(),
        );
        let decisions = json::obj(vec![
            ("decisions", json::num(self.decisions.decisions as f64)),
            ("placed", json::num(self.decisions.placed as f64)),
            ("no_fit", json::num(self.decisions.no_fit as f64)),
            ("inadmissible", json::num(self.decisions.inadmissible as f64)),
            (
                "servers_admitted",
                json::num(self.decisions.servers_admitted as f64),
            ),
            (
                "servers_rejected",
                json::num(self.decisions.servers_rejected as f64),
            ),
            ("gpus_eligible", json::num(self.decisions.gpus_eligible as f64)),
            ("candidates", json::num(self.decisions.candidates as f64)),
            ("rejects", rejects),
        ]);
        json::obj(vec![
            ("label", json::s(&self.label)),
            ("trace_total_min", json::num(self.trace_total_min)),
            ("avg_waiting_min", json::num(self.avg_waiting_min)),
            ("avg_execution_min", json::num(self.avg_execution_min)),
            ("avg_jct_min", json::num(self.avg_jct_min)),
            ("oom_crashes", json::num(self.oom_crashes as f64)),
            ("energy_mj", json::num(self.energy_mj)),
            ("mean_smact", json::num(self.mean_smact)),
            ("mean_mem_used_gb", json::num(self.mean_mem_used_gb)),
            ("completed", json::num(self.completed as f64)),
            ("total_tasks", json::num(self.total_tasks as f64)),
            ("per_shard", json::arr(shards)),
            ("gang", gang),
            ("placement", placement),
            ("placement_decisions", decisions),
            ("service", service),
            ("resilience", resilience),
            // always present, like every section: 0 = no trace or no loss
            (
                "obs",
                json::obj(vec![("trace_dropped", json::num(self.trace_dropped as f64))]),
            ),
        ])
    }
}

/// Aggregate the recorder's fault counters (DESIGN.md §15). Plain running
/// sums in both collection modes; availability defaults to 1.0 on an empty
/// trace (no time elapsed = nothing was lost).
fn resilience_stats(r: &Recorder) -> ResilienceStat {
    let offered = r.offered();
    let capacity_s = r.energy_j.len() as f64 * r.trace_total_s();
    ResilienceStat {
        faults_gpu: r.faults_injected[0],
        faults_server: r.faults_injected[1],
        faults_link: r.faults_injected[2],
        interruptions_gpu: r.fault_interruptions[0],
        interruptions_server: r.fault_interruptions[1],
        relaunches: r.fault_relaunches,
        fault_failed: r.fault_failed,
        repairs: r.fault_repairs,
        mttr_s: if r.fault_repairs == 0 {
            0.0
        } else {
            r.repair_time_sum_s / r.fault_repairs as f64
        },
        downtime_gpu_s: r.downtime_gpu_s,
        availability: if capacity_s <= 0.0 {
            1.0
        } else {
            (1.0 - r.downtime_gpu_s / capacity_s).max(0.0)
        },
        goodput: if offered == 0 {
            0.0
        } else {
            r.completed_count() as f64 / offered as f64
        },
        holds_invalidated: r.holds_invalidated,
    }
}

/// Aggregate the recorder's per-task singleton placement records: the
/// achieved-interconnect-cost view of every multi-GPU server-local
/// dispatch (1-GPU placements always cost zero and would only dilute the
/// mean the `placement_scale` comparison rests on).
fn placement_stats(r: &Recorder) -> PlacementStat {
    if r.stream() {
        return PlacementStat {
            multi_gpu_singletons: r.agg.multi_gpu_singletons,
            single_island: r.agg.single_island,
            mean_fabric_cost: if r.agg.multi_gpu_singletons == 0 {
                0.0
            } else {
                r.agg.place_cost_sum / r.agg.multi_gpu_singletons as f64
            },
            max_fabric_cost: r.agg.place_max_cost,
        };
    }
    let mut s = PlacementStat::default();
    let mut cost_sum = 0.0f64;
    for t in r.tasks.iter().filter(|t| !t.gang && t.placed_gpus >= 2) {
        s.multi_gpu_singletons += 1;
        if t.islands_spanned <= 1 {
            s.single_island += 1;
        }
        cost_sum += t.fabric_cost;
        s.max_fabric_cost = s.max_fabric_cost.max(t.fabric_cost);
    }
    if s.multi_gpu_singletons > 0 {
        s.mean_fabric_cost = cost_sum / s.multi_gpu_singletons as f64;
    }
    s
}

/// Aggregate the recorder's service-mode counters (DESIGN.md §13). The
/// queueing-delay percentiles come from the recorder's streaming
/// `LogHistogram` sketch in both collection modes — O(buckets) state, ±5%
/// relative error vs the nearest-rank order statistic (`obs::sketch`) —
/// covering every first dispatch. Shed counters and utilization windows
/// are only nonzero in open-loop runs (closed-loop recorders never shed
/// and keep windowing off).
fn service_stats(r: &Recorder) -> ServiceStat {
    let offered = r.offered();
    let mut s = ServiceStat {
        open_loop: r.open_loop,
        offered,
        shed: r.shed_total,
        shed_at_door: r.shed_at_door,
        rejection_rate: if offered == 0 {
            0.0
        } else {
            r.shed_total as f64 / offered as f64
        },
        queue_delay_p50_s: r.queue_delay.percentile(50.0),
        queue_delay_p99_s: r.queue_delay.percentile(99.0),
        queue_delay_p999_s: r.queue_delay.percentile(99.9),
        util_windows: r.util_windows.len(),
        ..ServiceStat::default()
    };
    if !r.util_windows.is_empty() {
        let n = r.util_windows.len() as f64;
        for &(_, smact, mem) in &r.util_windows {
            s.win_smact_mean += smact / n;
            s.win_smact_peak = s.win_smact_peak.max(smact);
            s.win_mem_mean_gb += mem / n;
            s.win_mem_peak_gb = s.win_mem_peak_gb.max(mem);
        }
    }
    s
}

/// Aggregate the recorder's per-task gang routing into the lane counters.
fn gang_stats(r: &Recorder) -> GangStat {
    let mut s = GangStat {
        holds_placed: r.gang_holds_placed,
        holds_expired: r.gang_holds_expired,
        partial_dispatches: r.gang_partial_dispatches,
        ..GangStat::default()
    };
    if r.stream() {
        s.gangs = r.agg.gangs;
        s.completed = r.agg.gang_completed;
        s.cross_server = r.agg.cross_server;
        s.max_servers_spanned = r.agg.max_servers_spanned;
        s.frag_excess = r.agg.frag_excess;
        if r.agg.gang_waited > 0 {
            s.mean_wait_min = to_minutes(r.agg.gang_wait_sum / r.agg.gang_waited as f64);
            s.mean_fabric_cost = r.agg.gang_cost_sum / r.agg.gang_waited as f64;
        }
        s.max_wait_min = to_minutes(r.agg.gang_max_wait_s);
        return s;
    }
    let mut wait_sum = 0.0f64;
    let mut cost_sum = 0.0f64;
    let mut waited = 0usize;
    for t in r.tasks.iter().filter(|t| t.gang) {
        s.gangs += 1;
        if t.completed_s.is_some() {
            s.completed += 1;
        }
        if t.servers_spanned > 1 {
            s.cross_server += 1;
        }
        s.max_servers_spanned = s.max_servers_spanned.max(t.servers_spanned);
        s.frag_excess += t.span_excess;
        if let Some(d) = t.dispatched_s {
            let w = d - t.arrival_s;
            wait_sum += w;
            cost_sum += t.fabric_cost;
            waited += 1;
            s.max_wait_min = s.max_wait_min.max(to_minutes(w));
        }
    }
    if waited > 0 {
        s.mean_wait_min = to_minutes(wait_sum / waited as f64);
        s.mean_fabric_cost = cost_sum / waited as f64;
    }
    s
}

/// Aggregate the recorder's per-task shard routing into per-shard counters.
/// Covers every configured shard — idle shards report zero tasks rather
/// than vanishing (least-loaded routing can leave trailing shards unused).
fn shard_stats(r: &Recorder) -> Vec<ShardStat> {
    if r.stream() {
        let n_shards = r.agg.per_shard.len().max(r.n_shards);
        return (0..n_shards)
            .map(|s| {
                let a = r.agg.per_shard.get(s);
                let (tasks, decisions, wait_sum, waited, steals) = a.map_or(
                    (0, 0, 0.0, 0, 0),
                    |a| (a.tasks, a.decisions, a.wait_sum, a.waited, a.steals),
                );
                ShardStat {
                    shard: s,
                    tasks,
                    decisions,
                    mean_wait_min: if waited == 0 {
                        0.0
                    } else {
                        to_minutes(wait_sum / waited as f64)
                    },
                    steals,
                }
            })
            .collect();
    }
    let n_shards = r
        .tasks
        .iter()
        .filter_map(|t| t.assigned_shard)
        .max()
        .map_or(0, |m| m + 1)
        .max(r.n_shards);
    (0..n_shards)
        .map(|s| {
            let mut tasks = 0usize;
            let mut decisions = 0u64;
            let mut wait_sum = 0.0f64;
            let mut waited = 0usize;
            for t in r.tasks.iter().filter(|t| t.assigned_shard == Some(s)) {
                tasks += 1;
                decisions += t.dispatches as u64;
                if let Some(d) = t.dispatched_s {
                    wait_sum += d - t.arrival_s;
                    waited += 1;
                }
            }
            let steals = r
                .tasks
                .iter()
                .filter(|t| t.stolen_by == Some(s))
                .count() as u64;
            ShardStat {
                shard: s,
                tasks,
                decisions,
                mean_wait_min: if waited == 0 {
                    0.0
                } else {
                    to_minutes(wait_sum / waited as f64)
                },
                steals,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_from_recorder() {
        let mut r = Recorder::new(1, 1);
        r.on_arrival(0, 0.0);
        r.on_dispatch(0, 60.0);
        r.on_completion(0, 660.0);
        r.on_sample(0, 1.0, 660.0, 5.0, 0.5, 200.0);
        let rep = RunReport::from_recorder("test", &r);
        assert!((rep.trace_total_min - 11.0).abs() < 1e-9);
        assert!((rep.avg_waiting_min - 1.0).abs() < 1e-9);
        assert!((rep.avg_execution_min - 10.0).abs() < 1e-9);
        assert_eq!(rep.completed, 1);
        let j = rep.to_json();
        assert_eq!(j.f64_of("oom_crashes"), 0.0);
        assert!(!rep.row().is_empty());
        assert!(!RunReport::header().is_empty());
    }

    #[test]
    fn per_shard_stats_aggregate_routing() {
        let mut r = Recorder::new(4, 1);
        for (task, shard, arr, disp) in
            [(0usize, 0usize, 0.0, 60.0), (1, 1, 0.0, 120.0), (2, 0, 30.0, 150.0)]
        {
            r.on_arrival(task, arr);
            r.on_assigned(task, shard);
            r.on_dispatch(task, disp);
        }
        r.on_dispatch(2, 400.0); // recovery re-dispatch: decision #2, wait unchanged
        r.on_arrival(3, 5.0); // never assigned/dispatched (failed fast)
        let rep = RunReport::from_recorder("t", &r);
        assert_eq!(rep.per_shard.len(), 2);
        assert_eq!(rep.per_shard[0].tasks, 2);
        assert_eq!(rep.per_shard[0].decisions, 3);
        // shard 0 waits: 60 and 120 s -> mean 1.5 min
        assert!((rep.per_shard[0].mean_wait_min - 1.5).abs() < 1e-9);
        assert_eq!(rep.per_shard[1].tasks, 1);
        assert_eq!(rep.per_shard[1].decisions, 1);
        assert!((rep.per_shard[1].mean_wait_min - 2.0).abs() < 1e-9);
        assert_eq!(rep.total_decisions(), 4);
        assert!((rep.per_shard[0].decisions_per_min(3.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gang_section_aggregates_lane_counters() {
        let mut r = Recorder::new(3, 1);
        // singleton
        r.on_arrival(0, 0.0);
        r.on_dispatch(0, 60.0);
        // cross-server gang: waits 2 min, spans 2 of a min-1 packing
        r.on_arrival(1, 0.0);
        r.on_gang_arrival(1);
        r.on_dispatch(1, 120.0);
        r.on_gang_dispatch(1, 8, 8, 2, 1, 0.1);
        r.on_completion(1, 500.0);
        // second gang, never dispatched
        r.on_arrival(2, 10.0);
        r.on_gang_arrival(2);
        r.on_gang_holds(5);
        r.on_gang_holds_expired(2);
        let rep = RunReport::from_recorder("t", &r);
        assert_eq!(rep.gang.gangs, 2);
        assert_eq!(rep.gang.completed, 1);
        assert_eq!(rep.gang.cross_server, 1);
        assert_eq!(rep.gang.max_servers_spanned, 2);
        assert_eq!(rep.gang.frag_excess, 1);
        assert!((rep.gang.mean_fabric_cost - 0.1).abs() < 1e-12);
        assert!((rep.gang.mean_wait_min - 2.0).abs() < 1e-9);
        assert!((rep.gang.max_wait_min - 2.0).abs() < 1e-9);
        assert_eq!(rep.gang.holds_placed, 5);
        assert_eq!(rep.gang.holds_expired, 2);
        assert_eq!(rep.gang.partial_dispatches, 0);
        let j = rep.to_json();
        let g = j.get("gang").expect("gang section always present");
        assert_eq!(g.f64_of("gangs"), 2.0);
        assert_eq!(g.f64_of("partial_dispatches"), 0.0);
        // a gang-free run still carries the (zeroed) section
        let empty = RunReport::from_recorder("e", &Recorder::new(1, 1));
        assert_eq!(empty.gang.gangs, 0);
        assert_eq!(empty.to_json().get("gang").unwrap().f64_of("holds_placed"), 0.0);
    }

    #[test]
    fn placement_section_aggregates_multi_gpu_singletons() {
        let mut r = Recorder::new(4, 1);
        // 1-GPU singleton: zero-cost by definition, excluded from the mean
        r.on_singleton_dispatch(0, 1, 0.0, 1);
        // island-local pair and a split pair
        r.on_singleton_dispatch(1, 2, 0.01, 1);
        r.on_singleton_dispatch(2, 2, 0.07, 2);
        // a gang never counts here even with a recorded cost
        r.on_gang_arrival(3);
        r.on_gang_dispatch(3, 8, 8, 2, 2, 0.5);
        let rep = RunReport::from_recorder("t", &r);
        assert_eq!(rep.placement.multi_gpu_singletons, 2);
        assert_eq!(rep.placement.single_island, 1);
        assert!((rep.placement.mean_fabric_cost - 0.04).abs() < 1e-12);
        assert!((rep.placement.max_fabric_cost - 0.07).abs() < 1e-12);
        let j = rep.to_json();
        let p = j.get("placement").expect("placement section always present");
        assert_eq!(p.f64_of("multi_gpu_singletons"), 2.0);
        assert_eq!(p.f64_of("single_island"), 1.0);
        // a run without multi-GPU singletons still carries the section
        let empty = RunReport::from_recorder("e", &Recorder::new(1, 1));
        assert_eq!(empty.placement.multi_gpu_singletons, 0);
        assert_eq!(empty.placement.mean_fabric_cost, 0.0);
        assert!(empty.to_json().get("placement").is_some());
    }

    #[test]
    fn service_section_always_present_with_percentiles() {
        // closed-loop run: section exists, sheds zero, percentiles real
        let mut r = Recorder::new(3, 1);
        for (task, arr, disp) in [(0usize, 0.0, 10.0), (1, 0.0, 30.0), (2, 5.0, 105.0)] {
            r.on_arrival(task, arr);
            r.on_dispatch(task, disp);
        }
        let rep = RunReport::from_recorder("t", &r);
        assert!(!rep.service.open_loop);
        assert_eq!(rep.service.offered, 3);
        assert_eq!(rep.service.shed, 0);
        assert_eq!(rep.service.rejection_rate, 0.0);
        // delays 10, 30, 100: sketch percentiles land within ±5% of the
        // nearest-rank order statistics (p50 -> 30, p99/p999 -> 100)
        assert!((rep.service.queue_delay_p50_s - 30.0).abs() <= 30.0 * 0.06);
        assert!((rep.service.queue_delay_p99_s - 100.0).abs() <= 100.0 * 0.06);
        assert!(rep.service.queue_delay_p999_s >= rep.service.queue_delay_p99_s - 1e-9);
        let j = rep.to_json();
        let svc = j.get("service").expect("service section always present");
        assert_eq!(svc.f64_of("open_loop"), 0.0);
        assert!((svc.f64_of("queue_delay_p50_s") - 30.0).abs() <= 30.0 * 0.06);
        // even an empty run carries every percentile key
        let empty = RunReport::from_recorder("e", &Recorder::new(0, 1));
        let ej = empty.to_json();
        let es = ej.get("service").unwrap();
        for key in ["queue_delay_p50_s", "queue_delay_p99_s", "queue_delay_p999_s"] {
            assert_eq!(es.f64_of(key), 0.0, "{key} missing or nonzero");
        }
    }

    #[test]
    fn service_section_reports_sheds_and_windows() {
        let mut r = Recorder::new(4, 2);
        r.open_loop = true;
        r.util_window_s = 10.0;
        for task in 0..4usize {
            r.on_arrival(task, task as f64);
        }
        r.on_dispatch(0, 8.0);
        r.on_shed(2, 2.0, false);
        r.on_shed(3, 3.0, true);
        for i in 0..20 {
            let t = (i + 1) as f64;
            r.on_sample(0, t, 1.0, 10.0, 0.8, 250.0);
            r.on_sample(1, t, 1.0, 2.0, 0.4, 120.0);
        }
        let rep = RunReport::from_recorder("svc", &r);
        assert!(rep.service.open_loop);
        assert_eq!(rep.service.offered, 4);
        assert_eq!(rep.service.shed, 2);
        assert_eq!(rep.service.shed_at_door, 1);
        assert!((rep.service.rejection_rate - 0.5).abs() < 1e-12);
        assert_eq!(rep.service.util_windows, 2);
        assert!((rep.service.win_smact_mean - 0.6).abs() < 1e-9);
        assert!((rep.service.win_smact_peak - 0.6).abs() < 1e-9);
        assert!((rep.service.win_mem_mean_gb - 6.0).abs() < 1e-9);
        let j = rep.to_json();
        assert_eq!(j.get("service").unwrap().f64_of("shed"), 2.0);
        assert_eq!(j.get("service").unwrap().f64_of("open_loop"), 1.0);
    }

    #[test]
    fn placement_decisions_section_always_present() {
        use crate::coordinator::placement::Explain;
        use crate::metrics::recorder::DecisionOutcome;
        let mut r = Recorder::new(1, 1);
        let mut ex = Explain::default();
        ex.servers_admitted = 1;
        ex.gpus_eligible = 3;
        ex.candidates = 2;
        ex.rejects[RejectReason::SmactCap.index()] = 1;
        r.on_decision(DecisionOutcome::Placed, &ex);
        let rep = RunReport::from_recorder("t", &r);
        assert_eq!(rep.decisions.decisions, 1);
        let j = rep.to_json();
        let d = j.get("placement_decisions").expect("section always present");
        assert_eq!(d.f64_of("decisions"), 1.0);
        assert_eq!(d.f64_of("placed"), 1.0);
        assert_eq!(d.f64_of("gpus_eligible"), 3.0);
        let rej = d.get("rejects").expect("per-reason reject counts");
        assert_eq!(rej.f64_of("smact_cap"), 1.0);
        assert_eq!(rej.f64_of("no_fit"), 0.0);
        // a decision-free run still carries the zeroed section
        let empty = RunReport::from_recorder("e", &Recorder::new(0, 1));
        let ej = empty.to_json();
        assert_eq!(
            ej.get("placement_decisions").unwrap().f64_of("decisions"),
            0.0
        );
    }

    #[test]
    fn stream_recorder_report_matches_full_mode_sections() {
        let mut full = Recorder::new(2, 1);
        let mut st = Recorder::new(0, 1);
        st.enable_stream();
        for r in [&mut full, &mut st] {
            r.open_loop = true;
            r.n_shards = 2;
            r.ensure_task(0);
            r.on_arrival(0, 0.0);
            r.on_assigned(0, 0);
            r.on_dispatch(0, 30.0);
            r.on_singleton_dispatch(0, 2, 0.01, 1);
            r.on_completion(0, 90.0);
            r.ensure_task(1);
            r.on_arrival(1, 5.0);
            r.on_shed(1, 5.0, true);
            r.finalize();
        }
        let rf = RunReport::from_recorder("x", &full);
        let rs = RunReport::from_recorder("x", &st);
        assert_eq!(rs.total_tasks, rf.total_tasks);
        assert_eq!(rs.completed, rf.completed);
        assert_eq!(rs.service.shed, rf.service.shed);
        assert_eq!(rs.service.offered, rf.service.offered);
        assert_eq!(rs.service.queue_delay_p50_s, rf.service.queue_delay_p50_s);
        assert_eq!(rs.placement.multi_gpu_singletons, rf.placement.multi_gpu_singletons);
        assert_eq!(rs.placement.single_island, rf.placement.single_island);
        assert_eq!(rs.per_shard.len(), rf.per_shard.len());
        assert_eq!(rs.per_shard[0].tasks, rf.per_shard[0].tasks);
        assert_eq!(rs.per_shard[0].decisions, rf.per_shard[0].decisions);
        assert!((rs.avg_jct_min - rf.avg_jct_min).abs() < 1e-9);
        assert!((rs.gang.mean_wait_min - rf.gang.mean_wait_min).abs() < 1e-9);
    }

    #[test]
    fn resilience_section_always_present_and_zeroed_without_faults() {
        use crate::sim::faults::FaultKind;
        // fault-free run: section exists, zeros, availability exactly 1.0
        let mut r = Recorder::new(1, 2);
        r.on_arrival(0, 0.0);
        r.on_dispatch(0, 10.0);
        r.on_completion(0, 110.0);
        let rep = RunReport::from_recorder("t", &r);
        assert_eq!(rep.resilience.faults_gpu, 0);
        assert_eq!(rep.resilience.availability, 1.0);
        assert_eq!(rep.resilience.goodput, 1.0);
        let j = rep.to_json();
        let res = j.get("resilience").expect("resilience section always present");
        assert_eq!(res.f64_of("relaunches"), 0.0);
        assert_eq!(res.f64_of("availability"), 1.0);
        // chaos run: counters flow through, MTTR and availability derive
        let mut c = Recorder::new(2, 2);
        c.on_arrival(0, 0.0);
        c.on_dispatch(0, 10.0);
        c.on_completion(0, 100.0); // trace 100 s × 2 GPUs = 200 GPU-s
        c.on_arrival(1, 5.0);
        c.on_fault(FaultKind::Gpu);
        c.on_fault_interruption(FaultKind::Gpu);
        c.on_fault_relaunch();
        c.on_fault_repair(40.0, 40.0);
        c.on_fault(FaultKind::Server);
        c.on_fault_failed();
        c.on_failed(1);
        c.on_holds_invalidated(2);
        let crep = RunReport::from_recorder("c", &c);
        assert_eq!(crep.resilience.faults_gpu, 1);
        assert_eq!(crep.resilience.faults_server, 1);
        assert_eq!(crep.resilience.interruptions_gpu, 1);
        assert_eq!(crep.resilience.relaunches, 1);
        assert_eq!(crep.resilience.fault_failed, 1);
        assert_eq!(crep.resilience.repairs, 1);
        assert!((crep.resilience.mttr_s - 40.0).abs() < 1e-12);
        assert!((crep.resilience.availability - 0.8).abs() < 1e-12);
        assert!((crep.resilience.goodput - 0.5).abs() < 1e-12);
        assert_eq!(crep.resilience.holds_invalidated, 2);
        let cj = crep.to_json();
        assert_eq!(cj.get("resilience").unwrap().f64_of("faults_gpu"), 1.0);
    }

    #[test]
    fn steals_attribute_to_the_thief_shard() {
        let mut r = Recorder::new(3, 1);
        r.n_shards = 2;
        r.on_arrival(0, 0.0);
        r.on_assigned(0, 0);
        r.on_stolen(0, 1); // shard 1 stole it off shard 0's queue
        r.on_dispatch(0, 90.0);
        let rep = RunReport::from_recorder("t", &r);
        assert_eq!(rep.per_shard[0].tasks, 1, "original routing attribution");
        assert_eq!(rep.per_shard[0].steals, 0);
        assert_eq!(rep.per_shard[1].steals, 1);
        let j = rep.to_json();
        assert!(j.to_string_pretty().contains("\"steals\""));
    }

    #[test]
    fn idle_trailing_shards_still_reported() {
        // least-loaded routing can park everything on shard 0; a 4-shard
        // run must still report 4 entries, not 1
        let mut r = Recorder::new(2, 1);
        r.n_shards = 4;
        r.on_arrival(0, 0.0);
        r.on_assigned(0, 0);
        r.on_dispatch(0, 60.0);
        let rep = RunReport::from_recorder("t", &r);
        assert_eq!(rep.per_shard.len(), 4);
        assert_eq!(rep.per_shard[0].tasks, 1);
        for s in &rep.per_shard[1..] {
            assert_eq!((s.tasks, s.decisions), (0, 0));
            assert_eq!(s.mean_wait_min, 0.0);
        }
    }
}
