//! Metrics (S14): per-task timing, GPU timelines, energy, OOM counts, and
//! the report type every experiment prints (paper §5.1.3 metric set).

pub mod recorder;
pub mod report;

pub use recorder::{Recorder, TimelinePoint};
pub use report::RunReport;
