//! Trace-analysis reproduction study (DESIGN.md §16): does `carma trace
//! analyze` recover the run's own report from the trace bytes alone?
//!
//! Two arms, each a traced run followed by a cold re-read of the JSONL
//! file through the replay/span/series pipeline:
//!
//! * **service** — open-loop Poisson arrivals over 4×4 GPUs with a tight
//!   queue cap, so the trace carries sheds and queueing delay;
//! * **chaos** — 64-task closed-loop trace under `mixed` faults, so the
//!   trace carries OOM crashes, strikes, quarantines and relaunches.
//!
//! For each arm the study asserts the §16 acceptance criteria:
//!
//! * the replay engine finds **zero** invariant violations and no
//!   non-terminal tasks in a trace the engine itself wrote;
//! * replayed conservation counters (offered / completed / shed) equal
//!   the report's exactly, and the analyzer re-derives the report's
//!   queue-delay percentiles and mean JCT within the documented sketch
//!   tolerance (6%, the same bound the recorder tests use);
//! * every task's span decomposition sums to its end-to-end JCT exactly
//!   (≤ 1 µs residual after the float-residual fold).
//!
//! The per-arm summary (plus the analyzer's records/sec, the cost of
//! consuming a trace) is appended to the `BENCH_sim.json` ledger under
//! `trace_analyze`; ci.sh fails if the section goes missing.

use std::time::Instant;

use crate::bench;
use crate::config::schema::{
    ArrivalKind, CarmaConfig, ClusterConfig, EstimatorKind, FaultProfile, PolicyKind, TimelineMode,
};
use crate::coordinator::carma::{run_service, run_trace, RunOutcome};
use crate::estimators;
use crate::obs::replay::{self, Analysis};
use crate::util::json::{self, Json};
use crate::workload::trace::trace_cluster;

use super::common::{save_json, zoo, DEFAULT_SEED};

const SERVERS: usize = 4;
const GPUS_PER_SERVER: usize = 4;
const RATE_PER_MIN: f64 = 60.0;
const QUEUE_CAP: usize = 4;
const CHAOS_TASKS: usize = 64;
const CHAOS_RATE_PER_HOUR: f64 = 30.0;
const FAULT_SEED: u64 = 7;
/// Relative tolerance for sketch-derived statistics — the log-bucket
/// width bound the recorder's own tests assert.
const SKETCH_TOL: f64 = 0.06;
/// Absolute ceiling on |decomposition total − JCT| per task.
const EXACT_EPS: f64 = 1e-6;
const WINDOW_S: f64 = 60.0;

fn within_tol(got: f64, want: f64) -> bool {
    (got - want).abs() <= want.abs().max(got.abs()) * SKETCH_TOL + 1e-9
}

fn service_cfg(artifacts_dir: &str, duration_s: f64, trace_path: &str) -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(SERVERS, GPUS_PER_SERVER, 40.0);
    c.coordinator.shards = 4;
    c.service.arrivals = Some(ArrivalKind::Poisson);
    c.service.rate_per_min = RATE_PER_MIN;
    c.service.duration_s = duration_s;
    c.service.queue_cap = QUEUE_CAP;
    c.service.seed = DEFAULT_SEED;
    c.artifacts_dir = artifacts_dir.to_string();
    // stream mode on purpose: the analyzer must work off the trace alone,
    // with no materialized timeline to lean on
    c.obs.timeline = TimelineMode::Off;
    c.obs.trace_out = Some(trace_path.to_string());
    c
}

fn chaos_cfg(artifacts_dir: &str, trace_path: &str) -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.seed = DEFAULT_SEED;
    c.cluster = ClusterConfig::homogeneous(2, GPUS_PER_SERVER, 40.0);
    c.coordinator.shards = 2;
    c.faults.profile = FaultProfile::Mixed;
    c.faults.rate_per_hour = CHAOS_RATE_PER_HOUR;
    c.faults.seed = FAULT_SEED;
    c.artifacts_dir = artifacts_dir.to_string();
    c.obs.timeline = TimelineMode::Off;
    c.obs.trace_out = Some(trace_path.to_string());
    c
}

/// Analyze a trace, check every §16 gate against the run that wrote it,
/// and return the ledger row.
fn check_arm(
    arm: &str,
    trace_path: &str,
    out: &RunOutcome,
) -> Result<Json, String> {
    let t0 = Instant::now();
    let a: Analysis = replay::analyze_file(trace_path, WINDOW_S)
        .map_err(|e| format!("{arm}: cannot read {trace_path}: {e}"))?;
    let analyze_wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let rep = &a.replay;

    // 1. the engine's own trace must replay clean
    if !rep.ok() {
        let first = &rep.violations[0];
        return Err(format!(
            "{arm}: {} invariant violation(s); first at seq {}: {}",
            rep.violations.len(),
            first.seq,
            first.what
        ));
    }
    if rep.non_terminal != 0 {
        return Err(format!("{arm}: {} task(s) never reached a terminal state", rep.non_terminal));
    }
    if rep.seq_gaps != 0 {
        return Err(format!("{arm}: trace has {} sequence gap(s)", rep.seq_gaps));
    }

    // 2. conservation counters must equal the report's, exactly
    let r = &out.report;
    if rep.offered != r.service.offered as u64 {
        return Err(format!(
            "{arm}: replay offered {} != report {}",
            rep.offered, r.service.offered
        ));
    }
    if rep.completed != r.completed as u64 {
        return Err(format!(
            "{arm}: replay completed {} != report {}",
            rep.completed, r.completed
        ));
    }
    if rep.shed != r.service.shed {
        return Err(format!("{arm}: replay shed {} != report {}", rep.shed, r.service.shed));
    }

    // 3. sketch reproduction: same histogram family over the same value
    //    stream, so percentiles land within the bucket-width tolerance
    let qd_pairs = [
        ("queue_delay_p50_s", a.queue_delay.percentile(50.0), r.service.queue_delay_p50_s),
        ("queue_delay_p99_s", a.queue_delay.percentile(99.0), r.service.queue_delay_p99_s),
        ("queue_delay_p999_s", a.queue_delay.percentile(99.9), r.service.queue_delay_p999_s),
    ];
    for (key, got, want) in qd_pairs {
        if !within_tol(got, want) {
            return Err(format!(
                "{arm}: analyzer {key} {got:.4} vs report {want:.4} — outside the \
                 {:.0}% sketch tolerance",
                SKETCH_TOL * 100.0
            ));
        }
    }
    if a.queue_delay.count() != out.recorder.queue_delay.count() {
        return Err(format!(
            "{arm}: analyzer saw {} queue-delay samples, recorder {}",
            a.queue_delay.count(),
            out.recorder.queue_delay.count()
        ));
    }
    let jct_mean_want = out.recorder.avg_jct_s();
    let jct_mean_got = a.jct.mean();
    if a.jct.count() > 0 && !within_tol(jct_mean_got, jct_mean_want) {
        return Err(format!(
            "{arm}: analyzer mean JCT {jct_mean_got:.3}s vs report {jct_mean_want:.3}s"
        ));
    }

    // 4. time accounting is exact: spans partition [arrival, terminal]
    let mut max_residual = 0.0f64;
    for t in &a.spans.tasks {
        let residual = (t.decomposition.total_s() - t.jct_s()).abs();
        max_residual = max_residual.max(residual);
        if residual > EXACT_EPS {
            return Err(format!(
                "{arm}: task {} decomposition sums to {:.9}s but JCT is {:.9}s",
                t.task,
                t.decomposition.total_s(),
                t.jct_s()
            ));
        }
    }

    let records_per_s = rep.records as f64 / analyze_wall_s;
    println!(
        "{:<9} {:>9} {:>8} {:>9} {:>6} {:>6} {:>10} {:>12.0} {:>12.2e}",
        arm,
        rep.records,
        rep.offered,
        rep.completed,
        rep.shed,
        rep.dispatches_during_outage,
        rep.violations.len(),
        records_per_s,
        max_residual,
    );

    Ok(json::obj(vec![
        ("arm", json::s(arm)),
        ("records", json::num(rep.records as f64)),
        ("offered", json::num(rep.offered as f64)),
        ("completed", json::num(rep.completed as f64)),
        ("shed", json::num(rep.shed as f64)),
        ("dispatches", json::num(rep.dispatches as f64)),
        (
            "dispatches_during_outage",
            json::num(rep.dispatches_during_outage as f64),
        ),
        ("violations", json::num(rep.violations.len() as f64)),
        ("queue_delay_p50_s", json::num(a.queue_delay.percentile(50.0))),
        ("report_queue_delay_p50_s", json::num(r.service.queue_delay_p50_s)),
        ("queue_delay_p99_s", json::num(a.queue_delay.percentile(99.0))),
        ("report_queue_delay_p99_s", json::num(r.service.queue_delay_p99_s)),
        ("jct_mean_s", json::num(jct_mean_got)),
        ("report_jct_mean_s", json::num(jct_mean_want)),
        ("max_decomposition_residual_s", json::num(max_residual)),
        ("makespan_s", json::num(a.spans.makespan_s)),
        ("critical_path_hops", json::num(a.spans.critical_path.len() as f64)),
        ("series_points", json::num(a.series.points.len() as f64)),
        ("analyze_records_per_s", json::num(records_per_s)),
        ("analyze_wall_s", json::num(analyze_wall_s)),
    ]))
}

pub fn run(artifacts_dir: &str) -> Result<(), String> {
    let smoke = bench::smoke_mode();
    let duration_s = if smoke { 240.0 } else { 1200.0 };
    let _ = std::fs::create_dir_all(format!("{artifacts_dir}/results"));
    println!(
        "Trace analysis: replay + spans + series over engine-written traces \
         (sketch tolerance {:.0}%{})\n",
        SKETCH_TOL * 100.0,
        if smoke { ", smoke" } else { "" }
    );
    println!(
        "{:<9} {:>9} {:>8} {:>9} {:>6} {:>6} {:>10} {:>12} {:>12}",
        "arm", "records", "offered", "completed", "shed", "outage", "violations",
        "records/s", "residual"
    );

    let mut rows: Vec<Json> = Vec::new();

    // service arm: sheds + queueing delay under saturating Poisson load
    let svc_trace = format!("{artifacts_dir}/results/trace_analyze_service.jsonl");
    let c = service_cfg(artifacts_dir, duration_s, &svc_trace);
    let est = estimators::build(c.estimator, artifacts_dir)?;
    let svc_out = run_service(c, est, "trace-analyze-service");
    rows.push(check_arm("service", &svc_trace, &svc_out)?);

    // chaos arm: OOM crashes, fault strikes, quarantines, relaunches
    let chaos_trace = format!("{artifacts_dir}/results/trace_analyze_chaos.jsonl");
    let c = chaos_cfg(artifacts_dir, &chaos_trace);
    let est = estimators::build(c.estimator, artifacts_dir)?;
    let trace = trace_cluster(&zoo(), CHAOS_TASKS, 2 * GPUS_PER_SERVER, DEFAULT_SEED);
    let chaos_out = run_trace(c, est, &trace, "trace-analyze-chaos");
    let res = &chaos_out.report.resilience;
    if res.faults_gpu + res.faults_server + res.faults_link == 0 {
        return Err("chaos arm injected no faults — the fault-path coverage is gone".into());
    }
    rows.push(check_arm("chaos", &chaos_trace, &chaos_out)?);

    save_json("trace_analyze", artifacts_dir, &json::arr(rows.clone()));
    bench::save_bench_section("trace_analyze", rows);

    println!(
        "\nReading: the trace is a sufficient statistic for the run — replay\n\
         proves the lifecycle/health/conservation invariants over every\n\
         record, the span decomposition accounts for each task's JCT to\n\
         within float residue, and the analyzer's sketches land on the\n\
         report's percentiles without touching the recorder."
    );
    Ok(())
}
