//! Engine-scale study (DESIGN.md §17): delta-maintained cluster views +
//! the arena event core under a million-task open-loop stream.
//!
//! Two arms over open-loop service mode, both `--timeline off`:
//!
//! * **million-task arm** — 16×4 GPUs, a saturating Poisson stream offering
//!   ≥10⁶ arrivals (most shed at the bounded queues), swept over
//!   delta-views {on, off} × shards {1, 4} × engine threads {1, 4}. This is
//!   the scale proof: the recorder holds O(buckets + GPUs + in-flight)
//!   memory (stream mode, no per-task rows, small `live_high_water`), the
//!   pre-sized lanes and event arena never reallocate mid-run, and the
//!   results JSON is byte-identical across every (delta, threads) cell of a
//!   shard count.
//!
//! * **view-churn-heavy arm** — 512 servers × 2 GPUs, moderate arrivals at
//!   a long observation window, so wall-clock is dominated by `ServerView`
//!   maintenance: every dispatch/completion commit invalidates the
//!   snapshot, and the full-rebuild baseline (delta off) pays O(cluster)
//!   per invalidation where delta maintenance rebuilds only the touched
//!   server. The study *gates* on the events/sec win of delta-on vs
//!   delta-off here: ≥2× on a dedicated run, a narrower structural gate
//!   under `CARMA_BENCH_SMOKE`.
//!
//! A third phase re-runs a short slice of the million-task stream with
//! `--trace-out` and byte-compares the JSONL trace across engine threads
//! {1, 4} (delta on) and against the delta-off baseline, per shard count.
//!
//! The summary is appended to the `BENCH_sim.json` ledger under
//! `engine_scale`; ci.sh fails if the section goes missing.

use std::time::Instant;

use crate::bench;
use crate::config::schema::{
    ArrivalKind, CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind, TimelineMode,
};
use crate::coordinator::carma::{run_service, RunOutcome};
use crate::estimators;
use crate::util::json::{self, Json};

use super::common::{save_json, DEFAULT_SEED};

const SHARD_SWEEP: &[usize] = &[1, 4];
const THREAD_SWEEP: &[usize] = &[1, 4];

// -- million-task arm (scale + memory + determinism) ------------------------
const M_SERVERS: usize = 16;
const M_GPUS_PER_SERVER: usize = 4;
/// 10 500/min over 6 000 s offers ~1.05M arrivals — comfortably past the
/// 10⁶ floor even under Poisson variance.
const M_RATE_PER_MIN: f64 = 10_500.0;
const M_QUEUE_CAP: usize = 4;

// -- view-churn-heavy arm (the ≥2× gate) ------------------------------------
/// Many small servers: a full rebuild touches 512 views, a delta apply
/// rebuilds only the server the commit landed on.
const C_SERVERS: usize = 512;
const C_GPUS_PER_SERVER: usize = 2;
/// Twice the mapping pipeline's drain capacity (shards / window), so the
/// shard queues stay busy without the run degenerating into shed handling.
const C_RATE_PER_MIN: f64 = 8.0;
const C_QUEUE_CAP: usize = 64;
/// Long window = long monitor sample period: cluster-wide `touch_all`
/// invalidations stay rare relative to per-commit invalidations, which is
/// exactly the regime delta maintenance targets.
const C_WINDOW_S: f64 = 60.0;

/// Dedicated-run gate on the delta-on vs delta-off events/sec ratio.
const GATE: f64 = 2.0;
/// Smoke gate: CI containers share cores — the smoke catches "delta views
/// stopped winning at all", not the precise 2× claim.
const SMOKE_GATE: f64 = 1.2;

fn million_cfg(
    shards: usize,
    threads: usize,
    delta: bool,
    duration_s: f64,
    artifacts_dir: &str,
) -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(M_SERVERS, M_GPUS_PER_SERVER, 40.0);
    c.coordinator.shards = shards;
    c.engine.threads = threads;
    c.engine.delta_views = delta;
    c.service.arrivals = Some(ArrivalKind::Poisson);
    c.service.rate_per_min = M_RATE_PER_MIN;
    c.service.duration_s = duration_s;
    c.service.queue_cap = M_QUEUE_CAP;
    c.service.seed = DEFAULT_SEED;
    c.obs.timeline = TimelineMode::Off;
    c.artifacts_dir = artifacts_dir.to_string();
    c
}

fn churn_cfg(delta: bool, duration_s: f64, artifacts_dir: &str) -> CarmaConfig {
    let mut c = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    c.cluster = ClusterConfig::homogeneous(C_SERVERS, C_GPUS_PER_SERVER, 40.0);
    c.coordinator.shards = 4;
    c.engine.threads = 1;
    c.engine.delta_views = delta;
    c.monitor.window_s = C_WINDOW_S;
    c.monitor.sample_period_s = C_WINDOW_S;
    c.service.arrivals = Some(ArrivalKind::Poisson);
    c.service.rate_per_min = C_RATE_PER_MIN;
    c.service.duration_s = duration_s;
    c.service.queue_cap = C_QUEUE_CAP;
    c.service.seed = DEFAULT_SEED;
    c.obs.timeline = TimelineMode::Off;
    c.artifacts_dir = artifacts_dir.to_string();
    c
}

fn one_run(c: CarmaConfig, label: &str, artifacts_dir: &str) -> Result<(RunOutcome, f64), String> {
    let est = estimators::build(c.estimator, artifacts_dir)?;
    let t0 = Instant::now();
    let out = run_service(c, est, label);
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    Ok((out, wall_s))
}

/// Scale + memory assertions for one million-arm run: the recorder stayed
/// in stream mode with no per-task rows and a live map bounded by the
/// in-flight set, the pre-sized lanes/arena never grew, terminal
/// accounting holds, and the ViewStats match the configured arm.
fn check_million(out: &RunOutcome, label: &str, shards: usize, delta: bool) -> Result<(), String> {
    let s = &out.report.service;
    if !s.open_loop {
        return Err(format!("{label}: report is not flagged open-loop"));
    }
    let terminal = out.report.completed + out.recorder.failed_total as usize + s.shed as usize;
    if terminal != s.offered {
        return Err(format!(
            "{label}: {terminal} terminal of {} offered — the drain leaked tasks",
            s.offered
        ));
    }
    // recorder memory: O(buckets + GPUs + in-flight), never O(offered)
    if !out.recorder.stream() {
        return Err(format!("{label}: timeline off must run the stream recorder"));
    }
    if !out.recorder.tasks.is_empty() {
        return Err(format!(
            "{label}: stream mode materialized {} per-task timing rows",
            out.recorder.tasks.len()
        ));
    }
    let gpus = M_SERVERS * M_GPUS_PER_SERVER;
    let live_bound = 4 * (gpus + shards * M_QUEUE_CAP + shards + 8);
    let live = out.recorder.live_high_water;
    if live == 0 || live > live_bound {
        return Err(format!(
            "{label}: in-flight map peaked at {live} (bound {live_bound}, \
             offered {}) — recorder memory is not O(in-flight)",
            s.offered
        ));
    }
    // arena event core: the live-set pre-sizing must hold at 10⁶ arrivals
    let es = &out.engine_stats;
    if es.lane_reallocs != 0 || es.arena_reallocs != 0 {
        return Err(format!(
            "{label}: pre-sized engine grew mid-run ({} lane / {} arena reallocs, \
             high water {} of {})",
            es.lane_reallocs, es.arena_reallocs, es.arena_high_water, es.arena_capacity
        ));
    }
    let vs = &out.view_stats;
    if delta && vs.delta_applies == 0 && vs.snapshot_hits == 0 {
        return Err(format!("{label}: delta views on, but every snapshot fully rebuilt"));
    }
    if !delta && vs.delta_applies != 0 {
        return Err(format!(
            "{label}: delta views off, but {} delta applies ran",
            vs.delta_applies
        ));
    }
    Ok(())
}

struct Cell {
    shards: usize,
    threads: usize,
    delta: bool,
    out: RunOutcome,
    wall_s: f64,
}

fn cell_json(c: &Cell) -> Json {
    let vs = &c.out.view_stats;
    let es = &c.out.engine_stats;
    let s = &c.out.report.service;
    json::obj(vec![
        ("arm", json::s("million")),
        ("shards", json::num(c.shards as f64)),
        ("threads", json::num(c.threads as f64)),
        ("delta_views", json::num(u64::from(c.delta) as f64)),
        ("offered", json::num(s.offered as f64)),
        ("shed", json::num(s.shed as f64)),
        ("events", json::num(c.out.events as f64)),
        ("wall_s", json::num(c.wall_s)),
        ("events_per_s", json::num(c.out.events as f64 / c.wall_s)),
        ("snapshot_hits", json::num(vs.snapshot_hits as f64)),
        ("full_rebuilds", json::num(vs.full_rebuilds as f64)),
        ("delta_applies", json::num(vs.delta_applies as f64)),
        ("servers_rebuilt", json::num(vs.servers_rebuilt as f64)),
        ("servers_reused", json::num(vs.servers_reused as f64)),
        ("cache_hit_rate", json::num(vs.hit_rate())),
        ("arena_high_water", json::num(es.arena_high_water as f64)),
        ("arena_capacity", json::num(es.arena_capacity as f64)),
        ("live_high_water", json::num(c.out.recorder.live_high_water as f64)),
    ])
}

pub fn run(artifacts_dir: &str) -> Result<(), String> {
    let smoke = bench::smoke_mode();
    let (m_dur, c_dur, t_dur, reps, gate, min_offered) = if smoke {
        (150.0, 2400.0, 60.0, 2, SMOKE_GATE, 20_000usize)
    } else {
        (6000.0, 14_400.0, 300.0, 3, GATE, 1_000_000usize)
    };
    let _ = std::fs::create_dir_all(format!("{artifacts_dir}/results"));
    println!(
        "Engine scale: million-task arm {M_SERVERS}×{M_GPUS_PER_SERVER} GPUs at \
         {M_RATE_PER_MIN:.0}/min for {m_dur:.0}s; churn arm {C_SERVERS}×{C_GPUS_PER_SERVER} \
         GPUs at {C_RATE_PER_MIN:.0}/min for {c_dur:.0}s; seed {DEFAULT_SEED} \
         (gate {gate:.1}x{})\n",
        if smoke { ", smoke" } else { "" }
    );

    // -- phase 1: the 10⁶-task sweep ------------------------------------
    println!(
        "{:<7} {:>8} {:>6} {:>9} {:>9} {:>10} {:>8} {:>8} {:>9} {:>8} {:>8}",
        "shards", "threads", "delta", "offered", "events", "events/s", "hits", "rebuild",
        "delta-app", "live-hw", "wall(s)"
    );
    let mut cells: Vec<Cell> = Vec::new();
    for &shards in SHARD_SWEEP {
        // one reference serialization per shard count: every (delta,
        // threads) cell must byte-reproduce it (DESIGN.md §10 + §17)
        let mut json_bits: Option<String> = None;
        for &delta in &[true, false] {
            for &threads in THREAD_SWEEP {
                let label = format!("engine-scale/{shards}-shard");
                let c = million_cfg(shards, threads, delta, m_dur, artifacts_dir);
                let (out, wall_s) = one_run(c, &label, artifacts_dir)?;
                check_million(&out, &label, shards, delta)?;
                if out.report.service.offered < min_offered {
                    return Err(format!(
                        "{label}: only {} arrivals offered (needs >= {min_offered})",
                        out.report.service.offered
                    ));
                }
                let j = out.report.to_json().to_string_pretty();
                match &json_bits {
                    None => json_bits = Some(j),
                    Some(prev) => {
                        if *prev != j {
                            return Err(format!(
                                "{shards} shards: delta={delta} threads={threads} \
                                 changed the results JSON — determinism broken"
                            ));
                        }
                    }
                }
                let vs = &out.view_stats;
                println!(
                    "{:<7} {:>8} {:>6} {:>9} {:>9} {:>10.0} {:>8} {:>8} {:>9} {:>8} {:>8.2}",
                    shards,
                    threads,
                    if delta { "on" } else { "off" },
                    out.report.service.offered,
                    out.events,
                    out.events as f64 / wall_s,
                    vs.snapshot_hits,
                    vs.full_rebuilds,
                    vs.delta_applies,
                    out.recorder.live_high_water,
                    wall_s,
                );
                cells.push(Cell { shards, threads, delta, out, wall_s });
            }
        }
    }

    // -- phase 2: JSONL trace byte-identity ------------------------------
    // a short slice of the same stream, traced: identical bytes across
    // engine threads {1,4} with delta on, and vs the delta-off baseline
    println!("\ntrace identity ({t_dur:.0}s traced slice):");
    for &shards in SHARD_SWEEP {
        let mut reference: Option<Vec<u8>> = None;
        for &(delta, threads) in &[(true, 1usize), (true, 4usize), (false, 1usize)] {
            let label = format!("engine-scale/{shards}-shard");
            let path = format!(
                "{artifacts_dir}/results/engine_scale_trace_{shards}s_{}_{threads}t.jsonl",
                if delta { "on" } else { "off" }
            );
            let mut c = million_cfg(shards, threads, delta, t_dur, artifacts_dir);
            c.obs.trace_out = Some(path.clone());
            let (_out, _) = one_run(c, &label, artifacts_dir)?;
            let bytes = std::fs::read(&path).map_err(|e| format!("{path}: {e}"))?;
            match &reference {
                None => reference = Some(bytes),
                Some(prev) => {
                    if *prev != bytes {
                        return Err(format!(
                            "{shards} shards: trace JSONL diverged at delta={delta} \
                             threads={threads} ({path})"
                        ));
                    }
                }
            }
        }
        let n = reference.map(|b| b.len()).unwrap_or(0);
        println!("  {shards} shard(s): {n} bytes identical across threads {{1,4}} and delta on/off");
    }

    // -- phase 3: the view-churn-heavy gate ------------------------------
    println!("\nview-churn arm ({C_SERVERS} servers, best of {reps}):");
    let mut rates = [0.0f64; 2]; // [on, off]
    let mut churn_events = 0u64;
    let mut churn_json: Option<String> = None;
    let mut churn_stats: Vec<Json> = Vec::new();
    for (slot, &delta) in [true, false].iter().enumerate() {
        let label = "engine-churn/4-shard";
        let mut best = 0.0f64;
        let mut kept: Option<(RunOutcome, f64)> = None;
        for rep in 0..reps {
            let c = churn_cfg(delta, c_dur, artifacts_dir);
            let (out, wall_s) = one_run(c, label, artifacts_dir)?;
            if rep == 0 && churn_events == 0 {
                churn_events = out.events;
            }
            if out.events != churn_events {
                return Err(format!(
                    "{label}: event count drifted ({} vs {churn_events}) — \
                     delta views changed the simulation",
                    out.events
                ));
            }
            best = best.max(out.events as f64 / wall_s);
            kept = Some((out, wall_s));
        }
        let (out, wall_s) = kept.expect("reps >= 1");
        // delta maintenance must be invisible in the results
        let j = out.report.to_json().to_string_pretty();
        match &churn_json {
            None => churn_json = Some(j),
            Some(prev) => {
                if *prev != j {
                    return Err(
                        "churn arm: delta on vs off changed the results JSON".to_string()
                    );
                }
            }
        }
        let vs = &out.view_stats;
        if delta && vs.servers_reused <= vs.servers_rebuilt {
            return Err(format!(
                "churn arm: delta views reused {} server views but rebuilt {} — \
                 the workload is not view-churn-dominated",
                vs.servers_reused, vs.servers_rebuilt
            ));
        }
        println!(
            "  delta {:<4} {:>9} events  {:>10.0} events/s  (rebuilt {} / reused {}, \
             hit rate {:.3}, wall {:.2}s)",
            if delta { "on" } else { "off" },
            out.events,
            best,
            vs.servers_rebuilt,
            vs.servers_reused,
            vs.hit_rate(),
            wall_s,
        );
        churn_stats.push(json::obj(vec![
            ("delta_views", json::num(u64::from(delta) as f64)),
            ("events", json::num(out.events as f64)),
            ("best_events_per_s", json::num(best)),
            ("snapshot_hits", json::num(vs.snapshot_hits as f64)),
            ("full_rebuilds", json::num(vs.full_rebuilds as f64)),
            ("delta_applies", json::num(vs.delta_applies as f64)),
            ("servers_rebuilt", json::num(vs.servers_rebuilt as f64)),
            ("servers_reused", json::num(vs.servers_reused as f64)),
        ]));
        rates[slot] = best;
    }
    let speedup = rates[0] / rates[1].max(1e-9);
    println!("\ndelta-views speedup on the churn arm: {speedup:.2}x (gate {gate:.1}x)");

    // -- ledger ----------------------------------------------------------
    let mut rows: Vec<Json> = cells.iter().map(cell_json).collect();
    rows.push(json::obj(vec![
        ("arm", json::s("churn")),
        ("servers", json::num(C_SERVERS as f64)),
        ("gpus_per_server", json::num(C_GPUS_PER_SERVER as f64)),
        ("rate_per_min", json::num(C_RATE_PER_MIN)),
        ("duration_s", json::num(c_dur)),
        ("window_s", json::num(C_WINDOW_S)),
        ("reps", json::num(reps as f64)),
        ("smoke", json::num(u64::from(smoke) as f64)),
        ("events", json::num(churn_events as f64)),
        ("delta_on_events_per_s", json::num(rates[0])),
        ("delta_off_events_per_s", json::num(rates[1])),
        ("speedup", json::num(speedup)),
        ("gate", json::num(gate)),
        ("arms", json::arr(churn_stats)),
    ]));
    save_json("engine_scale", artifacts_dir, &json::arr(rows.clone()));
    bench::save_bench_section("engine_scale", rows);

    if speedup < gate {
        return Err(format!(
            "delta-views speedup {speedup:.2}x is below the {gate:.1}x gate \
             on the view-churn-heavy arm"
        ));
    }
    println!(
        "\nReading: per-server epoch tags turn snapshot invalidation from\n\
         O(cluster) per commit into O(touched servers): a dispatch or\n\
         completion rebuilds one ServerView and carries the other {} forward\n\
         by Arc bump, while the arena event core keeps the million-task\n\
         arrival stream allocation-free after startup.",
        C_SERVERS - 1
    );
    Ok(())
}
