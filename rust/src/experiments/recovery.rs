//! Table 4 + Fig. 9 — recovery method and preconditions without estimators
//! (paper §5.3): collocation proceeds blindly until OOM or preconditions
//! stop it; the recovery queue re-runs crashed tasks exclusively.

use crate::config::schema::{CollocationMode, EstimatorKind, PolicyKind};
use crate::workload::trace::trace_90;

use super::common::{exclusive, run_grid, save_results, zoo, RunCfg, DEFAULT_SEED};

fn grid() -> Vec<RunCfg> {
    let blind = |p: PolicyKind| RunCfg::new(p, CollocationMode::Mps, EstimatorKind::None);
    vec![
        blind(PolicyKind::RoundRobin),                    // RR (no condition)
        blind(PolicyKind::Magm),                          // MAGM (no condition)
        blind(PolicyKind::Magm).smact(0.80),              // MAGM (SMACT<=80%)
        blind(PolicyKind::Magm).smact(0.80).min_free(2.0),
        blind(PolicyKind::Magm).smact(0.80).min_free(5.0),
        blind(PolicyKind::Magm).smact(0.75).min_free(5.0),
        blind(PolicyKind::Magm).smact(0.85).min_free(5.0),
        blind(PolicyKind::Lug).smact(0.80).min_free(5.0),
    ]
}

/// Table 4 — #OOM per policy/precondition combination.
pub fn table4(artifacts_dir: &str) -> Result<(), String> {
    let z = zoo();
    let trace = trace_90(&z, DEFAULT_SEED);
    println!(
        "Table 4: OOM errors without memory estimators (recovery only), {}\n",
        trace.name
    );
    let out = run_grid(&trace, &grid(), artifacts_dir);
    save_results("table4", artifacts_dir, &out);

    println!("\n{:<44} {:>12}", "Policy", "#OOM Crashes");
    for (label, o) in &out {
        println!("{:<44} {:>12}", label, o.report.oom_crashes);
    }
    println!("\n(paper: RR 8 > MAGM 5 > +SMACT 4 > +GMem 2; 75% tightest at 1;");
    println!(" all tasks still complete thanks to the recovery queue)");
    for (label, o) in &out {
        assert_eq!(
            o.report.completed, o.report.total_tasks,
            "{label}: recovery must complete every task"
        );
    }
    Ok(())
}

/// Fig. 9 — the same runs' timing profile vs Exclusive.
pub fn fig9(artifacts_dir: &str) -> Result<(), String> {
    let z = zoo();
    let trace = trace_90(&z, DEFAULT_SEED);
    println!(
        "Fig. 9: recovery-only collocation performance (all MPS), {}\n",
        trace.name
    );
    let mut runs = vec![exclusive()];
    runs.extend(grid());
    let out = run_grid(&trace, &runs, artifacts_dir);
    save_results("fig9", artifacts_dir, &out);

    let excl = &out[0].1.report;
    let best = out[1..]
        .iter()
        .min_by(|a, b| a.1.report.trace_total_min.total_cmp(&b.1.report.trace_total_min))
        .unwrap();
    println!(
        "\nbest collocation run: {} at {:.1}m = {:+.1}% vs Exclusive {:.1}m (paper: LUG/MAGM(80%,5GB) ~ -28%)",
        best.0,
        best.1.report.trace_total_min,
        -(excl.trace_total_min - best.1.report.trace_total_min) / excl.trace_total_min * 100.0,
        excl.trace_total_min
    );
    Ok(())
}
