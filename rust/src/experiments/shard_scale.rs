//! Shard-scale sweep (DESIGN.md §9/§10): how the sharded coordinator
//! removes the serial select→observe→map bottleneck that `repro
//! cluster_scale` quantifies, and how the parallel engine turns shard
//! count into wall-clock speedup.
//!
//! Fixed substrate (8 servers × 4 GPUs, the 256-task cluster trace), two
//! knobs: `coordinator.shards` ∈ {1, 2, 4, 8} × `engine.threads` ∈ {1, 4}.
//! One shard is the paper's serial pipeline — mapping throughput capped at
//! one decision per 60 s observation window; K shards hold K windows open
//! concurrently, so makespan and mean queueing delay fall near-linearly
//! until the cluster's own capacity (not the coordinator) becomes the
//! binding constraint. Engine threads change *only* the wall-clock column:
//! the sweep asserts the simulated results are bit-identical across thread
//! counts at every shard level (the §10 conservative-commit guarantee).

use std::time::Instant;

use crate::config::schema::{CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind};
use crate::coordinator::carma::run_trace;
use crate::estimators;
use crate::metrics::report::RunReport;
use crate::util::json::{self, Json};
use crate::workload::trace::trace_cluster;

use super::common::{improvement_pct, save_json, zoo, DEFAULT_SEED};

/// Shard counts swept (1 = the serial baseline).
pub const SHARD_SWEEP: &[usize] = &[1, 2, 4, 8];
/// Engine thread counts swept (1 = the serial engine).
pub const THREAD_SWEEP: &[usize] = &[1, 4];
pub const SERVERS: usize = 8;
pub const GPUS_PER_SERVER: usize = 4;
/// Same load the cluster-scale sweep puts on the 32-GPU pool.
pub const TASKS: usize = 256;

struct SweepRow {
    shards: usize,
    threads: usize,
    report: RunReport,
    events: u64,
    wall_s: f64,
}

fn one_run(shards: usize, threads: usize, artifacts_dir: &str) -> Result<SweepRow, String> {
    let mut cfg = CarmaConfig::default();
    cfg.cluster = ClusterConfig::homogeneous(SERVERS, GPUS_PER_SERVER, 40.0);
    cfg.policy = PolicyKind::Magm;
    cfg.estimator = EstimatorKind::Oracle;
    cfg.safety_margin_gb = 2.0;
    cfg.coordinator.shards = shards;
    cfg.engine.threads = threads;
    cfg.artifacts_dir = artifacts_dir.to_string();

    let z = zoo();
    let trace = trace_cluster(&z, TASKS, cfg.cluster.total_gpus(), DEFAULT_SEED);
    let est = estimators::build(cfg.estimator, artifacts_dir)?;
    let label = format!("{shards}-shard/{threads}-thread MAGM+MPS+oracle");
    let t0 = Instant::now();
    let out = run_trace(cfg, est, &trace, &label);
    let wall_s = t0.elapsed().as_secs_f64();
    if out.report.completed != out.report.total_tasks {
        return Err(format!(
            "{label}: {}/{} tasks completed",
            out.report.completed, out.report.total_tasks
        ));
    }
    Ok(SweepRow {
        shards,
        threads,
        report: out.report,
        events: out.events,
        wall_s,
    })
}

pub fn run(artifacts_dir: &str) -> Result<(), String> {
    println!(
        "Shard scale: {SERVERS}×{GPUS_PER_SERVER} GPUs, {TASKS} tasks, seed {DEFAULT_SEED} \
         (MAGM+MPS+oracle, shards ∈ {SHARD_SWEEP:?} × engine threads ∈ {THREAD_SWEEP:?})\n"
    );
    println!(
        "{:<8} {:>8} {:>9} {:>9} {:>9} {:>6} {:>10} {:>12} {:>9}",
        "shards", "threads", "total(m)", "wait(m)", "JCT(m)", "#OOM", "decisions", "dec/sim-min", "wall(s)"
    );

    let mut rows: Vec<SweepRow> = Vec::new();
    for &shards in SHARD_SWEEP {
        let mut makespan_bits: Option<u64> = None;
        for &threads in THREAD_SWEEP {
            let row = one_run(shards, threads, artifacts_dir)?;
            let decisions = row.report.total_decisions();
            println!(
                "{:<8} {:>8} {:>9.1} {:>9.1} {:>9.1} {:>6} {:>10} {:>12.2} {:>9.2}",
                row.shards,
                row.threads,
                row.report.trace_total_min,
                row.report.avg_waiting_min,
                row.report.avg_jct_min,
                row.report.oom_crashes,
                decisions,
                decisions as f64 / row.report.trace_total_min.max(1e-9),
                row.wall_s,
            );
            // the §10 guarantee, enforced on every sweep point: threads
            // change wall-clock only, never the simulated outcome
            let bits = row.report.trace_total_min.to_bits();
            match makespan_bits {
                None => makespan_bits = Some(bits),
                Some(b) => {
                    if b != bits {
                        return Err(format!(
                            "{shards} shards: {threads} engine threads changed the results"
                        ));
                    }
                }
            }
            rows.push(row);
        }
    }

    let base = &rows[0];
    for row in rows.iter().filter(|r| r.threads == 1).skip(1) {
        println!(
            "  {}→{} shards: makespan {:+.1}%, mean queueing delay {:+.1}%",
            base.shards,
            row.shards,
            -improvement_pct(base.report.trace_total_min, row.report.trace_total_min),
            -improvement_pct(base.report.avg_waiting_min, row.report.avg_waiting_min),
        );
    }
    for pair in rows.chunks(THREAD_SWEEP.len()) {
        if let [serial, threaded] = pair {
            println!(
                "  {} shards: engine threads {}→{} wall-clock x{:.2}",
                serial.shards,
                serial.threads,
                threaded.threads,
                serial.wall_s / threaded.wall_s.max(1e-9),
            );
        }
    }

    let out_rows: Vec<Json> = rows
        .iter()
        .map(|row| {
            let mut j = row.report.to_json();
            j.set("shards", json::num(row.shards as f64));
            j.set("threads", json::num(row.threads as f64));
            j.set("decisions", json::num(row.report.total_decisions() as f64));
            j.set("events", json::num(row.events as f64));
            j.set("wall_s", json::num(row.wall_s));
            j
        })
        .collect();
    save_json("shard_scale", artifacts_dir, &json::arr(out_rows));
    println!(
        "\nReading: overlapping observation windows lift the 1-decision-per-\n\
         minute cap; queueing delay scales down with shard count until the\n\
         GPUs themselves (capacity + interference), not the coordinator,\n\
         bound the makespan. Engine threads shrink only the wall(s) column —\n\
         the conservative (time, seq) commit keeps results bit-identical."
    );
    Ok(())
}
