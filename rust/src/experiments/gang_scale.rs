//! Gang-scale study (DESIGN.md §11): what fabric-aware all-or-nothing
//! gang scheduling buys over the server-local-only baseline.
//!
//! Fixed substrate (4 servers × 4 GPUs), a 96-task mixed trace where every
//! 12th submission is an 8-wide distributed job (`n_gpus >
//! gpus_per_server`, so it *cannot* exist under the old server-local
//! constraint). Two systems:
//!
//! * **gang** — the fabric + gang subsystem places the 8-wide jobs across
//!   two servers with all-or-nothing reservations;
//! * **server-local baseline** — the same workload with each distributed
//!   job shrunk to the largest single server (4 GPUs at 2× the wall time:
//!   identical GPU-seconds, `workload::trace::server_localize`), which is
//!   what a user must do when the manager cannot gang-schedule.
//!
//! The sweep also re-proves the determinism guarantees on the gang path:
//! byte-identical results JSON across engine threads {1, 4} at shards
//! ∈ {1, 4}, and zero `partial_dispatches` everywhere (the all-or-nothing
//! invariant is observable in the JSON, not just asserted in tests).

use std::time::Instant;

use crate::config::schema::{CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind};
use crate::coordinator::carma::run_trace;
use crate::estimators;
use crate::metrics::report::RunReport;
use crate::util::json::{self, Json};
use crate::workload::trace::{server_localize, trace_gang, TraceSpec};

use super::common::{improvement_pct, save_json, zoo, DEFAULT_SEED};

pub const SERVERS: usize = 4;
pub const GPUS_PER_SERVER: usize = 4;
pub const TASKS: usize = 96;
/// Distributed jobs are twice as wide as a server: spanning is mandatory.
pub const GANG_GPUS: usize = 2 * GPUS_PER_SERVER;
const SHARD_SWEEP: &[usize] = &[1, 4];
const THREAD_SWEEP: &[usize] = &[1, 4];

fn cfg(shards: usize, threads: usize, artifacts_dir: &str) -> CarmaConfig {
    let mut cfg = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    cfg.cluster = ClusterConfig::homogeneous(SERVERS, GPUS_PER_SERVER, 40.0);
    cfg.coordinator.shards = shards;
    cfg.engine.threads = threads;
    cfg.artifacts_dir = artifacts_dir.to_string();
    cfg
}

struct Row {
    system: &'static str,
    shards: usize,
    threads: usize,
    report: RunReport,
    events: u64,
    wall_s: f64,
}

fn one_run(
    system: &'static str,
    trace: &TraceSpec,
    shards: usize,
    threads: usize,
    artifacts_dir: &str,
) -> Result<Row, String> {
    let c = cfg(shards, threads, artifacts_dir);
    let est = estimators::build(c.estimator, artifacts_dir)?;
    // threads stay OUT of the label: the label is embedded in the results
    // JSON, and the thread sweep asserts that JSON is byte-identical
    let label = format!("{system}/{shards}-shard");
    let t0 = Instant::now();
    let out = run_trace(c, est, trace, &label);
    let wall_s = t0.elapsed().as_secs_f64();
    if out.report.completed != out.report.total_tasks {
        return Err(format!(
            "{label}: {}/{} tasks completed",
            out.report.completed, out.report.total_tasks
        ));
    }
    if out.report.gang.partial_dispatches != 0 {
        return Err(format!(
            "{label}: {} partial gang dispatches — all-or-nothing violated",
            out.report.gang.partial_dispatches
        ));
    }
    Ok(Row {
        system,
        shards,
        threads,
        report: out.report,
        events: out.events,
        wall_s,
    })
}

pub fn run(artifacts_dir: &str) -> Result<(), String> {
    println!(
        "Gang scale: {SERVERS}×{GPUS_PER_SERVER} GPUs, {TASKS} tasks ({}x {GANG_GPUS}-wide gangs), \
         seed {DEFAULT_SEED}\n(MAGM+MPS+oracle; baseline = each gang shrunk to one server at 2× \
         wall time)\n",
        TASKS / 12
    );
    println!(
        "{:<26} {:>7} {:>8} {:>9} {:>9} {:>11} {:>10} {:>6} {:>9}",
        "system", "shards", "threads", "total(m)", "wait(m)", "gang-wait(m)", "x-server", "frag", "wall(s)"
    );

    let z = zoo();
    let total_gpus = SERVERS * GPUS_PER_SERVER;
    let gang_trace = trace_gang(&z, TASKS, total_gpus, GANG_GPUS, DEFAULT_SEED);
    let local_trace = server_localize(&gang_trace, GPUS_PER_SERVER);

    let mut rows: Vec<Row> = Vec::new();
    for &shards in SHARD_SWEEP {
        let mut json_bits: Option<String> = None;
        for &threads in THREAD_SWEEP {
            let row = one_run("gang", &gang_trace, shards, threads, artifacts_dir)?;
            print_row(&row);
            // the §10 guarantee on the gang path: engine threads change
            // wall-clock only — the full results JSON must be byte-equal
            let j = row.report.to_json().to_string_pretty();
            match &json_bits {
                None => json_bits = Some(j),
                Some(prev) => {
                    if *prev != j {
                        return Err(format!(
                            "{shards} shards: {threads} engine threads changed the gang results"
                        ));
                    }
                }
            }
            rows.push(row);
        }
    }
    let baseline = one_run("server-local", &local_trace, 1, 1, artifacts_dir)?;
    print_row(&baseline);

    let gang_serial = &rows[0];
    let g = &gang_serial.report.gang;
    if g.cross_server == 0 || g.max_servers_spanned < 2 {
        return Err("no gang was placed across servers — the fabric lift is not engaging".into());
    }
    let speedup = improvement_pct(
        baseline.report.trace_total_min,
        gang_serial.report.trace_total_min,
    );
    println!(
        "\n  {} gangs placed cross-server (max span {} servers, frag excess {});\n  \
         makespan: gang {:.1} m vs server-local {:.1} m ({:+.1}%)",
        g.cross_server,
        g.max_servers_spanned,
        g.frag_excess,
        gang_serial.report.trace_total_min,
        baseline.report.trace_total_min,
        -speedup,
    );
    if gang_serial.report.trace_total_min >= baseline.report.trace_total_min {
        return Err(format!(
            "gang scheduling must strictly beat the server-local baseline: \
             {:.2} m !< {:.2} m",
            gang_serial.report.trace_total_min, baseline.report.trace_total_min
        ));
    }

    let out_rows: Vec<Json> = rows
        .iter()
        .chain(std::iter::once(&baseline))
        .map(|row| {
            let mut j = row.report.to_json();
            j.set("system", json::s(row.system));
            j.set("shards", json::num(row.shards as f64));
            j.set("threads", json::num(row.threads as f64));
            j.set("events", json::num(row.events as f64));
            j.set("wall_s", json::num(row.wall_s));
            j
        })
        .collect();
    save_json("gang_scale", artifacts_dir, &json::arr(out_rows));
    println!(
        "\nReading: lifting the server-local cap lets {GANG_GPUS}-wide jobs run at full\n\
         width across two servers — they pay the fabric's sync + NIC terms but\n\
         finish roughly twice as fast as their shrunken server-local versions,\n\
         and the all-or-nothing holds keep singleton backfill flowing around\n\
         pending gangs (zero partial dispatches, bit-identical across threads)."
    );
    Ok(())
}

fn print_row(row: &Row) {
    let g = &row.report.gang;
    println!(
        "{:<26} {:>7} {:>8} {:>9.1} {:>9.1} {:>11.1} {:>10} {:>6} {:>9.2}",
        row.system,
        row.shards,
        row.threads,
        row.report.trace_total_min,
        row.report.avg_waiting_min,
        g.mean_wait_min,
        g.cross_server,
        g.frag_excess,
        row.wall_s,
    );
}
