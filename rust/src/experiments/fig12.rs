//! Fig. 12 — GPU0 memory / SM activity / power over time (paper §5.6):
//! Exclusive vs MAGM+GPUMemNet+SMACT<=80% on the 60-task trace.

use crate::config::schema::{CollocationMode, EstimatorKind, PolicyKind};
use crate::metrics::recorder::TimelinePoint;
use crate::workload::trace::trace_60;

use super::common::{exclusive, run_grid, save_csv, zoo, RunCfg, DEFAULT_SEED};

pub fn run(artifacts_dir: &str) -> Result<(), String> {
    let z = zoo();
    let trace = trace_60(&z, DEFAULT_SEED);
    println!("Fig. 12: GPU0 resource usage over time, Exclusive vs MAGM+GPUMemNet(80%)\n");
    let runs = vec![
        exclusive(),
        RunCfg::new(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::GpuMemNet).smact(0.80),
    ];
    let out = run_grid(&trace, &runs, artifacts_dir);

    for (name, (label, o)) in ["exclusive", "magm_gpumemnet"].iter().zip(&out) {
        let tl = &o.recorder.timelines[0];
        let rows: Vec<String> = tl
            .iter()
            .map(|p| format!("{:.0},{:.3},{:.4},{:.1}", p.t, p.mem_used_gb, p.smact, p.power_w))
            .collect();
        save_csv(
            &format!("fig12_{name}"),
            artifacts_dir,
            "t_s,mem_used_gb,smact,power_w",
            &rows,
        );
        println!("\n--- {label}: GPU0 SMACT over time (ascii) ---");
        ascii_timeline(tl);
    }

    let excl = &out[0].1.report;
    let magm = &out[1].1.report;
    println!(
        "\nmean GPU utilization: Exclusive {:.1}% -> MAGM+GPUMemNet {:.1}% ({:+.1}% relative; paper: +39.3%)",
        excl.mean_smact * 100.0,
        magm.mean_smact * 100.0,
        (magm.mean_smact - excl.mean_smact) / excl.mean_smact * 100.0
    );
    println!(
        "mean GPU memory in use: {:.1} GB -> {:.1} GB; trace shortens {:.0}m -> {:.0}m",
        excl.mean_mem_used_gb, magm.mean_mem_used_gb, excl.trace_total_min, magm.trace_total_min
    );
    Ok(())
}

fn ascii_timeline(tl: &[TimelinePoint]) {
    // ~60 columns over the whole run
    if tl.is_empty() {
        return;
    }
    let cols = 60usize;
    let step = (tl.len() / cols).max(1);
    let mut smact_line = String::new();
    let mut mem_line = String::new();
    for chunk in tl.chunks(step).take(cols) {
        let s: f64 = chunk.iter().map(|p| p.smact).sum::<f64>() / chunk.len() as f64;
        let m: f64 = chunk.iter().map(|p| p.mem_used_gb).sum::<f64>() / chunk.len() as f64;
        smact_line.push(shade(s));
        mem_line.push(shade(m / 40.0));
    }
    println!("SMACT |{smact_line}|");
    println!("MEM   |{mem_line}| (40GB full scale)");
}

fn shade(x: f64) -> char {
    match (x * 5.0) as i64 {
        i64::MIN..=0 => ' ',
        1 => '.',
        2 => ':',
        3 => '+',
        4 => '#',
        _ => '@',
    }
}
