//! Service-scale study (DESIGN.md §13): open-loop arrival-driven scheduling
//! with bounded admission and load shedding.
//!
//! Fixed substrate (4 servers × 4 GPUs, MAGM+MPS+oracle), three arrival
//! processes — homogeneous Poisson, diurnal (sine-modulated) and bursty
//! flash-crowd — each swept over coordinator shards {1, 4} × engine
//! threads {1, 4} at a **saturating** offered rate against a small
//! per-shard queue cap, plus one low-rate control run per process.
//!
//! The study asserts the acceptance criteria:
//!
//! * the results JSON is byte-identical across engine threads within every
//!   (process, shards) cell — the §10 guarantee extended over the arrival
//!   generator, the shed path and the windowed steady-state metrics;
//! * the saturating rate sheds a nonzero number of arrivals under every
//!   process, and every shed is terminal (never dispatched);
//! * the low-rate control sheds nothing and completes everything admitted.
//!
//! The per-process steady-state summary is appended to the `BENCH_sim.json`
//! perf ledger under `service_scale`.

use std::time::Instant;

use crate::bench;
use crate::config::schema::{ArrivalKind, CarmaConfig, ClusterConfig, EstimatorKind, PolicyKind};
use crate::coordinator::carma::run_service;
use crate::estimators;
use crate::metrics::report::RunReport;
use crate::util::json::{self, Json};

use super::common::{save_json, DEFAULT_SEED};

pub const SERVERS: usize = 4;
pub const GPUS_PER_SERVER: usize = 4;
/// Saturating offered load: well beyond what 16 GPUs drain with a
/// per-shard queue cap of 4, so the shedder must engage.
pub const HOT_RATE_PER_MIN: f64 = 60.0;
/// Control load: a handful of tasks against a deep queue — nothing sheds.
pub const LOW_RATE_PER_MIN: f64 = 1.0;
pub const DURATION_S: f64 = 600.0;
pub const HOT_QUEUE_CAP: usize = 4;
const LOW_QUEUE_CAP: usize = 64;
const KINDS: &[ArrivalKind] = &[ArrivalKind::Poisson, ArrivalKind::Diurnal, ArrivalKind::Burst];
const SHARD_SWEEP: &[usize] = &[1, 4];
const THREAD_SWEEP: &[usize] = &[1, 4];

fn cfg(
    kind: ArrivalKind,
    rate_per_min: f64,
    queue_cap: usize,
    shards: usize,
    threads: usize,
    artifacts_dir: &str,
) -> CarmaConfig {
    let mut cfg = CarmaConfig {
        policy: PolicyKind::Magm,
        estimator: EstimatorKind::Oracle,
        safety_margin_gb: 2.0,
        ..Default::default()
    };
    cfg.cluster = ClusterConfig::homogeneous(SERVERS, GPUS_PER_SERVER, 40.0);
    cfg.coordinator.shards = shards;
    cfg.engine.threads = threads;
    cfg.service.arrivals = Some(kind);
    cfg.service.rate_per_min = rate_per_min;
    cfg.service.duration_s = DURATION_S;
    cfg.service.queue_cap = queue_cap;
    cfg.service.seed = DEFAULT_SEED;
    cfg.artifacts_dir = artifacts_dir.to_string();
    cfg
}

struct Row {
    kind: ArrivalKind,
    rate_per_min: f64,
    shards: usize,
    threads: usize,
    report: RunReport,
    events: u64,
    wall_s: f64,
}

fn one_run(
    kind: ArrivalKind,
    rate_per_min: f64,
    queue_cap: usize,
    shards: usize,
    threads: usize,
    artifacts_dir: &str,
) -> Result<Row, String> {
    let c = cfg(kind, rate_per_min, queue_cap, shards, threads, artifacts_dir);
    let est = estimators::build(c.estimator, artifacts_dir)?;
    // threads stay OUT of the label: the label is embedded in the results
    // JSON, and the thread sweep asserts that JSON is byte-identical
    let label = format!("{}/{shards}-shard", kind.name());
    let t0 = Instant::now();
    let out = run_service(c, est, &label);
    let wall_s = t0.elapsed().as_secs_f64();
    let s = &out.report.service;
    if !s.open_loop {
        return Err(format!("{label}: report is not flagged open-loop"));
    }
    if s.offered == 0 {
        return Err(format!("{label}: the generator emitted no arrivals"));
    }
    // every offered task must be terminal: completed, failed, or shed
    let terminal = out.report.completed + out.recorder.failed_total as usize + s.shed as usize;
    if terminal != s.offered {
        return Err(format!(
            "{label}: {terminal} terminal of {} offered — the drain leaked tasks",
            s.offered
        ));
    }
    // a shed task is terminal at the door: it can never have dispatched
    for t in &out.recorder.tasks {
        if t.shed_s.is_some() && t.dispatched_s.is_some() {
            return Err(format!("{label}: a shed task was also dispatched"));
        }
    }
    Ok(Row {
        kind,
        rate_per_min,
        shards,
        threads,
        report: out.report,
        events: out.events,
        wall_s,
    })
}

pub fn run(artifacts_dir: &str) -> Result<(), String> {
    println!(
        "Service scale: {SERVERS}×{GPUS_PER_SERVER} GPUs, open-loop arrivals for {DURATION_S:.0}s, \
         seed {DEFAULT_SEED}\n\
         (MAGM+MPS+oracle; saturating {HOT_RATE_PER_MIN:.0}/min vs control {LOW_RATE_PER_MIN:.0}/min, \
         queue cap {HOT_QUEUE_CAP} vs {LOW_QUEUE_CAP})\n"
    );
    println!(
        "{:<24} {:>7} {:>8} {:>8} {:>6} {:>7} {:>8} {:>9} {:>9} {:>9}",
        "process", "shards", "threads", "offered", "shed", "reject", "p50(s)", "p99(s)", "smact", "wall(s)"
    );

    let mut rows: Vec<Row> = Vec::new();
    for &kind in KINDS {
        for &shards in SHARD_SWEEP {
            let mut json_bits: Option<String> = None;
            for &threads in THREAD_SWEEP {
                let row = one_run(
                    kind,
                    HOT_RATE_PER_MIN,
                    HOT_QUEUE_CAP,
                    shards,
                    threads,
                    artifacts_dir,
                )?;
                print_row(&row);
                // the §10 guarantee over the open-loop path: engine threads
                // change wall-clock only — results JSON must be byte-equal
                let j = row.report.to_json().to_string_pretty();
                match &json_bits {
                    None => json_bits = Some(j),
                    Some(prev) => {
                        if *prev != j {
                            return Err(format!(
                                "{}/{shards} shards: {threads} engine threads changed \
                                 the open-loop results",
                                kind.name()
                            ));
                        }
                    }
                }
                if row.report.service.shed == 0 {
                    return Err(format!(
                        "{}/{shards} shards: saturating rate shed nothing",
                        kind.name()
                    ));
                }
                rows.push(row);
            }
        }
        // low-rate control: the queue never fills, so nothing may shed
        let control = one_run(kind, LOW_RATE_PER_MIN, LOW_QUEUE_CAP, 1, 1, artifacts_dir)?;
        print_row(&control);
        if control.report.service.shed != 0 {
            return Err(format!(
                "{}: low-rate control shed {} arrivals",
                kind.name(),
                control.report.service.shed
            ));
        }
        rows.push(control);
    }

    let out_rows: Vec<Json> = rows
        .iter()
        .map(|row| {
            let mut j = row.report.to_json();
            j.set("process", json::s(row.kind.name()));
            j.set("rate_per_min", json::num(row.rate_per_min));
            j.set("shards", json::num(row.shards as f64));
            j.set("threads", json::num(row.threads as f64));
            j.set("events", json::num(row.events as f64));
            j.set("wall_s", json::num(row.wall_s));
            j
        })
        .collect();
    save_json("service_scale", artifacts_dir, &json::arr(out_rows));

    // perf-ledger rows: one steady-state summary per arrival process at the
    // saturating rate (BENCH_sim.json accumulates across PRs)
    let ledger: Vec<Json> = KINDS
        .iter()
        .map(|&kind| {
            let r = rows
                .iter()
                .find(|r| r.kind == kind && r.rate_per_min == HOT_RATE_PER_MIN)
                .expect("hot rows exist");
            let s = &r.report.service;
            json::obj(vec![
                ("process", json::s(kind.name())),
                ("servers", json::num(SERVERS as f64)),
                ("gpus_per_server", json::num(GPUS_PER_SERVER as f64)),
                ("rate_per_min", json::num(HOT_RATE_PER_MIN)),
                ("duration_s", json::num(DURATION_S)),
                ("queue_cap", json::num(HOT_QUEUE_CAP as f64)),
                ("seed", json::num(DEFAULT_SEED as f64)),
                ("offered", json::num(s.offered as f64)),
                ("shed", json::num(s.shed as f64)),
                ("rejection_rate", json::num(s.rejection_rate)),
                ("queue_delay_p50_s", json::num(s.queue_delay_p50_s)),
                ("queue_delay_p99_s", json::num(s.queue_delay_p99_s)),
                ("win_smact_mean", json::num(s.win_smact_mean)),
                ("events", json::num(r.events as f64)),
                ("wall_s", json::num(r.wall_s)),
            ])
        })
        .collect();
    bench::save_bench_section("service_scale", ledger);

    println!(
        "\nReading: the open-loop intake turns the simulator into a service —\n\
         arrivals stream from a seeded generator, bounded per-shard queues\n\
         shed deterministically under saturation, and the steady-state\n\
         summary (rejection rate, queueing-delay percentiles, windowed\n\
         utilization) stays byte-identical at every shard and thread count."
    );
    Ok(())
}

fn print_row(row: &Row) {
    let s = &row.report.service;
    println!(
        "{:<24} {:>7} {:>8} {:>8} {:>6} {:>7.3} {:>8.1} {:>9.1} {:>9.3} {:>9.2}",
        format!("{}@{:.0}/min", row.kind.name(), row.rate_per_min),
        row.shards,
        row.threads,
        s.offered,
        s.shed,
        s.rejection_rate,
        s.queue_delay_p50_s,
        s.queue_delay_p99_s,
        s.win_smact_mean,
        row.wall_s,
    );
}
