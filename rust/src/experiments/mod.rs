//! Experiment harness (S15): one module per paper table/figure.
//!
//! Every entry regenerates the corresponding result with
//! `carma repro <id>` and drops machine-readable output under
//! `artifacts/results/` (DESIGN.md §4 maps ids to modules).

pub mod chaos_scale; // beyond the paper: fault injection + goodput degradation (DESIGN.md §15)
pub mod cluster_scale; // beyond the paper: N-server scaling sweep
pub mod common;
pub mod engine_scale; // beyond the paper: delta views + arena event core at 10⁶ tasks (DESIGN.md §17)
pub mod gang_scale; // beyond the paper: fabric-aware gang scheduling (DESIGN.md §11)
pub mod obs_overhead; // beyond the paper: observability tax gate (DESIGN.md §14)
pub mod placement_scale; // beyond the paper: island-aware singleton placement (DESIGN.md §12)
pub mod service_scale; // beyond the paper: open-loop service mode + load shedding (DESIGN.md §13)
pub mod shard_scale; // beyond the paper: sharded-coordinator sweep (DESIGN.md §9)
pub mod trace_analyze; // beyond the paper: trace-native analysis gates (DESIGN.md §16)
pub mod estimation; // fig1, fig2, fig6, table1, fig3, fig4
pub mod fig12;
pub mod fig8;
pub mod recovery; // table4 + fig9
pub mod sixty; // table6 + fig11 + table7
pub mod table5; // table5 + fig10

/// All experiment ids: the paper's tables/figures in paper order, then the
/// repo's own scaling studies.
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "table1", "fig6", "fig8", "table4", "fig9", "table5",
    "fig10", "table6", "fig11", "fig12", "table7", "cluster_scale", "shard_scale",
    "gang_scale", "placement_scale", "service_scale", "obs_overhead", "chaos_scale",
    "trace_analyze", "engine_scale",
];

/// Dispatch one experiment by id. `artifacts_dir` must contain the AOT
/// artifacts for GPUMemNet-dependent experiments.
pub fn run(id: &str, artifacts_dir: &str) -> Result<(), String> {
    match id {
        "fig1" => estimation::fig1(artifacts_dir),
        "fig2" => estimation::fig2(artifacts_dir),
        "fig3" => estimation::fig3(artifacts_dir),
        "fig4" => estimation::fig4(artifacts_dir),
        "table1" => estimation::table1(artifacts_dir),
        "fig6" => estimation::fig6(artifacts_dir),
        "fig8" => fig8::run(artifacts_dir),
        "table4" => recovery::table4(artifacts_dir),
        "fig9" => recovery::fig9(artifacts_dir),
        "table5" => table5::table5(artifacts_dir),
        "fig10" => table5::fig10(artifacts_dir),
        "table6" => sixty::table6(artifacts_dir),
        "fig11" => sixty::fig11(artifacts_dir),
        "fig12" => fig12::run(artifacts_dir),
        "table7" => sixty::table7(artifacts_dir),
        "cluster_scale" => cluster_scale::run(artifacts_dir),
        "shard_scale" => shard_scale::run(artifacts_dir),
        "gang_scale" => gang_scale::run(artifacts_dir),
        "placement_scale" => placement_scale::run(artifacts_dir),
        "service_scale" => service_scale::run(artifacts_dir),
        "obs_overhead" => obs_overhead::run(artifacts_dir),
        "chaos_scale" => chaos_scale::run(artifacts_dir),
        "trace_analyze" => trace_analyze::run(artifacts_dir),
        "engine_scale" => engine_scale::run(artifacts_dir),
        "all" => {
            for id in ALL {
                println!("\n================ {id} ================");
                run(id, artifacts_dir)?;
            }
            Ok(())
        }
        other => Err(format!(
            "unknown experiment '{other}' (known: {} or 'all')",
            ALL.join(", ")
        )),
    }
}
