//! Table 6 + Fig. 11 + Table 7 — the heavier 60-task trace (paper §5.5) and
//! its energy accounting (§5.6).

use crate::config::schema::{CollocationMode, EstimatorKind, PolicyKind};
use crate::coordinator::carma::RunOutcome;
use crate::metrics::report::RunReport;
use crate::util::json;
use crate::workload::trace::trace_60;

use super::common::{exclusive, run_grid, save_json, save_results, zoo, RunCfg, DEFAULT_SEED};

/// The exclusive baseline (first row) and the GPUMemNet run (last row) of a
/// comparison grid. A grid edit that leaves fewer than two runs must surface
/// as a proper error — the old `out.last().unwrap()` aborted the whole repro
/// sweep on an empty grid instead.
fn first_last(out: &[(String, RunOutcome)]) -> Result<(&RunReport, &RunReport), String> {
    if out.len() < 2 {
        return Err(format!(
            "comparison grid needs at least 2 runs (baseline + candidate), got {}",
            out.len()
        ));
    }
    let first = out.first().expect("len checked");
    let last = out.last().expect("len checked");
    Ok((&first.1.report, &last.1.report))
}

fn grid() -> Vec<RunCfg> {
    vec![
        exclusive(),
        RunCfg::new(PolicyKind::RoundRobin, CollocationMode::Streams, EstimatorKind::None),
        RunCfg::new(PolicyKind::RoundRobin, CollocationMode::Mps, EstimatorKind::None),
        RunCfg::new(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::None)
            .smact(0.80)
            .min_free(2.0),
        RunCfg::new(PolicyKind::Lug, CollocationMode::Mps, EstimatorKind::None)
            .smact(0.80)
            .min_free(2.0),
        RunCfg::new(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::Horus).smact(0.80),
        RunCfg::new(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::FakeTensor).smact(0.80),
        RunCfg::new(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::GpuMemNet).smact(0.80),
    ]
}

/// Table 6 — #OOM on the heavy trace.
pub fn table6(artifacts_dir: &str) -> Result<(), String> {
    let z = zoo();
    let trace = trace_60(&z, DEFAULT_SEED);
    println!("Table 6: OOM errors on the heavier 60-task trace\n");
    let out = run_grid(&trace, &grid(), artifacts_dir);
    save_results("table6", artifacts_dir, &out);

    println!("\n{:<44} {:>12}", "Policy", "#OOM Crashes");
    for (label, o) in &out {
        println!("{:<44} {:>12}", label, o.report.oom_crashes);
    }
    let (excl, gmn) = first_last(&out)?;
    assert_eq!(excl.oom_crashes, 0);
    println!(
        "\nGPUMemNet run: {} OOMs (paper: 1, the fewest among collocating runs)",
        gmn.oom_crashes
    );
    Ok(())
}

/// Fig. 11 — timing on the 60-task trace.
pub fn fig11(artifacts_dir: &str) -> Result<(), String> {
    let z = zoo();
    let trace = trace_60(&z, DEFAULT_SEED);
    println!("Fig. 11: policies, estimators and preconditions on the 60-task trace\n");
    let out = run_grid(&trace, &grid(), artifacts_dir);
    save_results("fig11", artifacts_dir, &out);

    let (excl, gmn) = first_last(&out)?;
    println!(
        "\nMAGM+GPUMemNet(80%) vs Exclusive: total {:+.1}% (paper: -26.7%), exec {:+.1}% \
         (paper: increases), waiting {:+.1}% (paper: large reduction)",
        -(excl.trace_total_min - gmn.trace_total_min) / excl.trace_total_min * 100.0,
        (gmn.avg_execution_min - excl.avg_execution_min) / excl.avg_execution_min * 100.0,
        -(excl.avg_waiting_min - gmn.avg_waiting_min) / excl.avg_waiting_min * 100.0,
    );
    Ok(())
}

/// Table 7 — accumulated 4-GPU energy per policy.
pub fn table7(artifacts_dir: &str) -> Result<(), String> {
    let z = zoo();
    let trace = trace_60(&z, DEFAULT_SEED);
    println!("Table 7: energy consumption under different policies (60-task trace)\n");
    let runs = vec![
        exclusive(),
        RunCfg::new(PolicyKind::RoundRobin, CollocationMode::Streams, EstimatorKind::None),
        RunCfg::new(PolicyKind::RoundRobin, CollocationMode::Mps, EstimatorKind::None),
        RunCfg::new(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::None)
            .smact(0.80)
            .min_free(2.0),
        RunCfg::new(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::Horus).smact(0.80),
        RunCfg::new(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::FakeTensor).smact(0.80),
        RunCfg::new(PolicyKind::Magm, CollocationMode::Mps, EstimatorKind::GpuMemNet).smact(0.80),
    ];
    let out = run_grid(&trace, &runs, artifacts_dir);
    save_results("table7", artifacts_dir, &out);

    println!("\n{:<44} {:>22}", "Policy", "Energy Consumption (MJ)");
    for (label, o) in &out {
        println!("{:<44} {:>22.2}", label, o.report.energy_mj);
    }
    let (excl, gmn) = first_last(&out)?;
    let red = (excl.energy_mj - gmn.energy_mj) / excl.energy_mj * 100.0;
    println!(
        "\nMAGM+GPUMemNet on MPS: {:.2} MJ vs Exclusive {:.2} MJ = -{red:.1}% \
         (paper: 28.5 vs 33.2 MJ, -14.16%)",
        gmn.energy_mj, excl.energy_mj
    );
    save_json(
        "table7_summary",
        artifacts_dir,
        &json::obj(vec![
            ("exclusive_mj", json::num(excl.energy_mj)),
            ("gpumemnet_mj", json::num(gmn.energy_mj)),
            ("reduction_pct", json::num(red)),
        ]),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::recorder::Recorder;

    fn outcome(label: &str) -> (String, RunOutcome) {
        let r = Recorder::new(0, 0);
        let report = RunReport::from_recorder(label, &r);
        let out = RunOutcome {
            report,
            recorder: r,
            events: 0,
            profile: None,
            view_stats: Default::default(),
            engine_stats: Default::default(),
        };
        (label.to_string(), out)
    }

    #[test]
    fn first_last_rejects_degenerate_grids() {
        // regression: table7 used `out.last().unwrap()` and aborted on an
        // empty grid; degenerate grids must be errors, not panics
        let empty: Vec<(String, RunOutcome)> = Vec::new();
        assert!(first_last(&empty).is_err());
        let one = vec![outcome("only")];
        assert!(first_last(&one).is_err());
    }

    #[test]
    fn first_last_picks_the_grid_ends() {
        let grid = vec![outcome("excl"), outcome("mid"), outcome("gmn")];
        let (first, last) = first_last(&grid).expect("3-run grid is valid");
        assert_eq!(first.label, "excl");
        assert_eq!(last.label, "gmn");
    }
}
